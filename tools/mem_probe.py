"""Bisect per-device temp memory of the train step on the prod mesh."""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=256"
import dataclasses
import sys

sys.path.insert(0, "src")
import jax

from repro.configs import get_config
from repro.configs.base import InputShape
from repro.launch.mesh import make_production_mesh
from repro.launch.train import TrainConfig, init_state, make_train_step
from repro.models import registry


def probe(tag, cfg, seq, batch):
    api = registry.build(cfg)
    shape = InputShape("p", seq, batch, "train")
    batch_shape = registry.input_specs(cfg, shape)
    mesh = make_production_mesh(multi_pod=False)
    with mesh:
        step, _, _ = make_train_step(api, mesh, TrainConfig(), batch_shape)
        state_shape = jax.eval_shape(lambda k: init_state(api, k),
                                     jax.random.PRNGKey(0))
        comp = step.lower(state_shape, batch_shape).compile()
    ma = comp.memory_analysis()
    print(f"{tag:50s} temp={ma.temp_size_in_bytes/1e9:8.2f} GB")


base = get_config("qwen2-0.5b")
probe("L24 s4096 b256 remat=full", base, 4096, 256)
probe("L24 s4096 b256 remat=none",
      dataclasses.replace(base, remat="none"), 4096, 256)
probe("L2 s4096 b256 remat=full",
      dataclasses.replace(base, n_layers=2), 4096, 256)
probe("L24 s1024 b256 remat=full", base, 1024, 256)
probe("L24 s4096 b64 remat=full", base, 4096, 64)
