"""Render a repro.autotune PrecisionPlan as a markdown Pareto report.

    PYTHONPATH=src python tools/plan_report.py results/plans/qwen2_0_5b.json
    PYTHONPATH=src python tools/plan_report.py <plan.json> --out report.md
"""
import argparse


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("plan", help="PrecisionPlan JSON artifact")
    ap.add_argument("--out", default=None,
                    help="write markdown here instead of stdout")
    args = ap.parse_args(argv)

    from repro.autotune.cli import render_report
    from repro.autotune.plan import load_plan
    text = render_report(load_plan(args.plan))
    if args.out:
        with open(args.out, "w") as f:
            f.write(text)
        print(f"report -> {args.out}")
    else:
        print(text, end="")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
