"""Measure the cross-pod gradient-exchange program at production scale.

Lowers parallel.blockfp.make_pod_exchange for a real architecture's full
gradient pytree on the 2x16x16 mesh and compares DCI wire bytes + derived
exchange time for f32 / int8 / blockfp8 — the §Perf collective-term
iteration (the paper's bounded-alignment insight applied to gradient
sync).

    PYTHONPATH=src python tools/exchange_bench.py --arch gemma2-9b
"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

import argparse  # noqa: E402
import json      # noqa: E402
import sys       # noqa: E402

sys.path.insert(0, "src")

import jax                                   # noqa: E402
import jax.numpy as jnp                      # noqa: E402

from repro.configs import get_config         # noqa: E402
from repro.launch.mesh import make_production_mesh  # noqa: E402
from repro.launch.roofline import LINK_BW, parse_collectives  # noqa: E402
from repro.models import registry            # noqa: E402
from repro.parallel.blockfp import make_pod_exchange  # noqa: E402


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="gemma2-9b")
    ap.add_argument("--out", default="results/perf/exchange.json")
    args = ap.parse_args()

    cfg = get_config(args.arch)
    api = registry.build(cfg)
    mesh = make_production_mesh(multi_pod=True)
    n_pods = mesh.shape["pod"]

    param_shape = jax.eval_shape(api.init, jax.random.PRNGKey(0))
    grad_shapes = jax.tree.map(
        lambda l: jax.ShapeDtypeStruct((n_pods,) + l.shape, jnp.float32),
        param_shape)
    n_params = sum(int(jnp.prod(jnp.asarray(l.shape[1:])))
                   for l in jax.tree_util.tree_leaves(grad_shapes))

    results = {"arch": args.arch, "n_params": n_params}
    for method in ("f32", "int8", "blockfp8"):
        fn, in_sh, _ = make_pod_exchange(mesh, grad_shapes, method)
        with mesh:
            compiled = fn.lower(grad_shapes).compile()
        coll = parse_collectives(compiled.as_text(),
                                 default_group=n_pods)
        t = coll.total_bytes / LINK_BW
        results[method] = {
            "per_chip_wire_bytes": coll.total_bytes,
            "exchange_s_at_link_bw": t,
            "by_op": coll.by_op,
        }
        print(f"{args.arch} exchange[{method}]: "
              f"{coll.total_bytes/1e6:.1f} MB/chip wire, "
              f"{t*1e3:.2f} ms at {LINK_BW/1e9:.0f} GB/s")
    base = results["f32"]["per_chip_wire_bytes"]
    for m in ("int8", "blockfp8"):
        results[f"{m}_reduction"] = base / results[m]["per_chip_wire_bytes"]
        print(f"{m}: {results[f'{m}_reduction']:.2f}x less DCI traffic")
    os.makedirs(os.path.dirname(args.out), exist_ok=True)
    with open(args.out, "w") as f:
        json.dump(results, f, indent=1, default=float)


if __name__ == "__main__":
    main()
