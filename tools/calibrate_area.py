"""Fit the area/power Calibration constants to the paper's numbers.

Least-squares over log-ratios of all Table 1 cells plus the §4.2 relative
area deltas and the abstract headline gains. Run:

    PYTHONPATH=src python tools/calibrate_area.py

and paste the printed Calibration into area_power.DEFAULT_CAL.
"""
import dataclasses
import math
import sys

import numpy as np
from scipy.optimize import least_squares

sys.path.insert(0, "src")

from repro.core import area_power as ap  # noqa: E402

PARAMS = ["a_scale", "b_scale", "alpha_add", "alpha_shift", "alpha_reg",
          "alpha_sram", "ctrl_area", "serial_area_factor",
          "serial_power_factor", "beta_mult", "beta_reg", "beta_sram",
          "misc_fraction"]
X0 = [0.1723, 9.64, 1.10, 0.42, 0.65, 0.30, 0.0, 0.5, 1.8,
      1.05e-3, 0.55e-3, 0.25e-3, 0.18]
LOWER = [0.01, 1.0, 0.2, 0.05, 0.1, 0.05, 0.0, 0.1, 1.0,
         0.2e-3, 0.1e-3, 0.05e-3, 0.05]
UPPER = [1.0, 50.0, 4.0, 2.0, 3.0, 1.5, 400.0, 1.5, 4.0,
         4e-3, 3e-3, 2e-3, 0.5]


def make_cal(x):
    kw = dict(zip(PARAMS, x))
    return dataclasses.replace(ap.Calibration(), **kw)


def residuals(x):
    cal = make_cal(x)
    res = []
    model = ap.table1_model(cal)
    for d, row in model.items():
        for wl, (a, p) in row.items():
            pa, pp = ap.PAPER_TABLE1[d][wl]
            if a is None or pa is None:
                continue
            res.append(math.log(a / pa))
            res.append(math.log(p / pp))
    deltas = ap.fig7_deltas(cal)
    for k, target in ap.PAPER_FIG7_DELTAS.items():
        res.append(3.0 * (deltas[k] - target))
    # headline targets: +46% TOPS/mm2, +25% TFLOPS/mm2, +63% TOPS/W,
    # +40% TFLOPS/W for the (16,1) point (paper abstract, 16-input).
    h = ap.headline_gains(1.3, cal)
    targets = {"tops_per_mm2_gain": 0.46, "tflops_per_mm2_gain": 0.25,
               "tops_per_w_gain": 0.63, "tflops_per_w_gain": 0.40}
    for k, t in targets.items():
        res.append(2.0 * (h[k] - t))
    return np.asarray(res)


def main():
    sol = least_squares(residuals, X0, bounds=(LOWER, UPPER),
                        xtol=1e-10, ftol=1e-10, max_nfev=4000)
    cal = make_cal(sol.x)
    print("# fitted Calibration:")
    for k, v in zip(PARAMS, sol.x):
        print(f"    {k}={v:.6g},")
    r = residuals(sol.x)
    print(f"# residual rms={np.sqrt((r**2).mean()):.4f} max={np.abs(r).max():.4f}")
    model = ap.table1_model(cal)
    errs = []
    for d, row in model.items():
        for wl, (a, p) in row.items():
            pa, pp = ap.PAPER_TABLE1[d][wl]
            if a is None:
                continue
            errs += [abs(a / pa - 1), abs(p / pp - 1)]
    print(f"# table1 median |err| {100*np.median(errs):.1f}%  "
          f"max {100*np.max(errs):.1f}%")
    print("# fig7:", {k: round(v, 3) for k, v in ap.fig7_deltas(cal).items()},
          "targets", ap.PAPER_FIG7_DELTAS)
    print("# headline:", {k: round(v, 3)
                          for k, v in ap.headline_gains(1.3, cal).items()})


if __name__ == "__main__":
    main()
