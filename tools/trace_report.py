"""Summarize a serving-engine Chrome trace on the terminal.

``ServingEngine.dump_trace(path)`` (``EngineConfig(trace=True)``)
exports Chrome trace-event JSON — load it graphically at
https://ui.perfetto.dev or ``chrome://tracing``, or render the same
file as a terminal summary here:

    PYTHONPATH=src python tools/trace_report.py /tmp/trace.json

The report validates the schema first (``repro.obs.trace.
validate_chrome_trace``, non-zero exit on errors), then prints:

  * per-phase totals of the engine-tick lane (admission / prefill
    dispatch / block dispatch / host sync / harvest): count, total and
    mean duration, share of the traced wall span;
  * compile events (``compile:*`` spans from ``traced_jit`` plus the
    ``jax_trace:*`` markers the program builders stamp), with the cost
    of each compilation;
  * request lanes: per-stage durations (queued / prefill / decode) of
    each request's B/E pairs and its first-token/finished instants;
  * the top individual spans by duration.
"""
import argparse
import collections
import json
import sys

sys.path.insert(0, "src")

from repro.obs.trace import (REQUEST_LANE_BASE,  # noqa: E402
                             validate_chrome_trace)


def _fmt_us(us: float) -> str:
    if us >= 1e6:
        return f"{us / 1e6:.2f}s"
    if us >= 1e3:
        return f"{us / 1e3:.2f}ms"
    return f"{us:.0f}us"


def load_events(path: str):
    with open(path) as f:
        data = json.load(f)
    errors = validate_chrome_trace(data)
    events = data["traceEvents"] if isinstance(data, dict) else data
    return events, errors


def phase_table(events):
    """name -> (count, total_us) over complete spans of the tick lane."""
    table = {}
    for ev in events:
        if ev.get("ph") != "X" or ev.get("cat") == "compile":
            continue
        if ev.get("tid", 0) >= REQUEST_LANE_BASE:
            continue
        n, tot = table.get(ev["name"], (0, 0.0))
        table[ev["name"]] = (n + 1, tot + float(ev.get("dur", 0.0)))
    return table


def compile_events(events):
    return [ev for ev in events
            if ev.get("cat") == "compile"
            or str(ev.get("name", "")).startswith(("compile:",
                                                   "jax_trace:"))]


def request_lanes(events):
    """tid -> {stage: duration_us, instants: [...]} from B/E pairs."""
    lanes = collections.defaultdict(
        lambda: {"stages": {}, "instants": [], "name": None})
    open_spans = {}
    for ev in events:
        tid = ev.get("tid", 0)
        if tid < REQUEST_LANE_BASE:
            continue
        lane = lanes[tid]
        ph = ev.get("ph")
        if ph == "M" and ev.get("name") == "thread_name":
            lane["name"] = ev.get("args", {}).get("name")
        elif ph == "B":
            open_spans[(tid, ev["name"])] = float(ev["ts"])
        elif ph == "E":
            t0 = open_spans.pop((tid, ev["name"]), None)
            if t0 is not None:
                lane["stages"][ev["name"]] = float(ev["ts"]) - t0
        elif ph == "i":
            lane["instants"].append(ev["name"])
    return dict(lanes)


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        prog="trace_report", description=__doc__,
        formatter_class=argparse.RawDescriptionHelpFormatter)
    ap.add_argument("path", help="Chrome trace-event JSON "
                                 "(engine.dump_trace output)")
    ap.add_argument("--top", type=int, default=5,
                    help="longest individual spans to list")
    args = ap.parse_args(argv)

    events, errors = load_events(args.path)
    if errors:
        print(f"INVALID trace ({len(errors)} schema errors):")
        for e in errors[:10]:
            print(f"  {e}")
        return 1
    if not events:
        print("empty trace")
        return 1

    xs = [ev for ev in events if ev.get("ph") == "X"]
    spanned = [float(ev["ts"]) for ev in events if ev.get("ph") != "M"]
    wall = (max(spanned) - min(spanned)) if len(spanned) > 1 else 0.0
    print(f"{args.path}: {len(events)} events, "
          f"{len(xs)} complete spans, wall {_fmt_us(wall)}")

    print("\ntick phases:")
    table = phase_table(events)
    for name, (n, tot) in sorted(table.items(), key=lambda kv: -kv[1][1]):
        share = 100.0 * tot / wall if wall > 0 else 0.0
        print(f"  {name:<18} n={n:<6} total={_fmt_us(tot):>9} "
              f"mean={_fmt_us(tot / n):>9}  {share:5.1f}% of wall")

    comp = compile_events(events)
    print(f"\ncompile events ({len(comp)}):")
    for ev in comp:
        dur = ev.get("dur")
        cost = f" {_fmt_us(float(dur))}" if dur is not None else ""
        print(f"  {ev['name']}{cost}")

    lanes = request_lanes(events)
    print(f"\nrequest lanes ({len(lanes)}):")
    for tid in sorted(lanes):
        lane = lanes[tid]
        stages = "  ".join(f"{k}={_fmt_us(v)}"
                           for k, v in lane["stages"].items())
        inst = (" | " + ", ".join(lane["instants"])
                if lane["instants"] else "")
        print(f"  {lane['name'] or tid}: {stages}{inst}")

    print(f"\ntop {args.top} spans:")
    for ev in sorted(xs, key=lambda e: -float(e.get("dur", 0)))[:args.top]:
        print(f"  {_fmt_us(float(ev['dur'])):>9}  {ev['name']}")
    return 0


if __name__ == "__main__":
    sys.exit(main())
