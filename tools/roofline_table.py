"""Build the EXPERIMENTS.md roofline tables from results/dryrun/*.json,
and render sweep-engine benchmark rows from results/bench/*.json.

Adds the analytic memory floor to the raw HLO terms: XLA-CPU byte counts
are unfused upper bounds (every op's operands counted at HBM), so the
credible memory term lies in [analytic floor, HLO count]; the roofline
fraction is reported against the HLO-term bound (conservative) with the
floor shown alongside. Decode steps are scored against their memory
ideal (weights+cache read once per token) rather than the compute ideal.

    PYTHONPATH=src python tools/roofline_table.py [--dir results/dryrun]
    PYTHONPATH=src python tools/roofline_table.py --bench [results/bench]
"""
import argparse
import glob
import json
import os
import sys

sys.path.insert(0, "src")

from repro.configs import get_config  # noqa: E402
from repro.configs.base import SHAPES  # noqa: E402
from repro.launch.roofline import HBM_BW, LINK_BW, PEAK_FLOPS  # noqa: E402


def analytic_floor_bytes(rec) -> float:
    """Minimum global HBM traffic: weights touched once per step (x3 for
    train: read + grad write + opt update read/write approx), plus
    activations written+read once, plus KV cache traffic for decode."""
    cfg = get_config(rec["arch"])
    shape = SHAPES[rec["shape"]]
    n = cfg.params_count()
    n_active = cfg.active_params_count()
    tokens = shape.seq_len * shape.global_batch
    if rec["kind"] == "train":
        # fwd+bwd touch active weights ~3x in bf16 + f32 optimizer states
        w = 3 * n_active * 2 + 3 * n * 4
        acts = 2 * tokens * cfg.d_model * cfg.n_layers * 2
        return float(w + acts)
    if rec["kind"] == "prefill":
        w = n_active * 2
        acts = 2 * tokens * cfg.d_model * cfg.n_layers * 2
        return float(w + acts)
    # decode: weights + cache read per token
    cache = rec.get("memory", {}).get("argument_size_in_bytes", 0) \
        * rec.get("chips", 1)
    return float(n_active * 2 + cache * 0.5)


def load(dirpath):
    recs = []
    for p in sorted(glob.glob(os.path.join(dirpath, "*.json"))):
        with open(p) as f:
            recs.append(json.load(f))
    return recs


def enrich(rec):
    r = rec.get("roofline")
    if not r:
        return None
    chips = rec["chips"]
    floor = analytic_floor_bytes(rec)
    mem_floor_s = floor / chips / HBM_BW
    ideal_compute_s = r["model_flops"] / chips / PEAK_FLOPS
    ideal_s = max(ideal_compute_s, mem_floor_s)
    bound_s = max(r["compute_s"], r["memory_s"], r["collective_s"])
    out = dict(r)
    out["mem_floor_s"] = mem_floor_s
    out["ideal_s"] = ideal_s
    out["fraction"] = min(ideal_s / bound_s, 1.0) if bound_s else 0.0
    out["fits_16gb"] = rec.get("fits_16gb")
    out["per_device_gb"] = (rec.get("per_device_bytes", 0) or 0) / 1e9
    out["compile_s"] = rec.get("compile_s")
    out["coll_by_op"] = rec.get("collectives", {}).get("by_op", {})
    return out


def fmt_row(e):
    return (f"| {e['arch']} | {e['shape']} | {e['mesh']} "
            f"| {e['compute_s']*1e3:9.2f} | {e['memory_s']*1e3:9.2f} "
            f"| {e['mem_floor_s']*1e3:9.2f} | {e['collective_s']*1e3:9.2f} "
            f"| {e['bottleneck']:10s} | {e['fraction']:.3f} "
            f"| {e['flops_ratio']:.2f} | {e['per_device_gb']:.2f} |")


def _flat_value(value):
    """Scalar-ize a sweep row value for tabular display."""
    if isinstance(value, dict):
        return {k: v for k, v in value.items()
                if isinstance(v, (int, float, bool, str)) or v is None}
    return {"value": value}


def bench_tables(dirpath: str) -> None:
    """Render the structured sweep rows every repro.exp-backed benchmark
    emits (payload key 'rows') as one markdown table per sweep."""
    for path in sorted(glob.glob(os.path.join(dirpath, "*.json"))):
        with open(path) as f:
            payload = json.load(f)
        rows = payload.get("rows")
        if not rows:
            continue
        by_sweep = {}
        for r in rows:
            by_sweep.setdefault(r["sweep"], []).append(r)
        for sweep, srows in by_sweep.items():
            params = list(srows[0]["params"])
            metrics = list(_flat_value(srows[0]["value"]))
            print(f"\n### {sweep} ({os.path.basename(path)})\n")
            print("| " + " | ".join(params + metrics) + " |")
            print("|" + "---|" * (len(params) + len(metrics)))
            for r in srows:
                vals = [str(r["params"].get(k)) for k in params]
                flat = _flat_value(r["value"])
                for m in metrics:
                    v = flat.get(m)
                    vals.append(f"{v:.4g}" if isinstance(v, float)
                                else str(v))
                print("| " + " | ".join(vals) + " |")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--dir", default="results/dryrun")
    ap.add_argument("--markdown", action="store_true")
    ap.add_argument("--bench", nargs="?", const="results/bench",
                    default=None, metavar="DIR",
                    help="render sweep-engine benchmark rows instead of "
                         "the dryrun roofline table")
    args = ap.parse_args()
    if args.bench:
        bench_tables(args.bench)
        return
    recs = load(args.dir)
    header = ("| arch | shape | mesh | compute ms | memHLO ms | memFloor ms"
              " | coll ms | bottleneck | frac | MODEL/HLO | GB/dev |")
    sep = "|" + "---|" * 11
    print(header)
    print(sep)
    rows = []
    for rec in recs:
        if rec.get("status") != "ok":
            continue
        e = enrich(rec)
        if e:
            rows.append(e)
            print(fmt_row(e))
    # summary stats
    ok = [e for e in rows if e["mesh"] == "pod16x16"]
    worst = sorted(ok, key=lambda e: e["fraction"])[:3]
    collb = sorted(ok, key=lambda e: -e["collective_s"])[:3]
    print("\nworst roofline fractions (single-pod):",
          [(e["arch"], e["shape"], round(e["fraction"], 3))
           for e in worst])
    print("most collective-heavy:",
          [(e["arch"], e["shape"], f"{e['collective_s']*1e3:.1f}ms")
           for e in collb])
    misfits = [e for e in rows if e["fits_16gb"] is False]
    print("cells exceeding 16GB/device:",
          [(e["arch"], e["shape"], e["mesh"], round(e["per_device_gb"], 1))
           for e in misfits])


if __name__ == "__main__":
    main()
