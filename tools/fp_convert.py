#!/usr/bin/env python3
"""Standalone fp32 -> fp8 (e4m3) / fp4 (e2m1) reference converter.

An independent numpy implementation of the storage codecs in
``repro.quant.quantize`` (which are jax and frexp-based): here each
format's full positive code grid is materialized by bit-field
arithmetic and encoding is a nearest-grid-value search with ties
broken to the even code — equivalent to round-to-nearest-even on the
mantissa grid because adjacent codes alternate mantissa parity and
exponent-boundary midpoints round up to the mantissa-0 code.

The prepare/quantize unit tests import this module as the reference
codec; disagreement between the two implementations fails CI.

CLI: round-trip report over a random sample and the exact code grid::

    python tools/fp_convert.py --fmt fp4 --samples 10000
"""
from __future__ import annotations

import argparse
import dataclasses
import json
import sys
from typing import Dict

import numpy as np


@dataclasses.dataclass(frozen=True)
class Format:
    name: str
    exp_bits: int
    man_bits: int
    bias: int
    max: float

    @property
    def bits(self) -> int:
        return 1 + self.exp_bits + self.man_bits


FP8_E4M3 = Format("fp8", exp_bits=4, man_bits=3, bias=7, max=448.0)
FP4_E2M1 = Format("fp4", exp_bits=2, man_bits=1, bias=1, max=6.0)
FORMATS: Dict[str, Format] = {f.name: f for f in (FP8_E4M3, FP4_E2M1)}


def decode_table(fmt: Format) -> np.ndarray:
    """value of every non-negative code, ascending (code order = value
    order for these inf/NaN-free formats)."""
    codes = np.arange(1 << (fmt.bits - 1), dtype=np.int64)
    exp_field = (codes >> fmt.man_bits) & ((1 << fmt.exp_bits) - 1)
    man = codes & ((1 << fmt.man_bits) - 1)
    normal = exp_field > 0
    sig = np.where(normal, man + (1 << fmt.man_bits), man)
    e = np.where(normal, exp_field - fmt.bias, 1 - fmt.bias)
    return (sig * np.exp2((e - fmt.man_bits).astype(np.float64))
            ).astype(np.float32)


def decode(codes: np.ndarray, fmt: Format) -> np.ndarray:
    """bit-field codes (any uint/int array) -> fp32, exact."""
    c = np.asarray(codes).astype(np.int64) & ((1 << fmt.bits) - 1)
    mag = decode_table(fmt)[c & ((1 << (fmt.bits - 1)) - 1)]
    sign = (c >> (fmt.bits - 1)) & 1
    return np.where(sign == 1, -mag, mag).astype(np.float32)


def encode(x: np.ndarray, fmt: Format) -> np.ndarray:
    """fp32 -> uint8 codes: saturating clip at fmt.max, then nearest
    grid value with ties to the even code."""
    xf = np.asarray(x, np.float32)
    sign = np.signbit(xf).astype(np.int64)
    ax = np.minimum(np.abs(xf), np.float32(fmt.max))
    grid = decode_table(fmt)
    hi = np.clip(np.searchsorted(grid, ax), 1, len(grid) - 1)
    lo = hi - 1
    d_lo = ax - grid[lo]
    d_hi = grid[hi] - ax
    pick_hi = (d_hi < d_lo) | ((d_hi == d_lo) & (hi % 2 == 0))
    code = np.where(pick_hi, hi, lo)
    return (code | (sign << (fmt.bits - 1))).astype(np.uint8)


def fp32_to_fp8(x: np.ndarray) -> np.ndarray:
    return encode(x, FP8_E4M3)


def fp8_to_fp32(codes: np.ndarray) -> np.ndarray:
    return decode(codes, FP8_E4M3)


def fp32_to_fp4(x: np.ndarray) -> np.ndarray:
    return encode(x, FP4_E2M1)


def fp4_to_fp32(codes: np.ndarray) -> np.ndarray:
    return decode(codes, FP4_E2M1)


def roundtrip_report(fmt: Format, samples: int = 10_000,
                     seed: int = 0) -> Dict:
    """Exactness on the code grid + error stats on a random sample."""
    # restrict to emittable codes: e4m3's exp=15/man=7 NaN pattern
    # decodes as 480 in the table but encode saturates at fmt.max
    grid = decode_table(fmt)
    grid = grid[grid <= fmt.max]
    regrid = decode(encode(grid, fmt), fmt)
    grid_exact = bool(np.array_equal(grid, regrid))

    rng = np.random.default_rng(seed)
    x = rng.normal(0.0, fmt.max / 4.0, samples).astype(np.float32)
    y = decode(encode(x, fmt), fmt)
    clipped = np.clip(x, -fmt.max, fmt.max)
    err = np.abs(y - clipped)
    nz = np.abs(clipped) > 0
    rel = err[nz] / np.abs(clipped[nz])
    # half-ULP bound of the mantissa grid for normal values
    rel_bound = 2.0 ** -(fmt.man_bits + 1)
    return {
        "format": fmt.name,
        "bits": fmt.bits,
        "codes": 1 << fmt.bits,
        "max": fmt.max,
        "grid_roundtrip_exact": grid_exact,
        "samples": samples,
        "max_abs_err": float(err.max()),
        "mean_abs_err": float(err.mean()),
        "max_rel_err": float(rel.max()),
        "mean_rel_err": float(rel.mean()),
        "rel_half_ulp_bound": rel_bound,
    }


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--fmt", choices=sorted(FORMATS), default=None,
                    help="format to report (default: all)")
    ap.add_argument("--samples", type=int, default=10_000)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)
    names = [args.fmt] if args.fmt else sorted(FORMATS)
    reports = [roundtrip_report(FORMATS[n], args.samples, args.seed)
               for n in names]
    json.dump(reports, sys.stdout, indent=1)
    sys.stdout.write("\n")
    return 0 if all(r["grid_roundtrip_exact"] for r in reports) else 1


if __name__ == "__main__":
    sys.exit(main())
