"""Fig. 3 reproduction: error of the approximate FP-IP vs IPU precision.

For each accumulator (FP16/FP32) and input distribution (Laplace, Normal,
Uniform — the paper's synthetic proxies for DNN tensors), measure the
median absolute error, absolute relative error (%), and contaminated
bits against the FP32-CPU (f64 here) reference, over IPU precisions.

Paper's conclusions to reproduce:
  * FP16 accumulation: errors < 1e-6 and 0 contaminated bits at w >= 16
  * FP32 accumulation: errors < 1e-5 at w >= 26; min contaminated at 27-28

The (accum, dist, w) grid is declared as a ``repro.exp`` sweep; each
cell draws its inputs from a per-distribution seed so any cell is
reproducible in isolation (and across worker processes).
"""
import functools

import numpy as np
import jax
import jax.numpy as jnp

from benchmarks.common import emit, engine_main, row
from repro import exp
from repro.core.ipu import IPUConfig, fp16_inner_product_raw

N = 16          # IPU width
LENGTH = 64     # inner-product length
SAMPLES = 400   # inner products per cell (median reported)

_DIST_IDS = {"laplace": 1, "normal": 2, "uniform": 3}


@functools.lru_cache(maxsize=None)
def _raw_fn(cfg: IPUConfig):
    return jax.jit(lambda a, b: fp16_inner_product_raw(a, b, cfg))


def approx_value(a, b, cfg) -> np.ndarray:
    """Raw non-normalized accumulator value in f64 — the paper's Fig.-3
    metric isolates the IPU-precision truncation error BEFORE the output
    format rounds it (an FP16-rounded output is never within 1e-6 of the
    reference; the accumulator is)."""
    acc, exp_ = _raw_fn(cfg)(jnp.asarray(a), jnp.asarray(b))
    hi = np.asarray(acc.hi, np.float64)
    lo = np.asarray(acc.lo, np.float64)
    e = np.asarray(exp_, np.int64)
    return (hi * 2.0 ** 24 + lo) * np.exp2(np.clip(e, -200, 200) - 30.0)


def draw(rng, dist, shape):
    if dist == "laplace":
        return rng.laplace(0, 1, shape)
    if dist == "normal":
        return rng.normal(0, 1, shape)
    return rng.uniform(-1, 1, shape)


def contaminated_bits(approx: np.ndarray, ref: np.ndarray) -> np.ndarray:
    """Differing mantissa bits vs the f32 reference (paper's metric)."""
    a = np.asarray(approx, np.float32).view(np.uint32).astype(np.int64)
    r = np.asarray(ref, np.float32).view(np.uint32).astype(np.int64)
    x = np.bitwise_xor(a, r)
    out = np.zeros_like(x)
    nz = x != 0
    out[nz] = np.floor(np.log2(x[nz])) + 1
    return np.minimum(out, 32)


def eval_point(accum: str, dist: str, w: int, n: int = N,
               length: int = LENGTH, samples: int = SAMPLES,
               seed: int = 0) -> dict:
    """One Fig.-3 cell: error metrics of the approximate FP-IP."""
    rng = np.random.default_rng([seed, _DIST_IDS[dist]])
    a = np.asarray(draw(rng, dist, (samples, length)), np.float16)
    b = np.asarray(draw(rng, dist, (samples, length)), np.float16)
    ref = (a.astype(np.float64) * b.astype(np.float64)).sum(-1)
    ref32 = ref.astype(np.float32)
    # w < 10 is modelled as a 10-bit datapath with the software mask at w
    # (the truncation study of §3.1)
    cfg = IPUConfig(n=n, w=max(min(w, 28), 10), accum=accum,
                    sw_precision=w)
    got = approx_value(a, b, cfg)
    abs_err = np.abs(got - ref)
    rel = np.abs(got - ref) / np.maximum(np.abs(ref), 1e-30)
    cb = contaminated_bits(got, ref32)
    return {
        "median_abs_err": float(np.median(abs_err)),
        "median_rel_err_pct": float(np.median(rel) * 100),
        "median_contaminated_bits": float(np.median(cb)),
        "mean_contaminated_bits": float(np.mean(cb)),
    }


PRECISIONS = [8, 10, 12, 14, 16, 20, 22, 24, 26, 27, 28]


def spec() -> exp.SweepSpec:
    return exp.SweepSpec(
        name="fig3_error", fn="benchmarks.fig3_error:eval_point",
        axes={"accum": ["fp16", "fp32"],
              "dist": ["laplace", "normal", "uniform"],
              "w": PRECISIONS},
        fixed={"n": N, "length": LENGTH, "samples": SAMPLES, "seed": 0},
        filters=[lambda p: not (p["accum"] == "fp16" and p["w"] > 16)])


def run(verbose: bool = True, engine: exp.EngineConfig = None):
    engine = engine or exp.EngineConfig()
    res, _ = exp.run_sweep(spec(), engine)
    results = {}
    for p, r in res:
        kw = p.kwargs
        key = f"{kw['accum']}/{kw['dist']}/w{kw['w']}"
        results[key] = r
        if verbose:
            row(f"fig3/{key}", 0.0,
                f"abs={r['median_abs_err']:.2e} "
                f"rel%={r['median_rel_err_pct']:.2e} "
                f"cbits={r['median_contaminated_bits']:.1f}")
    # paper-claim checks (functional forms; the paper's absolute 1e-6 at
    # w=16 depends on its input scaling — see EXPERIMENTS.md reproduction
    # notes. The operative claims: w=16 error is far below FP16's own
    # representational noise (2^-11 relative), so 16b suffices for FP16
    # accumulation; w>=26-28 is exact to the FP32 reference.)
    fp16_ulp_rel = 100 * 2.0 ** -11  # percent
    claims = {
        "fp16_w16_below_fp16_noise": (
            results["fp16/laplace/w16"]["median_rel_err_pct"]
            < 0.1 * fp16_ulp_rel),
        "fp16_monotone": (
            results["fp16/laplace/w12"]["median_abs_err"]
            >= results["fp16/laplace/w14"]["median_abs_err"]
            >= results["fp16/laplace/w16"]["median_abs_err"]),
        "fp32_w26_zero_contam":
            results["fp32/laplace/w26"]["median_contaminated_bits"] == 0,
        "fp32_w28_zero_contam":
            results["fp32/laplace/w28"]["median_contaminated_bits"] == 0,
        "fp32_monotone": (
            results["fp32/normal/w12"]["median_abs_err"]
            >= results["fp32/normal/w20"]["median_abs_err"]
            >= results["fp32/normal/w28"]["median_abs_err"]),
    }
    results["claims"] = claims
    results["rows"] = exp.rows_from(res, "fig3_error")
    emit("fig3_error", results)
    if verbose:
        print("fig3 claims:", claims)
    return results


def main(argv=None):
    engine_main(run, argv, __doc__)


if __name__ == "__main__":
    main()
