"""Fig. 10 reproduction: area/power efficiency trade-off space.

Design points (p, c) = (MC-IPU precision, cluster size) for 8- and
16-input tiles, INT4 TOPS vs *effective* FP16 TFLOPS (simulator-derived
multi-cycle factors on the forward study cases). NO-OPT = Baseline2.

Paper Pareto: (12,1) and (16,1) on the power-efficiency frontier;
(16,1) achieving ~+25% TFLOPS/mm2 and ~+46% TOPS/mm2 over NO-OPT.
"""
import dataclasses

from benchmarks.common import emit, row
from repro.core import simulator as sim
from repro.core import workloads as wl
from repro.core.area_power import (FP16, INT4, IPUDesign, baseline_design,
                                   efficiency)
from repro.core.simulator import TileConfig


def _mc_factor(n_inputs: int, w: int, cluster: int) -> float:
    """Effective FP16 slowdown at FP32 accumulation (sw precision 28 —
    matching the paper's +25%/+40% FP16 headline, which implies an
    mc factor of ~1.2 at the (16,1) point)."""
    base = sim.BASELINE1 if n_inputs == 8 else sim.BASELINE2
    tile = dataclasses.replace(base, adder_w=w, cluster_size=cluster)
    layers = wl.resnet50()
    return sim.normalized_exec_time(layers, tile, base,
                                    source=sim.FORWARD_SOURCE)


def run(verbose: bool = True):
    results = {}
    for n_inputs in (8, 16):
        tile = TileConfig() if n_inputs == 16 else dataclasses.replace(
            TileConfig(), c_unroll=8, k_unroll=8)
        points = [(w, c) for w in (12, 16, 20, 28)
                  for c in (1, 4, tile.ipus_per_tile)]
        for (w, c) in points:
            mc = _mc_factor(n_inputs, w, c)
            d = IPUDesign(f"mc{w}c{c}", 4, 4, w, True,
                          dataclasses.replace(tile, adder_w=w,
                                              cluster_size=c),
                          cluster_size=c, fp_mc_factor=mc)
            a_int, p_int = efficiency(d, INT4)
            a_fp, p_fp = efficiency(d, FP16)
            key = f"{n_inputs}in/w{w}c{c}"
            results[key] = {"tops_mm2": a_int, "tops_w": p_int,
                            "tflops_mm2": a_fp, "tflops_w": p_fp,
                            "mc_factor": mc}
            if verbose:
                row(f"fig10/{key}", 0.0,
                    f"TOPS/mm2={a_int:.1f} TFLOPS/mm2={a_fp:.2f} "
                    f"TOPS/W={p_int:.2f} TFLOPS/W={p_fp:.3f} mc={mc:.2f}")
    base = baseline_design(16)
    ab_int, pb_int = efficiency(base, INT4)
    ab_fp, pb_fp = efficiency(base, FP16)
    results["NO-OPT"] = {"tops_mm2": ab_int, "tops_w": pb_int,
                         "tflops_mm2": ab_fp, "tflops_w": pb_fp}
    opt = results["16in/w16c1"]
    results["headline"] = {
        "tops_mm2_gain": opt["tops_mm2"] / ab_int - 1,
        "tflops_mm2_gain": opt["tflops_mm2"] / ab_fp - 1,
        "tops_w_gain": opt["tops_w"] / pb_int - 1,
        "tflops_w_gain": opt["tflops_w"] / pb_fp - 1,
    }
    emit("fig10_tradeoff", results)
    return results


def main():
    res = run()
    h = res["headline"]
    print(f"fig10 headline (16-input (16,1) vs NO-OPT): "
          f"TOPS/mm2 {h['tops_mm2_gain']:+.0%} (paper +46%), "
          f"TFLOPS/mm2 {h['tflops_mm2_gain']:+.0%} (paper +25%), "
          f"TOPS/W {h['tops_w_gain']:+.0%} (paper +63%), "
          f"TFLOPS/W {h['tflops_w_gain']:+.0%} (paper +40%)")


if __name__ == "__main__":
    main()
