"""Fig. 10 reproduction: area/power efficiency trade-off space.

Design points (p, c) = (MC-IPU precision, cluster size) for 8- and
16-input tiles, INT4 TOPS vs *effective* FP16 TFLOPS (simulator-derived
multi-cycle factors on the forward study cases). NO-OPT = Baseline2.

Paper Pareto: (12,1) and (16,1) on the power-efficiency frontier;
(16,1) achieving ~+25% TFLOPS/mm2 and ~+46% TOPS/mm2 over NO-OPT.

The mc-factor sweep reuses ``benchmarks.fig8_perf:eval_point`` — the
effective FP16 slowdown of a (tile, precision, cluster) design on
ResNet-50 forward is the same simulator point fig8 sweeps, so a warm
fig8 cache already covers the overlap (sw precision 28, matching the
paper's +25%/+40% FP16 headline: mc factor ~1.2 at the (16,1) point).
"""
from benchmarks.common import emit, engine_main, row
from repro import exp
from repro.core.area_power import (FP16, INT4, baseline_design, efficiency,
                                   optimized_design)

_WIDTHS = (12, 16, 20, 28)


def spec() -> exp.SweepSpec:
    # cluster axis in concrete IPU counts so points are shared with the
    # fig8 cluster sweep where they coincide
    return exp.SweepSpec(
        name="fig10_mc", fn="benchmarks.fig8_perf:eval_point",
        axes={"n_inputs": [8, 16], "w": list(_WIDTHS),
              "cluster": [1, 4, 32, 64]},
        fixed={"case": "resnet50_fwd", "skip_empty": False},
        filters=[lambda p: p["cluster"] in (1, 4)
                 or p["cluster"] == 4 * p["n_inputs"]])


def run(verbose: bool = True, engine: exp.EngineConfig = None):
    engine = engine or exp.EngineConfig()
    res, _ = exp.run_sweep(spec(), engine)
    results = {}
    for p, mc in res:
        kw = p.kwargs
        n_inputs, w, c = kw["n_inputs"], kw["w"], kw["cluster"]
        d = optimized_design(n_inputs, w=w, cluster=c, fp_mc_factor=mc)
        a_int, p_int = efficiency(d, INT4)
        a_fp, p_fp = efficiency(d, FP16)
        key = f"{n_inputs}in/w{w}c{c}"
        results[key] = {"tops_mm2": a_int, "tops_w": p_int,
                        "tflops_mm2": a_fp, "tflops_w": p_fp,
                        "mc_factor": mc}
        if verbose:
            row(f"fig10/{key}", 0.0,
                f"TOPS/mm2={a_int:.1f} TFLOPS/mm2={a_fp:.2f} "
                f"TOPS/W={p_int:.2f} TFLOPS/W={p_fp:.3f} mc={mc:.2f}")
    base = baseline_design(16)
    ab_int, pb_int = efficiency(base, INT4)
    ab_fp, pb_fp = efficiency(base, FP16)
    results["NO-OPT"] = {"tops_mm2": ab_int, "tops_w": pb_int,
                         "tflops_mm2": ab_fp, "tflops_w": pb_fp}
    opt = results["16in/w16c1"]
    results["headline"] = {
        "tops_mm2_gain": opt["tops_mm2"] / ab_int - 1,
        "tflops_mm2_gain": opt["tflops_mm2"] / ab_fp - 1,
        "tops_w_gain": opt["tops_w"] / pb_int - 1,
        "tflops_w_gain": opt["tflops_w"] / pb_fp - 1,
    }
    results["rows"] = exp.rows_from(res, "fig10_mc")
    emit("fig10_tradeoff", results)
    if verbose:
        h = results["headline"]
        print(f"fig10 headline (16-input (16,1) vs NO-OPT): "
              f"TOPS/mm2 {h['tops_mm2_gain']:+.0%} (paper +46%), "
              f"TFLOPS/mm2 {h['tflops_mm2_gain']:+.0%} (paper +25%), "
              f"TOPS/W {h['tops_w_gain']:+.0%} (paper +63%), "
              f"TFLOPS/W {h['tflops_w_gain']:+.0%} (paper +40%)")
    return results


def main(argv=None):
    engine_main(run, argv, __doc__)


if __name__ == "__main__":
    main()
