"""Autotune trajectory bench: score the committed serving plan.

Loads the searched ``results/plans/<arch>.json`` artifact (falling back
to a fresh no-probe search when absent), re-derives its metrics from the
cached score table, verifies the plan still loads into an executable
policy, and writes a ``BENCH_autotune.json`` summary row — the series
the bench trajectory tracks across PRs:

    plan, cycles, TOPS/mm2, TOPS/W, accuracy proxy, frontier size.
"""
import os

from benchmarks.common import emit, engine_main, row
from repro import exp
from repro.autotune import candidates as cand_mod
from repro.autotune import search as search_mod
from repro.autotune.plan import load_plan

PLAN_PATH = os.environ.get("AUTOTUNE_PLAN", "results/plans/qwen2_0_5b.json")
ARCH = "qwen2-0.5b"


def _search_fresh(engine: exp.EngineConfig):
    """No committed plan yet: run a probe-free search so the bench row
    still populates (analytic accuracy proxy only)."""
    from repro.configs import get_config
    groups = cand_mod.groups_for(get_config(ARCH))
    table = search_mod.build_scores(
        ARCH, groups, cand_mod.default_candidates(), engine,
        seq=1, seed=0, shapes="full", probe=False)
    return search_mod.search_plan(ARCH, table), table, None


def _score_plan(plan, engine: exp.EngineConfig):
    """Re-derive the committed plan's metrics from the cached table
    (same eval-point params as the search -> warm cache, 0 executed)."""
    from repro.configs import get_config, reduced
    meta = plan.meta
    shapes = meta.get("shapes", "full")
    cfg = reduced(plan.arch) if shapes == "reduced" else get_config(plan.arch)
    groups = [g for g in cand_mod.groups_for(cfg)
              if g.name in {r.group for r in plan.rules}]
    cands = []
    for r in plan.rules:
        c = cand_mod.canonical(r.mode, w=r.w, sw_precision=r.sw_precision,
                               cluster=r.cluster)
        if c not in cands:
            cands.append(c)
    table = search_mod.build_scores(
        plan.arch, groups, cands, engine, seq=meta.get("seq", 1),
        seed=meta.get("seed", 0), shapes=shapes,
        probe=meta.get("probe", True))
    assign = {r.group: cand_mod.canonical(
        r.mode, w=r.w, sw_precision=r.sw_precision, cluster=r.cluster)
        for r in plan.rules}
    return search_mod.plan_metrics(table, assign)


def run(verbose: bool = True, engine: exp.EngineConfig = None):
    engine = engine or exp.EngineConfig()
    if os.path.exists(PLAN_PATH):
        plan = load_plan(PLAN_PATH)
        metrics = _score_plan(plan, engine)
    else:
        plan, _, _ = _search_fresh(engine)
        metrics = plan.metrics

    policy = plan.to_policy()   # the artifact must stay executable
    summary = {
        "plan": plan.name,
        "arch": plan.arch,
        "source": PLAN_PATH if os.path.exists(PLAN_PATH) else "fresh",
        "cycles": metrics["cycles"],
        "ideal_cycles": metrics["ideal_cycles"],
        "tops_per_mm2": metrics["tops_per_mm2"],
        "tops_per_w": metrics["tops_per_w"],
        "acc_proxy": metrics["acc_proxy"],
        "n_frontier": len(plan.frontier),
        "n_rules": len(policy.rules),
        "modes": metrics["modes"],
    }
    emit("BENCH_autotune", summary)
    if verbose:
        row(f"autotune/{plan.name}", 0.0,
            f"cycles={metrics['cycles']:.4g} "
            f"tops_mm2={metrics['tops_per_mm2']:.2f} "
            f"tops_w={metrics['tops_per_w']:.3f} "
            f"acc={metrics['acc_proxy']:.3g} "
            f"frontier={len(plan.frontier)}")
    return summary


def main(argv=None):
    engine_main(run, argv, __doc__)


if __name__ == "__main__":
    main()
