"""Serving throughput bench: the runtime under each precision policy.

The paper's kind is inference acceleration — this measures the actual
serving stack (``repro.serving`` batched-prefill continuous batching on
the reduced qwen2 model) across the policies the IPU datapath motivates,
on CPU wall time. Not a TPU number; the relative policy costs and the
engine overheads are the object of measurement. Engines are warmed
(one throwaway request compiles the prefill/decode programs) so the
reported tok/s is steady-state serving throughput, not jit latency.

Reports decode tok/s plus the latency distribution of the runtime —
TTFT and queue-delay percentiles per policy — and a two-replica
plan-aware router pass. Each policy is measured across the decode fast
path's block sizes (``decode_block`` in BLOCKS: a jitted scan of N
decode steps with on-device greedy selection, ONE host sync per block)
with the prepared-weight datapath and calibrated static activation
scales (the default serving configuration), plus a dynamic control
engine (per-step weight quantization, per-token activation absmax,
per-token sync — the pre-refactor behavior). ``host_syncs_per_token``
makes the sync elimination itself part of the trajectory.

Robustness: every engine of every policy is built and warmed up front,
and the best-of-3 timed passes are INTERLEAVED across policies — each
engine's samples span the whole bench wall-clock rather than one short
per-policy window, so a machine-load swing cannot silently invert the
cross-policy ratios.

The BURSTY section measures what continuous batching buys under load:
an open-loop wall-clock arrival trace (requests keep arriving on their
own schedule whether or not the engine kept up) through two engines at
the same ``decode_block`` — the continuous engine (mid-block admission
+ EOS stopping) against the flags-off PR-5-style baseline. Requests
carry harvested per-request stop ids (from a greedy pre-run) so EOS
events are guaranteed; the baseline cannot honour them and burns the
full budget. Reported: TTFT p50/p95, SLO attainment (deadline = the
baseline's own p50 TTFT) and goodput (``metrics.slo_report``).

The TRACE OVERHEAD section measures the observability tax: the same
prepared int8 engine with ``EngineConfig(trace=True)`` against trace
off, interleaved best-of-N passes. Span recording must observe, not
perturb — the ``trace_overhead`` block guards the traced throughput
within 5% of untraced.

The COLD START section measures what the fabric checkpoint buys a
restarted worker: serve-ready engine construction from raw fp32 params
(quantize + pack + calibrate on the critical path) against
``repro.fabric.build_engine`` from a prepared-weight checkpoint, per
policy, plus each checkpoint's on-disk footprint. The int4 row carries
the storage claim the paper's datapath rests on — packed projection
data bytes x 8 equals the fp32 bytes of the same projections exactly
(per-channel scales are the only overhead), asserted, not reported.

The FAILOVER section measures the fabric's recovery economics on a
deterministic two-worker fleet: recovery latency (ticks from losing a
worker to the first post-recovery token of a request it held) and
token waste (work generated twice), requeue-from-scratch against
reconnect-and-resume. Both scenarios must drain with zero loss and
reference-identical streams; resume's wasted_tokens is zero by
construction and the gap is reported as ``resume_waste_cut``.

Emits ONE artifact, ``BENCH_serving.json``: the compact trajectory row
``benchmarks/run.py`` tracks across PRs (like ``BENCH_autotune``), with
the full per-policy/router/bursty breakdown under its ``detail`` key.
(The old duplicate ``serve_bench.json`` is retired — one file, one
schema.)
"""
import dataclasses
import os
import time

import numpy as np
import jax
import jax.numpy as jnp

from benchmarks.common import emit, row
from repro.configs import reduced
from repro.serving import (EngineConfig, Request, Router, SamplingParams,
                           ServingEngine, build_replicas, slo_report)
from repro.models import registry

POLICIES = ("bf16", "int8_serving", "int4_serving", "paper_hybrid")
# decode fast-path block sizes swept per policy (1 = per-token dispatch)
BLOCKS = (1, 4, 8, 16)
# block the trajectory's block_speedup_8v1 column reads (falls back to
# the largest swept block if 8 ever leaves BLOCKS)
_HI_BLOCK = "8" if 8 in BLOCKS else str(max(BLOCKS))
N_REQUESTS = 8
PROMPT_LEN = 8
# enough decode steps that the timed region dwarfs per-tick Python
# overhead jitter (the prepared-vs-dynamic delta is the measurement);
# a multiple of every block size so block-N passes never compile a
# ragged tail program
MAX_NEW = 32


def _workload(cfg, tagged_every=0):
    rng = np.random.default_rng(0)
    reqs = []
    for rid in range(N_REQUESTS):
        reqs.append(Request(
            rid=rid,
            prompt=rng.integers(0, cfg.vocab, PROMPT_LEN, dtype=np.int32),
            max_new_tokens=MAX_NEW,
            tags=("accuracy",) if tagged_every and rid % tagged_every == 0
            else ()))
    return reqs


def _warmup(engine):
    """One throwaway request through prefill + decode so the jitted
    programs compile outside the timed window (time_fn-style warmup);
    MAX_NEW tokens so a blocked engine compiles its full-block decode
    program. The engine's request log and counters are then reset."""
    engine.submit(Request(rid=-1,
                          prompt=np.zeros(PROMPT_LEN, np.int32),
                          max_new_tokens=MAX_NEW))
    engine.run_until_drained()
    engine.completed.clear()
    for k in engine.counters:
        engine.counters[k] = 0


def _reset(engine):
    engine.completed.clear()
    for k in engine.counters:
        engine.counters[k] = 0


def _timed_pass(engine, cfg):
    """Submit the standard workload, drain, return (tok/s, ticks, dt)."""
    _reset(engine)
    for req in _workload(cfg):
        engine.submit(req)
    t0 = time.time()
    ticks = engine.run_until_drained()
    dt = time.time() - t0
    return engine.metrics()["new_tokens"] / dt, ticks, dt


def _build_policy(policy: str):
    """All engines of one policy: a prepared + calibrated engine per
    decode-block size, plus the dynamic control engine; warmed."""
    cfg = dataclasses.replace(reduced("qwen2-0.5b"),
                              precision_policy=policy)
    api = registry.build(cfg)
    params = api.init(jax.random.PRNGKey(0))
    # the first engine calibrates ("auto": the engine itself skips the
    # pass for policies routing no int projections) and prepares; the
    # rest of the block sweep shares its scales AND its prepared tree
    # (preparation is idempotent, so their own prepare is a
    # pass-through instead of 4 independent quantize/pack walks)
    engines = {}
    calibration, block_params = "auto", params
    for blk in BLOCKS:
        eng = ServingEngine(cfg, api, block_params, config=EngineConfig(
            batch_slots=4, cache_len=128, prepare_weights=True,
            act_calibration=calibration, decode_block=blk))
        calibration = eng.act_scales
        block_params = eng.params
        engines[blk] = eng
    engines["dynamic"] = ServingEngine(cfg, api, params,
                                       config=EngineConfig(
                                           batch_slots=4, cache_len=128,
                                           prepare_weights=False))
    for eng in engines.values():
        _warmup(eng)
    return cfg, engines


def _collect_policy(cfg, engines, best):
    """Summarize one policy from its best (tok/s, ticks, seconds) per
    engine — keeping the ticks/seconds of the best pass so the reported
    latency and throughput describe the same run."""
    sweep = {blk: best[blk][0] for blk in BLOCKS}
    # the workload is deterministic per engine, so syncs/token comes
    # straight off the last pass's counters
    syncs = {blk: engines[blk].counters["host_syncs"]
             / max(MAX_NEW * N_REQUESTS, 1) for blk in BLOCKS}
    best_block = max(BLOCKS, key=lambda blk: sweep[blk])
    eng = engines[1]
    m = eng.metrics()
    return {
        "tok_per_s": sweep[1],
        "ticks": best[1][1],
        "seconds": best[1][2],
        "tok_per_s_dynamic": best["dynamic"][0],
        "block_sweep": {str(blk): sweep[blk] for blk in BLOCKS},
        "host_syncs_per_token": {str(blk): syncs[blk] for blk in BLOCKS},
        "best_block": best_block,
        "tok_per_s_best_block": sweep[best_block],
        "ttft_s": m["ttft_s"], "queue_delay_s": m["queue_delay_s"],
        "prefill_calls": m["counters"]["prefill_calls"],
        "prefill_tokens": m["counters"]["prefill_tokens"],
        "decode_steps": m["counters"]["decode_steps"],
        "weight_bytes": m["weight_bytes"]["projections"],
        "weight_bytes_total": m["weight_bytes"]["total"],
        "weight_bytes_dynamic":
            engines["dynamic"].weight_bytes()["projections"],
        "weight_quants_per_step": eng.weight_quant_trace_count(),
        "weight_quants_per_step_dynamic":
            engines["dynamic"].weight_quant_trace_count(),
        "act_quants_per_step": eng.act_quant_trace_count(),
        "act_quants_per_step_dynamic":
            engines["dynamic"].act_quant_trace_count(),
    }


def _bench_router():
    """Two-replica plan-aware pass: the routing layer's overhead and
    split on a mixed (third accuracy-tagged) workload."""
    cfg = reduced("qwen2-0.5b")
    replicas = build_replicas(cfg, ("int8_serving", "bf16"),
                              config=EngineConfig(batch_slots=2,
                                                  cache_len=128))
    router = Router(replicas, strategy="plan_aware")
    for rep in replicas:
        _warmup(rep.engine)
    for req in _workload(cfg, tagged_every=3):
        router.submit(req)
    t0 = time.time()
    ticks = router.run_until_drained()
    dt = time.time() - t0
    new_tokens = sum(r.new_tokens for r in router.completed.values())
    return {
        "tok_per_s": new_tokens / dt, "ticks": ticks, "seconds": dt,
        "counters": router.routing_counters(),
        "completed": len(router.completed),
    }


# bursty open-loop section: request count, decode block, and where in
# the greedy stream the harvested stop token sits (~1/5 of the budget,
# so EOS stopping frees ~80% of a stopped request's decode work)
BURSTY_N = 10
BURSTY_BLOCK = 8
BURSTY_STOP_AT = 6


def _precompile_blocks(eng):
    """Compile every (block length, greedy) program the continuous
    engine can dispatch (mid-block cuts produce 1..decode_block), so no
    compile lands inside the timed open-loop window. The carry is
    all-inactive: the dispatch only pad-writes positions later real
    writes overwrite."""
    from repro.serving.config import MAX_STOP_IDS
    zeros = jnp.zeros((eng.b,), jnp.int32)
    carry = registry.DecodeCarry(
        tok=zeros, pos=zeros, rem=zeros, taken=zeros,
        stops=jnp.full((eng.b, MAX_STOP_IDS), -1, jnp.int32),
        temp=jnp.zeros((eng.b,), jnp.float32), top_k=zeros,
        top_p=jnp.ones((eng.b,), jnp.float32),
        keys=jnp.zeros((eng.b, 2), jnp.uint32))
    for n in range(1, eng.decode_block + 1):
        tokens, _, eng.caches = eng._block_decode(n, False)(
            eng.params, carry, eng.caches)
    np.asarray(tokens)


def _bursty_requests(cfg, stops):
    rng = np.random.default_rng(2)
    return [Request(rid=rid,
                    prompt=rng.integers(0, cfg.vocab, PROMPT_LEN,
                                        dtype=np.int32),
                    max_new_tokens=MAX_NEW,
                    sampling=SamplingParams(stop_ids=stops.get(rid, ())))
            for rid in range(BURSTY_N)]


def _drive_open_loop(engine, reqs, arrivals):
    """Open-loop: each request submits at its wall-clock arrival time
    regardless of engine progress (the load model closed-loop draining
    can't produce — a slow engine faces a growing queue)."""
    _reset(engine)
    pending = sorted(zip(arrivals, reqs), key=lambda ar: ar[0])
    t0 = time.time()
    while pending or engine.has_pending():
        now = time.time() - t0
        while pending and pending[0][0] <= now:
            engine.submit(pending.pop(0)[1])
        if engine.has_pending():
            engine.step()
        else:
            time.sleep(1e-4)
    return time.time() - t0


def _bench_bursty():
    """Continuous engine vs flags-off baseline on the same open-loop
    arrival trace, equal decode_block; returns the BENCH_serving
    'bursty' block."""
    cfg = dataclasses.replace(reduced("qwen2-0.5b"),
                              precision_policy="int8_serving")
    api = registry.build(cfg)
    params = api.init(jax.random.PRNGKey(0))
    cont_cfg = EngineConfig(batch_slots=2, cache_len=128,
                            decode_block=BURSTY_BLOCK,
                            act_calibration="auto")
    cont = ServingEngine(cfg, api, params, config=cont_cfg)
    base_cfg = dataclasses.replace(cont_cfg,
                                   act_calibration=cont.act_scales,
                                   mid_block_admission=False,
                                   eos_stopping=False)
    base = ServingEngine(cfg, api, cont.params, config=base_cfg)
    for eng in (cont, base):
        _warmup(eng)
        _precompile_blocks(eng)

    # greedy pre-run harvests a per-request stop id (for 2/3 of the
    # requests) so the continuous engine is guaranteed EOS events; the
    # baseline receives the SAME requests but cannot honour the stops
    harvest = _bursty_requests(cfg, {})
    for r in harvest:
        base.submit(r)
    base.run_until_drained()
    stops = {r.rid: (int(r.tokens[len(r.prompt) + BURSTY_STOP_AT]),)
             for r in harvest if r.rid % 3 != 0}

    # arrival spacing from the baseline's own measured tick time: one
    # request per ~1.2 ticks after an initial 4-request burst, so the
    # queue stays non-empty while slots are busy
    _reset(base)
    for r in _bursty_requests(cfg, {}):
        base.submit(r)
    t0 = time.time()
    ticks = base.run_until_drained()
    per_tick = (time.time() - t0) / max(ticks, 1)
    arrivals = [0.0 if i < 4 else (i - 3) * 1.2 * per_tick
                for i in range(BURSTY_N)]

    out = {"arrival_spacing_ms": per_tick * 1.2e3,
           "decode_block": BURSTY_BLOCK}
    slo = None
    for name, eng in (("baseline", base), ("continuous", cont)):
        reqs = _bursty_requests(cfg, stops)
        dt = _drive_open_loop(eng, reqs, arrivals)
        m = eng.metrics()
        if slo is None:                 # deadline = baseline p50 TTFT
            slo = m["ttft_s"]["p50"]
        rep = slo_report(eng.completed.values(), slo)
        out[name] = {
            "seconds": dt,
            "new_tokens": m["new_tokens"],
            "ttft_p50_ms": m["ttft_s"]["p50"] * 1e3,
            "ttft_p95_ms": m["ttft_s"]["p95"] * 1e3,
            "slo_attainment": rep["attainment"],
            "goodput_tok_per_s": rep["goodput_tok_per_s"],
            "counters": {k: m["counters"][k] for k in
                         ("short_blocks", "mid_block_admits",
                          "eos_stops", "decode_steps", "host_syncs")},
        }
    out["ttft_slo_ms"] = slo * 1e3
    out["ttft_p95_speedup"] = (out["baseline"]["ttft_p95_ms"]
                               / max(out["continuous"]["ttft_p95_ms"],
                                     1e-9))
    out["goodput_speedup"] = (out["continuous"]["goodput_tok_per_s"]
                              / max(out["baseline"]["goodput_tok_per_s"],
                                    1e-9))
    return out


FUSED_BLOCK = 8


def operand_bytes_per_block(engine, block: int):
    """Weight-operand memory traffic of one decode block, per datapath:
    'packed' = the stored operands (int8 rows / packed nibbles / fp
    codes + scales) the fused kernels stream on every scan step;
    'staged' = the compute-dtype (bf16) operand the staged fallback
    materializes once per block and re-reads every step. The ratio is
    the traffic the fused datapath removes."""
    from repro.quant.prepare import PreparedWeight, iter_projection_weights
    paths = registry.projection_paths(engine.cfg)
    packed = staged = 0
    for _, w in iter_projection_weights(engine.params, paths):
        if not isinstance(w, PreparedWeight) or w.kind == "fp16":
            continue
        elems = w.data.size * (2 if w.kind.endswith("_packed") else 1)
        packed += w.nbytes() * block
        staged += elems * 2 * (block + 1)    # one write + block reads
    return {"packed": int(packed), "staged": int(staged),
            "ratio": staged / max(packed, 1)}


def _bench_fused(repeats: int = 3):
    """Fused-vs-staged ablation at one decode block: the same prepared
    + calibrated int8 engine with ``fused_executors`` on vs off
    (identical params, scales and block size), interleaved best-of
    passes, plus the traced staged-materialization counts and the
    per-block operand-traffic column."""
    cfg = dataclasses.replace(reduced("qwen2-0.5b"),
                              precision_policy="int8_serving")
    api = registry.build(cfg)
    params = api.init(jax.random.PRNGKey(0))
    fused = ServingEngine(cfg, api, params, config=EngineConfig(
        batch_slots=4, cache_len=128, decode_block=FUSED_BLOCK,
        act_calibration="auto", fused_executors="on"))
    staged = ServingEngine(cfg, api, fused.params, config=EngineConfig(
        batch_slots=4, cache_len=128, decode_block=FUSED_BLOCK,
        act_calibration=fused.act_scales, fused_executors="off"))
    engines = {"fused": fused, "staged": staged}
    mats = {k: e.staged_trace_count() for k, e in engines.items()}
    assert mats["fused"] == 0 < mats["staged"], mats
    for eng in engines.values():
        _warmup(eng)
    best = {k: 0.0 for k in engines}
    for _ in range(repeats):
        for name, eng in engines.items():
            tok_s, _, _ = _timed_pass(eng, cfg)
            best[name] = max(best[name], tok_s)
    traffic = operand_bytes_per_block(fused, FUSED_BLOCK)
    return {
        "decode_block": FUSED_BLOCK,
        "tok_per_s": best,
        "fused_speedup": best["fused"] / max(best["staged"], 1e-9),
        "staged_materializations_per_block": mats,
        "operand_bytes_per_block": traffic,
    }


def _bench_trace_overhead(repeats: int = 3):
    """Tracing must observe, not perturb: the same prepared int8
    engine with spans on vs off, interleaved best-of-``repeats`` timed
    passes. Returns the ``trace_overhead`` summary block whose
    ``within_5pct`` flag guards the observability tax."""
    cfg = dataclasses.replace(reduced("qwen2-0.5b"),
                              precision_policy="int8_serving")
    api = registry.build(cfg)
    params = api.init(jax.random.PRNGKey(0))
    engines = {}
    calibration, p = "auto", params
    for name, trace in (("off", False), ("on", True)):
        eng = ServingEngine(cfg, api, p, config=EngineConfig(
            batch_slots=4, cache_len=128, decode_block=8,
            act_calibration=calibration, trace=trace))
        calibration, p = eng.act_scales, eng.params
        _warmup(eng)
        engines[name] = eng
    best = {k: 0.0 for k in engines}
    for _ in range(repeats):
        for name, eng in engines.items():
            tok_s, _, _ = _timed_pass(eng, cfg)
            best[name] = max(best[name], tok_s)
    overhead = 1.0 - best["on"] / max(best["off"], 1e-9)
    return {
        "tok_per_s_trace_off": best["off"],
        "tok_per_s_trace_on": best["on"],
        "overhead_frac": overhead,
        "trace_events": len(engines["on"].tracer.events),
        "within_5pct": overhead <= 0.05,
    }


def _bench_cold_start(repeats: int = 2):
    """Engine cold start per policy: raw fp32 construction (quantize +
    pack + calibrate) vs ``fabric.build_engine`` from a checkpoint.

    Best-of-``repeats`` on both paths so one-time trace/compile costs
    don't masquerade as the restart tax — the second construction
    reuses compiled quantization programs, matching a long-lived
    process picking up a new replica. Asserts the int4 storage
    identity: packed projection data bytes x 8 == the fp32 bytes of
    the same projections.
    """
    import os
    import tempfile

    from repro.fabric import build_engine, save_engine_checkpoint
    from repro.quant.prepare import PreparedWeight, iter_projection_weights

    out = {}
    with tempfile.TemporaryDirectory() as root:
        for policy in POLICIES:
            cfg = dataclasses.replace(reduced("qwen2-0.5b"),
                                      precision_policy=policy)
            api = registry.build(cfg)
            params = api.init(jax.random.PRNGKey(0))
            ecfg = EngineConfig(batch_slots=2, cache_len=128,
                                act_calibration="auto")
            raw_s, eng = float("inf"), None
            for _ in range(repeats):
                t0 = time.perf_counter()
                eng = ServingEngine(cfg, api, params, config=ecfg)
                raw_s = min(raw_s, time.perf_counter() - t0)
            ckpt = os.path.join(root, policy)
            save_engine_checkpoint(eng, ckpt)
            restore_s = float("inf")
            for _ in range(repeats):
                t0 = time.perf_counter()
                restored = build_engine(ckpt)
                restore_s = min(restore_s, time.perf_counter() - t0)
            assert restored.prepared == eng.prepared
            disk = sum(os.path.getsize(os.path.join(dp, fn))
                       for dp, _, fns in os.walk(ckpt) for fn in fns)
            paths = registry.projection_paths(cfg)
            raw_by_path = dict(iter_projection_weights(params, paths))
            packed = packed_fp32 = 0
            for p, w in iter_projection_weights(restored.params, paths):
                if (isinstance(w, PreparedWeight)
                        and w.kind == "int4_packed"):
                    packed += int(w.data.nbytes)
                    packed_fp32 += int(raw_by_path[p].size) * 4
            if policy == "int4_serving":
                assert packed and packed * 8 == packed_fp32, \
                    (policy, packed, packed_fp32)
            out[policy] = {
                "raw_s": raw_s,
                "restore_s": restore_s,
                "speedup": raw_s / max(restore_s, 1e-9),
                "checkpoint_bytes": disk,
                "int4_packed_proj_bytes": packed,
                "int4_packed_proj_bytes_fp32": packed_fp32,
            }
    return out


# failover section: fleet shape and workload for the deterministic
# kill/sever scenarios (small enough that requeued work visibly queues
# behind the survivor's two slots)
FAILOVER_N = 6
FAILOVER_MAX_NEW = 12
FAILOVER_KILL_TICK = 3


def _bench_failover():
    """Failover economics on a deterministic two-worker fleet restored
    from one serve-ready checkpoint: recovery latency (the clock time
    from losing a worker to the first post-recovery token of a request
    it held) and token waste (tokens the fleet generates twice) for the
    two recovery paths — requeue-from-scratch (a non-resumable worker
    dies) vs reconnect-and-resume (a resumable worker's link is severed
    and it rejoins holding its engine state). ManualClock-driven, so
    both numbers are scheduling facts in ticks, not wall-clock noise;
    each scenario must still drain with zero loss and streams identical
    to the single-engine reference."""
    import tempfile

    from repro.fabric import save_engine_checkpoint
    from repro.fabric.checkpoint import build_engine
    from repro.fabric.controller import (Controller, ManualClock,
                                         reattach_local_worker,
                                         spawn_local_worker)
    from repro.fabric.smoke import _engine_streams, _make_requests, _streams

    cfg = dataclasses.replace(reduced("qwen2-0.5b"),
                              precision_policy="int4_serving")
    api = registry.build(cfg)
    params = api.init(jax.random.PRNGKey(0))
    engine = ServingEngine(cfg, api, params, config=EngineConfig(
        batch_slots=2, cache_len=64, act_calibration="auto"))

    def _generated(req):
        return 0 if req.tokens is None else len(req.tokens) - len(req.prompt)

    out = {}
    with tempfile.TemporaryDirectory() as root:
        ckpt = os.path.join(root, "ckpt")
        save_engine_checkpoint(engine, ckpt, step=0)
        ref = _engine_streams(
            build_engine(ckpt, api=api),
            _make_requests(cfg, FAILOVER_N, FAILOVER_MAX_NEW, 0))
        for mode in ("requeue", "resume"):
            clock = ManualClock()
            ctrl = Controller(heartbeat_timeout=4.0, clock=clock)
            spawn_local_worker(ctrl, ckpt, name="survivor")
            victim = spawn_local_worker(ctrl, ckpt, name="victim",
                                        resumable=(mode == "resume"))
            reqs = _make_requests(cfg, FAILOVER_N, FAILOVER_MAX_NEW, 0)
            for r in reqs:
                ctrl.submit(r)
            for _ in range(FAILOVER_KILL_TICK):
                clock.advance(1.0)
                ctrl.tick()
            affected = sorted(victim.replica.in_flight)
            assert affected, "kill tick landed with nothing in flight"
            received = {rid: _generated(victim.replica.in_flight[rid])
                        for rid in affected}
            t_kill = clock()
            victim.endpoint.close()     # dead socket / severed link
            # tick until an affected request's token count GROWS again
            # (requeue resets it to zero first, so growth — not
            # exceeding the kill-time count — is the recovery event)
            by_rid = {r.rid: r for r in reqs}
            prev = dict(received)
            recovered_at = None
            reattached = False
            while ctrl.has_pending():
                clock.advance(1.0)
                ctrl.tick()
                if (mode == "resume" and not reattached
                        and victim.state == "suspect"):
                    reattach_local_worker(ctrl, victim.driver.worker)
                    reattached = True
                cur = {rid: _generated(by_rid[rid]) for rid in affected}
                if recovered_at is None and any(
                        cur[rid] > prev[rid] for rid in affected):
                    recovered_at = clock()
                prev = cur
            assert _streams(ctrl.completed) == ref, f"{mode} lost tokens"
            # requeue regenerates everything the controller already had
            # for the victim's in-flight work; resume regenerates
            # nothing (the engine kept its state across the severance)
            wasted = sum(received.values()) if mode == "requeue" else 0
            total = FAILOVER_N * FAILOVER_MAX_NEW
            out[mode] = {
                "recovery_s": recovered_at - t_kill,
                "affected_requests": len(affected),
                "tokens_at_kill": sum(received.values()),
                "wasted_tokens": wasted,
                "waste_frac": wasted / total,
                "requeued": ctrl.scheduler.requeued,
                "resumed": ctrl.resumed,
            }
            assert (ctrl.scheduler.requeued == 0) == (mode == "resume")
    out["resume_waste_cut"] = (out["requeue"]["wasted_tokens"]
                               - out["resume"]["wasted_tokens"])
    return out


def run(verbose: bool = True, repeats: int = 3):
    """Whole-bench wrapper: fused executors default to the Pallas
    backend, which on CPU means interpret mode — pure tracing overhead
    that would drown the datapath being measured. Pin the identical-math
    XLA reference backend for the duration of the bench (unless the
    caller pinned one explicitly) so every wall-clock row, fused or
    staged, measures real compute."""
    prev = os.environ.get("REPRO_FUSED_BACKEND")
    os.environ["REPRO_FUSED_BACKEND"] = prev or "xla"
    try:
        return _run(verbose, repeats)
    finally:
        if prev is None:
            os.environ.pop("REPRO_FUSED_BACKEND", None)
        else:
            os.environ["REPRO_FUSED_BACKEND"] = prev


def _run(verbose: bool = True, repeats: int = 3):
    # build + warm every engine of every policy FIRST, then interleave
    # the timed repeat sweeps across policies: each engine's
    # best-of-``repeats`` samples span the whole bench wall-clock
    # instead of one ~10s window per policy, so a machine-load swing
    # hits every policy's best equally and cannot invert the
    # cross-policy ratios (speedup_vs_bf16 and friends)
    built = {p: _build_policy(p) for p in POLICIES}
    best = {p: {k: (0.0, 0, 0.0) for k in built[p][1]} for p in POLICIES}
    for _ in range(repeats):
        for p, (cfg, engines) in built.items():
            for name, eng in engines.items():
                tok_s, ticks, seconds = _timed_pass(eng, cfg)
                if tok_s > best[p][name][0]:
                    best[p][name] = (tok_s, ticks, seconds)
    results = {}
    for policy in POLICIES:
        cfg, engines = built[policy]
        results[policy] = r = _collect_policy(cfg, engines, best[policy])
        if verbose:
            ttft = r["ttft_s"].get("p50", 0.0) * 1e3
            qd = r["queue_delay_s"].get("p90", 0.0) * 1e3
            sweep = ", ".join(f"b{blk}={r['block_sweep'][str(blk)]:.0f}"
                              for blk in BLOCKS)
            row(f"serve/{policy}",
                r["seconds"] * 1e6 / max(MAX_NEW * N_REQUESTS, 1),
                f"{r['tok_per_s']:.1f} tok/s prepared "
                f"({r['tok_per_s_dynamic']:.1f} dynamic; {sweep}), "
                f"{r['ticks']} ticks, ttft_p50={ttft:.0f}ms, "
                f"queue_p90={qd:.0f}ms, w={r['weight_bytes']}B")
    router_r = _bench_router()
    if verbose:
        row("serve/router[int8+bf16]",
            router_r["seconds"] * 1e6 / max(MAX_NEW * N_REQUESTS, 1),
            f"{router_r['tok_per_s']:.1f} tok/s, "
            f"counters={router_r['counters']}")
    bursty = _bench_bursty()
    if verbose:
        for name in ("baseline", "continuous"):
            b = bursty[name]
            row(f"serve/bursty-{name}",
                b["seconds"] * 1e6 / max(b["new_tokens"], 1),
                f"ttft_p95={b['ttft_p95_ms']:.0f}ms "
                f"slo={b['slo_attainment']:.2f} "
                f"goodput={b['goodput_tok_per_s']:.1f} tok/s "
                f"(eos_stops={b['counters']['eos_stops']}, "
                f"mid_block={b['counters']['mid_block_admits']})")
    trace_ov = _bench_trace_overhead(repeats)
    if verbose:
        row("serve/trace-overhead",
            trace_ov["overhead_frac"] * 1e6,
            f"{trace_ov['tok_per_s_trace_on']:.1f} tok/s traced vs "
            f"{trace_ov['tok_per_s_trace_off']:.1f} untraced "
            f"({trace_ov['overhead_frac'] * 100:+.1f}%, "
            f"{trace_ov['trace_events']} events)")
        if not trace_ov["within_5pct"]:
            print("WARNING: tracing overhead exceeds the 5% budget")
    fusedr = _bench_fused(repeats)
    if verbose:
        t = fusedr["operand_bytes_per_block"]
        row("serve/fused-vs-staged",
            1e6 / max(fusedr["tok_per_s"]["fused"], 1e-9),
            f"{fusedr['tok_per_s']['fused']:.1f} tok/s fused vs "
            f"{fusedr['tok_per_s']['staged']:.1f} staged "
            f"({fusedr['fused_speedup']:.2f}x, b{fusedr['decode_block']}), "
            f"mats={fusedr['staged_materializations_per_block']}, "
            f"operand {t['packed']}B vs {t['staged']}B "
            f"({t['ratio']:.2f}x traffic cut)")
    cold = _bench_cold_start()
    if verbose:
        for p, c in cold.items():
            row(f"serve/cold-start[{p}]", c["restore_s"] * 1e6,
                f"restore {c['restore_s'] * 1e3:.0f}ms vs raw "
                f"{c['raw_s'] * 1e3:.0f}ms ({c['speedup']:.1f}x), "
                f"ckpt={c['checkpoint_bytes']}B")
    failover = _bench_failover()
    if verbose:
        for mode in ("requeue", "resume"):
            f = failover[mode]
            row(f"serve/failover-{mode}", f["recovery_s"] * 1e6,
                f"recovery={f['recovery_s']:.0f} ticks, "
                f"wasted={f['wasted_tokens']} tok "
                f"({f['waste_frac'] * 100:.0f}% of run), "
                f"affected={f['affected_requests']}")

    base = results["bf16"]["tok_per_s"]
    summary = {
        "tok_per_s": {p: results[p]["tok_per_s"] for p in POLICIES},
        "tok_per_s_dynamic": {p: results[p]["tok_per_s_dynamic"]
                              for p in POLICIES},
        "prepared_speedup": {p: results[p]["tok_per_s"]
                             / results[p]["tok_per_s_dynamic"]
                             for p in POLICIES},
        "weight_bytes": {p: results[p]["weight_bytes"]
                         for p in POLICIES},
        "weight_bytes_fp32": results["bf16"]["weight_bytes_dynamic"],
        "weight_quants_per_step": {
            p: results[p]["weight_quants_per_step"] for p in POLICIES},
        "act_quants_per_step": {
            p: results[p]["act_quants_per_step"] for p in POLICIES},
        "act_quants_per_step_dynamic": {
            p: results[p]["act_quants_per_step_dynamic"]
            for p in POLICIES},
        "block_sweep": {p: results[p]["block_sweep"] for p in POLICIES},
        "host_syncs_per_token": {p: results[p]["host_syncs_per_token"]
                                 for p in POLICIES},
        "best_block": {p: results[p]["best_block"] for p in POLICIES},
        "tok_per_s_best_block": {p: results[p]["tok_per_s_best_block"]
                                 for p in POLICIES},
        "block_speedup_8v1": {
            p: results[p]["block_sweep"][_HI_BLOCK]
            / results[p]["block_sweep"][str(min(BLOCKS))]
            for p in POLICIES},
        "speedup_vs_bf16": {p: results[p]["tok_per_s"] / base
                            for p in POLICIES},
        "speedup_vs_bf16_best_block": {
            p: results[p]["tok_per_s_best_block"]
            / results["bf16"]["tok_per_s_best_block"] for p in POLICIES},
        "ttft_p50_ms": {p: results[p]["ttft_s"].get("p50", 0.0) * 1e3
                        for p in POLICIES},
        "ttft_p90_ms": {p: results[p]["ttft_s"].get("p90", 0.0) * 1e3
                        for p in POLICIES},
        "queue_delay_p90_ms": {
            p: results[p]["queue_delay_s"].get("p90", 0.0) * 1e3
            for p in POLICIES},
        "prefill_calls": {p: results[p]["prefill_calls"]
                          for p in POLICIES},
        "router": {"tok_per_s": router_r["tok_per_s"],
                   "counters": router_r["counters"]},
        "bursty": {
            "ttft_slo_ms": bursty["ttft_slo_ms"],
            "ttft_p95_ms": {k: bursty[k]["ttft_p95_ms"]
                            for k in ("baseline", "continuous")},
            "slo_attainment": {k: bursty[k]["slo_attainment"]
                               for k in ("baseline", "continuous")},
            "goodput_tok_per_s": {k: bursty[k]["goodput_tok_per_s"]
                                  for k in ("baseline", "continuous")},
            "ttft_p95_speedup": bursty["ttft_p95_speedup"],
            "goodput_speedup": bursty["goodput_speedup"],
        },
        "trace_overhead": trace_ov,
        "failover": {
            "recovery_s": {m: failover[m]["recovery_s"]
                           for m in ("requeue", "resume")},
            "wasted_tokens": {m: failover[m]["wasted_tokens"]
                              for m in ("requeue", "resume")},
            "waste_frac": {m: failover[m]["waste_frac"]
                           for m in ("requeue", "resume")},
            "resume_waste_cut": failover["resume_waste_cut"],
        },
        "fused": fusedr,
        "operand_bytes_per_block": fusedr["operand_bytes_per_block"],
        "cold_start": {
            "restore_s": {p: cold[p]["restore_s"] for p in POLICIES},
            "raw_s": {p: cold[p]["raw_s"] for p in POLICIES},
            "speedup": {p: cold[p]["speedup"] for p in POLICIES},
            "checkpoint_bytes": {p: cold[p]["checkpoint_bytes"]
                                 for p in POLICIES},
            "int4_packed_x8_equals_fp32": True,   # asserted above
        },
        # full per-policy/router/bursty breakdown (formerly the
        # separate serve_bench.json artifact)
        "detail": {**results, "router": router_r, "bursty": bursty,
                   "fused": fusedr, "cold_start": cold,
                   "failover": failover},
    }
    emit("BENCH_serving", summary)
    if verbose:
        print("serve: " + ", ".join(
            f"{k}={v['tok_per_s']:.1f} tok/s "
            f"({v['tok_per_s'] / base:.2f}x bf16, "
            f"{summary['prepared_speedup'][k]:.2f}x dynamic)"
            for k, v in results.items()))
        print("serve blocks: " + ", ".join(
            f"{p}@b{summary['best_block'][p]}="
            f"{summary['tok_per_s_best_block'][p]:.1f} tok/s "
            f"({summary['block_speedup_8v1'][p]:.2f}x b8/b1, "
            f"{summary['speedup_vs_bf16_best_block'][p]:.2f}x bf16)"
            for p in POLICIES))
        sb = summary["bursty"]
        print(f"serve bursty: continuous ttft_p95="
              f"{sb['ttft_p95_ms']['continuous']:.0f}ms vs baseline "
              f"{sb['ttft_p95_ms']['baseline']:.0f}ms "
              f"({sb['ttft_p95_speedup']:.2f}x), slo attainment "
              f"{sb['slo_attainment']['continuous']:.2f} vs "
              f"{sb['slo_attainment']['baseline']:.2f}, goodput "
              f"{sb['goodput_speedup']:.2f}x")
        print("serve cold-start: " + ", ".join(
            f"{p}={cold[p]['speedup']:.1f}x "
            f"({cold[p]['restore_s'] * 1e3:.0f}ms restore)"
            for p in POLICIES))
    return summary


def main():
    run()


if __name__ == "__main__":
    main()
