"""Serving throughput bench: the runtime under each precision policy.

The paper's kind is inference acceleration — this measures the actual
serving stack (``repro.serving`` batched-prefill continuous batching on
the reduced qwen2 model) across the policies the IPU datapath motivates,
on CPU wall time. Not a TPU number; the relative policy costs and the
engine overheads are the object of measurement. Engines are warmed
(one throwaway request compiles the prefill/decode programs) so the
reported tok/s is steady-state serving throughput, not jit latency.

Reports decode tok/s plus the latency distribution of the runtime —
TTFT and queue-delay percentiles per policy — and a two-replica
plan-aware router pass. Each policy is measured twice: with the
prepared-weight datapath (quant.prepare storage, the default) and with
preparation disabled (per-step dynamic weight quantization, the
pre-refactor behavior), so the trajectory captures both the decode
speedup and the per-replica weight-resident-bytes win. Emits two
artifacts:

* ``serve_bench.json`` — full per-policy detail (back-compat name);
* ``BENCH_serving.json`` — the compact trajectory row ``benchmarks/run.py``
  tracks across PRs, like ``BENCH_autotune``.
"""
import dataclasses
import time

import numpy as np
import jax

from benchmarks.common import emit, row
from repro.configs import reduced
from repro.serving import Request, Router, ServingEngine, build_replicas
from repro.models import registry

POLICIES = ("bf16", "int8_serving", "int4_serving", "paper_hybrid")
N_REQUESTS = 8
PROMPT_LEN = 8
# enough decode steps that the timed region dwarfs per-tick Python
# overhead jitter (the prepared-vs-dynamic delta is the measurement)
MAX_NEW = 32


def _workload(cfg, tagged_every=0):
    rng = np.random.default_rng(0)
    reqs = []
    for rid in range(N_REQUESTS):
        reqs.append(Request(
            rid=rid,
            prompt=rng.integers(0, cfg.vocab, PROMPT_LEN, dtype=np.int32),
            max_new_tokens=MAX_NEW,
            tags=("accuracy",) if tagged_every and rid % tagged_every == 0
            else ()))
    return reqs


def _warmup(engine):
    """One throwaway request through prefill + decode so the jitted
    programs compile outside the timed window (time_fn-style warmup);
    the engine's request log and counters are then reset."""
    engine.submit(Request(rid=-1,
                          prompt=np.zeros(PROMPT_LEN, np.int32),
                          max_new_tokens=2))
    engine.run_until_drained()
    engine.completed.clear()
    for k in engine.counters:
        engine.counters[k] = 0


def _reset(engine):
    engine.completed.clear()
    for k in engine.counters:
        engine.counters[k] = 0


def _timed_pass(engine, cfg):
    """Submit the standard workload, drain, return (tok/s, ticks, dt)."""
    _reset(engine)
    for req in _workload(cfg):
        engine.submit(req)
    t0 = time.time()
    ticks = engine.run_until_drained()
    dt = time.time() - t0
    return engine.metrics()["new_tokens"] / dt, ticks, dt


def _bench_policy(policy: str, repeats: int = 3):
    """One policy, prepared AND dynamic engines, alternating timed
    passes (best-of-``repeats``, so a machine-load spike during one
    pass cannot invert the prepared-vs-dynamic comparison)."""
    cfg = dataclasses.replace(reduced("qwen2-0.5b"),
                              precision_policy=policy)
    api = registry.build(cfg)
    params = api.init(jax.random.PRNGKey(0))
    engines = {
        "prepared": ServingEngine(cfg, api, params, batch_slots=4,
                                  cache_len=128, prepare_weights=True),
        "dynamic": ServingEngine(cfg, api, params, batch_slots=4,
                                 cache_len=128, prepare_weights=False),
    }
    for eng in engines.values():
        _warmup(eng)
    # best pass per engine, keeping the ticks/seconds of that pass so
    # the reported latency and throughput describe the same run
    best = {k: (0.0, 0, 0.0) for k in engines}
    for _ in range(repeats):
        for name, eng in engines.items():
            tok_s, ticks, seconds = _timed_pass(eng, cfg)
            if tok_s > best[name][0]:
                best[name] = (tok_s, ticks, seconds)
    eng = engines["prepared"]
    m = eng.metrics()
    return {
        "tok_per_s": best["prepared"][0],
        "ticks": best["prepared"][1],
        "seconds": best["prepared"][2],
        "tok_per_s_dynamic": best["dynamic"][0],
        "ttft_s": m["ttft_s"], "queue_delay_s": m["queue_delay_s"],
        "prefill_calls": m["counters"]["prefill_calls"],
        "prefill_tokens": m["counters"]["prefill_tokens"],
        "decode_steps": m["counters"]["decode_steps"],
        "weight_bytes": m["weight_bytes"]["projections"],
        "weight_bytes_total": m["weight_bytes"]["total"],
        "weight_bytes_dynamic":
            engines["dynamic"].weight_bytes()["projections"],
        "weight_quants_per_step": eng.weight_quant_trace_count(),
        "weight_quants_per_step_dynamic":
            engines["dynamic"].weight_quant_trace_count(),
    }


def _bench_router():
    """Two-replica plan-aware pass: the routing layer's overhead and
    split on a mixed (third accuracy-tagged) workload."""
    cfg = reduced("qwen2-0.5b")
    replicas = build_replicas(cfg, ("int8_serving", "bf16"),
                              batch_slots=2, cache_len=128)
    router = Router(replicas, strategy="plan_aware")
    for rep in replicas:
        _warmup(rep.engine)
    for req in _workload(cfg, tagged_every=3):
        router.submit(req)
    t0 = time.time()
    ticks = router.run_until_drained()
    dt = time.time() - t0
    new_tokens = sum(r.new_tokens for r in router.completed.values())
    return {
        "tok_per_s": new_tokens / dt, "ticks": ticks, "seconds": dt,
        "counters": router.routing_counters(),
        "completed": len(router.completed),
    }


def run(verbose: bool = True):
    results = {}
    for policy in POLICIES:
        results[policy] = r = _bench_policy(policy)
        if verbose:
            ttft = r["ttft_s"].get("p50", 0.0) * 1e3
            qd = r["queue_delay_s"].get("p90", 0.0) * 1e3
            row(f"serve/{policy}",
                r["seconds"] * 1e6 / max(MAX_NEW * N_REQUESTS, 1),
                f"{r['tok_per_s']:.1f} tok/s prepared "
                f"({r['tok_per_s_dynamic']:.1f} dynamic), "
                f"{r['ticks']} ticks, ttft_p50={ttft:.0f}ms, "
                f"queue_p90={qd:.0f}ms, w={r['weight_bytes']}B")
    router_r = _bench_router()
    if verbose:
        row("serve/router[int8+bf16]",
            router_r["seconds"] * 1e6 / max(MAX_NEW * N_REQUESTS, 1),
            f"{router_r['tok_per_s']:.1f} tok/s, "
            f"counters={router_r['counters']}")
    emit("serve_bench", {**results, "router": router_r})

    base = results["bf16"]["tok_per_s"]
    summary = {
        "tok_per_s": {p: results[p]["tok_per_s"] for p in POLICIES},
        "tok_per_s_dynamic": {p: results[p]["tok_per_s_dynamic"]
                              for p in POLICIES},
        "prepared_speedup": {p: results[p]["tok_per_s"]
                             / results[p]["tok_per_s_dynamic"]
                             for p in POLICIES},
        "weight_bytes": {p: results[p]["weight_bytes"]
                         for p in POLICIES},
        "weight_bytes_fp32": results["bf16"]["weight_bytes_dynamic"],
        "weight_quants_per_step": {
            p: results[p]["weight_quants_per_step"] for p in POLICIES},
        "speedup_vs_bf16": {p: results[p]["tok_per_s"] / base
                            for p in POLICIES},
        "ttft_p50_ms": {p: results[p]["ttft_s"].get("p50", 0.0) * 1e3
                        for p in POLICIES},
        "ttft_p90_ms": {p: results[p]["ttft_s"].get("p90", 0.0) * 1e3
                        for p in POLICIES},
        "queue_delay_p90_ms": {
            p: results[p]["queue_delay_s"].get("p90", 0.0) * 1e3
            for p in POLICIES},
        "prefill_calls": {p: results[p]["prefill_calls"]
                          for p in POLICIES},
        "router": {"tok_per_s": router_r["tok_per_s"],
                   "counters": router_r["counters"]},
    }
    emit("BENCH_serving", summary)
    if verbose:
        print("serve: " + ", ".join(
            f"{k}={v['tok_per_s']:.1f} tok/s "
            f"({v['tok_per_s'] / base:.2f}x bf16, "
            f"{summary['prepared_speedup'][k]:.2f}x dynamic)"
            for k, v in results.items()))
    return summary


def main():
    run()


if __name__ == "__main__":
    main()
