"""Serving throughput bench: the runtime under each precision policy.

The paper's kind is inference acceleration — this measures the actual
serving stack (``repro.serving`` batched-prefill continuous batching on
the reduced qwen2 model) across the policies the IPU datapath motivates,
on CPU wall time. Not a TPU number; the relative policy costs and the
engine overheads are the object of measurement. Engines are warmed
(one throwaway request compiles the prefill/decode programs) so the
reported tok/s is steady-state serving throughput, not jit latency.

Reports decode tok/s plus the latency distribution of the runtime —
TTFT and queue-delay percentiles per policy — and a two-replica
plan-aware router pass. Emits two artifacts:

* ``serve_bench.json`` — full per-policy detail (back-compat name);
* ``BENCH_serving.json`` — the compact trajectory row ``benchmarks/run.py``
  tracks across PRs, like ``BENCH_autotune``.
"""
import dataclasses
import time

import numpy as np
import jax

from benchmarks.common import emit, row
from repro.configs import reduced
from repro.serving import Request, Router, ServingEngine, build_replicas
from repro.models import registry

POLICIES = ("bf16", "int8_serving", "int4_serving", "paper_hybrid")
N_REQUESTS = 6
PROMPT_LEN = 8
MAX_NEW = 8


def _workload(cfg, tagged_every=0):
    rng = np.random.default_rng(0)
    reqs = []
    for rid in range(N_REQUESTS):
        reqs.append(Request(
            rid=rid,
            prompt=rng.integers(0, cfg.vocab, PROMPT_LEN, dtype=np.int32),
            max_new_tokens=MAX_NEW,
            tags=("accuracy",) if tagged_every and rid % tagged_every == 0
            else ()))
    return reqs


def _warmup(engine):
    """One throwaway request through prefill + decode so the jitted
    programs compile outside the timed window (time_fn-style warmup);
    the engine's request log and counters are then reset."""
    engine.submit(Request(rid=-1,
                          prompt=np.zeros(PROMPT_LEN, np.int32),
                          max_new_tokens=2))
    engine.run_until_drained()
    engine.completed.clear()
    for k in engine.counters:
        engine.counters[k] = 0


def _bench_policy(policy: str):
    cfg = dataclasses.replace(reduced("qwen2-0.5b"),
                              precision_policy=policy)
    api = registry.build(cfg)
    params = api.init(jax.random.PRNGKey(0))
    engine = ServingEngine(cfg, api, params, batch_slots=4, cache_len=128)
    _warmup(engine)
    for req in _workload(cfg):
        engine.submit(req)
    t0 = time.time()
    ticks = engine.run_until_drained()
    dt = time.time() - t0
    m = engine.metrics()
    new_tokens = m["new_tokens"]
    return {
        "tok_per_s": new_tokens / dt, "ticks": ticks, "seconds": dt,
        "ttft_s": m["ttft_s"], "queue_delay_s": m["queue_delay_s"],
        "prefill_calls": m["counters"]["prefill_calls"],
        "prefill_tokens": m["counters"]["prefill_tokens"],
        "decode_steps": m["counters"]["decode_steps"],
    }


def _bench_router():
    """Two-replica plan-aware pass: the routing layer's overhead and
    split on a mixed (third accuracy-tagged) workload."""
    cfg = reduced("qwen2-0.5b")
    replicas = build_replicas(cfg, ("int8_serving", "bf16"),
                              batch_slots=2, cache_len=128)
    router = Router(replicas, strategy="plan_aware")
    for rep in replicas:
        _warmup(rep.engine)
    for req in _workload(cfg, tagged_every=3):
        router.submit(req)
    t0 = time.time()
    ticks = router.run_until_drained()
    dt = time.time() - t0
    new_tokens = sum(r.new_tokens for r in router.completed.values())
    return {
        "tok_per_s": new_tokens / dt, "ticks": ticks, "seconds": dt,
        "counters": router.routing_counters(),
        "completed": len(router.completed),
    }


def run(verbose: bool = True):
    results = {}
    for policy in POLICIES:
        results[policy] = r = _bench_policy(policy)
        if verbose:
            ttft = r["ttft_s"].get("p50", 0.0) * 1e3
            qd = r["queue_delay_s"].get("p90", 0.0) * 1e3
            row(f"serve/{policy}",
                r["seconds"] * 1e6 / max(MAX_NEW * N_REQUESTS, 1),
                f"{r['tok_per_s']:.1f} tok/s, {r['ticks']} ticks, "
                f"ttft_p50={ttft:.0f}ms, queue_p90={qd:.0f}ms")
    router_r = _bench_router()
    if verbose:
        row("serve/router[int8+bf16]",
            router_r["seconds"] * 1e6 / max(MAX_NEW * N_REQUESTS, 1),
            f"{router_r['tok_per_s']:.1f} tok/s, "
            f"counters={router_r['counters']}")
    emit("serve_bench", {**results, "router": router_r})

    base = results["bf16"]["tok_per_s"]
    summary = {
        "tok_per_s": {p: results[p]["tok_per_s"] for p in POLICIES},
        "speedup_vs_bf16": {p: results[p]["tok_per_s"] / base
                            for p in POLICIES},
        "ttft_p50_ms": {p: results[p]["ttft_s"].get("p50", 0.0) * 1e3
                        for p in POLICIES},
        "ttft_p90_ms": {p: results[p]["ttft_s"].get("p90", 0.0) * 1e3
                        for p in POLICIES},
        "queue_delay_p90_ms": {
            p: results[p]["queue_delay_s"].get("p90", 0.0) * 1e3
            for p in POLICIES},
        "prefill_calls": {p: results[p]["prefill_calls"]
                          for p in POLICIES},
        "router": {"tok_per_s": router_r["tok_per_s"],
                   "counters": router_r["counters"]},
    }
    emit("BENCH_serving", summary)
    if verbose:
        print("serve: " + ", ".join(
            f"{k}={v['tok_per_s']:.1f} tok/s "
            f"({v['tok_per_s'] / base:.2f}x bf16)"
            for k, v in results.items()))
    return summary


def main():
    run()


if __name__ == "__main__":
    main()
