"""Serving throughput bench: decode tok/s under each precision policy.

The paper's kind is inference acceleration — this measures the actual
serving stack (ServingEngine continuous batching on the reduced qwen2
model) across the policies the IPU datapath motivates, on CPU wall time.
Not a TPU number; the relative policy costs and the engine overheads are
the object of measurement."""
import dataclasses
import time

import numpy as np
import jax

from benchmarks.common import emit, row
from repro.configs import reduced
from repro.launch.serve import Request, ServingEngine
from repro.models import registry


def run(verbose: bool = True):
    results = {}
    for policy in ("bf16", "int8_serving", "int4_serving", "paper_hybrid"):
        cfg = dataclasses.replace(reduced("qwen2-0.5b"),
                                  precision_policy=policy)
        api = registry.build(cfg)
        params = api.init(jax.random.PRNGKey(0))
        engine = ServingEngine(cfg, api, params, batch_slots=4,
                               cache_len=128)
        rng = np.random.default_rng(0)
        for rid in range(6):
            engine.submit(Request(
                rid=rid, prompt=rng.integers(0, cfg.vocab, 8,
                                             dtype=np.int32),
                max_new_tokens=8))
        t0 = time.time()
        ticks = engine.run_until_drained()
        dt = time.time() - t0
        new_tokens = sum(len(r.tokens) - len(r.prompt)
                        for r in engine.completed.values())
        results[policy] = {"tok_per_s": new_tokens / dt, "ticks": ticks,
                           "seconds": dt}
        if verbose:
            row(f"serve/{policy}", dt * 1e6 / max(new_tokens, 1),
                f"{new_tokens / dt:.1f} tok/s, {ticks} ticks")
    emit("serve_bench", results)
    if verbose:
        base = results["bf16"]["tok_per_s"]
        print("serve: " + ", ".join(
            f"{k}={v['tok_per_s']:.1f} tok/s "
            f"({v['tok_per_s']/base:.2f}x bf16)"
            for k, v in results.items()))
    return results


def main():
    run()


if __name__ == "__main__":
    main()
