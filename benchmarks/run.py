"""Benchmark harness: one module per paper table/figure.

Prints ``name,us_per_call,derived`` CSV rows per benchmark and writes
JSON artifacts to results/bench/ (consumed by EXPERIMENTS.md and
renderable with ``tools/roofline_table.py --bench``).

Figure/table sweeps run through the ``repro.exp`` engine: pass
``--jobs N`` to fan points out over worker processes and re-run with a
warm cache to skip every already-simulated point (``--no-cache`` to
force re-simulation).
"""
import argparse
import inspect
import sys
import time

from repro import exp


def main(argv=None) -> None:
    from benchmarks import (autotune_bench, fig3_error, fig7_breakdown,
                            fig8_perf, fig9_expdiff, fig10_tradeoff,
                            kernel_bench, serve_bench, table1)
    ap = argparse.ArgumentParser(description=__doc__)
    exp.add_cli_args(ap)
    ap.add_argument("--only", default=None, metavar="NAME",
                    help="run a single benchmark module (e.g. fig8_perf)")
    args = ap.parse_args(argv)
    engine = exp.EngineConfig.from_args(args)

    mods = (table1, fig7_breakdown, fig9_expdiff, fig8_perf,
            fig10_tradeoff, fig3_error, autotune_bench, kernel_bench,
            serve_bench)
    if args.only:
        mods = [m for m in mods if m.__name__.split(".")[-1] == args.only]
        if not mods:
            sys.exit(f"unknown benchmark {args.only!r}")
    t0 = time.time()
    print("name,us_per_call,derived")
    for mod in mods:
        name = mod.__name__.split(".")[-1]
        print(f"# --- {name} ---", flush=True)
        # wall-time benches (kernel/serve) don't sweep and take no engine
        if "engine" in inspect.signature(mod.run).parameters:
            mod.run(engine=engine)
        else:
            mod.run()
    print(f"# engine {engine.total.summary()}")
    print(f"# all benchmarks done in {time.time() - t0:.1f}s")


if __name__ == '__main__':
    main()
