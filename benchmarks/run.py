"""Benchmark harness: one module per paper table/figure.

Prints ``name,us_per_call,derived`` CSV rows per benchmark and writes
JSON artifacts to results/bench/ (consumed by EXPERIMENTS.md).
"""
import sys
import time


def main() -> None:
    from benchmarks import (fig3_error, fig7_breakdown, fig8_perf,
                            fig9_expdiff, fig10_tradeoff, kernel_bench,
                            serve_bench, table1)
    t0 = time.time()
    print("name,us_per_call,derived")
    for mod in (table1, fig7_breakdown, fig9_expdiff, fig8_perf,
                fig10_tradeoff, fig3_error, kernel_bench, serve_bench):
        name = mod.__name__.split(".")[-1]
        print(f"# --- {name} ---", flush=True)
        mod.main()
    print(f"# all benchmarks done in {time.time() - t0:.1f}s")


if __name__ == '__main__':
    main()
