"""Fig. 8 reproduction: MC-IPU execution time vs precision and cluster.

(a) Normalized execution time (vs the 38b-adder baselines) for adder
precisions {12, 16, 20, 24, 28} on the four study cases: ResNet-18/-50,
InceptionV3 forward and ResNet-18 backward, FP16 ops with FP32
accumulation (sw precision 28); 8-input tiles normalized to Baseline1,
16-input to Baseline2.

(b) Cluster-size sweep for MC-IPU(16).

Paper trends to reproduce: backward >> forward slowdown; >4x at 12b for
backprop; 8-input outperforms 16-input; small clusters recover most of
the loss for forward, backward keeps >= ~1.6x even at cluster 1.
"""
import dataclasses

from benchmarks.common import emit, row
from repro.core import simulator as sim
from repro.core import workloads as wl

CASES = {
    "resnet18_fwd": (wl.resnet18, sim.FORWARD_SOURCE),
    "resnet50_fwd": (wl.resnet50, sim.FORWARD_SOURCE),
    "inception_v3_fwd": (wl.inception_v3, sim.FORWARD_SOURCE),
    "resnet18_bwd": (wl.resnet18_backward, sim.BACKWARD_SOURCE),
}


def run(verbose: bool = True):
    results = {}
    # (a) precision sweep
    for n_inputs, base in ((8, sim.BASELINE1), (16, sim.BASELINE2)):
        for case, (layers_fn, source) in CASES.items():
            layers = layers_fn()
            for w in (12, 16, 20, 24, 28):
                tile = dataclasses.replace(base, adder_w=w)
                t = sim.normalized_exec_time(layers, tile, base,
                                             source=source)
                key = f"precision/{n_inputs}in/{case}/w{w}"
                results[key] = t
                if verbose:
                    row(f"fig8a/{key}", 0.0, f"normalized={t:.3f}")
    # (b) cluster sweep at w=16
    for n_inputs, base in ((8, sim.BASELINE1), (16, sim.BASELINE2)):
        for case, (layers_fn, source) in CASES.items():
            layers = layers_fn()
            for c in (base.ipus_per_tile, 8, 4, 2, 1):
                tile = dataclasses.replace(base, adder_w=16,
                                           cluster_size=c)
                t = sim.normalized_exec_time(layers, tile, base,
                                             source=source)
                key = f"cluster/{n_inputs}in/{case}/c{c}"
                results[key] = t
                if verbose:
                    row(f"fig8b/{key}", 0.0, f"normalized={t:.3f}")
    # ablation: Fig.-5 threshold walk (serve partition k in cycle k, empty
    # partitions burn a cycle) vs a scheduler that skips empty partitions
    # — a micro-optimization the paper's EHU design leaves on the table.
    for case, (layers_fn, source) in (("resnet50_fwd", CASES["resnet50_fwd"]),
                                      ("resnet18_bwd", CASES["resnet18_bwd"])):
        layers = layers_fn()
        for w in (12, 16):
            base_tile = dataclasses.replace(sim.BASELINE2, adder_w=w)
            opt_tile = dataclasses.replace(base_tile,
                                           skip_empty_partitions=True)
            t0 = sim.normalized_exec_time(layers, base_tile, sim.BASELINE2,
                                          source=source)
            t1 = sim.normalized_exec_time(layers, opt_tile, sim.BASELINE2,
                                          source=source)
            key = f"skip_empty/{case}/w{w}"
            results[key] = {"fig5_walk": t0, "skip_empty": t1,
                            "gain": t0 / t1}
            if verbose:
                row(f"fig8c/{key}", 0.0,
                    f"walk={t0:.3f} skip={t1:.3f} gain={t0/t1:.3f}x")

    # derived fp_mc_factors for the area/power designs (used by Table 1)
    fwd = [results[f"precision/16in/{c}/w16"]
           for c in ("resnet18_fwd", "resnet50_fwd", "inception_v3_fwd")]
    results["mc_factor_w16_fwd_mean"] = sum(fwd) / len(fwd)
    claims = {
        "bwd_slower_than_fwd": (
            results["precision/16in/resnet18_bwd/w16"]
            > results["precision/16in/resnet18_fwd/w16"]),
        "w12_bwd_over_2x": results["precision/8in/resnet18_bwd/w12"] > 2.0,
        "monotone_precision": (
            results["precision/16in/resnet50_fwd/w12"]
            >= results["precision/16in/resnet50_fwd/w20"]
            >= results["precision/16in/resnet50_fwd/w28"]),
        "clustering_recovers": (
            results["cluster/8in/resnet50_fwd/c1"]
            <= results["cluster/8in/resnet50_fwd/c8"]),
    }
    results["claims"] = claims
    emit("fig8_perf", results)
    return results


def main():
    res = run()
    print("fig8 claims:", res["claims"])


if __name__ == "__main__":
    main()
