"""Fig. 8 reproduction: MC-IPU execution time vs precision and cluster.

(a) Normalized execution time (vs the 38b-adder baselines) for adder
precisions {12, 16, 20, 24, 28} on the four study cases: ResNet-18/-50,
InceptionV3 forward and ResNet-18 backward, FP16 ops with FP32
accumulation (sw precision 28); 8-input tiles normalized to Baseline1,
16-input to Baseline2.

(b) Cluster-size sweep for MC-IPU(16).

Paper trends to reproduce: backward >> forward slowdown; >4x at 12b for
backprop; 8-input outperforms 16-input; small clusters recover most of
the loss for forward, backward keeps >= ~1.6x even at cluster 1.

Sweeps are declared through ``repro.exp``; ``eval_point`` is the shared
simulator entry other scripts (fig10) reuse so identical (workload,
tile, precision) points are cached once.
"""
import dataclasses

from benchmarks.common import emit, engine_main, row
from repro import exp
from repro.core import simulator as sim
from repro.core import workloads as wl

CASES = {
    "resnet18_fwd": (wl.resnet18, "forward"),
    "resnet50_fwd": (wl.resnet50, "forward"),
    "inception_v3_fwd": (wl.inception_v3, "forward"),
    "resnet18_bwd": (wl.resnet18_backward, "backward"),
}

_SOURCES = {"forward": sim.FORWARD_SOURCE, "backward": sim.BACKWARD_SOURCE}


def _base(n_inputs: int) -> sim.TileConfig:
    return sim.BASELINE1 if n_inputs == 8 else sim.BASELINE2


def eval_point(case: str, n_inputs: int, w: int, cluster=None,
               skip_empty: bool = False) -> float:
    """Normalized execution time of one (workload, tile) design point."""
    layers_fn, src_name = CASES[case]
    base = _base(n_inputs)
    tile = dataclasses.replace(base, adder_w=w, cluster_size=cluster,
                               skip_empty_partitions=skip_empty)
    return sim.normalized_exec_time(layers_fn(), tile, base,
                                    source=_SOURCES[src_name])


def _specs():
    precision = exp.SweepSpec(
        name="fig8a_precision", fn="benchmarks.fig8_perf:eval_point",
        axes={"n_inputs": [8, 16], "case": list(CASES),
              "w": [12, 16, 20, 24, 28]},
        fixed={"cluster": None, "skip_empty": False})
    # cluster values: the no-clustering point is the whole tile
    # (ipus_per_tile = 4 * n_inputs), then {8, 4, 2, 1}
    cluster = exp.SweepSpec(
        name="fig8b_cluster", fn="benchmarks.fig8_perf:eval_point",
        axes={"n_inputs": [8, 16], "case": list(CASES),
              "cluster": [64, 32, 8, 4, 2, 1]},
        fixed={"w": 16, "skip_empty": False},
        filters=[lambda p: p["cluster"] in (8, 4, 2, 1)
                 or p["cluster"] == 4 * p["n_inputs"]])
    # ablation: Fig.-5 threshold walk (serve partition k in cycle k, empty
    # partitions burn a cycle) vs a scheduler that skips empty partitions
    # — a micro-optimization the paper's EHU design leaves on the table.
    skip = exp.SweepSpec(
        name="fig8c_skip_empty", fn="benchmarks.fig8_perf:eval_point",
        axes={"case": ["resnet50_fwd", "resnet18_bwd"], "w": [12, 16],
              "skip_empty": [False, True]},
        fixed={"n_inputs": 16, "cluster": None})
    return precision, cluster, skip


def run(verbose: bool = True, engine: exp.EngineConfig = None):
    engine = engine or exp.EngineConfig()
    precision, cluster, skip = _specs()
    results = {}
    rows = []

    res, _ = exp.run_sweep(precision, engine)
    rows += exp.rows_from(res, precision.name)
    for p, t in res:
        kw = p.kwargs
        key = f"precision/{kw['n_inputs']}in/{kw['case']}/w{kw['w']}"
        results[key] = t
        if verbose:
            row(f"fig8a/{key}", 0.0, f"normalized={t:.3f}")

    res, _ = exp.run_sweep(cluster, engine)
    rows += exp.rows_from(res, cluster.name)
    for p, t in res:
        kw = p.kwargs
        key = f"cluster/{kw['n_inputs']}in/{kw['case']}/c{kw['cluster']}"
        results[key] = t
        if verbose:
            row(f"fig8b/{key}", 0.0, f"normalized={t:.3f}")

    res, _ = exp.run_sweep(skip, engine)
    rows += exp.rows_from(res, skip.name)
    walk = {(p.kwargs["case"], p.kwargs["w"]): t for p, t in res
            if not p.kwargs["skip_empty"]}
    for p, t in res:
        kw = p.kwargs
        if not kw["skip_empty"]:
            continue
        t0 = walk[(kw["case"], kw["w"])]
        key = f"skip_empty/{kw['case']}/w{kw['w']}"
        results[key] = {"fig5_walk": t0, "skip_empty": t, "gain": t0 / t}
        if verbose:
            row(f"fig8c/{key}", 0.0,
                f"walk={t0:.3f} skip={t:.3f} gain={t0/t:.3f}x")

    # derived fp_mc_factors for the area/power designs (used by Table 1)
    fwd = [results[f"precision/16in/{c}/w16"]
           for c in ("resnet18_fwd", "resnet50_fwd", "inception_v3_fwd")]
    results["mc_factor_w16_fwd_mean"] = sum(fwd) / len(fwd)
    claims = {
        "bwd_slower_than_fwd": (
            results["precision/16in/resnet18_bwd/w16"]
            > results["precision/16in/resnet18_fwd/w16"]),
        "w12_bwd_over_2x": results["precision/8in/resnet18_bwd/w12"] > 2.0,
        "monotone_precision": (
            results["precision/16in/resnet50_fwd/w12"]
            >= results["precision/16in/resnet50_fwd/w20"]
            >= results["precision/16in/resnet50_fwd/w28"]),
        "clustering_recovers": (
            results["cluster/8in/resnet50_fwd/c1"]
            <= results["cluster/8in/resnet50_fwd/c8"]),
    }
    results["claims"] = claims
    results["rows"] = rows
    emit("fig8_perf", results)
    if verbose:
        print("fig8 claims:", claims)
    return results


def main(argv=None):
    engine_main(run, argv, __doc__)


if __name__ == "__main__":
    main()
