"""Fig. 9 reproduction: distribution of alignment sizes (max_exp - exp).

Forward-path products cluster near zero (paper: only ~1% exceed 8 bits);
backward products spread much wider — the empirical basis for small
shifters + MC-IPU. Also derives the 'weight of tail > 8' statistic.
"""
import numpy as np

from benchmarks.common import emit, engine_main, row
from repro import exp
from repro.core import simulator as sim


def eval_point(direction: str, n: int = 8, samples: int = 200_000,
               seed: int = 0) -> dict:
    """Alignment-size histogram stats for one exponent source."""
    src = (sim.FORWARD_SOURCE if direction == "forward"
           else sim.BACKWARD_SOURCE)
    hist = sim.exponent_diff_histogram(src, n=n, samples=samples, seed=seed)
    return {
        "hist": hist.tolist(),
        "frac_gt8": float(hist[9:].sum()),
        "frac_le2": float(hist[:3].sum()),
        "mean": float((np.arange(len(hist)) * hist).sum()),
    }


def spec() -> exp.SweepSpec:
    return exp.SweepSpec(
        name="fig9_expdiff", fn="benchmarks.fig9_expdiff:eval_point",
        axes={"direction": ["forward", "backward"]},
        fixed={"n": 8, "samples": 200_000, "seed": 0})


def run(verbose: bool = True, engine: exp.EngineConfig = None):
    engine = engine or exp.EngineConfig()
    res, _ = exp.run_sweep(spec(), engine)
    results = {}
    for p, r in res:
        name = p.kwargs["direction"]
        results[name] = r
        if verbose:
            row(f"fig9/{name}", 0.0,
                f">8bits={r['frac_gt8']:.3%} <=2bits={r['frac_le2']:.1%} "
                f"mean={r['mean']:.2f}")
    claims = {
        "fwd_tail_small": results["forward"]["frac_gt8"] < 0.05,
        "bwd_much_wider": (results["backward"]["frac_gt8"]
                           > 5 * results["forward"]["frac_gt8"]),
    }
    results["claims"] = claims
    results["rows"] = exp.rows_from(res, "fig9_expdiff")
    emit("fig9_expdiff", results)
    if verbose:
        print("fig9 claims:", claims)
    return results


def main(argv=None):
    engine_main(run, argv, __doc__)


if __name__ == "__main__":
    main()
