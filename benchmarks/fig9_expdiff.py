"""Fig. 9 reproduction: distribution of alignment sizes (max_exp - exp).

Forward-path products cluster near zero (paper: only ~1% exceed 8 bits);
backward products spread much wider — the empirical basis for small
shifters + MC-IPU. Also derives the 'weight of tail > 8' statistic.
"""
import numpy as np

from benchmarks.common import emit, row
from repro.core import simulator as sim


def run(verbose: bool = True):
    results = {}
    for name, src in (("forward", sim.FORWARD_SOURCE),
                      ("backward", sim.BACKWARD_SOURCE)):
        hist = sim.exponent_diff_histogram(src, n=8, samples=200_000)
        results[name] = {
            "hist": hist.tolist(),
            "frac_gt8": float(hist[9:].sum()),
            "frac_le2": float(hist[:3].sum()),
            "mean": float((np.arange(len(hist)) * hist).sum()),
        }
        if verbose:
            r = results[name]
            row(f"fig9/{name}", 0.0,
                f">8bits={r['frac_gt8']:.3%} <=2bits={r['frac_le2']:.1%} "
                f"mean={r['mean']:.2f}")
    claims = {
        "fwd_tail_small": results["forward"]["frac_gt8"] < 0.05,
        "bwd_much_wider": (results["backward"]["frac_gt8"]
                           > 5 * results["forward"]["frac_gt8"]),
    }
    results["claims"] = claims
    emit("fig9_expdiff", results)
    return results


def main():
    print("fig9 claims:", run()["claims"])


if __name__ == "__main__":
    main()
