"""Table 1 reproduction: TOPS/mm^2 and TOPS/W across the design-space
sensitivity study (MC-SER / MC-IPU4 / MC-IPU84 / MC-IPU8 / NVDLA / FP16 /
INT8 / INT4) x workloads (4x4, 8x4, 8x8, FP16xFP16).

The design x workload grid is a ``repro.exp`` sweep over the analytic
area/power model.
"""
import numpy as np

from benchmarks.common import emit, engine_main, row
from repro import exp
from repro.core.area_power import (PAPER_TABLE1, WORKLOAD_TYPES,
                                   efficiency, paper_designs)


def eval_point(design: str, workload: str) -> dict:
    """One Table-1 cell: model-predicted (TOPS/mm2, TOPS/W) vs paper."""
    d = paper_designs()[design]
    a, p = efficiency(d, WORKLOAD_TYPES[workload])
    pa, pp = PAPER_TABLE1[design][workload]
    return {"model_tops_mm2": a, "paper_tops_mm2": pa,
            "model_tops_w": p, "paper_tops_w": pp}


def spec() -> exp.SweepSpec:
    return exp.SweepSpec(
        name="table1", fn="benchmarks.table1:eval_point",
        axes={"design": list(PAPER_TABLE1), "workload": list(WORKLOAD_TYPES)})


def run(verbose: bool = True, engine: exp.EngineConfig = None):
    engine = engine or exp.EngineConfig()
    res, _ = exp.run_sweep(spec(), engine)
    results = {}
    errs = []
    for p, r in res:
        kw = p.kwargs
        results[f"{kw['design']}/{kw['workload']}"] = r
        a, pa = r["model_tops_mm2"], r["paper_tops_mm2"]
        pw, pp = r["model_tops_w"], r["paper_tops_w"]
        if a is not None and pa is not None:
            errs += [abs(a / pa - 1), abs(pw / pp - 1)]
        if verbose:
            fmt = lambda v: f"{v:.2f}" if v is not None else "--"
            row(f"table1/{kw['design']}/{kw['workload']}", 0.0,
                f"area {fmt(a)} (paper {fmt(pa)}) "
                f"power {fmt(pw)} (paper {fmt(pp)})")
    results["median_abs_rel_err"] = float(np.median(errs))
    results["max_abs_rel_err"] = float(np.max(errs))
    results["rows"] = exp.rows_from(res, "table1")
    emit("table1", results)
    if verbose:
        print(f"table1: median |rel err| "
              f"{results['median_abs_rel_err']:.1%}, "
              f"max {results['max_abs_rel_err']:.1%}")
    return results


def main(argv=None):
    engine_main(run, argv, __doc__)


if __name__ == "__main__":
    main()
