"""Table 1 reproduction: TOPS/mm^2 and TOPS/W across the design-space
sensitivity study (MC-SER / MC-IPU4 / MC-IPU84 / MC-IPU8 / NVDLA / FP16 /
INT8 / INT4) x workloads (4x4, 8x4, 8x8, FP16xFP16)."""
import numpy as np

from benchmarks.common import emit, row
from repro.core.area_power import (PAPER_TABLE1, WORKLOAD_TYPES,
                                   table1_model)


def run(verbose: bool = True):
    model = table1_model()
    results = {}
    errs = []
    for design, rows in model.items():
        for wlk, (a, p) in rows.items():
            pa, pp = PAPER_TABLE1[design][wlk]
            results[f"{design}/{wlk}"] = {
                "model_tops_mm2": a, "paper_tops_mm2": pa,
                "model_tops_w": p, "paper_tops_w": pp,
            }
            if a is not None and pa is not None:
                errs += [abs(a / pa - 1), abs(p / pp - 1)]
            if verbose:
                fmt = lambda v: f"{v:.2f}" if v is not None else "--"
                row(f"table1/{design}/{wlk}", 0.0,
                    f"area {fmt(a)} (paper {fmt(pa)}) "
                    f"power {fmt(p)} (paper {fmt(pp)})")
    results["median_abs_rel_err"] = float(np.median(errs))
    results["max_abs_rel_err"] = float(np.max(errs))
    emit("table1", results)
    return results


def main():
    res = run()
    print(f"table1: median |rel err| {res['median_abs_rel_err']:.1%}, "
          f"max {res['max_abs_rel_err']:.1%}")


if __name__ == "__main__":
    main()
