"""Kernel wall-time benchmarks (CPU, XLA backend of the same math).

Measures the fidelity-path FP-IP emulation matmul — paper-faithful
nine-plane vs fused single-plane (the §Perf beyond-paper optimization) —
against the plain f32 matmul and the integer deployment path. Interpret-
mode Pallas numbers are reported once for reference (interpreter
overhead dominates; correctness is covered by tests)."""
import numpy as np
import jax
import jax.numpy as jnp

from benchmarks.common import emit, row, time_fn
from repro.core.ipu import IPUConfig
from repro.kernels import ops

M = N = 256
K = 512


def run(verbose: bool = True):
    rng = np.random.default_rng(0)
    a16 = jnp.asarray(rng.normal(0, 1, (M, K)), jnp.float16)
    b16 = jnp.asarray(rng.normal(0, 1, (K, N)), jnp.float16)
    a8 = jnp.asarray(rng.integers(-127, 128, (M, K)), jnp.int8)
    b8 = jnp.asarray(rng.integers(-127, 128, (K, N)), jnp.int8)
    cfg = IPUConfig(n=16, w=16, accum="fp32")
    cfg28 = IPUConfig(n=16, w=28, accum="fp32")

    results = {}

    def bench(name, fn, *args, flops=2 * M * N * K):
        us = time_fn(fn, *args)
        results[name] = {"us": us, "gflops": flops / us / 1e3}
        if verbose:
            row(f"kernel/{name}", us,
                f"{results[name]['gflops']:.2f} GFLOP/s-equiv")

    bench("f32_matmul",
          jax.jit(lambda a, b: a.astype(jnp.float32)
                  @ b.astype(jnp.float32)), a16, b16)
    bench("int8_qmm_xla",
          lambda a, b: ops.int8_matmul(a, b, backend="xla"), a8, b8)
    bench("mpmm_faithful_w16",
          lambda a, b: ops.mp_matmul(a, b, cfg, backend="xla"), a16, b16)
    bench("mpmm_fused_w16",
          lambda a, b: ops.mp_matmul(a, b, cfg, fused=True,
                                     backend="xla"), a16, b16)
    bench("mpmm_faithful_w28",
          lambda a, b: ops.mp_matmul(a, b, cfg28, backend="xla"), a16, b16)
    bench("mpmm_fused_w28",
          lambda a, b: ops.mp_matmul(a, b, cfg28, fused=True,
                                     backend="xla"), a16, b16)

    results["fused_speedup_w16"] = (results["mpmm_faithful_w16"]["us"]
                                    / results["mpmm_fused_w16"]["us"])
    results["fused_speedup_w28"] = (results["mpmm_faithful_w28"]["us"]
                                    / results["mpmm_fused_w28"]["us"])
    results["emulation_overhead_vs_f32"] = (
        results["mpmm_fused_w16"]["us"] / results["f32_matmul"]["us"])
    emit("kernel_bench", results)
    if verbose:
        print(f"kernel: fused speedup w16 "
              f"{results['fused_speedup_w16']:.2f}x, "
              f"w28 {results['fused_speedup_w28']:.2f}x; emulation "
              f"overhead vs f32 "
              f"{results['emulation_overhead_vs_f32']:.1f}x")
    return results


def main():
    run()


if __name__ == "__main__":
    main()
