"""Shared benchmark utilities: timing, result emission, engine CLI."""
import argparse
import json
import os
import time

import numpy as np

from repro import exp

RESULTS_DIR = os.environ.get("BENCH_OUT", "results/bench")


def engine_main(run_fn, argv=None, doc=None):
    """Shared entry point of every sweep-backed benchmark module: parse
    the engine CLI (--jobs/--no-cache/--cache-dir), run, print the
    executed/cached counter line."""
    ap = argparse.ArgumentParser(description=doc)
    exp.add_cli_args(ap)
    args = ap.parse_args(argv)
    engine = exp.EngineConfig.from_args(args)
    run_fn(engine=engine)
    print(f"# {engine.total.summary()}")


def emit(name: str, payload: dict):
    os.makedirs(RESULTS_DIR, exist_ok=True)
    with open(os.path.join(RESULTS_DIR, f"{name}.json"), "w") as f:
        json.dump(payload, f, indent=1, default=float)


def time_fn(fn, *args, warmup: int = 1, iters: int = 5) -> float:
    """Median wall-time per call in microseconds (post-warmup, blocked)."""
    import jax
    for _ in range(warmup):
        jax.block_until_ready(fn(*args))
    ts = []
    for _ in range(iters):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args))
        ts.append(time.perf_counter() - t0)
    return float(np.median(ts) * 1e6)


def row(name: str, us: float, derived: str = ""):
    print(f"{name},{us:.1f},{derived}")
