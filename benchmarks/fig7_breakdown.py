"""Fig. 7 reproduction: area/power breakdown of MC-IPU tiles.

Columns: INT-only, MC-IPU(12..28), NVDLA-like 38b baseline, for 8- and
16-input tiles; component categories (FAcc, WBuf, ShCNT, MULT, Shft, AT).
Also prints the §4.2 deltas the paper calls out.

The variant grid runs through ``repro.exp`` (analytic model — cheap, but
cached and fanned out like every other sweep for uniformity).
"""
from benchmarks.common import emit, engine_main, row
from repro import exp
from repro.core.area_power import (IPUDesign, area_breakdown, fig7_deltas,
                                   power_breakdown, tile_area_mm2,
                                   tile_power_w)
from repro.core.simulator import tile_for


def eval_point(n_inputs: int, w: int, fp: bool) -> dict:
    """Area/power of one tile variant (fp=False -> INT-only design)."""
    tile = tile_for(n_inputs)
    name = f"mc{w}" if fp else "INT"
    d = IPUDesign(name, 4, 4, w, fp, tile)
    return {
        "area_mm2": tile_area_mm2(d),
        "power_w": tile_power_w(d),
        "area_breakdown": area_breakdown(d),
        "power_breakdown": power_breakdown(d),
    }


def spec() -> exp.SweepSpec:
    return exp.SweepSpec(
        name="fig7_breakdown", fn="benchmarks.fig7_breakdown:eval_point",
        axes={"n_inputs": [8, 16], "fp": [False, True],
              "w": [12, 16, 20, 24, 28, 38]},
        # the INT column is a single design point per tile width
        filters=[lambda p: p["fp"] or p["w"] == 12])


def run(verbose: bool = True, engine: exp.EngineConfig = None):
    engine = engine or exp.EngineConfig()
    res, _ = exp.run_sweep(spec(), engine)
    results = {"deltas": fig7_deltas()}
    for p, r in res:
        kw = p.kwargs
        name = f"MC-IPU({kw['w']})" if kw["fp"] else "INT"
        key = f"{kw['n_inputs']}in/{name}"
        results[key] = r
        if verbose:
            ab = r["area_breakdown"]
            top = max(ab, key=ab.get)
            row(f"fig7/{key}", 0.0,
                f"area={r['area_mm2']:.4f}mm2 "
                f"power={r['power_w']:.3f}W top={top}"
                f"({ab[top]:.0%})")
    results["rows"] = exp.rows_from(res, "fig7_breakdown")
    emit("fig7_breakdown", results)
    if verbose:
        d = results["deltas"]
        print(f"fig7 deltas: 38->28 {d['adder_38_to_28']:+.1%} "
              f"(paper -17%), 38->12 {d['adder_38_to_12']:+.1%} "
              f"(paper -39%), INT->MC12 {d['int_to_mcipu12']:+.1%} "
              f"(paper +43%)")
    return results


def main(argv=None):
    engine_main(run, argv, __doc__)


if __name__ == "__main__":
    main()
