"""Fig. 7 reproduction: area/power breakdown of MC-IPU tiles.

Columns: INT-only, MC-IPU(12..28), NVDLA-like 38b baseline, for 8- and
16-input tiles; component categories (FAcc, WBuf, ShCNT, MULT, Shft, AT).
Also prints the §4.2 deltas the paper calls out.
"""
import dataclasses

from benchmarks.common import emit, row
from repro.core.area_power import (IPUDesign, area_breakdown, fig7_deltas,
                                   power_breakdown, tile_area_mm2,
                                   tile_power_w)
from repro.core.simulator import TileConfig


def run(verbose: bool = True):
    results = {"deltas": fig7_deltas()}
    for n_inputs in (8, 16):
        tile = TileConfig() if n_inputs == 16 else dataclasses.replace(
            TileConfig(), c_unroll=8, k_unroll=8)
        variants = {"INT": IPUDesign("INT", 4, 4, 12, False, tile)}
        for w in (12, 16, 20, 24, 28, 38):
            variants[f"MC-IPU({w})"] = IPUDesign(f"mc{w}", 4, 4, w, True,
                                                 tile)
        for name, d in variants.items():
            key = f"{n_inputs}in/{name}"
            results[key] = {
                "area_mm2": tile_area_mm2(d),
                "power_w": tile_power_w(d),
                "area_breakdown": area_breakdown(d),
                "power_breakdown": power_breakdown(d),
            }
            if verbose:
                ab = results[key]["area_breakdown"]
                top = max(ab, key=ab.get)
                row(f"fig7/{key}", 0.0,
                    f"area={results[key]['area_mm2']:.4f}mm2 "
                    f"power={results[key]['power_w']:.3f}W top={top}"
                    f"({ab[top]:.0%})")
    emit("fig7_breakdown", results)
    return results


def main():
    res = run()
    d = res["deltas"]
    print(f"fig7 deltas: 38->28 {d['adder_38_to_28']:+.1%} (paper -17%), "
          f"38->12 {d['adder_38_to_12']:+.1%} (paper -39%), "
          f"INT->MC12 {d['int_to_mcipu12']:+.1%} (paper +43%)")


if __name__ == "__main__":
    main()
