"""Quickstart: the paper's arithmetic in five minutes.

    PYTHONPATH=src python examples/quickstart.py

1. Decompose FP16 numbers the way the IPU does.
2. Run the approximate FP-IP at several IPU precisions; compare against
   the exact dot product and the Theorem-1 bound.
3. Show the MC-IPU multi-cycle schedule on the paper's Fig.-4 example.
4. Query the calibrated 7nm area/power model.
"""
import numpy as np
import jax.numpy as jnp

from repro.core import exact_ref
from repro.core.ipu import IPUConfig, fp16_inner_product
from repro.core import ehu, error_bounds
from repro.core.area_power import (INT4, FP16, efficiency, paper_designs)


def main():
    rng = np.random.default_rng(0)

    print("=== 1. FP16 decomposition ===")
    for v in (1.0, -0.375, 6.1e-5):
        s, e, m = exact_ref.decompose_fp16(v)
        print(f"  {v:>10}: sign={s:+d} exp={e:+d} mag={m} "
              f"(= {s} * {m} * 2^{e - 10})")

    print("\n=== 2. Approximate FP-IP vs exact ===")
    a = np.asarray(rng.normal(0, 1, 64), np.float16)
    b = np.asarray(rng.normal(0, 1, 64), np.float16)
    exact = float(exact_ref.exact_dot(a, b))
    print(f"  exact dot: {exact:.8f}")
    for w in (12, 16, 20, 28):
        cfg = IPUConfig(n=16, w=w, accum="fp32", sw_precision=w)
        approx = float(np.asarray(fp16_inner_product(
            jnp.asarray(a), jnp.asarray(b), cfg)))
        bound = float(error_bounds.fp_ip_bound(w, 10, 16))
        print(f"  IPU({w:2d}):  {approx:.8f}   |err|={abs(approx-exact):.2e}"
              f"   Theorem-1 bound~{bound:.2e}")

    print("\n=== 3. MC-IPU schedule (paper Fig. 4: sp=5) ===")
    shift = jnp.asarray([0, 8, 7, 2])      # alignments of A, B, C, D
    active = jnp.ones(4, bool)
    cyc, local = ehu.service_schedule(shift, active, sp=5)
    n = ehu.num_cycles(shift, active, sp=5)
    print(f"  products A-D alignments {list(map(int, shift))}")
    print(f"  cycles needed: {int(n)}")
    for i, name in enumerate("ABCD"):
        print(f"  {name}: served in cycle {int(cyc[i])}, "
              f"local shift {int(local[i])}")

    print("\n=== 4. Area/power model (calibrated to the paper's 7nm) ===")
    for name, d in paper_designs().items():
        a4, p4 = efficiency(d, INT4)
        af, pf = efficiency(d, FP16)
        fmt = lambda v: f"{v:6.2f}" if v is not None else "    --"
        print(f"  {name:9s} INT4: {fmt(a4)} TOPS/mm2 {fmt(p4)} TOPS/W"
              f"   FP16: {fmt(af)} TFLOPS/mm2 {fmt(pf)} TFLOPS/W")


if __name__ == "__main__":
    main()
