"""Design-space exploration: size an MC-IPU accelerator for YOUR model.

Reproduces the paper's Fig.-10 sweep and then goes beyond it: scores the
(precision, cluster) design points on a *transformer serving* workload
built from one of the assigned architectures' projection shapes — the
kind of study a deployment team would run before taping out.

    PYTHONPATH=src python examples/accelerator_study.py --arch qwen2-0.5b
"""
import argparse
import dataclasses

from repro.configs import get_config
from repro.core import simulator as sim
from repro.core import workloads as wl
from repro.core.area_power import (FP16, INT4, IPUDesign, baseline_design,
                                   efficiency)
from repro.core.simulator import TileConfig


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2-0.5b")
    ap.add_argument("--seq", type=int, default=128)
    args = ap.parse_args()

    cfg = get_config(args.arch)
    layers = wl.lm_projection_layers(
        cfg.d_model, cfg.d_ff, cfg.n_layers, cfg.vocab, seq=args.seq,
        name=cfg.arch_id)
    print(f"workload: {cfg.arch_id} projections, seq={args.seq}, "
          f"{wl.total_macs(layers)/1e9:.1f} GMACs/token-batch")

    base = sim.BASELINE2
    print(f"\n{'design':>12s} {'mc':>5s} {'TOPS/mm2':>9s} {'TFLOPS/mm2':>11s}"
          f" {'TOPS/W':>7s} {'TFLOPS/W':>9s}")
    rows = []
    for w in (12, 16, 20, 28):
        for c in (1, 4, 16):
            tile = dataclasses.replace(TileConfig(), adder_w=w,
                                       cluster_size=c)
            mc = sim.normalized_exec_time(layers, tile, base,
                                          source=sim.FORWARD_SOURCE)
            d = IPUDesign(f"({w},{c})", 4, 4, w, True, tile,
                          cluster_size=c, fp_mc_factor=mc)
            ai, pi = efficiency(d, INT4)
            af, pf = efficiency(d, FP16)
            rows.append(((w, c), mc, ai, af, pi, pf))
            print(f"{f'({w},{c})':>12s} {mc:5.2f} {ai:9.1f} {af:11.2f} "
                  f"{pi:7.2f} {pf:9.3f}")
    b = baseline_design(16)
    ai, pi = efficiency(b, INT4)
    af, pf = efficiency(b, FP16)
    print(f"{'NO-OPT':>12s} {1.0:5.2f} {ai:9.1f} {af:11.2f} "
          f"{pi:7.2f} {pf:9.3f}")

    # simple Pareto over (TOPS/mm2, TFLOPS/mm2)
    pareto = []
    for r in rows:
        if not any((o[2] >= r[2] and o[3] >= r[3] and o != r)
                   for o in rows):
            pareto.append(r[0])
    print(f"\narea-efficiency Pareto points: {pareto}")
    print("paper's power-Pareto picks: (12,1) and (16,1)")


if __name__ == "__main__":
    main()
