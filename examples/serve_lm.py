"""End-to-end serving driver (the paper's kind is inference/accelerator).

Serves a small qwen2-family model with batched requests through the
continuous-batching engine, under a *mixed-precision policy* — the
paper's technique as deployment configuration: INT4 projections with the
router/head in higher precision, exactly the hybrid scheme the IPU is
built for. Also reports what the calibrated accelerator model says this
policy buys in area/power.

A plan searched offline by the precision planner serves directly:

    PYTHONPATH=src python examples/serve_lm.py [--policy int4_serving]
    PYTHONPATH=src python examples/serve_lm.py --plan results/plans/qwen2_0_5b.json
"""
import argparse
import dataclasses
import time

import numpy as np
import jax

from repro.configs import reduced
from repro.launch.serve import Request, ServingEngine
from repro.models import registry


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--policy", default="int4_serving",
                    choices=["bf16", "int8_serving", "int4_serving",
                             "paper_hybrid"])
    ap.add_argument("--plan", default=None, metavar="PLAN_JSON",
                    help="serve under a repro.autotune PrecisionPlan "
                         "artifact (overrides --policy)")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--max-new", type=int, default=12)
    args = ap.parse_args()

    policy_name = f"plan:{args.plan}" if args.plan else args.policy
    cfg = dataclasses.replace(reduced("qwen2-0.5b"),
                              precision_policy=policy_name)
    api = registry.build(cfg)
    params = api.init(jax.random.PRNGKey(0))
    engine = ServingEngine(cfg, api, params, batch_slots=args.slots,
                           cache_len=128)
    if args.plan:
        from repro.autotune.plan import load_plan
        plan = load_plan(args.plan)
        print(f"plan={plan.name} (arch {plan.arch}, "
              f"{len(plan.frontier)} frontier plans)")
        for path, mode in sorted(engine.routing_report().items()):
            print(f"  route {path}: {mode}")

    rng = np.random.default_rng(0)
    t0 = time.time()
    for rid in range(args.requests):
        prompt = rng.integers(0, cfg.vocab, rng.integers(4, 12),
                              dtype=np.int32)
        engine.submit(Request(rid=rid, prompt=prompt,
                              max_new_tokens=args.max_new))
    ticks = engine.run_until_drained()
    dt = time.time() - t0

    total_new = sum(len(r.tokens) - len(r.prompt)
                    for r in engine.completed.values())
    print(f"policy={policy_name} requests={args.requests} "
          f"slots={args.slots} ticks={ticks}")
    print(f"generated {total_new} tokens in {dt:.2f}s "
          f"({total_new / dt:.1f} tok/s on CPU)")
    for rid in sorted(engine.completed)[:3]:
        r = engine.completed[rid]
        print(f"  req{rid}: prompt={list(r.prompt[:6])}... -> "
              f"completion={r.tokens[len(r.prompt):][:8]}")

    # what the accelerator model says about this policy
    from repro.core.area_power import (INT4, INT8, FP16, efficiency,
                                       paper_designs)
    d = paper_designs()["MC-IPU4"]
    wl = {"int4_serving": INT4, "int8_serving": INT8}.get(args.policy)
    if wl is not None:
        a, p = efficiency(d, wl)
        af, pf = efficiency(d, FP16)
        print(f"\nMC-IPU4 accelerator at this policy: {a:.1f} TOPS/mm2, "
              f"{p:.2f} TOPS/W (vs FP16 path {af:.1f}/{pf:.2f}) — the "
              f"INT4 datapath the paper optimizes for.")


if __name__ == "__main__":
    main()
