"""End-to-end serving driver (the paper's kind is inference/accelerator).

Serves a small qwen2-family model through the ``repro.serving`` runtime
under mixed-precision policies — the paper's technique as deployment
configuration. Two modes:

* single engine (``--policy`` / ``--plan``): continuous batching with
  chunked prefill admission under one precision policy (engine tuning
  via ``EngineConfig``, per-request decoding via ``SamplingParams`` —
  try ``--temperature 0.8``), printing the per-projection routing
  report for plans;
* multi-replica router (``--replicas``): each replica carries its own
  policy or searched plan, and the plan-aware router splits a mixed
  workload (a third of the requests are accuracy-tagged) by the
  simulator-backed cost model.

    PYTHONPATH=src python examples/serve_lm.py [--policy int4_serving]
    PYTHONPATH=src python examples/serve_lm.py --plan results/plans/qwen2_0_5b.json
    PYTHONPATH=src python examples/serve_lm.py \
        --replicas int8_serving,plan:results/plans/qwen2_0_5b.json
"""
import argparse
import dataclasses
import time

import numpy as np
import jax

from repro.configs import reduced
from repro.serving import (EngineConfig, Request, Router, SamplingParams,
                           ServingEngine, build_replicas)


def _mixed_workload(cfg, n, max_new, tagged_every=3, temperature=0.0):
    rng = np.random.default_rng(0)
    sampling = SamplingParams(temperature=temperature)
    reqs = []
    for rid in range(n):
        prompt = rng.integers(0, cfg.vocab, int(rng.integers(4, 12)),
                              dtype=np.int32)
        reqs.append(Request(
            rid=rid, prompt=prompt, max_new_tokens=max_new,
            sampling=sampling,
            tags=("accuracy",) if rid % tagged_every == 0 else ()))
    return reqs


def _pct(block, key="p50"):
    return f"{block.get(key, 0) * 1e3:.1f}ms" if block else "n/a"


def _engine_config(args):
    return EngineConfig(
        batch_slots=args.slots, cache_len=128,
        decode_block=args.decode_block,
        act_calibration="auto" if args.calibrate else None)


def run_router(args, cfg):
    policies = [p for p in args.replicas.split(",") if p]
    replicas = build_replicas(cfg, policies, config=_engine_config(args))
    router = Router(replicas, strategy=args.strategy)
    for rep in replicas:
        storage = "prepared" if rep.engine.prepared else "dynamic"
        print(f"replica {rep.name}: cycles/tok="
              f"{rep.cost['cycles_per_token']:.4g} "
              f"tops/W={rep.cost['tops_per_w']:.3g} "
              f"acc_proxy={rep.cost['acc_proxy']:.3g} "
              f"weights={rep.cost['weight_bytes']['projections']}B "
              f"({storage})")

    t0 = time.time()
    for req in _mixed_workload(cfg, args.requests, args.max_new,
                               temperature=args.temperature):
        router.submit(req)
    ticks = router.run_until_drained()
    dt = time.time() - t0

    completed = router.completed
    total_new = sum(r.new_tokens for r in completed.values())
    print(f"\nstrategy={router.strategy} requests={args.requests} "
          f"completed={len(completed)} ticks={ticks} "
          f"({total_new / dt:.1f} tok/s on CPU)")
    for name, rep in router.report()["replicas"].items():
        m = rep["metrics"]
        print(f"  {name}: routed={rep['routed']} "
              f"ttft_p50={_pct(m['ttft_s'])} "
              f"queue_p90={_pct(m['queue_delay_s'], 'p90')} "
              f"prefill_calls={m['counters']['prefill_calls']}")


def run_single(args, cfg):
    policy_name = f"plan:{args.plan}" if args.plan else args.policy
    cfg = dataclasses.replace(cfg, precision_policy=policy_name)
    from repro.models import registry
    api = registry.build(cfg)
    params = api.init(jax.random.PRNGKey(0))
    engine = ServingEngine(cfg, api, params, config=_engine_config(args))
    if args.plan:
        from repro.autotune.plan import load_plan
        plan = load_plan(args.plan)
        print(f"plan={plan.name} (arch {plan.arch}, "
              f"{len(plan.frontier)} frontier plans)")
        for path, mode in sorted(engine.routing_report().items()):
            print(f"  route {path}: {mode}")

    t0 = time.time()
    for req in _mixed_workload(cfg, args.requests, args.max_new,
                               temperature=args.temperature):
        engine.submit(req)
    ticks = engine.run_until_drained()
    dt = time.time() - t0

    total_new = sum(r.new_tokens for r in engine.completed.values())
    m = engine.metrics()
    print(f"policy={policy_name} requests={args.requests} "
          f"slots={args.slots} ticks={ticks} "
          f"decode_block={engine.decode_block}"
          + (" calibrated" if m["act_calibrated"] else ""))
    print(f"generated {total_new} tokens in {dt:.2f}s "
          f"({total_new / dt:.1f} tok/s on CPU); "
          f"ttft_p50={_pct(m['ttft_s'])} "
          f"queue_p90={_pct(m['queue_delay_s'], 'p90')} "
          f"prefill_calls={m['counters']['prefill_calls']} "
          f"host_syncs={m['counters']['host_syncs']}")
    for rid in sorted(engine.completed)[:3]:
        r = engine.completed[rid]
        print(f"  req{rid}: prompt={list(r.prompt[:6])}... -> "
              f"completion={r.tokens[len(r.prompt):][:8]}")

    # what the accelerator model says about this policy
    from repro.core.area_power import (INT4, INT8, FP16, efficiency,
                                       paper_designs)
    d = paper_designs()["MC-IPU4"]
    wl = {"int4_serving": INT4, "int8_serving": INT8}.get(args.policy)
    if wl is not None and not args.plan:
        a, p = efficiency(d, wl)
        af, pf = efficiency(d, FP16)
        print(f"\nMC-IPU4 accelerator at this policy: {a:.1f} TOPS/mm2, "
              f"{p:.2f} TOPS/W (vs FP16 path {af:.1f}/{pf:.2f}) — the "
              f"INT4 datapath the paper optimizes for.")


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--policy", default="int4_serving",
                    choices=["bf16", "int8_serving", "int4_serving",
                             "paper_hybrid"])
    ap.add_argument("--plan", default=None, metavar="PLAN_JSON",
                    help="serve under a repro.autotune PrecisionPlan "
                         "artifact (overrides --policy)")
    ap.add_argument("--replicas", default=None, metavar="POLICY,POLICY,..",
                    help="run the multi-replica router instead: comma-"
                         "separated policy names or plan:<file> refs")
    ap.add_argument("--strategy", default="plan_aware",
                    choices=Router.STRATEGIES)
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--max-new", type=int, default=12)
    ap.add_argument("--decode-block", type=int, default=1,
                    help="tokens decoded per host dispatch (jitted scan "
                         "with on-device greedy selection; 1 = per-token; "
                         "quantized policies also need --calibrate)")
    ap.add_argument("--calibrate", action="store_true",
                    help="calibrate static activation scales at engine "
                         "construction (drops the per-token absmax)")
    ap.add_argument("--temperature", type=float, default=0.0,
                    help="per-request sampling temperature "
                         "(SamplingParams; 0 = greedy, seeded on-device "
                         "sampling otherwise)")
    args = ap.parse_args()

    cfg = reduced("qwen2-0.5b")
    if args.replicas:
        run_router(args, cfg)
    else:
        run_single(args, cfg)


if __name__ == "__main__":
    main()
