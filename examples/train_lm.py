"""Train a small LM on the synthetic Markov stream with the full stack:
sharded train step, AdamW, LR schedule, fault-tolerant loop with
checkpointing, and a mixed-precision policy.

Default is a fast CPU demo (~2 min). Scale knobs up on real hardware:

    PYTHONPATH=src python examples/train_lm.py \
        --d-model 256 --layers 4 --steps 200 --policy int8_serving
"""
import argparse
import dataclasses
import time

import jax
import numpy as np

from repro.configs import reduced
from repro.data.pipeline import DataConfig, SyntheticLMDataset
from repro.launch.train import TrainConfig, init_state, make_train_step
from repro.models import registry
from repro.optim import AdamWConfig
from repro.runtime.fault_tolerance import FTConfig, FaultTolerantLoop


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2-0.5b")
    ap.add_argument("--d-model", type=int, default=128)
    ap.add_argument("--layers", type=int, default=2)
    ap.add_argument("--steps", type=int, default=150)
    ap.add_argument("--batch", type=int, default=16)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--vocab", type=int, default=512)
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--policy", default="bf16")
    ap.add_argument("--ckpt-dir", default="/tmp/repro_train_lm")
    ap.add_argument("--resume", action="store_true",
                    help="resume from existing checkpoints (default: "
                         "start fresh)")
    args = ap.parse_args()
    if not args.resume:
        import shutil
        shutil.rmtree(args.ckpt_dir, ignore_errors=True)

    cfg = dataclasses.replace(
        reduced(args.arch),
        d_model=args.d_model, n_layers=args.layers, d_ff=4 * args.d_model,
        vocab=args.vocab, precision_policy=args.policy,
        head_dim=args.d_model // 4)
    api = registry.build(cfg)
    print(f"arch={cfg.arch_id} params~{cfg.params_count()/1e6:.1f}M "
          f"policy={args.policy}")

    mesh = jax.make_mesh((1, 1), ("data", "model"))
    tc = TrainConfig(adamw=AdamWConfig(lr=args.lr), warmup=20,
                     total_steps=args.steps)
    with mesh:
        step_fn, st_shard, _ = make_train_step(api, mesh, tc)
        state = init_state(api, jax.random.PRNGKey(0))

        ds = SyntheticLMDataset(DataConfig(
            vocab=cfg.vocab, seq_len=args.seq, global_batch=args.batch))
        loop = FaultTolerantLoop(
            step_fn=lambda s, b: step_fn(s, b), batch_fn=ds.batch,
            ckpt_dir=args.ckpt_dir, cfg=FTConfig(checkpoint_every=50))

        t0 = time.time()
        state, step = loop.run(state, 0, args.steps)
        dt = time.time() - t0

    losses = [h["loss"] for h in loop.history]
    ent = ds.conditional_entropy()
    print(f"steps={step} time={dt:.1f}s "
          f"({args.steps * args.batch * args.seq / dt:.0f} tok/s)")
    print(f"loss: start={losses[0]:.3f} -> end={losses[-1]:.3f} "
          f"(markov entropy floor = {ent:.3f} nats)")
    assert losses[-1] < losses[0], "no learning happened"
    if losses[-1] < 0.8 * losses[0]:
        print("model is learning the Markov structure ✓")


if __name__ == "__main__":
    main()
