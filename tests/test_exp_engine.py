"""Unit tests for the repro.exp sweep engine: expansion semantics, cache
key stability/invalidation, and runner determinism across job counts."""
import json
import subprocess
import sys

import pytest

from repro import exp
from repro.exp.sweep import encode


SQUARE = "repro.exp.smoke:square"


# ------------------------------------------------------------- expansion

class TestSweepExpansion:
    def test_cartesian_order_last_axis_fastest(self):
        spec = exp.SweepSpec("s", SQUARE, axes={"a": [1, 2], "b": [10, 20]})
        combos = [p.kwargs for p in spec.points()]
        assert combos == [{"a": 1, "b": 10}, {"a": 1, "b": 20},
                          {"a": 2, "b": 10}, {"a": 2, "b": 20}]

    def test_zip_mode(self):
        spec = exp.SweepSpec("s", SQUARE, axes={"a": [1, 2, 3],
                                                "b": [4, 5, 6]},
                             mode="zip")
        combos = [p.kwargs for p in spec.points()]
        assert combos == [{"a": 1, "b": 4}, {"a": 2, "b": 5},
                          {"a": 3, "b": 6}]

    def test_zip_length_mismatch_rejected(self):
        with pytest.raises(ValueError, match="zip axes"):
            exp.SweepSpec("s", SQUARE, axes={"a": [1, 2], "b": [1]},
                          mode="zip")

    def test_filters_drop_points(self):
        spec = exp.SweepSpec(
            "s", SQUARE, axes={"a": [1, 2, 3], "b": [1, 2, 3]},
            filters=[lambda p: p["a"] < p["b"]])
        combos = [(p.kwargs["a"], p.kwargs["b"]) for p in spec.points()]
        assert combos == [(1, 2), (1, 3), (2, 3)]

    def test_fixed_params_on_every_point(self):
        spec = exp.SweepSpec("s", SQUARE, axes={"a": [1]},
                             fixed={"b": "x"})
        assert spec.points()[0].kwargs == {"a": 1, "b": "x"}

    def test_swept_and_fixed_overlap_rejected(self):
        with pytest.raises(ValueError, match="both swept and fixed"):
            exp.SweepSpec("s", SQUARE, axes={"a": [1]}, fixed={"a": 2})

    def test_unencodable_axis_value_rejected_eagerly(self):
        spec = exp.SweepSpec("s", SQUARE, axes={"a": [object()]})
        with pytest.raises(TypeError, match="canonically encode"):
            spec.points()

    def test_encode_distinguishes_types(self):
        assert encode(True) != encode(1)
        assert encode((1, 2)) != encode([1, 2])
        assert encode(1.0) != encode(1)

    def test_encode_distinguishes_mapping_key_types(self):
        assert encode({1: "v"}) != encode({"1": "v"})
        assert encode({True: "v"}) != encode({1: "v"})
        # mixed key types still sort deterministically
        assert encode({1: "a", "x": "b"}) == encode({"x": "b", 1: "a"})

    def test_encode_normalizes_numpy_scalars(self):
        import numpy as np
        assert encode(np.float64(1.5)) == encode(1.5)
        assert encode(np.int64(3)) == encode(3)
        assert encode(np.bool_(True)) == encode(True)

    def test_encode_frozen_dataclass(self):
        from repro.core.simulator import TileConfig
        a = encode(TileConfig())
        b = encode(TileConfig(adder_w=16))
        assert a != b
        assert a == encode(TileConfig())


# ----------------------------------------------------------------- cache

def _point(**params):
    spec = exp.SweepSpec("s", SQUARE,
                         axes={k: [v] for k, v in params.items()})
    return spec.points()[0]


class TestCache:
    def test_key_stable_across_processes(self):
        p = _point(x=3)
        here = exp.point_key(p, salt="fixed")
        prog = (
            "from repro import exp\n"
            "from repro.exp.sweep import ExperimentPoint\n"
            "p = ExperimentPoint(%r, (('x', 3),))\n"
            "print(exp.point_key(p, salt='fixed'))\n" % SQUARE)
        out = subprocess.run([sys.executable, "-c", prog],
                             capture_output=True, text=True, check=True)
        assert out.stdout.strip() == here

    def test_key_independent_of_param_order(self):
        a = exp.ExperimentPoint(SQUARE, (("x", 1), ("y", 2)))
        b = exp.ExperimentPoint(SQUARE, (("y", 2), ("x", 1)))
        assert exp.point_key(a, "s") == exp.point_key(b, "s")

    def test_key_changes_with_salt_fn_and_params(self):
        p = _point(x=3)
        base = exp.point_key(p, salt="a")
        assert exp.point_key(p, salt="b") != base
        assert exp.point_key(_point(x=4), salt="a") != base
        q = exp.ExperimentPoint("other.mod:fn", p.params)
        assert exp.point_key(q, salt="a") != base

    def test_roundtrip_and_salt_invalidation(self, tmp_path):
        cache = exp.ResultCache(str(tmp_path), salt="v1")
        p = _point(x=5)
        assert cache.get(p) == (False, None)
        cache.put(p, {"v": 25})
        assert cache.get(p) == (True, {"v": 25})
        # bumping the code-version salt orphans the old entry
        stale = exp.ResultCache(str(tmp_path), salt="v2")
        assert stale.get(p) == (False, None)

    def test_corrupt_entry_is_a_miss(self, tmp_path):
        cache = exp.ResultCache(str(tmp_path), salt="v1")
        p = _point(x=5)
        cache.put(p, 25)
        path = cache._path(exp.point_key(p, "v1"))
        with open(path, "w") as f:
            f.write("{not json")
        assert cache.get(p) == (False, None)

    def test_default_salt_is_deterministic(self):
        assert exp.code_salt() == exp.code_salt()
        assert len(exp.code_salt()) == 16

    def test_eval_module_edit_invalidates_key(self, tmp_path, monkeypatch):
        from repro.exp import cache as cache_mod
        mod = tmp_path / "exp_tmp_eval_mod.py"
        mod.write_text("def f(x):\n    return x\n")
        monkeypatch.syspath_prepend(str(tmp_path))
        p = exp.ExperimentPoint("exp_tmp_eval_mod:f", (("x", 1),))
        cache_mod._module_salt.cache_clear()
        k1 = exp.point_key(p, salt="s")
        mod.write_text("def f(x):\n    return x + 1\n")
        cache_mod._module_salt.cache_clear()
        assert exp.point_key(p, salt="s") != k1


# ---------------------------------------------------------------- runner

def _spec(n=6):
    return exp.SweepSpec("sq", SQUARE, axes={"x": list(range(n))})


class TestRunner:
    def test_inline_run_and_counters(self, tmp_path):
        eng = exp.EngineConfig(jobs=1, cache=exp.ResultCache(str(tmp_path)))
        res, rep = exp.run_sweep(_spec(), eng)
        assert [v for _, v in res] == [0, 1, 4, 9, 16, 25]
        assert (rep.n_points, rep.n_cached, rep.n_executed) == (6, 0, 6)

    def test_warm_cache_executes_zero(self, tmp_path):
        cache = exp.ResultCache(str(tmp_path))
        exp.run_sweep(_spec(), exp.EngineConfig(cache=cache))
        res, rep = exp.run_sweep(_spec(), exp.EngineConfig(cache=cache))
        assert rep.n_executed == 0
        assert rep.n_cached == 6
        assert [v for _, v in res] == [0, 1, 4, 9, 16, 25]

    def test_partial_cache_executes_only_misses(self, tmp_path):
        cache = exp.ResultCache(str(tmp_path))
        exp.run_sweep(_spec(3), exp.EngineConfig(cache=cache))
        _, rep = exp.run_sweep(_spec(6), exp.EngineConfig(cache=cache))
        assert (rep.n_cached, rep.n_executed) == (3, 3)

    def test_no_cache_mode(self, tmp_path):
        eng = exp.EngineConfig(cache=None)
        _, rep = exp.run_sweep(_spec(), eng)
        assert rep.n_executed == 6

    @pytest.mark.parametrize("jobs", [2, 4])
    def test_parallel_matches_serial_byte_identical(self, jobs):
        spec = exp.SweepSpec(
            "smoke", "repro.exp.smoke:eval_point",
            axes={"w": [12, 16], "cluster": [1, 4]},
            fixed={"seed": 0, "source": "forward"})
        serial, _ = exp.run_sweep(spec, exp.EngineConfig(jobs=1, cache=None))
        par, rep = exp.run_sweep(spec, exp.EngineConfig(jobs=jobs,
                                                        cache=None))
        assert rep.n_executed == len(spec.points())
        s = json.dumps(exp.rows_from(serial, "smoke"), sort_keys=True)
        p = json.dumps(exp.rows_from(par, "smoke"), sort_keys=True)
        assert s == p

    def test_parallel_fills_cache_for_serial_rerun(self, tmp_path):
        cache = exp.ResultCache(str(tmp_path))
        spec = _spec()
        _, rep1 = exp.run_sweep(spec, exp.EngineConfig(jobs=3, cache=cache))
        assert rep1.n_executed == 6
        _, rep2 = exp.run_sweep(spec, exp.EngineConfig(jobs=1, cache=cache))
        assert rep2.n_executed == 0

    def test_total_report_accumulates(self, tmp_path):
        eng = exp.EngineConfig(cache=exp.ResultCache(str(tmp_path)))
        exp.run_sweep(_spec(3), eng)
        exp.run_sweep(_spec(6), eng)
        assert eng.total.n_points == 9
        assert eng.total.n_executed == 6
        assert eng.total.n_cached == 3

    def test_parallel_failure_caches_completed_points(self, tmp_path):
        cache = exp.ResultCache(str(tmp_path))
        spec = exp.SweepSpec("mixed", "repro.exp.smoke:square_or_raise",
                             axes={"x": [1, 2, -1, 3]})
        with pytest.raises(ValueError, match="negative"):
            exp.run_sweep(spec, exp.EngineConfig(jobs=2, cache=cache))
        # the three good points were cached despite the failure
        good = exp.SweepSpec("mixed", "repro.exp.smoke:square_or_raise",
                             axes={"x": [1, 2, 3]})
        _, rep = exp.run_sweep(good, exp.EngineConfig(cache=cache))
        assert rep.n_cached == 3 and rep.n_executed == 0

    def test_bad_fn_reference_rejected(self):
        from repro.exp.runner import resolve_fn
        with pytest.raises(ValueError, match="bad fn reference"):
            resolve_fn("no.colon.here")
