"""Direct attention-layer tests: chunked (flash-style) == dense,
masking semantics, RoPE relative-position property, ring caches."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.layers import attention
from repro.layers.attention import AttnConfig
from repro.layers.common import apply_rope


def _mk(b=2, s=256, hq=4, hkv=2, d=16, seed=0, dtype=jnp.float32):
    ks = jax.random.split(jax.random.PRNGKey(seed), 3)
    q = jax.random.normal(ks[0], (b, s, hq, d), dtype)
    k = jax.random.normal(ks[1], (b, s, hkv, d), dtype)
    v = jax.random.normal(ks[2], (b, s, hkv, d), dtype)
    pos = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32), (b, s))
    valid = jnp.ones((b, s), bool)
    return q, k, v, pos, valid


CFG = dict(d_model=64, n_heads=4, n_kv_heads=2, head_dim=16)


class TestChunkedEqualsDense:
    @pytest.mark.parametrize("window", [None, 64])
    @pytest.mark.parametrize("softcap", [None, 30.0])
    def test_causal(self, window, softcap):
        cfg = AttnConfig(**CFG, causal=True, window=window,
                         attn_softcap=softcap,
                         q_chunk=64, kv_chunk=64, chunk_threshold=1)
        cfg_dense = dataclasses.replace(cfg, chunk_threshold=1 << 30)
        q, k, v, pos, valid = _mk()
        out_c = attention._attend(cfg, q, k, v, pos, pos, valid)
        out_d = attention._attend(cfg_dense, q, k, v, pos, pos, valid)
        np.testing.assert_allclose(np.asarray(out_c, np.float32),
                                   np.asarray(out_d, np.float32),
                                   rtol=2e-5, atol=2e-5)

    def test_bidirectional(self):
        cfg = AttnConfig(**CFG, causal=False, q_chunk=32, kv_chunk=32,
                         chunk_threshold=1)
        cfg_dense = dataclasses.replace(cfg, chunk_threshold=1 << 30)
        q, k, v, pos, valid = _mk(s=96)
        out_c = attention._attend(cfg, q, k, v, pos, pos, valid)
        out_d = attention._attend(cfg_dense, q, k, v, pos, pos, valid)
        np.testing.assert_allclose(np.asarray(out_c, np.float32),
                                   np.asarray(out_d, np.float32),
                                   rtol=2e-5, atol=2e-5)

    def test_ragged_chunk_boundaries(self):
        """Non-multiple sequence lengths exercise the padding paths."""
        cfg = AttnConfig(**CFG, causal=True, q_chunk=64, kv_chunk=64,
                         chunk_threshold=1)
        cfg_dense = dataclasses.replace(cfg, chunk_threshold=1 << 30)
        q, k, v, pos, valid = _mk(s=130)
        out_c = attention._attend(cfg, q, k, v, pos, pos, valid)
        out_d = attention._attend(cfg_dense, q, k, v, pos, pos, valid)
        np.testing.assert_allclose(np.asarray(out_c, np.float32),
                                   np.asarray(out_d, np.float32),
                                   rtol=2e-5, atol=2e-5)


class TestMasking:
    def test_causal_no_future_leak(self):
        """Perturbing future keys must not change past outputs."""
        cfg = AttnConfig(**CFG, causal=True, chunk_threshold=1 << 30)
        q, k, v, pos, valid = _mk(s=32)
        out1 = attention._attend(cfg, q, k, v, pos, pos, valid)
        k2 = k.at[:, 20:].add(3.0)
        v2 = v.at[:, 20:].add(-5.0)
        out2 = attention._attend(cfg, q, k2, v2, pos, pos, valid)
        np.testing.assert_allclose(np.asarray(out1[:, :20]),
                                   np.asarray(out2[:, :20]), rtol=1e-6)
        assert not np.allclose(np.asarray(out1[:, 20:]),
                               np.asarray(out2[:, 20:]))

    def test_window_excludes_old_keys(self):
        cfg = AttnConfig(**CFG, causal=True, window=8,
                         chunk_threshold=1 << 30)
        q, k, v, pos, valid = _mk(s=32)
        out1 = attention._attend(cfg, q, k, v, pos, pos, valid)
        # keys older than the window for the last query: positions < 24
        k2 = k.at[:, :16].add(7.0)
        out2 = attention._attend(cfg, q, k2, v, pos, pos, valid)
        np.testing.assert_allclose(np.asarray(out1[:, -1]),
                                   np.asarray(out2[:, -1]), rtol=1e-6)


class TestRoPE:
    def test_relative_property(self):
        """q_m . k_n depends only on (m - n): shifting both positions by a
        constant leaves all dot products unchanged."""
        key = jax.random.PRNGKey(0)
        x = jax.random.normal(key, (1, 8, 2, 32), jnp.float32)
        pos = jnp.arange(8, dtype=jnp.int32)[None]
        q1 = apply_rope(x, pos)
        k1 = apply_rope(x, pos)
        q2 = apply_rope(x, pos + 100)
        k2 = apply_rope(x, pos + 100)
        d1 = jnp.einsum("bqhd,bkhd->bhqk", q1, k1)
        d2 = jnp.einsum("bqhd,bkhd->bhqk", q2, k2)
        np.testing.assert_allclose(np.asarray(d1), np.asarray(d2),
                                   rtol=1e-4, atol=1e-4)

    def test_partial_rotary_passthrough(self):
        x = jax.random.normal(jax.random.PRNGKey(1), (1, 4, 1, 32))
        pos = jnp.arange(4, dtype=jnp.int32)[None]
        out = apply_rope(x, pos, rotary_pct=0.5)
        np.testing.assert_array_equal(np.asarray(out[..., 16:]),
                                      np.asarray(x[..., 16:]))


class TestRingCache:
    def test_prefill_matches_scatter_semantics(self):
        """DUS rotation writes == slot = pos % cap reference."""
        cfg = AttnConfig(**CFG, causal=True, window=16)
        b, s, cap = 2, 40, 16
        params = attention.init(jax.random.PRNGKey(0), cfg)
        x = jax.random.normal(jax.random.PRNGKey(1), (b, s, 64),
                              jnp.float32)
        pos = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32), (b, s))
        cache = attention.init_cache(b, cap, cfg, jnp.float32)
        from repro.core.policy import get_policy
        _, new_cache = attention.prefill(params, cfg, x, pos, cache,
                                         get_policy("bf16"), "t")
        # every surviving position p in [s-cap, s) sits at slot p % cap
        got_pos = np.asarray(new_cache.pos)
        for bi in range(b):
            for p in range(s - cap, s):
                assert got_pos[bi, p % cap] == p
