"""Fast coverage: quantization properties, precision policies, cell
configs, and the HLO collective parser."""
import numpy as np
import jax.numpy as jnp
import pytest
from _hypothesis_compat import given, settings, st

from repro.core.policy import POLICIES, get_policy
from repro.launch.cell_configs import RECOMMENDED, recommended
from repro.launch.roofline import (_ring_factor, _shape_bytes,
                                   parse_collectives)
from repro.quant.quantize import (calibrate_absmax, dequantize, fake_quant,
                                  quantize_symmetric)


class TestQuant:
    @given(st.integers(0, 1000), st.sampled_from([4, 8]))
    @settings(max_examples=30, deadline=None)
    def test_roundtrip_error_bounded(self, seed, bits):
        rng = np.random.default_rng(seed)
        x = jnp.asarray(rng.normal(0, 1, 256), jnp.float32)
        q, s = quantize_symmetric(x, bits)
        y = dequantize(q, s)
        # error <= scale/2 (round-to-nearest) except clipped extremes
        err = np.abs(np.asarray(y) - np.asarray(x))
        assert (err <= float(s) * 0.5 + 1e-7).all()

    def test_int_range(self):
        x = jnp.linspace(-3, 3, 100)
        for bits in (4, 8):
            q, _ = quantize_symmetric(x, bits)
            qmax = (1 << (bits - 1)) - 1
            assert int(jnp.min(q)) >= -qmax - 1
            assert int(jnp.max(q)) <= qmax

    def test_fake_quant_straight_through(self):
        import jax
        x = jnp.asarray([0.1, -0.7, 0.5])
        g = jax.grad(lambda v: fake_quant(v, 4).sum())(x)
        np.testing.assert_allclose(np.asarray(g), 1.0)  # identity STE

    def test_per_channel_axis(self):
        rng = np.random.default_rng(0)
        x = jnp.asarray(rng.normal(0, 1, (16, 8))
                        * np.asarray([1, 100] * 4 + [1] * 8)[None, :8],
                        jnp.float32)
        q, s = quantize_symmetric(x, 8, axis=0)
        assert s.shape == (1, 8)  # one scale per output channel


class TestPolicies:
    def test_all_policies_resolve(self):
        for name, pol in POLICIES.items():
            spec = pol.spec_for("block/full/attn/wq")
            assert spec.mode in ("bf16", "fp32", "int8", "int4", "fp16_ipu")

    def test_hybrid_keeps_sensitive_layers_fp(self):
        pol = get_policy("paper_hybrid")
        assert pol.spec_for("lm_head").mode == "fp16_ipu"
        assert pol.spec_for("block/attn/wo").mode == "fp16_ipu"
        assert pol.spec_for("block/mlp/w_gate").mode == "int4"

    def test_first_match_wins(self):
        pol = get_policy("int4_serving")
        assert pol.spec_for("router/w").mode == "bf16"
        assert pol.spec_for("block/moe/experts").mode == "int4"


class TestCellConfigs:
    def test_every_recommended_cell_is_valid(self):
        from repro.configs import ARCH_IDS
        from repro.configs.base import SHAPES
        for (arch, shape), cc in RECOMMENDED.items():
            assert arch in ARCH_IDS, arch
            assert shape in SHAPES, shape
            assert cc.microbatches >= 1
            if cc.moe_dispatch:
                assert cc.moe_dispatch in ("einsum", "gather")

    def test_defaults_for_unlisted(self):
        cc = recommended("rwkv6-1.6b", "decode_32k")
        assert cc.microbatches == 1 and cc.moe_dispatch is None


class TestRooflineParser:
    def test_shape_bytes(self):
        assert _shape_bytes("f32[128,256]") == 128 * 256 * 4
        assert _shape_bytes("bf16[8]") == 16
        assert _shape_bytes("(f32[4], s8[2,2])") == 16 + 4

    def test_ring_factors(self):
        assert _ring_factor("all-reduce", 2) == pytest.approx(1.0)
        assert _ring_factor("all-gather", 4) == pytest.approx(0.75)
        assert _ring_factor("collective-permute", 8) == 1.0
        assert _ring_factor("all-reduce", 1) == 0.0

    def test_parse_synthetic_hlo(self):
        hlo = """
  %ar = f32[1024]{0} all-reduce(f32[1024]{0} %x), replica_groups={{0,1}}
  %ag = (f32[64,32]{1,0}) all-gather(f32[16,32]{1,0} %y), replica_groups=[2,4]<=[8]
"""
        stats = parse_collectives(hlo, default_group=8)
        assert stats.count == 2
        # all-reduce: 4096 B * 2*(1/2) = 4096
        assert stats.by_op["all-reduce"] == pytest.approx(4096)
        # all-gather: out 64*32*4 / group 4 * 3/4 = 1536
        assert stats.by_op["all-gather"] == pytest.approx(
            64 * 32 * 4 / 4 * 0.75)
