"""Static activation-scale calibration coverage.

The contracts: a calibration pass produces a scale for every projection
the policy routes; calibrated containers make int executors skip the
per-token absmax reduce (``count_act_quant`` == 0) without changing the
quantization semantics (a fixed rounding grid is elementwise, so
quantizing a prompt matrix is bit-identical to quantizing its rows one
token at a time — which is also why calibrated batched-prefill and
teacher-forced admission numerics agree exactly as they do under bf16);
and plans carry their calibration through JSON into a serving engine.
"""
import dataclasses

import numpy as np
import pytest

from repro.configs import reduced

ARCH = "qwen2-0.5b"


@pytest.fixture(scope="module")
def int8_setup():
    import jax

    from repro.models import registry
    cfg = dataclasses.replace(reduced(ARCH),
                              precision_policy="int8_serving")
    api = registry.build(cfg)
    params = api.init(jax.random.PRNGKey(0))
    return cfg, api, params


@pytest.fixture(scope="module")
def scales(int8_setup):
    from repro.quant.calibrate import calibrate_act_scales
    cfg, api, params = int8_setup
    return calibrate_act_scales(cfg, api, params)


# ------------------------------------------------------- the core claim

def test_static_scale_quant_is_elementwise_bit_identical():
    """fake_quant against a FIXED scale gives the same values whether it
    sees the whole prompt matrix or its rows one token at a time — the
    property that erases the prefill/decode scale-granularity caveat
    (dynamic absmax spans the prompt in prefill, one token in decode)."""
    import jax.numpy as jnp

    from repro.quant.quantize import calibrate_absmax, fake_quant
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.normal(size=(6, 16)), jnp.float32)
    scale = float(calibrate_absmax(x)) / 127
    whole = np.asarray(fake_quant(x, 8, scale=scale))
    rows = np.stack([np.asarray(fake_quant(x[i], 8, scale=scale))
                     for i in range(x.shape[0])])
    np.testing.assert_array_equal(whole, rows)
    # ...whereas dynamic per-call scales genuinely differ between views
    whole_dyn = np.asarray(fake_quant(x, 8))
    rows_dyn = np.stack([np.asarray(fake_quant(x[i], 8))
                         for i in range(x.shape[0])])
    assert not np.array_equal(whole_dyn, rows_dyn)


def test_static_scale_matches_dynamic_on_same_absmax():
    """With the static scale set to what absmax would have found, the
    calibrated path reproduces the dynamic value bit-exactly — the
    executors changed where the scale comes from, not the arithmetic."""
    import jax.numpy as jnp

    from repro.quant.quantize import calibrate_absmax, fake_quant
    rng = np.random.default_rng(1)
    x = jnp.asarray(rng.normal(size=(4, 8)), jnp.float32)
    s = calibrate_absmax(x) / 127
    np.testing.assert_array_equal(np.asarray(fake_quant(x, 8)),
                                  np.asarray(fake_quant(x, 8, scale=s)))


# ------------------------------------------------------ calibration pass

def test_calibrate_covers_every_routed_projection(int8_setup, scales):
    """Every path the decode step routes through the policy must have a
    calibrated scale (prefill exercises the same projections)."""
    from repro.serving import EngineConfig, ServingEngine
    cfg, api, params = int8_setup
    eng = ServingEngine(cfg, api, params,
                        config=EngineConfig(batch_slots=2, cache_len=16))
    routed = set(eng.routing_report())
    assert routed <= set(scales), routed - set(scales)
    assert all(s > 0 for s in scales.values())


def test_calibrate_on_prompts(int8_setup):
    from repro.quant.calibrate import calibrate_act_scales
    cfg, api, params = int8_setup
    rng = np.random.default_rng(2)
    prompts = [rng.integers(0, cfg.vocab, n, dtype=np.int32)
               for n in (5, 9)]
    s = calibrate_act_scales(cfg, api, params, prompts=prompts)
    assert s and all(v > 0 for v in s.values())


def test_prepare_attaches_and_threads_scales(int8_setup, scales):
    from repro.core.policy import get_policy
    from repro.quant.prepare import (PreparedWeight,
                                     iter_projection_weights)
    from repro.models.registry import projection_paths
    cfg, api, params = int8_setup
    prepared = api.prepare(params, get_policy(cfg.precision_policy),
                           act_scales=scales)
    paths = projection_paths(cfg)
    n_scaled = 0
    for p, w in iter_projection_weights(prepared, paths):
        if isinstance(w, PreparedWeight) and w.weight_bits:
            assert w.act_scale is not None, p
            # the scale leaf carries the stacked-block leading axes
            assert w.act_scale.shape == w.data.shape[:-2], p
            n_scaled += 1
    assert n_scaled > 0


# --------------------------------------------------- engine integration

def test_calibrated_engine_zero_act_quants(int8_setup, scales):
    from repro.serving import EngineConfig, ServingEngine
    cfg, api, params = int8_setup
    cal = ServingEngine(cfg, api, params,
                        config=EngineConfig(batch_slots=2, cache_len=16,
                                            act_calibration=scales))
    dyn = ServingEngine(cfg, api, params,
                        config=EngineConfig(batch_slots=2, cache_len=16))
    assert cal.act_quant_trace_count() == 0
    assert cal.weight_quant_trace_count() == 0
    assert dyn.act_quant_trace_count() > 0
    assert cal.metrics()["act_calibrated"] is True
    assert dyn.metrics()["act_calibrated"] is False


def test_calibration_requires_prepared_weights(int8_setup, scales):
    """Scales only take effect through prepared containers: asking for
    calibration with preparation off must fail, not silently measure
    the dynamic path."""
    from repro.serving import EngineConfig, ServingEngine
    cfg, api, params = int8_setup
    with pytest.raises(ValueError, match="prepared weights"):
        ServingEngine(cfg, api, params,
                      config=EngineConfig(batch_slots=2, cache_len=16,
                                          prepare_weights=False,
                                          act_calibration=scales))


def test_calibrated_prefill_matches_teacher_forced(int8_setup, scales):
    """With static scales the batched-prefill and teacher-forced
    admission paths agree under int8 fake-quant exactly like they do
    under bf16 (the dynamic-scale granularity caveat is gone): same
    per-slot cache prefixes, same first generated token, same
    first-step logits."""
    import jax
    import jax.numpy as jnp

    from repro.serving import EngineConfig, Request, ServingEngine
    cfg, api, params = int8_setup
    lengths = [5, 1, 9]
    rng = np.random.default_rng(0)
    engines = {}
    for mode in ("batched", "teacher"):
        eng = ServingEngine(cfg, api, params, config=EngineConfig(
            batch_slots=3, cache_len=64, prefill=mode, prefill_chunk=4,
            act_calibration=scales))
        r = np.random.default_rng(0)
        for i, n in enumerate(lengths):
            eng.submit(Request(
                rid=i, prompt=r.integers(0, cfg.vocab, n, dtype=np.int32),
                max_new_tokens=2))
        eng._admit()
        while eng._prefill_tick():   # drain the chunked waves
            pass
        engines[mode] = eng
    fast, slow = engines["batched"], engines["teacher"]
    assert np.array_equal(fast.pos, slow.pos)
    for lf, ls in zip(jax.tree.leaves(fast.caches),
                      jax.tree.leaves(slow.caches)):
        for slot, n in enumerate(lengths):
            if n <= 1:
                continue
            a = np.asarray(lf[:, slot, :n - 1], np.float32)
            b = np.asarray(ls[:, slot, :n - 1], np.float32)
            np.testing.assert_allclose(a, b, rtol=0.05, atol=0.05)
    tok = np.zeros((fast.b, 1), np.int32)
    for s in range(fast.b):
        assert fast.slot_req[s].next_input == slow.slot_req[s].next_input
        tok[s, 0] = fast.slot_req[s].next_input

    def first_logits(eng):
        logits, _ = eng._decode(eng.params, jnp.array(tok),
                                jnp.array(eng.pos), eng.caches)
        return np.asarray(logits, np.float32)

    np.testing.assert_allclose(first_logits(fast), first_logits(slow),
                               rtol=0.1, atol=0.1)


# ------------------------------------------------------- plan artifacts

def test_plan_carries_act_scales(int8_setup, scales, tmp_path):
    """A plan ships its calibration: saved scales round-trip through
    JSON and an engine resolving the plan with act_calibration='auto'
    consumes them instead of re-calibrating."""
    from repro.autotune.plan import (PlanRule, PrecisionPlan,
                                     load_act_scales)
    from repro.models import registry
    from repro.models.registry import projection_groups

    cfg, api, params = int8_setup
    groups = {g.name: g for g in projection_groups(cfg)}
    plan = PrecisionPlan(
        name="cal", arch=ARCH,
        rules=(PlanRule("attn_qkv", groups["attn_qkv"].pattern, "int8"),
               PlanRule("ffn_in", groups["ffn_in"].pattern, "int8")),
        default_mode="bf16", act_scales=dict(scales))
    path = str(tmp_path / "cal_plan.json")
    plan.save(path)
    assert load_act_scales(path) == pytest.approx(scales)

    from repro.serving import EngineConfig, ServingEngine
    pcfg = dataclasses.replace(cfg, precision_policy=f"plan:{path}")
    papi = registry.build(pcfg)
    eng = ServingEngine(pcfg, papi, params,
                        config=EngineConfig(batch_slots=2, cache_len=16,
                                            act_calibration="auto"))
    assert eng.act_scales == pytest.approx(scales)
    assert eng.act_quant_trace_count() == 0


def test_plan_without_scales_falls_back_to_calibration(int8_setup,
                                                       tmp_path):
    from repro.autotune.plan import PlanRule, PrecisionPlan
    from repro.models import registry
    from repro.models.registry import projection_groups

    cfg, _, params = int8_setup
    groups = {g.name: g for g in projection_groups(cfg)}
    plan = PrecisionPlan(
        name="nocal", arch=ARCH,
        rules=(PlanRule("attn_qkv", groups["attn_qkv"].pattern, "int8"),),
        default_mode="bf16")
    path = str(tmp_path / "nocal_plan.json")
    plan.save(path)
    from repro.serving import EngineConfig, ServingEngine
    pcfg = dataclasses.replace(cfg, precision_policy=f"plan:{path}")
    papi = registry.build(pcfg)
    eng = ServingEngine(pcfg, papi, params,
                        config=EngineConfig(batch_slots=2, cache_len=16,
                                            act_calibration="auto"))
    assert eng.act_scales          # ran its own calibration pass
    assert eng.act_quant_trace_count() == 0
