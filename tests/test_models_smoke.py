"""Per-architecture smoke tests: reduced configs, one forward/train step
on CPU, asserting output shapes and no NaNs; plus prefill+decode
consistency for every family."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCH_IDS, get_config, reduced
from repro.configs.base import SHAPES, InputShape, shape_applicable
from repro.models import registry


SMOKE_SHAPE = InputShape("smoke", seq_len=32, global_batch=2, kind="train")


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_full_config_counts(arch):
    cfg = get_config(arch)
    n = cfg.params_count()
    expected = {
        "qwen2-0.5b": (0.3e9, 0.8e9),
        "gemma2-9b": (8e9, 11e9),
        "stablelm-12b": (10e9, 14e9),
        "glm4-9b": (8e9, 11e9),
        "rwkv6-1.6b": (1.2e9, 2.2e9),
        "seamless-m4t-medium": (0.4e9, 1.5e9),
        "mixtral-8x7b": (40e9, 52e9),
        "qwen3-moe-30b-a3b": (26e9, 34e9),
        "recurrentgemma-9b": (7e9, 11e9),
        "internvl2-1b": (0.3e9, 1.0e9),
    }[arch]
    assert expected[0] < n < expected[1], (arch, n)


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_train_step_smoke(arch):
    cfg = reduced(arch)
    api = registry.build(cfg)
    params = api.init(jax.random.PRNGKey(0))
    batch = registry.materialize_batch(cfg, SMOKE_SHAPE)

    (loss, metrics), grads = jax.value_and_grad(api.loss_fn, has_aux=True)(
        params, batch)
    assert np.isfinite(float(loss)), (arch, float(loss))
    # gradients finite and not all-zero
    leaves = jax.tree_util.tree_leaves(grads)
    assert all(np.isfinite(np.asarray(g, np.float32)).all() for g in leaves)
    total = sum(float(jnp.sum(jnp.abs(g.astype(jnp.float32))))
                for g in leaves)
    assert total > 0, arch


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_prefill_decode_consistency(arch):
    """decode_step after prefill must agree with a longer prefill's last
    logits (same tokens) — validates cache semantics per family. Runs at
    f32 compute (bf16 rounding through recurrence gates is not what this
    test checks; griffin matches exactly at f32)."""
    cfg = dataclasses.replace(reduced(arch), compute_dtype="float32")
    api = registry.build(cfg)
    params = api.init(jax.random.PRNGKey(1))
    b, s = 2, 16
    key = jax.random.PRNGKey(2)
    tokens = jax.random.randint(key, (b, s + 1), 0, cfg.vocab)

    extra = {}
    if cfg.family == "encdec":
        extra["frames"] = jax.random.normal(key, (b, 8, cfg.frontend_dim),
                                            jnp.float32)
    if cfg.family == "vlm":
        extra["patches"] = jax.random.normal(key, (b, cfg.n_patches,
                                                   cfg.vit_dim), jnp.float32)

    caches = api.init_cache(b, s + 1)
    logits_a, caches = api.prefill(params,
                                   {"tokens": tokens[:, :s], **extra},
                                   caches)
    pos = jnp.full((b,), s, jnp.int32)
    if cfg.family == "vlm":
        pos = pos + cfg.n_patches
    logits_b, _ = api.decode_step(
        params, {"token": tokens[:, s:s + 1], "pos": pos}, caches)

    caches2 = api.init_cache(b, s + 1)
    logits_c, _ = api.prefill(params,
                              {"tokens": tokens[:, :s + 1], **extra},
                              caches2)
    assert logits_b.shape == (b, cfg.vocab)
    np.testing.assert_allclose(np.asarray(logits_b, np.float32),
                               np.asarray(logits_c, np.float32),
                               rtol=2e-2, atol=2e-2)


@pytest.mark.parametrize("arch", ["qwen2-0.5b", "mixtral-8x7b",
                                  "rwkv6-1.6b", "recurrentgemma-9b"])
def test_precision_policies_run(arch):
    """The paper's technique as a policy: int4/int8/paper-hybrid variants
    produce finite, distinct outputs."""
    outs = {}
    for pol in ("bf16", "int4_serving", "int8_serving"):
        cfg = dataclasses.replace(reduced(arch), precision_policy=pol)
        api = registry.build(cfg)
        params = api.init(jax.random.PRNGKey(0))
        batch = registry.materialize_batch(cfg, SMOKE_SHAPE)
        loss, _ = api.loss_fn(params, batch)
        assert np.isfinite(float(loss)), (arch, pol)
        outs[pol] = float(loss)
    assert outs["bf16"] != outs["int4_serving"]  # quantization changed math


def test_fidelity_policy_exact_kernels():
    """fidelity_fp16_ipu routes matmuls through the bit-exact emulation."""
    cfg = dataclasses.replace(reduced("qwen2-0.5b"),
                              precision_policy="fidelity_fp16_ipu")
    api = registry.build(cfg)
    params = api.init(jax.random.PRNGKey(0))
    batch = registry.materialize_batch(cfg, InputShape("s", 8, 1, "train"))
    from repro.models import lm
    logits, _ = lm.train_logits(params, cfg, batch["tokens"][:, :-1])
    assert np.isfinite(np.asarray(logits, np.float32)).all()


def test_long_500k_applicability():
    expected_runs = {"rwkv6-1.6b", "recurrentgemma-9b", "mixtral-8x7b"}
    runs = {a for a in ARCH_IDS
            if shape_applicable(get_config(a), SHAPES["long_500k"])}
    assert runs == expected_runs


def test_swa_cache_is_window_bounded():
    cfg = get_config("mixtral-8x7b")
    api = registry.build(cfg)
    caches = jax.eval_shape(lambda: api.init_cache(1, 524288))
    k = caches["b0"].k
    assert k.shape[2] == cfg.window  # (groups, B, capacity, Hkv, D)


def test_moe_dispatch_modes_equivalent():
    """gather-based dispatch == one-hot einsum dispatch (bit-level not
    required; f32 compute, tight tolerance)."""
    import jax.numpy as jnp
    from repro.layers import moe as moe_layer
    cfg = moe_layer.MoEConfig(d_model=32, d_expert=16, n_experts=4,
                              top_k=2, capacity_factor=1.5)
    params = moe_layer.init(jax.random.PRNGKey(0), cfg)
    x = jax.random.normal(jax.random.PRNGKey(1), (3, 16, 32), jnp.float32)
    from repro.core.policy import get_policy
    pol = get_policy("bf16")
    y1, a1 = moe_layer.forward(params, cfg, x, pol, "m")
    cfg2 = dataclasses.replace(cfg, dispatch="gather")
    y2, a2 = moe_layer.forward(params, cfg2, x, pol, "m")
    np.testing.assert_allclose(np.asarray(y1, np.float32),
                               np.asarray(y2, np.float32),
                               rtol=2e-2, atol=2e-2)
    assert float(a1) == pytest.approx(float(a2))
