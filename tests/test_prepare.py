"""Prepared-weight datapath coverage (quant/prepare.py + the mplinear
precision-dispatch registry).

The contract under refactor: preparing a weight ahead of time must not
change what the datapath computes —

  * exact int8/int4 kernel path: bit-exact (same integer operands, same
    scale epilogue — prepared int4 additionally rides packed nibbles);
  * fake-quant and fp16_ipu paths: allclose (in fact bit-equal, since
    dequant-on-demand reproduces the same q * scale product);
  * at model scale, prepared params thread through scan/jit/eval_shape
    like raw ones and decode bit-exactly matches dynamic quantization;
  * preparation is idempotent and leaves bf16/fp32 groups untouched;
  * packed int4 storage round-trips and costs <= 1/6 of fp32.
"""
import dataclasses

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.configs import reduced
from repro.core.ipu import IPUConfig
from repro.core.policy import (PrecisionPolicy, PrecisionSpec, get_policy)
from repro.kernels import ops as kops
from repro.layers import mplinear
from repro.layers.mplinear import mp_linear
from repro.models import registry
from repro.quant.prepare import (PreparedWeight, prepare_params,
                                 prepare_weight, weight_resident_bytes)

ARCH = "qwen2-0.5b"


def _wx(k=32, n=24, m=6, seed=0):
    rng = np.random.default_rng(seed)
    w = jnp.asarray(rng.normal(0, 1, (k, n)), jnp.float32)
    x = jnp.asarray(rng.normal(0, 1, (2, m, k)), jnp.float32)
    return w, x


# ------------------------------------------------------- single weights

class TestPreparedLinear:
    @pytest.mark.parametrize("mode", ["int8", "int4"])
    def test_exact_kernel_path_bit_exact(self, mode):
        """The acceptance bar: prepared integer storage feeds the exact
        Pallas kernel path bit-identically to dynamic quantization."""
        w, x = _wx()
        spec = PrecisionSpec(mode, exact=True)
        pw = prepare_weight(w, spec)
        assert isinstance(pw, PreparedWeight)
        if mode == "int4":
            assert pw.kind == "int4_packed"
        y_dyn = mp_linear({"w": w}, x, spec)
        y_prep = mp_linear({"w": pw}, x, spec)
        np.testing.assert_array_equal(np.asarray(y_dyn),
                                      np.asarray(y_prep))

    @pytest.mark.parametrize("mode", ["int8", "int4"])
    def test_fake_quant_path_allclose(self, mode):
        w, x = _wx(seed=1)
        spec = PrecisionSpec(mode)
        y_dyn = mp_linear({"w": w}, x, spec)
        y_prep = mp_linear({"w": prepare_weight(w, spec)}, x, spec)
        np.testing.assert_allclose(np.asarray(y_dyn, np.float32),
                                   np.asarray(y_prep, np.float32),
                                   rtol=1e-6, atol=1e-6)

    @pytest.mark.parametrize("exact", [False, True])
    def test_fp16_ipu_path_allclose(self, exact):
        w, x = _wx(seed=2)
        spec = PrecisionSpec("fp16_ipu", exact=exact,
                             ipu=IPUConfig(n=16, w=28))
        y_dyn = mp_linear({"w": w}, x, spec)
        y_prep = mp_linear({"w": prepare_weight(w, spec)}, x, spec)
        np.testing.assert_allclose(np.asarray(y_dyn, np.float32),
                                   np.asarray(y_prep, np.float32),
                                   rtol=1e-6, atol=1e-6)

    def test_odd_contraction_dim_falls_back_unpacked(self):
        w = jnp.ones((5, 4), jnp.float32)
        pw = prepare_weight(w, PrecisionSpec("int4"))
        assert pw.kind == "int4"          # int8-storage nibbles, no pack
        np.testing.assert_array_equal(np.asarray(pw.unpacked()),
                                      np.asarray(pw.data))

    def test_unknown_mode_has_no_executor(self):
        with pytest.raises(ValueError, match="no executor"):
            mplinear.executor_for("int12")


# ------------------------------------------------------ pack round trip

class TestPackRoundTrip:
    def test_model_scale_pack_unpack(self):
        """Every packed container in a prepared reduced model unpacks
        back to exactly the dynamically quantized integer weights."""
        from repro.quant.quantize import quantize_symmetric
        cfg = dataclasses.replace(reduced(ARCH),
                                  precision_policy="int4_serving")
        api = registry.build(cfg)
        params = api.init(jax.random.PRNGKey(0))
        prepared = api.prepare(params, get_policy(cfg.precision_policy))

        def pairs(raw, prep):
            if isinstance(prep, PreparedWeight):
                yield raw, prep
            elif isinstance(prep, dict):
                for k in prep:
                    yield from pairs(raw[k], prep[k])
            elif isinstance(prep, (list, tuple)):
                for r, p in zip(raw, prep):
                    yield from pairs(r, p)

        n_packed = 0
        for raw_w, pw in pairs(params, prepared):
            if pw.kind != "int4_packed":
                continue
            n_packed += 1
            q, s = quantize_symmetric(raw_w.astype(jnp.float32), 4,
                                      axis=-2)
            np.testing.assert_array_equal(np.asarray(pw.unpacked()),
                                          np.asarray(q))
            np.testing.assert_array_equal(np.asarray(pw.scale),
                                          np.asarray(s))
        assert n_packed > 0, "no packed containers in an int4 plan"

    def test_leading_dims_roundtrip(self):
        rng = np.random.default_rng(3)
        q = jnp.asarray(rng.integers(-8, 8, (3, 4, 10, 6)), jnp.int8)
        np.testing.assert_array_equal(
            np.asarray(kops.unpack_int4(kops.pack_int4(q))), np.asarray(q))


# --------------------------------------------------------- model scale

class TestModelScale:
    @pytest.mark.parametrize("arch,policy", [
        ("qwen2-0.5b", "int8_serving"),
        ("qwen2-0.5b", "int4_serving"),
        ("qwen2-0.5b", "paper_hybrid"),
        ("rwkv6-1.6b", "int8_serving"),
        ("recurrentgemma-9b", "int4_serving"),
        ("mixtral-8x7b", "int8_serving"),
    ])
    def test_prepared_decode_matches_dynamic(self, arch, policy):
        cfg = dataclasses.replace(reduced(arch), precision_policy=policy)
        api = registry.build(cfg)
        params = api.init(jax.random.PRNGKey(0))
        prepared = api.prepare(params, get_policy(policy))
        caches = api.init_cache(2, 16)
        batch = {"token": jnp.full((2, 1), 7, jnp.int32),
                 "pos": jnp.full((2,), 3, jnp.int32)}
        l_dyn, _ = api.decode_step(params, batch, caches)
        l_prep, _ = api.decode_step(prepared, batch, caches)
        np.testing.assert_allclose(np.asarray(l_dyn, np.float32),
                                   np.asarray(l_prep, np.float32),
                                   rtol=1e-5, atol=1e-5)

    def test_idempotent_and_mixed_policy(self):
        """Preparing twice is a no-op; bf16-routed groups keep their raw
        arrays (same objects, untouched by a mixed policy)."""
        policy = PrecisionPolicy(
            "mixed_t",
            rules=((r"attn/", PrecisionSpec("int8")),),
            default=PrecisionSpec("bf16"))
        cfg = dataclasses.replace(reduced(ARCH), precision_policy="bf16")
        api = registry.build(cfg)
        params = api.init(jax.random.PRNGKey(0))
        paths = registry.projection_paths(cfg)
        once = prepare_params(params, policy, paths)
        twice = prepare_params(once, policy, paths)
        flat1 = jax.tree.leaves(
            once, is_leaf=lambda x: isinstance(x, PreparedWeight))
        flat2 = jax.tree.leaves(
            twice, is_leaf=lambda x: isinstance(x, PreparedWeight))
        assert all(a is b for a, b in zip(flat1, flat2))
        # attn projections prepared, mlp left raw
        assert isinstance(once["blocks"]["b0"]["attn"]["wq"]["w"],
                          PreparedWeight)
        assert once["blocks"]["b0"]["mlp"]["w_gate"]["w"] is \
            params["blocks"]["b0"]["mlp"]["w_gate"]["w"]

    def test_int4_weight_bytes_ratio(self):
        """Paper memory win at model scale: packed int4 projection
        storage <= 1/6 of the fp32 bytes (1/8 + scales)."""
        cfg = dataclasses.replace(reduced(ARCH),
                                  precision_policy="int4_serving")
        api = registry.build(cfg)
        params = api.init(jax.random.PRNGKey(0))
        paths = registry.projection_paths(cfg)
        raw = weight_resident_bytes(params, paths)
        prep = weight_resident_bytes(
            api.prepare(params, get_policy("int4_serving")), paths)
        assert raw["projections"] > 0
        assert prep["projections"] * 6 <= raw["projections"], (prep, raw)
        assert prep["total"] < raw["total"]

    def test_byte_contracts_per_kind(self):
        """The storage-tier byte contracts, pinned per kind on one
        weight: packed int4 and packed fp4 are ~1/8 of fp32 (one byte
        per two elements + per-channel scales), int8 and fp8 ~1/4."""
        k, n = 256, 64
        w = jnp.asarray(np.random.default_rng(0).normal(0, 1, (k, n)),
                        jnp.float32)
        fp32_bytes = w.nbytes
        scale_bytes = 4 * n
        expect = {"int4": fp32_bytes / 8, "fp4": fp32_bytes / 8,
                  "int8": fp32_bytes / 4, "fp8": fp32_bytes / 4}
        for mode, payload in expect.items():
            pw = prepare_weight(w, PrecisionSpec(mode))
            assert pw.nbytes() == payload + scale_bytes, (mode, pw.kind)

    def test_by_kind_breakdown(self):
        """weight_resident_bytes(by_kind=True) reports each storage
        kind under its own key and the parts sum to the total."""
        policy = PrecisionPolicy(
            "kinds_t",
            rules=((r"attn/", PrecisionSpec("fp4")),
                   (r"mlp/w_gate", PrecisionSpec("int8")),),
            default=PrecisionSpec("bf16"))
        cfg = dataclasses.replace(reduced(ARCH), precision_policy="bf16")
        api = registry.build(cfg)
        params = api.init(jax.random.PRNGKey(0))
        paths = registry.projection_paths(cfg)
        prep = prepare_params(params, policy, paths)
        rep = weight_resident_bytes(prep, paths, by_kind=True)
        kinds = rep["by_kind"]
        assert "fp4_packed" in kinds and "int8" in kinds
        assert "raw" in kinds            # head/default groups stay raw
        assert sum(kinds.values()) == rep["projections"]
        assert "by_kind" not in weight_resident_bytes(
            prep, paths, by_kind=False)


# ------------------------------------------------- fp codec cross-check

def _load_fp_convert():
    import importlib.util
    import os
    import sys
    spec = importlib.util.spec_from_file_location(
        "fp_convert", os.path.join(os.path.dirname(__file__), "..",
                                   "tools", "fp_convert.py"))
    fc = importlib.util.module_from_spec(spec)
    sys.modules["fp_convert"] = fc       # dataclasses resolve __module__
    spec.loader.exec_module(fc)
    return fc


class TestFPConvertReference:
    """tools/fp_convert.py is an independent numpy codec; the jax codec
    in quant.quantize must agree with it bit-for-bit."""

    @pytest.mark.parametrize("name", ["fp8", "fp4"])
    def test_encode_decode_agree(self, name):
        fc = _load_fp_convert()
        from repro.quant.quantize import FP_FORMATS, fp_decode, fp_encode
        jf, nf = FP_FORMATS[name], fc.FORMATS[name]
        assert (jf.exp_bits, jf.man_bits, jf.bias, jf.max) == \
            (nf.exp_bits, nf.man_bits, nf.bias, nf.max)
        rng = np.random.default_rng(0)
        x = np.concatenate([
            rng.normal(0, nf.max / 3, 2048).astype(np.float32),
            fc.decode_table(nf), -fc.decode_table(nf),
            np.asarray([0.0, -0.0, nf.max, -nf.max, 1e9, -1e9],
                       np.float32)])
        codes_np = fc.encode(x, nf)
        codes_jax = np.asarray(fp_encode(jnp.asarray(x), jf))
        np.testing.assert_array_equal(codes_np, codes_jax)
        np.testing.assert_array_equal(
            fc.decode(codes_np, nf),
            np.asarray(fp_decode(jnp.asarray(codes_np), jf)))

    def test_roundtrip_report_exact_on_grid(self):
        fc = _load_fp_convert()
        for fmt in fc.FORMATS.values():
            rep = fc.roundtrip_report(fmt, samples=512)
            assert rep["grid_roundtrip_exact"], fmt.name
            assert rep["max_rel_err"] <= 1.0


# ------------------------------------------------------------- serving

class TestServingPrepared:
    def test_engine_prepares_and_counts_zero_weight_quants(self):
        from repro.serving import EngineConfig, ServingEngine
        cfg = dataclasses.replace(reduced(ARCH),
                                  precision_policy="int8_serving")
        api = registry.build(cfg)
        params = api.init(jax.random.PRNGKey(0))
        eng = ServingEngine(cfg, api, params,
                            config=EngineConfig(batch_slots=2,
                                                cache_len=32))
        assert eng.prepared
        assert eng.weight_quant_trace_count() == 0
        dyn = ServingEngine(cfg, api, params,
                            config=EngineConfig(batch_slots=2,
                                                cache_len=32,
                                                prepare_weights=False))
        assert not dyn.prepared
        assert dyn.weight_quant_trace_count() > 0
        # prepared engine serves end to end and reports weight memory
        req_tokens = np.asarray([3, 1, 4, 1, 5], np.int32)
        from repro.serving import Request
        eng.submit(Request(rid=0, prompt=req_tokens, max_new_tokens=3))
        eng.run_until_drained()
        assert eng.completed[0].new_tokens == 3
        m = eng.metrics()
        assert m["prepared_weights"] is True
        assert m["weight_bytes"]["projections"] < \
            dyn.metrics()["weight_bytes"]["projections"]

    def test_replica_costs_carry_weight_bytes(self):
        from repro.serving import EngineConfig, Router, build_replicas
        cfg = reduced(ARCH)
        reps = build_replicas(cfg, ("int4_serving", "bf16"),
                              config=EngineConfig(batch_slots=2,
                                                  cache_len=32))
        by_name = {r.policy_name: r for r in reps}
        b_int4 = by_name["int4_serving"].cost["weight_bytes"]
        b_bf16 = by_name["bf16"].cost["weight_bytes"]
        assert b_int4["projections"] * 6 <= b_bf16["projections"]
        report = Router(reps).report()
        for rep in report["replicas"].values():
            assert "weight_bytes" in rep["cost"]
