"""Serving runtime coverage: engine drain edge cases, batched-prefill
equivalence, scheduler policies, plan-aware routing, metrics schema, and
the ``repro.launch.serve`` compat shim.

The engine contract under refactor: batched prefill admission must
produce the same per-slot cache state (and next-step logits) as the
teacher-forced loop, and ``routing_report()`` must keep satisfying the
plan→policy→routing round trip (also covered via the shim in
tests/test_autotune.py).
"""
import dataclasses

import numpy as np
import pytest

from repro.configs import reduced
from repro.serving import (AdmissionScheduler, EngineConfig, Request,
                           Router, SamplingParams, SchedulerFull,
                           ServingEngine, build_replicas, percentiles,
                           request_metrics, slo_report)

ARCH = "qwen2-0.5b"


@pytest.fixture(scope="module")
def lm_setup():
    import jax

    from repro.models import registry
    cfg = dataclasses.replace(reduced(ARCH),
                              precision_policy="int8_serving")
    api = registry.build(cfg)
    params = api.init(jax.random.PRNGKey(0))
    return cfg, api, params


def _engine(lm_setup, **kw):
    cfg, api, params = lm_setup
    kw.setdefault("batch_slots", 3)
    kw.setdefault("cache_len", 64)
    return ServingEngine(cfg, api, params, config=EngineConfig(**kw))


def _requests(cfg, lengths, max_new):
    rng = np.random.default_rng(0)
    return [Request(rid=i, prompt=rng.integers(0, cfg.vocab, n,
                                               dtype=np.int32),
                    max_new_tokens=m)
            for i, (n, m) in enumerate(zip(lengths, max_new))]


# --------------------------------------------------------------- engine

class TestEngineDrain:
    def test_more_requests_than_slots(self, lm_setup):
        cfg = lm_setup[0]
        eng = _engine(lm_setup, batch_slots=2)
        reqs = _requests(cfg, [5, 7, 3, 9, 4, 6, 8], [3] * 7)
        for r in reqs:
            eng.submit(r)
        eng.run_until_drained()
        assert len(eng.completed) == 7
        for r in reqs:
            assert r.done and r.new_tokens == 3

    def test_mixed_max_new_and_zero_generation(self, lm_setup):
        cfg = lm_setup[0]
        eng = _engine(lm_setup)
        reqs = _requests(cfg, [5, 6, 4, 7, 3], [4, 0, 1, 2, 0])
        for r in reqs:
            eng.submit(r)
        eng.run_until_drained()
        assert len(eng.completed) == 5
        for r in reqs:
            assert r.new_tokens == max(r.max_new_tokens, 0)
        # zero-generation requests complete without ever decoding
        assert reqs[1].first_token_time is None
        assert reqs[1].finish_time is not None
        assert reqs[1].tokens == [int(t) for t in reqs[1].prompt]

    def test_single_token_and_empty_prompt(self, lm_setup):
        cfg = lm_setup[0]
        eng = _engine(lm_setup)
        one = Request(rid=0, prompt=np.asarray([7], np.int32),
                      max_new_tokens=2)
        empty = Request(rid=1, prompt=np.zeros(0, np.int32),
                        max_new_tokens=2)
        eng.submit(one)
        eng.submit(empty)
        eng.run_until_drained()
        assert one.new_tokens == 2
        assert empty.done and empty.new_tokens == 0
        # a 1-token prompt needs no prefill call at all
        assert eng.counters["prefill_calls"] == 0

    def test_oversized_requests_truncate_instead_of_rejecting(
            self, lm_setup):
        """Chunked prefill lifted the old ``prompt + generation <=
        cache_len`` admission bound: requests that would wrap the KV
        ring are now admitted with ``truncated=True`` (trailing-window
        ring semantics) and still serve their full budget, instead of
        raising at submit."""
        eng = _engine(lm_setup, cache_len=8)
        long_prompt = Request(rid=0, prompt=np.arange(12, dtype=np.int32),
                              max_new_tokens=1)
        # decode growth counts too: 5-1+5 > 8
        growth = Request(rid=1, prompt=np.arange(5, dtype=np.int32),
                         max_new_tokens=5)
        # exact fit (5-1+4 == 8) stays untruncated
        ok = Request(rid=2, prompt=np.arange(5, dtype=np.int32),
                     max_new_tokens=4)
        for r in (long_prompt, growth, ok):
            eng.submit(r)
        eng.run_until_drained()
        assert set(eng.completed) == {0, 1, 2}
        for r in (long_prompt, growth, ok):
            assert r.done and r.error is None
            assert r.new_tokens == r.max_new_tokens
            assert r.finish_reason == "length"
        assert long_prompt.truncated and growth.truncated
        assert not ok.truncated


class TestBatchedPrefill:
    def test_no_decode_per_prompt_token(self, lm_setup):
        """A prompt of length S streams through ceil((S-1)/chunk)
        prefill waves and decode runs exactly max_new steps — never S
        teacher-forced decodes."""
        cfg = lm_setup[0]
        eng = _engine(lm_setup, prefill="batched", prefill_chunk=8)
        eng.submit(_requests(cfg, [23], [4])[0])
        eng.run_until_drained()
        # 22 prompt tokens at chunk 8 -> waves of 8/8/6
        assert eng.counters["prefill_calls"] == 3
        assert eng.counters["prefill_tokens"] == 22
        assert eng.counters["decode_steps"] == 4
        assert eng.counters["teacher_forced_tokens"] == 0

    def test_matches_teacher_forced_admission(self):
        """The bucket-padded prefill + per-slot cache merge produces the
        same per-slot cache state and next-step logits as feeding the
        prompt token-by-token through decode. Compared numerically under
        the bf16 policy: greedy trajectories would amplify an argmax tie
        into divergent completions, and dynamic fake-quant policies
        legitimately differ between the paths (the per-tensor activation
        absmax spans the whole prompt in prefill but one token in
        decode)."""
        import jax
        import jax.numpy as jnp

        from repro.models import registry
        cfg = dataclasses.replace(reduced(ARCH),
                                  precision_policy="bf16")
        api = registry.build(cfg)
        params = api.init(jax.random.PRNGKey(0))
        lengths = [5, 1, 9]          # mixed: one slot needs no prefill
        engines = {}
        for mode in ("batched", "teacher"):
            eng = ServingEngine(cfg, api, params,
                                config=EngineConfig(batch_slots=3,
                                                    cache_len=64,
                                                    prefill=mode,
                                                    prefill_chunk=4))
            for r in _requests(cfg, lengths, [2] * len(lengths)):
                eng.submit(r)
            eng._admit()
            while eng._prefill_tick():   # drain the chunked waves
                pass
            engines[mode] = eng
        fast, slow = engines["batched"], engines["teacher"]
        assert np.array_equal(fast.pos, slow.pos)
        # 4 + 8 prompt tokens at chunk 4: two packed waves
        assert fast.counters["prefill_calls"] == 2
        assert slow.counters["teacher_forced_tokens"] == sum(
            n - 1 for n in lengths)

        # every cache leaf is (n_groups, slots, capacity, ...): the
        # admitted prefix of each slot must carry the same K/V and tags
        for lf, ls in zip(jax.tree.leaves(fast.caches),
                          jax.tree.leaves(slow.caches)):
            for slot, n in enumerate(lengths):
                if n <= 1:
                    continue
                a = np.asarray(lf[:, slot, :n - 1], np.float32)
                b = np.asarray(ls[:, slot, :n - 1], np.float32)
                np.testing.assert_allclose(a, b, rtol=0.05, atol=0.05)

        # and the first decode step sees the same distribution
        tok = np.zeros((fast.b, 1), np.int32)
        for s in range(fast.b):
            tok[s, 0] = fast.slot_req[s].next_input
            assert fast.slot_req[s].next_input \
                == slow.slot_req[s].next_input
        def first_logits(eng):
            logits, _ = eng._decode(eng.params, jnp.asarray(tok),
                                    jnp.asarray(eng.pos), eng.caches)
            return np.asarray(logits, np.float32)
        np.testing.assert_allclose(first_logits(fast),
                                   first_logits(slow),
                                   rtol=0.1, atol=0.1)

    def test_batched_rejected_for_recurrent_families(self):
        """Recurrent state is not position-tagged: padded prefill would
        corrupt it, so forcing the fast path must fail fast."""
        import jax

        from repro.models import registry
        cfg = reduced("rwkv6-1.6b")
        api = registry.build(cfg)
        params = api.init(jax.random.PRNGKey(0))
        with pytest.raises(ValueError, match="not eligible"):
            ServingEngine(cfg, api, params,
                          config=EngineConfig(batch_slots=2, cache_len=16,
                                              prefill="batched"))
        # auto mode falls back to teacher forcing and still serves
        eng = ServingEngine(cfg, api, params,
                            config=EngineConfig(batch_slots=2,
                                                cache_len=16))
        assert not eng._fast_prefill
        eng.submit(Request(rid=0, prompt=np.asarray([3, 1, 4], np.int32),
                           max_new_tokens=2))
        eng.run_until_drained()
        assert eng.completed[0].new_tokens == 2
        assert eng.counters["teacher_forced_tokens"] == 2


class TestBlockedDecode:
    """The decode fast path (jitted scan + on-device argmax, one host
    sync per block) must be a pure dispatch optimization: per-request
    token streams are identical to per-token decode at every block
    size, because batch rows are independent and masked (budget-
    exhausted) slots feed exactly what the per-token engine feeds freed
    slots (a pad write at the slot's current frontier position, which
    the next real write overwrites before any query attends it)."""

    LENGTHS = [5, 7, 3, 9, 4, 6]
    BUDGETS = [6, 3, 8, 2, 5, 4]      # mixed: slots mask mid-block

    def _tokens(self, lm_setup, cfg=None, **kw):
        if cfg is None:
            cfg = dataclasses.replace(lm_setup[0],
                                      precision_policy="bf16")
        from repro.models import registry
        api = registry.build(cfg)
        eng = ServingEngine(cfg, api, lm_setup[2],
                            config=EngineConfig(batch_slots=3,
                                                cache_len=64, **kw))
        reqs = _requests(cfg, self.LENGTHS, self.BUDGETS)
        for r in reqs:
            eng.submit(r)
        eng.run_until_drained()
        return {r.rid: list(r.tokens) for r in reqs}, eng

    def test_blocked_equals_per_token_all_block_sizes(self, lm_setup):
        base, _ = self._tokens(lm_setup)
        for blk in (1, 2, 3, 8):
            toks, eng = self._tokens(lm_setup, decode_block=blk)
            assert toks == base, f"decode_block={blk} diverged"
            for rid, budget in enumerate(self.BUDGETS):
                assert len(toks[rid]) == self.LENGTHS[rid] + budget

    def test_block_one_is_per_token_engine(self, lm_setup):
        """decode_block=1 must reproduce today's behavior exactly —
        same tokens AND same counters (one host sync per decode)."""
        base, eng0 = self._tokens(lm_setup)
        toks, eng1 = self._tokens(lm_setup, decode_block=1)
        assert toks == base
        assert eng1.counters == eng0.counters
        assert eng1.counters["host_syncs"] == eng1.counters["decode_steps"]

    def test_blocked_counter_contract(self, lm_setup):
        """A tick dispatches at most one block; a block syncs once."""
        blk = 4
        _, per_tok = self._tokens(lm_setup)
        _, fast = self._tokens(lm_setup, decode_block=blk)
        c, c1 = fast.counters, per_tok.counters
        assert c["decode_steps"] <= c["ticks"] * blk, c
        assert c["host_syncs"] * blk >= c["decode_steps"], c
        assert c["host_syncs"] < c1["host_syncs"], (c, c1)
        assert fast.metrics()["decode_block"] == blk

    def test_blocked_quantized_policy_matches(self, lm_setup):
        """int8 with calibrated static activation scales: the blocked
        trajectory still matches per-token exactly."""
        cfg = lm_setup[0]          # int8_serving
        from repro.quant.calibrate import calibrate_act_scales
        scales = calibrate_act_scales(cfg, lm_setup[1], lm_setup[2])
        base, _ = self._tokens(lm_setup, cfg=cfg,
                               act_calibration=scales)
        toks, eng = self._tokens(lm_setup, cfg=cfg,
                                 act_calibration=scales, decode_block=8)
        assert toks == base
        assert eng.act_quant_trace_count() == 0
        assert eng.weight_quant_trace_count() == 0

    def test_blocked_allows_moe_experts_uncovered(self):
        """MoE expert stacks quantize weights only (activations ride
        the bf16 einsums), so they cannot couple batch rows — and no
        mp_linear call exists for calibration to cover them. The
        dynamic-fake-quant guard must exempt them or MoE models could
        never use the fast path under int policies."""
        import jax

        from repro.models import registry
        from repro.quant.calibrate import calibrate_act_scales
        cfg = dataclasses.replace(reduced("mixtral-8x7b"),
                                  precision_policy="int8_serving")
        api = registry.build(cfg)
        params = api.init(jax.random.PRNGKey(0))
        scales = calibrate_act_scales(cfg, api, params)
        assert "block/moe/experts" not in scales
        eng = ServingEngine(cfg, api, params,
                            config=EngineConfig(batch_slots=2,
                                                cache_len=32,
                                                decode_block=4,
                                                act_calibration=scales))
        assert eng.act_quant_trace_count() == 0
        assert eng.weight_quant_trace_count() == 0

    def test_blocked_rejects_dynamic_fake_quant(self, lm_setup):
        """Dynamic fake-quant activations share ONE per-tensor absmax
        across batch rows, so a blocked engine's pad cadence would leak
        into other slots' tokens (measured: uncalibrated int8 diverges
        at block 4 under queue pressure) — rejected at construction."""
        cfg, api, params = lm_setup          # int8_serving, uncalibrated
        with pytest.raises(ValueError, match="per-slot-independent"):
            ServingEngine(cfg, api, params,
                          config=EngineConfig(batch_slots=2, cache_len=32,
                                              decode_block=4))
        # calibrated scales decouple the rows: construction succeeds
        from repro.quant.calibrate import calibrate_act_scales
        ServingEngine(cfg, api, params, config=EngineConfig(
            batch_slots=2, cache_len=32, decode_block=4,
            act_calibration=calibrate_act_scales(cfg, api, params)))

    def test_blocked_equals_per_token_vlm(self):
        """The other eligible family: vlm's position-tagged caches make
        masked pad writes causally invisible too."""
        import jax

        from repro.models import registry
        cfg = dataclasses.replace(reduced("internvl2-1b"),
                                  precision_policy="bf16")
        api = registry.build(cfg)
        params = api.init(jax.random.PRNGKey(0))

        def run(blk):
            eng = ServingEngine(cfg, api, params,
                                config=EngineConfig(batch_slots=2,
                                                    cache_len=32,
                                                    decode_block=blk))
            reqs = _requests(cfg, [5, 7, 3, 4], [4, 2, 5, 3])
            for r in reqs:
                eng.submit(r)
            eng.run_until_drained()
            return {r.rid: list(r.tokens) for r in reqs}

        assert run(1) == run(4)

    def test_blocked_rejected_for_recurrent_families(self):
        """Recurrent state folds every masked pad step in, so the
        block-vs-tick pad cadence diverges the token streams (measured
        on rwkv/griffin with mixed budgets) — blocked decode must fail
        fast for them rather than silently drift."""
        import jax

        from repro.models import registry
        cfg = reduced("rwkv6-1.6b")
        api = registry.build(cfg)
        params = api.init(jax.random.PRNGKey(0))
        with pytest.raises(ValueError, match="not eligible"):
            ServingEngine(cfg, api, params,
                          config=EngineConfig(batch_slots=2, cache_len=16,
                                              decode_block=4))
        with pytest.raises(ValueError, match="not eligible"):
            registry.make_block_decode(api, 4)


# ------------------------------------------------- serving API surfaces

class TestServingAPI:
    """EngineConfig / SamplingParams redesign: validation at
    construction, the legacy-kwarg deprecation shim, and per-request
    sampling plumbed through ``submit()``."""

    def test_engine_config_validation(self):
        with pytest.raises(ValueError, match="batch_slots"):
            EngineConfig(batch_slots=0)
        with pytest.raises(ValueError, match="cache_len"):
            EngineConfig(cache_len=0)
        with pytest.raises(ValueError, match="prefill mode"):
            EngineConfig(prefill="bogus")
        with pytest.raises(ValueError, match="prefill_chunk"):
            EngineConfig(prefill_chunk=0)
        with pytest.raises(ValueError, match="decode_block"):
            EngineConfig(decode_block=0)
        with pytest.raises(ValueError, match="eos_id"):
            EngineConfig(eos_id=-2)
        with pytest.raises(ValueError, match="cost_correction"):
            EngineConfig(cost_correction="sometimes")
        with pytest.raises(ValueError, match="stats_window"):
            EngineConfig(stats_window=0)
        with pytest.raises(ValueError, match="stats_alpha"):
            EngineConfig(stats_alpha=0.0)

    def test_sampling_params_validation(self):
        with pytest.raises(ValueError, match="temperature"):
            SamplingParams(temperature=-0.1)
        with pytest.raises(ValueError, match="top_k"):
            SamplingParams(top_k=-1)
        with pytest.raises(ValueError, match="top_p"):
            SamplingParams(top_p=0.0)
        with pytest.raises(ValueError, match="stop_ids"):
            SamplingParams(stop_ids=(-3,))
        with pytest.raises(ValueError, match="stop_ids"):
            SamplingParams(stop_ids=tuple(range(9)))
        with pytest.raises(ValueError, match="max_new_tokens"):
            SamplingParams(max_new_tokens=-1)
        assert SamplingParams().greedy
        assert not SamplingParams(temperature=0.7).greedy
        assert SamplingParams(stop_ids=[3, 1]).stop_ids == (3, 1)

    def test_from_legacy_kwargs(self):
        legacy = EngineConfig.from_legacy_kwargs(
            {"batch_slots": 2, "decode_block": 4, "greedy": True})
        assert legacy == EngineConfig(batch_slots=2, decode_block=4)
        with pytest.raises(TypeError, match="unknown"):
            EngineConfig.from_legacy_kwargs({"slots": 2})

    def test_legacy_kwargs_deprecation_shim(self, lm_setup):
        cfg, api, params = lm_setup
        with pytest.warns(DeprecationWarning, match="EngineConfig"):
            eng = ServingEngine(cfg, api, params, batch_slots=2,
                                cache_len=32, greedy=True)
        assert eng.config == EngineConfig(batch_slots=2, cache_len=32)
        # a legacy-constructed engine still serves
        eng.submit(Request(rid=0, prompt=np.asarray([3, 1, 4], np.int32),
                           max_new_tokens=2))
        eng.run_until_drained()
        assert eng.completed[0].new_tokens == 2
        with pytest.raises(TypeError, match="not both"):
            ServingEngine(cfg, api, params, config=EngineConfig(),
                          batch_slots=2)

    def test_submit_validates_sampling(self, lm_setup):
        eng = _engine(lm_setup)
        bad = Request(rid=0, prompt=np.zeros(3, np.int32))
        bad.sampling = {"temperature": 1.0}
        with pytest.raises(TypeError, match="SamplingParams"):
            eng.submit(bad)
        # engine-wide eos_id counts against the per-slot stop slots
        eng2 = _engine(lm_setup, eos_id=5)
        full = Request(rid=1, prompt=np.zeros(3, np.int32),
                       sampling=SamplingParams(stop_ids=(1, 2, 3, 4)))
        with pytest.raises(ValueError, match="stop slots"):
            eng2.submit(full)

    def test_sampling_budget_overrides_request(self, lm_setup):
        cfg = lm_setup[0]
        eng = _engine(lm_setup)
        req = _requests(cfg, [5], [8])[0]
        req.sampling = SamplingParams(max_new_tokens=3)
        eng.submit(req)
        eng.run_until_drained()
        assert req.new_tokens == 3 and req.finish_reason == "length"


class TestSampledDecode:
    """On-device sampling: per-request seeded PRNG keys ride the decode
    carry, so sampled streams are reproducible and invariant to
    decode_block — and greedy rows in a mixed batch stay bit-identical
    to the all-greedy program (argmax on raw logits)."""

    def _run(self, lm_setup, sampling_by_rid, blk=1, engine_seed=0):
        cfg = dataclasses.replace(lm_setup[0], precision_policy="bf16")
        from repro.models import registry
        api = registry.build(cfg)
        eng = ServingEngine(cfg, api, lm_setup[2],
                            config=EngineConfig(batch_slots=2,
                                                cache_len=64,
                                                decode_block=blk,
                                                seed=engine_seed))
        reqs = _requests(cfg, [5, 7, 3], [8, 6, 7])
        for r in reqs:
            r.sampling = sampling_by_rid.get(r.rid, SamplingParams())
            eng.submit(r)
        eng.run_until_drained()
        return {r.rid: list(r.tokens) for r in reqs}

    def test_seeded_sampling_deterministic_and_block_invariant(
            self, lm_setup):
        sp = {0: SamplingParams(temperature=0.8, seed=7),
              1: SamplingParams(temperature=1.0, top_k=8, seed=7),
              2: SamplingParams(temperature=0.9, top_p=0.8, seed=7)}
        a = self._run(lm_setup, sp, blk=1)
        b = self._run(lm_setup, sp, blk=1)
        assert a == b, "same seeds must reproduce the streams"
        for blk in (2, 4):
            assert self._run(lm_setup, sp, blk=blk) == a, \
                f"decode_block={blk} changed a sampled stream"

    def test_engine_seed_fold_in_reproducible_and_distinct(
            self, lm_setup):
        hot = {i: SamplingParams(temperature=1.0) for i in range(3)}
        a = self._run(lm_setup, hot, engine_seed=0)
        b = self._run(lm_setup, hot, engine_seed=0)
        c = self._run(lm_setup, hot, engine_seed=123)
        assert a == b, "engine-seed fold_in must be reproducible"
        assert a != c, "different engine seeds should move the streams"

    def test_greedy_rows_unchanged_by_sampled_neighbors(self, lm_setup):
        base = self._run(lm_setup, {})          # all greedy
        mixed = self._run(lm_setup, {1: SamplingParams(temperature=1.0,
                                                       seed=3)})
        assert mixed[0] == base[0] and mixed[2] == base[2]
        assert mixed[1] != base[1]


class TestContinuousServing:
    """The continuous-batching loop (chunked prefill continuation +
    mid-block admission + EOS stopping) must not change greedy token
    streams — it only changes WHEN work is dispatched. Compared against
    the flags-off engine (the PR-5 between-block baseline) on the same
    staggered arrival trace."""

    LENGTHS = [6, 18, 4, 9, 5, 23]
    BUDGETS = [2, 12, 3, 12, 4, 12]   # heterogeneous: blocks cut short
    SUBMIT_TICKS = [0, 0, 1, 2, 4, 6]

    def _drive(self, eng, reqs, ticks):
        """Tick-driven open loop: submit each request at its trace tick
        while the engine keeps stepping."""
        order = sorted(range(len(reqs)), key=lambda i: ticks[i])
        i, tick = 0, 0
        while i < len(order) or eng.has_pending():
            while i < len(order) and ticks[order[i]] <= tick:
                eng.submit(reqs[order[i]])
                i += 1
            if eng.has_pending():
                eng.step()
            tick += 1
        return {r.rid: list(r.tokens) for r in reqs}

    def _run(self, lm_setup, flags_on, blk=4, stops=None):
        cfg = dataclasses.replace(lm_setup[0], precision_policy="bf16")
        from repro.models import registry
        api = registry.build(cfg)
        eng = ServingEngine(cfg, api, lm_setup[2], config=EngineConfig(
            batch_slots=2, cache_len=64, decode_block=blk,
            prefill_chunk=4, mid_block_admission=flags_on,
            eos_stopping=flags_on))
        reqs = _requests(cfg, self.LENGTHS, self.BUDGETS)
        for r in reqs:
            if stops and r.rid in stops:
                r.sampling = SamplingParams(stop_ids=(stops[r.rid],))
        toks = self._drive(eng, reqs, self.SUBMIT_TICKS)
        return toks, reqs, eng

    def test_continuous_equals_flags_off_engine(self, lm_setup):
        base, _, ref = self._run(lm_setup, flags_on=False)
        toks, _, eng = self._run(lm_setup, flags_on=True)
        assert toks == base, "continuous flags changed a greedy stream"
        for rid in range(len(self.LENGTHS)):
            assert len(base[rid]) == self.LENGTHS[rid] + self.BUDGETS[rid]
        assert ref.counters["short_blocks"] == 0
        assert ref.counters["mid_block_admits"] == 0
        assert eng.counters["short_blocks"] > 0
        assert eng.counters["mid_block_admits"] > 0
        # both stream long prompts through chunked waves, never teacher
        for e in (ref, eng):
            assert e.counters["prefill_calls"] >= 5
            assert e.counters["teacher_forced_tokens"] == 0
        # trimming blocks to admissions never costs decode work
        assert eng.counters["decode_steps"] <= ref.counters["decode_steps"]

    def test_eos_stops_blocked_equals_per_token(self, lm_setup):
        base, _, _ = self._run(lm_setup, flags_on=False)
        # harvest stop tokens from the greedy streams so they fire
        stops = {1: base[1][self.LENGTHS[1] + 3],
                 3: base[3][self.LENGTHS[3] + 2]}
        blocked, breqs, beng = self._run(lm_setup, flags_on=True,
                                         stops=stops)
        tick, treqs, teng = self._run(lm_setup, flags_on=True, blk=1,
                                      stops=stops)
        assert blocked == tick, "EOS stopping diverged blocked vs tick"
        assert beng.counters["eos_stops"] == len(stops)
        assert teng.counters["eos_stops"] == len(stops)
        for rid, stop_tok in stops.items():
            r = breqs[rid]
            assert r.finish_reason == "stop"
            assert r.tokens[-1] == stop_tok
            assert r.new_tokens < self.BUDGETS[rid]
            # cut at the FIRST occurrence, as a prefix of the free run
            gen = r.tokens[self.LENGTHS[rid]:]
            assert stop_tok not in gen[:-1]
            assert base[rid][:len(r.tokens)] == r.tokens
        for r in breqs:
            if r.rid not in stops:
                assert r.finish_reason == "length"


class TestRoutingReport:
    def test_plan_policy_routing_roundtrip(self, lm_setup, tmp_path):
        """Plan → policy → observed decode routing stays consistent
        across the serving refactor."""
        import jax

        from repro.autotune.plan import PlanRule, PrecisionPlan
        from repro.models import registry
        from repro.models.registry import projection_groups

        groups = {g.name: g for g in projection_groups(reduced(ARCH))}
        plan = PrecisionPlan(
            name="t", arch=ARCH,
            rules=(PlanRule("attn_qkv", groups["attn_qkv"].pattern,
                            "int8"),
                   PlanRule("ffn_in", groups["ffn_in"].pattern, "int4")),
            default_mode="bf16")
        path = str(tmp_path / "plan.json")
        plan.save(path)
        cfg = dataclasses.replace(reduced(ARCH),
                                  precision_policy=f"plan:{path}")
        api = registry.build(cfg)
        params = api.init(jax.random.PRNGKey(0))
        eng = ServingEngine(cfg, api, params,
                            config=EngineConfig(batch_slots=2,
                                                cache_len=16))
        routes = eng.routing_report()
        assert routes, "decode step routed no projections"
        policy = plan.to_policy()
        for p, mode in routes.items():
            assert mode == policy.spec_for(p).mode, p
        assert routes["block/full/attn/wq"] == "int8"
        assert routes["block/mlp/w_gate"] == "int4"
        assert routes["block/full/attn/wo"] == "bf16"


class TestFusedExecutors:
    """EngineConfig.fused_executors routing: the on/off/auto contract,
    the staged-materialization trace counter, and the fp storage tier
    serving end-to-end from an autotune plan through a checkpoint."""

    def test_on_requires_prepared(self, lm_setup):
        with pytest.raises(ValueError, match="prepared"):
            _engine(lm_setup, prepare_weights=False,
                    fused_executors="on")

    def test_auto_resolution_and_staged_counter(self, lm_setup):
        # prepared + calibrated resolves onto the fused datapath: zero
        # staged compute-dtype materializations in the traced program
        eng = _engine(lm_setup, act_calibration="auto", decode_block=4)
        assert eng.fused
        assert eng.staged_trace_count() == 0
        assert eng.metrics()["fused_executors"] is True
        # "off" pins the staged fallback — the counter hook is live
        off = _engine(lm_setup, act_calibration=eng.act_scales,
                      decode_block=4, fused_executors="off")
        assert not off.fused
        assert off.staged_trace_count() > 0
        assert off.metrics()["fused_executors"] is False
        # prepared int without act scales cannot fuse (the int kernels
        # need a static activation scale), nor can a dynamic engine
        assert not _engine(lm_setup).fused
        assert not _engine(lm_setup, prepare_weights=False).fused

    @pytest.mark.slow
    def test_fp_plan_serves_end_to_end(self, tmp_path):
        """The acceptance path: an autotune plan selecting fp8 (per
        -group scales) + fp4 prepares fp storage, resolves fused WITHOUT
        activation scales (fp kernels need none), survives a fabric
        checkpoint round trip, and the rebuilt engine serves identical
        greedy streams."""
        import jax

        from repro.autotune.plan import PlanRule, PrecisionPlan
        from repro.fabric.checkpoint import (build_engine,
                                             save_engine_checkpoint)
        from repro.models import registry
        from repro.models.registry import projection_groups
        from repro.quant.prepare import iter_projection_weights

        groups = {g.name: g for g in projection_groups(reduced(ARCH))}
        plan = PrecisionPlan(
            name="fp_tier", arch=ARCH,
            rules=(PlanRule("attn_qkv", groups["attn_qkv"].pattern,
                            "fp8", group_size=8),
                   PlanRule("ffn_in", groups["ffn_in"].pattern, "fp4")),
            default_mode="bf16")
        path = str(tmp_path / "plan.json")
        plan.save(path)
        cfg = dataclasses.replace(reduced(ARCH),
                                  precision_policy=f"plan:{path}")
        api = registry.build(cfg)
        params = api.init(jax.random.PRNGKey(0))
        eng = ServingEngine(cfg, api, params, config=EngineConfig(
            batch_slots=2, cache_len=64, decode_block=4))
        assert eng.prepared and eng.fused
        assert eng.staged_trace_count() == 0
        kinds = {w.kind for _, w in iter_projection_weights(
                     eng.params, registry.projection_paths(cfg))
                 if hasattr(w, "kind")}
        assert {"fp8", "fp4_packed"} <= kinds, kinds
        reqs = _requests(cfg, [5, 7], [4, 4])
        for r in reqs:
            eng.submit(r)
        eng.run_until_drained()
        want = {r.rid: list(r.tokens) for r in reqs}
        assert all(len(t) >= 4 for t in want.values()), want

        ckpt = str(tmp_path / "ckpt")
        save_engine_checkpoint(eng, ckpt, step=1)
        eng2 = build_engine(ckpt)
        assert eng2.prepared and eng2.fused
        reqs2 = _requests(cfg, [5, 7], [4, 4])
        for r in reqs2:
            eng2.submit(r)
        eng2.run_until_drained()
        assert {r.rid: list(r.tokens) for r in reqs2} == want


def test_launch_serve_shim():
    from repro.launch import serve as shim
    from repro.serving import config as cfg_mod
    from repro.serving import engine as eng_mod
    assert shim.ServingEngine is eng_mod.ServingEngine
    assert shim.Request is eng_mod.Request
    assert shim.make_serve_fns is eng_mod.make_serve_fns
    assert shim.EngineConfig is cfg_mod.EngineConfig
    assert shim.SamplingParams is cfg_mod.SamplingParams


# ------------------------------------------------------------ scheduler

def _req(rid, plen=4, priority=0, submit_time=None):
    r = Request(rid=rid, prompt=np.zeros(plen, np.int32),
                priority=priority)
    r.submit_time = submit_time
    return r


class TestScheduler:
    def test_priority_then_fifo(self):
        s = AdmissionScheduler()
        s.submit(_req(0, priority=1), now=0.0)
        s.submit(_req(1, priority=0), now=0.0)
        s.submit(_req(2, priority=0), now=0.0)
        assert [r.rid for r in s.select(3, now=0.1)] == [1, 2, 0]

    def test_max_wait_promotion(self):
        s = AdmissionScheduler(max_wait=5.0)
        s.submit(_req(0, priority=9), now=0.0)     # old, low priority
        s.submit(_req(1, priority=0), now=4.0)     # fresh, high priority
        # before promotion the high-priority request wins...
        assert [r.rid for r in s.select(1, now=4.5)] == [1]
        # ...after max_wait the starved one jumps every class
        assert [r.rid for r in s.select(1, now=6.0)] == [0]

    def test_bounded_queue_raises(self):
        s = AdmissionScheduler(max_queue=2)
        s.submit(_req(0))
        s.submit(_req(1))
        with pytest.raises(SchedulerFull):
            s.submit(_req(2))
        assert len(s) == 2

    def test_prefill_budget_defers_long_prompts(self):
        s = AdmissionScheduler(prefill_budget=8)
        s.submit(_req(0, plen=9), now=0.0)    # cost 8: fills the budget
        s.submit(_req(1, plen=9), now=0.0)    # cost 8: over budget
        s.submit(_req(2, plen=3), now=0.0)    # cost 2: over budget too
        wave = s.select(3, now=0.1)
        assert [r.rid for r in wave] == [0]   # progress guarantee only
        assert [r.rid for r in s.select(3, now=0.2)] == [1]
        assert [r.rid for r in s.select(3, now=0.3)] == [2]

    def test_promoted_bypass_budget(self):
        s = AdmissionScheduler(prefill_budget=4, max_wait=1.0)
        s.submit(_req(0, plen=9), now=0.0)
        s.submit(_req(1, plen=9), now=0.0)
        assert len(s.select(2, now=5.0)) == 2  # both promoted


# --------------------------------------------------------------- router

@pytest.fixture(scope="module")
def two_replicas(lm_setup):
    cfg, _, params = lm_setup
    base = dataclasses.replace(cfg, precision_policy="bf16")
    return build_replicas(base, ("int8_serving", "bf16"), params=params,
                          config=EngineConfig(batch_slots=2,
                                              cache_len=32))


class TestRouter:
    def test_cost_model_orders_replicas(self, two_replicas):
        int8, bf16 = two_replicas
        assert int8.cost["cycles_per_token"] \
            < bf16.cost["cycles_per_token"]
        assert bf16.cost["acc_proxy"] < int8.cost["acc_proxy"]
        assert int8.cost["tops_per_w"] > 0 and bf16.cost["tops_per_w"] > 0

    def test_plan_aware_routes_by_tag(self, two_replicas):
        router = Router(two_replicas, strategy="plan_aware")
        cheap = router.route(Request(rid=0,
                                     prompt=np.zeros(4, np.int32)))
        accurate = router.route(Request(rid=1,
                                        prompt=np.zeros(4, np.int32),
                                        tags=("accuracy",)))
        assert cheap.name == "int8_serving"
        assert accurate.name == "bf16"

    def test_round_robin_alternates(self, two_replicas):
        router = Router(two_replicas, strategy="round_robin")
        names = [router.route(_req(i)).name for i in range(4)]
        assert names == ["int8_serving", "bf16", "int8_serving", "bf16"]

    def test_mixed_workload_drains_and_counts(self, two_replicas):
        router = Router(two_replicas, strategy="plan_aware")
        rng = np.random.default_rng(1)
        reqs = [Request(rid=i,
                        prompt=rng.integers(0, 512, 5, dtype=np.int32),
                        max_new_tokens=2,
                        tags=("accuracy",) if i % 2 else ())
                for i in range(6)]
        for r in reqs:
            router.submit(r)
        router.run_until_drained()
        assert len(router.completed) == 6
        counters = router.routing_counters()
        assert sum(counters.values()) == 6
        assert all(n > 0 for n in counters.values()), counters
        rep = router.report()
        assert rep["strategy"] == "plan_aware"
        for name, r in rep["replicas"].items():
            assert r["metrics"]["counters"]["teacher_forced_tokens"] == 0

    def test_invalid_strategy_and_empty(self, two_replicas):
        with pytest.raises(ValueError):
            Router(two_replicas, strategy="nope")
        with pytest.raises(ValueError):
            Router([])
        with pytest.raises(ValueError, match="cost_correction"):
            Router(two_replicas, cost_correction="maybe")
        with pytest.raises(ValueError, match="online_blend"):
            Router(two_replicas, online_blend=1.5)

    def test_online_cost_correction_shifts_routing(self, two_replicas):
        """A statically-cheap replica that MEASURES slow loses traffic
        under online correction; static costing can't see it. Stats are
        injected directly — the engine-driven path is covered by the
        serving smoke's dilated-clock contract."""
        int8, bf16 = two_replicas
        static = Router(two_replicas, strategy="plan_aware",
                        cost_correction="static")
        online = Router(two_replicas, strategy="plan_aware",
                        cost_correction="online")
        req = Request(rid=0, prompt=np.zeros(4, np.int32))
        # the module-scoped fixture's engines may carry measurements
        # from earlier routing tests — force a cold fleet first
        saved = (int8.engine.stats.tok_per_s, bf16.engine.stats.tok_per_s)
        try:
            int8.engine.stats.tok_per_s = None
            bf16.engine.stats.tok_per_s = None
            # cold fleet: no measurements, online ranks like static
            assert static.route(req).name == "int8_serving"
            assert online.route(req).name == "int8_serving"
            int8.engine.stats.tok_per_s = 1.0     # became 100x slower
            bf16.engine.stats.tok_per_s = 100.0
            assert static.route(req).name == "int8_serving"
            assert online.route(req).name == "bf16"
            rep = online.routing_report()
            assert rep["cost_correction"] == "online"
            r8, rb = (rep["replicas"]["int8_serving"],
                      rep["replicas"]["bf16"])
            assert r8["static_cycles_per_token"] \
                < rb["static_cycles_per_token"]
            assert rb["effective_cost"] < r8["effective_cost"]
            assert r8["measured"]["tok_per_s"] == 1.0
        finally:
            int8.engine.stats.tok_per_s, bf16.engine.stats.tok_per_s = saved

    def test_replica_cost_covers_every_group(self, lm_setup):
        """Every projection group must resolve to a policy mode — a
        pattern no candidate path matches would silently drop a group
        from the cost model."""
        import re

        from repro.models.registry import projection_groups
        from repro.serving.router import _CANDIDATE_PATHS
        for arch in ("qwen2-0.5b", "rwkv6-1.6b", "recurrentgemma-9b",
                     "mixtral-8x7b", "internvl2-1b",
                     "seamless-m4t-medium", "gemma2-9b"):
            for g in projection_groups(reduced(arch)):
                assert any(re.search(g.pattern, p)
                           for p in _CANDIDATE_PATHS), (arch, g.name)


# -------------------------------------------------------------- metrics

class TestMetrics:
    def test_percentiles_empty_and_none_safe(self):
        assert percentiles([]) == {}
        assert percentiles([None, None]) == {}
        block = percentiles([1.0, 2.0, 3.0, None])
        assert block["p50"] == 2.0 and block["max"] == 3.0

    def test_request_metrics_decomposition(self):
        r = Request(rid=0, prompt=np.zeros(3, np.int32))
        r.tokens = [0, 0, 0, 1, 2]
        r.submit_time, r.admit_time = 10.0, 10.5
        r.first_token_time, r.finish_time = 11.0, 12.5
        m = request_metrics(r)
        assert m["ttft_s"] == pytest.approx(1.0)
        assert m["queue_delay_s"] == pytest.approx(0.5)
        assert m["e2e_s"] == pytest.approx(2.5)
        assert m["new_tokens"] == 2
        assert m["tok_per_s"] == pytest.approx(1.0)

    def test_engine_metrics_schema(self, lm_setup):
        cfg = lm_setup[0]
        eng = _engine(lm_setup)
        for r in _requests(cfg, [4, 6], [2, 2]):
            eng.submit(r)
        eng.run_until_drained()
        m = eng.metrics()
        assert m["n"] == 2 and m["new_tokens"] == 4
        for key in ("ttft_s", "queue_delay_s", "e2e_s"):
            assert m[key] and m[key]["p50"] >= 0.0
        assert m["counters"]["prefill_calls"] >= 1
        assert m["queue"] == 0 and m["active_slots"] == 0
        for key in ("short_blocks", "mid_block_admits", "eos_stops"):
            assert key in m["counters"]

    def test_slo_report(self):
        def req(rid, submit, first, finish, n_new):
            r = Request(rid=rid, prompt=np.zeros(2, np.int32))
            r.tokens = [0, 0] + [1] * n_new
            r.submit_time = submit
            r.first_token_time, r.finish_time = first, finish
            return r

        reqs = [req(0, 0.0, 0.5, 2.0, 10),    # TTFT 0.5 <= 1.0: attains
                req(1, 0.0, 2.0, 4.0, 6),     # TTFT 2.0 > 1.0: misses
                Request(rid=2, prompt=np.zeros(2, np.int32))]  # no token
        rep = slo_report(reqs, ttft_slo_s=1.0)
        assert rep["n"] == 2                  # tokenless one excluded
        assert rep["completed"] == 2
        assert rep["attainment"] == pytest.approx(0.5)
        # goodput counts attaining tokens only, over the 0.0->4.0 span
        assert rep["goodput_tok_per_s"] == pytest.approx(10 / 4.0)
        empty = slo_report([], ttft_slo_s=1.0)
        assert empty["attainment"] is None
        assert empty["goodput_tok_per_s"] is None and empty["n"] == 0

    def test_slo_report_all_in_flight(self):
        """Mid-run snapshot with nothing finished: used to raise on the
        empty ``max()``; now reports partial goodput up to the latest
        first token."""
        r = Request(rid=0, prompt=np.zeros(2, np.int32))
        r.tokens = [0, 0, 1, 1, 1]            # 3 generated so far
        r.submit_time, r.first_token_time = 0.0, 0.5
        assert r.finish_time is None
        rep = slo_report([r], ttft_slo_s=1.0)
        assert rep["n"] == 1 and rep["completed"] == 0
        assert rep["attainment"] == pytest.approx(1.0)
        assert rep["goodput_tok_per_s"] == pytest.approx(3 / 0.5)


# -------------------------------------------------------- observability

class _FakeClock:
    """Deterministic engine clock: +0.25s per read."""

    def __init__(self):
        self.t = 0.0

    def __call__(self):
        self.t += 0.25
        return self.t


class TestServingObservability:
    """The obs subsystem threaded through the engine: Chrome-trace
    export, zero-perturbation tracing, deterministic spans under an
    injected clock, and the metrics() observability blocks."""

    def _run(self, lm_setup, trace, clock=None):
        cfg, api, params = lm_setup
        kw = {"clock": clock} if clock is not None else {}
        eng = ServingEngine(cfg, api, params,
                            config=EngineConfig(batch_slots=2,
                                                cache_len=64,
                                                trace=trace), **kw)
        for r in _requests(cfg, [5, 1, 7], [2, 3, 2]):
            eng.submit(r)
        eng.run_until_drained()
        return eng

    def test_traced_engine_exports_valid_chrome_trace(self, lm_setup,
                                                      tmp_path):
        import json

        from repro.obs import validate_chrome_trace
        eng = self._run(lm_setup, trace=True)
        path = eng.dump_trace(str(tmp_path / "trace.json"))
        with open(path) as f:
            data = json.load(f)
        assert validate_chrome_trace(data) == []
        names = [e["name"] for e in data["traceEvents"]]
        for phase in ("admission", "prefill_dispatch",
                      "block_dispatch", "host_sync", "harvest"):
            assert phase in names, f"missing phase span {phase!r}"
        for stage in ("queued", "prefill", "decode", "first_token",
                      "finished"):
            assert stage in names, f"missing request span {stage!r}"
        assert any(str(n).startswith("compile:") for n in names), \
            "cold engine recorded no compile spans"

    def test_tracing_does_not_perturb(self, lm_setup):
        on = self._run(lm_setup, trace=True)
        off = self._run(lm_setup, trace=False)
        assert on.counters == off.counters            # CountersView ==
        assert dict(on.counters) == dict(off.counters)
        assert {r.rid: r.tokens for r in on.completed.values()} == \
            {r.rid: r.tokens for r in off.completed.values()}
        assert off.tracer.events == []
        with pytest.raises(RuntimeError, match="trace"):
            off.dump_trace("/dev/null")

    def test_trace_deterministic_under_injected_clock(self, lm_setup):
        import json
        traces = []
        for _ in range(2):
            eng = self._run(lm_setup, trace=True, clock=_FakeClock())
            traces.append(json.dumps(eng.tracer.to_chrome(),
                                     sort_keys=True))
        assert traces[0] == traces[1]

    def test_metrics_observability_schema(self, lm_setup):
        eng = self._run(lm_setup, trace=False)
        m = eng.metrics()
        # bit-compat: the counters block is the plain pre-refactor dict
        assert m["counters"] == dict(eng.counters)
        assert isinstance(m["counters"], dict)
        assert set(m["gauges"]) >= {"tok_per_tick", "queue_depth",
                                    "batch_occupancy"}
        assert m["gauges"]["tok_per_tick"]["n"] > 0
        assert m["replica_stats"]["ticks"] == m["counters"]["ticks"]
        assert m["replica_stats"]["ttft_samples"] == 3
        assert m["replica_stats"]["tok_per_s"] > 0
        assert m["queue_highwater"] == 3
        assert m["trace"] == {"enabled": False, "events": 0,
                              "dropped": 0}
