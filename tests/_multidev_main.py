"""Subprocess entry for multi-device tests (run with forced host devices).

Modes:
  lower <arch> <mesh>    — lower+compile reduced-arch train step
  run <arch> <mesh>      — run 3 real train steps, print losses
  elastic <arch>         — checkpoint on (2,4), restore+step on (4,2)
  serve <arch> <mesh>    — lower prefill+decode on the mesh
"""
import os

os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"

import sys  # noqa: E402

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax  # noqa: E402
import jax.numpy as jnp  # noqa: E402
import numpy as np  # noqa: E402

from repro.configs import reduced  # noqa: E402
from repro.configs.base import InputShape  # noqa: E402
from repro.data.pipeline import batch_for  # noqa: E402
from repro.launch.train import (TrainConfig, init_state,  # noqa: E402
                                make_train_step, state_shardings)
from repro.models import registry  # noqa: E402
from repro.parallel import sharding as shd  # noqa: E402


def make_mesh(name):
    if name == "multi":
        return jax.make_mesh((2, 2, 2), ("pod", "data", "model"))
    if name == "mesh42":
        return jax.make_mesh((4, 2), ("data", "model"))
    return jax.make_mesh((2, 4), ("data", "model"))


def main():
    mode, arch = sys.argv[1], sys.argv[2]
    mesh_name = sys.argv[3] if len(sys.argv) > 3 else "single"
    cfg = reduced(arch)
    api = registry.build(cfg)
    shape = InputShape("t", 32, 8, "train")
    batch_shape = registry.input_specs(cfg, shape)
    mesh = make_mesh(mesh_name)

    if mode in ("lower", "run"):
        with mesh:
            step, st_sh, _ = make_train_step(api, mesh, TrainConfig(),
                                             batch_shape)
            if mode == "lower":
                state_shape = jax.eval_shape(
                    lambda k: init_state(api, k), jax.random.PRNGKey(0))
                step.lower(state_shape, batch_shape).compile()
                print("LOWER_OK")
                return
            state = init_state(api, jax.random.PRNGKey(0))
            state = jax.device_put(state, st_sh)
            losses = []
            for i in range(3):
                batch = batch_for(cfg, shape, i)
                state, metrics = step(state, batch)
                losses.append(float(metrics["loss"]))
            assert all(np.isfinite(l) for l in losses), losses
            print("RUN_OK", " ".join(f"{l:.4f}" for l in losses))
            return

    if mode == "elastic":
        import tempfile
        from repro.checkpoint import CheckpointManager
        from repro.launch.train import TrainState
        tmp = tempfile.mkdtemp()
        mesh_a = make_mesh("single")
        with mesh_a:
            step_a, sh_a, _ = make_train_step(api, mesh_a, TrainConfig(),
                                              batch_shape)
            state = jax.device_put(init_state(api, jax.random.PRNGKey(0)),
                                   sh_a)
            batch = batch_for(cfg, shape, 0)
            state, m0 = step_a(state, batch)
            CheckpointManager(tmp).save(1, state)
        # restore onto a different mesh topology
        mesh_b = make_mesh("mesh42")
        with mesh_b:
            step_b, sh_b, _ = make_train_step(api, mesh_b, TrainConfig(),
                                              batch_shape)
            state_shape = jax.eval_shape(
                lambda k: init_state(api, k), jax.random.PRNGKey(0))
            s, st, _ = CheckpointManager(tmp).restore_latest(state_shape,
                                                             sh_b)
            assert s == 1
            st2, m1 = step_b(st, batch_for(cfg, shape, 1))
            assert np.isfinite(float(m1["loss"]))
            print("ELASTIC_OK", f"{float(m1['loss']):.4f}")
            return

    if mode == "serve":
        cache_len = 64
        with mesh:
            param_shape = jax.eval_shape(api.init, jax.random.PRNGKey(0))
            p_sh = shd.param_shardings(param_shape, mesh)
            cache_shape = jax.eval_shape(lambda: api.init_cache(8, cache_len))
            c_sh = shd.cache_shardings(cache_shape, mesh)
            dshape = {"token": jax.ShapeDtypeStruct((8, 1), jnp.int32),
                      "pos": jax.ShapeDtypeStruct((8,), jnp.int32)}
            fn = jax.jit(lambda p, b, c: api.decode_step(p, b, c),
                         in_shardings=(p_sh, None, c_sh))
            fn.lower(param_shape, dshape, cache_shape).compile()
            print("SERVE_OK")
            return

    raise SystemExit(f"unknown mode {mode}")


if __name__ == "__main__":
    main()
