"""Graceful degradation for property-based tests.

When ``hypothesis`` is installed (the ``[dev]`` extra), this module
re-exports the real ``given``/``settings``/``strategies``. When it is
not, a minimal deterministic fallback runs each property over a fixed
number of seeded pseudo-random examples (plus the bound corners), so the
suite still exercises the properties instead of failing at collection.

The fallback implements only what this repo's tests use:
``st.integers``, ``st.sampled_from``, ``st.lists``, and the ``.map`` /
``.filter`` combinators. It does no shrinking — on failure it reports
the raw counterexample values in the assertion traceback.
"""
try:
    from hypothesis import given, settings, strategies as st  # noqa: F401
    HAVE_HYPOTHESIS = True
except ModuleNotFoundError:
    HAVE_HYPOTHESIS = False

    import functools
    import random

    _FALLBACK_MAX_EXAMPLES = 25  # cap: no shrinking, keep the lane fast

    class _Strategy:
        def __init__(self, draw, corners=()):
            self._draw = draw          # (rng) -> value
            self._corners = tuple(corners)

        def example(self, rng, i):
            if i < len(self._corners):
                return self._corners[i]
            return self._draw(rng)

        def map(self, f):
            return _Strategy(lambda rng: f(self._draw(rng)),
                             [f(c) for c in self._corners])

        def filter(self, pred):
            def draw(rng):
                for _ in range(10_000):
                    v = self._draw(rng)
                    if pred(v):
                        return v
                raise ValueError("filter predicate rejected 10k draws")
            return _Strategy(draw, [c for c in self._corners if pred(c)])

    class st:  # noqa: N801 - mirrors the hypothesis module name
        @staticmethod
        def integers(min_value=None, max_value=None):
            lo = -(2 ** 63) if min_value is None else min_value
            hi = 2 ** 63 if max_value is None else max_value
            corners = sorted({lo, hi} | ({0} if lo <= 0 <= hi else set()))
            return _Strategy(lambda rng: rng.randint(lo, hi), corners)

        @staticmethod
        def sampled_from(seq):
            seq = list(seq)
            return _Strategy(lambda rng: rng.choice(seq), seq[:2])

        @staticmethod
        def lists(elem, min_size=0, max_size=10):
            def draw(rng):
                size = rng.randint(min_size, max_size)
                return [elem._draw(rng) for _ in range(size)]
            return _Strategy(draw)

    def settings(max_examples=_FALLBACK_MAX_EXAMPLES, **_kw):
        def deco(f):
            f._compat_max_examples = max_examples
            return f
        return deco

    def given(*strats):
        def deco(f):
            @functools.wraps(f)
            def wrapper(*args, **kwargs):
                n = min(getattr(wrapper, "_compat_max_examples",
                                getattr(f, "_compat_max_examples",
                                        _FALLBACK_MAX_EXAMPLES)),
                        _FALLBACK_MAX_EXAMPLES)
                rng = random.Random(1234)
                for i in range(n):
                    vals = [s.example(rng, i) for s in strats]
                    f(*args, *vals, **kwargs)
            # keep pytest from treating the property's value parameters
            # as fixtures (inspect.signature follows __wrapped__)
            del wrapper.__wrapped__
            return wrapper
        return deco
