"""Observability subsystem coverage: the span tracer (deterministic
under an injected clock, free when disabled, Chrome-trace-valid on
export), the typed metrics registry (dict-compatible counters view —
the engine's ``metrics()["counters"]`` bit-compat contract), rolling
gauges, and the measured ``ReplicaStats`` the router's online cost
correction consumes.
"""
import json

import numpy as np
import pytest

from repro.obs import (PERCENTILES, CountersView, MetricsRegistry,
                       ReplicaStats, RollingGauge, Tracer, percentile_block,
                       traced_jit, validate_chrome_trace)
from repro.obs.trace import REQUEST_LANE_BASE, TICK_LANE, _NULL_SPAN


class FakeClock:
    """Deterministic monotonic clock: +0.5s per read."""

    def __init__(self, t: float = 0.0):
        self.t = t

    def __call__(self) -> float:
        self.t += 0.5
        return self.t


def _record_session(tracer):
    with tracer.span("admission"):
        pass
    tracer.req_begin(7, "queued", args={"prompt_len": 3})
    with tracer.span("block_dispatch", args={"n": 4}):
        pass
    tracer.req_end(7, "queued")
    tracer.req_instant(7, "first_token")
    tracer.instant("tick_done")


# --------------------------------------------------------------- tracer

class TestTracer:
    def test_deterministic_under_injected_clock(self):
        runs = []
        for _ in range(2):
            tr = Tracer(clock=FakeClock(), enabled=True)
            _record_session(tr)
            runs.append(json.dumps(tr.to_chrome(), sort_keys=True))
        assert runs[0] == runs[1]

    def test_disabled_tracer_is_a_noop(self):
        tr = Tracer(clock=FakeClock(), enabled=False)
        assert tr.span("x") is _NULL_SPAN
        _record_session(tr)
        assert tr.events == [] and tr.dropped == 0

    def test_complete_span_timestamps_microseconds(self):
        tr = Tracer(clock=FakeClock(), enabled=True)
        with tr.span("phase"):      # enter reads 0.5s, exit reads 1.0s
            pass
        ev = [e for e in tr.events if e["ph"] == "X"][0]
        assert ev["ts"] == pytest.approx(0.5e6)
        assert ev["dur"] == pytest.approx(0.5e6)
        assert ev["tid"] == TICK_LANE

    def test_request_lanes_and_metadata(self):
        tr = Tracer(clock=FakeClock(), enabled=True)
        tr.req_begin(3, "queued")
        tr.req_end(3, "queued")
        lane = tr.request_lane(3)
        assert lane == REQUEST_LANE_BASE + 3
        names = [e for e in tr.events if e["ph"] == "M"
                 and e["name"] == "thread_name"]
        assert any(e["tid"] == lane and e["args"]["name"] == "req 3"
                   for e in names)
        b = [e for e in tr.events if e["ph"] == "B"][0]
        e = [e for e in tr.events if e["ph"] == "E"][0]
        assert b["tid"] == e["tid"] == lane and b["ts"] <= e["ts"]

    def test_dump_validate_round_trip(self, tmp_path):
        tr = Tracer(clock=FakeClock(), enabled=True)
        _record_session(tr)
        path = tr.dump(str(tmp_path / "t.json"))
        with open(path) as f:
            data = json.load(f)
        assert validate_chrome_trace(data) == []
        assert data["traceEvents"]

    def test_max_events_cap_counts_drops(self):
        tr = Tracer(clock=FakeClock(), enabled=True, max_events=5)
        for _ in range(10):
            tr.instant("x")
        assert len(tr.events) == 5
        assert tr.dropped == 7          # 2 metadata events + 3 instants fit
        out = tr.to_chrome()["traceEvents"]
        assert "dropped" in out[-1]["name"]
        assert validate_chrome_trace(out) == []


class TestTracedJit:
    def test_compile_span_once_per_signature(self):
        import jax
        import jax.numpy as jnp

        tr = Tracer(clock=FakeClock(), enabled=True)
        fn = traced_jit(jax.jit(lambda x: x + 1), "add", tr)
        fn(jnp.zeros(2))                # compiles
        fn(jnp.zeros(2))                # cached
        spans = [e for e in tr.events
                 if e["name"] == "compile:add" and e["ph"] == "X"]
        assert len(spans) == 1 and spans[0]["cat"] == "compile"
        fn(jnp.zeros(3))                # new shape: compiles again
        spans = [e for e in tr.events if e["name"] == "compile:add"]
        assert len(spans) == 2

    def test_disabled_returns_raw_callable(self):
        tr = Tracer(enabled=False)
        fn = object()
        assert traced_jit(fn, "x", tr) is fn
        assert traced_jit(fn, "x", None) is fn


class TestValidateChromeTrace:
    def test_accepts_object_and_bare_list(self):
        ev = {"name": "a", "ph": "i", "ts": 0, "pid": 1, "tid": 0}
        assert validate_chrome_trace({"traceEvents": [ev]}) == []
        assert validate_chrome_trace([ev]) == []

    def test_rejects_malformed(self):
        assert validate_chrome_trace(42)
        assert validate_chrome_trace({"nope": []})
        assert validate_chrome_trace([{"ph": "i"}])                # no name
        assert validate_chrome_trace(
            [{"name": "a", "ph": "Z", "ts": 0, "pid": 1, "tid": 0}])
        # X span without a dur
        assert validate_chrome_trace(
            [{"name": "a", "ph": "X", "ts": 0, "pid": 1, "tid": 0}])
        assert validate_chrome_trace(
            [{"name": "a", "ph": "i", "ts": "late", "pid": 1, "tid": 0}])


# ------------------------------------------------------------- registry

class TestMetricsRegistry:
    def test_counters_view_is_dict_compatible(self):
        reg = MetricsRegistry()
        view = reg.counters_view()
        view["ticks"] = 0
        view["ticks"] += 3
        view["steps"] = 2
        assert view["ticks"] == 3
        assert dict(view) == {"ticks": 3, "steps": 2}
        assert view == {"ticks": 3, "steps": 2}
        assert {"ticks": 3, "steps": 2} == view
        assert view != {"ticks": 4, "steps": 2}
        assert list(view) == ["ticks", "steps"]   # creation order
        assert len(view) == 2 and "ticks" in view
        assert repr(view) == repr({"ticks": 3, "steps": 2})
        other = MetricsRegistry().counters_view()
        other["ticks"], other["steps"] = 3, 2
        assert view == other
        del view["steps"]
        assert dict(view) == {"ticks": 3}
        # the view writes through to the typed instrument
        assert reg.counter("ticks").value == 3

    def test_percentile_block_schema(self):
        assert percentile_block([]) == {}
        assert percentile_block([None, None]) == {}
        block = percentile_block([1.0, None, 3.0])
        assert set(block) == {f"p{p}" for p in PERCENTILES} | \
            {"mean", "max"}
        assert block["mean"] == pytest.approx(2.0)
        assert block["max"] == pytest.approx(3.0)

    def test_histogram_matches_serving_percentiles(self):
        from repro.serving.metrics import percentiles
        reg = MetricsRegistry()
        h = reg.histogram("lat")
        xs = list(np.random.default_rng(0).uniform(0, 1, 50))
        for x in xs:
            h.observe(x)
        assert h.summary() == percentiles(xs)

    def test_rolling_gauge_window_and_rate(self):
        g = RollingGauge("tok", window=4)
        assert g.last is None and g.mean() is None and g.rate() is None
        for t in range(8):                    # 1 tok per 1s tick
            g.observe(float(t), 1.0)
        assert len(g) == 4                    # window bounds the deque
        assert g.last == 1.0 and g.mean() == pytest.approx(1.0)
        assert g.rate() == pytest.approx(1.0)  # 3 tokens over 3 seconds
        snap = g.snapshot()
        assert set(snap) == {"last", "mean", "rate", "n"}
        same_t = RollingGauge("x", window=4)
        same_t.observe(1.0, 5.0)
        same_t.observe(1.0, 5.0)              # zero time span
        assert same_t.rate() is None

    def test_snapshot_schema(self):
        reg = MetricsRegistry()
        reg.counter("c").inc(2)
        reg.gauge("g").set(1.5)
        reg.histogram("h").observe(1.0)
        reg.rolling("r").observe(0.0, 1.0)
        snap = reg.snapshot()
        assert snap["counters"] == {"c": 2}
        assert snap["gauges"] == {"g": 1.5}
        assert set(snap["histograms"]["h"]) >= {"p50", "mean", "max"}
        assert snap["rolling"]["r"]["last"] == 1.0


# ---------------------------------------------------------- replica stats

class TestReplicaStats:
    def test_ewma_over_per_tick_rates(self):
        st = ReplicaStats(alpha=0.5)
        assert not st.measured
        st.on_tick(0.0, 0, 0)            # first sample: no dt yet
        st.on_tick(1.0, 10, 0, active_slots=1)   # 10 tok/s
        assert st.tok_per_s == pytest.approx(10.0)
        st.on_tick(2.0, 20, 0, active_slots=1)   # 20 tok/s
        assert st.tok_per_s == pytest.approx(15.0)   # 0.5*20 + 0.5*10
        assert st.measured and st.ticks == 3

    def test_idle_and_zero_dt_ticks_excluded(self):
        st = ReplicaStats(alpha=0.5)
        st.on_tick(0.0, 0, 0)
        st.on_tick(1.0, 10, 0, active_slots=1)
        st.on_tick(2.0, 0, 0, active_slots=0)    # idle: no signal
        assert st.tok_per_s == pytest.approx(10.0)
        st.on_tick(2.0, 50, 0, active_slots=1)   # dt == 0: guarded
        assert st.tok_per_s == pytest.approx(10.0)

    def test_ttft_window_and_p95(self):
        st = ReplicaStats(window=8)
        assert st.p95_ttft_s is None
        for i in range(20):
            st.observe_ttft(float(i))
        # only the last 8 samples (12..19) survive the window
        assert st.p95_ttft_s == pytest.approx(
            float(np.percentile(np.arange(12, 20), 95)))
        assert st.snapshot()["ttft_samples"] == 8

    def test_snapshot_schema_and_validation(self):
        with pytest.raises(ValueError, match="alpha"):
            ReplicaStats(alpha=0.0)
        st = ReplicaStats()
        snap = st.snapshot()
        assert set(snap) == {"tok_per_s", "queue_depth", "active_slots",
                             "p95_ttft_s", "ttft_samples", "ticks",
                             "transported"}
        assert snap["tok_per_s"] is None
