"""Dedicated ``repro.checkpoint`` coverage: the self-describing restore
path prepared-weight (fabric) checkpoints depend on, per-leaf checksum
verification, the unified miss behavior, and crash-safety/GC.

Complements the pipeline-level tests in test_substrates.py (plain
roundtrip through a ``like`` template, keep-k, FT-loop resume): here the
contracts are about the checkpoint format itself.
"""
import os

import numpy as np
import pytest

import jax.numpy as jnp

from repro.checkpoint import (CheckpointError, CheckpointManager,
                              CheckpointNotFound, ChecksumError,
                              latest_step, list_steps,
                              restore_checkpoint, save_checkpoint)
from repro.core.policy import PrecisionSpec
from repro.quant.prepare import PreparedWeight, prepare_weight


def _prepared_tree():
    """A serving-shaped tree: packed int4 + int8 PreparedWeights (with
    an act scale), a raw bf16 leaf, a tuple, a None hole."""
    rng = np.random.default_rng(0)
    w4 = jnp.asarray(rng.normal(0, 1, (16, 8)), jnp.float32)
    w8 = jnp.asarray(rng.normal(0, 1, (12, 8)), jnp.float32)
    p4 = prepare_weight(w4, PrecisionSpec("int4", exact=True),
                        act_scale=0.125)
    p8 = prepare_weight(w8, PrecisionSpec("int8", exact=True))
    assert p4.kind == "int4_packed" and p8.kind == "int8"
    return {
        "blocks": {"b0": {"attn": {"wq": p4, "wo": p8}}},
        "emb": jnp.arange(24, dtype=jnp.bfloat16).reshape(4, 6),
        "pair": (jnp.ones(3, jnp.float32), None),
        "ids": [jnp.arange(5, dtype=jnp.int32)],
    }


class TestSelfDescribingRestore:
    def test_prepared_tree_bit_exact_without_template(self, tmp_path):
        tree = _prepared_tree()
        save_checkpoint(str(tmp_path), 3, tree, {"policy": "int4"})
        out, meta = restore_checkpoint(str(tmp_path), 3)   # no `like`
        assert meta == {"policy": "int4"}

        got4 = out["blocks"]["b0"]["attn"]["wq"]
        ref4 = tree["blocks"]["b0"]["attn"]["wq"]
        assert isinstance(got4, PreparedWeight)
        assert got4.kind == "int4_packed"
        # packed nibbles are uint8: any astype round trip would destroy
        # them — bit-equality here is the whole point of the spec'd path
        assert got4.data.dtype == ref4.data.dtype
        np.testing.assert_array_equal(np.asarray(got4.data),
                                      np.asarray(ref4.data))
        np.testing.assert_array_equal(np.asarray(got4.scale),
                                      np.asarray(ref4.scale))
        np.testing.assert_array_equal(np.asarray(got4.act_scale),
                                      np.asarray(ref4.act_scale))
        got8 = out["blocks"]["b0"]["attn"]["wo"]
        assert got8.kind == "int8" and got8.act_scale is None
        np.testing.assert_array_equal(np.asarray(got8.data),
                                      np.asarray(ref8 := tree["blocks"][
                                          "b0"]["attn"]["wo"].data))
        assert ref8.dtype == got8.data.dtype

        # container fidelity: tuple stays tuple, list stays list, the
        # None hole survives, bf16 comes back as bf16 bit-for-bit
        assert isinstance(out["pair"], tuple) and out["pair"][1] is None
        assert isinstance(out["ids"], list)
        assert out["emb"].dtype == jnp.bfloat16
        np.testing.assert_array_equal(
            np.asarray(out["emb"]).view(np.uint16),
            np.asarray(tree["emb"]).view(np.uint16))

    def test_fp_and_per_group_tree_bit_exact(self, tmp_path):
        """The fp storage tier round-trips: e4m3/e2m1 bit-field codes
        (uint8, packed or not) and (G, N) per-group scales restore
        bit-for-bit with no template."""
        rng = np.random.default_rng(1)
        w8 = jnp.asarray(rng.normal(0, 1, (32, 8)), jnp.float32)
        w4 = jnp.asarray(rng.normal(0, 1, (32, 8)), jnp.float32)
        p8 = prepare_weight(w8, PrecisionSpec("fp8", group_size=8),
                            act_scale=0.25)
        p4 = prepare_weight(w4, PrecisionSpec("fp4"))
        assert p8.kind == "fp8" and p8.scale_groups == 4
        assert p4.kind == "fp4_packed"
        tree = {"fp8": p8, "fp4": p4}
        save_checkpoint(str(tmp_path), 1, tree, {"tier": "fp"})
        out, meta = restore_checkpoint(str(tmp_path), 1)
        assert meta == {"tier": "fp"}
        for key, want in tree.items():
            got = out[key]
            assert isinstance(got, PreparedWeight)
            assert got.kind == want.kind
            assert got.data.dtype == want.data.dtype
            assert got.scale.shape == want.scale.shape
            np.testing.assert_array_equal(np.asarray(got.data),
                                          np.asarray(want.data))
            np.testing.assert_array_equal(np.asarray(got.scale),
                                          np.asarray(want.scale))
        # dequant of the restored container reproduces the original grid
        np.testing.assert_array_equal(np.asarray(out["fp8"].dequant()),
                                      np.asarray(p8.dequant()))

    def test_like_template_still_casts(self, tmp_path):
        tree = {"w": jnp.ones((2, 3), jnp.float32)}
        save_checkpoint(str(tmp_path), 1, tree)
        like = {"w": jnp.zeros((2, 3), jnp.bfloat16)}
        out, _ = restore_checkpoint(str(tmp_path), 1, like)
        assert out["w"].dtype == jnp.bfloat16

    def test_like_shape_mismatch_is_checkpoint_error(self, tmp_path):
        save_checkpoint(str(tmp_path), 1, {"w": jnp.ones((2, 3))})
        with pytest.raises(CheckpointError, match="shape"):
            restore_checkpoint(str(tmp_path), 1,
                               {"w": jnp.ones((3, 2))})


class TestChecksums:
    def _corrupt(self, tmp_path, step, key):
        npz = os.path.join(str(tmp_path), f"step_{step:09d}",
                           "arrays.npz")
        data = dict(np.load(npz))
        arr = data[key]
        flat = arr.reshape(-1).copy()
        if flat.dtype.kind in "iu":
            flat[0] ^= 1
        else:
            flat[0] = flat[0] + 1.0
        data[key] = flat.reshape(arr.shape)
        np.savez(npz, **data)

    def test_corruption_raises_naming_leaf(self, tmp_path):
        tree = {"alpha": jnp.arange(4, dtype=jnp.int32),
                "beta": jnp.ones(3, jnp.float32)}
        save_checkpoint(str(tmp_path), 5, tree)
        self._corrupt(tmp_path, 5, "a0")        # leaf 0 == 'alpha'
        with pytest.raises(ChecksumError) as ei:
            restore_checkpoint(str(tmp_path), 5)
        assert "alpha" in str(ei.value)
        # the template path verifies too
        with pytest.raises(ChecksumError, match="alpha"):
            restore_checkpoint(str(tmp_path), 5, tree)

    def test_verify_off_skips_the_check(self, tmp_path):
        tree = {"alpha": jnp.arange(4, dtype=jnp.int32)}
        save_checkpoint(str(tmp_path), 5, tree)
        self._corrupt(tmp_path, 5, "a0")
        out, _ = restore_checkpoint(str(tmp_path), 5, verify=False)
        assert out["alpha"].shape == (4,)

    def test_intact_checkpoint_verifies_clean(self, tmp_path):
        tree = _prepared_tree()
        save_checkpoint(str(tmp_path), 2, tree)
        restore_checkpoint(str(tmp_path), 2)     # verify=True default


class TestMissBehavior:
    def test_restore_checkpoint_raises_not_found(self, tmp_path):
        with pytest.raises(CheckpointNotFound):
            restore_checkpoint(str(tmp_path), 9)
        save_checkpoint(str(tmp_path), 1, {"x": jnp.zeros(2)})
        with pytest.raises(CheckpointNotFound, match="have steps \\[1\\]"):
            restore_checkpoint(str(tmp_path), 9)

    def test_restore_latest_unified(self, tmp_path):
        mgr = CheckpointManager(str(tmp_path))
        with pytest.raises(CheckpointNotFound):
            mgr.restore_latest()
        assert mgr.restore_latest(missing_ok=True) == (None, None, {})
        # CheckpointNotFound doubles as FileNotFoundError for callers
        # that catch the stdlib type
        with pytest.raises(FileNotFoundError):
            mgr.restore_latest()


class TestCrashSafetyAndGC:
    def test_leftover_tmp_ignored_and_cleaned(self, tmp_path):
        # a writer that died mid-save leaves step_N.tmp behind
        stale = tmp_path / "step_000000042.tmp"
        os.makedirs(stale)
        (stale / "arrays.npz").write_bytes(b"partial")
        assert latest_step(str(tmp_path)) is None
        assert list_steps(str(tmp_path)) == []
        # the next managed save garbage-collects the staging dir
        mgr = CheckpointManager(str(tmp_path), keep=2)
        mgr.save(1, {"x": jnp.zeros(2)})
        assert not stale.exists()
        assert list_steps(str(tmp_path)) == [1]

    def test_keep_last_k(self, tmp_path):
        mgr = CheckpointManager(str(tmp_path), keep=1)
        for s in (1, 2, 3):
            mgr.save(s, {"x": jnp.full(2, s)})
        assert list_steps(str(tmp_path)) == [3]
        step, out, _ = mgr.restore_latest({"x": jnp.zeros(2)})
        assert step == 3
        np.testing.assert_array_equal(np.asarray(out["x"]), [3.0, 3.0])

    def test_save_over_same_step_replaces(self, tmp_path):
        save_checkpoint(str(tmp_path), 7, {"x": jnp.zeros(2)})
        save_checkpoint(str(tmp_path), 7, {"x": jnp.ones(2)})
        out, _ = restore_checkpoint(str(tmp_path), 7)
        np.testing.assert_array_equal(np.asarray(out["x"]), [1.0, 1.0])
