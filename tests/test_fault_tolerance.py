"""``runtime.fault_tolerance`` contracts beyond the happy paths in
test_substrates.py: exact batch-order replay across restarts, restart
metadata persistence, repeated failures, and straggler policy hooks.
"""
import numpy as np
import pytest

import jax.numpy as jnp

from repro.checkpoint import CheckpointManager
from repro.runtime.fault_tolerance import (FTConfig, FaultTolerantLoop,
                                           StragglerMonitor,
                                           WorkerFailure)


def _loop(tmp_path, calls, failure_hook=None, straggler_hook=None,
          checkpoint_every=4, max_restarts=3):
    def step_fn(state, batch):
        return {"w": state["w"] + batch["tokens"].sum()}, {"loss": 0.0}

    def batch_fn(step):
        calls.append(step)
        return {"tokens": jnp.full((2,), step, jnp.int32)}

    return FaultTolerantLoop(
        step_fn, batch_fn, str(tmp_path),
        FTConfig(checkpoint_every=checkpoint_every,
                 max_restarts=max_restarts),
        failure_hook=failure_hook, straggler_hook=straggler_hook)


class TestResumeReplay:
    def test_resume_replays_exact_batch_order(self, tmp_path):
        """After a failure the loop restores the last checkpoint and
        re-consumes the data stream from that step: the observed
        batch-index sequence is exactly (progress so far) + (replay from
        the checkpoint step) — deterministic, no skipped or duplicated
        steps relative to the checkpoint."""
        calls = []
        fired = {"done": False}

        def fail_at_6(step):
            if step == 6 and not fired["done"]:
                fired["done"] = True
                raise WorkerFailure("injected")

        loop = _loop(tmp_path, calls, failure_hook=fail_at_6)
        state, step = loop.run({"w": jnp.zeros(())}, 0, 10)
        assert step == 10
        # ran 0..5, failed at 6 (before batch_fn), restored step-4
        # checkpoint, replayed 4 and 5, then continued
        assert calls == [0, 1, 2, 3, 4, 5, 4, 5, 6, 7, 8, 9]
        assert float(state["w"]) == sum(2 * s for s in range(10))

    def test_fresh_loop_resumes_from_disk(self, tmp_path):
        """A NEW loop over the same directory (the restarted-process
        case) resumes at the checkpointed step instead of step 0."""
        calls = []
        loop = _loop(tmp_path, calls)
        loop.run({"w": jnp.zeros(())}, 0, 8)     # checkpoints at 4, 8
        calls2 = []
        loop2 = _loop(tmp_path, calls2)
        state, step = loop2.run({"w": jnp.zeros(())}, 0, 12)
        assert step == 12
        assert calls2 == [8, 9, 10, 11]          # nothing before 8 reran
        assert float(state["w"]) == sum(2 * s for s in range(12))

    def test_restart_count_persisted_in_metadata(self, tmp_path):
        calls = []
        fired = {"n": 0}

        def fail_twice(step):
            if step == 5 and fired["n"] < 2:
                fired["n"] += 1
                raise WorkerFailure("injected")

        loop = _loop(tmp_path, calls, failure_hook=fail_twice)
        state, step = loop.run({"w": jnp.zeros(())}, 0, 8)
        assert step == 8 and loop.restarts == 2
        _, _, meta = CheckpointManager(str(tmp_path)).restore_latest(
            {"w": jnp.zeros(())})
        assert meta["restarts"] == 2

    def test_failure_before_first_checkpoint_replays_from_start(
            self, tmp_path):
        calls = []
        fired = {"done": False}

        def fail_at_2(step):
            if step == 2 and not fired["done"]:
                fired["done"] = True
                raise WorkerFailure("injected")

        loop = _loop(tmp_path, calls, failure_hook=fail_at_2,
                     checkpoint_every=50)
        state, step = loop.run({"w": jnp.zeros(())}, 0, 5)
        assert step == 5
        assert calls == [0, 1, 0, 1, 2, 3, 4]    # full replay from 0
        assert float(state["w"]) == sum(2 * s for s in range(5))


class TestStraggler:
    def test_hook_fires_on_flagged_step(self, tmp_path, monkeypatch):
        import repro.runtime.fault_tolerance as ft

        flagged = []
        calls = []
        loop = _loop(tmp_path, calls,
                     straggler_hook=lambda s: flagged.append(s))
        # scripted clock: the loop reads monotonic() twice per step
        # (t0, then t0 + duration); step 9 takes 10x the median
        durations = [1.0] * 9 + [10.0] + [1.0] * 4
        seq, t = [], 0.0
        for d in durations:
            seq += [t, t + d]
            t += d + 1.0
        it = iter(seq)

        class _ScriptedTime:
            monotonic = staticmethod(lambda: next(it))

        # swap the module's `time` reference, not the global time
        # module — jax internals keep the real clock
        monkeypatch.setattr(ft, "time", _ScriptedTime)
        loop.run({"w": jnp.zeros(())}, 0, len(durations))
        assert loop.monitor.flagged == [9]
        assert flagged == [9]

    def test_monitor_warmup_and_window(self):
        mon = StragglerMonitor(FTConfig(deadline_factor=2.0,
                                        straggler_window=8))
        # fewer than 8 observations: never flags, even huge outliers
        for i in range(7):
            assert not mon.observe(i, 100.0 if i == 3 else 1.0)
        for i in range(7, 30):
            mon.observe(i, 1.0)
        # median of the trailing window is 1.0 now: 2.5 flags
        assert mon.observe(30, 2.5)
        assert 30 in mon.flagged
