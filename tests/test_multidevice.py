"""Multi-device SPMD tests via subprocess (8 forced host devices — the
env var must be set before jax initializes, hence the subprocess)."""
import os
import subprocess
import sys

import pytest

pytestmark = pytest.mark.slow

_MAIN = os.path.join(os.path.dirname(__file__), "_multidev_main.py")


def _run(*args, timeout=420):
    out = subprocess.run([sys.executable, _MAIN, *args],
                         capture_output=True, text=True, timeout=timeout)
    assert out.returncode == 0, out.stdout[-2000:] + out.stderr[-2000:]
    return out.stdout


@pytest.mark.parametrize("arch,mesh", [
    ("qwen2-0.5b", "single"),
    ("qwen2-0.5b", "multi"),
    ("gemma2-9b", "single"),
    ("mixtral-8x7b", "multi"),
    ("qwen3-moe-30b-a3b", "single"),
    ("rwkv6-1.6b", "single"),
    ("recurrentgemma-9b", "multi"),
    ("seamless-m4t-medium", "single"),
    ("internvl2-1b", "multi"),
    ("stablelm-12b", "multi"),
    ("glm4-9b", "single"),
])
def test_train_lowers_on_mesh(arch, mesh):
    assert "LOWER_OK" in _run("lower", arch, mesh)


@pytest.mark.parametrize("arch,mesh", [
    ("qwen2-0.5b", "single"),
    ("mixtral-8x7b", "multi"),
    ("rwkv6-1.6b", "single"),
])
def test_train_runs_real_steps(arch, mesh):
    out = _run("run", arch, mesh)
    assert "RUN_OK" in out


def test_elastic_reshard_across_topologies():
    assert "ELASTIC_OK" in _run("elastic", "qwen2-0.5b")


@pytest.mark.parametrize("arch,mesh", [
    ("qwen2-0.5b", "single"),
    ("recurrentgemma-9b", "single"),
])
def test_decode_lowers_on_mesh(arch, mesh):
    assert "SERVE_OK" in _run("serve", arch, mesh)
