"""Fused dequant-matmul kernel + executor-variant coverage.

The tentpole contract: the fused Pallas kernels (kernels/fused.py) take
STORED operands — int8 rows, nibble-packed int4, fp8/fp4 bit-field
codes, per-channel or per-group scales — and must reproduce the staged
datapath they replace:

  * exact-int per-channel (fused_quantized_matmul): bit-exact to
    static-scale quantize + quantized_matmul[_packed] (int32 math);
  * general dequant (fused_dequant_matmul): allclose to decode + f32
    matmul (f32 accumulation order differs between the block loop and
    one big dot);
  * every storage kind x scale granularity x MXU-unaligned shape;
  * the 'fused' executor variant routes mp_linear through them and
    falls back to the base executors when operands aren't fusable.
"""
import numpy as np
import pytest

import jax.numpy as jnp

pytestmark = [pytest.mark.kernel, pytest.mark.slow]

from repro.core.policy import PrecisionSpec
from repro.kernels import ops, ref
from repro.layers import mplinear
from repro.layers.mplinear import executor_variant, mp_linear
from repro.quant.prepare import PreparedWeight, prepare_weight
from repro.quant.quantize import (FP4_E2M1, FP8_E4M3, fp_quantize,
                                  quantize_symmetric)

SHAPES = [(8, 16, 8), (16, 32, 128), (33, 64, 17), (1, 16, 1),
          (130, 48, 257)]
INT_KINDS = ["int8", "int4", "int4_packed"]
ALL_KINDS = INT_KINDS + ["fp8", "fp4", "fp4_packed"]


def _stored(rng, k, n, kind, groups=1):
    """(stored operand, (G, N) scales) for one storage kind."""
    w = jnp.asarray(rng.normal(0, 1, (k, n)), jnp.float32)
    wg = w.reshape(groups, k // groups, n) if groups > 1 else w
    ax = -2
    if kind in ("fp8", "fp4", "fp4_packed"):
        fmt = FP8_E4M3 if kind == "fp8" else FP4_E2M1
        q, s = fp_quantize(wg, fmt, axis=ax)
    else:
        bits = 8 if kind == "int8" else 4
        q, s = quantize_symmetric(wg, bits, axis=ax)
    if groups > 1:
        q = q.reshape(k, n)
        s = jnp.squeeze(s, -2)
    else:
        s = s.reshape(1, n)
    if kind == "int4_packed":
        q = ops.pack_int4(q)
    elif kind == "fp4_packed":
        q = ops.pack_u4(q)
    return q, s


def _x(rng, m, k):
    return jnp.asarray(rng.normal(0, 2, (m, k)), jnp.float32)


class TestPackU4:
    def test_roundtrip_preserves_high_codes(self):
        """fp4 codes with the sign bit set (>= 8) survive the unsigned
        pack — the int4 unpack's sign extension would corrupt them."""
        rng = np.random.default_rng(0)
        codes = jnp.asarray(rng.integers(0, 16, (3, 10, 6)), jnp.uint8)
        np.testing.assert_array_equal(
            np.asarray(ops.unpack_u4(ops.pack_u4(codes))),
            np.asarray(codes))
        signed = np.asarray(ops.unpack_int4(
            ops.pack_int4(codes.astype(jnp.int8))))
        assert (signed < 0).any(), "test codes never exercised bit 3"

    def test_odd_k_rejected(self):
        with pytest.raises(ValueError):
            ops.pack_u4(jnp.zeros((3, 4), jnp.uint8))


class TestFusedQMM:
    """The exact-int fused kernel: bit-exact to the staged composition."""

    @pytest.mark.parametrize("shape", SHAPES)
    @pytest.mark.parametrize("kind", INT_KINDS)
    def test_pallas_matches_ref(self, shape, kind):
        m, k, n = shape
        rng = np.random.default_rng(hash((shape, kind)) % 2**32)
        w, sw = _stored(rng, k, n, kind)
        x = _x(rng, m, k)
        sa = jnp.float32(0.11)
        got = ops.fused_quantized_matmul(x, w, sw, sa, kind=kind)
        want = ref.fused_qmm_ref(x, w, sw, sa, kind=kind)
        np.testing.assert_array_equal(np.asarray(got), np.asarray(want))

    @pytest.mark.parametrize("kind", INT_KINDS)
    def test_bit_exact_to_staged_pipeline(self, kind):
        """The acceptance bar: fused == static-scale activation quantize
        + quantized_matmul[_packed], bitwise, with zero staged arrays."""
        rng = np.random.default_rng(7)
        m, k, n = 9, 32, 21
        w, sw = _stored(rng, k, n, kind)
        x = _x(rng, m, k)
        sa = jnp.float32(0.2)
        aq, _ = quantize_symmetric(x, 8, scale=sa)
        if kind == "int4_packed":
            staged = ops.quantized_matmul_packed(aq, w, sa, sw.reshape(-1))
        else:
            staged = ops.quantized_matmul(aq, w, sa, sw.reshape(-1))
        fused = ops.fused_quantized_matmul(x, w, sw, sa, kind=kind)
        np.testing.assert_array_equal(np.asarray(fused),
                                      np.asarray(staged))

    def test_backends_agree(self):
        rng = np.random.default_rng(11)
        w, sw = _stored(rng, 48, 40, "int8")
        x = _x(rng, 24, 48)
        sa = jnp.float32(0.15)
        p = ops.fused_quantized_matmul(x, w, sw, sa, kind="int8",
                                       backend="pallas")
        r = ops.fused_quantized_matmul(x, w, sw, sa, kind="int8",
                                       backend="xla")
        np.testing.assert_array_equal(np.asarray(p), np.asarray(r))


class TestFusedDequant:
    """The general fused kernel: every kind x scale granularity x act."""

    @pytest.mark.parametrize("shape", SHAPES)
    @pytest.mark.parametrize("kind", ALL_KINDS)
    def test_per_channel_matches_ref(self, shape, kind):
        m, k, n = shape
        rng = np.random.default_rng(hash((shape, kind, "pc")) % 2**32)
        w, sw = _stored(rng, k, n, kind)
        x = _x(rng, m, k)
        got = ops.fused_dequant_matmul(x, w, sw, kind=kind)
        want = ref.fused_dequant_mm_ref(x, w, sw, None, kind=kind)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   rtol=1e-5, atol=1e-5)

    @pytest.mark.parametrize("groups", [2, 4, 8])
    @pytest.mark.parametrize("kind", ALL_KINDS)
    def test_per_group_matches_ref(self, groups, kind):
        m, k, n = 13, 64, 19
        rng = np.random.default_rng(hash((groups, kind)) % 2**32)
        w, sw = _stored(rng, k, n, kind, groups=groups)
        assert sw.shape == (groups, n)
        x = _x(rng, m, k)
        got = ops.fused_dequant_matmul(x, w, sw, kind=kind)
        want = ref.fused_dequant_mm_ref(x, w, sw, None, kind=kind)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   rtol=1e-5, atol=1e-5)

    @pytest.mark.parametrize("act", ["qdq", "quant"])
    @pytest.mark.parametrize("kind", ["int8", "int4_packed", "fp8",
                                      "fp4_packed"])
    def test_act_epilogue_matches_ref(self, act, kind):
        m, k, n = 7, 32, 23
        rng = np.random.default_rng(hash((act, kind)) % 2**32)
        w, sw = _stored(rng, k, n, kind, groups=4)
        x = _x(rng, m, k)
        sa = jnp.float32(0.17)
        got = ops.fused_dequant_matmul(x, w, sw, sa, kind=kind, act=act)
        want = ref.fused_dequant_mm_ref(x, w, sw, sa, kind=kind, act=act)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   rtol=1e-5, atol=1e-5)

    def test_backends_agree(self):
        rng = np.random.default_rng(13)
        w, sw = _stored(rng, 64, 24, "fp8", groups=4)
        x = _x(rng, 10, 64)
        p = ops.fused_dequant_matmul(x, w, sw, kind="fp8",
                                     backend="pallas")
        r = ops.fused_dequant_matmul(x, w, sw, kind="fp8", backend="xla")
        np.testing.assert_allclose(np.asarray(p), np.asarray(r),
                                   rtol=1e-5, atol=1e-5)


# ------------------------------------------------------ executor variant

def _prep(mode, k=32, n=24, exact=False, group_size=None, act_scale=0.2,
          seed=0):
    rng = np.random.default_rng(seed)
    w = jnp.asarray(rng.normal(0, 1, (k, n)), jnp.float32)
    spec = PrecisionSpec(mode, exact=exact, group_size=group_size)
    return prepare_weight(w, spec, act_scale=act_scale), w, spec


class TestExecutorVariant:
    def test_variant_dispatch_and_fallback(self):
        base = mplinear.executor_for("int8")
        fused = mplinear.executor_for("int8", "fused")
        assert fused is not base
        # modes without the variant keep their base executor
        assert mplinear.executor_for("bf16", "fused") \
            is mplinear.executor_for("bf16")
        with pytest.raises(ValueError, match="no executor"):
            mplinear.executor_for("int12", "fused")

    def test_context_scopes_and_restores(self):
        assert mplinear._EXECUTOR_VARIANT is None
        with executor_variant("fused"):
            assert mplinear._EXECUTOR_VARIANT == "fused"
            with executor_variant(None):
                assert mplinear._EXECUTOR_VARIANT is None
            assert mplinear._EXECUTOR_VARIANT == "fused"
        assert mplinear._EXECUTOR_VARIANT is None

    @pytest.mark.parametrize("mode", ["int8", "int4"])
    def test_fused_exact_bit_exact_to_base(self, mode):
        """Per-channel exact int: the fused variant is bit-exact to the
        staged executor path on the same prepared container."""
        pw, _, spec = _prep(mode, exact=True)
        x = jnp.asarray(np.random.default_rng(1).normal(0, 1, (2, 5, 32)),
                        jnp.float32)
        y_base = mp_linear({"w": pw}, x, spec)
        with executor_variant("fused"):
            y_fused = mp_linear({"w": pw}, x, spec)
        np.testing.assert_array_equal(np.asarray(y_base),
                                      np.asarray(y_fused))

    @pytest.mark.parametrize("mode,exact", [("int8", False),
                                            ("int4", False),
                                            ("int8", True)])
    def test_fused_per_group_close_to_base(self, mode, exact):
        pw, _, spec = _prep(mode, k=64, exact=exact, group_size=16,
                            seed=2)
        assert pw.scale_groups == 4
        x = jnp.asarray(np.random.default_rng(3).normal(0, 1, (6, 64)),
                        jnp.float32)
        y_base = mp_linear({"w": pw}, x, spec)
        with executor_variant("fused"):
            y_fused = mp_linear({"w": pw}, x, spec)
        np.testing.assert_allclose(np.asarray(y_base, np.float32),
                                   np.asarray(y_fused, np.float32),
                                   rtol=0.05, atol=0.05)

    @pytest.mark.parametrize("mode", ["fp8", "fp4"])
    def test_fused_fp_close_to_base(self, mode):
        pw, _, spec = _prep(mode, k=64, group_size=16, act_scale=None,
                            seed=4)
        assert pw.kind in ("fp8", "fp4_packed")
        x = jnp.asarray(np.random.default_rng(5).normal(0, 1, (6, 64)),
                        jnp.float32)
        y_base = mp_linear({"w": pw}, x, spec)
        with executor_variant("fused"):
            y_fused = mp_linear({"w": pw}, x, spec)
        np.testing.assert_allclose(np.asarray(y_base, np.float32),
                                   np.asarray(y_fused, np.float32),
                                   rtol=0.05, atol=0.05)

    def test_unfusable_falls_back_to_base(self):
        """No calibrated act scale -> the int fused executor must produce
        the base executor's value exactly (it delegates)."""
        pw, _, spec = _prep("int8", exact=True, act_scale=None)
        assert pw.act_scale is None
        x = jnp.asarray(np.random.default_rng(6).normal(0, 1, (4, 32)),
                        jnp.float32)
        y_base = mp_linear({"w": pw}, x, spec)
        with executor_variant("fused"):
            y_fused = mp_linear({"w": pw}, x, spec)
        np.testing.assert_array_equal(np.asarray(y_base),
                                      np.asarray(y_fused))

    def test_fused_counts_no_dynamic_quant(self):
        """The fused datapath neither absmax-reduces activations nor
        re-quantizes weights — the serving counters stay zero."""
        pw, _, spec = _prep("int8", exact=True)
        x = jnp.asarray(np.random.default_rng(7).normal(0, 1, (4, 32)),
                        jnp.float32)
        with mplinear.count_weight_quant() as wq, \
                mplinear.count_act_quant() as aq, \
                executor_variant("fused"):
            mp_linear({"w": pw}, x, spec)
        assert wq[0] == 0 and aq[0] == 0


class TestPreparedStorageKinds:
    @pytest.mark.parametrize("mode,kind", [("fp8", "fp8"),
                                           ("fp4", "fp4_packed")])
    def test_fp_prepare_kinds(self, mode, kind):
        pw, w, spec = _prep(mode, act_scale=None)
        assert pw.kind == kind
        # dequant reproduces the codec's q*scale grid value
        fmt = FP8_E4M3 if mode == "fp8" else FP4_E2M1
        q, s = fp_quantize(w, fmt, axis=-2)
        from repro.quant.quantize import fp_dequantize
        np.testing.assert_array_equal(np.asarray(pw.dequant()),
                                      np.asarray(fp_dequantize(q, s, fmt)))

    def test_fp4_odd_k_falls_back_unpacked(self):
        pw = prepare_weight(jnp.ones((5, 4)), PrecisionSpec("fp4"))
        assert pw.kind == "fp4"

    def test_group_size_not_dividing_k_falls_back(self):
        pw, _, _ = _prep("int8", k=30, group_size=7)
        assert pw.scale_groups == 1

    def test_staged_kind_mapping(self):
        from repro.quant.prepare import _STAGED_KIND
        for kind in ALL_KINDS:
            assert _STAGED_KIND[kind].startswith("staged")
