"""Tests for the cycle-accurate simulator and the area/power model."""
import dataclasses

import numpy as np
import pytest

from repro.core import area_power as ap
from repro.core import simulator as sim
from repro.core import workloads as wl


class TestWorkloads:
    def test_resnet18_macs(self):
        # ResNet-18 @224: ~1.81 GMACs (conv+fc)
        macs = wl.total_macs(wl.resnet18())
        assert 1.6e9 < macs < 2.0e9, macs

    def test_resnet50_macs(self):
        # ResNet-50 @224: ~4.1 GMACs
        macs = wl.total_macs(wl.resnet50())
        assert 3.6e9 < macs < 4.4e9, macs

    def test_inception_macs(self):
        # InceptionV3 @299: ~5.7 GMACs
        macs = wl.total_macs(wl.inception_v3())
        assert 5.0e9 < macs < 6.4e9, macs

    def test_backward_doubles_work(self):
        fwd = wl.total_macs(wl.resnet18())
        bwd = wl.total_macs(wl.resnet18_backward())
        assert 1.7 * fwd < bwd < 2.1 * fwd


class TestSimulator:
    def test_int_mode_no_data_dependence(self):
        layer = wl.ConvLayer("x", 64, 64, 28, 28, 3, 3)
        s = sim.simulate_layer(layer, sim.BIG_TILE, sim.INT4)
        assert s.cycles == s.ideal_cycles
        # groups: ceil(64/16)*9 = 36; passes: ceil(64/16)*14*14 = 2744
        assert s.groups == 36
        assert s.iterations_per_group == 1

    def test_int8_iterations(self):
        layer = wl.ConvLayer("x", 64, 64, 28, 28, 3, 3)
        s4 = sim.simulate_layer(layer, sim.BIG_TILE, sim.INT4)
        s8 = sim.simulate_layer(layer, sim.BIG_TILE, sim.INT8)
        assert s8.cycles == pytest.approx(4 * s4.cycles)

    def test_baseline_fp16_single_cycle(self):
        layer = wl.ConvLayer("x", 64, 64, 28, 28, 3, 3)
        s = sim.simulate_layer(layer, sim.BASELINE2, sim.FP16)
        assert s.mc_factor == 1.0
        assert s.cycles == pytest.approx(9 * sim.simulate_layer(
            layer, sim.BASELINE2, sim.INT4).cycles)

    def test_narrow_adder_slower(self):
        layer = wl.ConvLayer("x", 256, 256, 14, 14, 3, 3)
        cycles = {}
        for w in (12, 16, 20, 28, 38):
            tile = dataclasses.replace(sim.BIG_TILE, adder_w=w)
            cycles[w] = sim.simulate_layer(layer, tile, sim.FP16,
                                           sim.BACKWARD_SOURCE).cycles
        assert cycles[12] > cycles[16] > cycles[20] >= cycles[28] >= cycles[38]

    def test_clustering_helps(self):
        layer = wl.ConvLayer("x", 256, 256, 14, 14, 3, 3)
        times = []
        for c in (16, 8, 4, 2, 1):
            tile = dataclasses.replace(sim.BIG_TILE, adder_w=16,
                                       cluster_size=c)
            times.append(sim.simulate_layer(
                layer, tile, sim.FP16, sim.BACKWARD_SOURCE).cycles)
        # smaller clusters monotonically (weakly) faster
        assert all(a >= b * 0.999 for a, b in zip(times, times[1:]))

    def test_skip_empty_partitions_helps(self):
        layer = wl.ConvLayer("x", 256, 256, 14, 14, 3, 3)
        base = dataclasses.replace(sim.BIG_TILE, adder_w=12)
        opt = dataclasses.replace(base, skip_empty_partitions=True)
        cb = sim.simulate_layer(layer, base, sim.FP16, sim.BACKWARD_SOURCE)
        co = sim.simulate_layer(layer, opt, sim.FP16, sim.BACKWARD_SOURCE)
        assert co.cycles <= cb.cycles

    def test_backward_wider_than_forward(self):
        """Fig. 9: backward exponent diffs are much wider; forward diffs
        exceed 8 for only ~1% of products."""
        hf = sim.exponent_diff_histogram(sim.FORWARD_SOURCE, samples=20000)
        hb = sim.exponent_diff_histogram(sim.BACKWARD_SOURCE, samples=20000)
        frac_fwd_gt8 = hf[9:].sum()
        frac_bwd_gt8 = hb[9:].sum()
        assert frac_fwd_gt8 < 0.05
        assert frac_bwd_gt8 > 4 * frac_fwd_gt8

    def test_fig8_trend_small_beats_big(self):
        """8-input MC-IPUs multi-cycle less often than 16-input (paper
        §4.3): normalized slowdown of the small tile <= big tile."""
        layers = wl.resnet18()[:6]
        small = dataclasses.replace(sim.SMALL_TILE, adder_w=16)
        big = dataclasses.replace(sim.BIG_TILE, adder_w=16)
        t_small = sim.normalized_exec_time(layers, small, sim.BASELINE1,
                                           source=sim.BACKWARD_SOURCE)
        t_big = sim.normalized_exec_time(layers, big, sim.BASELINE2,
                                         source=sim.BACKWARD_SOURCE)
        assert t_small <= t_big * 1.05

    def test_network_stats(self):
        st = sim.simulate_network(wl.resnet18()[:4], sim.BIG_TILE, sim.FP16)
        assert st.cycles >= st.ideal_cycles
        assert 1.0 <= st.slowdown < 4.0


class TestAreaPower:
    def test_table1_tolerance(self):
        model = ap.table1_model()
        errs = []
        for d, row in model.items():
            for wlk, (a, p) in row.items():
                pa, pp = ap.PAPER_TABLE1[d][wlk]
                if a is None:
                    assert pa is None
                    continue
                errs.append(abs(a / pa - 1))
                errs.append(abs(p / pp - 1))
        assert np.median(errs) < 0.10, np.median(errs)
        assert max(errs) < 0.30, max(errs)

    def test_fig7_deltas(self):
        d = ap.fig7_deltas()
        assert -0.25 < d["adder_38_to_28"] < -0.10  # paper: -17%
        assert -0.50 < d["adder_38_to_12"] < -0.30  # paper: up to -39%
        assert 0.30 < d["int_to_mcipu12"] < 0.60    # paper: +43%

    def test_headline_gains(self):
        h = ap.headline_gains(1.3)
        assert h["tops_per_mm2_gain"] > 0.35        # paper: up to +46%
        assert h["tops_per_w_gain"] > 0.50          # paper: up to +63%
        assert h["tflops_per_mm2_gain"] > 0.08      # paper: up to +25%
        assert h["tflops_per_w_gain"] > 0.20        # paper: up to +40%

    def test_breakdown_sums_to_one(self):
        for d in ap.paper_designs().values():
            assert sum(ap.area_breakdown(d).values()) == pytest.approx(1.0)
            assert sum(ap.power_breakdown(d).values()) == pytest.approx(1.0)

    def test_adder_tree_dominates_wide_designs(self):
        """38b adder trees are the overhead the paper attacks: AT+Shft
        share must shrink when w drops 38 -> 12."""
        wide = ap.IPUDesign("w", 4, 4, 38, True)
        narrow = ap.IPUDesign("n", 4, 4, 12, True)
        bw = ap.area_breakdown(wide)
        bn = ap.area_breakdown(narrow)
        assert bw["AT"] + bw["Shft"] > bn["AT"] + bn["Shft"]

    def test_int_only_cheaper(self):
        fp = ap.IPUDesign("fp", 4, 4, 12, True)
        nofp = ap.IPUDesign("int", 4, 4, 12, False)
        assert ap.tile_area_mm2(nofp) < ap.tile_area_mm2(fp)
        assert ap.tile_power_w(nofp) < ap.tile_power_w(fp)

    def test_throughput_accounting(self):
        d = ap.paper_designs()["MC-IPU4"]
        t44 = ap.throughput_tops(d, ap.WORKLOAD_TYPES["4x4"])
        t88 = ap.throughput_tops(d, ap.WORKLOAD_TYPES["8x8"])
        assert t44 == pytest.approx(4 * t88)
        # big-tile baseline: 4 TOPS INT4 (paper §4.1)
        base = ap.baseline_design(16)
        assert ap.throughput_tops(base, ap.WORKLOAD_TYPES["4x4"]) == (
            pytest.approx(4.0, rel=0.05))

    def test_int_unsupported_on_int_designs(self):
        d = ap.paper_designs()["INT8"]
        assert ap.throughput_tops(d, ap.WORKLOAD_TYPES["fp16"]) is None
