"""Autotune planner + policy-routing coverage.

Satellite contract: core.policy path matching (first-match-wins,
unmatched default, invalid-mode rejection), PrecisionPlan ->
PrecisionPolicy -> identical mplinear routing, and the planner's
cold/warm cache behavior with a non-trivial Pareto frontier.
"""
import dataclasses

import numpy as np
import pytest

from repro import exp
from repro.autotune import candidates as cand_mod
from repro.autotune import search as search_mod
from repro.autotune.cli import cmd_search, render_report, resolve_arch
from repro.autotune.plan import (PlanRule, PrecisionPlan, load_plan,
                                 load_policy)
from repro.configs import reduced
from repro.core.policy import (PrecisionPolicy, PrecisionSpec, get_policy,
                               trace_routing)
from repro.models.registry import projection_groups

ARCH = "qwen2-0.5b"


def _demo_plan() -> PrecisionPlan:
    """A hand-small plan over the real qwen2 projection groups."""
    groups = {g.name: g for g in projection_groups(reduced(ARCH))}
    return PrecisionPlan(
        name="demo_plan", arch=ARCH,
        rules=(
            PlanRule("attn_qkv", groups["attn_qkv"].pattern, "int8"),
            PlanRule("attn_wo", groups["attn_wo"].pattern, "fp16_ipu",
                     w=16, sw_precision=28),
            PlanRule("ffn_in", groups["ffn_in"].pattern, "int4"),
            PlanRule("ffn_out", groups["ffn_out"].pattern, "int8"),
            PlanRule("head", groups["head"].pattern, "bf16"),
        ),
        default_mode="bf16")


class TestPolicyMatching:
    def test_first_match_wins_ordering(self):
        spec8, spec4 = PrecisionSpec("int8"), PrecisionSpec("int4")
        broad_first = PrecisionPolicy(
            "t1", rules=((r"attn", spec8), (r"attn/wo$", spec4)))
        assert broad_first.spec_for("block/full/attn/wo").mode == "int8"
        narrow_first = PrecisionPolicy(
            "t2", rules=((r"attn/wo$", spec4), (r"attn", spec8)))
        assert narrow_first.spec_for("block/full/attn/wo").mode == "int4"
        assert narrow_first.spec_for("block/full/attn/wq").mode == "int8"

    def test_unmatched_path_gets_default(self):
        pol = PrecisionPolicy("t", rules=((r"attn", PrecisionSpec("int8")),),
                              default=PrecisionSpec("fp32"))
        assert pol.spec_for("some/novel/projection").mode == "fp32"

    def test_invalid_mode_rejected(self):
        with pytest.raises(ValueError):
            PrecisionSpec("int3")
        with pytest.raises(ValueError):
            PlanRule("g", "pat", "fp3")
        with pytest.raises(ValueError):
            PrecisionPlan(name="p", arch=ARCH, default_mode="int3")

    def test_invalid_mode_rejected_at_load(self, tmp_path):
        plan = _demo_plan()
        obj = plan.to_json()
        obj["rules"][0]["mode"] = "int3"
        import json
        path = tmp_path / "bad.json"
        path.write_text(json.dumps(obj))
        with pytest.raises(ValueError):
            load_plan(str(path))

    def test_schema_version_enforced(self, tmp_path):
        import json
        obj = _demo_plan().to_json()
        obj["schema"] = "precision-plan-v999"
        path = tmp_path / "future.json"
        path.write_text(json.dumps(obj))
        with pytest.raises(ValueError):
            load_plan(str(path))


class TestPlanRoundtrip:
    def test_json_roundtrip_identity(self):
        plan = _demo_plan()
        assert PrecisionPlan.from_json(plan.to_json()) == plan

    def test_plan_to_policy_routing(self, tmp_path):
        """PrecisionPlan -> saved JSON -> get_policy("plan:...") routes
        every projection path exactly like the in-memory policy."""
        plan = _demo_plan()
        path = str(tmp_path / "plan.json")
        plan.save(path)
        mem = plan.to_policy()
        disk = get_policy(f"plan:{path}")
        paths = [
            "block/full/attn/wq", "block/full/attn/wk",
            "block/full/attn/wv", "block/full/attn/wo",
            "block/mlp/w_gate", "block/mlp/w_up", "block/mlp/w_down",
            "lm_head", "unmatched/xyz",
        ]
        for p in paths:
            assert disk.spec_for(p) == mem.spec_for(p), p
        assert disk.spec_for("block/full/attn/wo").mode == "fp16_ipu"
        assert disk.spec_for("block/mlp/w_gate").mode == "int4"
        assert disk.spec_for("unmatched/xyz").mode == "bf16"

    def test_load_policy_caches_by_mtime(self, tmp_path):
        plan = _demo_plan()
        path = str(tmp_path / "plan.json")
        plan.save(path)
        assert load_policy(path) is load_policy(path)


class TestServeRouting:
    """The acceptance assertion: serving with --plan routes the decode
    loop's projections with the planned per-layer precisions."""

    def test_decode_routes_match_plan(self, tmp_path):
        import jax
        from repro.launch.serve import (EngineConfig, Request,
                                       ServingEngine)
        from repro.models import registry

        plan = _demo_plan()
        path = str(tmp_path / "plan.json")
        plan.save(path)
        cfg = dataclasses.replace(reduced(ARCH),
                                  precision_policy=f"plan:{path}")
        api = registry.build(cfg)
        params = api.init(jax.random.PRNGKey(0))
        engine = ServingEngine(cfg, api, params,
                               config=EngineConfig(batch_slots=2,
                                                   cache_len=32))
        routes = engine.routing_report()
        assert routes, "decode step routed no projections"
        policy = plan.to_policy()
        for p, mode in routes.items():
            assert mode == policy.spec_for(p).mode, p
        # the planned modes actually reach the datapaths
        assert routes["block/full/attn/wq"] == "int8"
        assert routes["block/full/attn/wo"] == "fp16_ipu"
        assert routes["block/mlp/w_gate"] == "int4"

        # and the decode loop runs under the plan end to end
        engine.submit(Request(rid=0, prompt=np.asarray([5, 7, 11],
                                                       np.int32),
                              max_new_tokens=2))
        engine.run_until_drained()
        done = engine.completed[0]
        assert len(done.tokens) == len(done.prompt) + 2


def _toy_setup(cache_dir):
    cfg = reduced(ARCH)
    groups = projection_groups(cfg)
    cands = cand_mod.default_candidates(
        widths=(12, 16), clusters=(1,),
        modes=("bf16", "fp16_ipu", "int8", "int4"))
    engine = exp.EngineConfig(cache=exp.ResultCache(str(cache_dir)))
    return groups, cands, engine


class TestSearch:
    def test_cold_then_warm_and_frontier(self, tmp_path):
        groups, cands, engine = _toy_setup(tmp_path / "cache")
        table = search_mod.build_scores(
            ARCH, groups, cands, engine, seq=1, seed=0, shapes="reduced",
            probe=False)
        assert engine.total.n_executed > 0
        plan = search_mod.search_plan(ARCH, table)
        assert len(plan.frontier) >= 3, "trivial Pareto frontier"

        warm = exp.EngineConfig(cache=exp.ResultCache(
            str(tmp_path / "cache")))
        table2 = search_mod.build_scores(
            ARCH, groups, cands, warm, seq=1, seed=0, shapes="reduced",
            probe=False)
        assert warm.total.n_executed == 0, "warm re-run re-evaluated"
        assert search_mod.search_plan(ARCH, table2).to_json() \
            == plan.to_json()

    def test_frontier_is_non_dominated(self, tmp_path):
        groups, cands, engine = _toy_setup(tmp_path / "cache")
        table = search_mod.build_scores(
            ARCH, groups, cands, engine, seq=1, seed=0, shapes="reduced",
            probe=False)
        plan = search_mod.search_plan(ARCH, table)
        front = list(plan.frontier)
        for a in front:
            for b in front:
                if a is b:
                    continue
                am, bm = a["metrics"], b["metrics"]
                dominated = (bm["cycles"] <= am["cycles"]
                             and bm["acc_proxy"] <= am["acc_proxy"]
                             and bm["tops_per_w"] >= am["tops_per_w"]
                             and (bm["cycles"] < am["cycles"]
                                  or bm["acc_proxy"] < am["acc_proxy"]
                                  or bm["tops_per_w"] > am["tops_per_w"]))
                assert not dominated, (a["name"], b["name"])

    def test_seed_is_part_of_cache_key(self):
        point = exp.SweepSpec(
            name="k", fn="repro.autotune.objectives:cycles_point",
            axes={"seed": [0]}, fixed={"arch": ARCH, "group": "attn_qkv",
                                       "mode": "int8", "w": 16,
                                       "sw_precision": 28, "cluster": 1,
                                       "seq": 1, "shapes": "reduced"})
        p0 = point.points()[0]
        p1 = dataclasses.replace(
            p0, params=tuple(("seed", 1) if k == "seed" else (k, v)
                             for k, v in p0.params))
        assert exp.point_key(p0, salt="s") != exp.point_key(p1, salt="s")

    def test_greedy_descent_monotone_cycles(self, tmp_path):
        groups, cands, engine = _toy_setup(tmp_path / "cache")
        table = search_mod.build_scores(
            ARCH, groups, cands, engine, seq=1, seed=0, shapes="reduced",
            probe=False)
        bf16 = next(c for c in cands if c.mode == "bf16")
        traj = search_mod.greedy_descent(
            table, {g.name: bf16 for g in groups})
        cycles = [search_mod.plan_metrics(table, a)["cycles"]
                  for a in traj]
        assert all(b < a for a, b in zip(cycles, cycles[1:]))
        assert len(traj) >= 2


class TestCLI:
    def test_search_cli_acceptance(self, tmp_path, capsys):
        """`search --model qwen2_0_5b` (alias form) emits a plan JSON
        with a non-trivial frontier that serves via --plan."""
        out = str(tmp_path / "plan.json")
        rc = cmd_search([
            "--model", "qwen2_0_5b", "--no-probe", "--shapes", "reduced",
            "--widths", "12", "16", "--cache-dir",
            str(tmp_path / "cache"), "--quiet-progress", "--out", out])
        assert rc == 0
        plan = load_plan(out)
        assert plan.arch == ARCH
        assert len(plan.frontier) >= 3
        policy = get_policy(f"plan:{out}")
        assert policy.rules
        report = render_report(plan)
        assert "Pareto frontier" in report and plan.name in report

    def test_resolve_arch_aliases(self):
        assert resolve_arch("qwen2-0.5b") == ARCH
        assert resolve_arch("qwen2_0_5b") == ARCH
        assert resolve_arch("QWEN2_0_5B") == ARCH
        with pytest.raises(SystemExit):
            resolve_arch("not-a-model")


class TestCommittedPlan:
    def test_demo_artifact_loads_and_serves(self):
        """The committed qwen2 demo plan stays a valid, non-trivial,
        executable artifact."""
        import os
        path = os.path.join(os.path.dirname(__file__), "..", "results",
                            "plans", "qwen2_0_5b.json")
        if not os.path.exists(path):
            pytest.skip("demo plan not present")
        plan = load_plan(path)
        assert plan.arch == ARCH
        assert len(plan.frontier) >= 3
        policy = get_policy(f"plan:{path}")
        assert policy.spec_for("block/full/attn/wq").mode \
            == plan.assignment()["attn_qkv"]


class TestRoutingTrace:
    def test_trace_restores_previous_state(self):
        pol = PrecisionPolicy("t", rules=(), default=PrecisionSpec("bf16"))
        with trace_routing() as outer:
            pol.spec_for("a")
            with trace_routing() as inner:
                pol.spec_for("b")
            pol.spec_for("c")
        assert [p for p, _ in outer] == ["a", "c"]
        assert [p for p, _ in inner] == ["b"]
        pol.spec_for("d")   # no active trace: must not record anywhere
        assert len(outer) == 2 and len(inner) == 1
