"""Block-FP compressed collectives (beyond-paper: the paper's alignment
insight applied to cross-pod gradient traffic)."""
import os
import subprocess
import sys

import numpy as np
import jax.numpy as jnp
import pytest

pytest.importorskip("hypothesis", reason="install the [dev] extra")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.parallel.blockfp import blockfp_dequantize, blockfp_quantize

_SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import sys; sys.path.insert(0, "src")
import jax, jax.numpy as jnp, numpy as np, re
from repro.parallel.blockfp import make_pod_exchange
from repro.launch.roofline import parse_collectives

mesh = jax.make_mesh((2, 2, 2), ("pod", "data", "model"))
rng = np.random.default_rng(0)
grads = {"wq": {"w": jnp.asarray(rng.normal(0, 1e-3, (2, 64, 64)),
                                 jnp.float32)},
         "embed": {"w": jnp.asarray(rng.normal(0, 1e-3, (2, 512, 64)),
                                    jnp.float32)}}
shapes = jax.tree.map(lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype),
                      grads)
ref = jax.tree.map(lambda g: jnp.broadcast_to(g.mean(0), g.shape), grads)
wire = {}
for method in ("f32", "int8", "blockfp8"):
    fn, in_sh, out_sh = make_pod_exchange(mesh, shapes, method)
    with mesh:
        out = fn(jax.device_put(grads, in_sh))
        txt = fn.lower(shapes).compile().as_text()
    err = max(float(jnp.abs(a - b).max() / jnp.abs(b).max())
              for a, b in zip(jax.tree_util.tree_leaves(out),
                              jax.tree_util.tree_leaves(ref)))
    wire[method] = parse_collectives(txt, 8).total_bytes
    assert err < {"f32": 1e-7, "int8": 0.02, "blockfp8": 0.05}[method], \
        (method, err)
assert wire["blockfp8"] <= wire["f32"] / 3.5, wire
assert wire["int8"] <= wire["f32"] / 3.5, wire
print("EXCHANGE_OK", wire)
"""


def test_pod_exchange_subprocess():
    out = subprocess.run([sys.executable, "-c", _SCRIPT],
                         capture_output=True, text=True, timeout=420,
                         cwd=os.path.join(os.path.dirname(__file__), ".."))
    assert out.returncode == 0, out.stdout[-1500:] + out.stderr[-1500:]
    assert "EXCHANGE_OK" in out.stdout


class TestBlockFPQuant:
    def test_roundtrip_error_bounded(self):
        rng = np.random.default_rng(1)
        x = jnp.asarray(rng.normal(0, 1, 5000), jnp.float32)
        m, e, n = blockfp_quantize(x, 8)
        y = blockfp_dequantize(m.astype(jnp.int32), e, n, 8, x.shape)
        # per-block error < 1 ULP of block scale = 2**(max_e - 6)
        blocks = np.asarray(x[: (5000 // 256) * 256]).reshape(-1, 256)
        scale = 2.0 ** (np.asarray(e[:len(blocks)], np.int32) - 6)
        err = np.abs(np.asarray(y)[: len(blocks) * 256].reshape(-1, 256)
                     - blocks)
        assert (err <= scale[:, None] * 1.0001).all()

    @given(st.integers(2, 8))
    @settings(max_examples=7, deadline=None)
    def test_width_sweep_monotone(self, w):
        rng = np.random.default_rng(w)
        x = jnp.asarray(rng.normal(0, 1, 2048), jnp.float32)
        m, e, n = blockfp_quantize(x, w)
        y = blockfp_dequantize(m.astype(jnp.int32), e, n, w, x.shape)
        err_w = float(jnp.abs(y - x).max())
        m2, e2, n2 = blockfp_quantize(x, min(w + 1, 8))
        y2 = blockfp_dequantize(m2.astype(jnp.int32), e2, n2,
                                min(w + 1, 8), x.shape)
        assert float(jnp.abs(y2 - x).max()) <= err_w * 1.0001

    def test_exact_on_powers_of_two(self):
        x = jnp.asarray([1.0, 0.5, 2.0, -1.0] * 64, jnp.float32)
        m, e, n = blockfp_quantize(x, 8)
        y = blockfp_dequantize(m.astype(jnp.int32), e, n, 8, x.shape)
        np.testing.assert_array_equal(np.asarray(y), np.asarray(x))
