"""Pallas kernel tests: interpret-mode kernels vs pure-jnp oracles.

Integer and emulation kernels are exact — assertions are array_equal
(bitwise), not allclose. Shapes sweep non-aligned sizes to exercise the
padding paths.
"""
import numpy as np
import pytest
import jax.numpy as jnp

pytestmark = [pytest.mark.kernel, pytest.mark.slow]

from repro.core.ipu import IPUConfig
from repro.core import exact_ref
from repro.kernels import ops, ref
from repro.kernels.mpmm import mp_matmul
from repro.kernels.qmm import qmm, qmm_packed


def _rand_int(rng, shape, bits):
    return rng.integers(-(1 << (bits - 1)), 1 << (bits - 1),
                        shape).astype(np.int8)


def _rand_f16(rng, shape, dist="normal"):
    if dist == "wide":
        x = rng.normal(0, 1, shape) * np.exp2(rng.integers(-10, 12, shape))
    else:
        x = rng.normal(0, 1, shape)
    x = np.asarray(x, np.float16)
    x[~np.isfinite(x)] = 0
    return x


SHAPES = [(8, 16, 8), (16, 32, 128), (33, 70, 17), (128, 256, 128),
          (1, 16, 1), (130, 50, 257)]


class TestQMM:
    @pytest.mark.parametrize("shape", SHAPES)
    @pytest.mark.parametrize("bits", [4, 8])
    def test_matches_ref(self, shape, bits):
        m, k, n = shape
        rng = np.random.default_rng(hash((shape, bits)) % 2**32)
        a = _rand_int(rng, (m, k), bits)
        b = _rand_int(rng, (k, n), bits)
        got = qmm(jnp.asarray(a), jnp.asarray(b), bm=16, bn=16, bk=16)
        want = ref.qmm_ref(jnp.asarray(a), jnp.asarray(b))
        np.testing.assert_array_equal(np.asarray(got), np.asarray(want))

    @pytest.mark.parametrize("shape", [(8, 16, 8), (16, 32, 24),
                                       (33, 64, 17)])
    def test_packed_matches_ref(self, shape):
        m, k, n = shape
        rng = np.random.default_rng(3)
        a = _rand_int(rng, (m, k), 8)
        w = _rand_int(rng, (k, n), 4)
        packed = ops.pack_int4(jnp.asarray(w))
        assert packed.shape == (k // 2, n)
        # pack/unpack roundtrip
        np.testing.assert_array_equal(
            np.asarray(ops.unpack_int4(packed)), w)
        got = qmm_packed(jnp.asarray(a), packed, bm=16, bn=16, bk=16)
        want = ref.qmm_ref(jnp.asarray(a), jnp.asarray(w))
        np.testing.assert_array_equal(np.asarray(got), np.asarray(want))

    def test_ops_wrapper_backends_agree(self):
        rng = np.random.default_rng(5)
        a = _rand_int(rng, (24, 48), 8)
        b = _rand_int(rng, (48, 40), 8)
        p = ops.int8_matmul(jnp.asarray(a), jnp.asarray(b), backend="pallas")
        x = ops.int8_matmul(jnp.asarray(a), jnp.asarray(b), backend="xla")
        np.testing.assert_array_equal(np.asarray(p), np.asarray(x))

    def test_quantized_matmul_scales(self):
        rng = np.random.default_rng(6)
        a = _rand_int(rng, (8, 32), 8)
        b = _rand_int(rng, (32, 12), 8)
        sa = np.abs(rng.normal(1, 0.1, 8)).astype(np.float32)
        sb = np.abs(rng.normal(1, 0.1, 12)).astype(np.float32)
        got = ops.quantized_matmul(jnp.asarray(a), jnp.asarray(b),
                                   jnp.asarray(sa), jnp.asarray(sb))
        want = (a.astype(np.int64) @ b.astype(np.int64)).astype(np.float64) \
            * sa[:, None] * sb[None, :]
        np.testing.assert_allclose(np.asarray(got, np.float64), want,
                                   rtol=1e-6)


MP_CFGS = [
    IPUConfig(n=16, w=16, accum="fp32"),
    IPUConfig(n=16, w=28, accum="fp32"),
    IPUConfig(n=8, w=12, accum="fp16"),
]


class TestMPMM:
    @pytest.mark.parametrize("cfg", MP_CFGS,
                             ids=lambda c: f"n{c.n}w{c.w}{c.accum}")
    @pytest.mark.parametrize("shape", [(8, 16, 8), (16, 48, 24), (5, 33, 7)])
    @pytest.mark.parametrize("dist", ["normal", "wide"])
    def test_faithful_kernel_matches_core(self, cfg, shape, dist):
        m, k, n = shape
        rng = np.random.default_rng(hash((shape, cfg.w, dist)) % 2**32)
        a = _rand_f16(rng, (m, k), dist)
        b = _rand_f16(rng, (k, n), dist)
        got = mp_matmul(jnp.asarray(a), jnp.asarray(b), cfg, bm=8, bn=8)
        want = ref.mp_matmul_ref(jnp.asarray(a), jnp.asarray(b), cfg)
        np.testing.assert_array_equal(np.asarray(got), np.asarray(want))

    @pytest.mark.parametrize("cfg", MP_CFGS,
                             ids=lambda c: f"n{c.n}w{c.w}{c.accum}")
    def test_fused_kernel_matches_fused_ref(self, cfg):
        rng = np.random.default_rng(9)
        a = _rand_f16(rng, (16, 32), "wide")
        b = _rand_f16(rng, (32, 24), "wide")
        got = mp_matmul(jnp.asarray(a), jnp.asarray(b), cfg, bm=8, bn=8,
                        fused=True)
        want = ref.mp_matmul_fused_ref(jnp.asarray(a), jnp.asarray(b), cfg)
        np.testing.assert_array_equal(np.asarray(got), np.asarray(want))

    def test_xla_backend_faithful_bitexact(self):
        cfg = IPUConfig(n=16, w=16)
        rng = np.random.default_rng(11)
        a = _rand_f16(rng, (12, 40))
        b = _rand_f16(rng, (40, 9))
        x = ops.mp_matmul(jnp.asarray(a), jnp.asarray(b), cfg, backend="xla")
        p = ops.mp_matmul(jnp.asarray(a), jnp.asarray(b), cfg,
                          backend="pallas")
        np.testing.assert_array_equal(np.asarray(x), np.asarray(p))

    def test_against_python_oracle_single_output(self):
        """One output element of the kernel == the Python-int oracle."""
        cfg = IPUConfig(n=16, w=16, accum="fp32")
        rng = np.random.default_rng(13)
        a = _rand_f16(rng, (3, 32), "wide")
        b = _rand_f16(rng, (32, 2), "wide")
        got = np.asarray(mp_matmul(jnp.asarray(a), jnp.asarray(b), cfg,
                                   bm=8, bn=8))
        for i in range(3):
            for j in range(2):
                want = exact_ref.approx_fp_ip(a[i], b[:, j], cfg)
                assert np.float64(got[i, j]) == np.float64(want)

    def test_fused_more_accurate_than_faithful(self):
        """The fused datapath truncates once instead of nine times, so its
        aggregate error vs the exact dot must not be worse."""
        cfg = IPUConfig(n=16, w=16, accum="fp32")
        rng = np.random.default_rng(17)
        a = _rand_f16(rng, (16, 64), "wide")
        b = _rand_f16(rng, (64, 16), "wide")
        exact = (np.asarray(a, np.float64) @ np.asarray(b, np.float64))
        faithful = np.asarray(ops.mp_matmul(jnp.asarray(a), jnp.asarray(b),
                                            cfg, backend="xla"), np.float64)
        fused = np.asarray(ops.mp_matmul(jnp.asarray(a), jnp.asarray(b),
                                         cfg, fused=True, backend="xla"),
                           np.float64)
        assert np.abs(fused - exact).sum() <= np.abs(faithful - exact).sum() \
            * 1.05

    def test_fp16_accum_dtype(self):
        cfg = IPUConfig(n=8, w=12, accum="fp16")
        rng = np.random.default_rng(19)
        a = _rand_f16(rng, (4, 16))
        b = _rand_f16(rng, (16, 4))
        out = mp_matmul(jnp.asarray(a), jnp.asarray(b), cfg, bm=8, bn=8)
        assert out.dtype == jnp.float16
