"""Unit + property tests for the bit-exact IPU numerics core.

The key claim: ``repro.core.ipu`` (vectorized JAX int32 emulation) agrees
bit-for-bit with ``repro.core.exact_ref`` (independent Python-int oracle)
for every IPU configuration, and the measured approximation error obeys
the Theorem-1-style bounds.
"""
import math
from fractions import Fraction

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.core import exact_ref, error_bounds, fixedpoint as fx, fp16 as fpmod
from repro.core import ehu, nibble
from repro.core.ipu import IPUConfig, fp16_inner_product, int_inner_product

# ---------------------------------------------------------------- helpers

def rand_fp16(rng, n, scale=1.0, dist="normal"):
    if dist == "normal":
        x = rng.normal(0, scale, n)
    elif dist == "laplace":
        x = rng.laplace(0, scale, n)
    elif dist == "uniform":
        x = rng.uniform(-scale, scale, n)
    elif dist == "wide":
        x = rng.normal(0, 1, n) * np.exp2(rng.integers(-12, 14, n))
    else:
        raise ValueError(dist)
    x = np.asarray(x, np.float16)
    x[~np.isfinite(x)] = 0.0
    return x


finite_f16 = st.integers(min_value=0, max_value=0xFFFF).map(
    lambda b: np.uint16(b).view(np.float16)
).filter(lambda v: np.isfinite(v))


# ------------------------------------------------------------- fp16 codec

class TestCodec:
    def test_roundtrip_all_finite_fp16(self):
        bits = np.arange(1 << 16, dtype=np.uint16)
        x = bits.view(np.float16)
        finite = np.isfinite(x)
        x = jnp.asarray(x[finite])
        s, e, m = fpmod.decompose(x, fpmod.FP16)
        # value identity
        val = np.asarray(s, np.float64) * np.asarray(m, np.float64) * np.exp2(
            np.asarray(e, np.float64) - 10)
        np.testing.assert_array_equal(val, np.asarray(x, np.float64))
        # bit roundtrip (sign of -0 is dropped: compare values)
        back = fpmod.compose(s, e, m, fpmod.FP16)
        np.testing.assert_array_equal(np.asarray(back, np.float64),
                                      np.asarray(x, np.float64))

    def test_fp32_decompose_values(self):
        rng = np.random.default_rng(0)
        x = jnp.asarray(rng.normal(0, 1e3, 256), jnp.float32)
        s, e, m = fpmod.decompose(x, fpmod.FP32)
        val = np.asarray(s, np.float64) * np.asarray(m, np.float64) * np.exp2(
            np.asarray(e, np.float64) - 23)
        np.testing.assert_array_equal(val, np.asarray(x, np.float64))

    def test_product_exponent_range(self):
        assert fpmod.product_exponent_range(fpmod.FP16) == (-28, 30)
        assert fpmod.max_alignment(fpmod.FP16) == 58  # paper §2.2

    def test_make_inf(self):
        out = fpmod.make_inf(jnp.asarray([1, -1]), fpmod.FP16)
        assert np.isposinf(np.asarray(out[0], np.float64))
        assert np.isneginf(np.asarray(out[1], np.float64))


# ------------------------------------------------------------ fixedpoint

class TestFixedPoint:
    @given(st.integers(-(2**47), 2**47), st.integers(-(2**47), 2**47))
    @settings(max_examples=200, deadline=None)
    def test_add(self, a, b):
        if abs(a + b) >= 2**53:
            return
        fa = fx.canon(jnp.int32(a // 2**24), jnp.int32(a % 2**24))
        fb = fx.canon(jnp.int32(b // 2**24), jnp.int32(b % 2**24))
        r = fx.add(fa, fb)
        assert int(r.hi) * 2**24 + int(r.lo) == a + b

    @given(st.integers(-(2**47), 2**47), st.integers(0, 60))
    @settings(max_examples=200, deadline=None)
    def test_shr_trunc(self, a, s):
        fa = fx.canon(jnp.int32(a // 2**24), jnp.int32(a % 2**24))
        r = fx.shr_trunc(fa, jnp.int32(s))
        expect = (abs(a) >> s) * (1 if a >= 0 else -1)
        assert int(r.hi) * 2**24 + int(r.lo) == expect

    @given(st.integers(-(2**47), 2**47), st.integers(0, 60))
    @settings(max_examples=200, deadline=None)
    def test_shr_floor(self, a, s):
        fa = fx.canon(jnp.int32(a // 2**24), jnp.int32(a % 2**24))
        r = fx.shr_floor(fa, jnp.int32(s))
        assert int(r.hi) * 2**24 + int(r.lo) == a >> s

    @given(st.integers(0, 2**30), st.integers(0, 21))
    @settings(max_examples=200, deadline=None)
    def test_shl(self, a, s):
        fa = fx.canon(jnp.int32(a // 2**24), jnp.int32(a % 2**24))
        r = fx.shl(fa, s)
        assert int(r.hi) * 2**24 + int(r.lo) == a << s

    @given(st.integers(-(2**46), 2**46), st.integers(-40, 20))
    @settings(max_examples=300, deadline=None)
    def test_round_to_fp(self, mag_signed, exp):
        """round_to_fp == python-int RNE oracle for fp16 and fp32."""
        v = fx.canon(jnp.int32(mag_signed // 2**24),
                     jnp.int32(mag_signed % 2**24))
        e = jnp.int32(exp)
        for fmt_name, fmt in (("fp16", fpmod.FP16), ("fp32", fpmod.FP32)):
            got = fx.round_to_fp(v, e, fmt)
            sign = -1 if mag_signed < 0 else 1
            want = exact_ref.round_value_to_fp(sign, abs(mag_signed),
                                               exp - 30, fmt_name)
            g = np.asarray(got, np.float64)
            w = np.float64(want)
            assert (g == w) or (np.isnan(g) and np.isnan(w)), (
                f"{fmt_name}: mag={mag_signed} exp={exp}: {g} != {w}")


# --------------------------------------------------------------- nibbles

class TestNibble:
    def test_fp16_plane_identity(self):
        bits = np.arange(1 << 16, dtype=np.uint16)
        x = bits.view(np.float16)
        x = jnp.asarray(x[np.isfinite(x)])
        s, e, m = fpmod.decompose(x, fpmod.FP16)
        n0, n1, n2 = nibble.fp16_planes(s, m)
        recon = (np.asarray(n2, np.float64) * 2.0**7
                 + np.asarray(n1, np.float64) * 2.0**3
                 + np.asarray(n0, np.float64) * 0.5)
        np.testing.assert_array_equal(
            recon, np.asarray(s, np.float64) * np.asarray(m, np.float64))

    @pytest.mark.parametrize("bits", [4, 8, 12])
    def test_int_plane_identity(self, bits):
        lo, hi = -(1 << (bits - 1)), (1 << (bits - 1)) - 1
        x = jnp.arange(lo, hi + 1, dtype=jnp.int32)
        planes = nibble.int_planes(x, bits)
        recon = sum(np.asarray(p, np.int64) * 16**i
                    for i, p in enumerate(planes))
        np.testing.assert_array_equal(recon, np.asarray(x, np.int64))
        for i, p in enumerate(planes):
            p = np.asarray(p)
            if i < len(planes) - 1:
                assert p.min() >= 0 and p.max() <= 15  # unsigned low nibble
            else:
                assert p.min() >= -8 and p.max() <= 7  # signed top nibble

    def test_iteration_counts(self):
        assert nibble.num_nibble_iterations(8, 12) == 6  # paper §2.1 example
        assert nibble.num_nibble_iterations(12, 12) == 9  # FP16 mantissas


# ------------------------------------------------------------------ EHU

class TestEHU:
    def test_run_and_mask(self):
        ea = jnp.asarray([[0, 5, -3, 2]])
        eb = jnp.asarray([[0, 5, -3, 2]])
        out = ehu.run(ea, eb, sw_precision=8)
        assert int(out.max_exp[0]) == 10
        np.testing.assert_array_equal(np.asarray(out.shift[0]),
                                      [10, 0, 16, 6])
        np.testing.assert_array_equal(np.asarray(out.active[0]),
                                      [False, True, False, True])

    def test_walkthrough_fig4(self):
        """Paper Fig. 4: exponents (10,2,3,8), sp=5 -> 2 cycles; A,D in
        cycle 0 with local shifts (0,2); B,C in cycle 1 with (3,2)."""
        shift = jnp.asarray([0, 8, 7, 2])
        active = jnp.ones(4, bool)
        cycles = ehu.num_cycles(shift, active, sp=5)
        assert int(cycles) == 2
        cyc, local = ehu.service_schedule(shift, active, sp=5)
        np.testing.assert_array_equal(np.asarray(cyc), [0, 1, 1, 0])
        np.testing.assert_array_equal(np.asarray(local), [0, 3, 2, 2])

    def test_skip_empty(self):
        shift = jnp.asarray([0, 40])
        active = jnp.ones(2, bool)
        assert int(ehu.num_cycles(shift, active, sp=5)) == 9  # 40//5 + 1
        assert int(ehu.num_cycles(shift, active, sp=5, skip_empty=True)) == 2


# ------------------------------------------------------ INT-mode exactness

class TestIntMode:
    @pytest.mark.parametrize("a_bits,b_bits", [(4, 4), (8, 4), (8, 8),
                                               (8, 12), (12, 12)])
    def test_matches_integer_dot(self, a_bits, b_bits):
        rng = np.random.default_rng(1)
        a = rng.integers(-(1 << (a_bits - 1)), 1 << (a_bits - 1),
                         (16, 64)).astype(np.int32)
        b = rng.integers(-(1 << (b_bits - 1)), 1 << (b_bits - 1),
                         (16, 64)).astype(np.int32)
        got = int_inner_product(jnp.asarray(a), jnp.asarray(b),
                                a_bits, b_bits)
        want = (a.astype(np.int64) * b.astype(np.int64)).sum(-1)
        np.testing.assert_array_equal(np.asarray(got, np.int64), want)

    def test_unsigned_low_nibbles_ok(self):
        # extremes: most negative * most positive
        a = jnp.asarray([[-128, 127, -128]], jnp.int32)
        b = jnp.asarray([[127, -128, -128]], jnp.int32)
        got = int_inner_product(a, b, 8, 8)
        assert int(got[0]) == -128 * 127 * 2 + 128 * 128


# ----------------------------------------------- FP-IP vs python oracle

CONFIGS = [
    IPUConfig(n=16, w=16, accum="fp16"),
    IPUConfig(n=16, w=16, accum="fp32"),
    IPUConfig(n=16, w=28, accum="fp32"),
    IPUConfig(n=8, w=12, accum="fp32"),
    IPUConfig(n=8, w=12, accum="fp32", multi_cycle=True),
    IPUConfig(n=16, w=16, accum="fp32", multi_cycle=True),
    IPUConfig(n=16, w=12, accum="fp16", multi_cycle=True),
    IPUConfig(n=16, w=16, accum="fp32", rounding="floor"),
    IPUConfig(n=16, w=20, accum="fp32", iter_order="desc"),
]


@pytest.mark.parametrize("cfg", CONFIGS, ids=lambda c: (
    f"n{c.n}w{c.w}{c.accum}{'mc' if c.multi_cycle else ''}"
    f"{c.rounding[:2]}{c.iter_order[:1]}"))
@pytest.mark.parametrize("dist", ["normal", "wide"])
def test_fp_ip_matches_oracle(cfg, dist):
    rng = np.random.default_rng(hash((cfg.w, cfg.n, dist)) % 2**32)
    for length in (5, 33):
        a = rand_fp16(rng, length, dist=dist)
        b = rand_fp16(rng, length, dist=dist)
        got = np.asarray(fp16_inner_product(jnp.asarray(a), jnp.asarray(b),
                                            cfg))
        want = exact_ref.approx_fp_ip(a, b, cfg)
        assert got.dtype == np.dtype(np.float16 if cfg.accum == "fp16"
                                     else np.float32)
        g, w = np.float64(got), np.float64(want)
        assert (g == w) or (np.isnan(g) and np.isnan(w)), (
            f"len={length}: jax={g} oracle={w}")


def test_fp_ip_batched_matches_loop():
    rng = np.random.default_rng(7)
    cfg = IPUConfig(n=16, w=16, accum="fp32")
    a = rand_fp16(rng, 4 * 3 * 40).reshape(4, 3, 40)
    b = rand_fp16(rng, 4 * 3 * 40).reshape(4, 3, 40)
    got = np.asarray(fp16_inner_product(jnp.asarray(a), jnp.asarray(b), cfg))
    assert got.shape == (4, 3)
    for i in range(4):
        for j in range(3):
            want = exact_ref.approx_fp_ip(a[i, j], b[i, j], cfg)
            assert np.float64(got[i, j]) == np.float64(want)


def test_fp_ip_jit_and_vmap():
    cfg = IPUConfig(n=16, w=16)
    f = jax.jit(lambda a, b: fp16_inner_product(a, b, cfg))
    rng = np.random.default_rng(3)
    a = jnp.asarray(rand_fp16(rng, 8 * 32).reshape(8, 32))
    b = jnp.asarray(rand_fp16(rng, 8 * 32).reshape(8, 32))
    direct = fp16_inner_product(a, b, cfg)
    np.testing.assert_array_equal(np.asarray(f(a, b)), np.asarray(direct))
    vm = jax.vmap(lambda x, y: fp16_inner_product(x, y, cfg))(a, b)
    np.testing.assert_array_equal(np.asarray(vm), np.asarray(direct))


# --------------------------------------------------- accuracy properties

def test_high_precision_is_exactish():
    """At w=28/fp32 accumulation the paper reports CPU-level accuracy; the
    result must match the f64 dot to fp32 within 1 ulp-ish."""
    rng = np.random.default_rng(11)
    cfg = IPUConfig(n=16, w=28, accum="fp32", sw_precision=28)
    for _ in range(20):
        a = rand_fp16(rng, 64)
        b = rand_fp16(rng, 64)
        got = np.float64(np.asarray(
            fp16_inner_product(jnp.asarray(a), jnp.asarray(b), cfg)))
        want = float(exact_ref.exact_dot(a, b))
        if want == 0:
            assert abs(got) < 1e-6
        else:
            assert abs(got - want) <= 2e-6 * abs(want) + 1e-12


def test_mc_ipu_at_least_as_accurate_as_plain():
    """MC-IPU(w) with software precision P serves alignments exactly within
    each band, so its error must not exceed plain IPU(w) truncation error
    (statistically; we assert on aggregate)."""
    rng = np.random.default_rng(13)
    plain_err = mc_err = 0.0
    for _ in range(30):
        a = rand_fp16(rng, 32, dist="wide")
        b = rand_fp16(rng, 32, dist="wide")
        exact = float(exact_ref.exact_dot(a, b))
        plain = np.float64(np.asarray(fp16_inner_product(
            jnp.asarray(a), jnp.asarray(b),
            IPUConfig(n=16, w=12, accum="fp32"))))
        mc = np.float64(np.asarray(fp16_inner_product(
            jnp.asarray(a), jnp.asarray(b),
            IPUConfig(n=16, w=12, accum="fp32", multi_cycle=True))))
        plain_err += abs(plain - exact)
        mc_err += abs(mc - exact)
    assert mc_err <= plain_err + 1e-9


@given(st.lists(finite_f16, min_size=2, max_size=16),
       st.lists(finite_f16, min_size=2, max_size=16),
       st.sampled_from([12, 16, 20, 28]))
@settings(max_examples=80, deadline=None)
def test_theorem1_tight_bound_property(xs, ys, w):
    """Measured |approx - exact| <= sum of tight iteration bounds plus
    accumulator-granularity slack, for adversarial (hypothesis) inputs."""
    n = min(len(xs), len(ys))
    if n == 0:
        return
    # Pad to a fixed length so each w compiles exactly once (zeros only
    # lower exponents below max and contribute nothing).
    a = np.zeros(16, np.float16)
    b = np.zeros(16, np.float16)
    a[:n] = xs[:n]
    b[:n] = ys[:n]
    if not (np.isfinite(a).all() and np.isfinite(b).all()):
        return
    n = 16
    cfg = IPUConfig(n=16, w=w, accum="fp32", sw_precision=w)
    got = Fraction(np.float64(np.asarray(
        fp16_inner_product(jnp.asarray(a), jnp.asarray(b), cfg))))
    exact = exact_ref.exact_dot(a, b)
    prods = [exact_ref.decompose_fp16(x)[1] + exact_ref.decompose_fp16(y)[1]
             for x, y in zip(a, b)]
    max_exp = max(prods)
    # 9 iterations truncate in the tree; every acc update can truncate one
    # more ULP at 2**(max-30); 9 updates + final rounding half-ulp slack.
    bound = error_bounds.fp_ip_bound(w, max_exp, n,
                                     constant=error_bounds.TIGHT_CONSTANT,
                                     acc_granularity_updates=16)
    # final output rounding to fp32: half ULP of the result
    out_ulp = Fraction(2) ** (max_exp + 10 - 23)
    assert abs(got - exact) <= bound + out_ulp, (
        f"err={float(abs(got - exact))} bound={float(bound)}")


# ------------------------------------------------------- BF16 (Appendix B)

class TestBF16Operands:
    """Paper Appendix B: BF16 via an 8-bit-exponent EHU and four nibble
    iterations (2 planes x 2 planes)."""

    @pytest.mark.parametrize("w", [12, 16, 28])
    @pytest.mark.parametrize("dist", ["normal", "wide"])
    def test_matches_oracle(self, w, dist):
        cfg = IPUConfig(n=16, w=w, accum="fp32", operand="bf16")
        rng = np.random.default_rng(hash((w, dist)) % 2**32)
        for length in (5, 33):
            raw = rand_fp16(rng, length, dist=dist).astype(np.float32)
            a = np.asarray(jnp.asarray(raw, jnp.bfloat16))
            raw = rand_fp16(rng, length, dist=dist).astype(np.float32)
            b = np.asarray(jnp.asarray(raw, jnp.bfloat16))
            got = np.asarray(fp16_inner_product(jnp.asarray(a),
                                                jnp.asarray(b), cfg),
                             np.float32)
            want = exact_ref.approx_fp_ip(a.astype(np.float32),
                                          b.astype(np.float32), cfg)
            assert np.float64(got) == np.float64(want), (length, got, want)

    def test_iteration_count(self):
        cfg = IPUConfig(operand="bf16")
        assert len(cfg.iteration_pairs()) == 4  # paper: "four iterations"
        assert cfg.num_planes == 2

    def test_high_precision_accurate(self):
        cfg = IPUConfig(n=16, w=28, accum="fp32", operand="bf16",
                        sw_precision=28)
        rng = np.random.default_rng(5)
        raw = rng.normal(0, 1, 64).astype(np.float32)
        a = np.asarray(jnp.asarray(raw, jnp.bfloat16))
        b = np.asarray(jnp.asarray(rng.normal(0, 1, 64).astype(np.float32),
                                   jnp.bfloat16))
        got = np.float64(np.asarray(fp16_inner_product(
            jnp.asarray(a), jnp.asarray(b), cfg)))
        want = float(exact_ref.exact_dot(a.astype(np.float32),
                                         b.astype(np.float32),
                                         operand="bf16"))
        assert abs(got - want) <= 2e-6 * abs(want) + 1e-10

    def test_bf16_plane_identity(self):
        mag = jnp.arange(256, dtype=jnp.int32)
        sign = jnp.where(mag % 3 == 0, -1, 1)
        n0, n1 = nibble.bf16_planes(sign, mag)
        recon = np.asarray(n1, np.int64) * 16 + np.asarray(n0, np.int64)
        np.testing.assert_array_equal(
            recon, np.asarray(sign * mag, np.int64))


class TestTF32Operands:
    """TF32 (paper Appendix B): FP16's 11-bit magnitude planes on an
    8-bit-exponent EHU; f32 inputs RNE-rounded to TF32."""

    @pytest.mark.parametrize("w", [12, 16, 28])
    def test_matches_oracle(self, w):
        cfg = IPUConfig(n=16, w=w, accum="fp32", operand="tf32")
        rng = np.random.default_rng(w)
        for length in (5, 33):
            a = (rng.normal(0, 1, length)
                 * np.exp2(rng.integers(-20, 20, length))).astype(np.float32)
            b = (rng.normal(0, 1, length)
                 * np.exp2(rng.integers(-20, 20, length))).astype(np.float32)
            got = np.asarray(fp16_inner_product(jnp.asarray(a),
                                                jnp.asarray(b), cfg),
                             np.float32)
            want = exact_ref.approx_fp_ip(a, b, cfg)
            assert np.float64(got) == np.float64(want), (length, got, want)

    def test_high_precision_accurate(self):
        cfg = IPUConfig(n=16, w=28, accum="fp32", operand="tf32",
                        sw_precision=28)
        rng = np.random.default_rng(3)
        a = rng.normal(0, 1, 64).astype(np.float32)
        b = rng.normal(0, 1, 64).astype(np.float32)
        got = np.float64(np.asarray(fp16_inner_product(
            jnp.asarray(a), jnp.asarray(b), cfg)))
        want = float(exact_ref.exact_dot(a, b, operand="tf32"))
        assert abs(got - want) <= 2e-6 * abs(want) + 1e-10

    def test_nine_iterations(self):
        cfg = IPUConfig(operand="tf32")
        assert len(cfg.iteration_pairs()) == 9
        assert cfg.num_planes == 3
