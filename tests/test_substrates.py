"""Data pipeline, optimizer, checkpointing, fault tolerance."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import CheckpointManager, latest_step, save_checkpoint
from repro.checkpoint.checkpoint import restore_checkpoint
from repro.data.pipeline import DataConfig, SyntheticLMDataset
from repro.optim import (AdamWConfig, adamw_init, adamw_update,
                         warmup_cosine)
from repro.optim.loss_scale import (grads_finite, loss_scale_init,
                                    loss_scale_update)
from repro.runtime.fault_tolerance import (FTConfig, FaultTolerantLoop,
                                           StragglerMonitor, WorkerFailure)


class TestData:
    def test_deterministic_across_restarts(self):
        cfg = DataConfig(vocab=256, seq_len=32, global_batch=8, seed=3)
        a = SyntheticLMDataset(cfg).batch(5)["tokens"]
        b = SyntheticLMDataset(cfg).batch(5)["tokens"]
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))

    def test_host_sharding_partitions_batch(self):
        cfg = DataConfig(vocab=256, seq_len=16, global_batch=8, seed=0)
        h0 = SyntheticLMDataset(cfg, 0, 2).batch(0)["tokens"]
        h1 = SyntheticLMDataset(cfg, 1, 2).batch(0)["tokens"]
        assert h0.shape == (4, 17) and h1.shape == (4, 17)
        assert not np.array_equal(np.asarray(h0), np.asarray(h1))

    def test_markov_structure_learnable(self):
        """Next token is always one of the 16 successors of the current."""
        cfg = DataConfig(vocab=128, seq_len=64, global_batch=4, seed=1)
        ds = SyntheticLMDataset(cfg)
        from repro.data.pipeline import _transition_table
        table = _transition_table(cfg)
        toks = np.asarray(ds.batch(0)["tokens"])
        for row in toks:
            for t in range(len(row) - 1):
                assert row[t + 1] in table[row[t]]


class TestOptim:
    def test_adamw_converges_quadratic(self):
        params = {"w": jnp.asarray([4.0, -3.0])}
        state = adamw_init(params)
        cfg = AdamWConfig(lr=0.1, weight_decay=0.0, grad_clip=None)
        for _ in range(200):
            grads = jax.grad(lambda p: jnp.sum(p["w"] ** 2))(params)
            params, state, _ = adamw_update(cfg, params, grads, state)
        assert float(jnp.abs(params["w"]).max()) < 0.05

    def test_grad_clip(self):
        cfg = AdamWConfig(grad_clip=1.0)
        params = {"w": jnp.ones(4)}
        state = adamw_init(params)
        grads = {"w": jnp.full(4, 100.0)}
        _, _, m = adamw_update(cfg, params, grads, state)
        assert m["grad_norm"] > 100

    def test_schedule(self):
        assert float(warmup_cosine(0, warmup=10, total=100)) == 0.0
        assert float(warmup_cosine(10, warmup=10, total=100)) == \
            pytest.approx(1.0)
        assert float(warmup_cosine(100, warmup=10, total=100)) == \
            pytest.approx(0.1)

    def test_loss_scale_dynamics(self):
        st = loss_scale_init(1024.0)
        st = loss_scale_update(st, jnp.asarray(False))
        assert float(st.scale) == 512.0
        for _ in range(2000):
            st = loss_scale_update(st, jnp.asarray(True))
        assert float(st.scale) > 512.0

    def test_grads_finite(self):
        assert bool(grads_finite({"a": jnp.ones(3)}))
        assert not bool(grads_finite({"a": jnp.asarray([1.0, jnp.nan])}))


class TestCheckpoint:
    def test_roundtrip(self, tmp_path):
        tree = {"a": jnp.arange(6).reshape(2, 3).astype(jnp.float32),
                "b": {"c": jnp.ones(4, jnp.bfloat16)}}
        save_checkpoint(str(tmp_path), 7, tree, {"note": "x"})
        out, meta = restore_checkpoint(str(tmp_path), 7, tree)
        np.testing.assert_array_equal(np.asarray(out["a"]),
                                      np.asarray(tree["a"]))
        assert out["b"]["c"].dtype == jnp.bfloat16
        assert meta["note"] == "x"

    def test_atomicity_no_partial(self, tmp_path):
        # a .tmp dir left behind must not be listed as a checkpoint
        os.makedirs(tmp_path / "step_000000099.tmp")
        assert latest_step(str(tmp_path)) is None

    def test_keep_k(self, tmp_path):
        mgr = CheckpointManager(str(tmp_path), keep=2)
        tree = {"x": jnp.zeros(2)}
        for s in (1, 2, 3, 4):
            mgr.save(s, tree)
        from repro.checkpoint.checkpoint import list_steps
        assert list_steps(str(tmp_path)) == [3, 4]


class TestFaultTolerance:
    def _loop(self, tmp_path, failure_hook=None):
        def step_fn(state, batch):
            return {"w": state["w"] + batch["tokens"].sum()}, \
                {"loss": 0.0}

        def batch_fn(step):
            return {"tokens": jnp.full((2,), step, jnp.int32)}

        return FaultTolerantLoop(
            step_fn, batch_fn, str(tmp_path),
            FTConfig(checkpoint_every=5, max_restarts=3),
            failure_hook=failure_hook)

    def test_runs_to_completion(self, tmp_path):
        loop = self._loop(tmp_path)
        state, step = loop.run({"w": jnp.zeros(())}, 0, 12)
        assert step == 12
        # sum over steps s of 2*s
        assert float(state["w"]) == sum(2 * s for s in range(12))

    def test_recovers_from_failure(self, tmp_path):
        fired = {"done": False}

        def fail_once(step):
            if step == 7 and not fired["done"]:
                fired["done"] = True
                raise WorkerFailure("injected preemption")

        loop = self._loop(tmp_path, fail_once)
        state, step = loop.run({"w": jnp.zeros(())}, 0, 12)
        assert step == 12
        assert loop.restarts == 1
        # deterministic replay: same final state as the clean run
        assert float(state["w"]) == sum(2 * s for s in range(12))

    def test_gives_up_after_max_restarts(self, tmp_path):
        def always_fail(step):
            raise WorkerFailure("dead node")

        loop = self._loop(tmp_path, always_fail)
        with pytest.raises(WorkerFailure):
            loop.run({"w": jnp.zeros(())}, 0, 5)

    def test_straggler_monitor(self):
        mon = StragglerMonitor(FTConfig(deadline_factor=3.0))
        for i in range(20):
            assert not mon.observe(i, 1.0)
        assert mon.observe(20, 10.0)
        assert mon.flagged == [20]
