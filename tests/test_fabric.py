"""Serving-fabric unit contracts (``repro.fabric``).

The wire layer (typed messages, framing, endpoint pairs), the config
round trips behind serve-ready checkpoints, the scheduler's
failure-recovery requeue, and the controller's kill → requeue →
re-admit loop — the latter over deterministic jax-free fake engines so
the control-plane logic is tested at unit speed. The real-model
end-to-end (restore bit-exactness, identical streams through real
engines, CI contract) lives in ``python -m repro.fabric smoke`` and
TestEngineCheckpoint below.
"""
import dataclasses

import msgpack
import numpy as np
import pytest

from repro.configs.base import ModelConfig, MoESpec
from repro.fabric import transport as tp
from repro.fabric.chaos import ChaosEndpoint, FaultSchedule, fail_at
from repro.fabric.checkpoint import (engine_config_from_dict,
                                     engine_config_to_dict,
                                     model_config_from_dict,
                                     model_config_to_dict)
from repro.fabric.controller import (Controller, FabricError, FleetBusy,
                                     LocalWorkerDriver, ManualClock,
                                     reattach_local_worker)
from repro.fabric.worker import FabricWorker
from repro.obs import ReplicaStats
from repro.runtime.fault_tolerance import WorkerFailure
from repro.serving.config import EngineConfig, SamplingParams
from repro.serving.engine import Request
from repro.serving.scheduler import AdmissionScheduler, SchedulerFull


# ---------------------------------------------------------------- wire

class TestWireProtocol:
    MESSAGES = [
        tp.Hello(name="w0", policy="int4_serving", slots=4,
                 model_config={"d_model": 64, "rec_pattern": []},
                 cost_correction="online", resumable=True),
        tp.SubmitRequest(rid=7, prompt=[1, 2, 3], max_new_tokens=8,
                         priority=2, tags=["accuracy"],
                         temperature=0.7, top_k=5, top_p=0.9,
                         stop_ids=[11], seed=42),
        tp.TokenChunk(rid=7, tokens=[4, 5], done=True,
                      finish_reason="stop", truncated=True, start=3),
        tp.StatsSnapshot(name="w0", stats={"tok_per_s": 3.5},
                         slots=4, completed=9),
        tp.Heartbeat(tick=12, time=3.25),
        tp.Register(name="fresh", need_checkpoint=True),
        tp.RegisterAck(ckpt_dir="/shared/ckpt", step=7),
        tp.Resume(name="w0", progress={3: 5, 9: 0}),
        tp.ResumeAck(progress={3: 4}, cancel=[9]),
        tp.Drain(), tp.Drained(completed=3), tp.Shutdown(),
    ]

    @pytest.mark.parametrize("msg", MESSAGES,
                             ids=lambda m: type(m).__name__)
    def test_codec_roundtrip(self, msg):
        assert tp.decode_message(tp.encode_message(msg)) == msg

    def test_unknown_type_rejected(self):
        with pytest.raises(ValueError, match="unknown fabric message"):
            tp.decode_message(msgpack.packb({"t": "Nope", "f": {}}))
        with pytest.raises(TypeError):
            tp.encode_message({"not": "a message"})

    def test_framing_survives_arbitrary_chunking(self):
        payloads = [tp.encode_message(m) for m in self.MESSAGES]
        stream = b"".join(tp.pack_frame(p) for p in payloads)
        for chunk in (1, 3, len(stream)):      # byte-by-byte .. all-at-once
            dec = tp.FrameDecoder()
            frames = []
            for i in range(0, len(stream), chunk):
                frames.extend(dec.feed(stream[i:i + chunk]))
            assert frames == payloads

    def test_local_pair_is_a_framed_wire(self):
        a, b = tp.local_pair()
        a.send(tp.Heartbeat(tick=1, time=0.0))
        a.send(tp.Drain())
        assert b.poll() == [tp.Heartbeat(tick=1, time=0.0), tp.Drain()]
        assert b.poll() == []
        b.send(tp.Drained())
        assert a.poll() == [tp.Drained()]
        # closing either side closes both (a dead TCP peer, in memory)
        b.close()
        assert a.closed and b.closed
        with pytest.raises(tp.TransportClosed):
            a.send(tp.Shutdown())

    def test_hostile_frames_raise_typed_errors(self):
        # corrupt msgpack payload
        with pytest.raises(tp.ProtocolError, match="malformed"):
            tp.decode_message(b"\xc1\xff\x00garbage")
        # valid msgpack, but not the typed envelope
        with pytest.raises(tp.ProtocolError, match="envelope"):
            tp.decode_message(msgpack.packb([1, 2, 3]))
        # envelope whose fields are not a map
        with pytest.raises(tp.ProtocolError, match="not a map"):
            tp.decode_message(msgpack.packb({"t": "Drain", "f": [1]}))
        # right type, wrong fields
        with pytest.raises(tp.ProtocolError, match="bad Heartbeat"):
            tp.decode_message(msgpack.packb(
                {"t": "Heartbeat", "f": {"warp": 9}}))
        # every ProtocolError is a ValueError: containment code that
        # predates the subclass keeps working
        assert issubclass(tp.ProtocolError, ValueError)

    def test_oversized_frames_rejected_both_directions(self):
        with pytest.raises(tp.FrameTooLarge):
            tp.pack_frame(b"\x00" * (tp.MAX_FRAME + 1))
        dec = tp.FrameDecoder()
        # a hostile header announcing an absurd payload is refused at
        # the 4-byte mark — no buffering of unbounded garbage
        import struct
        with pytest.raises(tp.FrameTooLarge):
            dec.feed(struct.pack(">I", tp.MAX_FRAME + 1))

    def test_truncated_stream_is_visible_not_fatal(self):
        dec = tp.FrameDecoder()
        frame = tp.pack_frame(tp.encode_message(tp.Drain()))
        assert dec.feed(frame[:5]) == []
        assert dec.pending_bytes == 5          # mid-frame truncation
        assert dec.feed(frame[5:]) == [tp.encode_message(tp.Drain())]
        assert dec.pending_bytes == 0

    def test_backoff_is_seeded_and_bounded(self):
        a = tp.backoff_delays(8, seed=3)
        b = tp.backoff_delays(8, seed=3)
        c = tp.backoff_delays(8, seed=4)
        assert a == b and a != c               # pure function of seed
        assert all(0 < d <= 5.0 for d in a)
        # exponential envelope: undelayed upper bounds double
        assert all(d <= 0.1 * (2.0 ** k) for k, d in enumerate(a))

    def test_connect_with_retry_exhausts_into_typed_error(self):
        lst = tp.Listener()
        host, port = lst.host, lst.port
        lst.close()                            # nobody home
        slept = []
        with pytest.raises(tp.TransportClosed, match="after 3 attempts"):
            tp.connect_with_retry(host, port, attempts=3,
                                  sleep=slept.append)
        assert slept == tp.backoff_delays(3)[:len(slept)]

    def test_socket_endpoints_roundtrip(self):
        listener = tp.Listener()
        client = tp.connect(listener.host, listener.port)
        server = listener.accept(timeout=10.0)
        listener.close()
        try:
            client.send(tp.Hello(name="w", policy="bf16", slots=1))
            for _ in range(100):
                got = server.poll()
                if got:
                    break
            assert got == [tp.Hello(name="w", policy="bf16", slots=1)]
            server.send(tp.Shutdown())
            for _ in range(100):
                back = client.poll()
                if back:
                    break
            assert back == [tp.Shutdown()]
        finally:
            client.close()
            server.close()


# ------------------------------------------------------- config codecs

def _tiny_cfg(policy="bf16", **kw) -> ModelConfig:
    return ModelConfig(arch_id="tiny", family="lm", n_layers=1,
                       d_model=32, n_heads=2, n_kv_heads=2, d_ff=64,
                       vocab=128, precision_policy=policy, **kw)


class TestConfigRoundTrip:
    def test_model_config_through_the_wire(self):
        cfg = _tiny_cfg(moe=MoESpec(n_experts=4, top_k=2, d_expert=16),
                        rec_pattern=("rec", "rec", "attn"))
        wire = msgpack.unpackb(msgpack.packb(model_config_to_dict(cfg)))
        back = model_config_from_dict(wire)
        assert back == cfg                    # tuples/MoESpec restored
        assert isinstance(back.rec_pattern, tuple)
        assert isinstance(back.moe, MoESpec)

    def test_model_config_unknown_field_rejected(self):
        d = model_config_to_dict(_tiny_cfg())
        d["from_the_future"] = 1
        with pytest.raises(ValueError, match="unknown fields"):
            model_config_from_dict(d)

    def test_engine_config_reinjects_act_scales(self):
        config = EngineConfig(batch_slots=2, cache_len=64,
                              act_calibration="auto",
                              cost_correction="online")
        wire = msgpack.unpackb(msgpack.packb(
            engine_config_to_dict(config)))
        assert "act_calibration" not in wire  # never serialized
        scales = {"block/mlp/w_up": 0.25}
        back = engine_config_from_dict(wire, scales)
        # restore swaps 'auto' (a calibration PASS) for the resolved
        # scales dict (zero-work) and keeps every other knob
        assert back.act_calibration == scales
        assert dataclasses.replace(back, act_calibration="auto") == config

    def test_engine_config_unknown_field_rejected(self):
        with pytest.raises(ValueError, match="unknown fields"):
            engine_config_from_dict({"warp_drive": True}, None)


# --------------------------------------------------- scheduler requeue

class TestSchedulerRequeue:
    def test_requeue_jumps_the_line_and_bypasses_the_bound(self):
        sched = AdmissionScheduler(max_queue=2)
        a, b = Request(rid=1, prompt=np.arange(3)), \
            Request(rid=2, prompt=np.arange(3))
        sched.submit(a, now=0.0)
        sched.submit(b, now=0.0)
        with pytest.raises(SchedulerFull):
            sched.submit(Request(rid=3, prompt=np.arange(3)), now=0.0)
        # recovery re-entries are admitted work: never bounced, placed
        # ahead of every waiting submit of the same priority class
        r1 = Request(rid=10, prompt=np.arange(3), submit_time=0.0)
        r2 = Request(rid=11, prompt=np.arange(3), submit_time=0.0)
        sched.requeue(r1)
        sched.requeue(r2)
        assert sched.requeued == 2 and len(sched) == 4
        picked = sched.select(4, now=1.0)
        assert [r.rid for r in picked] == [10, 11, 1, 2]

    def test_requeue_preserves_submit_time_for_promotion(self):
        sched = AdmissionScheduler(max_wait=5.0)
        old = Request(rid=1, prompt=np.arange(3), priority=9,
                      submit_time=0.0)
        sched.requeue(old)
        fresh = Request(rid=2, prompt=np.arange(3), priority=0)
        sched.submit(fresh, now=6.0)
        # the requeued request kept its original submission clock: it
        # is already past max_wait and outranks the priority-0 arrival
        assert [r.rid for r in sched.select(1, now=6.0)] == [1]


# ------------------------------------------------ fake-engine fleet

class FakeEngine:
    """Deterministic jax-free stand-in for ServingEngine: one token per
    slot per step, value ``(rid * 31 + position) % 97`` — placement-
    and batch-independent by construction, like greedy decode."""

    def __init__(self, cfg, config, clock):
        self.cfg = cfg
        self.config = config
        self.b = config.batch_slots
        self.clock = clock
        self.stats = ReplicaStats()
        self.scheduler = AdmissionScheduler()
        self.slot_req = [None] * self.b
        self.completed = {}

    def submit(self, req):
        self.scheduler.submit(req, now=self.clock())

    def has_pending(self):
        return len(self.scheduler) > 0 \
            or any(r is not None for r in self.slot_req)

    def step(self):
        now = self.clock()
        free = [s for s, r in enumerate(self.slot_req) if r is None]
        for req in self.scheduler.select(len(free), now):
            self.slot_req[free.pop(0)] = req
            req.tokens = [int(t) for t in req.prompt]
        new = 0
        for s, req in enumerate(self.slot_req):
            if req is None:
                continue
            req.tokens.append((req.rid * 31 + len(req.tokens)) % 97)
            new += 1
            if len(req.tokens) - len(req.prompt) >= req.budget:
                req.done = True
                req.finish_reason = "length"
                self.completed[req.rid] = req
                self.slot_req[s] = None
        self.stats.on_tick(now, new, len(self.scheduler),
                           active_slots=sum(r is not None
                                            for r in self.slot_req))


def _expected_stream(req) -> list:
    start = len(req.prompt)
    return [int(t) for t in req.prompt] + [
        (req.rid * 31 + start + j) % 97 for j in range(req.budget)]


def _spawn_fake(ctrl, name, clock, *, slots=2, failure_hook=None):
    cfg = _tiny_cfg()
    engine = FakeEngine(cfg, EngineConfig(batch_slots=slots,
                                          cost_correction="online"),
                        clock)
    ctrl_ep, worker_ep = tp.local_pair()
    worker = FabricWorker(name, engine, worker_ep, clock=clock,
                          failure_hook=failure_hook)
    worker.announce()
    return ctrl.add_worker(ctrl_ep, driver=LocalWorkerDriver(worker),
                           name=name)


def _requests(n, max_new=6, seed=0):
    rng = np.random.default_rng(seed)
    return [Request(rid=rid,
                    prompt=rng.integers(0, 97, int(rng.integers(2, 6)),
                                        dtype=np.int32),
                    max_new_tokens=max_new)
            for rid in range(n)]


def _run(n_requests, *, kill_tick=None, heartbeat_timeout=3.0,
         max_new=6):
    clock = ManualClock()
    ctrl = Controller(heartbeat_timeout=heartbeat_timeout, clock=clock)

    def die(tick):
        if kill_tick is not None and tick == kill_tick:
            raise WorkerFailure("injected")

    _spawn_fake(ctrl, "worker-a", clock)
    _spawn_fake(ctrl, "worker-b", clock, failure_hook=die)
    reqs = _requests(n_requests, max_new=max_new)
    for r in reqs:
        ctrl.submit(r)
    ctrl.run_until_drained(advance=lambda: clock.advance(1.0))
    return ctrl, reqs


class TestControllerFleet:
    def test_fleet_completes_with_exact_streams(self):
        ctrl, reqs = _run(6)
        assert sorted(ctrl.completed) == [r.rid for r in reqs]
        for req in reqs:
            assert req.done and req.tokens == _expected_stream(req)
        # routing ran over TRANSPORTED stats, not in-process objects
        report = ctrl.routing_report()
        assert report["cost_correction"] == "online"
        for name, rep in report["replicas"].items():
            assert rep["measured"]["transported"], name
        routed = ctrl.routing_counters()
        assert sum(routed.values()) == 6 and all(
            v > 0 for v in routed.values()), routed

    def test_kill_mid_flight_loses_nothing(self):
        ref, _ = _run(8, max_new=8)
        ref_streams = {rid: list(r.tokens)
                       for rid, r in ref.completed.items()}
        ctrl, reqs = _run(8, kill_tick=2, max_new=8)
        assert ctrl.failures == ["worker-b"]
        assert ctrl.scheduler.requeued > 0
        assert sorted(ctrl.completed) == sorted(ref_streams)
        for rid, req in ctrl.completed.items():
            assert req.tokens == ref_streams[rid], f"rid {rid} diverged"
        alive = [h.name for h in ctrl.workers.values() if h.alive]
        assert alive == ["worker-a"]

    def test_closed_endpoint_detected_without_heartbeat_wait(self):
        clock = ManualClock()
        ctrl = Controller(heartbeat_timeout=1e9, clock=clock)
        _spawn_fake(ctrl, "worker-a", clock)
        hb = _spawn_fake(ctrl, "worker-b", clock)
        reqs = _requests(4)
        for r in reqs:
            ctrl.submit(r)
        ctrl.tick()
        hb.endpoint.close()     # process death: socket EOF, no timeout
        ctrl.run_until_drained(advance=lambda: clock.advance(1.0))
        assert ctrl.failures == ["worker-b"]
        assert sorted(ctrl.completed) == [r.rid for r in reqs]

    def test_last_worker_death_is_a_fleet_error(self):
        clock = ManualClock()
        ctrl = Controller(heartbeat_timeout=2.0, clock=clock)

        def die(tick):
            if tick == 1:
                raise WorkerFailure("injected")

        _spawn_fake(ctrl, "only", clock, failure_hook=die)
        for r in _requests(3):
            ctrl.submit(r)
        with pytest.raises(FabricError, match="no alive workers"):
            ctrl.run_until_drained(advance=lambda: clock.advance(1.0))

    def test_worker_drain_and_shutdown(self):
        clock = ManualClock()
        engine = FakeEngine(_tiny_cfg(), EngineConfig(batch_slots=2),
                            clock)
        ctrl_ep, worker_ep = tp.local_pair()
        worker = FabricWorker("w", engine, worker_ep, clock=clock)
        req = _requests(1)[0]
        sp = req.sampling
        ctrl_ep.send(tp.SubmitRequest(
            rid=req.rid, prompt=[int(t) for t in req.prompt],
            max_new_tokens=req.budget, temperature=sp.temperature,
            top_k=sp.top_k, top_p=sp.top_p))
        ctrl_ep.send(tp.Drain())
        for _ in range(32):
            clock.advance(1.0)
            worker.tick()
        msgs = ctrl_ep.poll()
        drained = [m for m in msgs if isinstance(m, tp.Drained)]
        assert len(drained) == 1 and drained[0].completed == 1
        final = [m for m in msgs if isinstance(m, tp.TokenChunk)
                 and m.done]
        assert len(final) == 1
        ctrl_ep.send(tp.Shutdown())
        assert worker.tick() is False


# ---------------------------------------------------- chaos endpoint

class TestChaosEndpoint:
    def _pair(self, schedule, t0=0.0):
        clock = ManualClock(t0)
        ctrl_side, worker_side = tp.local_pair()
        return clock, ctrl_side, ChaosEndpoint(worker_side, schedule,
                                               clock)

    def test_deterministic_delivery_trace(self):
        def run():
            sched = FaultSchedule(seed=5, drop_rate=0.5,
                                  duplicate_every=3, partial_every=4)
            clock, ctrl_side, ep = self._pair(sched)
            got = []
            for i in range(40):
                clock.advance(1.0)
                ep.send(tp.Heartbeat(tick=i, time=clock.t))
                ep.send(tp.TokenChunk(rid=1, tokens=[i], start=i))
                got.extend(ctrl_side.poll())
            got.extend(ctrl_side.poll())
            return list(ep.log), got
        assert run() == run()          # same seed -> bit-identical run

    def test_drop_only_touches_droppable_types(self):
        sched = FaultSchedule(seed=0, drop_rate=1.0)   # drop EVERYTHING
        clock, ctrl_side, ep = self._pair(sched)
        for i in range(10):
            ep.send(tp.Heartbeat(tick=i, time=0.0))
            ep.send(tp.TokenChunk(rid=1, tokens=[i], start=i))
        got = ctrl_side.poll()
        # every heartbeat gone, every data-plane chunk intact: TCP
        # does not drop individual frames, so the data plane may only
        # fail by severance (reset_at_msg), never silent frame loss
        assert [m for m in got if isinstance(m, tp.Heartbeat)] == []
        chunks = [m for m in got if isinstance(m, tp.TokenChunk)]
        assert [c.tokens[0] for c in chunks] == list(range(10))

    def test_partial_write_reassembles_across_polls(self):
        sched = FaultSchedule(seed=0, partial_every=1)  # split all
        clock, ctrl_side, ep = self._pair(sched)
        ep.send(tp.Heartbeat(tick=1, time=0.0))
        assert ctrl_side.poll() == []          # only the head arrived
        ep.send(tp.Heartbeat(tick=2, time=0.0))   # flushes held tail
        got = ctrl_side.poll()
        assert tp.Heartbeat(tick=1, time=0.0) in got

    def test_delay_holds_until_clock_matures(self):
        sched = FaultSchedule(seed=0, delay_msgs=((0, 5.0),))
        clock, ctrl_side, ep = self._pair(sched)
        ep.send(tp.Heartbeat(tick=1, time=0.0))
        assert ctrl_side.poll() == []
        clock.advance(4.0)
        ep.send(tp.Drain())                    # flush: not matured yet
        assert [type(m).__name__ for m in ctrl_side.poll()] == ["Drain"]
        clock.advance(2.0)
        ep.send(tp.Drain())                    # now past the deadline
        assert tp.Heartbeat(tick=1, time=0.0) in ctrl_side.poll()

    def test_reset_severs_and_leaks_a_truncated_frame(self):
        sched = FaultSchedule(seed=0, reset_at_msg=2)
        clock, ctrl_side, ep = self._pair(sched)
        ep.send(tp.Heartbeat(tick=1, time=0.0))
        ep.send(tp.Heartbeat(tick=2, time=0.0))
        with pytest.raises(tp.TransportClosed, match="reset"):
            ep.send(tp.Heartbeat(tick=3, time=0.0))
        assert ep.tripped and ep.closed and ctrl_side.closed
        got = ctrl_side.poll()                 # pre-reset frames drain
        assert len(got) == 2
        with pytest.raises(tp.TransportClosed):
            ep.send(tp.Drain())

    def test_schedule_validation(self):
        with pytest.raises(ValueError, match="drop_rate"):
            FaultSchedule(drop_rate=1.5)
        with pytest.raises(ValueError, match="duplicate_every"):
            FaultSchedule(duplicate_every=-1)
        assert fail_at(None) is None
        hook = fail_at(3)
        hook(2)
        with pytest.raises(WorkerFailure):
            hook(3)


# ------------------------------------------- suspect/resume liveness

def _spawn_resumable(ctrl, name, clock, *, slots=2):
    cfg = _tiny_cfg()
    engine = FakeEngine(cfg, EngineConfig(batch_slots=slots,
                                          cost_correction="online"),
                        clock)
    ctrl_ep, worker_ep = tp.local_pair()
    worker = FabricWorker(name, engine, worker_ep, clock=clock,
                          resumable=True)
    worker.announce()
    handle = ctrl.add_worker(ctrl_ep, driver=LocalWorkerDriver(worker),
                             name=name)
    return worker, handle


class TestSuspectResume:
    def _fleet(self, **ctrl_kw):
        clock = ManualClock()
        ctrl = Controller(heartbeat_timeout=4.0, clock=clock, **ctrl_kw)
        _spawn_fake(ctrl, "worker-a", clock)
        worker_b, handle_b = _spawn_resumable(ctrl, "worker-b", clock)
        return clock, ctrl, worker_b, handle_b

    def test_transient_partition_resumes_in_place(self):
        ref_ctrl, ref_reqs = _run(8, max_new=8)
        ref = {r.rid: list(r.tokens) for r in ref_reqs}

        clock, ctrl, worker_b, hb = self._fleet()
        reqs = _requests(8, max_new=8)
        for r in reqs:
            ctrl.submit(r)
        # let work land on both workers, then sever worker-b's link
        for _ in range(2):
            clock.advance(1.0)
            ctrl.tick()
        assert hb.replica.in_flight, "worker-b got no work"
        held = dict(hb.replica.in_flight)
        worker_b.endpoint.close()
        clock.advance(1.0)
        ctrl.tick()
        assert hb.state == "suspect"
        # suspicion HOLDS in-flight work (no requeue) and stops new
        # routing, the suspect's requests stay owned by it
        assert ctrl.scheduler.requeued == 0
        assert dict(hb.replica.in_flight) == held
        # heal: fresh pair, worker dials back in with Resume
        reattach_local_worker(ctrl, worker_b)
        ctrl.run_until_drained(advance=lambda: clock.advance(1.0))
        assert ctrl.scheduler.requeued == 0    # resume path, not requeue
        assert ctrl.resumed == 1
        assert ctrl.failures == []
        assert hb.state == "alive"
        assert worker_b.reconnects == 1
        assert sorted(ctrl.completed) == sorted(ref)
        for rid, req in ctrl.completed.items():
            assert req.tokens == ref[rid], f"rid {rid} diverged"

    def test_grace_expiry_requeues_and_late_resume_rejoins_empty(self):
        clock, ctrl, worker_b, hb = self._fleet(resume_grace=2.0)
        reqs = _requests(6, max_new=8)
        for r in reqs:
            ctrl.submit(r)
        for _ in range(2):
            clock.advance(1.0)
            ctrl.tick()
        assert hb.replica.in_flight
        worker_b.endpoint.close()
        # stay gone past the grace: the controller gives up holding
        for _ in range(4):
            clock.advance(1.0)
            ctrl.tick()
        assert hb.state == "dead"
        assert ctrl.failures == ["worker-b"]
        assert ctrl.scheduler.requeued > 0
        # the worker finally comes back: everything it held was
        # rerouted, so the ResumeAck cancels it all and it rejoins
        # as an empty-handed alive worker
        reattach_local_worker(ctrl, worker_b)
        ctrl.run_until_drained(advance=lambda: clock.advance(1.0))
        assert hb.state == "alive" and ctrl.resumed == 1
        # the cancel wiped its pre-death ledger; anything live now is
        # post-resume work it finished and retains for resume safety
        assert all(req.done for req, _ in worker_b._live.values())
        assert sorted(ctrl.completed) == [r.rid for r in reqs]
        for req in reqs:
            assert req.tokens == _expected_stream(req)

    def test_duplicated_chunks_never_duplicate_tokens(self):
        clock = ManualClock()
        ctrl = Controller(heartbeat_timeout=4.0, clock=clock)
        handle = _spawn_fake(ctrl, "w", clock)
        req = _requests(1, max_new=4)[0]
        ctrl.submit(req)
        for _ in range(32):
            if req.done:
                break
            clock.advance(1.0)
            ctrl.tick()
            if req.tokens is None or req.done:
                continue
            gen = [int(t) for t in req.tokens[len(req.prompt):]]
            # faithful duplicate of everything streamed so far: the
            # start offset trims it to nothing
            ctrl._on_tokens(handle, tp.TokenChunk(
                rid=req.rid, tokens=gen, start=0))
            # chunk from the future (its predecessor was lost): the
            # gap means it must be ignored outright
            ctrl._on_tokens(handle, tp.TokenChunk(
                rid=req.rid, tokens=[99], start=len(gen) + 5))
        assert req.done and req.tokens == _expected_stream(req)

    def test_suspect_worker_gets_no_new_work(self):
        clock, ctrl, worker_b, hb = self._fleet()
        for r in _requests(2, max_new=30):
            ctrl.submit(r)
        clock.advance(1.0)
        ctrl.tick()
        worker_b.endpoint.close()
        clock.advance(1.0)
        ctrl.tick()
        assert hb.state == "suspect"
        routed_before = hb.replica.routed
        for r in _requests(4, max_new=4, seed=9)[2:]:
            r.rid += 100
            ctrl.submit(r)
        for _ in range(3):
            clock.advance(0.1)             # stay inside the grace
            ctrl.tick()
        assert hb.replica.routed == routed_before


# --------------------------------------------- graceful degradation

class TestDegradation:
    def test_shed_factor_raises_retriable_fleet_busy(self):
        clock = ManualClock()
        ctrl = Controller(heartbeat_timeout=4.0, clock=clock,
                          shed_factor=1.0)
        _spawn_fake(ctrl, "w", clock, slots=2)   # capacity 2, limit 2
        reqs = _requests(5, max_new=4)
        ctrl.submit(reqs[0])
        ctrl.submit(reqs[1])
        with pytest.raises(FleetBusy) as ei:
            ctrl.submit(reqs[2])
        assert ei.value.retry_after > 0
        assert ctrl.shed == 1
        # FleetBusy is a FabricError: existing handlers still catch it
        assert isinstance(ei.value, FabricError)
        # the queue drains, admission reopens
        ctrl.run_until_drained(advance=lambda: clock.advance(1.0))
        ctrl.submit(reqs[2])
        ctrl.run_until_drained(advance=lambda: clock.advance(1.0))
        assert sorted(ctrl.completed) == [0, 1, 2]

    def test_malformed_frames_contained_not_fatal(self):
        clock = ManualClock()
        ctrl = Controller(heartbeat_timeout=4.0, clock=clock)
        _spawn_fake(ctrl, "worker-a", clock)
        hb = _spawn_fake(ctrl, "worker-b", clock)
        reqs = _requests(6, max_new=6)
        for r in reqs:
            ctrl.submit(r)
        clock.advance(1.0)
        ctrl.tick()
        # worker-b's stream turns to garbage mid-run
        hb.endpoint._in.append(b"\x00\x00\x00\x04ABCD")
        ctrl.run_until_drained(advance=lambda: clock.advance(1.0))
        assert "worker-b" in ctrl.peer_errors
        assert "worker-b" in ctrl.failures
        assert hb.endpoint.closed
        # the fleet routed around the bad peer with zero loss
        assert sorted(ctrl.completed) == [r.rid for r in reqs]
        for req in reqs:
            assert req.tokens == _expected_stream(req)

    def test_drain_deadline_reports_stragglers(self):
        clock = ManualClock()
        ctrl = Controller(heartbeat_timeout=1e9, clock=clock)

        def hang(tick):
            if tick >= 2:
                raise WorkerFailure("hung mid-drain")

        _spawn_fake(ctrl, "good", clock)
        _spawn_fake(ctrl, "hung", clock, failure_hook=hang)
        clock.advance(1.0)
        ctrl.tick()
        # the hung worker never answers Drained and (with the huge
        # heartbeat window) never dies either: the deadline must fire
        assert ctrl.drain(5.0,
                          advance=lambda: clock.advance(1.0)) is False
        assert ctrl.workers["good"].drained
        assert not ctrl.workers["hung"].drained

    def test_drain_completes_on_a_healthy_fleet(self):
        clock = ManualClock()
        ctrl = Controller(heartbeat_timeout=4.0, clock=clock)
        _spawn_fake(ctrl, "a", clock)
        _spawn_fake(ctrl, "b", clock)
        for r in _requests(4, max_new=3):
            ctrl.submit(r)
        assert ctrl.drain(50.0,
                          advance=lambda: clock.advance(1.0)) is True
        assert all(h.drained for h in ctrl.workers.values())
        assert len(ctrl.completed) == 4


# ------------------------------------------------ controller clocking

class TestControllerClock:
    def test_await_hello_deadline_runs_on_injected_clock(self):
        clock = ManualClock()
        ctrl = Controller(clock=clock, hello_timeout=5.0)
        endpoint, _ = tp.local_pair()       # peer that never speaks

        class MuteDriver:
            dead = False

            def tick(self):
                clock.advance(1.0)          # only the INJECTED clock moves

        with pytest.raises(FabricError, match="never announced"):
            ctrl.add_worker(endpoint, driver=MuteDriver())
        # the deadline fired from ManualClock advances alone — under
        # the old time.monotonic() mixing this would spin ~forever
        assert clock.t <= 7.0

    def test_await_hello_detects_closed_endpoint(self):
        clock = ManualClock()
        ctrl = Controller(clock=clock)
        a, b = tp.local_pair()
        b.close()
        with pytest.raises(FabricError, match="closed before Hello"):
            ctrl.add_worker(a, driver=None)

    def test_await_hello_contains_pre_hello_garbage(self):
        clock = ManualClock()
        ctrl = Controller(clock=clock)
        a, b = tp.local_pair()
        b.send_bytes(b"\x00\x00\x00\x02\xc1\xff")
        with pytest.raises(FabricError, match="garbage before Hello"):
            ctrl.add_worker(a, driver=None)
        assert a.closed


# ------------------------------------------- real-model checkpoint

class TestEngineCheckpoint:
    def test_prepared_engine_roundtrips_bit_exact(self, tmp_path):
        import jax

        from repro.configs import reduced
        from repro.fabric.checkpoint import (build_engine,
                                             load_engine_checkpoint,
                                             save_engine_checkpoint)
        from repro.models import registry
        from repro.quant.prepare import PreparedWeight
        from repro.serving.engine import ServingEngine

        cfg = dataclasses.replace(reduced("qwen2-0.5b"),
                                  precision_policy="int4_serving")
        api = registry.build(cfg)
        params = api.init(jax.random.PRNGKey(0))
        config = EngineConfig(batch_slots=2, cache_len=64,
                              act_calibration="auto",
                              cost_correction="online")
        engine = ServingEngine(cfg, api, params, config=config)
        save_engine_checkpoint(engine, str(tmp_path), step=0)

        rcfg, rconfig, rparams, rscales, _ = load_engine_checkpoint(
            str(tmp_path))
        assert rcfg == cfg
        assert rconfig == dataclasses.replace(
            config, act_calibration=rscales)
        assert rscales == {k: pytest.approx(float(v))
                           for k, v in engine.act_scales.items()}

        ref_leaves, ref_def = jax.tree_util.tree_flatten(engine.params)
        got_leaves, got_def = jax.tree_util.tree_flatten(rparams)
        assert ref_def == got_def
        assert any(isinstance(x, PreparedWeight)
                   for x in jax.tree_util.tree_leaves(
                       rparams,
                       is_leaf=lambda x: isinstance(x, PreparedWeight)))
        for ref, got in zip(ref_leaves, got_leaves):
            assert ref.dtype == got.dtype
            np.testing.assert_array_equal(np.asarray(ref),
                                          np.asarray(got))

        # the rebuilt engine skipped quantize/pack/calibrate entirely
        restored = build_engine(str(tmp_path), api=api)
        assert restored.weight_quant_trace_count() == 0
        assert restored.act_quant_trace_count() == 0


# ------------------------------------------------- subprocess fleet

@pytest.mark.slow
class TestSubprocessFleet:
    """The real multi-process path: forked ``python -m repro.fabric
    worker`` processes dialing the controller's TCP listener — one
    from a local checkpoint, one via the Register -> RegisterAck
    checkpoint handoff. Real sockets, real wall clock, real engines.
    The fabric-smoke CI lane runs this; the default lane skips it."""

    def test_tcp_fleet_handoff_drain_shutdown(self, tmp_path):
        import jax

        from repro.configs import reduced
        from repro.fabric.checkpoint import (build_engine,
                                             save_engine_checkpoint)
        from repro.fabric.controller import spawn_subprocess_worker
        from repro.fabric.smoke import (POLICY, _engine_streams,
                                        _make_requests, _streams)
        from repro.models import registry
        from repro.serving.engine import ServingEngine

        cfg = dataclasses.replace(reduced("qwen2-0.5b"),
                                  precision_policy=POLICY)
        api = registry.build(cfg)
        params = api.init(jax.random.PRNGKey(0))
        config = EngineConfig(batch_slots=2, cache_len=64,
                              act_calibration="auto",
                              cost_correction="online")
        engine = ServingEngine(cfg, api, params, config=config)
        ckpt = str(tmp_path / "ckpt")
        save_engine_checkpoint(engine, ckpt, step=0)
        ref = _engine_streams(build_engine(ckpt, api=api),
                              _make_requests(cfg, 4, 6, 0))

        ctrl = Controller(heartbeat_timeout=120.0,
                          checkpoint_dir=ckpt)
        ctrl.listen("127.0.0.1", 0)
        try:
            spawn_subprocess_worker(ctrl, ckpt, name="proc-a")
            # fresh host: forked WITHOUT --ckpt, takes its checkpoint
            # directory from the controller's RegisterAck handoff
            spawn_subprocess_worker(ctrl, name="proc-b",
                                    register=True)
            for r in _make_requests(cfg, 4, 6, 0):
                ctrl.submit(r)
            ctrl.run_until_drained(max_ticks=500_000)
            assert _streams(ctrl.completed) == ref
            assert ctrl.failures == []
            assert ctrl.drain(60.0) is True
        finally:
            ctrl.shutdown()
        for h in ctrl.workers.values():
            assert h.process is not None
            assert h.process.poll() is not None   # actually exited
