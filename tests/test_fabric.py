"""Serving-fabric unit contracts (``repro.fabric``).

The wire layer (typed messages, framing, endpoint pairs), the config
round trips behind serve-ready checkpoints, the scheduler's
failure-recovery requeue, and the controller's kill → requeue →
re-admit loop — the latter over deterministic jax-free fake engines so
the control-plane logic is tested at unit speed. The real-model
end-to-end (restore bit-exactness, identical streams through real
engines, CI contract) lives in ``python -m repro.fabric smoke`` and
TestEngineCheckpoint below.
"""
import dataclasses

import msgpack
import numpy as np
import pytest

from repro.configs.base import ModelConfig, MoESpec
from repro.fabric import transport as tp
from repro.fabric.checkpoint import (engine_config_from_dict,
                                     engine_config_to_dict,
                                     model_config_from_dict,
                                     model_config_to_dict)
from repro.fabric.controller import (Controller, FabricError,
                                     LocalWorkerDriver, ManualClock)
from repro.fabric.worker import FabricWorker
from repro.obs import ReplicaStats
from repro.runtime.fault_tolerance import WorkerFailure
from repro.serving.config import EngineConfig, SamplingParams
from repro.serving.engine import Request
from repro.serving.scheduler import AdmissionScheduler, SchedulerFull


# ---------------------------------------------------------------- wire

class TestWireProtocol:
    MESSAGES = [
        tp.Hello(name="w0", policy="int4_serving", slots=4,
                 model_config={"d_model": 64, "rec_pattern": []},
                 cost_correction="online"),
        tp.SubmitRequest(rid=7, prompt=[1, 2, 3], max_new_tokens=8,
                         priority=2, tags=["accuracy"],
                         temperature=0.7, top_k=5, top_p=0.9,
                         stop_ids=[11], seed=42),
        tp.TokenChunk(rid=7, tokens=[4, 5], done=True,
                      finish_reason="stop", truncated=True),
        tp.StatsSnapshot(name="w0", stats={"tok_per_s": 3.5},
                         slots=4, completed=9),
        tp.Heartbeat(tick=12, time=3.25),
        tp.Drain(), tp.Drained(completed=3), tp.Shutdown(),
    ]

    @pytest.mark.parametrize("msg", MESSAGES,
                             ids=lambda m: type(m).__name__)
    def test_codec_roundtrip(self, msg):
        assert tp.decode_message(tp.encode_message(msg)) == msg

    def test_unknown_type_rejected(self):
        with pytest.raises(ValueError, match="unknown fabric message"):
            tp.decode_message(msgpack.packb({"t": "Nope", "f": {}}))
        with pytest.raises(TypeError):
            tp.encode_message({"not": "a message"})

    def test_framing_survives_arbitrary_chunking(self):
        payloads = [tp.encode_message(m) for m in self.MESSAGES]
        stream = b"".join(tp.pack_frame(p) for p in payloads)
        for chunk in (1, 3, len(stream)):      # byte-by-byte .. all-at-once
            dec = tp.FrameDecoder()
            frames = []
            for i in range(0, len(stream), chunk):
                frames.extend(dec.feed(stream[i:i + chunk]))
            assert frames == payloads

    def test_local_pair_is_a_framed_wire(self):
        a, b = tp.local_pair()
        a.send(tp.Heartbeat(tick=1, time=0.0))
        a.send(tp.Drain())
        assert b.poll() == [tp.Heartbeat(tick=1, time=0.0), tp.Drain()]
        assert b.poll() == []
        b.send(tp.Drained())
        assert a.poll() == [tp.Drained()]
        # closing either side closes both (a dead TCP peer, in memory)
        b.close()
        assert a.closed and b.closed
        with pytest.raises(tp.TransportClosed):
            a.send(tp.Shutdown())

    def test_socket_endpoints_roundtrip(self):
        listener = tp.Listener()
        client = tp.connect(listener.host, listener.port)
        server = listener.accept(timeout=10.0)
        listener.close()
        try:
            client.send(tp.Hello(name="w", policy="bf16", slots=1))
            for _ in range(100):
                got = server.poll()
                if got:
                    break
            assert got == [tp.Hello(name="w", policy="bf16", slots=1)]
            server.send(tp.Shutdown())
            for _ in range(100):
                back = client.poll()
                if back:
                    break
            assert back == [tp.Shutdown()]
        finally:
            client.close()
            server.close()


# ------------------------------------------------------- config codecs

def _tiny_cfg(policy="bf16", **kw) -> ModelConfig:
    return ModelConfig(arch_id="tiny", family="lm", n_layers=1,
                       d_model=32, n_heads=2, n_kv_heads=2, d_ff=64,
                       vocab=128, precision_policy=policy, **kw)


class TestConfigRoundTrip:
    def test_model_config_through_the_wire(self):
        cfg = _tiny_cfg(moe=MoESpec(n_experts=4, top_k=2, d_expert=16),
                        rec_pattern=("rec", "rec", "attn"))
        wire = msgpack.unpackb(msgpack.packb(model_config_to_dict(cfg)))
        back = model_config_from_dict(wire)
        assert back == cfg                    # tuples/MoESpec restored
        assert isinstance(back.rec_pattern, tuple)
        assert isinstance(back.moe, MoESpec)

    def test_model_config_unknown_field_rejected(self):
        d = model_config_to_dict(_tiny_cfg())
        d["from_the_future"] = 1
        with pytest.raises(ValueError, match="unknown fields"):
            model_config_from_dict(d)

    def test_engine_config_reinjects_act_scales(self):
        config = EngineConfig(batch_slots=2, cache_len=64,
                              act_calibration="auto",
                              cost_correction="online")
        wire = msgpack.unpackb(msgpack.packb(
            engine_config_to_dict(config)))
        assert "act_calibration" not in wire  # never serialized
        scales = {"block/mlp/w_up": 0.25}
        back = engine_config_from_dict(wire, scales)
        # restore swaps 'auto' (a calibration PASS) for the resolved
        # scales dict (zero-work) and keeps every other knob
        assert back.act_calibration == scales
        assert dataclasses.replace(back, act_calibration="auto") == config

    def test_engine_config_unknown_field_rejected(self):
        with pytest.raises(ValueError, match="unknown fields"):
            engine_config_from_dict({"warp_drive": True}, None)


# --------------------------------------------------- scheduler requeue

class TestSchedulerRequeue:
    def test_requeue_jumps_the_line_and_bypasses_the_bound(self):
        sched = AdmissionScheduler(max_queue=2)
        a, b = Request(rid=1, prompt=np.arange(3)), \
            Request(rid=2, prompt=np.arange(3))
        sched.submit(a, now=0.0)
        sched.submit(b, now=0.0)
        with pytest.raises(SchedulerFull):
            sched.submit(Request(rid=3, prompt=np.arange(3)), now=0.0)
        # recovery re-entries are admitted work: never bounced, placed
        # ahead of every waiting submit of the same priority class
        r1 = Request(rid=10, prompt=np.arange(3), submit_time=0.0)
        r2 = Request(rid=11, prompt=np.arange(3), submit_time=0.0)
        sched.requeue(r1)
        sched.requeue(r2)
        assert sched.requeued == 2 and len(sched) == 4
        picked = sched.select(4, now=1.0)
        assert [r.rid for r in picked] == [10, 11, 1, 2]

    def test_requeue_preserves_submit_time_for_promotion(self):
        sched = AdmissionScheduler(max_wait=5.0)
        old = Request(rid=1, prompt=np.arange(3), priority=9,
                      submit_time=0.0)
        sched.requeue(old)
        fresh = Request(rid=2, prompt=np.arange(3), priority=0)
        sched.submit(fresh, now=6.0)
        # the requeued request kept its original submission clock: it
        # is already past max_wait and outranks the priority-0 arrival
        assert [r.rid for r in sched.select(1, now=6.0)] == [1]


# ------------------------------------------------ fake-engine fleet

class FakeEngine:
    """Deterministic jax-free stand-in for ServingEngine: one token per
    slot per step, value ``(rid * 31 + position) % 97`` — placement-
    and batch-independent by construction, like greedy decode."""

    def __init__(self, cfg, config, clock):
        self.cfg = cfg
        self.config = config
        self.b = config.batch_slots
        self.clock = clock
        self.stats = ReplicaStats()
        self.scheduler = AdmissionScheduler()
        self.slot_req = [None] * self.b
        self.completed = {}

    def submit(self, req):
        self.scheduler.submit(req, now=self.clock())

    def has_pending(self):
        return len(self.scheduler) > 0 \
            or any(r is not None for r in self.slot_req)

    def step(self):
        now = self.clock()
        free = [s for s, r in enumerate(self.slot_req) if r is None]
        for req in self.scheduler.select(len(free), now):
            self.slot_req[free.pop(0)] = req
            req.tokens = [int(t) for t in req.prompt]
        new = 0
        for s, req in enumerate(self.slot_req):
            if req is None:
                continue
            req.tokens.append((req.rid * 31 + len(req.tokens)) % 97)
            new += 1
            if len(req.tokens) - len(req.prompt) >= req.budget:
                req.done = True
                req.finish_reason = "length"
                self.completed[req.rid] = req
                self.slot_req[s] = None
        self.stats.on_tick(now, new, len(self.scheduler),
                           active_slots=sum(r is not None
                                            for r in self.slot_req))


def _expected_stream(req) -> list:
    start = len(req.prompt)
    return [int(t) for t in req.prompt] + [
        (req.rid * 31 + start + j) % 97 for j in range(req.budget)]


def _spawn_fake(ctrl, name, clock, *, slots=2, failure_hook=None):
    cfg = _tiny_cfg()
    engine = FakeEngine(cfg, EngineConfig(batch_slots=slots,
                                          cost_correction="online"),
                        clock)
    ctrl_ep, worker_ep = tp.local_pair()
    worker = FabricWorker(name, engine, worker_ep, clock=clock,
                          failure_hook=failure_hook)
    worker.announce()
    return ctrl.add_worker(ctrl_ep, driver=LocalWorkerDriver(worker),
                           name=name)


def _requests(n, max_new=6, seed=0):
    rng = np.random.default_rng(seed)
    return [Request(rid=rid,
                    prompt=rng.integers(0, 97, int(rng.integers(2, 6)),
                                        dtype=np.int32),
                    max_new_tokens=max_new)
            for rid in range(n)]


def _run(n_requests, *, kill_tick=None, heartbeat_timeout=3.0,
         max_new=6):
    clock = ManualClock()
    ctrl = Controller(heartbeat_timeout=heartbeat_timeout, clock=clock)

    def die(tick):
        if kill_tick is not None and tick == kill_tick:
            raise WorkerFailure("injected")

    _spawn_fake(ctrl, "worker-a", clock)
    _spawn_fake(ctrl, "worker-b", clock, failure_hook=die)
    reqs = _requests(n_requests, max_new=max_new)
    for r in reqs:
        ctrl.submit(r)
    ctrl.run_until_drained(advance=lambda: clock.advance(1.0))
    return ctrl, reqs


class TestControllerFleet:
    def test_fleet_completes_with_exact_streams(self):
        ctrl, reqs = _run(6)
        assert sorted(ctrl.completed) == [r.rid for r in reqs]
        for req in reqs:
            assert req.done and req.tokens == _expected_stream(req)
        # routing ran over TRANSPORTED stats, not in-process objects
        report = ctrl.routing_report()
        assert report["cost_correction"] == "online"
        for name, rep in report["replicas"].items():
            assert rep["measured"]["transported"], name
        routed = ctrl.routing_counters()
        assert sum(routed.values()) == 6 and all(
            v > 0 for v in routed.values()), routed

    def test_kill_mid_flight_loses_nothing(self):
        ref, _ = _run(8, max_new=8)
        ref_streams = {rid: list(r.tokens)
                       for rid, r in ref.completed.items()}
        ctrl, reqs = _run(8, kill_tick=2, max_new=8)
        assert ctrl.failures == ["worker-b"]
        assert ctrl.scheduler.requeued > 0
        assert sorted(ctrl.completed) == sorted(ref_streams)
        for rid, req in ctrl.completed.items():
            assert req.tokens == ref_streams[rid], f"rid {rid} diverged"
        alive = [h.name for h in ctrl.workers.values() if h.alive]
        assert alive == ["worker-a"]

    def test_closed_endpoint_detected_without_heartbeat_wait(self):
        clock = ManualClock()
        ctrl = Controller(heartbeat_timeout=1e9, clock=clock)
        _spawn_fake(ctrl, "worker-a", clock)
        hb = _spawn_fake(ctrl, "worker-b", clock)
        reqs = _requests(4)
        for r in reqs:
            ctrl.submit(r)
        ctrl.tick()
        hb.endpoint.close()     # process death: socket EOF, no timeout
        ctrl.run_until_drained(advance=lambda: clock.advance(1.0))
        assert ctrl.failures == ["worker-b"]
        assert sorted(ctrl.completed) == [r.rid for r in reqs]

    def test_last_worker_death_is_a_fleet_error(self):
        clock = ManualClock()
        ctrl = Controller(heartbeat_timeout=2.0, clock=clock)

        def die(tick):
            if tick == 1:
                raise WorkerFailure("injected")

        _spawn_fake(ctrl, "only", clock, failure_hook=die)
        for r in _requests(3):
            ctrl.submit(r)
        with pytest.raises(FabricError, match="no alive workers"):
            ctrl.run_until_drained(advance=lambda: clock.advance(1.0))

    def test_worker_drain_and_shutdown(self):
        clock = ManualClock()
        engine = FakeEngine(_tiny_cfg(), EngineConfig(batch_slots=2),
                            clock)
        ctrl_ep, worker_ep = tp.local_pair()
        worker = FabricWorker("w", engine, worker_ep, clock=clock)
        req = _requests(1)[0]
        sp = req.sampling
        ctrl_ep.send(tp.SubmitRequest(
            rid=req.rid, prompt=[int(t) for t in req.prompt],
            max_new_tokens=req.budget, temperature=sp.temperature,
            top_k=sp.top_k, top_p=sp.top_p))
        ctrl_ep.send(tp.Drain())
        for _ in range(32):
            clock.advance(1.0)
            worker.tick()
        msgs = ctrl_ep.poll()
        drained = [m for m in msgs if isinstance(m, tp.Drained)]
        assert len(drained) == 1 and drained[0].completed == 1
        final = [m for m in msgs if isinstance(m, tp.TokenChunk)
                 and m.done]
        assert len(final) == 1
        ctrl_ep.send(tp.Shutdown())
        assert worker.tick() is False


# ------------------------------------------- real-model checkpoint

class TestEngineCheckpoint:
    def test_prepared_engine_roundtrips_bit_exact(self, tmp_path):
        import jax

        from repro.configs import reduced
        from repro.fabric.checkpoint import (build_engine,
                                             load_engine_checkpoint,
                                             save_engine_checkpoint)
        from repro.models import registry
        from repro.quant.prepare import PreparedWeight
        from repro.serving.engine import ServingEngine

        cfg = dataclasses.replace(reduced("qwen2-0.5b"),
                                  precision_policy="int4_serving")
        api = registry.build(cfg)
        params = api.init(jax.random.PRNGKey(0))
        config = EngineConfig(batch_slots=2, cache_len=64,
                              act_calibration="auto",
                              cost_correction="online")
        engine = ServingEngine(cfg, api, params, config=config)
        save_engine_checkpoint(engine, str(tmp_path), step=0)

        rcfg, rconfig, rparams, rscales, _ = load_engine_checkpoint(
            str(tmp_path))
        assert rcfg == cfg
        assert rconfig == dataclasses.replace(
            config, act_calibration=rscales)
        assert rscales == {k: pytest.approx(float(v))
                           for k, v in engine.act_scales.items()}

        ref_leaves, ref_def = jax.tree_util.tree_flatten(engine.params)
        got_leaves, got_def = jax.tree_util.tree_flatten(rparams)
        assert ref_def == got_def
        assert any(isinstance(x, PreparedWeight)
                   for x in jax.tree_util.tree_leaves(
                       rparams,
                       is_leaf=lambda x: isinstance(x, PreparedWeight)))
        for ref, got in zip(ref_leaves, got_leaves):
            assert ref.dtype == got.dtype
            np.testing.assert_array_equal(np.asarray(ref),
                                          np.asarray(got))

        # the rebuilt engine skipped quantize/pack/calibrate entirely
        restored = build_engine(str(tmp_path), api=api)
        assert restored.weight_quant_trace_count() == 0
        assert restored.act_quant_trace_count() == 0
