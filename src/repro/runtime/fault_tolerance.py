"""Fault-tolerant training runtime: checkpoint/restart, failure
injection, straggler mitigation, elastic re-mesh.

On a real multi-pod deployment the failure signals come from the cluster
scheduler / jax.distributed heartbeats; here the policy logic is
identical and the signals are injectable, so every path is testable on
one host:

  * ``FaultTolerantLoop`` — drives (data -> step -> checkpoint) with
    retry-from-checkpoint on WorkerFailure, bounded restarts, and a
    deterministic data stream (resume replays the exact batch order).
  * ``StragglerMonitor`` — per-step deadline tracking: steps slower than
    ``deadline_factor`` x the rolling median are flagged; the policy
    hook decides (log | skip-and-redispatch | re-mesh). On TPU pods the
    skip corresponds to deadline-based collective abort + retry.
  * ``elastic_reshard`` — re-materialize a (params, opt) checkpoint onto
    a different device count/mesh (scale up/down without restart).
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable, Dict, List, Optional

import jax
import numpy as np

from repro.checkpoint.checkpoint import CheckpointManager


class WorkerFailure(RuntimeError):
    """Simulated node failure (preemption, ICI error, kernel crash).

    The one injectable death signal shared across the repo: the
    training loop's retry-from-checkpoint path catches it, and the
    serving fabric's workers die on it (``repro.fabric`` requeues or
    resumes their work; ``repro.fabric.chaos`` schedules it
    declaratively via ``FaultSchedule.kill_at_tick``)."""


def fail_at_step(step: int,
                 reason: str = "injected failure") -> Callable[[int], None]:
    """The canonical ``failure_hook``: raise :class:`WorkerFailure` at
    exactly ``step``. Used directly by training-loop tests and wrapped
    by the fabric chaos harness (:func:`repro.fabric.chaos.fail_at`)
    so both runtimes inject death through one code path."""
    def hook(t: int) -> None:
        if t == step:
            raise WorkerFailure(f"{reason} at step {step}")
    return hook


@dataclasses.dataclass
class FTConfig:
    checkpoint_every: int = 50
    max_restarts: int = 5
    keep_checkpoints: int = 3
    deadline_factor: float = 3.0     # straggler threshold vs median
    straggler_window: int = 32


class StragglerMonitor:
    def __init__(self, cfg: FTConfig):
        self.cfg = cfg
        self.durations: List[float] = []
        self.flagged: List[int] = []

    def observe(self, step: int, seconds: float) -> bool:
        """Returns True if this step is a straggler."""
        window = self.durations[-self.cfg.straggler_window:]
        self.durations.append(seconds)
        if len(window) < 8:
            return False
        med = float(np.median(window))
        if seconds > self.cfg.deadline_factor * med:
            self.flagged.append(step)
            return True
        return False


class FaultTolerantLoop:
    """Run ``step_fn(state, batch) -> (state, metrics)`` with automatic
    checkpoint/restart.

    ``state`` is any pytree (params, opt, loss-scale, ...). ``batch_fn``
    must be a pure function of the step index (the data pipeline
    guarantees this) so that restarts replay identically.
    ``failure_hook(step)`` may raise WorkerFailure to inject faults.
    """

    def __init__(self, step_fn: Callable, batch_fn: Callable,
                 ckpt_dir: str, cfg: FTConfig = FTConfig(),
                 failure_hook: Optional[Callable[[int], None]] = None,
                 straggler_hook: Optional[Callable[[int], None]] = None):
        self.step_fn = step_fn
        self.batch_fn = batch_fn
        self.cfg = cfg
        self.manager = CheckpointManager(ckpt_dir, keep=cfg.keep_checkpoints)
        self.failure_hook = failure_hook
        self.straggler_hook = straggler_hook
        self.monitor = StragglerMonitor(cfg)
        self.restarts = 0
        self.history: List[Dict] = []

    def run(self, state: Any, start_step: int, num_steps: int,
            shardings: Any = None) -> Any:
        step = start_step
        init_state = state      # step_fn is pure: safe to re-enter from
        # resume if checkpoints exist
        ck_step, ck_state, _ = self.manager.restore_latest(
            state, shardings, missing_ok=True)
        if ck_step is not None and ck_step >= step:
            state, step = ck_state, ck_step
        end = start_step + num_steps
        while step < end:
            try:
                if self.failure_hook:
                    self.failure_hook(step)
                t0 = time.monotonic()
                batch = self.batch_fn(step)
                state, metrics = self.step_fn(state, batch)
                dt = time.monotonic() - t0
                if self.monitor.observe(step, dt) and self.straggler_hook:
                    self.straggler_hook(step)
                self.history.append({"step": step, **{
                    k: float(v) for k, v in metrics.items()}})
                step += 1
                if step % self.cfg.checkpoint_every == 0 or step == end:
                    self.manager.save(step, state, {"restarts":
                                                    self.restarts})
            except WorkerFailure:
                self.restarts += 1
                if self.restarts > self.cfg.max_restarts:
                    raise
                ck_step, ck_state, _ = self.manager.restore_latest(
                    state, shardings, missing_ok=True)
                if ck_step is None:
                    # no checkpoint yet: replay from the start — with
                    # the INITIAL state, or the replayed steps would
                    # apply on top of the partial progress
                    state, step = init_state, start_step
                else:
                    state, step = ck_state, ck_step
        return state, step


def elastic_reshard(tree: Any, mesh, pspec_tree) -> Any:
    """Re-place a host/abstract pytree onto a (possibly different) mesh.

    Combined with checkpoint restore this is the elastic-scaling path: a
    run checkpointed on one topology resumes on another; XLA SPMD handles
    the rest because programs are retraced against the new mesh."""
    from jax.sharding import NamedSharding

    def place(x, spec):
        return jax.device_put(np.asarray(x), NamedSharding(mesh, spec))

    return jax.tree.map(place, tree, pspec_tree)
