"""Grouped-query attention with the zoo's feature set: GQA/MQA/MHA,
RoPE (partial), sliding windows, gemma-2 attention softcap, QKV biases,
qwen-3 QK-norm, bidirectional (encoder) and cross-attention modes, and a
position-tagged KV cache that serves both full-attention decode and
ring-buffer sliding-window decode.
"""
from __future__ import annotations

import dataclasses
import math
from typing import NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core.policy import PrecisionPolicy
from repro.layers.common import apply_rope, norm_init, rms_norm, softcap
from repro.layers.mplinear import linear_init, mp_linear
from repro.parallel import act_sharding as act


@dataclasses.dataclass(frozen=True)
class AttnConfig:
    d_model: int
    n_heads: int
    n_kv_heads: int
    head_dim: int
    qkv_bias: bool = False
    qk_norm: bool = False
    rope_theta: float = 10000.0
    rotary_pct: float = 1.0
    window: Optional[int] = None       # sliding window (tokens), None=full
    attn_softcap: Optional[float] = None
    causal: bool = True                # False for encoder self-attn
    cross: bool = False                # cross-attention (no RoPE, kv=ctx)
    scale: Optional[float] = None      # default 1/sqrt(head_dim)
    # Chunked (flash-style online-softmax) attention kicks in when the KV
    # length exceeds chunk_threshold and Sq > 1 — O(S) memory, mandatory
    # for 32k prefill.
    q_chunk: int = 512
    kv_chunk: int = 1024
    chunk_threshold: int = 2048

    @property
    def q_dim(self):
        return self.n_heads * self.head_dim

    @property
    def kv_dim(self):
        return self.n_kv_heads * self.head_dim


class KVCache(NamedTuple):
    """Position-tagged cache: ring-indexed when capacity < sequence."""

    k: jax.Array    # (B, C, Hkv, D)
    v: jax.Array    # (B, C, Hkv, D)
    pos: jax.Array  # (B, C) int32 absolute positions, -1 = empty


def init(key, cfg: AttnConfig, dtype=jnp.float32):
    ks = jax.random.split(key, 4)
    p = {
        "wq": linear_init(ks[0], cfg.d_model, cfg.q_dim, cfg.qkv_bias, dtype),
        "wk": linear_init(ks[1], cfg.d_model, cfg.kv_dim, cfg.qkv_bias, dtype),
        "wv": linear_init(ks[2], cfg.d_model, cfg.kv_dim, cfg.qkv_bias, dtype),
        "wo": linear_init(ks[3], cfg.q_dim, cfg.d_model, False, dtype),
    }
    if cfg.qk_norm:
        p["q_norm"] = norm_init("rms", cfg.head_dim, dtype)
        p["k_norm"] = norm_init("rms", cfg.head_dim, dtype)
    return p


def init_cache(batch: int, capacity: int, cfg: AttnConfig,
               dtype=jnp.bfloat16) -> KVCache:
    return KVCache(
        k=jnp.zeros((batch, capacity, cfg.n_kv_heads, cfg.head_dim), dtype),
        v=jnp.zeros((batch, capacity, cfg.n_kv_heads, cfg.head_dim), dtype),
        pos=jnp.full((batch, capacity), -1, jnp.int32),
    )


def _project_qkv(params, cfg: AttnConfig, x, positions, policy, path,
                 kv_input=None):
    spec = policy.spec_for
    b, s, _ = x.shape
    q = mp_linear(params["wq"], x, spec(f"{path}/wq"), path=f"{path}/wq").reshape(
        b, s, cfg.n_heads, cfg.head_dim)
    kv_src = x if kv_input is None else kv_input
    bk, sk, _ = kv_src.shape
    k = mp_linear(params["wk"], kv_src, spec(f"{path}/wk"), path=f"{path}/wk").reshape(
        bk, sk, cfg.n_kv_heads, cfg.head_dim)
    v = mp_linear(params["wv"], kv_src, spec(f"{path}/wv"), path=f"{path}/wv").reshape(
        bk, sk, cfg.n_kv_heads, cfg.head_dim)
    if cfg.qk_norm:
        q = rms_norm(q, params["q_norm"]["w"])
        k = rms_norm(k, params["k_norm"]["w"])
    if not cfg.cross:
        q = apply_rope(q, positions, cfg.rope_theta, cfg.rotary_pct)
        k = apply_rope(k, positions, cfg.rope_theta, cfg.rotary_pct)
    return act.heads(q), act.heads(k), act.heads(v)


def _mask(cfg: AttnConfig, q_pos, k_pos, k_valid):
    """(B, 1, 1, Sq, Sk) boolean mask from position tags."""
    m = k_valid[:, None, None, None, :]
    if cfg.causal:
        m = m & (k_pos[:, None, None, None, :]
                 <= q_pos[:, None, None, :, None])
    if cfg.window is not None:
        m = m & (k_pos[:, None, None, None, :]
                 > q_pos[:, None, None, :, None] - cfg.window)
    return m


def _attend_dense(cfg: AttnConfig, q, k, v, q_pos, k_pos, k_valid):
    """Materialized-logits attention (short sequences / decode)."""
    b, sq, hq, d = q.shape
    hkv = k.shape[2]
    g = hq // hkv
    scale = cfg.scale if cfg.scale is not None else 1.0 / math.sqrt(d)
    qg = q.reshape(b, sq, hkv, g, d)
    logits = jnp.einsum("bqhgd,bkhd->bhgqk", qg.astype(jnp.float32),
                        k.astype(jnp.float32)) * scale
    logits = softcap(logits, cfg.attn_softcap)
    mask = _mask(cfg, q_pos, k_pos, k_valid)  # (B,1,1,Sq,Sk)
    logits = jnp.where(mask, logits, -1e30)
    probs = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum("bhgqk,bkhd->bqhgd", probs.astype(v.dtype), v)
    return out.reshape(b, sq, hq * d)


def _attend_chunked(cfg: AttnConfig, q, k, v, q_pos, k_pos, k_valid):
    """Flash-style online-softmax attention: O(S) memory via a scan over
    KV chunks inside a map over Q chunks. All accumulation in f32."""
    b, sq, hq, d = q.shape
    hkv = k.shape[2]
    g = hq // hkv
    scale = cfg.scale if cfg.scale is not None else 1.0 / math.sqrt(d)
    qc, kc = cfg.q_chunk, cfg.kv_chunk

    pad_q = -sq % qc
    q = jnp.pad(q, ((0, 0), (0, pad_q), (0, 0), (0, 0)))
    q_pos_p = jnp.pad(q_pos, ((0, 0), (0, pad_q)))
    sk = k.shape[1]
    pad_k = -sk % kc
    k = jnp.pad(k, ((0, 0), (0, pad_k), (0, 0), (0, 0)))
    v = jnp.pad(v, ((0, 0), (0, pad_k), (0, 0), (0, 0)))
    k_pos = jnp.pad(k_pos, ((0, 0), (0, pad_k)))
    k_valid = jnp.pad(k_valid, ((0, 0), (0, pad_k)))
    nq, nk = q.shape[1] // qc, k.shape[1] // kc

    qg = q.reshape(b, nq, qc, hkv, g, d).astype(jnp.float32)
    qp = q_pos_p.reshape(b, nq, qc)
    kg = jnp.moveaxis(k.reshape(b, nk, kc, hkv, d), 1, 0)
    vg = jnp.moveaxis(v.reshape(b, nk, kc, hkv, d), 1, 0)
    kpg = jnp.moveaxis(k_pos.reshape(b, nk, kc), 1, 0)
    kvg = jnp.moveaxis(k_valid.reshape(b, nk, kc), 1, 0)

    def one_q_chunk(args):
        qi, qpi = args  # (B, qc, hkv, g, d), (B, qc)

        def kv_step(carry, kv):
            m, l, acc = carry
            ki, vi, kpi, kvi = kv
            logits = jnp.einsum("bqhgd,bkhd->bhgqk", qi,
                                ki.astype(jnp.float32)) * scale
            logits = softcap(logits, cfg.attn_softcap)
            msk = _mask(cfg, qpi, kpi, kvi)
            logits = jnp.where(msk, logits, -1e30)
            m_new = jnp.maximum(m, logits.max(-1))
            corr = jnp.exp(m - m_new)
            p = jnp.exp(logits - m_new[..., None])
            l_new = l * corr + p.sum(-1)
            acc_new = acc * corr[..., None] + jnp.einsum(
                "bhgqk,bkhd->bhgqd", p, vi.astype(jnp.float32))
            return (m_new, l_new, acc_new), None

        m0 = jnp.full((b, hkv, g, qc), -jnp.inf, jnp.float32)
        l0 = jnp.zeros((b, hkv, g, qc), jnp.float32)
        a0 = jnp.zeros((b, hkv, g, qc, d), jnp.float32)
        # Rematerialized backward (flash-attention style): without the
        # checkpoints, the backward keeps every chunk-pair's probability
        # tensor live at once — O(S^2) memory, hundreds of GB/device at
        # train_4k (see EXPERIMENTS.md §Perf memory iteration).
        (m, l, acc), _ = jax.lax.scan(jax.checkpoint(kv_step), (m0, l0, a0),
                                      (kg, vg, kpg, kvg))
        out = acc / jnp.maximum(l, 1e-30)[..., None]
        return jnp.moveaxis(out, 3, 1)  # (B, qc, hkv, g, d)

    outs = jax.lax.map(jax.checkpoint(one_q_chunk),
                       (jnp.moveaxis(qg, 1, 0), jnp.moveaxis(qp, 1, 0)))
    out = jnp.moveaxis(outs, 0, 1).reshape(b, nq * qc, hq, d)
    return out[:, :sq].reshape(b, sq, hq * d).astype(v.dtype)


def _attend(cfg: AttnConfig, q, k, v, q_pos, k_pos, k_valid):
    """Core attention dispatch: q (B,Sq,Hq,D); k/v (B,Sk,Hkv,D);
    q_pos (B,Sq), k_pos (B,Sk) absolute positions; k_valid (B,Sk)."""
    if q.shape[1] > 1 and k.shape[1] > cfg.chunk_threshold:
        return _attend_chunked(cfg, q, k, v, q_pos, k_pos, k_valid)
    return _attend_dense(cfg, q, k, v, q_pos, k_pos, k_valid)


def forward(params, cfg: AttnConfig, x, positions, policy: PrecisionPolicy,
            path: str, kv_input=None, kv_valid=None):
    """Training / prefill attention over full sequences.

    x: (B, S, d); positions: (B, S). kv_input for cross-attention.
    Returns (B, S, d)."""
    q, k, v = _project_qkv(params, cfg, x, positions, policy, path,
                           kv_input)
    k_pos = positions if kv_input is None else (
        jnp.broadcast_to(jnp.arange(kv_input.shape[1], dtype=jnp.int32),
                         kv_input.shape[:2]))
    if kv_valid is None:
        kv_valid = jnp.ones(k.shape[:2], bool)
    out = _attend(cfg, q, k, v, positions, k_pos, kv_valid)
    return mp_linear(params["wo"], out, policy.spec_for(f"{path}/wo"), path=f"{path}/wo")


def prefill(params, cfg: AttnConfig, x, positions, cache: KVCache,
            policy, path):
    """Prefill: full-sequence attention + cache fill.

    Prefill always starts at position 0, so the ring slots of the
    surviving (trailing `cap`) positions form a STATIC rotation — the
    write is two contiguous dynamic_update_slices, never a gather/scatter
    (SPMD scatters onto the capacity-sharded cache would force the K/V
    tensors batch-unsharded: +8 GB/device at gemma2 prefill_32k)."""
    q, k, v = _project_qkv(params, cfg, x, positions, policy, path)
    out = _attend(cfg, q, k, v, positions,
                  positions, jnp.ones(k.shape[:2], bool))
    cap = cache.k.shape[1]
    s = k.shape[1]
    k_w, v_w, pos_w = k, v, positions
    if s > cap:  # ring: only the trailing cap positions survive
        k_w, v_w, pos_w = k[:, -cap:], v[:, -cap:], positions[:, -cap:]
    start = (s - cap) % cap if s > cap else 0

    def write(buf, upd):
        buf = buf.astype(upd.dtype)
        first = upd[:, : cap - start]
        buf = jax.lax.dynamic_update_slice_in_dim(buf, first, start, axis=1)
        if start:
            buf = jax.lax.dynamic_update_slice_in_dim(
                buf, upd[:, cap - start:], 0, axis=1)
        return buf

    new_cache = KVCache(
        k=write(cache.k, k_w),
        v=write(cache.v, v_w),
        pos=write(cache.pos, pos_w),
    )
    return mp_linear(params["wo"], out, policy.spec_for(f"{path}/wo"), path=f"{path}/wo"), \
        new_cache


def prefill_chunk(params, cfg: AttnConfig, x, positions, valid,
                  cache: KVCache, policy, path):
    """Prefill CONTINUATION: write one chunk of a prompt at arbitrary
    absolute positions into a LIVE cache, attending the chunk's queries
    against the whole updated cache (earlier chunks included) by
    position tags — the serving-engine path that streams a long prompt
    through multiple admission waves.

    x: (B, S, d); positions: (B, S) absolute positions; valid: (B, S)
    bool — invalid entries (padding rows/tails of the packed wave)
    write nothing and their outputs are garbage the caller discards.
    Requires S <= capacity (distinct ring slots within a chunk row).
    Same write-then-attend order as ``decode_step``: a query at
    position p sees every tag <= p already written, including its own
    chunk's earlier tokens, so chunking is invariant to chunk size.
    Scatter-indexed, unlike ``prefill``'s static rotation — this is the
    few-slot engine path, not the sharded 32k prefill."""
    q, k, v = _project_qkv(params, cfg, x, positions, policy, path)
    cap = cache.k.shape[1]
    slot = positions % cap                          # (B, S)
    bidx = jnp.arange(x.shape[0], dtype=jnp.int32)[:, None]
    vk = valid[..., None, None]
    ck = cache.k.astype(k.dtype)
    cv = cache.v.astype(v.dtype)
    ck = ck.at[bidx, slot].set(jnp.where(vk, k, ck[bidx, slot]))
    cv = cv.at[bidx, slot].set(jnp.where(vk, v, cv[bidx, slot]))
    cpos = cache.pos.at[bidx, slot].set(
        jnp.where(valid, positions, cache.pos[bidx, slot]))
    new_cache = KVCache(ck, cv, cpos)
    out = _attend(cfg, q, ck, cv, positions, cpos, cpos >= 0)
    return mp_linear(params["wo"], out, policy.spec_for(f"{path}/wo"), path=f"{path}/wo"), \
        new_cache


def decode_step(params, cfg: AttnConfig, x, pos, cache: KVCache,
                policy, path):
    """One-token decode. x: (B, 1, d); pos: (B,) absolute positions.

    Writes the new KV at slot pos % capacity, masks by position tags —
    correct for both full caches (capacity >= seq) and SWA ring buffers
    (capacity == window)."""
    positions = pos[:, None]
    q, k, v = _project_qkv(params, cfg, x, positions, policy, path)
    cap = cache.k.shape[1]
    slot = pos % cap
    bidx = jnp.arange(x.shape[0], dtype=jnp.int32)
    ck = cache.k.astype(k.dtype).at[bidx, slot].set(k[:, 0])
    cv = cache.v.astype(v.dtype).at[bidx, slot].set(v[:, 0])
    cpos = cache.pos.at[bidx, slot].set(pos)
    new_cache = KVCache(ck, cv, cpos)
    out = _attend(cfg, q, ck, cv, positions, cpos, cpos >= 0)
    return mp_linear(params["wo"], out, policy.spec_for(f"{path}/wo"), path=f"{path}/wo"), \
        new_cache
