"""Gated MLP (SwiGLU / GeGLU) under the mixed-precision policy."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.layers.common import activation
from repro.layers.mplinear import linear_init, mp_linear
from repro.parallel import act_sharding


def init(key, d_model: int, d_ff: int, dtype=jnp.float32):
    k1, k2, k3 = jax.random.split(key, 3)
    return {
        "w_gate": linear_init(k1, d_model, d_ff, False, dtype),
        "w_up": linear_init(k2, d_model, d_ff, False, dtype),
        "w_down": linear_init(k3, d_ff, d_model, False, dtype),
    }


def forward(params, x, policy, path: str, act: str = "silu"):
    fn = activation(act)
    g = mp_linear(params["w_gate"], x, policy.spec_for(f"{path}/w_gate"), path=f"{path}/w_gate")
    u = mp_linear(params["w_up"], x, policy.spec_for(f"{path}/w_up"), path=f"{path}/w_up")
    h = act_sharding.ffn_hidden(
        fn(g.astype(jnp.float32)).astype(u.dtype) * u)
    return act_sharding.batch_seq(
        mp_linear(params["w_down"], h, policy.spec_for(f"{path}/w_down"), path=f"{path}/w_down"))
