"""Top-k Mixture-of-Experts with capacity-based GShard-style dispatch.

Dense one-hot dispatch/combine einsums: SPMD-friendly (the expert axis
shards over the mesh 'model' axis when n_experts divides it — expert
parallelism with XLA-inserted all-to-alls — otherwise d_ff shards, pure
TP). Router runs in f32 (softmax sensitivity); experts take the
mixed-precision policy.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp

from repro.layers.common import activation, dense_init
from repro.layers.mplinear import mp_linear
from repro.parallel import act_sharding


@dataclasses.dataclass(frozen=True)
class MoEConfig:
    d_model: int
    d_expert: int
    n_experts: int
    top_k: int
    capacity_factor: float = 1.25
    act: str = "silu"
    router_noise: float = 0.0
    # 'einsum': GShard one-hot dispatch/combine matmuls (simple, but the
    # dispatch einsum costs G*S*E*C*d MACs — for 128-expert configs that
    # is orders of magnitude more FLOPs than the experts themselves).
    # 'gather': index-based dispatch/combine (scatter token ids into the
    # (E, C) queue, gather activations) — removes the dispatch FLOPs
    # entirely (§Perf hillclimb on qwen3-moe).
    dispatch: str = "einsum"


def init(key, cfg: MoEConfig, dtype=jnp.float32):
    ks = jax.random.split(key, 4)
    e, d, f = cfg.n_experts, cfg.d_model, cfg.d_expert

    def stack(k, din, dout):
        kk = jax.random.split(k, e)
        return jnp.stack([dense_init(kk[i], din, dout, dtype)
                          for i in range(e)])

    return {
        "router": {"w": dense_init(ks[0], d, e, jnp.float32)},
        "w_gate": {"w": stack(ks[1], d, f)},
        "w_up": {"w": stack(ks[2], d, f)},
        "w_down": {"w": stack(ks[3], f, d)},
    }


def _capacity(tokens_per_group: int, cfg: MoEConfig) -> int:
    cap = int(cfg.capacity_factor * tokens_per_group * cfg.top_k
              / cfg.n_experts)
    return max(cap, cfg.top_k)


def forward(params, cfg: MoEConfig, x, policy, path: str):
    """x: (B, S, d) -> (B, S, d), plus aux load-balancing loss.

    GShard-style *grouped* dispatch: each sequence is its own routing
    group with capacity proportional to S — the dispatch one-hot is
    (G, S, E, C_g), linear in total tokens. (A flat dispatch over all
    B*S tokens would be quadratic: C grows with T, giving T*E*C ~ T^2 —
    hundreds of TB at train_4k scale.) Groups ride the data axes; the
    expert dim shards over 'model' (EP) when divisible.
    """
    b, s, d = x.shape
    cap = _capacity(s, cfg)

    logits = jnp.einsum("gsd,de->gse", x.astype(jnp.float32),
                        params["router"]["w"].astype(jnp.float32))
    probs = jax.nn.softmax(logits, axis=-1)           # (G, S, E)

    # top-k selection -> (G, S, k) expert ids + renormalized gates
    gate_vals, expert_ids = jax.lax.top_k(probs, cfg.top_k)
    gate_vals = gate_vals / jnp.maximum(
        gate_vals.sum(-1, keepdims=True), 1e-9)

    # position of each (token, k) within its expert queue, per group
    onehot = jax.nn.one_hot(expert_ids, cfg.n_experts,
                            dtype=jnp.int32)          # (G, S, k, E)
    flat = onehot.reshape(b, s * cfg.top_k, cfg.n_experts)
    pos_in_expert = (jnp.cumsum(flat, axis=1) - flat).reshape(
        b, s, cfg.top_k, cfg.n_experts)
    pos = (pos_in_expert * onehot).sum(-1)            # (G, S, k)
    fits = pos < cap
    gate_vals = gate_vals * fits

    if cfg.dispatch == "gather":
        # scatter token ids into the expert queues, gather activations
        gidx = jnp.arange(b, dtype=jnp.int32)[:, None, None]
        s_ids = jnp.broadcast_to(
            jnp.arange(s, dtype=jnp.int32)[None, :, None],
            (b, s, cfg.top_k))
        pos_safe = jnp.where(fits, pos, cap)  # overflow slot dropped
        sidx = jnp.full((b, cfg.n_experts, cap + 1), -1, jnp.int32)
        sidx = sidx.at[gidx, expert_ids, pos_safe].set(s_ids)
        sidx = sidx[:, :, :cap]                       # (G, E, C)
        valid = sidx >= 0
        xe = x[jnp.arange(b, dtype=jnp.int32)[:, None, None],
               jnp.maximum(sidx, 0)]                  # (G, E, C, d)
        xe = jnp.where(valid[..., None], xe, 0)
    else:
        # dispatch (G, S, E, C) one-hot and combine weights
        disp = (jax.nn.one_hot(expert_ids, cfg.n_experts, dtype=x.dtype)
                [..., None]
                * jax.nn.one_hot(pos, cap, dtype=x.dtype)[..., None, :]
                * fits[..., None, None].astype(x.dtype))  # (G, S, k, E, C)
        combine = (disp * gate_vals[..., None, None].astype(x.dtype)
                   ).sum(2)                               # (G, S, E, C)
        disp = disp.sum(2)                                # (G, S, E, C)
        xe = jnp.einsum("gsd,gsec->gecd", x, disp)        # (G, E, C, d)
    xe = act_sharding.constrain(xe, act_sharding.DP, act_sharding.MDL)
    spec = policy.spec_for(f"{path}/experts")
    fn = activation(cfg.act)

    def expert_weights(w):
        """Raw stacked (E, d_in, d_out) experts fake-quant per call;
        prepared containers (quant.prepare) dequantize from storage —
        bit-exact to the dynamic value, no per-call quantization."""
        from repro.layers.mplinear import note_weight_quant
        from repro.quant.prepare import PreparedWeight
        if isinstance(w, PreparedWeight):
            return w.dequant()
        if spec.weight_bits:  # per-expert per-out-channel fake-quant
            from repro.quant.quantize import fake_quant
            note_weight_quant()
            return fake_quant(w.astype(jnp.float32), spec.weight_bits,
                              axis=-2)
        return w

    wg, wu, wd = (expert_weights(params["w_gate"]["w"]),
                  expert_weights(params["w_up"]["w"]),
                  expert_weights(params["w_down"]["w"]))
    g = jnp.einsum("gecd,edf->gecf", xe.astype(jnp.bfloat16),
                   wg.astype(jnp.bfloat16))
    u = jnp.einsum("gecd,edf->gecf", xe.astype(jnp.bfloat16),
                   wu.astype(jnp.bfloat16))
    h = fn(g.astype(jnp.float32)).astype(jnp.bfloat16) * u
    ye = jnp.einsum("gecf,efd->gecd", h, wd.astype(jnp.bfloat16))
    if cfg.dispatch == "gather":
        # combine: gather each (token, k)'s expert output, weight, sum
        flat = (expert_ids * cap + pos_safe.clip(0, cap - 1)).reshape(
            b, -1)                                    # (G, S*k)
        yk = jnp.take_along_axis(
            ye.reshape(b, cfg.n_experts * cap, d),
            flat[..., None], axis=1).reshape(b, s, cfg.top_k, d)
        gatesz = (gate_vals * fits).astype(ye.dtype)
        y = jnp.einsum("gskd,gsk->gsd", yk, gatesz).astype(x.dtype)
    else:
        y = jnp.einsum("gecd,gsec->gsd", ye.astype(x.dtype), combine)

    # aux load-balance loss (Switch): E * sum(frac_tokens * frac_probs)
    frac_tokens = (onehot.sum(2) > 0).astype(jnp.float32).mean((0, 1))
    frac_probs = probs.mean((0, 1))
    aux = cfg.n_experts * jnp.sum(frac_tokens * frac_probs)
    return y, aux
