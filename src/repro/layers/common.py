"""Shared building blocks: initializers, norms, RoPE, activation."""
from __future__ import annotations

import math
from typing import Optional, Tuple

import jax
import jax.numpy as jnp


def dense_init(key, d_in: int, d_out: int, dtype=jnp.float32,
               scale: Optional[float] = None) -> jax.Array:
    scale = scale if scale is not None else 1.0 / math.sqrt(d_in)
    return (jax.random.truncated_normal(key, -3, 3, (d_in, d_out),
                                        jnp.float32) * scale).astype(dtype)


def embed_init(key, vocab: int, d: int, dtype=jnp.float32) -> jax.Array:
    # 1/sqrt(d): unit-scale rows after the gemma-style sqrt(d) input
    # multiplier, and O(1) tied logits from RMS-normed hidden states.
    return (jax.random.truncated_normal(key, -3, 3, (vocab, d),
                                        jnp.float32)
            * (d ** -0.5)).astype(dtype)


def rms_norm(x: jax.Array, weight: jax.Array, eps: float = 1e-6,
             zero_centered: bool = False) -> jax.Array:
    dt = x.dtype
    x = x.astype(jnp.float32)
    var = jnp.mean(x * x, axis=-1, keepdims=True)
    x = x * jax.lax.rsqrt(var + eps)
    w = weight.astype(jnp.float32)
    if zero_centered:  # gemma-style (1 + w)
        w = 1.0 + w
    return (x * w).astype(dt)


def layer_norm(x: jax.Array, weight: jax.Array, bias: Optional[jax.Array],
               eps: float = 1e-5) -> jax.Array:
    dt = x.dtype
    x = x.astype(jnp.float32)
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.mean(jnp.square(x - mu), axis=-1, keepdims=True)
    x = (x - mu) * jax.lax.rsqrt(var + eps)
    x = x * weight.astype(jnp.float32)
    if bias is not None:
        x = x + bias.astype(jnp.float32)
    return x.astype(dt)


def apply_norm(kind: str, x, params, eps=1e-6):
    if kind == "rms":
        return rms_norm(x, params["w"], eps)
    if kind == "rms_zc":
        return rms_norm(x, params["w"], eps, zero_centered=True)
    if kind == "ln":
        return layer_norm(x, params["w"], params.get("b"), eps)
    raise ValueError(kind)


def norm_init(kind: str, d: int, dtype=jnp.float32):
    if kind in ("rms",):
        return {"w": jnp.ones((d,), dtype)}
    if kind in ("rms_zc",):
        return {"w": jnp.zeros((d,), dtype)}
    if kind == "ln":
        return {"w": jnp.ones((d,), dtype), "b": jnp.zeros((d,), dtype)}
    raise ValueError(kind)


def activation(name: str):
    return {"silu": jax.nn.silu, "gelu": jax.nn.gelu,
            "gelu_tanh": lambda x: jax.nn.gelu(x, approximate=True),
            "relu": jax.nn.relu}[name]


# ---------------------------------------------------------------- RoPE

def rope_freqs(head_dim: int, rotary_dim: int, theta: float) -> jax.Array:
    """Inverse frequencies for the rotary dims (rotary_dim <= head_dim)."""
    return 1.0 / (theta ** (jnp.arange(0, rotary_dim, 2, dtype=jnp.float32)
                            / rotary_dim))


def apply_rope(x: jax.Array, positions: jax.Array, theta: float = 10000.0,
               rotary_pct: float = 1.0) -> jax.Array:
    """x: (B, S, H, D); positions: (B, S) int32. Rotates the first
    rotary_pct * D dims (GPT-NeoX/llama convention, pairwise halves)."""
    b, s, h, d = x.shape
    rot = int(d * rotary_pct)
    rot -= rot % 2
    if rot == 0:
        return x
    inv = rope_freqs(d, rot, theta)                      # (rot/2,)
    ang = positions.astype(jnp.float32)[:, :, None] * inv  # (B,S,rot/2)
    cos = jnp.cos(ang)[:, :, None, :]
    sin = jnp.sin(ang)[:, :, None, :]
    x_rot, x_pass = x[..., :rot], x[..., rot:]
    x1, x2 = jnp.split(x_rot.astype(jnp.float32), 2, axis=-1)
    out1 = x1 * cos - x2 * sin
    out2 = x2 * cos + x1 * sin
    x_rot = jnp.concatenate([out1, out2], -1).astype(x.dtype)
    return jnp.concatenate([x_rot, x_pass], -1) if rot < d else x_rot


def softcap(x: jax.Array, cap: Optional[float]) -> jax.Array:
    """Gemma-2 logit soft-capping: cap * tanh(x / cap)."""
    if cap is None:
        return x
    return (cap * jnp.tanh(x.astype(jnp.float32) / cap)).astype(x.dtype)
