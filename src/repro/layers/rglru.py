"""RG-LRU recurrent block (Griffin / RecurrentGemma).

    r_t = sigmoid(W_a x_t + b_a)            (recurrence gate)
    i_t = sigmoid(W_x x_t + b_x)            (input gate)
    a_t = exp(-c * softplus(Lambda) * r_t)  (data-dependent decay, c=8)
    h_t = a_t h_{t-1} + sqrt(1 - a_t^2) * (i_t * x_t)

Training runs an associative scan over sequence chunks; decode is the
O(1) recurrence (long_500k-capable). The full recurrent block is
conv1d(width 4) -> RG-LRU inside a gated (GeGLU-style) branch pair, as in
the Griffin paper.
"""
from __future__ import annotations

import dataclasses
from typing import NamedTuple, Tuple

import jax
import jax.numpy as jnp

from repro.layers.common import dense_init
from repro.layers.mplinear import linear_init, mp_linear

_C = 8.0


@dataclasses.dataclass(frozen=True)
class RGLRUConfig:
    d_model: int
    d_rnn: int
    conv_width: int = 4


class RGLRUState(NamedTuple):
    h: jax.Array        # (B, d_rnn) recurrent state
    conv: jax.Array     # (B, conv_width - 1, d_rnn) conv tail


def init(key, cfg: RGLRUConfig, dtype=jnp.float32):
    ks = jax.random.split(key, 7)
    d, dr = cfg.d_model, cfg.d_rnn
    # Lambda init so decay a^c in [0.9, 0.999] (Griffin appendix)
    u = jax.random.uniform(ks[5], (dr,), jnp.float32, 0.9, 0.999)
    lam = jnp.log(jnp.expm1(-jnp.log(u) / _C))  # softplus^-1
    return {
        "w_in_rnn": linear_init(ks[0], d, dr, False, dtype),   # x branch
        "w_in_gate": linear_init(ks[1], d, dr, False, dtype),  # gate branch
        "w_out": linear_init(ks[2], dr, d, False, dtype),
        "conv_w": (jax.random.normal(ks[3], (cfg.conv_width, dr),
                                     jnp.float32) * 0.1).astype(dtype),
        "conv_b": jnp.zeros((dr,), dtype),
        "w_a": dense_init(ks[4], dr, dr, dtype),
        "b_a": jnp.zeros((dr,), dtype),
        "w_x": dense_init(ks[6], dr, dr, dtype),
        "b_x": jnp.zeros((dr,), dtype),
        "lambda": lam.astype(dtype),
    }


def init_state(batch: int, cfg: RGLRUConfig, dtype=jnp.float32
               ) -> RGLRUState:
    return RGLRUState(
        h=jnp.zeros((batch, cfg.d_rnn), jnp.float32),
        conv=jnp.zeros((batch, cfg.conv_width - 1, cfg.d_rnn), dtype),
    )


def _causal_conv(x, w, b, tail):
    """Depthwise causal conv1d. x: (B,S,dr); tail: (B,W-1,dr)."""
    wdt = x.dtype
    full = jnp.concatenate([tail.astype(wdt), x], axis=1)
    width = w.shape[0]
    out = jnp.zeros_like(x, dtype=jnp.float32)
    for i in range(width):
        seg = full[:, i:i + x.shape[1]]
        out = out + seg.astype(jnp.float32) * w[width - 1 - i].astype(
            jnp.float32)
    new_tail = full[:, -(width - 1):] if width > 1 else tail
    return (out + b.astype(jnp.float32)).astype(wdt), new_tail


def _gates(params, xr):
    r = jax.nn.sigmoid(xr.astype(jnp.float32)
                       @ params["w_a"].astype(jnp.float32)
                       + params["b_a"].astype(jnp.float32))
    i = jax.nn.sigmoid(xr.astype(jnp.float32)
                       @ params["w_x"].astype(jnp.float32)
                       + params["b_x"].astype(jnp.float32))
    log_a = -_C * jax.nn.softplus(
        params["lambda"].astype(jnp.float32)) * r
    a = jnp.exp(log_a)
    gated = jnp.sqrt(jnp.maximum(1.0 - jnp.exp(2 * log_a), 1e-12)) \
        * (i * xr.astype(jnp.float32))
    return a, gated


def _scan_rglru(a, b, h0):
    """h_t = a_t h_{t-1} + b_t via associative scan. a,b: (B,S,dr)."""
    def combine(u, v):
        au, bu = u
        av, bv = v
        return au * av, bu * av + bv

    a_s, b_s = jax.lax.associative_scan(combine, (a, b), axis=1)
    h = a_s * h0[:, None] + b_s
    return h, h[:, -1]


def forward(params, cfg: RGLRUConfig, x, state: RGLRUState, policy,
            path: str) -> Tuple[jax.Array, RGLRUState]:
    """Full recurrent block over (B, S, d)."""
    sp = policy.spec_for
    xr = mp_linear(params["w_in_rnn"], x, sp(f"{path}/w_in_rnn"), path=f"{path}/w_in_rnn")
    gate = mp_linear(params["w_in_gate"], x, sp(f"{path}/w_in_gate"), path=f"{path}/w_in_gate")
    xr, new_tail = _causal_conv(xr, params["conv_w"], params["conv_b"],
                                state.conv)
    a, b = _gates(params, xr)
    h, h_last = _scan_rglru(a, b, state.h)
    out = h * jax.nn.gelu(gate.astype(jnp.float32))
    out = mp_linear(params["w_out"], out.astype(x.dtype),
                    sp(f"{path}/w_out"), path=f"{path}/w_out")
    return out, RGLRUState(h_last, new_tail)


def decode_step(params, cfg: RGLRUConfig, x, state: RGLRUState, policy,
                path: str) -> Tuple[jax.Array, RGLRUState]:
    """x: (B, 1, d)."""
    sp = policy.spec_for
    xr = mp_linear(params["w_in_rnn"], x, sp(f"{path}/w_in_rnn"), path=f"{path}/w_in_rnn")
    gate = mp_linear(params["w_in_gate"], x, sp(f"{path}/w_in_gate"), path=f"{path}/w_in_gate")
    xr, new_tail = _causal_conv(xr, params["conv_w"], params["conv_b"],
                                state.conv)
    a, b = _gates(params, xr)
    h = a[:, 0] * state.h + b[:, 0]
    out = h[:, None] * jax.nn.gelu(gate.astype(jnp.float32))
    out = mp_linear(params["w_out"], out.astype(x.dtype),
                    sp(f"{path}/w_out"), path=f"{path}/w_out")
    return out, RGLRUState(h, new_tail)
