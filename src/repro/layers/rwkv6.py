"""RWKV-6 "Finch" time-mix and channel-mix layers (attention-free).

Time mix (per head, head size N):
    S_t = diag(w_t) S_{t-1} + k_t^T v_t          (S: N x N state)
    o_t = r_t (S_{t-1} + diag(u) k_t^T v_t)

with data-dependent decay w_t = exp(-exp(ww_t)) produced by a LoRA on the
token-shifted input (the paper's core novelty vs RWKV-5). Training uses a
chunked linear-attention algorithm (intra-chunk quadratic + inter-chunk
state carry through a lax.scan); decode is the O(1) recurrence — which is
why this arch runs the long_500k shape.

Mixed precision: projections take the policy; the recurrence itself runs
in f32 (tiny FLOP share, wide dynamic range — see DESIGN.md
§Arch-applicability).
"""
from __future__ import annotations

import dataclasses
from typing import NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.layers.common import dense_init
from repro.layers.mplinear import linear_init, mp_linear


@dataclasses.dataclass(frozen=True)
class RWKVConfig:
    d_model: int
    n_heads: int
    d_ff: int
    lora_rank: int = 32
    chunk: int = 64

    @property
    def head_dim(self):
        return self.d_model // self.n_heads


class RWKVState(NamedTuple):
    s: jax.Array       # (B, H, N, N) wkv state
    x_prev_t: jax.Array  # (B, d) last input of time-mix (token shift)
    x_prev_c: jax.Array  # (B, d) last input of channel-mix


def init(key, cfg: RWKVConfig, dtype=jnp.float32):
    ks = jax.random.split(key, 12)
    d, h, n = cfg.d_model, cfg.n_heads, cfg.head_dim
    p = {
        "w_r": linear_init(ks[0], d, d, False, dtype),
        "w_k": linear_init(ks[1], d, d, False, dtype),
        "w_v": linear_init(ks[2], d, d, False, dtype),
        "w_g": linear_init(ks[3], d, d, False, dtype),
        "w_o": linear_init(ks[4], d, d, False, dtype),
        # token-shift mixing coefficients (static part)
        "mu": {k: jnp.full((d,), 0.5, dtype)
               for k in ("r", "k", "v", "g", "w")},
        # decay LoRA: ww = tanh(x @ A) @ B + bias
        "w_lora_a": dense_init(ks[5], d, cfg.lora_rank, dtype),
        "w_lora_b": dense_init(ks[6], cfg.lora_rank, d, dtype),
        "w_bias": jnp.full((d,), -6.0, dtype),
        "u": (jax.random.normal(ks[7], (h, n), jnp.float32) * 0.1
              ).astype(dtype),
        # channel mix
        "c_key": linear_init(ks[8], d, cfg.d_ff, False, dtype),
        "c_val": linear_init(ks[9], cfg.d_ff, d, False, dtype),
        "c_rec": linear_init(ks[10], d, d, False, dtype),
        "c_mu": {k: jnp.full((d,), 0.5, dtype) for k in ("k", "r")},
    }
    return p


def init_state(batch: int, cfg: RWKVConfig, dtype=jnp.float32) -> RWKVState:
    h, n = cfg.n_heads, cfg.head_dim
    return RWKVState(
        s=jnp.zeros((batch, h, n, n), jnp.float32),
        x_prev_t=jnp.zeros((batch, cfg.d_model), dtype),
        x_prev_c=jnp.zeros((batch, cfg.d_model), dtype),
    )


def _token_shift(x, x_prev):
    """x: (B, S, d); x_prev: (B, d) -> shifted (B, S, d), new x_prev."""
    shifted = jnp.concatenate([x_prev[:, None], x[:, :-1]], axis=1)
    return shifted, x[:, -1]


def _mix(x, shifted, mu):
    return x + (shifted - x) * mu.astype(x.dtype)


def _projections(params, cfg: RWKVConfig, x, shifted, policy, path):
    b, s, d = x.shape
    h, n = cfg.n_heads, cfg.head_dim
    mu = params["mu"]
    xr = _mix(x, shifted, mu["r"])
    xk = _mix(x, shifted, mu["k"])
    xv = _mix(x, shifted, mu["v"])
    xg = _mix(x, shifted, mu["g"])
    xw = _mix(x, shifted, mu["w"])
    sp = policy.spec_for
    r = mp_linear(params["w_r"], xr, sp(f"{path}/w_r"), path=f"{path}/w_r").reshape(b, s, h, n)
    k = mp_linear(params["w_k"], xk, sp(f"{path}/w_k"), path=f"{path}/w_k").reshape(b, s, h, n)
    v = mp_linear(params["w_v"], xv, sp(f"{path}/w_v"), path=f"{path}/w_v").reshape(b, s, h, n)
    g = mp_linear(params["w_g"], xg, sp(f"{path}/w_g"), path=f"{path}/w_g")
    ww = (jnp.tanh(xw.astype(jnp.float32) @
                   params["w_lora_a"].astype(jnp.float32))
          @ params["w_lora_b"].astype(jnp.float32)
          + params["w_bias"].astype(jnp.float32))
    w = jnp.exp(-jnp.exp(ww)).reshape(b, s, h, n)  # decay in (0, 1)
    return r, k, v, g, w


def time_mix(params, cfg: RWKVConfig, x, state: RWKVState, policy,
             path: str) -> Tuple[jax.Array, RWKVState]:
    """Chunked parallel form over (B, S, d). Returns output + new state."""
    b, s, d = x.shape
    h, n = cfg.n_heads, cfg.head_dim
    shifted, x_last = _token_shift(x, state.x_prev_t)
    r, k, v, g, w = _projections(params, cfg, x, shifted, policy, path)
    u = params["u"].astype(jnp.float32)

    c = cfg.chunk
    pad = -s % c
    if pad:
        z = lambda a: jnp.pad(a, ((0, 0), (0, pad)) + ((0, 0),) * (a.ndim - 2))
        r, k, v = z(r), z(k), z(v)
        w = jnp.pad(w, ((0, 0), (0, pad), (0, 0), (0, 0)),
                    constant_values=1.0)  # decay 1 = no-op
    sp_ = s + pad
    nc = sp_ // c

    # (B, nc, c, H, N) -> f32
    rs = r.astype(jnp.float32).reshape(b, nc, c, h, n)
    ks_ = k.astype(jnp.float32).reshape(b, nc, c, h, n)
    vs = v.astype(jnp.float32).reshape(b, nc, c, h, n)
    ws = w.astype(jnp.float32).reshape(b, nc, c, h, n)

    # cumulative decay within chunk: P[t] = prod_{i<=t} w_i
    logw = jnp.log(jnp.maximum(ws, 1e-38))
    cum = jnp.cumsum(logw, axis=2)                  # (B,nc,c,H,N)
    p_all = jnp.exp(cum[:, :, -1:])                 # full-chunk decay

    def chunk_step(s0, inp):
        rs_, ks__, vs_, cum_, logw_, pall_ = inp
        # inter-chunk: contribution of carried state
        #   o_t += (r_t * prod_{i<=t-1} w) @ S0   (decay applied to r side)
        r_dec = rs_ * jnp.exp(cum_ - logw_)         # (B,c,H,N) exclusive
        o_inter = jnp.einsum("bchn,bhnm->bchm", r_dec, s0)
        # intra-chunk: A[t,i] = r_t . (k_i * prod_{i<j<=t} w) for i < t
        # k_i scaled by the inverse chunk-start decay; the exponent is
        # clamped at 40 — pairs needing more relative decay contribute
        # ~exp(-40) of the output (GLA-style stability compromise).
        k_sc = ks__ * jnp.exp(jnp.clip(-cum_, None, 40.0))
        att = jnp.einsum("bchn,bihn->bhci", r_dec, k_sc)
        tri = jnp.tril(jnp.ones((c, c), bool), k=-1)
        att = att * tri[None, None]
        o_intra = jnp.einsum("bhci,bihm->bchm", att, vs_)
        # bonus current-token term: r_t . (u * k_t) v_t
        bonus = jnp.einsum("bchn,bchn->bch", rs_, ks__ * u[None, None])
        o_cur = bonus[..., None] * vs_
        # state update: S = diag(prod w) S0 + sum_i (k_i * decay_to_end) v_i
        decay_to_end = jnp.exp(cum_[:, -1:] - cum_)  # prod_{j>i} w
        s_new = s0 * pall_[:, 0][..., None] + jnp.einsum(
            "bihn,bihm->bhnm", ks__ * decay_to_end, vs_)
        return s_new, o_inter + o_intra + o_cur

    inputs = (jnp.moveaxis(rs, 1, 0), jnp.moveaxis(ks_, 1, 0),
              jnp.moveaxis(vs, 1, 0),
              jnp.moveaxis(cum, 1, 0), jnp.moveaxis(logw, 1, 0),
              jnp.moveaxis(p_all, 1, 0))
    s_fin, outs = jax.lax.scan(chunk_step, state.s, inputs)
    out = jnp.moveaxis(outs, 0, 1).reshape(b, sp_, h, n)[:, :s]
    out = out.reshape(b, s, d).astype(x.dtype)
    out = out * jax.nn.silu(g.astype(jnp.float32)).astype(x.dtype)
    sp2 = policy.spec_for(f"{path}/w_o")
    out = mp_linear(params["w_o"], out, sp2, path=f"{path}/w_o")
    return out, RWKVState(s_fin, x_last, state.x_prev_c)


def time_mix_step(params, cfg: RWKVConfig, x, state: RWKVState, policy,
                  path: str) -> Tuple[jax.Array, RWKVState]:
    """O(1) single-token decode. x: (B, 1, d)."""
    b, _, d = x.shape
    h, n = cfg.n_heads, cfg.head_dim
    shifted = state.x_prev_t[:, None]
    r, k, v, g, w = _projections(params, cfg, x, shifted, policy, path)
    u = params["u"].astype(jnp.float32)
    r1, k1, v1, w1 = (a[:, 0].astype(jnp.float32) for a in (r, k, v, w))
    kv = jnp.einsum("bhn,bhm->bhnm", k1, v1)
    o = jnp.einsum("bhn,bhnm->bhm", r1,
                   state.s + u[None, :, :, None] * kv)
    s_new = state.s * w1[..., None] + kv
    out = o.reshape(b, 1, d).astype(x.dtype)
    out = out * jax.nn.silu(g.astype(jnp.float32)).astype(x.dtype)
    out = mp_linear(params["w_o"], out, policy.spec_for(f"{path}/w_o"), path=f"{path}/w_o")
    return out, RWKVState(s_new, x[:, -1], state.x_prev_c)


def channel_mix(params, cfg: RWKVConfig, x, state: RWKVState, policy,
                path: str, single_step: bool = False
                ) -> Tuple[jax.Array, RWKVState]:
    if single_step:
        shifted, x_last = state.x_prev_c[:, None], x[:, -1]
    else:
        shifted, x_last = _token_shift(x, state.x_prev_c)
    xk = _mix(x, shifted, params["c_mu"]["k"])
    xr = _mix(x, shifted, params["c_mu"]["r"])
    sp = policy.spec_for
    kk = mp_linear(params["c_key"], xk, sp(f"{path}/c_key"), path=f"{path}/c_key")
    kk = jnp.square(jax.nn.relu(kk.astype(jnp.float32))).astype(x.dtype)
    vv = mp_linear(params["c_val"], kk, sp(f"{path}/c_val"), path=f"{path}/c_val")
    rr = jax.nn.sigmoid(mp_linear(params["c_rec"], xr,
                                  sp(f"{path}/c_rec"), path=f"{path}/c_rec").astype(jnp.float32))
    out = (rr * vv.astype(jnp.float32)).astype(x.dtype)
    return out, RWKVState(state.s, state.x_prev_t, x_last)
