"""Mixed-precision linear layer: every model projection routes through
here, and the PrecisionPolicy decides which datapath executes it.

Paths:
  bf16 / fp32  — dense jnp.dot in the compute dtype.
  int8 / int4  — fake-quant (default; MXU + shardable + STE gradients)
                 or exact integer Pallas kernels (fidelity).
  fp16_ipu     — exact=False: fp16-cast operands, f32 accumulation (what
                 a w>=28 IPU computes up to accumulator granularity);
                 exact=True: bit-exact kernels.ops.mp_matmul.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.core.policy import PrecisionSpec
from repro.kernels import ops as kops
from repro.layers.common import dense_init
from repro.quant.quantize import fake_quant, quantize_symmetric


def linear_init(key, d_in: int, d_out: int, bias: bool = False,
                dtype=jnp.float32):
    p = {"w": dense_init(key, d_in, d_out, dtype)}
    if bias:
        p["b"] = jnp.zeros((d_out,), dtype)
    return p


def mp_linear(params, x: jax.Array, spec: PrecisionSpec,
              compute_dtype=jnp.bfloat16) -> jax.Array:
    """y = x @ w (+ b) under the precision spec. x: (..., d_in)."""
    w = params["w"]
    b = params.get("b")

    if spec.mode in ("bf16", "fp32"):
        dt = jnp.bfloat16 if spec.mode == "bf16" else jnp.float32
        y = jnp.dot(x.astype(dt), w.astype(dt),
                    preferred_element_type=jnp.float32)

    elif spec.mode in ("int8", "int4"):
        bits = spec.weight_bits
        if not spec.exact:
            # fake-quant both operands; per-out-channel weight scales
            wq = fake_quant(w.astype(jnp.float32), bits, axis=0)
            xq = fake_quant(x.astype(jnp.float32), bits if bits == 8 else 8)
            y = jnp.dot(xq.astype(compute_dtype), wq.astype(compute_dtype),
                        preferred_element_type=jnp.float32)
        else:
            lead = x.shape[:-1]
            x2 = x.reshape(-1, x.shape[-1])
            aq, sa = quantize_symmetric(x2, 8, axis=1)
            wq, sw = quantize_symmetric(w, bits, axis=0)
            y = kops.quantized_matmul(aq, wq, sa[:, 0], sw[0, :])
            y = y.reshape(*lead, -1)

    elif spec.mode == "fp16_ipu":
        if not spec.exact:
            y = jnp.dot(x.astype(jnp.float16), w.astype(jnp.float16),
                        preferred_element_type=jnp.float32)
        else:
            cfg = spec.ipu
            lead = x.shape[:-1]
            x2 = x.astype(jnp.float16).reshape(-1, x.shape[-1])
            y = kops.mp_matmul(x2, w.astype(jnp.float16), cfg,
                               backend="xla")
            y = y.astype(jnp.float32).reshape(*lead, -1)
    else:
        raise ValueError(spec.mode)

    if b is not None:
        y = y + b.astype(y.dtype)
    return y.astype(compute_dtype)
