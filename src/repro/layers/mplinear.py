"""Mixed-precision linear layer: every model projection routes through
here, and the PrecisionSpec decides which datapath executes it.

Dispatch is a registry (``spec.mode -> executor``) instead of an
``if/elif`` ladder: new modes (int12, per-group scales, fp8) plug in via
:func:`register_executor` without touching any call site. Every executor
consumes either a raw fp32 weight *or* a ``quant.prepare.PreparedWeight``
container holding the weight in its deployment storage format — the
prepared path skips the per-call weight quantization entirely (decode
stops re-quantizing static weights every token) and, for packed INT4,
feeds nibbles straight to the packed kernel.

Paths:
  bf16 / fp32  — dense jnp.dot in the compute dtype.
  int8 / int4  — fake-quant (default; MXU + shardable + STE gradients)
                 or exact integer Pallas kernels (fidelity). Prepared
                 weights dequantize (fake-quant path, bit-exact to the
                 dynamic quantize-dequantize) or ride the int kernels
                 directly (exact path).
  fp16_ipu     — exact=False: fp16-cast operands, f32 accumulation (what
                 a w>=28 IPU computes up to accumulator granularity);
                 exact=True: bit-exact kernels.ops.mp_matmul.

Activations mirror the weight story one PR later: int executors
calibrate an absmax per call (dynamic scale) unless the PreparedWeight
carries a *calibrated static scale* (``quant.calibrate`` ->
``PreparedWeight.act_scale``), in which case the per-token reduce is
skipped and the scalar scale rides straight into the quantized-matmul
epilogue.

The ``count_weight_quant`` / ``count_act_quant`` hooks count dynamic
(per-call) weight / activation quantizations entering a trace — the
observability surface the serving-smoke CI contract uses to prove
prepared replicas never quantize weights per decode step and calibrated
replicas never absmax-reduce activations. ``collect_act_stats`` is the
calibration-time hook: while open, every ``mp_linear`` call records its
input absmax under the projection's policy path.
"""
from __future__ import annotations

import contextlib
import os
from typing import Callable, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core.policy import PrecisionSpec
from repro.kernels import ops as kops
from repro.layers.common import dense_init
from repro.quant.prepare import PreparedWeight
from repro.quant.quantize import (FP_FORMATS, fake_quant, fp_dequantize,
                                  fp_quantize, quantize_symmetric)

# ------------------------------------------------------------- registry

_EXECUTORS: Dict[Tuple[str, Optional[str]], Callable] = {}
_EXECUTOR_VARIANT: Optional[str] = None


def register_executor(*modes: str, variant: Optional[str] = None):
    """Register an executor for one or more policy modes. The executor
    signature is ``fn(w, x, spec, compute_dtype) -> y`` where ``w`` is a
    raw (d_in, d_out) array or a PreparedWeight and ``x`` is
    (..., d_in); it returns (..., d_out) before bias/cast.

    ``variant`` registers an alternative datapath for the same mode
    (e.g. 'fused': the Pallas fused dequant-matmul executors); dispatch
    prefers the active variant (:func:`executor_variant`) and falls
    back to the base executor when the mode has no such variant."""
    def deco(fn):
        for m in modes:
            _EXECUTORS[(m, variant)] = fn
        return fn
    return deco


def executor_for(mode: str, variant: Optional[str] = None) -> Callable:
    if variant is not None:
        fn = _EXECUTORS.get((mode, variant))
        if fn is not None:
            return fn
    try:
        return _EXECUTORS[(mode, None)]
    except KeyError:
        known = sorted({m for m, v in _EXECUTORS if v is None})
        raise ValueError(
            f"no executor registered for precision mode {mode!r} "
            f"(known: {known})") from None


@contextlib.contextmanager
def executor_variant(name: Optional[str]):
    """Route every ``mp_linear`` dispatch traced while open through the
    named executor variant (modes without that variant keep their base
    executor). The serving engine opens this around its traced programs
    when ``EngineConfig.fused_executors`` resolves on — trace-time
    scoped, like the counter hooks."""
    global _EXECUTOR_VARIANT
    prev = _EXECUTOR_VARIANT
    _EXECUTOR_VARIANT = name
    try:
        yield
    finally:
        _EXECUTOR_VARIANT = prev


# ------------------------------------------- weight-quantization counter

_WEIGHT_QUANT_COUNT: Optional[List[int]] = None


@contextlib.contextmanager
def count_weight_quant():
    """Count dynamic weight quantizations traced while open. Prepared
    weights never hit this counter; raw weights under an int/fp16 spec
    bump it once per projection per traced forward."""
    global _WEIGHT_QUANT_COUNT
    prev = _WEIGHT_QUANT_COUNT
    box = [0]
    _WEIGHT_QUANT_COUNT = box
    try:
        yield box
    finally:
        _WEIGHT_QUANT_COUNT = prev


def note_weight_quant(n: int = 1):
    """Executors (and moe.forward) call this on the dynamic
    weight-quantize branch; a no-op outside count_weight_quant()."""
    if _WEIGHT_QUANT_COUNT is not None:
        _WEIGHT_QUANT_COUNT[0] += n


# ---------------------------------------- activation-quantization hooks

_ACT_QUANT_COUNT: Optional[List[int]] = None
_ACT_STATS: Optional[Dict[str, float]] = None


@contextlib.contextmanager
def count_act_quant():
    """Count dynamic activation-scale calibrations (per-call absmax
    reduces) traced while open. Calibrated containers (a PreparedWeight
    carrying ``act_scale``) never hit this counter; every other int
    projection bumps it once per traced forward."""
    global _ACT_QUANT_COUNT
    prev = _ACT_QUANT_COUNT
    box = [0]
    _ACT_QUANT_COUNT = box
    try:
        yield box
    finally:
        _ACT_QUANT_COUNT = prev


def note_act_quant(n: int = 1):
    """Executors call this on the dynamic activation-absmax branch; a
    no-op outside count_act_quant()."""
    if _ACT_QUANT_COUNT is not None:
        _ACT_QUANT_COUNT[0] += n


@contextlib.contextmanager
def collect_act_stats():
    """Record per-projection activation absmax while open (calibration).

    Yields a dict {policy path -> running absmax over every forward run
    inside the context}. Values arrive via ``jax.debug.callback`` so
    recording works inside ``lax.scan`` over stacked blocks (one record
    per executed iteration, concrete at runtime); callers should run
    their forwards eagerly and flush (``jax.effects_barrier``) before
    reading the dict."""
    global _ACT_STATS
    prev = _ACT_STATS
    stats: Dict[str, float] = {}
    _ACT_STATS = stats
    try:
        yield stats
    finally:
        _ACT_STATS = prev


def _note_act_absmax(path: Optional[str], x: jax.Array):
    if _ACT_STATS is None or path is None:
        return

    def record(amax):
        stats = _ACT_STATS
        if stats is not None:
            stats[path] = max(stats.get(path, 0.0), float(amax))

    jax.debug.callback(record, jnp.max(jnp.abs(x.astype(jnp.float32))))


# ------------------------------------------------------------ executors

def _weight_scale_vec(w: PreparedWeight) -> jax.Array:
    """(N,) per-out-channel scales from the stored keepdims layout."""
    return w.scale.reshape(-1)


@register_executor("bf16", "fp32")
def _dense_executor(w, x, spec: PrecisionSpec, compute_dtype):
    dt = jnp.bfloat16 if spec.mode == "bf16" else jnp.float32
    wf = w.dequant() if isinstance(w, PreparedWeight) else w
    return jnp.dot(x.astype(dt), wf.astype(dt),
                   preferred_element_type=jnp.float32)


@register_executor("int8", "int4")
def _int_executor(w, x, spec: PrecisionSpec, compute_dtype):
    bits = spec.weight_bits
    prepared = (isinstance(w, PreparedWeight)
                and w.weight_bits == bits)
    # calibrated static activation scale (quant.calibrate): quantize
    # against the stored grid instead of absmax-reducing per call
    act_scale = w.act_scale if prepared else None
    if not spec.exact:
        # fake-quant both operands; per-out-channel weight scales.
        # Prepared weights dequantize to the identical q * scale value;
        # staged containers (quant.prepare.stage_params, blocked
        # decode) already hold it in the compute dtype.
        if prepared and w.staged:
            wq = w.data
        elif prepared:
            wq = w.dequant()
        else:
            note_weight_quant()
            wraw = w.dequant() if isinstance(w, PreparedWeight) else w
            wq = fake_quant(wraw.astype(jnp.float32), bits, axis=0)
        if act_scale is None:
            note_act_quant()
        xq = fake_quant(x.astype(jnp.float32), 8, scale=act_scale)
        return jnp.dot(xq.astype(compute_dtype), wq.astype(compute_dtype),
                       preferred_element_type=jnp.float32)
    # exact integer kernel path: weight operands straight from storage
    # when prepared; activation scale static when calibrated (the scalar
    # rides straight into the quantized-matmul epilogue), absmax per
    # token row otherwise
    if prepared and w.staged:
        raise ValueError("staged containers carry dequantized operands; "
                         "exact integer kernels need int storage "
                         "(stage_params never stages exact specs)")
    lead = x.shape[:-1]
    x2 = x.reshape(-1, x.shape[-1])
    if act_scale is None:
        note_act_quant()
        aq, sa = quantize_symmetric(x2, 8, axis=1)
        sa = sa[:, 0]
    else:
        aq, sa = quantize_symmetric(x2, 8, scale=act_scale)
    if prepared and w.scale_groups > 1:
        # per-group scales vary along K: the column-scale epilogue
        # can't fold them, so the fused dequant kernel consumes the
        # stored operand directly and the act scale rides outside
        y = kops.fused_dequant_matmul(aq.astype(jnp.float32), w.data,
                                      w.scale, None, kind=w.kind)
        y = y * (sa[:, None] if sa.ndim else sa)
    elif prepared and w.kind == "int4_packed":
        y = kops.quantized_matmul_packed(aq, w.data, sa,
                                         _weight_scale_vec(w))
    elif prepared:
        y = kops.quantized_matmul(aq, w.data, sa,
                                  _weight_scale_vec(w))
    else:
        note_weight_quant()
        wraw = w.dequant() if isinstance(w, PreparedWeight) else w
        wq, sw = quantize_symmetric(wraw, bits, axis=0)
        y = kops.quantized_matmul(aq, wq, sa, sw[0, :])
    return y.reshape(*lead, -1)


_FP_STORAGE_KINDS = ("fp8", "fp4", "fp4_packed",
                     "staged_fp8", "staged_fp4")


@register_executor("fp8", "fp4")
def _fp_executor(w, x, spec: PrecisionSpec, compute_dtype):
    """fp8 (e4m3) / fp4 (e2m1) weight-storage tier: weights live as
    bit-field codes + scales and dequantize to the compute dtype;
    activations ride through unquantized (weight-only storage modes).
    Raw weights fake-quant through the codec per call (the dynamic
    control path); staged containers carry the pre-dequantized block
    operand."""
    if isinstance(w, PreparedWeight) and w.kind in _FP_STORAGE_KINDS:
        wf = w.data if w.staged else w.dequant()
    else:
        note_weight_quant()
        wraw = w.dequant() if isinstance(w, PreparedWeight) else w
        fmt = FP_FORMATS[spec.mode]
        codes, s = fp_quantize(wraw.astype(jnp.float32), fmt, axis=0)
        wf = fp_dequantize(codes, s, fmt)
    return jnp.dot(x.astype(compute_dtype), wf.astype(compute_dtype),
                   preferred_element_type=jnp.float32)


# ------------------------------------------------ fused Pallas variants

def _fused_backend() -> str:
    """Backend for the fused executors, resolved at trace time:
    'pallas' (default; interpret mode on CPU — what CI exercises) or
    'xla' via ``REPRO_FUSED_BACKEND`` — the identical-math reference
    path benchmarks use for CPU wall time, where interpreter overhead
    would drown the datapath being measured."""
    return os.environ.get("REPRO_FUSED_BACKEND", "pallas")


@register_executor("int8", "int4", variant="fused")
def _int_fused_executor(w, x, spec: PrecisionSpec, compute_dtype):
    """Fused int datapath (kernels.fused): stored int8 rows / packed
    nibbles + scales enter the kernel as operands, the calibrated
    static activation scale quantizes in-register, and the epilogue is
    fused — no staged compute-dtype operand, no materialized int
    activation tensor. Exact per-channel specs are bit-exact to the
    staged exact path; fake-quant specs match it to f32-vs-bf16
    rounding. Falls back to the base executor when the projection has
    no prepared storage or no calibrated static scale (dynamic
    per-token scales need the per-row epilogue)."""
    bits = spec.weight_bits
    fusable = (isinstance(w, PreparedWeight) and w.weight_bits == bits
               and not w.staged and w.act_scale is not None
               and w.data.ndim == 2)
    if not fusable:
        return _int_executor(w, x, spec, compute_dtype)
    lead = x.shape[:-1]
    x2 = x.astype(jnp.float32).reshape(-1, x.shape[-1])
    sa = w.act_scale
    backend = _fused_backend()
    if spec.exact and w.scale_groups == 1:
        y = kops.fused_quantized_matmul(x2, w.data, w.scale, sa,
                                        kind=w.kind, backend=backend)
    elif spec.exact:
        y = kops.fused_dequant_matmul(x2, w.data, w.scale, sa,
                                      kind=w.kind, act="quant",
                                      backend=backend)
    else:
        y = kops.fused_dequant_matmul(x2, w.data, w.scale, sa,
                                      kind=w.kind, act="qdq",
                                      backend=backend)
    return y.reshape(*lead, -1)


@register_executor("fp8", "fp4", variant="fused")
def _fp_fused_executor(w, x, spec: PrecisionSpec, compute_dtype):
    """Fused fp8/fp4 datapath: stored e4m3/e2m1 codes decode and
    dequantize in-register inside the kernel block loop (per-channel or
    per-group scales); no staged operand. Falls back to the base
    executor for raw/staged weights."""
    fusable = (isinstance(w, PreparedWeight)
               and w.kind in ("fp8", "fp4", "fp4_packed")
               and w.data.ndim == 2)
    if not fusable:
        return _fp_executor(w, x, spec, compute_dtype)
    lead = x.shape[:-1]
    x2 = x.astype(jnp.float32).reshape(-1, x.shape[-1])
    y = kops.fused_dequant_matmul(x2, w.data, w.scale, None,
                                  kind=w.kind, act="none",
                                  backend=_fused_backend())
    return y.reshape(*lead, -1)


@register_executor("fp16_ipu")
def _fp16_ipu_executor(w, x, spec: PrecisionSpec, compute_dtype):
    if isinstance(w, PreparedWeight) and w.kind == "fp16":
        w16 = w.data
    else:
        note_weight_quant()
        wraw = w.dequant() if isinstance(w, PreparedWeight) else w
        w16 = wraw.astype(jnp.float16)
    if not spec.exact:
        return jnp.dot(x.astype(jnp.float16), w16,
                       preferred_element_type=jnp.float32)
    cfg = spec.ipu
    lead = x.shape[:-1]
    x2 = x.astype(jnp.float16).reshape(-1, x.shape[-1])
    y = kops.mp_matmul(x2, w16, cfg, backend="xla")
    return y.astype(jnp.float32).reshape(*lead, -1)


# -------------------------------------------------------------- wrapper

def linear_init(key, d_in: int, d_out: int, bias: bool = False,
                dtype=jnp.float32):
    p = {"w": dense_init(key, d_in, d_out, dtype)}
    if bias:
        p["b"] = jnp.zeros((d_out,), dtype)
    return p


def mp_linear(params, x: jax.Array, spec: PrecisionSpec,
              compute_dtype=jnp.bfloat16,
              path: Optional[str] = None) -> jax.Array:
    """y = x @ w (+ b) under the precision spec. x: (..., d_in).

    ``path`` is the projection's policy path (the same string the call
    site resolved the spec with) — only consumed by the calibration
    hook (``collect_act_stats``) to key activation statistics."""
    _note_act_absmax(path, x)
    y = executor_for(spec.mode, _EXECUTOR_VARIANT)(
        params["w"], x, spec, compute_dtype)
    b = params.get("b")
    if b is not None:
        y = y + b.astype(y.dtype)
    return y.astype(compute_dtype)
