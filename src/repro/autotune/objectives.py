"""The three scoring axes of the planner, as cacheable eval points.

Every function here is a module-level ``repro.exp`` eval target
(referenced as ``"repro.autotune.objectives:<fn>"``): primitives in,
JSON-serializable dict out, and an *explicit* ``seed`` parameter that is
part of the cache key — every sampled quantity (simulator exponent
draws, probe model init, probe tokens) derives from it, so cached scores
are bitwise identical between ``--jobs N`` and serial runs.

Axes:
  * ``cycles_point``     — execution cycles of one projection group on
    the MC-IPU tile (``core.simulator``).
  * ``efficiency_point`` — TOPS/mm^2 and TOPS/W of the candidate's
    hardware point on that workload (``core.area_power``).
  * ``accuracy_point``   — accuracy proxy: the Theorem-1 analytic bound
    (``core.error_bounds``) plus a fake-quant forward-divergence probe
    on the real (family-preserving reduced) model.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Dict, Optional

from repro.configs import get_config, reduced
from repro.core import simulator as sim
from repro.core.workloads import ConvLayer
from repro.models.registry import ProjGroup, projection_groups

_TYPES = {"int4": sim.INT4, "int8": sim.INT8, "fp16_ipu": sim.FP16,
          "bf16": sim.FP16, "fp8": sim.FP8, "fp4": sim.FP4}


def _cfg(arch: str, shapes: str):
    if shapes == "reduced":
        return reduced(arch)
    if shapes == "full":
        return get_config(arch)
    raise ValueError(f"shapes must be 'full' or 'reduced', got {shapes!r}")


def _group(arch: str, group: str, shapes: str) -> ProjGroup:
    cfg = _cfg(arch, shapes)
    for g in projection_groups(cfg):
        if g.name == group:
            return g
    raise KeyError(f"{arch} has no projection group {group!r}")


def _layer(g: ProjGroup, seq: int) -> ConvLayer:
    # a matmul is the 1x1-conv special case: C=d_in, K=d_out, Ho=tokens
    return ConvLayer(g.name, c=g.d_in, k=g.d_out, ho=seq, wo=1, r=1, s=1,
                     count=g.count)


def _tile(mode: str, w: int, sw_precision: int,
          cluster: Optional[int]) -> sim.TileConfig:
    return dataclasses.replace(sim.BIG_TILE, adder_w=w,
                               cluster_size=cluster,
                               sw_precision=sw_precision)


def cycles_point(arch: str, group: str, mode: str, w: int,
                 sw_precision: int, cluster: int, seq: int = 1,
                 seed: int = 0, shapes: str = "full") -> Dict:
    """Cycles for one projection group under one candidate."""
    g = _group(arch, group, shapes)
    layer = _layer(g, seq)
    stats = sim.simulate_network(
        [layer], _tile(mode, w, sw_precision, cluster), _TYPES[mode],
        sim.FORWARD_SOURCE, seed=seed)
    return {"cycles": stats.cycles, "ideal_cycles": stats.ideal_cycles,
            "mc_factor": stats.slowdown, "macs": layer.macs}


def efficiency_point(arch: str, group: str, mode: str, w: int,
                     sw_precision: int, cluster: int, seq: int = 1,
                     seed: int = 0, shapes: str = "full") -> Dict:
    """TOPS/mm^2 and TOPS/W of the candidate's MC-IPU hardware point on
    this group's workload (area model needs the simulator-derived mean
    alignment cycles per iteration, so this point samples them too)."""
    from repro.core import area_power as ap
    g = _group(arch, group, shapes)
    types = _TYPES[mode]
    tile = _tile(mode, w, sw_precision, cluster)
    mc = 1.0
    if types.is_fp and w < tile.sw_precision:
        stats = sim.simulate_network([_layer(g, seq)], tile, types,
                                     sim.FORWARD_SOURCE, seed=seed)
        mc = stats.slowdown
    design = ap.IPUDesign(
        f"plan_{mode}_w{w}", mult_a=4, mult_b=4, adder_w=w,
        fp_support=True, tile=tile,
        cluster_size=cluster if types.is_fp else None, fp_mc_factor=mc)
    tops = ap.throughput_tops(design, types)
    tops_mm2, tops_w = ap.efficiency(design, types)
    return {"tops": tops, "tops_per_mm2": tops_mm2, "tops_per_w": tops_w,
            "mc_factor": mc}


# --------------------------------------------------------------- accuracy

def analytic_proxy(mode: str, w: int, sw_precision: int) -> float:
    """First-order relative-error scale of the datapath (dimensionless).
    Also the accuracy axis of the serving router's replica cost model
    (``repro.serving.router.replica_cost``)."""
    if mode == "bf16":
        # bf16's own 8-bit mantissa rounding noise
        return 2.0 ** -8 / math.sqrt(12.0)
    if mode in ("int4", "int8"):
        bits = 4 if mode == "int4" else 8
        # symmetric absmax fake-quant: step ~ 2^(1-bits), RMS step/sqrt(12)
        return 2.0 ** (1 - bits) / math.sqrt(12.0)
    if mode in ("fp8", "fp4"):
        # fp storage codecs: relative step of the mantissa grid is
        # 2^-(man_bits+1) at the bin midpoint; RMS step/sqrt(12). The
        # exponent field tracks magnitude, so unlike the int modes the
        # error is relative rather than absmax-absolute — which is the
        # whole point of the tier — but as a dimensionless proxy the
        # mantissa-grid RMS is the comparable first-order number.
        man = 3 if mode == "fp8" else 1
        return 2.0 ** -(man + 1) / math.sqrt(12.0)
    # fp16_ipu: Theorem-1 FP-IP bound at unit product scale, relative to
    # the n-product sum, plus fp16's own mantissa noise floor
    from repro.core.error_bounds import fp_ip_bound
    n = 16
    bound = float(fp_ip_bound(min(w, sw_precision), max_exp=0, n=n)) / n
    return bound + 2.0 ** -11 / math.sqrt(12.0)


def _probe_policy_name(arch: str, group: str, mode: str, w: int,
                       sw_precision: int) -> str:
    return f"_probe/{arch}/{group}/{mode}/w{w}/p{sw_precision}"


def divergence_probe(arch: str, group: str, mode: str, w: int,
                     sw_precision: int, seed: int = 0,
                     probe_batch: int = 2, probe_seq: int = 16) -> float:
    """Mean token KL between the bf16 reference forward and a forward
    with *only this group* flipped to the candidate, on the
    family-preserving reduced model — a measured, end-to-end sensitivity
    signal the analytic bound cannot provide."""
    import jax
    import jax.numpy as jnp
    from repro.autotune.candidates import exact_for
    from repro.autotune.plan import PlanRule
    from repro.configs.base import InputShape
    from repro.core.policy import (POLICIES, PrecisionPolicy,
                                   PrecisionSpec, register_policy)
    from repro.models import registry

    cfg = reduced(arch)
    g = _group(arch, group, "reduced")
    rule = PlanRule(group=g.name, pattern=g.pattern, mode=mode, w=w,
                    sw_precision=sw_precision, exact=exact_for(mode, w))
    name = _probe_policy_name(arch, group, mode, w, sw_precision)
    register_policy(PrecisionPolicy(
        name, rules=((g.pattern, rule.spec()),),
        default=PrecisionSpec("bf16")))

    def logits_for(policy_name: str):
        c = dataclasses.replace(cfg, precision_policy=policy_name)
        api = registry.build(c)
        params = api.init(jax.random.PRNGKey(seed))
        shape = InputShape("probe", probe_seq, probe_batch, "prefill")
        batch = registry.materialize_batch(c, shape, seed=seed)
        caches = api.init_cache(probe_batch, probe_seq)
        logits, _ = api.prefill(params, batch, caches)
        return jnp.asarray(logits, jnp.float32)

    try:
        base = jax.nn.log_softmax(logits_for("bf16"), axis=-1)
        cand = jax.nn.log_softmax(logits_for(name), axis=-1)
    finally:
        # probe policies are transient: never leave them resolvable (or
        # accumulating) in the global registry
        POLICIES.pop(name, None)
    kl = jnp.sum(jnp.exp(base) * (base - cand), axis=-1)
    return float(jnp.mean(kl))


def accuracy_point(arch: str, group: str, mode: str, w: int,
                   sw_precision: int, seed: int = 0,
                   probe: bool = True) -> Dict:
    """Accuracy proxy of one candidate on one group: analytic bound +
    (optionally) the measured forward-divergence probe. ``acc_proxy`` is
    what the search minimizes; additive across groups by construction.

    Deliberately takes no ``seq``/``shapes``: the probe always runs the
    reduced config at its own fixed shape, so those axes must not enter
    the cache key (they would orphan the expensive model probes)."""
    bound = analytic_proxy(mode, w, sw_precision)
    div = 0.0
    if probe and mode != "bf16":
        div = divergence_probe(arch, group, mode, w, sw_precision,
                               seed=seed)
    # measured divergence dominates; the analytic bound is a tiebreaker
    # between candidates the tiny probe cannot distinguish
    return {"bound_rel": bound, "divergence": div,
            "acc_proxy": div + 1e-3 * bound}
