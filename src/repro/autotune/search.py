"""Plan search: cached score table -> greedy descent -> Pareto frontier.

Every (projection group x candidate) score is one ``repro.exp`` point
(content-addressed, shared across runs and job counts), so the search
itself is pure arithmetic over the table: re-running with a warm cache
executes zero simulator/model evaluations.

Search procedure:
  1. score all (group, candidate) pairs on the three axes;
  2. seed a plan pool with every *uniform* plan (one candidate
     everywhere) — the classic serving presets fall out as special
     cases;
  3. greedy ratio descent from the all-bf16 plan: repeatedly apply the
     single group-candidate swap with the best cycles-saved per unit
     accuracy-proxy cost, snapshotting every step — the trajectory
     traces the accuracy/performance curve;
  4. keep the non-dominated plans (minimize cycles, minimize accuracy
     proxy, maximize TOPS/W) as the frontier, and select the fastest
     plan whose accuracy proxy stays within budget (default: no worse
     than uniform INT8).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Sequence, Tuple

from repro import exp
from repro.autotune.candidates import Candidate
from repro.autotune.plan import PlanRule, PrecisionPlan
from repro.models.registry import ProjGroup

_OBJ = "repro.autotune.objectives"


@dataclasses.dataclass
class ScoreTable:
    """Merged per-(group, candidate) scores from the three objectives."""

    scores: Dict[Tuple[str, str], Dict]
    groups: Tuple[ProjGroup, ...]
    candidates: Tuple[Candidate, ...]

    def score(self, group: str, cand: Candidate) -> Dict:
        return self.scores[(group, cand.key())]


def _zip_axes(pairs: Sequence[Tuple[ProjGroup, Candidate]]) -> Dict:
    return {
        "group": [g.name for g, _ in pairs],
        "mode": [c.mode for _, c in pairs],
        "w": [c.w for _, c in pairs],
        "sw_precision": [c.sw_precision for _, c in pairs],
        "cluster": [c.cluster for _, c in pairs],
    }


def build_scores(arch: str, groups: Sequence[ProjGroup],
                 candidates: Sequence[Candidate],
                 engine: Optional[exp.EngineConfig] = None,
                 seq: int = 1, seed: int = 0, shapes: str = "full",
                 probe: bool = True) -> ScoreTable:
    """Evaluate (or fetch from cache) every group x candidate score."""
    engine = engine or exp.EngineConfig()
    pairs = [(g, c) for g in groups for c in candidates]
    fixed = {"arch": arch, "seq": seq, "seed": seed, "shapes": shapes}

    table: Dict[Tuple[str, str], Dict] = {
        (g.name, c.key()): {} for g, c in pairs}

    for sweep_name, fn, extra_fixed in (
            ("autotune_cycles", f"{_OBJ}:cycles_point", {}),
            ("autotune_efficiency", f"{_OBJ}:efficiency_point", {})):
        spec = exp.SweepSpec(name=sweep_name, fn=fn, mode="zip",
                             axes=_zip_axes(pairs),
                             fixed={**fixed, **extra_fixed})
        results, _ = exp.run_sweep(spec, engine)
        for (g, c), (_, value) in zip(pairs, results):
            table[(g.name, c.key())].update(value)

    # accuracy is cluster-independent: dedupe the hardware axis so the
    # (expensive) model probe runs once per (group, mode, w, P)
    acc_pairs: List[Tuple[ProjGroup, Candidate]] = []
    seen = set()
    for g, c in pairs:
        k = (g.name, c.mode, c.w, c.sw_precision)
        if k not in seen:
            seen.add(k)
            acc_pairs.append((g, c))
    axes = _zip_axes(acc_pairs)
    del axes["cluster"]
    # accuracy_point's key carries only (arch, seed, probe): the probe
    # shape is fixed, so seq/shapes must not fragment its cache entries
    spec = exp.SweepSpec(
        name="autotune_accuracy", fn=f"{_OBJ}:accuracy_point", mode="zip",
        axes=axes, fixed={"arch": arch, "seed": seed, "probe": probe})
    results, _ = exp.run_sweep(spec, engine)
    acc = {(g.name, c.mode, c.w, c.sw_precision): v
           for (g, c), (_, v) in zip(acc_pairs, results)}
    for g, c in pairs:
        table[(g.name, c.key())].update(
            acc[(g.name, c.mode, c.w, c.sw_precision)])

    return ScoreTable(table, tuple(groups), tuple(candidates))


# ---------------------------------------------------------------- metrics

Assignment = Dict[str, Candidate]   # group name -> candidate


def plan_metrics(table: ScoreTable, assign: Assignment) -> Dict:
    """Compose per-group scores into whole-plan metrics. Cycles and the
    accuracy proxy are additive; efficiency aggregates time-weighted
    (total MACs over total compute time across heterogeneous layers)."""
    cycles = ideal = acc = 0.0
    macs_tot = 0.0
    t_mm2 = t_w = 0.0   # sum of macs / per-layer TOPS (time in mm2/W form)
    for gname, cand in assign.items():
        s = table.score(gname, cand)
        cycles += s["cycles"]
        ideal += s["ideal_cycles"]
        acc += s["acc_proxy"]
        macs = float(s["macs"])
        macs_tot += macs
        t_mm2 += macs / s["tops_per_mm2"]
        t_w += macs / s["tops_per_w"]
    return {
        "cycles": cycles,
        "ideal_cycles": ideal,
        "acc_proxy": acc,
        "tops_per_mm2": macs_tot / t_mm2 if t_mm2 else 0.0,
        "tops_per_w": macs_tot / t_w if t_w else 0.0,
        "modes": {g: c.mode for g, c in sorted(assign.items())},
    }


# ----------------------------------------------------------------- search

def greedy_descent(table: ScoreTable, start: Assignment,
                   max_steps: int = 256) -> List[Assignment]:
    """Ratio-greedy: at each step apply the single swap with the best
    cycles-saved per accuracy cost (swaps that improve both always win).
    Returns the trajectory including the start point; every step strictly
    reduces total cycles, so termination is guaranteed."""
    traj = [dict(start)]
    cur = dict(start)
    for _ in range(max_steps):
        best = None   # (ratio_key, group, cand)
        for g in table.groups:
            s_cur = table.score(g.name, cur[g.name])
            for cand in table.candidates:
                if cand == cur[g.name]:
                    continue
                s = table.score(g.name, cand)
                d_cyc = s["cycles"] - s_cur["cycles"]
                if d_cyc >= 0:
                    continue
                d_acc = s["acc_proxy"] - s_cur["acc_proxy"]
                # strictly-improving swaps rank above any trade-off;
                # among trade-offs, maximize cycles saved per acc cost
                ratio = (float("inf") if d_acc <= 0
                         else -d_cyc / d_acc)
                key = (ratio, -d_cyc)
                if best is None or key > best[0]:
                    best = (key, g.name, cand)
        if best is None:
            break
        cur[best[1]] = best[2]
        traj.append(dict(cur))
    return traj


def pareto_front(plans: List[Dict]) -> List[Dict]:
    """Non-dominated filter: minimize cycles and acc_proxy, maximize
    TOPS/W. Ties collapse to the first occurrence."""
    def dominates(a, b):
        am, bm = a["metrics"], b["metrics"]
        no_worse = (am["cycles"] <= bm["cycles"]
                    and am["acc_proxy"] <= bm["acc_proxy"]
                    and am["tops_per_w"] >= bm["tops_per_w"])
        better = (am["cycles"] < bm["cycles"]
                  or am["acc_proxy"] < bm["acc_proxy"]
                  or am["tops_per_w"] > bm["tops_per_w"])
        return no_worse and better

    front = []
    for p in plans:
        if any(dominates(q, p) for q in plans):
            continue
        if any(q["assignment"] == p["assignment"] for q in front):
            continue
        front.append(p)
    return front


def _plan_record(name: str, table: ScoreTable, assign: Assignment) -> Dict:
    return {"name": name,
            "assignment": {g: c.key() for g, c in sorted(assign.items())},
            "metrics": plan_metrics(table, assign)}


def _rules_for(table: ScoreTable, assign: Assignment) -> Tuple[PlanRule, ...]:
    from repro.autotune.candidates import exact_for
    return tuple(
        PlanRule(group=g.name, pattern=g.pattern,
                 mode=assign[g.name].mode, w=assign[g.name].w,
                 sw_precision=assign[g.name].sw_precision,
                 cluster=assign[g.name].cluster,
                 exact=exact_for(assign[g.name].mode, assign[g.name].w),
                 group_size=assign[g.name].group_size)
        for g in table.groups)


def search_plan(arch: str, table: ScoreTable,
                acc_budget: Optional[float] = None,
                name: Optional[str] = None) -> PrecisionPlan:
    """Full search over a score table -> a PrecisionPlan artifact whose
    frontier holds every non-dominated assignment found."""
    pool: List[Dict] = []
    by_name: Dict[str, Assignment] = {}

    def add(pname: str, assign: Assignment):
        if assign in by_name.values():
            return
        by_name[pname] = dict(assign)
        pool.append(_plan_record(pname, table, assign))

    for cand in table.candidates:
        add(f"uniform_{cand.key()}",
            {g.name: cand for g in table.groups})

    bf16 = next((c for c in table.candidates if c.mode == "bf16"),
                table.candidates[0])
    traj = greedy_descent(table, {g.name: bf16 for g in table.groups})
    for i, assign in enumerate(traj[1:], 1):
        add(f"greedy_step{i}", assign)

    front = pareto_front(pool)
    front.sort(key=lambda p: p["metrics"]["cycles"])

    if acc_budget is None:
        # default budget: no less accurate than quantizing everything to
        # INT8 (the standard serving baseline); falls back to the median
        # frontier accuracy when INT8 isn't in the candidate set
        int8 = next((p for p in pool
                     if p["name"] == "uniform_int8"), None)
        if int8 is not None:
            acc_budget = int8["metrics"]["acc_proxy"]
        else:
            accs = sorted(p["metrics"]["acc_proxy"] for p in front)
            acc_budget = accs[len(accs) // 2]

    eligible = [p for p in front
                if p["metrics"]["acc_proxy"] <= acc_budget * (1 + 1e-9)]
    selected = (min(eligible, key=lambda p: p["metrics"]["cycles"])
                if eligible else
                min(front, key=lambda p: p["metrics"]["acc_proxy"]))
    assign = by_name[selected["name"]]

    return PrecisionPlan(
        name=name or f"{arch.replace('-', '_').replace('.', '_')}_auto",
        arch=arch,
        rules=_rules_for(table, assign),
        default_mode="bf16",
        metrics=selected["metrics"],
        frontier=tuple(front),
        meta={"selected_from": selected["name"],
              "acc_budget": acc_budget,
              "n_pool": len(pool),
              "n_groups": len(table.groups),
              "n_candidates": len(table.candidates)},
    )
