"""Candidate enumeration: the joint space the planner searches.

A :class:`Candidate` is one per-layer precision option — an operand mode
(INT4/INT8 quantized, the approximate FP16 IPU datapath, or plain BF16)
crossed with the MC-IPU configuration that executes it (adder precision
``w``, software precision ``P``, cluster size; paper §3.2–3.3). INT and
BF16 candidates are canonicalized to one hardware point each (no
alignment hardware / wide-adder reference) so the score cache never
fragments over parameters that cannot change their cost.
"""
from __future__ import annotations

import dataclasses
import itertools
from typing import Dict, List, Sequence, Tuple

from repro.configs.base import ModelConfig
from repro.models.registry import ProjGroup, projection_groups

# The wide-adder reference point: a 38-bit tree serves any FP16
# alignment in one cycle (simulator baseline; §4.1).
WIDE_W = 38


@dataclasses.dataclass(frozen=True)
class Candidate:
    """One per-layer precision option. Hashable, canonically encodable
    (frozen dataclass of primitives) — usable directly as sweep-axis
    values and cache-key material."""

    mode: str                 # int4 | int8 | fp16_ipu | bf16
    w: int = 16               # MC-IPU adder precision
    sw_precision: int = 28    # software precision P (FP32 accumulation)
    cluster: int = 1          # intra-tile cluster size (§3.3)

    def __post_init__(self):
        if self.mode not in ("int4", "int8", "fp16_ipu", "bf16"):
            raise ValueError(f"unknown candidate mode {self.mode!r}")

    def key(self) -> str:
        if self.mode in ("int4", "int8", "bf16"):
            return self.mode
        return f"{self.mode}_w{self.w}_p{self.sw_precision}_c{self.cluster}"


def exact_for(mode: str, w: int) -> bool:
    """Whether a candidate must execute on the bit-exact kernel path.
    fp16_ipu below w=28 is *not* approximated by the fp16-cast matmul
    (§3.1: indistinguishable only at w >= 28), so both the divergence
    probe and the emitted plan rules route it through kernels.ops —
    measured accuracy always describes the datapath that serves."""
    return mode == "fp16_ipu" and w < 28


def canonical(mode: str, w: int = 16, sw_precision: int = 28,
              cluster: int = 1) -> Candidate:
    """Canonicalize hardware axes that are meaningless for a mode: INT
    datapaths never align (any w serves them; pin the narrow INT point),
    and bf16 is the wide-adder single-cycle reference."""
    if mode in ("int4", "int8"):
        return Candidate(mode, w=16, sw_precision=28, cluster=1)
    if mode == "bf16":
        return Candidate(mode, w=WIDE_W, sw_precision=28, cluster=1)
    return Candidate(mode, w=w, sw_precision=sw_precision, cluster=cluster)


def default_candidates(widths: Sequence[int] = (12, 16, 20, 28),
                       clusters: Sequence[int] = (1,),
                       modes: Sequence[str] = ("bf16", "fp16_ipu", "int8",
                                               "int4"),
                       ) -> Tuple[Candidate, ...]:
    """The default per-layer search grid. fp16_ipu expands over the
    (w, cluster) hardware axes; INT/BF16 contribute one point each."""
    out: List[Candidate] = []
    for mode in modes:
        if mode == "fp16_ipu":
            for w, c in itertools.product(widths, clusters):
                out.append(canonical(mode, w=w, cluster=c))
        else:
            out.append(canonical(mode))
    # dedupe, preserving order (canonicalization can collapse points)
    seen: Dict[Candidate, None] = {}
    for c in out:
        seen.setdefault(c)
    return tuple(seen)


def groups_for(cfg: ModelConfig) -> Tuple[ProjGroup, ...]:
    """The tunable projection groups of an architecture (re-exported so
    the CLI and search only import this module)."""
    return projection_groups(cfg)
