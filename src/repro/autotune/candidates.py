"""Candidate enumeration: the joint space the planner searches.

A :class:`Candidate` is one per-layer precision option — an operand mode
(INT4/INT8 quantized, the approximate FP16 IPU datapath, or plain BF16)
crossed with the MC-IPU configuration that executes it (adder precision
``w``, software precision ``P``, cluster size; paper §3.2–3.3). INT and
BF16 candidates are canonicalized to one hardware point each (no
alignment hardware / wide-adder reference) so the score cache never
fragments over parameters that cannot change their cost.
"""
from __future__ import annotations

import dataclasses
import itertools
from typing import Dict, List, Optional, Sequence, Tuple

from repro.configs.base import ModelConfig
from repro.models.registry import ProjGroup, projection_groups

# The wide-adder reference point: a 38-bit tree serves any FP16
# alignment in one cycle (simulator baseline; §4.1).
WIDE_W = 38


@dataclasses.dataclass(frozen=True)
class Candidate:
    """One per-layer precision option. Hashable, canonically encodable
    (frozen dataclass of primitives) — usable directly as sweep-axis
    values and cache-key material."""

    mode: str                 # int4 | int8 | fp8 | fp4 | fp16_ipu | bf16
    w: int = 16               # MC-IPU adder precision
    sw_precision: int = 28    # software precision P (FP32 accumulation)
    cluster: int = 1          # intra-tile cluster size (§3.3)
    # per-group weight scales for the storage modes (int/fp8/fp4):
    # K/group_size scale groups along the contraction dim; None keeps
    # per-out-channel scales (the serving default)
    group_size: Optional[int] = None

    def __post_init__(self):
        if self.mode not in ("int4", "int8", "fp8", "fp4", "fp16_ipu",
                             "bf16"):
            raise ValueError(f"unknown candidate mode {self.mode!r}")
        if self.group_size is not None and self.group_size < 1:
            raise ValueError(f"group_size must be positive, got "
                             f"{self.group_size}")

    def key(self) -> str:
        g = f"_g{self.group_size}" if self.group_size else ""
        if self.mode in ("int4", "int8", "fp8", "fp4", "bf16"):
            return self.mode + g
        return f"{self.mode}_w{self.w}_p{self.sw_precision}_c{self.cluster}"


def exact_for(mode: str, w: int) -> bool:
    """Whether a candidate must execute on the bit-exact kernel path.
    fp16_ipu below w=28 is *not* approximated by the fp16-cast matmul
    (§3.1: indistinguishable only at w >= 28), so both the divergence
    probe and the emitted plan rules route it through kernels.ops —
    measured accuracy always describes the datapath that serves."""
    return mode == "fp16_ipu" and w < 28


def canonical(mode: str, w: int = 16, sw_precision: int = 28,
              cluster: int = 1, group_size: Optional[int] = None
              ) -> Candidate:
    """Canonicalize hardware axes that are meaningless for a mode: INT
    and fp-storage datapaths never multi-cycle (any w serves them; pin
    the narrow INT point), and bf16 is the wide-adder single-cycle
    reference. ``group_size`` survives canonicalization only for the
    storage modes it parameterizes."""
    if mode in ("int4", "int8", "fp8", "fp4"):
        return Candidate(mode, w=16, sw_precision=28, cluster=1,
                         group_size=group_size)
    if mode == "bf16":
        return Candidate(mode, w=WIDE_W, sw_precision=28, cluster=1)
    return Candidate(mode, w=w, sw_precision=sw_precision, cluster=cluster)


def default_candidates(widths: Sequence[int] = (12, 16, 20, 28),
                       clusters: Sequence[int] = (1,),
                       modes: Sequence[str] = ("bf16", "fp16_ipu", "int8",
                                               "int4", "fp8", "fp4"),
                       group_sizes: Sequence[Optional[int]] = (None,),
                       ) -> Tuple[Candidate, ...]:
    """The default per-layer search grid. fp16_ipu expands over the
    (w, cluster) hardware axes; the storage modes (int4/int8/fp8/fp4)
    expand over ``group_sizes`` (None = per-out-channel scales); bf16
    contributes one point."""
    out: List[Candidate] = []
    for mode in modes:
        if mode == "fp16_ipu":
            for w, c in itertools.product(widths, clusters):
                out.append(canonical(mode, w=w, cluster=c))
        elif mode in ("int4", "int8", "fp8", "fp4"):
            for g in group_sizes:
                out.append(canonical(mode, group_size=g))
        else:
            out.append(canonical(mode))
    # dedupe, preserving order (canonicalization can collapse points)
    seen: Dict[Candidate, None] = {}
    for c in out:
        seen.setdefault(c)
    return tuple(seen)


def groups_for(cfg: ModelConfig) -> Tuple[ProjGroup, ...]:
    """The tunable projection groups of an architecture (re-exported so
    the CLI and search only import this module)."""
    return projection_groups(cfg)
