"""``python -m repro.autotune`` — search / score / report / smoke.

search  — build the cached score table for a model and emit the selected
          PrecisionPlan (+ Pareto frontier) as a versioned JSON artifact.
score   — re-derive the metrics of an existing plan from the (cached)
          score table and print them.
report  — render a plan's Pareto frontier as a markdown table.
smoke   — CI contract: a tiny 2-layer search executes > 0 evaluations
          cold and exactly 0 on an immediate warm re-run.

All evaluations go through the ``repro.exp`` cache; the engine flags
(``--jobs/--no-cache/--cache-dir``) behave exactly as in benchmarks/.
"""
from __future__ import annotations

import argparse
import json
import re
import sys
import tempfile
from typing import List, Optional

from repro import exp
from repro.autotune import candidates as cand_mod
from repro.autotune import search as search_mod
from repro.autotune.plan import PrecisionPlan, load_plan

DEFAULT_PLAN_DIR = "results/plans"


def resolve_arch(name: str) -> str:
    """Accept registry ids and filesystem-safe aliases
    (``qwen2_0_5b`` -> ``qwen2-0.5b``)."""
    from repro.configs import ARCH_IDS

    def norm(s: str) -> str:
        return re.sub(r"[^a-z0-9]+", "_", s.lower()).strip("_")

    if name in ARCH_IDS:
        return name
    for aid in ARCH_IDS:
        if norm(aid) == norm(name):
            return aid
    raise SystemExit(f"unknown model {name!r}; known: "
                     f"{', '.join(ARCH_IDS)}")


def arch_slug(arch: str) -> str:
    return re.sub(r"[^a-z0-9]+", "_", arch.lower()).strip("_")


def _candidates(args) -> tuple:
    group_sizes = tuple(None if g <= 0 else g
                        for g in getattr(args, "group_sizes", [0]))
    return cand_mod.default_candidates(
        widths=tuple(args.widths), clusters=tuple(args.clusters),
        modes=tuple(args.modes), group_sizes=group_sizes)


def _table(args, engine, arch, shapes):
    from repro.configs import get_config, reduced
    cfg = reduced(arch) if shapes == "reduced" else get_config(arch)
    groups = cand_mod.groups_for(cfg)
    return search_mod.build_scores(
        arch, groups, _candidates(args), engine, seq=args.seq,
        seed=args.seed, shapes=shapes, probe=not args.no_probe)


def _add_search_args(p: argparse.ArgumentParser):
    p.add_argument("--model", required=True,
                   help="registry arch id (aliases like qwen2_0_5b ok)")
    p.add_argument("--seq", type=int, default=1,
                   help="tokens per forward the simulator scores "
                        "(1 = decode step)")
    p.add_argument("--seed", type=int, default=0,
                   help="RNG seed threaded through every eval point "
                        "(part of the cache key)")
    p.add_argument("--widths", type=int, nargs="+", default=[12, 16, 20, 28],
                   help="fp16_ipu adder precisions to enumerate")
    p.add_argument("--clusters", type=int, nargs="+", default=[1],
                   help="cluster sizes to enumerate")
    p.add_argument("--modes", nargs="+",
                   default=["bf16", "fp16_ipu", "int8", "int4",
                            "fp8", "fp4"],
                   help="candidate operand modes")
    p.add_argument("--group-sizes", type=int, nargs="+", default=[0],
                   help="per-group weight-scale sizes for the storage "
                        "modes (0 = per-out-channel scales)")
    p.add_argument("--no-probe", action="store_true",
                   help="skip the model forward-divergence probe "
                        "(analytic accuracy proxy only)")
    p.add_argument("--shapes", choices=["full", "reduced"], default="full",
                   help="score the published dims or the reduced config")
    exp.add_cli_args(p)


def cmd_search(argv: List[str]) -> int:
    ap = argparse.ArgumentParser(prog="repro.autotune search")
    _add_search_args(ap)
    ap.add_argument("--acc-budget", type=float, default=None,
                    help="accuracy-proxy ceiling for plan selection "
                         "(default: uniform-INT8 accuracy)")
    ap.add_argument("--out", default=None,
                    help=f"plan path (default {DEFAULT_PLAN_DIR}/<arch>.json)")
    ap.add_argument("--calibrate", action="store_true",
                    help="run a short activation-calibration pass "
                         "(quant.calibrate on the reduced config, under "
                         "the selected plan's own policy) and embed the "
                         "static act scales in the plan artifact")
    args = ap.parse_args(argv)
    arch = resolve_arch(args.model)
    engine = exp.EngineConfig.from_args(args)

    import dataclasses
    table = _table(args, engine, arch, args.shapes)
    plan = search_mod.search_plan(arch, table, acc_budget=args.acc_budget)
    # record the eval-point parameters so downstream scoring (bench,
    # `score`) addresses the exact same cached points
    plan = dataclasses.replace(plan, meta={
        **plan.meta, "seq": args.seq, "seed": args.seed,
        "shapes": args.shapes, "probe": not args.no_probe})
    if args.calibrate:
        plan = dataclasses.replace(
            plan, act_scales=plan_act_scales(plan, seed=args.seed))
    out = args.out or f"{DEFAULT_PLAN_DIR}/{arch_slug(arch)}.json"
    plan.save(out)

    print(f"# {engine.total.summary()}")
    print(f"plan {plan.name} ({arch}) -> {out}")
    print(f"  selected: {plan.meta['selected_from']}  "
          f"frontier: {len(plan.frontier)} non-dominated plans")
    m = plan.metrics
    print(f"  cycles={m['cycles']:.3g} (ideal {m['ideal_cycles']:.3g})  "
          f"tops/mm2={m['tops_per_mm2']:.2f}  tops/W={m['tops_per_w']:.3f}  "
          f"acc_proxy={m['acc_proxy']:.3g}")
    for g, mode in m["modes"].items():
        print(f"    {g}: {mode}")
    return 0


def cmd_score(argv: List[str]) -> int:
    ap = argparse.ArgumentParser(prog="repro.autotune score")
    _add_search_args(ap)
    ap.add_argument("--plan", required=True, help="plan JSON to score")
    args = ap.parse_args(argv)
    arch = resolve_arch(args.model)
    engine = exp.EngineConfig.from_args(args)
    plan = load_plan(args.plan)

    table = _table(args, engine, arch, args.shapes)
    assign = {}
    for rule in plan.rules:
        assign[rule.group] = cand_mod.canonical(
            rule.mode, w=rule.w, sw_precision=rule.sw_precision,
            cluster=rule.cluster, group_size=rule.group_size)
    missing = [g.name for g in table.groups if g.name not in assign]
    if missing:
        raise SystemExit(f"plan {plan.name} lacks groups {missing}")
    metrics = search_mod.plan_metrics(table, assign)
    print(f"# {engine.total.summary()}")
    json.dump({"plan": plan.name, "arch": arch, "metrics": metrics},
              sys.stdout, indent=1, sort_keys=True)
    print()
    return 0


def plan_act_scales(plan: PrecisionPlan, seed: int = 0) -> dict:
    """Calibrated static activation scales for ``plan``: forwards random
    token batches through the family-preserving reduced model under the
    plan's own policy (so downstream activations carry the plan's
    quantization noise) and records every projection's input absmax —
    the ``quant.calibrate`` pass, keyed to ride in the plan artifact.

    Scales are measured on the ``PRNGKey(0)`` model init — the fixed
    convention of every serving entry point (serve_lm, smoke,
    serve_bench, build_replicas) — regardless of the search ``seed``,
    which only drives the calibration token draws; embedding scales
    calibrated on a differently-initialized model would silently
    mis-grid every activation at serve time. A replica serving a
    different checkpoint should re-calibrate (``act_calibration="auto"``
    on a plan without scales, or an explicit ``calibrate_act_scales``
    dict) rather than consume plan scales measured on other weights."""
    import dataclasses as dc

    import jax

    from repro.configs import reduced
    from repro.core.policy import POLICIES, register_policy
    from repro.models import registry
    from repro.quant.calibrate import calibrate_act_scales

    name = f"_calib/{plan.name}"
    register_policy(dc.replace(plan.to_policy(), name=name))
    try:
        cfg = dc.replace(reduced(plan.arch), precision_policy=name)
        api = registry.build(cfg)
        params = api.init(jax.random.PRNGKey(0))
        return calibrate_act_scales(cfg, api, params, seed=seed)
    finally:
        POLICIES.pop(name, None)


def plan_weight_bytes(arch: str, modes, shapes: str = "full"
                      ) -> Optional[float]:
    """Estimated weight-resident bytes of serving ``arch`` with each
    projection group stored in its assigned mode (quant.prepare storage
    formats: packed nibbles for int4, int8 + per-out-channel scales,
    fp16 casts; bf16/fp32 raw). Matches what the serving engine keeps
    resident: the head/embedding group is costed at fp32 regardless of
    its assigned mode (``registry.projection_paths`` never routes it
    through preparation), and MoE experts are costed at their *stored*
    count (all ``n_experts``, not the ``top_k`` executed per token).
    None when the arch is unknown."""
    from repro.models.registry import projection_groups
    from repro.quant.prepare import MODE_BYTES_PER_PARAM
    try:
        from repro.configs import get_config, reduced
        cfg = reduced(arch) if shapes == "reduced" else get_config(arch)
    except KeyError:
        return None
    total = 0.0
    for g in projection_groups(cfg):
        mode = modes.get(g.name)
        if mode is None:
            return None              # partial assignment: no estimate
        count = g.count
        if g.name == "moe_experts" and cfg.moe:
            count = 3 * cfg.moe.n_experts * cfg.n_layers
        if g.name == "head":
            mode = "fp32"            # never prepared: stays raw resident
        total += g.d_in * g.d_out * count * MODE_BYTES_PER_PARAM[mode]
        if mode in ("int8", "int4", "fp8", "fp4"):
            total += g.d_out * count * 4     # f32 scales per out-channel
    return total


def _fmt_bytes(n: Optional[float]) -> str:
    if n is None:
        return "?"
    for unit in ("B", "KB", "MB", "GB"):
        if n < 1024 or unit == "GB":
            return f"{n:.0f}{unit}" if unit == "B" else f"{n:.2f}{unit}"
        n /= 1024
    return f"{n:.2f}GB"


def render_report(plan: PrecisionPlan) -> str:
    """Markdown Pareto report of a plan artifact."""
    shapes = plan.meta.get("shapes", "full")
    lines = [
        f"# Precision plan `{plan.name}` ({plan.arch})",
        "",
        f"Selected from `{plan.meta.get('selected_from', '?')}` — "
        f"{len(plan.frontier)} non-dominated plans out of "
        f"{plan.meta.get('n_pool', '?')} searched "
        f"({plan.meta.get('n_groups', '?')} groups x "
        f"{plan.meta.get('n_candidates', '?')} candidates).",
        "",
        "## Selected assignment",
        "",
        "| group | mode | w | P | cluster |",
        "|---|---|---|---|---|",
    ]
    for r in plan.rules:
        lines.append(f"| {r.group} | {r.mode} | {r.w} | {r.sw_precision} "
                     f"| {r.cluster} |")
    lines += [
        "",
        "## Pareto frontier (cycles v, acc_proxy v, TOPS/W ^)",
        "",
        "| plan | cycles | TOPS/mm2 | TOPS/W | acc proxy | weights "
        "| modes |",
        "|---|---|---|---|---|---|---|",
    ]
    for p in plan.frontier:
        m = p["metrics"]
        modes = ", ".join(f"{g}:{mo}" for g, mo in m["modes"].items())
        sel = " **(selected)**" if p["name"] == plan.meta.get(
            "selected_from") else ""
        wb = plan_weight_bytes(plan.arch, m["modes"], shapes)
        lines.append(
            f"| {p['name']}{sel} | {m['cycles']:.4g} "
            f"| {m['tops_per_mm2']:.2f} | {m['tops_per_w']:.3f} "
            f"| {m['acc_proxy']:.3g} | {_fmt_bytes(wb)} | {modes} |")
    return "\n".join(lines) + "\n"


def cmd_report(argv: List[str]) -> int:
    ap = argparse.ArgumentParser(prog="repro.autotune report")
    ap.add_argument("--plan", required=True)
    ap.add_argument("--out", default=None,
                    help="write markdown here instead of stdout")
    args = ap.parse_args(argv)
    text = render_report(load_plan(args.plan))
    if args.out:
        with open(args.out, "w") as f:
            f.write(text)
        print(f"report -> {args.out}")
    else:
        print(text, end="")
    return 0


def cmd_smoke(argv: List[str]) -> int:
    """Tiny 2-layer search, twice: cold executes > 0 points, an
    immediate warm re-run executes exactly 0 (the CI contract)."""
    ap = argparse.ArgumentParser(prog="repro.autotune smoke")
    ap.add_argument("--cache-dir", default=None)
    ap.add_argument("--jobs", type=int, default=1)
    args = ap.parse_args(argv)
    base = args.cache_dir or tempfile.gettempdir()
    import os
    os.makedirs(base, exist_ok=True)
    cache_dir = tempfile.mkdtemp(dir=base, prefix="autotune-smoke-")

    arch = resolve_arch("qwen2-0.5b")
    from repro.configs import reduced
    cfg = reduced(arch)          # 2-layer toy config
    assert cfg.n_layers == 2, cfg.n_layers
    groups = cand_mod.groups_for(cfg)
    cands = cand_mod.default_candidates(
        widths=(16,), clusters=(1,), modes=("bf16", "fp16_ipu", "int8"))

    def run(engine):
        table = search_mod.build_scores(
            arch, groups, cands, engine, seq=1, seed=0, shapes="reduced",
            probe=True)
        return search_mod.search_plan(arch, table)

    cold = exp.EngineConfig(jobs=args.jobs,
                            cache=exp.ResultCache(cache_dir), progress=True)
    plan = run(cold)
    assert cold.total.n_executed > 0, "cold run executed no points"
    assert len(plan.frontier) >= 1, "empty Pareto frontier"

    warm = exp.EngineConfig(jobs=args.jobs,
                            cache=exp.ResultCache(cache_dir), progress=True)
    plan_warm = run(warm)
    assert warm.total.n_executed == 0, \
        f"warm run re-executed {warm.total.n_executed} points"
    assert plan_warm.to_json() == plan.to_json(), \
        "warm-cache plan differs from cold plan"

    # the plan round-trips through JSON into an executable policy
    path = os.path.join(cache_dir, "smoke_plan.json")
    plan.save(path)
    policy = load_plan(path).to_policy()
    assert policy.rules == plan.to_policy().rules, \
        "reloaded plan routes differently"
    import shutil
    shutil.rmtree(cache_dir, ignore_errors=True)
    print(f"autotune smoke OK: cold {cold.total.n_executed} executed, "
          f"warm {warm.total.n_cached} cached / 0 executed, "
          f"frontier {len(plan.frontier)}")
    return 0


COMMANDS = {"search": cmd_search, "score": cmd_score,
            "report": cmd_report, "smoke": cmd_smoke}


def main(argv: Optional[List[str]] = None) -> int:
    argv = list(sys.argv[1:] if argv is None else argv)
    if not argv or argv[0] in ("-h", "--help"):
        print(__doc__)
        print("subcommands:", ", ".join(COMMANDS))
        return 0 if argv else 2
    cmd = argv[0]
    if cmd not in COMMANDS:
        print(f"unknown subcommand {cmd!r}; want one of "
              f"{', '.join(COMMANDS)}", file=sys.stderr)
        return 2
    return COMMANDS[cmd](argv[1:])
