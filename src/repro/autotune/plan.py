"""PrecisionPlan — the serializable artifact the planner searches for.

A plan is a complete per-layer precision assignment for one architecture:
an ordered list of (projection-group pattern -> candidate) rules plus a
default, exactly the shape :class:`repro.core.policy.PrecisionPolicy`
consumes — ``to_policy()`` is a pure translation, so a plan searched
offline is what serves traffic (``precision_policy="plan:<file>"``).

The JSON schema is versioned. Besides the selected assignment, the
artifact carries the searched Pareto frontier (every non-dominated
assignment with its metrics) so downstream tools can re-select a
different trade-off point without re-running the search.
"""
from __future__ import annotations

import dataclasses
import functools
import json
import os
from typing import Any, Dict, Tuple

from repro.core.ipu import IPUConfig
from repro.core.policy import PrecisionPolicy, PrecisionSpec

PLAN_SCHEMA = "precision-plan-v1"

MODES = ("bf16", "fp32", "int8", "int4", "fp8", "fp4", "fp16_ipu")


@dataclasses.dataclass(frozen=True)
class PlanRule:
    """One plan entry: a projection-group pattern and its candidate.

    ``w``/``sw_precision``/``cluster`` describe the MC-IPU configuration
    the candidate was scored on; only fp16_ipu rules carry them into the
    executed PrecisionSpec (INT modes need no alignment hardware).
    ``group_size`` (int/fp storage modes) selects per-group weight
    scales — K/group_size scale groups along the contraction dim —
    threaded into the PrecisionSpec; None keeps per-out-channel scales.
    (``group`` is the projection-group *name*, not related.)
    """

    group: str
    pattern: str
    mode: str
    w: int = 16
    sw_precision: int = 28
    cluster: int = 1
    exact: bool = False
    group_size: Any = None

    def __post_init__(self):
        if self.mode not in MODES:
            raise ValueError(f"invalid plan mode {self.mode!r} "
                             f"(want one of {MODES})")
        if self.group_size is not None and int(self.group_size) < 1:
            raise ValueError(f"group_size must be positive, got "
                             f"{self.group_size}")

    def spec(self) -> PrecisionSpec:
        if self.mode == "fp16_ipu":
            return PrecisionSpec(
                "fp16_ipu", exact=self.exact,
                ipu=IPUConfig(n=16, w=max(self.w, 10),
                              sw_precision=self.sw_precision))
        gs = None if self.group_size is None else int(self.group_size)
        return PrecisionSpec(self.mode, exact=self.exact, group_size=gs)


@dataclasses.dataclass(frozen=True)
class PrecisionPlan:
    """A versioned, serializable per-layer precision assignment."""

    name: str
    arch: str
    rules: Tuple[PlanRule, ...] = ()
    default_mode: str = "bf16"
    metrics: Dict[str, Any] = dataclasses.field(default_factory=dict)
    frontier: Tuple[Dict[str, Any], ...] = ()
    meta: Dict[str, Any] = dataclasses.field(default_factory=dict)
    # calibrated static activation scales {runtime policy path -> f32
    # scale} (quant.calibrate): a plan searched offline ships its own
    # calibration, and serving engines resolving the plan consume the
    # scales via ``act_calibration="auto"``
    act_scales: Dict[str, float] = dataclasses.field(default_factory=dict)

    def __post_init__(self):
        if self.default_mode not in MODES:
            raise ValueError(f"invalid default mode {self.default_mode!r}")

    def assignment(self) -> Dict[str, str]:
        """group name -> mode (compact summary for reports/benches)."""
        return {r.group: r.mode for r in self.rules}

    def to_policy(self) -> PrecisionPolicy:
        """The executable policy: first-match-wins rules in plan order,
        unmatched paths fall through to the default spec."""
        return PrecisionPolicy(
            name=self.name,
            rules=tuple((r.pattern, r.spec()) for r in self.rules),
            default=PrecisionSpec(self.default_mode),
        )

    # ------------------------------------------------------ serialization

    def to_json(self) -> Dict[str, Any]:
        return {
            "schema": PLAN_SCHEMA,
            "name": self.name,
            "arch": self.arch,
            "default_mode": self.default_mode,
            "rules": [dataclasses.asdict(r) for r in self.rules],
            "metrics": self.metrics,
            "frontier": list(self.frontier),
            "meta": self.meta,
            "act_scales": dict(self.act_scales),
        }

    @classmethod
    def from_json(cls, obj: Dict[str, Any]) -> "PrecisionPlan":
        schema = obj.get("schema")
        if schema != PLAN_SCHEMA:
            raise ValueError(
                f"unsupported plan schema {schema!r} (want {PLAN_SCHEMA})")
        return cls(
            name=obj["name"],
            arch=obj["arch"],
            rules=tuple(PlanRule(**r) for r in obj["rules"]),
            default_mode=obj.get("default_mode", "bf16"),
            metrics=obj.get("metrics", {}),
            frontier=tuple(obj.get("frontier", [])),
            meta=obj.get("meta", {}),
            act_scales=obj.get("act_scales", {}),
        )

    def save(self, path: str) -> str:
        os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
        with open(path, "w") as f:
            json.dump(self.to_json(), f, indent=1, sort_keys=True)
            f.write("\n")
        return path


def load_plan(path: str) -> PrecisionPlan:
    with open(path) as f:
        return PrecisionPlan.from_json(json.load(f))


@functools.lru_cache(maxsize=64)
def _load_policy_cached(path: str, mtime_ns: int) -> PrecisionPolicy:
    return load_plan(path).to_policy()


def load_policy(path: str) -> PrecisionPolicy:
    """Plan file -> policy, cached on (path, mtime) so the per-forward
    ``get_policy`` resolution in the model zoo never re-reads the file."""
    apath = os.path.abspath(path)
    return _load_policy_cached(apath, os.stat(apath).st_mtime_ns)


def load_act_scales(path: str) -> Dict[str, float]:
    """Calibrated activation scales carried by a plan artifact (empty
    when the plan was searched without calibration)."""
    return dict(load_plan(path).act_scales)
