"""Autotune: the Pareto-frontier precision planner.

Closes the loop from the paper's three cost models to the serving
stack: enumerate per-layer precision candidates (``candidates``), score
them on cycles / area-power efficiency / accuracy through the cached
``repro.exp`` engine (``objectives``), search the joint space
(``search``), and emit a versioned :class:`PrecisionPlan` artifact
(``plan``) that ``core.policy`` loads directly via
``precision_policy="plan:<file>"``.

CLI: ``python -m repro.autotune {search,score,report,smoke}``.

Imports stay lazy (PEP 562) so cache-salt computation and plan loading
never pull the jax model stack.
"""
_LAZY = {
    "Candidate": "repro.autotune.candidates",
    "default_candidates": "repro.autotune.candidates",
    "PlanRule": "repro.autotune.plan",
    "PrecisionPlan": "repro.autotune.plan",
    "load_plan": "repro.autotune.plan",
    "load_policy": "repro.autotune.plan",
    "build_scores": "repro.autotune.search",
    "search_plan": "repro.autotune.search",
}

__all__ = sorted(_LAZY)


def __getattr__(name):
    if name in _LAZY:
        import importlib
        return getattr(importlib.import_module(_LAZY[name]), name)
    raise AttributeError(name)
