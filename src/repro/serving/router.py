"""Multi-replica routing: each replica serves its own precision plan.

The routing layer the paper's heterogeneity argument calls for: mixed
precision only pays off when the runtime sends each request to the right
datapath. A :class:`Replica` wraps one ``ServingEngine`` whose config
carries its own ``precision_policy`` (a preset name or a searched
``plan:<file>`` artifact). The :class:`Router` places requests across
replicas under one of three strategies:

  * ``plan_aware`` (default) — a static cost model scores every replica
    from ``core.simulator`` cycles and ``core.area_power`` efficiency
    under the replica's *actual* per-projection policy: requests tagged
    ``"accuracy"`` go to the replica with the lowest accuracy proxy
    (e.g. the bf16 replica), everything else to the replica with the
    cheapest load-discounted cycles/token (e.g. the int8 replica).
  * ``least_loaded`` — min (active slots + waiting) / slots.
  * ``round_robin`` — the baseline.

**Measured-cost feedback** (``cost_correction="online"``): the static
simulator estimate cannot see a replica that *became* slow — a noisy
neighbor, thermal throttling, a bigger co-resident batch. Every engine
publishes measured :class:`repro.obs.ReplicaStats` (EWMA tok/s, queue
depth, p95 TTFT), and the online mode blends the measured
seconds-per-token into the static cycles score: both are normalized by
their fleet mean (unit-free), then mixed with weight ``online_blend``
on the measured term. Replicas without a throughput sample yet fall
back to their static score, so cold fleets route exactly like
``"static"``. ``routing_report()`` shows static, measured and
effective side by side.
"""
from __future__ import annotations

import dataclasses
import os
import re
from typing import Dict, List, Optional, Sequence

from repro.configs.base import ModelConfig
from repro.core import area_power as ap
from repro.core import simulator as sim
from repro.core.policy import PrecisionPolicy, PrecisionSpec
from repro.core.workloads import ConvLayer
from repro.models.registry import projection_groups
from repro.serving.engine import Request, ServingEngine

# workload datatype of each policy mode on the MC-IPU tile; bf16/fp32
# projections run the FP16 datapath at full alignment width
_MODE_TYPES = {"int4": sim.INT4, "int8": sim.INT8, "fp16_ipu": sim.FP16,
               "bf16": sim.FP16, "fp32": sim.FP16}

# literal parameter paths covering every projection-group pattern of the
# model zoo (see registry.projection_groups): the cost model resolves a
# policy's mode per group by matching the group pattern against these
_CANDIDATE_PATHS = (
    "block/full/attn/wq", "block/full/attn/wk", "block/full/attn/wv",
    "block/full/attn/wo", "block/swa/attn/wq", "block/swa/attn/wo",
    "block/mlp/w_gate", "block/mlp/w_up", "block/mlp/w_down",
    "block/moe/experts",
    "block/mix/w_r", "block/mix/w_o", "block/mix/c_key",
    "block/rec/w_in_rnn", "block/rec/w_out",
    "projector/fc1", "lm_head",
)


def _spec_width(spec: PrecisionSpec) -> int:
    if spec.ipu is not None:
        return max(spec.ipu.w, 10)
    # bf16/fp32 model the wide-adder FP16 path (never multi-cycles);
    # fp16_ipu without an explicit IPU config uses the paper's w=16
    return 38 if spec.mode in ("bf16", "fp32") else 16


def replica_cost(cfg: ModelConfig, policy: PrecisionPolicy,
                 seed: int = 0) -> Dict[str, float]:
    """Static per-token cost of serving ``cfg`` under ``policy``.

    Sums ``core.simulator`` cycles of every projection group at its
    policy-routed precision (one decode token), MAC-weights
    ``core.area_power`` TOPS/W across groups, and carries the additive
    analytic accuracy proxy the autotune planner searches on — the three
    axes plan-aware routing trades off.
    """
    from repro.autotune.objectives import analytic_proxy
    cycles = ideal = 0.0
    macs_total = 0
    seconds_per_watt = 0.0   # sum over groups of macs / (TOPS/W)
    acc = 0.0
    for g in projection_groups(cfg):
        path = next((p for p in _CANDIDATE_PATHS if re.search(g.pattern, p)),
                    None)
        spec = policy.spec_for(path) if path else policy.default
        types = _MODE_TYPES[spec.mode]
        w = _spec_width(spec)
        sw = spec.ipu.sw_precision if spec.ipu is not None else 28
        tile = dataclasses.replace(sim.BIG_TILE, adder_w=w, cluster_size=1,
                                   sw_precision=sw)
        layer = ConvLayer(g.name, c=g.d_in, k=g.d_out, ho=1, wo=1, r=1,
                          s=1, count=g.count)
        stats = sim.simulate_network([layer], tile, types,
                                     sim.FORWARD_SOURCE, seed=seed)
        cycles += stats.cycles
        ideal += stats.ideal_cycles
        design = ap.IPUDesign(
            f"route_{spec.mode}_w{w}", mult_a=4, mult_b=4, adder_w=w,
            fp_support=True, tile=tile, cluster_size=1,
            fp_mc_factor=stats.slowdown)
        _, tops_w = ap.efficiency(design, types)
        macs_total += g.macs_per_token
        seconds_per_watt += g.macs_per_token / max(tops_w, 1e-9)
        acc += analytic_proxy(spec.mode, w, sw)
    return {
        "cycles_per_token": cycles,
        "ideal_cycles_per_token": ideal,
        "tops_per_w": macs_total / max(seconds_per_watt, 1e-9),
        "acc_proxy": acc,
    }


@dataclasses.dataclass
class Replica:
    """One serving engine + its precision policy and routing counters.

    The attribute surface the :class:`Router` reads is deliberately
    narrow — ``name``/``cost``/``routed``/``load``/``stats``/
    ``cost_correction`` plus ``submit``/``has_pending``/``step``/
    ``completed``/``metrics`` — so a replica does NOT have to hold its
    engine in-process: ``repro.fabric.controller.RemoteReplica``
    implements the same protocol over a transport (stats ingested from
    ``StatsSnapshot`` messages instead of read off the engine object),
    and the Router ranks both kinds identically.
    """

    name: str
    policy_name: str
    engine: ServingEngine
    cost: Dict[str, float] = dataclasses.field(default_factory=dict)
    routed: int = 0

    @property
    def load(self) -> float:
        """Occupancy estimate: (active slots + waiting) / slots."""
        eng = self.engine
        active = sum(r is not None for r in eng.slot_req)
        return (active + len(eng.scheduler)) / max(eng.b, 1)

    @property
    def stats(self):
        """Measured :class:`repro.obs.ReplicaStats` the online cost
        correction blends in."""
        return self.engine.stats

    @property
    def cost_correction(self) -> str:
        """How this replica asks to be costed ('static' | 'online')."""
        return self.engine.config.cost_correction

    def submit(self, req: Request) -> None:
        self.engine.submit(req)

    def has_pending(self) -> bool:
        return self.engine.has_pending()

    def step(self) -> None:
        self.engine.step()

    @property
    def completed(self) -> Dict[int, Request]:
        return self.engine.completed

    def metrics(self) -> Dict:
        return self.engine.metrics()


def _replica_name(policy_name: str) -> str:
    if policy_name.startswith("plan:"):
        stem = os.path.splitext(os.path.basename(policy_name[5:]))[0]
        return f"plan:{stem}"
    return policy_name


def build_replicas(cfg: ModelConfig, policy_names: Sequence[str],
                   params=None, config: Optional["EngineConfig"] = None,
                   **engine_kw) -> List[Replica]:
    """One replica per policy/plan ref, initialized from a single raw
    parameter set. Each engine *prepares* its own storage copy from its
    policy at construction (quant.prepare): the int4 replica holds
    packed nibbles + scales, the bf16 replica the raw tree — so the
    per-replica ``cost['weight_bytes']`` genuinely differ.

    ``config`` is the shared :class:`~repro.serving.config.EngineConfig`
    every replica runs under (default ``EngineConfig(cache_len=128)``);
    legacy flat engine kwargs still pass through ``**engine_kw`` and
    take the deprecation path in ``ServingEngine``."""
    import jax

    from repro.models import registry
    from repro.serving.config import EngineConfig
    if config is None and not engine_kw:
        config = EngineConfig(cache_len=128)
    replicas: List[Replica] = []
    names: Dict[str, int] = {}
    for pname in policy_names:
        rcfg = dataclasses.replace(cfg, precision_policy=pname)
        api = registry.build(rcfg)
        if params is None:
            params = api.init(jax.random.PRNGKey(0))
        engine = ServingEngine(rcfg, api, params, config=config,
                               **engine_kw)
        name = _replica_name(pname)
        if name in names:           # duplicate policies stay addressable
            names[name] += 1
            name = f"{name}#{names[name]}"
        else:
            names[name] = 0
        cost = replica_cost(rcfg, engine.policy)
        cost["weight_bytes"] = engine.weight_bytes()
        replicas.append(Replica(name=name, policy_name=pname,
                                engine=engine, cost=cost))
    return replicas


class Router:
    """Places requests on replicas and drives their engines to drain."""

    STRATEGIES = ("plan_aware", "least_loaded", "round_robin")

    def __init__(self, replicas: Sequence[Replica],
                 strategy: str = "plan_aware",
                 cost_correction: Optional[str] = None,
                 online_blend: float = 0.75):
        if not replicas:
            raise ValueError("router needs at least one replica")
        if strategy not in self.STRATEGIES:
            raise ValueError(f"unknown strategy {strategy!r} "
                             f"(want one of {self.STRATEGIES})")
        if cost_correction is None:
            # inherit the fleet's declaration: one replica asking for
            # online correction turns it on for the whole cost ranking
            # (a partially-measured fleet degrades gracefully — see
            # _effective_costs)
            cost_correction = "online" if any(
                r.cost_correction == "online"
                for r in replicas) else "static"
        if cost_correction not in ("static", "online"):
            raise ValueError(f"cost_correction must be 'static' or "
                             f"'online', got {cost_correction!r}")
        if not 0.0 <= online_blend <= 1.0:
            raise ValueError(f"online_blend must be in [0, 1], got "
                             f"{online_blend}")
        self.replicas = list(replicas)
        self.strategy = strategy
        self.cost_correction = cost_correction
        self.online_blend = online_blend
        self._rr = 0

    def _effective_costs(self) -> List[float]:
        """Unit-free cost score per replica, lower is better.

        Static cycles/token and measured seconds/token (1 / EWMA tok/s)
        live in different units, so each is normalized by its mean over
        the replicas it exists for; ``online`` blends the two with
        weight ``online_blend`` on the measured term. Unmeasured
        replicas (no throughput sample yet) keep their static score —
        a cold fleet routes exactly like ``cost_correction="static"``.
        """
        static = [r.cost.get("cycles_per_token", 0.0)
                  for r in self.replicas]
        s_mean = sum(static) / len(static)
        s_norm = [s / s_mean if s_mean > 0 else 1.0 for s in static]
        if self.cost_correction != "online":
            return s_norm
        spt = [1.0 / r.stats.tok_per_s
               if r.stats.measured and r.stats.tok_per_s > 0
               else None
               for r in self.replicas]
        measured = [v for v in spt if v is not None]
        if not measured:
            return s_norm
        m_mean = sum(measured) / len(measured)
        w = self.online_blend
        return [(1.0 - w) * sn + w * (v / m_mean) if v is not None
                else sn
                for sn, v in zip(s_norm, spt)]

    def route(self, req: Request) -> Replica:
        if self.strategy == "round_robin":
            rep = self.replicas[self._rr % len(self.replicas)]
            self._rr += 1
            return rep
        if self.strategy == "least_loaded":
            return min(enumerate(self.replicas),
                       key=lambda ir: (ir[1].load, ir[0]))[1]
        # plan_aware: accuracy-tagged traffic takes the most accurate
        # datapath; the rest takes the cheapest (possibly
        # measurement-corrected) cost score, discounted by load so a
        # hot replica spills onto the others
        idx = range(len(self.replicas))
        if "accuracy" in req.tags:
            return min(zip(idx, self.replicas),
                       key=lambda ir: (ir[1].cost.get("acc_proxy", 0.0),
                                       ir[1].load, ir[0]))[1]
        costs = self._effective_costs()
        return min(zip(idx, self.replicas),
                   key=lambda ir: (costs[ir[0]] * (1.0 + ir[1].load),
                                   ir[0]))[1]

    def submit(self, req: Request) -> Replica:
        rep = self.route(req)
        rep.routed += 1
        rep.submit(req)
        return rep

    # ---------------------------------------------------------- execution

    def has_pending(self) -> bool:
        return any(r.has_pending() for r in self.replicas)

    def step(self) -> bool:
        stepped = False
        for rep in self.replicas:
            if rep.has_pending():
                rep.step()
                stepped = True
        return stepped

    def run_until_drained(self, max_ticks: int = 10_000) -> int:
        ticks = 0
        while self.has_pending():
            self.step()
            ticks += 1
            if ticks > max_ticks:
                raise RuntimeError("router did not drain")
        return ticks

    # ------------------------------------------------------ observability

    @property
    def completed(self) -> Dict[int, Request]:
        out: Dict[int, Request] = {}
        for rep in self.replicas:
            out.update(rep.completed)
        return out

    def routing_counters(self) -> Dict[str, int]:
        return {rep.name: rep.routed for rep in self.replicas}

    def routing_report(self) -> Dict:
        """The cost ranking as the router sees it right now: static
        simulator estimate, measured replica stats, and the effective
        (possibly blended) score ``route()`` ranks non-accuracy traffic
        by — the ablation surface for online vs static correction."""
        costs = self._effective_costs()
        return {
            "cost_correction": self.cost_correction,
            "online_blend": self.online_blend,
            "replicas": {
                rep.name: {
                    "static_cycles_per_token":
                        rep.cost.get("cycles_per_token", 0.0),
                    "measured": rep.stats.snapshot(),
                    "effective_cost": costs[i],
                    "load": rep.load,
                    "routed": rep.routed,
                } for i, rep in enumerate(self.replicas)
            },
        }

    def report(self) -> Dict:
        """Per-replica routing counters, cost model, and engine metrics."""
        return {
            "strategy": self.strategy,
            "cost_correction": self.cost_correction,
            "routing": self.routing_report()["replicas"],
            "replicas": {
                rep.name: {
                    "policy": rep.policy_name,
                    "routed": rep.routed,
                    "cost": dict(rep.cost),
                    "metrics": rep.metrics(),
                } for rep in self.replicas
            },
        }
