"""Plan-aware serving runtime.

The runtime layer that turns the paper's payoff — mixed-precision
datapaths trading accuracy for TOPS/W — into a deployment: requests are
admitted through a batched prefill path (``engine``), scheduled with
priorities and starvation protection (``scheduler``), and routed across
replicas that each carry their own precision policy or searched
``PrecisionPlan`` (``router``), with per-request latency metrics
(``metrics``). ``repro.launch.serve`` remains a thin compat shim.
"""
from repro.serving.engine import (Request, ServingEngine,   # noqa: F401
                                  make_serve_fns)
from repro.serving.metrics import (percentiles,             # noqa: F401
                                   request_metrics, summarize_requests)
from repro.serving.router import (Replica, Router,          # noqa: F401
                                  build_replicas, replica_cost)
from repro.serving.scheduler import (AdmissionScheduler,    # noqa: F401
                                     SchedulerFull)
