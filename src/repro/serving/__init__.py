"""Plan-aware serving runtime.

The runtime layer that turns the paper's payoff — mixed-precision
datapaths trading accuracy for TOPS/W — into a deployment: requests are
admitted through a chunked-prefill continuous-batching loop
(``engine``), scheduled with priorities and starvation protection
(``scheduler``), and routed across replicas that each carry their own
precision policy or searched ``PrecisionPlan`` (``router``), with
per-request latency + SLO metrics (``metrics``). ``repro.launch.serve``
remains a thin compat shim.

Observability lives in ``repro.obs``: engines run typed metrics
(``MetricsRegistry`` behind the dict-compatible ``counters`` view),
record request-lifecycle / tick-phase / compile spans when
``EngineConfig(trace=True)`` (``engine.dump_trace(path)`` exports
Chrome trace-event JSON; ``tools/trace_report.py`` summarizes it), and
publish measured ``ReplicaStats`` that ``Router``'s
``cost_correction="online"`` blends into the static replica cost.

Public configuration surfaces (``config``):

* :class:`EngineConfig` — one frozen dataclass of engine-level tuning
  (slots, cache length, prefill mode/chunk, decode block, prepared
  weights, activation calibration, mid-block admission, EOS stopping,
  engine eos_id/seed). ``ServingEngine(cfg, api, params,
  config=EngineConfig(...))``; the old flat kwargs still work through
  a deprecation shim.
* :class:`SamplingParams` — per-request decoding behavior (temperature,
  top_k, top_p, stop_ids, max_new_tokens, seed) carried on
  ``Request.sampling``; the default is greedy, matching the old
  engine-level ``greedy=True``.
"""
from repro.serving.config import (EngineConfig,             # noqa: F401
                                  SamplingParams)
from repro.serving.engine import (Request, ServingEngine,   # noqa: F401
                                  make_serve_fns)
from repro.serving.metrics import (percentiles,             # noqa: F401
                                   request_metrics, slo_report,
                                   summarize_requests)
from repro.serving.router import (Replica, Router,          # noqa: F401
                                  build_replicas, replica_cost)
from repro.serving.scheduler import (AdmissionScheduler,    # noqa: F401
                                     SchedulerFull)
