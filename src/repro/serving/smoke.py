"""``python -m repro.serving smoke`` — the serving-runtime CI contract.

A two-replica router (int8_serving + bf16, tiny reduced qwen2) serves a
mixed workload: a third of the requests are accuracy-tagged, priorities
and prompt lengths vary. The contract asserts, in the style of the
autotune-smoke cold/warm contract:

  * every submitted request completes, with its generated-token count
    exactly ``max_new_tokens``;
  * BOTH replicas receive traffic (plan-aware routing splits tagged
    traffic onto the accurate replica and the rest onto the cheap one);
  * admission runs through the chunked prefill path — zero
    teacher-forced prompt tokens, > 0 prefill calls;
  * per-request metrics (TTFT / queue delay) are populated;
  * the int4 replica serves PREPARED weights: its traced decode step
    performs zero dynamic weight quantizations (the
    ``mplinear.count_weight_quant`` hook), its packed projection storage
    is <= 1/6 of the raw fp32 bytes, and a control engine with
    preparation disabled shows the counter is live (> 0);
  * a second identical run routes identically (determinism contract —
    the analogue of the warm-cache run reproducing the cold plan);
  * the decode FAST PATH holds its contracts on a blocked + calibrated
    replica (``--decode-block``, default 4): token-for-token identical
    output to the per-token engine on every request, the
    decode_steps-vs-ticks counter relation (one host sync per block),
    zero per-step weight quants still, and zero per-token activation
    absmax reduces (``mplinear.count_act_quant`` — static calibrated
    scales);
  * the FUSED datapath holds its contracts: the blocked + calibrated
    replica resolves ``fused_executors="auto"`` onto the fused
    dequant-matmul executors and its traced decode step materializes
    zero staged compute-dtype operands (``quant.prepare.count_staged``),
    a staged control shows the counter is live, ``fused_executors="on"``
    without prepared weights refuses construction, and an exact
    per-channel int8 (fidelity_int8) fused engine reproduces the staged
    engine's greedy streams token-for-token (bit-exact integer math);
  * the CONTINUOUS-BATCHING loop holds its contracts on a bursty
    tick-driven arrival trace (staggered submits landing mid-decode): a
    long prompt streams through multiple prefill waves while decode
    keeps running, queue pressure cuts blocks short and at least one
    admission lands mid-block, at least one request EOS-stops mid-block
    with its budget unspent, an oversized request (prompt + budget >
    cache_len) admits with trailing-window context instead of being
    rejected, greedy token streams stay identical to a
    flags-off (PR-5-style between-block) engine on the same trace, and
    the continuous fast path still performs zero dynamic weight/act
    quants per step;
  * ONLINE COST CORRECTION moves traffic: two same-policy replicas
    (identical static cost), one slowed through a dilated clock, serve
    a sequential trickle — static costing tie-breaks every request onto
    the slow replica, online costing reads the measured throughput gap
    (``repro.obs.ReplicaStats``) and shifts every request to the fast
    one;
  * with ``--trace PATH`` the OBSERVABILITY contract also runs: a
    traced engine serves the workload and must export a schema-valid,
    non-empty Chrome trace containing every tick-phase span, every
    request-lifecycle stage, and at least one ``compile:*`` span (cold
    engine), with counters identical to an untraced engine on the same
    workload (tracing observes, never perturbs).
"""
from __future__ import annotations

import argparse
from typing import List, Optional

import numpy as np

REPLICAS = ("int8_serving", "bf16", "int4_serving")


def _run_workload(requests: int, slots: int, max_new: int, seed: int):
    from repro.configs import reduced
    from repro.serving.config import EngineConfig
    from repro.serving.engine import Request
    from repro.serving.router import Router, build_replicas

    cfg = reduced("qwen2-0.5b")
    assert cfg.n_layers == 2, cfg.n_layers   # tiny model: CI-sized
    replicas = build_replicas(cfg, REPLICAS,
                              config=EngineConfig(batch_slots=slots,
                                                  cache_len=64))
    router = Router(replicas, strategy="plan_aware")

    rng = np.random.default_rng(seed)
    reqs: List[Request] = []
    for rid in range(requests):
        prompt = rng.integers(0, cfg.vocab, int(rng.integers(3, 12)),
                              dtype=np.int32)
        reqs.append(Request(
            rid=rid, prompt=prompt, max_new_tokens=max_new,
            priority=int(rng.integers(0, 3)),
            tags=("accuracy",) if rid % 3 == 0 else ()))
    for r in reqs:
        router.submit(r)
    ticks = router.run_until_drained()
    return router, reqs, ticks


def _run_blocked_pair(decode_block: int, requests: int, slots: int,
                      max_new: int, seed: int):
    """The same workload through a per-token and a blocked+calibrated
    int8 engine pair (shared raw params); returns both engines and the
    per-request token streams."""
    import jax

    from repro.configs import reduced
    from repro.models import registry
    from repro.serving.config import EngineConfig
    from repro.serving.engine import Request, ServingEngine

    import dataclasses
    cfg = dataclasses.replace(reduced("qwen2-0.5b"),
                              precision_policy="int8_serving")
    api = registry.build(cfg)
    params = api.init(jax.random.PRNGKey(seed))
    scales = None
    engines, tokens = {}, {}
    for blk in (1, decode_block):
        eng = ServingEngine(cfg, api, params, config=EngineConfig(
            batch_slots=slots, cache_len=64, decode_block=blk,
            act_calibration=scales or "auto"))
        scales = eng.act_scales      # calibrate once, share the scales
        rng = np.random.default_rng(seed)
        reqs = [Request(rid=rid,
                        prompt=rng.integers(0, cfg.vocab,
                                            int(rng.integers(3, 12)),
                                            dtype=np.int32),
                        max_new_tokens=max_new)
                for rid in range(requests)]
        for r in reqs:
            eng.submit(r)
        eng.run_until_drained()
        engines[blk] = eng
        tokens[blk] = {r.rid: list(r.tokens) for r in reqs}
    return engines, tokens


def _run_fused_pair(decode_block: int, requests: int, slots: int,
                    max_new: int, seed: int):
    """The same workload through a fused (``fused_executors='on'``) and
    a base (``'off'``) fidelity_int8 engine (shared params + scales):
    exact per-channel int8, so the fused kernels must reproduce the
    base datapath BIT-exactly — greedy streams are asserted identical,
    not merely close. Returns both engines and the token streams."""
    import dataclasses

    import jax

    from repro.configs import reduced
    from repro.models import registry
    from repro.serving.config import EngineConfig
    from repro.serving.engine import Request, ServingEngine

    cfg = dataclasses.replace(reduced("qwen2-0.5b"),
                              precision_policy="fidelity_int8")
    api = registry.build(cfg)
    params = api.init(jax.random.PRNGKey(seed))
    scales = None
    engines, tokens = {}, {}
    for mode in ("on", "off"):
        eng = ServingEngine(cfg, api, params, config=EngineConfig(
            batch_slots=slots, cache_len=64, decode_block=decode_block,
            act_calibration=scales or "auto", fused_executors=mode))
        scales = eng.act_scales
        rng = np.random.default_rng(seed)
        reqs = [Request(rid=rid,
                        prompt=rng.integers(0, cfg.vocab,
                                            int(rng.integers(3, 12)),
                                            dtype=np.int32),
                        max_new_tokens=max_new)
                for rid in range(requests)]
        for r in reqs:
            eng.submit(r)
        eng.run_until_drained()
        engines[mode] = eng
        tokens[mode] = {r.rid: list(r.tokens) for r in reqs}
    return engines, tokens


# deterministic bursty trace for the continuous-batching contract:
# rid -> (prompt_len, budget, submit_tick). rid 0 is the multi-wave long
# prompt, rid 4 is oversized (9 + 60 > cache_len 64, truncated-admit),
# rids 2/3/4 land mid-run while slots are busy (queue pressure)
_CONTINUOUS_TRACE = {
    0: (18, 7, 0),
    1: (5, 10, 0),
    2: (7, 11, 2),
    3: (4, 6, 3),
    4: (10, 60, 5),
}


def _drive_trace(cfg, api, params, config, stops):
    """Run the bursty trace: submits land at their trace tick (possibly
    mid-decode), the engine steps once per tick until drained."""
    from repro.serving.config import SamplingParams
    from repro.serving.engine import Request, ServingEngine

    rng = np.random.default_rng(1)
    prompts = {rid: rng.integers(0, cfg.vocab, n, dtype=np.int32)
               for rid, (n, _, _) in sorted(_CONTINUOUS_TRACE.items())}
    eng = ServingEngine(cfg, api, params, config=config)
    pending = {rid: t for rid, (_, _, t) in _CONTINUOUS_TRACE.items()}
    tick = 0
    while pending or eng.has_pending():
        for rid in [r for r, t in pending.items() if t <= tick]:
            del pending[rid]
            eng.submit(Request(
                rid=rid, prompt=prompts[rid],
                max_new_tokens=_CONTINUOUS_TRACE[rid][1],
                sampling=SamplingParams(stop_ids=stops.get(rid, ()))))
        eng.step()
        tick += 1
        if tick > 10_000:
            raise RuntimeError("continuous trace did not drain")
    return eng


def _run_continuous(decode_block: int, seed: int):
    """The continuous engine vs the flags-off (PR-5-style) baseline on
    the same bursty arrival trace; stop ids for rids 1 and 3 are
    harvested from the baseline's greedy streams so EOS events are
    guaranteed. Returns (continuous engine, baseline engine, expected
    per-rid streams)."""
    import dataclasses

    import jax

    from repro.configs import reduced
    from repro.models import registry
    from repro.quant.calibrate import calibrate_act_scales
    from repro.serving.config import EngineConfig

    cfg = dataclasses.replace(reduced("qwen2-0.5b"),
                              precision_policy="int8_serving")
    api = registry.build(cfg)
    params = api.init(jax.random.PRNGKey(seed))
    scales = calibrate_act_scales(cfg, api, params)
    base = EngineConfig(batch_slots=2, cache_len=64,
                        decode_block=decode_block, prefill_chunk=4,
                        act_calibration=scales)
    off = dataclasses.replace(base, mid_block_admission=False,
                              eos_stopping=False)
    ref = _drive_trace(cfg, api, params, off, stops={})
    streams = {r.rid: list(r.tokens) for r in ref.completed.values()}
    # harvest a stop id per EOS request from its greedy stream; the
    # expected continuous stream cuts at the FIRST occurrence
    stops, expected = {}, {}
    for rid, (n, budget, _) in _CONTINUOUS_TRACE.items():
        gen = streams[rid][n:]
        if rid in (1, 3):
            tok = gen[min(2, budget - 1)]
            stops[rid] = (int(tok),)
            expected[rid] = streams[rid][:n + gen.index(tok) + 1]
        else:
            expected[rid] = streams[rid]
    cont = _drive_trace(cfg, api, params, base, stops=stops)
    return cont, ref, expected, stops


def _run_cost_correction(slots: int, requests: int, seed: int):
    """Two same-policy replicas, one slowed by a dilated clock, under
    static vs online costing. Requests drain one at a time so load is
    zero at every routing decision: the static ranking ties (identical
    policies) and tie-breaks onto replica 0 — the slow one — while the
    online ranking reads the measured tok/s gap and picks the fast one.
    Returns {mode: routing counters}."""
    import dataclasses
    import time

    import jax

    from repro.configs import reduced
    from repro.models import registry
    from repro.serving.config import EngineConfig
    from repro.serving.engine import Request, ServingEngine
    from repro.serving.router import Replica, Router, replica_cost

    cfg = dataclasses.replace(reduced("qwen2-0.5b"),
                              precision_policy="bf16")
    api = registry.build(cfg)
    params = api.init(jax.random.PRNGKey(seed))
    shares = {}
    for mode in ("static", "online"):
        replicas = []
        for name, clock in (("slow", lambda: time.monotonic() * 8.0),
                            ("fast", time.monotonic)):
            eng = ServingEngine(cfg, api, params, clock=clock,
                                config=EngineConfig(batch_slots=slots,
                                                    cache_len=64))
            replicas.append(Replica(
                name=name, policy_name="bf16", engine=eng,
                cost=replica_cost(cfg, eng.policy)))
        router = Router(replicas, strategy="plan_aware",
                        cost_correction=mode)
        # warm-up: one request per replica seeds the measured stats
        # (the slow replica's dilated clock stretches its per-tick dt,
        # so its EWMA tok/s lands ~8x lower)
        for wid, rep in enumerate(replicas):
            rep.engine.submit(Request(
                rid=-(wid + 1),
                prompt=np.arange(1, 7, dtype=np.int32),
                max_new_tokens=4))
            rep.engine.run_until_drained()
        rng = np.random.default_rng(seed)
        for rid in range(requests):
            router.submit(Request(
                rid=rid,
                prompt=rng.integers(0, cfg.vocab, 6, dtype=np.int32),
                max_new_tokens=4))
            router.run_until_drained()
        shares[mode] = router.routing_counters()
    return shares


def _run_trace_contract(path: str, requests: int, slots: int,
                        max_new: int, seed: int):
    """Traced engine run: a schema-valid non-empty Chrome trace with
    every tick-phase span, every request-lifecycle stage, and >= 1
    compile span — and counters identical to an untraced engine on the
    same workload (tracing observes, never perturbs)."""
    import dataclasses
    import json

    import jax

    from repro.configs import reduced
    from repro.models import registry
    from repro.obs import validate_chrome_trace
    from repro.serving.config import EngineConfig
    from repro.serving.engine import Request, ServingEngine

    cfg = dataclasses.replace(reduced("qwen2-0.5b"),
                              precision_policy="int8_serving")
    api = registry.build(cfg)
    params = api.init(jax.random.PRNGKey(seed))
    scales = None
    engines = {}
    for trace in (True, False):
        eng = ServingEngine(cfg, api, params, config=EngineConfig(
            batch_slots=slots, cache_len=64, decode_block=4,
            act_calibration=scales or "auto", trace=trace))
        scales = eng.act_scales
        rng = np.random.default_rng(seed)
        for rid in range(requests):
            eng.submit(Request(
                rid=rid,
                prompt=rng.integers(0, cfg.vocab,
                                    int(rng.integers(3, 12)),
                                    dtype=np.int32),
                max_new_tokens=max_new))
        eng.run_until_drained()
        engines[trace] = eng
    traced = engines[True]
    traced.dump_trace(path)
    with open(path) as f:
        data = json.load(f)
    errs = validate_chrome_trace(data)
    assert not errs, errs[:5]
    events = data["traceEvents"]
    assert events, "trace is empty"
    names = [e["name"] for e in events]
    for phase in ("admission", "prefill_dispatch", "block_dispatch",
                  "host_sync", "harvest"):
        assert phase in names, f"missing tick-phase span {phase!r}"
    for stage in ("queued", "prefill", "decode", "first_token",
                  "finished"):
        assert stage in names, f"missing request span {stage!r}"
    assert any(str(n).startswith("compile:") for n in names), \
        "cold traced engine recorded no compile spans"
    assert dict(traced.counters) == dict(engines[False].counters), \
        (dict(traced.counters), dict(engines[False].counters))
    return len(events)


def main(argv: Optional[List[str]] = None) -> int:
    ap = argparse.ArgumentParser(
        prog="repro.serving smoke", description=__doc__)
    ap.add_argument("--requests", type=int, default=9)
    ap.add_argument("--slots", type=int, default=2)
    ap.add_argument("--max-new", type=int, default=3)
    ap.add_argument("--decode-block", type=int, default=4,
                    help="block size of the fast-path replica (>= 2: "
                         "the contract compares it against per-token)")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--trace", metavar="PATH", default=None,
                    help="also run the observability contract and "
                         "write the traced engine's Chrome trace here")
    args = ap.parse_args(argv)
    if args.decode_block < 2:
        ap.error("--decode-block must be >= 2 (the blocked replica is "
                 "compared against a decode_block=1 engine)")

    router, reqs, ticks = _run_workload(args.requests, args.slots,
                                        args.max_new, args.seed)
    counters = router.routing_counters()
    report = router.report()

    # --- completion: every request finished with the asked-for tokens
    completed = router.completed
    assert len(completed) == len(reqs), \
        f"{len(reqs) - len(completed)} requests never completed"
    for r in reqs:
        assert r.done and r.new_tokens == args.max_new, \
            f"req{r.rid}: done={r.done} new={r.new_tokens}"

    # --- routing: both replicas took traffic
    for name, n in counters.items():
        assert n > 0, f"replica {name!r} received no traffic: {counters}"

    # --- admission went through chunked prefill, not teacher forcing
    for name, rep in report["replicas"].items():
        c = rep["metrics"]["counters"]
        assert c["teacher_forced_tokens"] == 0, (name, c)
        assert c["prefill_calls"] > 0, (name, c)
        assert rep["metrics"]["ttft_s"], f"{name}: no TTFT samples"
        assert rep["metrics"]["queue_delay_s"], f"{name}: no queue delays"

    # --- prepared-weight contract: the int4 replica holds packed
    # storage and its decode trace never quantizes a weight
    int4 = next(rep for rep in router.replicas
                if rep.policy_name == "int4_serving")
    assert int4.engine.prepared, "int4 replica did not prepare weights"
    assert int4.engine.weight_quant_trace_count() == 0, \
        "prepared int4 replica still quantizes weights per decode step"
    wb = int4.engine.weight_bytes()
    raw = next(rep for rep in router.replicas if rep.policy_name == "bf16")
    raw_proj = raw.engine.weight_bytes()["projections"]
    assert wb["projections"] * 6 <= raw_proj, (wb, raw_proj)
    # the counter hook is live: an unprepared engine shows > 0
    from repro.serving.config import EngineConfig
    from repro.serving.engine import ServingEngine
    dyn = ServingEngine(int4.engine.cfg, int4.engine.api,
                        raw.engine.params,
                        config=EngineConfig(batch_slots=args.slots,
                                            cache_len=64,
                                            prepare_weights=False))
    dyn_quants = dyn.weight_quant_trace_count()
    assert dyn_quants > 0, "dynamic control engine counted no quants"

    # --- determinism: an identical second run routes identically
    router2, _, _ = _run_workload(args.requests, args.slots,
                                  args.max_new, args.seed)
    assert router2.routing_counters() == counters, \
        (router2.routing_counters(), counters)

    # --- decode fast path: a blocked + calibrated replica reproduces
    # the per-token engine token-for-token, honours the counter
    # contract (a tick dispatches at most one block, a block syncs the
    # host once), and the fast path still performs zero per-step weight
    # quants and zero per-token activation absmax reduces
    blk = args.decode_block
    engines, tokens = _run_blocked_pair(blk, args.requests, args.slots,
                                        args.max_new, args.seed)
    assert tokens[blk] == tokens[1], \
        "blocked decode diverged from per-token decode"
    fast, per_tok = engines[blk].counters, engines[1].counters
    assert per_tok["host_syncs"] == per_tok["decode_steps"], per_tok
    assert fast["decode_steps"] <= fast["ticks"] * blk, (fast, blk)
    assert fast["host_syncs"] * blk >= fast["decode_steps"], (fast, blk)
    assert fast["host_syncs"] < per_tok["host_syncs"], (fast, per_tok)
    assert engines[blk].weight_quant_trace_count() == 0, \
        "blocked replica quantizes weights per decode step"
    assert engines[blk].act_quant_trace_count() == 0, \
        "calibrated replica still absmax-reduces activations"
    assert dyn.act_quant_trace_count() > 0, \
        "dynamic control engine counted no activation quants"

    # --- fused executors: the blocked + calibrated replica resolved
    # fused_executors="auto" onto the fused datapath — its traced decode
    # program materializes ZERO staged compute-dtype operands (prepared
    # storage enters the kernels directly), and the token-identity
    # assert above therefore already pinned fused block invariance; a
    # staged control (fused_executors="off", same params + scales) shows
    # the count_staged hook is live
    assert engines[blk].fused, "calibrated blocked replica did not fuse"
    assert engines[blk].staged_trace_count() == 0, \
        "fused replica still materializes staged operands"
    import jax

    from repro.serving.engine import ServingEngine as _SE
    staged_ctl = _SE(engines[blk].cfg, engines[blk].api,
                     engines[blk].api.init(jax.random.PRNGKey(args.seed)),
                     config=EngineConfig(
                         batch_slots=args.slots, cache_len=64,
                         decode_block=blk,
                         act_calibration=engines[blk].act_scales,
                         fused_executors="off"))
    staged_mats = staged_ctl.staged_trace_count()
    assert staged_mats > 0, "staged control counted no materializations"
    # fused_executors="on" is a hard contract: without prepared weights
    # there is no fused storage to consume, so construction must refuse
    try:
        _SE(engines[blk].cfg, engines[blk].api, staged_ctl.params,
            config=EngineConfig(batch_slots=args.slots, cache_len=64,
                                prepare_weights=False,
                                fused_executors="on"))
    except ValueError:
        pass
    else:
        raise AssertionError(
            "fused_executors='on' accepted prepare_weights=False")

    # --- fused bit-exactness: exact per-channel int8 (fidelity_int8)
    # through fused vs staged executors produces IDENTICAL greedy
    # streams — the fused kernels are the same integer math, not an
    # approximation of it
    fus_engines, fus_tokens = _run_fused_pair(
        blk, args.requests, args.slots, args.max_new, args.seed)
    assert fus_tokens["on"] == fus_tokens["off"], \
        "fused exact-int8 streams diverged from the base datapath"
    # exact specs never stage (storage operands feed the kernels on
    # both paths), so BOTH engines trace zero materializations — the
    # int8_serving staged control above is what proves the hook is live
    assert fus_engines["on"].staged_trace_count() == 0 \
        and fus_engines["off"].staged_trace_count() == 0, \
        (fus_engines["on"].staged_trace_count(),
         fus_engines["off"].staged_trace_count())

    # --- continuous batching: bursty arrivals, chunked prefill
    # continuation, mid-block admission, EOS stopping — all against a
    # flags-off baseline on the same trace
    cont, ref, expected, stops = _run_continuous(blk, args.seed)
    cc, rc = cont.counters, ref.counters
    got = {r.rid: list(r.tokens) for r in cont.completed.values()}
    assert got == expected, "continuous greedy streams diverged"
    # long prompt (rid 0) streamed through > 1 prefill wave while the
    # engine kept ticking: with chunk 4, 17 prefill tokens need 5 waves
    assert cc["prefill_calls"] >= 5, cc
    assert cc["teacher_forced_tokens"] == 0, cc
    # queue pressure cut blocks short and at least one admission landed
    # right after a shortened block
    assert cc["short_blocks"] > 0, cc
    assert cc["mid_block_admits"] > 0, cc
    assert rc["short_blocks"] == 0 and rc["mid_block_admits"] == 0, rc
    # EOS: the stop requests ended mid-budget, freeing slot + budget
    assert cc["eos_stops"] == len(stops), (cc, stops)
    for rid in stops:
        req = cont.completed[rid]
        assert req.finish_reason == "stop", (rid, req.finish_reason)
        assert req.new_tokens < req.budget, (rid, req.new_tokens)
    # the oversized request admitted (trailing-window) and ran its
    # full budget instead of being rejected at submit
    over = cont.completed[4]
    assert over.truncated and over.new_tokens == 60, \
        (over.truncated, over.new_tokens)
    assert rc["eos_stops"] == 0 and ref.completed[1].new_tokens == 10, rc
    # the continuous fast path stays on prepared weights + static scales
    assert cont.weight_quant_trace_count() == 0, \
        "continuous replica quantizes weights per decode step"
    assert cont.act_quant_trace_count() == 0, \
        "continuous replica still absmax-reduces activations"

    # --- online cost correction: measured throughput moves traffic off
    # an artificially slowed replica that static costing cannot see
    shares = _run_cost_correction(args.slots, requests=6,
                                  seed=args.seed)
    assert shares["static"]["slow"] == 6 and \
        shares["static"]["fast"] == 0, shares["static"]
    assert shares["online"]["fast"] == 6 and \
        shares["online"]["slow"] == 0, shares["online"]

    # --- observability: traced run exports a valid Chrome trace and
    # perturbs nothing (only with --trace: the extra engine pair costs
    # compiles the default CI smoke doesn't need)
    trace_events = None
    if args.trace:
        trace_events = _run_trace_contract(args.trace, args.requests,
                                           args.slots, args.max_new,
                                           args.seed)

    for name, rep in report["replicas"].items():
        m = rep["metrics"]
        print(f"replica {name}: routed={rep['routed']} "
              f"cycles/tok={rep['cost']['cycles_per_token']:.3g} "
              f"acc_proxy={rep['cost']['acc_proxy']:.3g} "
              f"ttft_p50={m['ttft_s'].get('p50', 0) * 1e3:.1f}ms "
              f"queue_p90={m['queue_delay_s'].get('p90', 0) * 1e3:.1f}ms")
    print(f"serving-smoke OK: {len(completed)} requests over "
          f"{len(counters)} replicas in {ticks} ticks, "
          f"counters={counters}; int4 prepared "
          f"{wb['projections']}B vs {raw_proj}B fp32 projections, "
          f"0 weight quants/step (dynamic control: {dyn_quants}); "
          f"decode_block={blk} token-identical with "
          f"{fast['host_syncs']} syncs / {fast['decode_steps']} steps "
          f"(per-token: {per_tok['host_syncs']}), 0 act quants/step "
          f"(dynamic control: {dyn.act_quant_trace_count()}); "
          f"fused: 0 staged mats/step (staged control: {staged_mats}), "
          f"exact-int8 fused==staged streams; "
          f"continuous: {cc['prefill_calls']} prefill waves, "
          f"{cc['short_blocks']} short blocks, "
          f"{cc['mid_block_admits']} mid-block admits, "
          f"{cc['eos_stops']} EOS stops, streams identical to the "
          f"flags-off baseline; cost correction static={shares['static']} "
          f"online={shares['online']}"
          + (f"; trace: {trace_events} events -> {args.trace}"
             if args.trace else ""))
    return 0
