"""``python -m repro.serving <command>`` — currently: smoke."""
import sys


def main(argv=None) -> int:
    argv = list(sys.argv[1:] if argv is None else argv)
    if not argv or argv[0] in ("-h", "--help"):
        print("usage: python -m repro.serving smoke [options]")
        return 0 if argv else 2
    cmd, rest = argv[0], argv[1:]
    if cmd == "smoke":
        from repro.serving.smoke import main as smoke_main
        return smoke_main(rest)
    print(f"unknown command {cmd!r} (want: smoke)", file=sys.stderr)
    return 2


if __name__ == "__main__":
    sys.exit(main())
