"""Admission scheduling: bounded queue, priorities, starvation control.

The scheduler owns the waiting line in front of a ``ServingEngine``'s
decode slots. It is deliberately clock-free — every entry point takes
``now`` from the caller (the engine injects its own clock), so tests can
drive promotion and queue-delay behavior with synthetic timestamps.

Three policies compose in ``select``:

  * **priority** — lower ``Request.priority`` admits first (FIFO within
    a priority class);
  * **max-waiting-time promotion** — a request waiting longer than
    ``max_wait`` seconds jumps every priority class (FIFO among the
    promoted), so low-priority traffic cannot starve;
  * **prefill/decode interleaving** — ``prefill_budget`` caps the prompt
    tokens admitted per wave. A wave that already admitted one request
    defers prompts that exceed the remaining budget to a later tick, so
    a burst of long prompts cannot monopolize the engine while decode
    slots sit idle; the first pick is always admitted (progress
    guarantee) and promoted requests bypass the budget.

With the chunked-prefill continuous engine the budget's role softens:
an admitted long prompt no longer stalls decode (it streams through
fixed-size prefill waves while other slots generate), so the budget now
paces how much *prefill bandwidth per tick* admission can commit rather
than protecting decode from a prefill monopoly. Queue depth also feeds
back into the engine's block-length choice (mid-block admission): a
non-empty waiting line shortens decode blocks so ``select`` runs again
sooner.
"""
from __future__ import annotations

import dataclasses
from typing import List, Optional, Tuple

from repro.serving.engine import Request


class SchedulerFull(RuntimeError):
    """Raised when the bounded admission queue rejects a submit."""


@dataclasses.dataclass
class AdmissionScheduler:
    max_queue: int = 256           # bounded queue: submits beyond raise
    max_wait: float = 5.0          # seconds before promotion to the front
    prefill_budget: Optional[int] = None   # prompt tokens per admit wave

    def __post_init__(self):
        self._waiting: List[Tuple[int, Request]] = []
        self._seq = 0              # FIFO tiebreaker within a class
        # requeued entries draw seqs from a far-negative counter: all
        # outrank normal submits, FIFO among themselves
        self._front = -(1 << 31)
        self.depth_highwater = 0   # deepest the queue has ever been
        self.requeued = 0          # failure-recovery re-entries

    def __len__(self) -> int:
        return len(self._waiting)

    @property
    def pending(self) -> List[Request]:
        return [r for _, r in self._waiting]

    def pending_new_tokens(self) -> int:
        """Upper bound on decode tokens the waiting line still owes —
        what a backpressure retry-after estimate divides by fleet
        throughput."""
        return sum(r.budget for _, r in self._waiting)

    def submit(self, req: Request, now: Optional[float] = None) -> None:
        if len(self._waiting) >= self.max_queue:
            raise SchedulerFull(
                f"admission queue full ({self.max_queue} waiting)")
        if now is not None and req.submit_time is None:
            req.submit_time = now
        self._waiting.append((self._seq, req))
        self._seq += 1
        if len(self._waiting) > self.depth_highwater:
            self.depth_highwater = len(self._waiting)

    def requeue(self, req: Request) -> None:
        """Failure-recovery re-entry: put back a request that was
        already admitted somewhere that died.

        Differs from ``submit`` in exactly the ways recovery demands:
        the bounded-queue check is bypassed (recovery must never drop
        admitted work — the queue bound protects against NEW load, and
        a requeue adds back work the fleet already accepted), the entry
        goes to the FRONT of its priority class (decreasing negative
        seq: FIFO among requeued, ahead of every normal submit), and
        ``submit_time`` is preserved so max-wait promotion counts from
        the original submission.
        """
        self._waiting.append((self._front, req))
        self._front += 1
        self.requeued += 1
        if len(self._waiting) > self.depth_highwater:
            self.depth_highwater = len(self._waiting)

    def _promoted(self, req: Request, now: float) -> bool:
        return (req.submit_time is not None
                and now - req.submit_time >= self.max_wait)

    def select(self, n_slots: int, now: float) -> List[Request]:
        """Pop up to ``n_slots`` requests for this admission wave."""
        if n_slots <= 0 or not self._waiting:
            return []

        def key(item):
            seq, r = item
            return (0 if self._promoted(r, now) else 1, r.priority, seq)

        picked: List[Tuple[int, Request]] = []
        budget = self.prefill_budget
        for item in sorted(self._waiting, key=key):
            if len(picked) >= n_slots:
                break
            _, req = item
            cost = max(len(req.prompt) - 1, 0)
            if (budget is not None and picked and cost > budget
                    and not self._promoted(req, now)):
                continue    # defer the long prompt; decode keeps running
            picked.append(item)
            if budget is not None:
                budget -= cost
        for item in picked:
            self._waiting.remove(item)
        return [r for _, r in picked]
