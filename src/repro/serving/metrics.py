"""Per-request serving metrics: TTFT, queue delay, throughput.

Everything is computed from the four timestamps the engine stamps on a
``Request`` (submit/admit/first-token/finish) and returned as plain
dicts — the schema benches serialize into ``BENCH_serving.json`` and
tests assert on.

Schema (``summarize_requests``)::

    {"n": int, "new_tokens": int,
     "ttft_s":        <percentile block>,
     "queue_delay_s": <percentile block>,
     "e2e_s":         <percentile block>,
     "tok_per_s_per_request": <percentile block>}

where ``<percentile block>`` is the canonical summary defined once in
``repro.obs.registry`` (one ``p<N>`` key per entry of ``PERCENTILES``
plus ``mean``/``max``; ``{}`` when no request carries the timestamps —
e.g. nothing completed yet). ``PERCENTILES`` and the block builder are
re-exported here for backward compatibility.

``slo_report`` layers the serving-quality view on top: SLO attainment
(fraction of requests whose TTFT meets a deadline) and goodput (tokens
per second counting only attaining requests) — the pair the bursty
open-loop bench compares across engine configurations.
"""
from __future__ import annotations

from typing import Dict, Iterable, Optional, Sequence

from repro.obs.registry import PERCENTILES, percentile_block
from repro.serving.engine import Request

__all__ = ["PERCENTILES", "percentiles", "request_metrics",
           "summarize_requests", "slo_report"]


def percentiles(values: Sequence[float],
                ps: Sequence[int] = PERCENTILES) -> Dict[str, float]:
    """Summary block of a sample; ``{}`` for an empty sample. Alias of
    :func:`repro.obs.registry.percentile_block` (the canonical home)."""
    return percentile_block(values, ps)


def request_metrics(req: Request) -> Dict[str, Optional[float]]:
    """Latency decomposition of one request (None where not measured)."""
    new = 0 if req.tokens is None else len(req.tokens) - len(req.prompt)

    def span(a, b):
        return None if a is None or b is None else max(b - a, 0.0)

    e2e = span(req.submit_time, req.finish_time)
    gen = span(req.admit_time, req.finish_time)
    return {
        "ttft_s": span(req.submit_time, req.first_token_time),
        "queue_delay_s": span(req.submit_time, req.admit_time),
        "e2e_s": e2e,
        "new_tokens": new,
        "tok_per_s": (new / gen) if gen else None,
    }


def summarize_requests(reqs: Iterable[Request]) -> Dict:
    """Aggregate percentile blocks over a set of (completed) requests."""
    rows = [request_metrics(r) for r in reqs]
    return {
        "n": len(rows),
        "new_tokens": int(sum(r["new_tokens"] for r in rows)),
        "ttft_s": percentiles([r["ttft_s"] for r in rows]),
        "queue_delay_s": percentiles([r["queue_delay_s"] for r in rows]),
        "e2e_s": percentiles([r["e2e_s"] for r in rows]),
        "tok_per_s_per_request": percentiles(
            [r["tok_per_s"] for r in rows]),
    }


def slo_report(reqs: Iterable[Request], ttft_slo_s: float) -> Dict:
    """SLO attainment + goodput over a set of completed requests.

    A request ATTAINS when its TTFT (submit -> first token) is at most
    ``ttft_slo_s``; requests that never produced a token (zero-budget
    completions) are excluded from the denominator. Goodput counts only
    the generated tokens of attaining requests, over the span from the
    earliest submit to the latest finish — so a config that burns the
    batch on requests that miss their deadline scores low even at equal
    raw throughput.

    Mid-run snapshots are fine: when every request is still in flight
    (first token seen, nothing finished yet) the span falls back to the
    latest first-token time and goodput is the PARTIAL rate over the
    tokens generated so far — it used to raise on the empty ``max()``.
    ``completed`` counts the requests that actually finished.
    """
    rows = [r for r in reqs if r.first_token_time is not None]
    if not rows:
        return {"n": 0, "completed": 0, "ttft_slo_s": float(ttft_slo_s),
                "attainment": None, "goodput_tok_per_s": None}
    attain = [r for r in rows
              if (r.first_token_time - r.submit_time) <= ttft_slo_s]
    finished = [r.finish_time for r in rows if r.finish_time is not None]
    t0 = min(r.submit_time for r in rows)
    # all-in-flight snapshot: no finish yet, measure up to the latest
    # first token instead of raising on an empty max()
    t1 = max(finished) if finished \
        else max(r.first_token_time for r in rows)
    span = max(t1 - t0, 1e-9)
    good = sum(len(r.tokens) - len(r.prompt) for r in attain)
    return {
        "n": len(rows),
        "completed": len(finished),
        "ttft_slo_s": float(ttft_slo_s),
        "attainment": len(attain) / len(rows),
        "goodput_tok_per_s": good / span,
    }
