"""Serving configuration surfaces: ``EngineConfig`` + ``SamplingParams``.

The serving API separates three concerns (FlexiBit's lesson in
PAPERS.md — keep the precision ladder orthogonal to the control plane):

* **plan/policy** — ``ModelConfig.precision_policy`` (a preset name or
  ``plan:<file>`` artifact), owned by the model config;
* **engine tuning** — :class:`EngineConfig`, one frozen dataclass
  validated at construction, passed as ``ServingEngine(cfg, api,
  params, config=EngineConfig(...))``;
* **per-request sampling** — :class:`SamplingParams` on each
  ``Request`` (temperature/top-k/top-p/stop ids/budget/seed); greedy is
  ``SamplingParams(temperature=0.0)``, the default.

The legacy 12-kwarg ``ServingEngine(batch_slots=..., decode_block=...)``
construction maps onto ``EngineConfig`` through a deprecation shim in
the engine (one ``DeprecationWarning``, same semantics).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Optional, Tuple

# stop-id slots carried per decode slot inside the jitted scan state
# (fixed so the blocked program's shape never depends on a request)
MAX_STOP_IDS = 4

_PREFILL_MODES = ("auto", "batched", "teacher")


@dataclasses.dataclass(frozen=True)
class EngineConfig:
    """Engine-level tuning knobs of a :class:`~repro.serving.engine.
    ServingEngine`, validated at construction.

    ``mid_block_admission`` lets the engine cut decode blocks short
    when requests are queued (block boundaries chosen by queue depth
    and the nearest completion, floored at half the configured block so
    the extra host syncs stay bounded), so a freed slot admits after
    roughly half a block instead of a full one.
    ``eos_stopping`` honours per-request stop ids (plus the engine-wide
    ``eos_id``) inside the blocked scan, freeing slots and budget
    mid-block. Turning both off reproduces the PR-5 between-block
    engine — the ablation baseline.

    ``fused_executors`` selects the fused Pallas datapath
    (``kernels.fused`` via ``layers.mplinear.executor_variant``):
    ``"on"`` traces every engine program under the 'fused' variant and
    skips the per-block staging walk (no staged compute-dtype operand is
    ever materialized); ``"off"`` keeps the staged path; ``"auto"``
    (default) turns it on exactly when the engine prepared weights and
    resolved calibrated activation scales — the operands the fused
    kernels need.

    Observability (``repro.obs``): ``trace=True`` records request
    lifecycle + tick-phase + compile spans on the engine's
    :class:`~repro.obs.Tracer` (``engine.dump_trace(path)`` exports
    Chrome trace-event JSON; tracing off costs nothing).
    ``cost_correction`` declares how a router should cost this replica:
    ``"static"`` keeps the simulator estimate, ``"online"`` blends in
    the measured :class:`~repro.obs.ReplicaStats` (EWMA tok/s over
    per-tick samples with weight ``stats_alpha``; TTFT p95 and rolling
    gauges over the last ``stats_window`` samples).
    """

    batch_slots: int = 4
    cache_len: int = 512
    prefill: str = "auto"              # auto | batched | teacher
    prefill_chunk: int = 32            # prompt tokens per prefill wave
    decode_block: int = 1              # decode steps per host dispatch
    prepare_weights: bool = True
    act_calibration: Any = None        # None | {path: scale} | "auto"
    fused_executors: str = "auto"      # auto | on | off
    mid_block_admission: bool = True
    eos_stopping: bool = True
    eos_id: Optional[int] = None       # engine-wide stop id (e.g. <eos>)
    seed: int = 0                      # base PRNG seed for sampling
    trace: bool = False                # record spans (obs.Tracer)
    cost_correction: str = "static"    # static | online (router costing)
    stats_window: int = 64             # rolling gauge / TTFT window
    stats_alpha: float = 0.2           # EWMA weight of newest rate sample

    def __post_init__(self):
        if self.batch_slots < 1:
            raise ValueError(f"batch_slots must be >= 1, got "
                             f"{self.batch_slots}")
        if self.cache_len < 1:
            raise ValueError(f"cache_len must be >= 1, got "
                             f"{self.cache_len}")
        if self.prefill not in _PREFILL_MODES:
            raise ValueError(f"prefill mode {self.prefill!r} "
                             f"(want one of {_PREFILL_MODES})")
        if self.prefill_chunk < 1:
            raise ValueError(f"prefill_chunk must be >= 1, got "
                             f"{self.prefill_chunk}")
        if self.decode_block < 1:
            raise ValueError(f"decode_block must be >= 1, got "
                             f"{self.decode_block}")
        if self.fused_executors not in ("auto", "on", "off"):
            raise ValueError(
                f"fused_executors must be 'auto', 'on' or 'off', got "
                f"{self.fused_executors!r}")
        if self.eos_id is not None and self.eos_id < 0:
            raise ValueError(f"eos_id must be a token id, got "
                             f"{self.eos_id}")
        if self.cost_correction not in ("static", "online"):
            raise ValueError(
                f"cost_correction must be 'static' or 'online', got "
                f"{self.cost_correction!r}")
        if self.stats_window < 1:
            raise ValueError(f"stats_window must be >= 1, got "
                             f"{self.stats_window}")
        if not 0.0 < self.stats_alpha <= 1.0:
            raise ValueError(f"stats_alpha must be in (0, 1], got "
                             f"{self.stats_alpha}")

    # legacy kwargs of the pre-EngineConfig ServingEngine signature that
    # map 1:1 onto config fields ('greedy' is accepted and ignored —
    # selection is per-request now, see SamplingParams)
    _LEGACY_FIELDS = ("batch_slots", "cache_len", "prefill",
                      "prefill_chunk", "decode_block", "prepare_weights",
                      "act_calibration", "mid_block_admission",
                      "eos_stopping", "eos_id", "seed")

    @classmethod
    def from_legacy_kwargs(cls, kwargs) -> "EngineConfig":
        """Map old ``ServingEngine(batch_slots=..., ...)`` kwargs onto a
        config; raises on kwargs that never existed."""
        kw = dict(kwargs)
        kw.pop("greedy", None)
        unknown = set(kw) - set(cls._LEGACY_FIELDS)
        if unknown:
            raise TypeError(
                f"unknown ServingEngine kwargs: {sorted(unknown)}")
        return cls(**kw)


@dataclasses.dataclass(frozen=True)
class SamplingParams:
    """Per-request decoding parameters (vLLM-shaped), carried on
    ``Request.sampling``.

    ``temperature <= 0`` selects greedy argmax (the default);
    ``top_k=0`` / ``top_p=1.0`` leave the distribution unrestricted.
    ``stop_ids`` end the stream as soon as one is generated (the stop
    token is kept in the output); ``max_new_tokens`` overrides the
    request-level budget when set. ``seed`` pins the request's PRNG key
    — otherwise the key derives from the engine seed and the request id
    (``fold_in``), so sampled streams are reproducible regardless of
    slot placement, co-resident requests, or ``decode_block``.
    """

    temperature: float = 0.0
    top_k: int = 0
    top_p: float = 1.0
    stop_ids: Tuple[int, ...] = ()
    max_new_tokens: Optional[int] = None
    seed: Optional[int] = None

    def __post_init__(self):
        if self.temperature < 0.0:
            raise ValueError(f"temperature must be >= 0, got "
                             f"{self.temperature}")
        if self.top_k < 0:
            raise ValueError(f"top_k must be >= 0, got {self.top_k}")
        if not 0.0 < self.top_p <= 1.0:
            raise ValueError(f"top_p must be in (0, 1], got "
                             f"{self.top_p}")
        stops = tuple(int(t) for t in self.stop_ids)
        if any(t < 0 for t in stops):
            raise ValueError(f"stop_ids must be token ids, got {stops}")
        if len(stops) > MAX_STOP_IDS:
            raise ValueError(
                f"at most {MAX_STOP_IDS} stop_ids per request "
                f"(got {len(stops)}; the blocked scan carries a fixed "
                f"number of per-slot stop slots)")
        object.__setattr__(self, "stop_ids", stops)
        if self.max_new_tokens is not None and self.max_new_tokens < 0:
            raise ValueError(f"max_new_tokens must be >= 0, got "
                             f"{self.max_new_tokens}")

    @property
    def greedy(self) -> bool:
        return self.temperature <= 0.0
