"""Continuous-batching serving engine (vLLM-shaped).

``make_serve_fns`` builds the sharded prefill/decode artifacts the
dry-run lowers for the prefill_32k / decode_32k / long_500k cells.
``ServingEngine`` is the single-replica runtime: fixed decode slots over
one shared KV cache, an :class:`repro.serving.scheduler.AdmissionScheduler`
in front, and a steady-state loop in which prefill and decode
interleave. Engine tuning lives in one frozen
:class:`repro.serving.config.EngineConfig` (``ServingEngine(cfg, api,
params, config=EngineConfig(...))``; the legacy kwargs still map
through a deprecation shim), and per-request decoding behavior lives in
:class:`repro.serving.config.SamplingParams` on each ``Request``.

The continuous loop, per tick:

* **admission** drains the scheduler into free slots;
* **chunked prefill continuation** advances every prefilling slot by
  one ``prefill_chunk``-token wave in a SINGLE fixed-shape jitted
  dispatch (``api.prefill_chunk``: position-offset scatter into the
  live cache — one compile ever, no per-bucket programs). Long prompts
  stream through multiple waves while other slots keep decoding, so
  admission no longer requires ``prompt + generation <= cache_len``:
  oversized requests serve with trailing-window (ring) context and are
  stamped ``Request.truncated``;
* **decode** runs one block: ``decode_block`` scan steps with on-device
  selection (``models.registry.make_block_decode``), ONE host sync.
  With ``mid_block_admission`` the engine cuts the block short while
  requests are queued (boundaries chosen by queue depth), so freed
  slots admit mid-stream instead of after a full drain. With
  ``eos_stopping`` a generated stop id zeroes the slot's budget ON
  DEVICE: short completions free their slot and budget mid-block.
  Selection is per-request — greedy argmax by default, or
  temperature/top-k/top-p sampling (``models.sampling.sample_tokens``)
  with the PRNG key threaded through the scan carry, so sampled
  streams are seeded-deterministic and invariant to ``decode_block``.

Why position-offset prefill is safe here: the KV cache is
position-tagged (``layers.attention.KVCache.pos``) and attention masks
by tag, so chunk writes at absolute positions compose exactly like
decode writes, and the garbage a masked pad row writes carries tags the
next real write overwrites before any query attends them. That
invariant holds for attention caches but *not* for recurrent state
(rwkv/griffin fold every consumed token into O(1) state), so the fast
path is gated per family and everything else falls back to the
teacher-forced admission loop the engine always had.

Weights are PREPARED at construction (``quant.prepare`` via the model
family's ``api.prepare`` hook, default on) and activation scales can be
CALIBRATED (``act_calibration=``) — see quant/prepare.py and
quant/calibrate.py; the trace counters
(``weight_quant_trace_count`` / ``act_quant_trace_count``) assert the
fast path performs zero dynamic weight quants and zero per-token
activation absmax reduces. Dynamically-scaled fake-quant projections
couple batch rows through their shared per-tensor absmax and are
rejected for ``decode_block > 1`` at construction.
"""
from __future__ import annotations

import dataclasses
import time
import warnings
from typing import Callable, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh

from repro.configs.base import ModelConfig
from repro.core import policy as policy_mod
from repro.models import registry
from repro.obs import MetricsRegistry, ReplicaStats, Tracer, traced_jit
from repro.parallel import sharding as shd
from repro.serving.config import (MAX_STOP_IDS, EngineConfig,
                                  SamplingParams)


def _with_variant(fn: Callable, name: Optional[str]) -> Callable:
    """Trace ``fn`` under ``layers.mplinear.executor_variant(name)``:
    the context is held over the function *body* (which jax executes at
    trace time), so every mp_linear dispatch the program contains
    resolves against the named executor variant."""
    if name is None:
        return fn
    from repro.layers.mplinear import executor_variant

    def wrapped(*args, **kwargs):
        with executor_variant(name):
            return fn(*args, **kwargs)

    return wrapped

# families whose prefill consumes only tokens and whose caches are
# position-tagged (padding-safe): eligible for the chunked prefill path
_FAST_PREFILL_FAMILIES = ("lm",)


def make_serve_fns(api: registry.ModelAPI, mesh: Mesh,
                   batch_shape: Dict, cache_len: int, batch_size: int):
    """Returns (jitted prefill, jitted decode, cache shardings)."""
    cache_shape = jax.eval_shape(lambda: api.init_cache(batch_size,
                                                        cache_len))
    cache_shard = shd.cache_shardings(cache_shape, mesh)
    param_shape = jax.eval_shape(api.init, jax.random.PRNGKey(0))
    param_shard = shd.param_shardings(param_shape, mesh)

    prefill_in = {k: v for k, v in batch_shape.items()
                  if k not in ("token", "pos")}
    pf_shard = shd.batch_shardings(prefill_in, mesh) if prefill_in else None

    prefill = jax.jit(
        lambda p, b, c: api.prefill(p, b, c),
        in_shardings=(param_shard, pf_shard, cache_shard),
        donate_argnums=(2,))

    # decode state sharding may differ from cache (encdec carries enc_out)
    def _decode(p, b, c):
        return api.decode_step(p, b, c)

    decode = jax.jit(_decode, in_shardings=(param_shard, None, None),
                     donate_argnums=(2,))
    return prefill, decode, cache_shard, param_shard


@dataclasses.dataclass
class Request:
    rid: int
    prompt: np.ndarray           # (S,) int32
    max_new_tokens: int = 16
    priority: int = 0            # lower admits first (see scheduler)
    tags: Tuple[str, ...] = ()   # e.g. ("accuracy",) for router SLOs
    tokens: Optional[List[int]] = None
    done: bool = False
    error: Optional[str] = None        # set on terminal admission errors
    next_input: Optional[int] = None   # next token to feed decode
    # timestamps stamped by scheduler/engine (engine clock domain)
    submit_time: Optional[float] = None
    admit_time: Optional[float] = None
    first_token_time: Optional[float] = None
    finish_time: Optional[float] = None
    # per-request decoding parameters (greedy by default)
    sampling: SamplingParams = SamplingParams()
    finish_reason: Optional[str] = None   # 'length' | 'stop'
    truncated: bool = False        # served with trailing-window context
    prefill_pos: int = 0           # prompt tokens consumed by prefill

    @property
    def new_tokens(self) -> int:
        return 0 if self.tokens is None else len(self.tokens) - len(self.prompt)

    @property
    def budget(self) -> int:
        """Effective generation budget: ``sampling.max_new_tokens``
        when set, else the request-level ``max_new_tokens``."""
        if self.sampling.max_new_tokens is not None:
            return self.sampling.max_new_tokens
        return self.max_new_tokens


class ServingEngine:
    """Slot-based continuous batching with chunked prefill admission.

    All slots share one decode program (fixed batch); free slots idle on
    pad tokens. Admission drains the scheduler into free slots; every
    tick one fixed-shape ``(slots, prefill_chunk)`` prefill wave
    advances all prefilling slots at their own position offsets while
    decode keeps running for the rest — no drain barrier between
    admission and generation.
    """

    def __init__(self, cfg: ModelConfig, api: registry.ModelAPI, params,
                 config: Optional[EngineConfig] = None, *,
                 scheduler=None,
                 clock: Callable[[], float] = time.monotonic,
                 **legacy_kwargs):
        from repro.serving.scheduler import AdmissionScheduler
        if legacy_kwargs:
            if config is not None:
                raise TypeError(
                    "pass either config=EngineConfig(...) or the legacy "
                    f"kwargs, not both: {sorted(legacy_kwargs)}")
            warnings.warn(
                "ServingEngine(batch_slots=..., cache_len=..., ...) "
                "kwargs are deprecated; pass config=EngineConfig(...) "
                "(and per-request SamplingParams instead of 'greedy')",
                DeprecationWarning, stacklevel=2)
            config = EngineConfig.from_legacy_kwargs(legacy_kwargs)
        self.config = config if config is not None else EngineConfig()
        self.cfg = cfg
        self.api = api
        self.b = self.config.batch_slots
        self.cache_len = self.config.cache_len
        self.clock = clock
        # resolve the serving policy up front: a bad policy name or a
        # missing/invalid plan file fails at engine construction, not on
        # the first decode (plan: refs load repro.autotune artifacts)
        self.policy = policy_mod.get_policy(cfg.precision_policy)
        # cheap decode_block validation FIRST: a misconfigured fast
        # path must not pay the calibration forwards below before
        # failing
        self.decode_block = self.config.decode_block
        if self.decode_block > 1 and not registry.block_decode_eligible(cfg):
            raise ValueError(
                f"family {cfg.family!r} is not eligible for blocked decode")
        # prepared-weight datapath: quantize/pack the replica's weights
        # ONCE at construction (quant.prepare) so decode never
        # re-quantizes static weights per token and int4 replicas hold
        # packed nibbles instead of fp32; calibrated static activation
        # scales ride on the prepared containers the same way
        self.prepared = bool(self.config.prepare_weights) \
            and api.prepare is not None
        self.act_scales = self._resolve_act_scales(
            self.config.act_calibration, params)
        self.params = api.prepare(params, self.policy,
                                  act_scales=self.act_scales) \
            if self.prepared else params
        # fused Pallas executors (kernels.fused): 'on'/'off' explicit,
        # 'auto' exactly when the operands the fused kernels consume
        # exist — prepared storage plus calibrated static activation
        # scales for int routes (fp8/fp4 routes need no act scale)
        self.fused = self._resolve_fused(params)
        self._variant = "fused" if self.fused else None
        self.caches = api.init_cache(self.b, self.cache_len)
        self.pos = np.zeros(self.b, np.int32)
        self.slot_req: List[Optional[Request]] = [None] * self.b
        self.scheduler = scheduler if scheduler is not None \
            else AdmissionScheduler()
        self.completed: Dict[int, Request] = {}
        prefill = self.config.prefill
        if prefill == "batched" and cfg.family not in _FAST_PREFILL_FAMILIES:
            raise ValueError(
                f"batched prefill needs a position-tagged token-only "
                f"prefill; family {cfg.family!r} is not eligible")
        self._fast_prefill = (cfg.family in _FAST_PREFILL_FAMILIES
                              if prefill == "auto" else prefill == "batched")
        if self.decode_block > 1:
            # dynamic fake-quant calibrates ONE absmax over the whole
            # (slots, 1, d) activation tensor, coupling batch rows — a
            # blocked engine's pad cadence would then leak into other
            # slots' tokens (measured). Exact int kernels quantize
            # per row and calibrated scales are elementwise, so both
            # stay per-slot independent.
            uncovered = self._dynamic_fake_int_paths(params)
            if uncovered:
                raise ValueError(
                    "decode_block > 1 needs per-slot-independent "
                    "decode, but dynamically-scaled fake-quant "
                    "projections couple batch rows through their "
                    "shared per-tensor activation absmax "
                    f"({sorted(uncovered)[:3]}...); calibrate static "
                    "activation scales (act_calibration='auto' or a "
                    "quant.calibrate dict) or serve exact int kernels")
        # observability: typed metrics behind a dict-compatible view
        # (metrics()["counters"] schema unchanged), a span tracer on the
        # engine clock (free when config.trace is off) and the measured
        # per-replica stats the router's online cost correction reads
        self.registry = MetricsRegistry()
        for k in ("ticks", "decode_steps", "host_syncs",
                  "prefill_calls", "prefill_tokens",
                  "teacher_forced_tokens", "admitted", "submitted",
                  "short_blocks", "mid_block_admits", "eos_stops"):
            self.registry.counter(k)
        self.counters = self.registry.counters_view()
        self.tracer = Tracer(clock=self.clock, enabled=self.config.trace)
        self.stats = ReplicaStats(alpha=self.config.stats_alpha,
                                  window=self.config.stats_window)
        w = self.config.stats_window
        self._g_tok = self.registry.rolling("tok_per_tick", w)
        self._g_queue = self.registry.rolling("queue_depth", w)
        self._g_occ = self.registry.rolling("batch_occupancy", w)
        self._g_short = self.registry.rolling("short_block", w)
        self._decode = traced_jit(
            jax.jit(_with_variant(
                lambda p, tok, pos, c: api.decode_step(
                    p, {"token": tok, "pos": pos}, c),
                self._variant)),
            "decode_step", self.tracer)
        # per-slot sampling state mirrored on host, scattered into the
        # decode programs per dispatch (rows reset when slots free)
        self._temp = np.zeros(self.b, np.float32)
        self._topk = np.zeros(self.b, np.int32)
        self._topp = np.ones(self.b, np.float32)
        self._stops = np.full((self.b, MAX_STOP_IDS), -1, np.int32)
        self._keys = np.zeros((self.b, 2), np.uint32)
        self._stop_sets: List[frozenset] = [frozenset()] * self.b
        from repro.models.sampling import sample_tokens
        self._select = traced_jit(jax.jit(sample_tokens), "select",
                                  self.tracer)
        # effective prefill chunk: bounded by the smallest cache ring so
        # a chunk's positions occupy distinct slots within each row
        # (SWA groups cap at their window)
        self.prefill_chunk = self.config.prefill_chunk
        if self._fast_prefill:
            caps = [c.pos.shape[-1]
                    for c in jax.tree.leaves(
                        self.caches, is_leaf=lambda x: hasattr(x, "pos"))]
            self.prefill_chunk = max(
                min(self.prefill_chunk, min(caps), self.cache_len), 1)
            self._prefill_chunk_fn = traced_jit(
                jax.jit(_with_variant(
                    lambda p, tokens, offs, lens, c: api.prefill_chunk(
                        p, {"tokens": tokens, "offsets": offs,
                            "lengths": lens}, c),
                    self._variant)),
                "prefill_chunk", self.tracer)
        # blocked-decode programs, one jit cache entry per (block
        # length, sample?) pair — at most 2 * decode_block compiles
        self._block_fns: Dict[Tuple[int, bool], Callable] = {}
        self._last_block_short = False
        # params are immutable after preparation: walk the tree for the
        # resident-bytes report once, not on every metrics() call
        from repro.quant.prepare import weight_resident_bytes
        self._weight_bytes = weight_resident_bytes(
            self.params, registry.projection_paths(self.cfg))

    def _resolve_act_scales(self, act_calibration, params):
        """None | mapping | 'auto' -> {policy path: static scale}.

        'auto' prefers scales embedded in a ``plan:`` artifact (the
        searched plan carries its calibration — which assumes the plan
        was calibrated against the same seeded-init checkpoint this
        replica serves) and otherwise runs a short random-token
        calibration pass over the raw params."""
        if act_calibration is None:
            return None
        if not self.prepared:
            # refusing beats silently measuring the dynamic path: the
            # scales only take effect through prepared containers
            raise ValueError("act_calibration requires prepared weights "
                             "(prepare_weights=True)")
        if isinstance(act_calibration, dict):
            return dict(act_calibration)
        if act_calibration != "auto":
            raise ValueError(
                f"act_calibration must be None, a dict or 'auto', got "
                f"{act_calibration!r}")
        if not self._routes_int(params):
            # nothing would consume the scales (e.g. a pure-bf16
            # policy): skip the pass and keep act_calibrated honest
            return None
        pol = self.cfg.precision_policy
        if pol.startswith("plan:"):
            from repro.autotune.plan import load_act_scales
            scales = load_act_scales(pol[len("plan:"):])
            if scales:
                return scales
        from repro.quant.calibrate import calibrate_act_scales
        return calibrate_act_scales(self.cfg, self.api, params)

    def _resolve_fused(self, params) -> bool:
        mode = self.config.fused_executors
        if mode == "off":
            return False
        if mode == "on":
            if not self.prepared:
                raise ValueError(
                    "fused_executors='on' requires prepared weights "
                    "(the fused kernels consume prepared storage)")
            return True
        return self.prepared and (self.act_scales is not None
                                  or self._routes_fp(params))

    def _routes_fp(self, params) -> bool:
        """Does the policy route any projection to an fp storage mode
        (fp8/fp4)? Those fuse without calibrated activation scales."""
        from repro.quant.prepare import iter_projection_weights
        paths = registry.projection_paths(self.cfg)
        return any(
            self.policy.spec_for(paths(prefix)).mode in ("fp8", "fp4")
            for prefix, _ in iter_projection_weights(params, paths))

    def _routes_int(self, params) -> bool:
        """Does the policy route any projection of this param tree to an
        int mode? (Pure tree walk + spec resolution; no compute.)"""
        from repro.quant.prepare import iter_projection_weights
        paths = registry.projection_paths(self.cfg)
        return any(
            self.policy.spec_for(paths(prefix)).weight_bits
            for prefix, _ in iter_projection_weights(params, paths))

    def _dynamic_fake_int_paths(self, params) -> set:
        """Policy paths routed to fake-quant int modes whose activation
        scale stays dynamic (no calibrated scale covers them) — the
        projections whose per-tensor absmax couples batch rows. MoE
        expert stacks are exempt: ``moe.forward`` fake-quants weights
        only (activations ride the bf16 einsums untouched), so there is
        no row coupling — and no mp_linear call for calibration to ever
        cover."""
        from repro.quant.prepare import iter_projection_weights
        paths = registry.projection_paths(self.cfg)
        scales = self.act_scales or {}
        out = set()
        for prefix, _ in iter_projection_weights(params, paths):
            pol_path = paths(prefix)
            if pol_path == "block/moe/experts":
                continue
            spec = self.policy.spec_for(pol_path)
            if (spec.weight_bits and not spec.exact
                    and pol_path not in scales):
                out.add(pol_path)
        return out

    # ------------------------------------------------------- observability

    def _trace_decode(self, hook):
        """Trace ONE decode step abstractly (``jax.eval_shape`` — no
        compute runs, the KV caches are untouched) under a capture
        context manager and return whatever the context yielded. The
        shared scaffolding of every trace-time assertion surface:
        routing, weight-quant and act-quant counters.

        Traces the program the engine actually dispatches: the plain
        ``decode_step`` at ``decode_block=1``, or the blocked scan
        program — staging walk included — on the fast path, so the
        counter contracts keep covering what really runs (a staging
        regression that dropped scales or storage would fire here)."""
        with hook() as captured:
            if self.decode_block > 1:
                fn = registry.make_block_decode(self.api, 1,
                                                policy=self.policy,
                                                fused=self.fused)
                zeros = jnp.zeros((self.b,), jnp.int32)
                carry = registry.DecodeCarry(
                    tok=zeros, pos=zeros,
                    rem=jnp.ones((self.b,), jnp.int32),
                    taken=zeros,
                    stops=jnp.full((self.b, MAX_STOP_IDS), -1, jnp.int32),
                    temp=jnp.zeros((self.b,), jnp.float32),
                    top_k=zeros,
                    top_p=jnp.ones((self.b,), jnp.float32),
                    keys=jnp.zeros((self.b, 2), jnp.uint32))
                jax.eval_shape(lambda p, c: fn(p, carry, c),
                               self.params, self.caches)
            else:
                tok = jnp.zeros((self.b, 1), jnp.int32)
                pos = jnp.zeros((self.b,), jnp.int32)
                jax.eval_shape(
                    _with_variant(
                        lambda p, c: self.api.decode_step(
                            p, {"token": tok, "pos": pos}, c),
                        self._variant),
                    self.params, self.caches)
        return captured

    def routing_report(self) -> Dict[str, str]:
        """Observed (parameter path -> datapath mode) of one decode step
        under the active policy — the verification surface the
        plan-routing assertion tests use."""
        return dict(self._trace_decode(policy_mod.trace_routing))

    def weight_bytes(self) -> Dict:
        """Weight memory resident in this replica's param tree: total
        bytes, the policy-routed projection subset, and a per-storage-
        kind breakdown ('raw' = unprepared fp32/bf16). Computed once at
        construction — params are immutable after preparation."""
        return self._weight_bytes

    def weight_quant_trace_count(self) -> int:
        """Dynamic weight quantizations traced into ONE decode step —
        the counter hook the serving-smoke contract asserts is zero for
        prepared replicas."""
        from repro.layers import mplinear
        return self._trace_decode(mplinear.count_weight_quant)[0]

    def act_quant_trace_count(self) -> int:
        """Dynamic activation-scale calibrations (per-token absmax
        reduces) traced into ONE decode step — zero for calibrated
        replicas (static scales), > 0 for any dynamically-scaled int
        projection."""
        from repro.layers import mplinear
        return self._trace_decode(mplinear.count_act_quant)[0]

    def staged_trace_count(self) -> int:
        """Staged compute-dtype operand materializations traced into ONE
        decode dispatch (the ``quant.prepare.count_staged`` hook through
        the same program the engine runs). Zero on the fused datapath —
        prepared storage enters the kernels directly — and > 0 for any
        staged-path blocked engine with fake-quant int/fp projections."""
        from repro.quant import prepare
        return self._trace_decode(prepare.count_staged)[0]

    def metrics(self) -> Dict:
        """Aggregate request latency metrics + engine counters (the
        ``counters`` block keeps the pre-registry plain-dict schema),
        plus the rolling tick gauges and the measured replica stats the
        router's online cost correction reads."""
        from repro.serving.metrics import summarize_requests
        m = summarize_requests(self.completed.values())
        m["counters"] = dict(self.counters)
        m["queue"] = len(self.scheduler)
        m["queue_highwater"] = self.scheduler.depth_highwater
        m["active_slots"] = sum(r is not None for r in self.slot_req)
        m["prepared_weights"] = self.prepared
        m["act_calibrated"] = self.act_scales is not None
        m["fused_executors"] = self.fused
        m["decode_block"] = self.decode_block
        m["mid_block_admission"] = self.config.mid_block_admission
        m["eos_stopping"] = self.config.eos_stopping
        m["weight_bytes"] = self.weight_bytes()
        m["gauges"] = self.registry.snapshot()["rolling"]
        m["replica_stats"] = self.stats.snapshot()
        m["trace"] = {"enabled": self.tracer.enabled,
                      "events": len(self.tracer.events),
                      "dropped": self.tracer.dropped}
        return m

    def dump_trace(self, path: str) -> str:
        """Export the recorded spans as Chrome trace-event JSON (load
        at https://ui.perfetto.dev or ``chrome://tracing``); requires
        ``EngineConfig(trace=True)``."""
        if not self.tracer.enabled:
            raise RuntimeError(
                "tracing is off — construct the engine with "
                "EngineConfig(trace=True)")
        return self.tracer.dump(path)

    def has_pending(self) -> bool:
        return (len(self.scheduler) > 0
                or any(r is not None for r in self.slot_req))

    # ------------------------------------------------------------ admission

    def _capacity_needed(self, req: Request) -> int:
        """Cache positions the request will write: prompt prefill at
        0..S-2, decode at S-1..S-2+budget. Beyond ``cache_len`` the ring
        write (pos % capacity) overwrites early context — the request
        still serves, with trailing-window semantics, and is stamped
        ``truncated`` at admission."""
        if req.budget <= 0:
            return 0
        return max(len(req.prompt) - 1, 0) + req.budget

    def submit(self, req: Request):
        if not isinstance(req.sampling, SamplingParams):
            raise TypeError(
                f"req{req.rid}.sampling must be a SamplingParams, got "
                f"{type(req.sampling).__name__}")
        if len(self._merged_stops(req)) > MAX_STOP_IDS:
            raise ValueError(
                f"req{req.rid}: stop_ids + engine eos_id exceed the "
                f"{MAX_STOP_IDS} per-slot stop slots")
        self.scheduler.submit(req, now=self.clock())
        self.counters["submitted"] += 1
        self.tracer.req_begin(req.rid, "queued",
                              args={"prompt_len": len(req.prompt),
                                    "budget": req.budget})

    def _merged_stops(self, req: Request) -> Tuple[int, ...]:
        stops = list(req.sampling.stop_ids)
        if self.config.eos_id is not None \
                and self.config.eos_id not in stops:
            stops.append(self.config.eos_id)
        return tuple(stops)

    def _install_sampling(self, slot: int, req: Request):
        sp = req.sampling
        self._temp[slot] = sp.temperature
        self._topk[slot] = sp.top_k
        self._topp[slot] = sp.top_p
        stops = self._merged_stops(req) if self.config.eos_stopping \
            else ()
        self._stops[slot] = -1
        self._stops[slot, :len(stops)] = stops
        self._stop_sets[slot] = frozenset(stops)
        # per-request key derivation: explicit seed, else engine seed
        # folded with the rid — placement- and block-size-independent
        if sp.seed is not None:
            key = jax.random.PRNGKey(sp.seed)
        else:
            key = jax.random.fold_in(
                jax.random.PRNGKey(self.config.seed),
                req.rid & 0xFFFFFFFF)   # fold_in wants uint32-range data
        self._keys[slot] = np.asarray(key, np.uint32)

    def _clear_sampling(self, slot: int):
        self._temp[slot] = 0.0
        self._topk[slot] = 0
        self._topp[slot] = 1.0
        self._stops[slot] = -1
        self._keys[slot] = 0
        self._stop_sets[slot] = frozenset()

    def _admit(self):
        free = [s for s in range(self.b) if self.slot_req[s] is None]
        if not free:
            return
        now = self.clock()
        teacher: List[Tuple[int, Request]] = []
        for req in self.scheduler.select(len(free), now):
            req.admit_time = now
            req.tokens = [int(t) for t in req.prompt]
            self.counters["admitted"] += 1
            self.tracer.req_end(req.rid, "queued")
            if req.budget <= 0 or len(req.prompt) == 0:
                # nothing to generate: complete without holding a slot
                req.done = True
                req.finish_reason = "length"
                req.finish_time = now
                self.completed[req.rid] = req
                self.tracer.req_instant(req.rid, "finished",
                                        args={"reason": "length"})
                continue
            if self._capacity_needed(req) > self.cache_len:
                # chunked prefill lifted the old admission bound: the
                # request serves with trailing-window (ring) context
                req.truncated = True
            slot = free.pop(0)
            self.slot_req[slot] = req
            self._install_sampling(slot, req)
            if self._last_block_short:
                self.counters["mid_block_admits"] += 1
            req.prefill_pos = 0
            self.tracer.req_begin(req.rid, "prefill",
                                  args={"slot": slot})
            if len(req.prompt) == 1:
                self.pos[slot] = 0
                req.next_input = int(req.prompt[0])
                self._req_decode_start(req)
            elif self._fast_prefill:
                # chunked continuation: the slot enters the prefilling
                # state (next_input None) and advances one wave per
                # tick in _prefill_tick; pos tracks the frontier so the
                # idle decode write it receives meanwhile lands on a
                # position the next chunk overwrites
                self.pos[slot] = 0
                req.next_input = None
            else:
                # teacher-forced fallback (recurrent-state families)
                self.pos[slot] = 0
                req.next_input = int(req.prompt[-1])
                teacher.append((slot, req))
        for slot, req in teacher:
            for t in req.prompt[:-1]:
                self._step_slot_token(slot, int(t))
            req.prefill_pos = len(req.prompt) - 1
            self.counters["teacher_forced_tokens"] += len(req.prompt) - 1
            self._req_decode_start(req)

    def _req_decode_start(self, req: Request):
        """Request lifecycle transition: prompt fully consumed, the slot
        is decodable from the next tick on."""
        if self.tracer.enabled:
            self.tracer.req_end(req.rid, "prefill")
            self.tracer.req_begin(req.rid, "decode")

    def _prefill_tick(self) -> bool:
        """Advance every prefilling slot by one chunk in ONE fixed-shape
        jitted dispatch; slots whose prompt completes become decodable
        this tick."""
        pref = [(s, r) for s, r in enumerate(self.slot_req)
                if r is not None and r.next_input is None]
        if not pref:
            return False
        chunk = self.prefill_chunk
        tokens = np.zeros((self.b, chunk), np.int32)
        offs = np.zeros(self.b, np.int32)
        lens = np.zeros(self.b, np.int32)
        total = 0
        for s, req in pref:
            todo = len(req.prompt) - 1 - req.prefill_pos
            take = min(chunk, todo)
            tokens[s, :take] = np.asarray(
                req.prompt[req.prefill_pos:req.prefill_pos + take],
                np.int32)
            offs[s] = req.prefill_pos
            lens[s] = take
            total += take
        with self.tracer.span("prefill_dispatch",
                              args={"tokens": total,
                                    "slots": len(pref)}):
            self.caches = self._prefill_chunk_fn(
                self.params, jnp.array(tokens), jnp.array(offs),
                jnp.array(lens), self.caches)
        self.counters["prefill_calls"] += 1
        self.counters["prefill_tokens"] += total
        for s, req in pref:
            req.prefill_pos += int(lens[s])
            if req.prefill_pos >= len(req.prompt) - 1:
                self.pos[s] = len(req.prompt) - 1
                req.next_input = int(req.prompt[-1])
                self._req_decode_start(req)
            else:
                self.pos[s] = req.prefill_pos
        return True

    def _step_slot_token(self, slot: int, token: int) -> int:
        """Teacher-forced fallback: feed one prompt token through decode
        (recurrent-state families, where padded prefill is unsound)."""
        tok = np.zeros((self.b, 1), np.int32)
        tok[slot, 0] = token
        # jnp.array (never asarray): jax may alias an aligned numpy
        # buffer zero-copy, and self.pos mutates while the async decode
        # is still in flight — observed as corrupted cache position tags
        logits, self.caches = self._decode(
            self.params, jnp.array(tok), jnp.array(self.pos), self.caches)
        self.pos[slot] += 1
        self.counters["host_syncs"] += 1
        return int(np.asarray(jnp.argmax(logits[slot])))

    # --------------------------------------------------------- decode loop

    def _block_decode(self, n: int, sample: bool) -> Callable:
        fn = self._block_fns.get((n, sample))
        if fn is None:
            # pass the eagerly-resolved policy: a plan: file deleted
            # after construction must not fail the first dispatch
            kind = "sample" if sample else "greedy"
            fn = traced_jit(
                jax.jit(registry.make_block_decode(
                    self.api, n, policy=self.policy, sample=sample,
                    tracer=self.tracer, fused=self.fused)),
                f"block_decode[n={n},{kind}]", self.tracer)
            self._block_fns[(n, sample)] = fn
        return fn

    def _finish_slot(self, s: int, now: float, reason: str):
        req = self.slot_req[s]
        req.done = True
        req.finish_time = now
        req.finish_reason = reason
        if reason == "stop":
            self.counters["eos_stops"] += 1
        if self.tracer.enabled:
            self.tracer.req_end(req.rid, "decode")
            self.tracer.req_instant(
                req.rid, "finished",
                args={"reason": reason, "new_tokens": req.new_tokens})
        self.completed[req.rid] = req
        self.slot_req[s] = None
        self.pos[s] = 0
        self._clear_sampling(s)

    def _stop_hit(self, s: int, token: int) -> bool:
        return bool(self._stop_sets[s]) and token in self._stop_sets[s]

    def _choose_block(self, rem: np.ndarray) -> int:
        """Block length for this dispatch. Mid-block admission policy:
        while requests are queued, cut the block at the nearest
        completion (smallest positive budget) or the queue-depth-scaled
        boundary — ceil(decode_block / (1 + depth)) — whichever comes
        first, but never below HALF the configured block. The floor
        bounds the cost of the extra host syncs shorter blocks imply
        (on dispatch-overhead-dominated hosts unbounded cutting
        degrades both throughput and the TTFT it is meant to improve):
        queued work admits after at most ~half a block, for at most one
        extra sync per block."""
        alive = rem[rem > 0]
        full = int(min(self.decode_block, int(alive.max())))
        depth = len(self.scheduler)
        if self.config.mid_block_admission and depth > 0:
            cut = min(int(alive.min()),
                      -(-self.decode_block // (1 + depth)))
            return max(1, min(full, max(cut, self.decode_block // 2)))
        return max(full, 1)

    def _first_token(self, req: Request, now: float):
        req.first_token_time = now
        if req.submit_time is not None:
            self.stats.observe_ttft(now - req.submit_time)
        self.tracer.req_instant(req.rid, "first_token")

    def _sample_tick(self, new_tokens: int):
        """Per-tick measured stats: the ReplicaStats EWMA the router's
        online cost correction reads, plus the rolling gauges
        ``metrics()['gauges']`` reports."""
        now = self.clock()
        occupied = sum(r is not None for r in self.slot_req)
        depth = len(self.scheduler)
        self.stats.on_tick(now, new_tokens, depth,
                           active_slots=occupied)
        self._g_tok.observe(now, new_tokens)
        self._g_queue.observe(now, depth)
        self._g_occ.observe(now, occupied / self.b)
        if self.decode_block > 1:
            self._g_short.observe(
                now, 1.0 if self._last_block_short else 0.0)

    def step(self):
        """One engine tick: admit, advance prefilling slots one chunk,
        run one decode block (one host sync) for the decodable slots."""
        with self.tracer.span("admission"):
            self._admit()
        self.counters["ticks"] += 1
        prefilled = self._fast_prefill and self._prefill_tick()
        active = [s for s, r in enumerate(self.slot_req)
                  if r is not None and r.next_input is not None]
        if not active:
            self._sample_tick(0)
            return prefilled
        if self.decode_block > 1:
            return self._step_block(active)
        self._last_block_short = False
        tok = np.zeros((self.b, 1), np.int32)
        for s in active:
            tok[s, 0] = self.slot_req[s].next_input
        # copying jnp.array: self.pos mutates below while the dispatch
        # may still be reading it (see _step_slot_token)
        with self.tracer.span("block_dispatch", args={"n": 1}):
            logits, self.caches = self._decode(
                self.params, jnp.array(tok), jnp.array(self.pos),
                self.caches)
        self.counters["decode_steps"] += 1
        self.counters["host_syncs"] += 1
        with self.tracer.span("host_sync"):
            if any(self._temp[s] > 0 for s in active):
                keys2, nxt = self._select(
                    jnp.array(self._keys), logits, jnp.array(self._temp),
                    jnp.array(self._topk), jnp.array(self._topp))
                nxt = np.asarray(nxt)
                keys2 = np.asarray(keys2)
                for s in active:
                    self._keys[s] = keys2[s]
            else:
                nxt = np.asarray(jnp.argmax(logits, axis=-1))
        now = self.clock()
        with self.tracer.span("harvest"):
            for s in active:
                req = self.slot_req[s]
                self.pos[s] += 1
                if req.first_token_time is None:
                    self._first_token(req, now)
                t = int(nxt[s])
                req.tokens.append(t)
                req.next_input = t
                if self.config.eos_stopping and self._stop_hit(s, t):
                    self._finish_slot(s, now, "stop")
                elif req.new_tokens >= req.budget:
                    self._finish_slot(s, now, "length")
        self._sample_tick(len(active))
        return True

    def _step_block(self, active: List[int]) -> bool:
        """Fast path: run one decode block in ONE dispatch (jitted scan
        with on-device selection + active masks + stop ids) and sync
        the token trajectory once. Each slot's active prefix of the
        block comes back in ``carry.taken`` (EOS stopping means the
        host can no longer derive it from budgets alone)."""
        rem = np.zeros(self.b, np.int32)
        tok = np.zeros(self.b, np.int32)
        for s in active:
            req = self.slot_req[s]
            rem[s] = req.budget - req.new_tokens
            tok[s] = req.next_input
        n = self._choose_block(rem)
        full = int(min(self.decode_block, int(rem.max())))
        self._last_block_short = n < full
        if self._last_block_short:
            self.counters["short_blocks"] += 1
        sample = bool(any(self._temp[s] > 0 for s in active))
        carry = registry.DecodeCarry(
            tok=jnp.array(tok), pos=jnp.array(self.pos),
            rem=jnp.array(rem),
            taken=jnp.zeros(self.b, jnp.int32),
            stops=jnp.array(self._stops), temp=jnp.array(self._temp),
            top_k=jnp.array(self._topk), top_p=jnp.array(self._topp),
            keys=jnp.array(self._keys))
        with self.tracer.span("block_dispatch", args={"n": n}):
            tokens, out, self.caches = self._block_decode(n, sample)(
                self.params, carry, self.caches)
        with self.tracer.span("host_sync"):
            tokens = np.asarray(tokens)      # ONE host sync per block
            taken = np.asarray(out.taken)
            rem_after = np.asarray(out.rem)
            keys_after = np.asarray(out.keys)
        self.counters["decode_steps"] += n
        self.counters["host_syncs"] += 1
        now = self.clock()
        harvested = 0
        with self.tracer.span("harvest"):
            for s in active:
                req = self.slot_req[s]
                steps = int(taken[s])        # this slot's active prefix
                harvested += steps
                if req.first_token_time is None:
                    self._first_token(req, now)
                req.tokens.extend(int(t) for t in tokens[:steps, s])
                req.next_input = int(tokens[steps - 1, s])
                self.pos[s] += steps
                self._keys[s] = keys_after[s]
                if int(rem_after[s]) == 0:
                    last = int(tokens[steps - 1, s])
                    reason = "stop" if (self.config.eos_stopping
                                        and self._stop_hit(s, last)) \
                        else "length"
                    self._finish_slot(s, now, reason)
        self._sample_tick(harvested)
        return True

    def run_until_drained(self, max_ticks: int = 10_000):
        ticks = 0
        while self.has_pending():
            self.step()
            ticks += 1
            if ticks > max_ticks:
                raise RuntimeError("engine did not drain")
        return ticks
