"""Continuous-batching serving engine with a batched prefill path.

``make_serve_fns`` builds the sharded prefill/decode artifacts the
dry-run lowers for the prefill_32k / decode_32k / long_500k cells.
``ServingEngine`` is the single-replica runtime: fixed decode slots over
one shared KV cache, an :class:`repro.serving.scheduler.AdmissionScheduler`
in front, and admission through the model's real ``prefill`` program —
a prompt of length S costs one jitted prefill over a chunk-rounded
bucket (O(S/chunk) prefill work), not S ``decode_step`` calls.

Why bucket-padded prefill is safe here: the KV cache is position-tagged
(``layers.attention.KVCache.pos``) and attention masks by tag, so the
junk K/V a padded prefill writes past the prompt carries tags the causal
mask rejects until the decode loop overwrites them in place. That
invariant holds for attention caches but *not* for recurrent state
(rwkv/griffin fold every consumed token into O(1) state), so the fast
path is gated per family and everything else falls back to the
teacher-forced admission loop the engine always had.

Weights are PREPARED at construction (``quant.prepare`` via the model
family's ``api.prepare`` hook, default on): each replica stores its
projections in the policy's deployment format — packed int4 nibbles,
int8 + scales, fp16 casts — so decode never re-quantizes static weights
per token and per-replica weight-resident bytes reflect the policy
(``weight_bytes()`` / ``metrics()['weight_bytes']``). Preparation is
output-equivalent to dynamic quantization (tests/test_prepare.py);
``prepare_weights=False`` restores the dynamic path (benchmarked as the
baseline in benchmarks/serve_bench.py).

Activation scales can be CALIBRATED the same way (``act_calibration=``:
a {path: scale} dict, or ``"auto"`` to take them from the serving
plan's ``act_scales`` or run a short ``quant.calibrate`` pass at
construction): int executors then quantize activations against stored
static scales — zero per-token absmax reduces
(``act_quant_trace_count()``), and prefill/decode fake-quant numerics
become identical (a fixed rounding grid is elementwise), so batched and
teacher-forced admission agree exactly as they do under bf16. An
UNCALIBRATED int engine (the default) keeps the historical dynamic
behavior: the per-tensor absmax spans the whole prompt in prefill but
single tokens in decode, so its two admission paths agree only up to
that scale granularity, and the shared absmax couples batch rows.

Decode runs a FAST PATH when ``decode_block > 1``: a jitted
``lax.scan`` of ``decode_block`` ``decode_step`` calls with on-device
greedy selection (``models.registry.make_block_decode``), per-slot
active masks and remaining-token budgets carried in the scan state. The
host syncs generated tokens once per block instead of once per token
(the ``host_syncs`` counter); admission still runs between blocks.
``decode_block=1`` dispatches single steps exactly as before, and the
blocked path is token-for-token identical to it per request
(tests/test_serving.py::TestBlockedDecode) — which is also why it
requires per-slot-independent decode: eligible families only
(position-tagged caches), greedy selection, and no dynamically-scaled
fake-quant projections (their batch-row coupling is rejected at
construction; calibrate or use exact kernels).
"""
from __future__ import annotations

import dataclasses
import time
from typing import Callable, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh

from repro.configs.base import ModelConfig
from repro.core import policy as policy_mod
from repro.models import registry
from repro.parallel import sharding as shd

# families whose prefill consumes only tokens and whose caches are
# position-tagged (padding-safe): eligible for the batched prefill path
_FAST_PREFILL_FAMILIES = ("lm",)


def make_serve_fns(api: registry.ModelAPI, mesh: Mesh,
                   batch_shape: Dict, cache_len: int, batch_size: int):
    """Returns (jitted prefill, jitted decode, cache shardings)."""
    cache_shape = jax.eval_shape(lambda: api.init_cache(batch_size,
                                                        cache_len))
    cache_shard = shd.cache_shardings(cache_shape, mesh)
    param_shape = jax.eval_shape(api.init, jax.random.PRNGKey(0))
    param_shard = shd.param_shardings(param_shape, mesh)

    prefill_in = {k: v for k, v in batch_shape.items()
                  if k not in ("token", "pos")}
    pf_shard = shd.batch_shardings(prefill_in, mesh) if prefill_in else None

    prefill = jax.jit(
        lambda p, b, c: api.prefill(p, b, c),
        in_shardings=(param_shard, pf_shard, cache_shard),
        donate_argnums=(2,))

    # decode state sharding may differ from cache (encdec carries enc_out)
    def _decode(p, b, c):
        return api.decode_step(p, b, c)

    decode = jax.jit(_decode, in_shardings=(param_shard, None, None),
                     donate_argnums=(2,))
    return prefill, decode, cache_shard, param_shard


@dataclasses.dataclass
class Request:
    rid: int
    prompt: np.ndarray           # (S,) int32
    max_new_tokens: int = 16
    priority: int = 0            # lower admits first (see scheduler)
    tags: Tuple[str, ...] = ()   # e.g. ("accuracy",) for router SLOs
    tokens: Optional[List[int]] = None
    done: bool = False
    error: Optional[str] = None        # set when rejected at admission
    next_input: Optional[int] = None   # next token to feed decode
    # timestamps stamped by scheduler/engine (engine clock domain)
    submit_time: Optional[float] = None
    admit_time: Optional[float] = None
    first_token_time: Optional[float] = None
    finish_time: Optional[float] = None

    @property
    def new_tokens(self) -> int:
        return 0 if self.tokens is None else len(self.tokens) - len(self.prompt)


class ServingEngine:
    """Slot-based continuous batching with batched prefill admission.

    All slots share one decode program (fixed batch); free slots idle on
    pad tokens. Admission drains the scheduler into free slots and runs
    ONE jitted prefill over the whole wave: per-slot prompts are packed
    into a (slots, L) token matrix (L rounded up to ``prefill_chunk`` to
    bound recompiles), prefilled against a fresh cache, and the admitted
    rows are merged into the live cache at their slot positions.
    """

    def __init__(self, cfg: ModelConfig, api: registry.ModelAPI, params,
                 batch_slots: int = 4, cache_len: int = 512,
                 greedy: bool = True, prefill_chunk: int = 32,
                 prefill: str = "auto", scheduler=None,
                 prepare_weights: bool = True,
                 act_calibration=None, decode_block: int = 1,
                 clock: Callable[[], float] = time.monotonic):
        from repro.serving.scheduler import AdmissionScheduler
        self.cfg = cfg
        self.api = api
        self.b = batch_slots
        self.cache_len = cache_len
        self.greedy = greedy
        self.prefill_chunk = max(int(prefill_chunk), 1)
        self.clock = clock
        # resolve the serving policy up front: a bad policy name or a
        # missing/invalid plan file fails at engine construction, not on
        # the first decode (plan: refs load repro.autotune artifacts)
        self.policy = policy_mod.get_policy(cfg.precision_policy)
        # cheap decode_block validation FIRST: a misconfigured fast
        # path must not pay the calibration forwards below before
        # failing
        self.decode_block = max(int(decode_block), 1)
        if self.decode_block > 1 and not self.greedy:
            raise ValueError("decode_block > 1 selects tokens on device "
                             "(greedy argmax); needs greedy=True")
        if self.decode_block > 1 and not registry.block_decode_eligible(cfg):
            raise ValueError(
                f"family {cfg.family!r} is not eligible for blocked decode")
        # prepared-weight datapath: quantize/pack the replica's weights
        # ONCE at construction (quant.prepare) so decode never
        # re-quantizes static weights per token and int4 replicas hold
        # packed nibbles instead of fp32; calibrated static activation
        # scales ride on the prepared containers the same way
        self.prepared = bool(prepare_weights) and api.prepare is not None
        self.act_scales = self._resolve_act_scales(act_calibration, params)
        self.params = api.prepare(params, self.policy,
                                  act_scales=self.act_scales) \
            if self.prepared else params
        self.caches = api.init_cache(batch_slots, cache_len)
        self.pos = np.zeros(batch_slots, np.int32)
        self.slot_req: List[Optional[Request]] = [None] * batch_slots
        self.scheduler = scheduler if scheduler is not None \
            else AdmissionScheduler()
        self.completed: Dict[int, Request] = {}
        if prefill not in ("auto", "batched", "teacher"):
            raise ValueError(f"prefill mode {prefill!r}")
        if prefill == "batched" and cfg.family not in _FAST_PREFILL_FAMILIES:
            raise ValueError(
                f"batched prefill needs a position-tagged token-only "
                f"prefill; family {cfg.family!r} is not eligible")
        self._fast_prefill = (cfg.family in _FAST_PREFILL_FAMILIES
                              if prefill == "auto" else prefill == "batched")
        if self.decode_block > 1:
            # dynamic fake-quant calibrates ONE absmax over the whole
            # (slots, 1, d) activation tensor, coupling batch rows — a
            # blocked engine's pad cadence would then leak into other
            # slots' tokens (measured). Exact int kernels quantize
            # per row and calibrated scales are elementwise, so both
            # stay per-slot independent.
            uncovered = self._dynamic_fake_int_paths(params)
            if uncovered:
                raise ValueError(
                    "decode_block > 1 needs per-slot-independent "
                    "decode, but dynamically-scaled fake-quant "
                    "projections couple batch rows through their "
                    "shared per-tensor activation absmax "
                    f"({sorted(uncovered)[:3]}...); calibrate static "
                    "activation scales (act_calibration='auto' or a "
                    "quant.calibrate dict) or serve exact int kernels")
        self.counters = {"ticks": 0, "decode_steps": 0, "host_syncs": 0,
                         "prefill_calls": 0, "prefill_tokens": 0,
                         "teacher_forced_tokens": 0,
                         "admitted": 0, "submitted": 0}
        self._decode = jax.jit(
            lambda p, tok, pos, c: api.decode_step(
                p, {"token": tok, "pos": pos}, c))
        self._prefill_admit = jax.jit(self._prefill_admit_impl)
        # blocked-decode programs, one jit cache entry per block length
        # (lengths are min(decode_block, largest remaining budget), so
        # at most decode_block distinct compiles)
        self._block_fns: Dict[int, Callable] = {}
        # params are immutable after preparation: walk the tree for the
        # resident-bytes report once, not on every metrics() call
        from repro.quant.prepare import weight_resident_bytes
        self._weight_bytes = weight_resident_bytes(
            self.params, registry.projection_paths(self.cfg))

    def _resolve_act_scales(self, act_calibration, params):
        """None | mapping | 'auto' -> {policy path: static scale}.

        'auto' prefers scales embedded in a ``plan:`` artifact (the
        searched plan carries its calibration — which assumes the plan
        was calibrated against the same seeded-init checkpoint this
        replica serves) and otherwise runs a short random-token
        calibration pass over the raw params."""
        if act_calibration is None:
            return None
        if not self.prepared:
            # refusing beats silently measuring the dynamic path: the
            # scales only take effect through prepared containers
            raise ValueError("act_calibration requires prepared weights "
                             "(prepare_weights=True)")
        if isinstance(act_calibration, dict):
            return dict(act_calibration)
        if act_calibration != "auto":
            raise ValueError(
                f"act_calibration must be None, a dict or 'auto', got "
                f"{act_calibration!r}")
        if not self._routes_int(params):
            # nothing would consume the scales (e.g. a pure-bf16
            # policy): skip the pass and keep act_calibrated honest
            return None
        pol = self.cfg.precision_policy
        if pol.startswith("plan:"):
            from repro.autotune.plan import load_act_scales
            scales = load_act_scales(pol[len("plan:"):])
            if scales:
                return scales
        from repro.quant.calibrate import calibrate_act_scales
        return calibrate_act_scales(self.cfg, self.api, params)

    def _routes_int(self, params) -> bool:
        """Does the policy route any projection of this param tree to an
        int mode? (Pure tree walk + spec resolution; no compute.)"""
        from repro.quant.prepare import iter_projection_weights
        paths = registry.projection_paths(self.cfg)
        return any(
            self.policy.spec_for(paths(prefix)).weight_bits
            for prefix, _ in iter_projection_weights(params, paths))

    def _dynamic_fake_int_paths(self, params) -> set:
        """Policy paths routed to fake-quant int modes whose activation
        scale stays dynamic (no calibrated scale covers them) — the
        projections whose per-tensor absmax couples batch rows. MoE
        expert stacks are exempt: ``moe.forward`` fake-quants weights
        only (activations ride the bf16 einsums untouched), so there is
        no row coupling — and no mp_linear call for calibration to ever
        cover."""
        from repro.quant.prepare import iter_projection_weights
        paths = registry.projection_paths(self.cfg)
        scales = self.act_scales or {}
        out = set()
        for prefix, _ in iter_projection_weights(params, paths):
            pol_path = paths(prefix)
            if pol_path == "block/moe/experts":
                continue
            spec = self.policy.spec_for(pol_path)
            if (spec.weight_bits and not spec.exact
                    and pol_path not in scales):
                out.add(pol_path)
        return out

    # ------------------------------------------------------- observability

    def _trace_decode(self, hook):
        """Trace ONE decode step abstractly (``jax.eval_shape`` — no
        compute runs, the KV caches are untouched) under a capture
        context manager and return whatever the context yielded. The
        shared scaffolding of every trace-time assertion surface:
        routing, weight-quant and act-quant counters.

        Traces the program the engine actually dispatches: the plain
        ``decode_step`` at ``decode_block=1``, or the blocked scan
        program — staging walk included — on the fast path, so the
        counter contracts keep covering what really runs (a staging
        regression that dropped scales or storage would fire here)."""
        with hook() as captured:
            if self.decode_block > 1:
                fn = registry.make_block_decode(self.api, 1,
                                                policy=self.policy)
                zeros = jnp.zeros((self.b,), jnp.int32)
                jax.eval_shape(
                    lambda p, c: fn(p, zeros, zeros,
                                    jnp.ones((self.b,), jnp.int32), c),
                    self.params, self.caches)
            else:
                tok = jnp.zeros((self.b, 1), jnp.int32)
                pos = jnp.zeros((self.b,), jnp.int32)
                jax.eval_shape(
                    lambda p, c: self.api.decode_step(
                        p, {"token": tok, "pos": pos}, c),
                    self.params, self.caches)
        return captured

    def routing_report(self) -> Dict[str, str]:
        """Observed (parameter path -> datapath mode) of one decode step
        under the active policy — the verification surface the
        plan-routing assertion tests use."""
        return dict(self._trace_decode(policy_mod.trace_routing))

    def weight_bytes(self) -> Dict:
        """Weight memory resident in this replica's param tree: total
        bytes, the policy-routed projection subset, and a per-storage-
        kind breakdown ('raw' = unprepared fp32/bf16). Computed once at
        construction — params are immutable after preparation."""
        return self._weight_bytes

    def weight_quant_trace_count(self) -> int:
        """Dynamic weight quantizations traced into ONE decode step —
        the counter hook the serving-smoke contract asserts is zero for
        prepared replicas."""
        from repro.layers import mplinear
        return self._trace_decode(mplinear.count_weight_quant)[0]

    def act_quant_trace_count(self) -> int:
        """Dynamic activation-scale calibrations (per-token absmax
        reduces) traced into ONE decode step — zero for calibrated
        replicas (static scales), > 0 for any dynamically-scaled int
        projection."""
        from repro.layers import mplinear
        return self._trace_decode(mplinear.count_act_quant)[0]

    def metrics(self) -> Dict:
        """Aggregate request latency metrics + engine counters."""
        from repro.serving.metrics import summarize_requests
        m = summarize_requests(self.completed.values())
        m["counters"] = dict(self.counters)
        m["queue"] = len(self.scheduler)
        m["active_slots"] = sum(r is not None for r in self.slot_req)
        m["prepared_weights"] = self.prepared
        m["act_calibrated"] = self.act_scales is not None
        m["decode_block"] = self.decode_block
        m["weight_bytes"] = self.weight_bytes()
        return m

    def has_pending(self) -> bool:
        return (len(self.scheduler) > 0
                or any(r is not None for r in self.slot_req))

    # ------------------------------------------------------------ admission

    def _capacity_needed(self, req: Request) -> int:
        """Cache positions the request will write: prompt prefill at
        0..S-2, decode at S-1..S-2+max_new. Beyond cache_len the ring
        write (pos % capacity) silently overwrites early context on
        full-attention models, so oversized requests are rejected."""
        if req.max_new_tokens <= 0:
            return 0
        return max(len(req.prompt) - 1, 0) + req.max_new_tokens

    def submit(self, req: Request):
        if self._capacity_needed(req) > self.cache_len:
            raise ValueError(
                f"req{req.rid}: prompt of {len(req.prompt)} tokens + "
                f"{req.max_new_tokens} new tokens needs "
                f"{self._capacity_needed(req)} cache positions, but "
                f"cache_len={self.cache_len}")
        self.scheduler.submit(req, now=self.clock())
        self.counters["submitted"] += 1

    def _prefill_admit_impl(self, params, tokens, admit_mask, caches):
        """One admission wave: prefill the packed (slots, L) prompts into
        a fresh cache, then merge admitted rows into the live cache."""
        fresh = self.api.init_cache(self.b, self.cache_len)
        _, fresh = self.api.prefill(params, {"tokens": tokens}, fresh)

        def merge(old, new):
            # every cache leaf is (n_groups, slots, ...): batch axis 1
            m = admit_mask.reshape((1, self.b) + (1,) * (old.ndim - 2))
            return jnp.where(m, new.astype(old.dtype), old)

        return jax.tree.map(merge, caches, fresh)

    def _admit(self):
        free = [s for s in range(self.b) if self.slot_req[s] is None]
        if not free:
            return
        now = self.clock()
        wave: List[Tuple[int, Request]] = []
        for req in self.scheduler.select(len(free), now):
            req.admit_time = now
            req.tokens = [int(t) for t in req.prompt]
            self.counters["admitted"] += 1
            if req.max_new_tokens <= 0 or len(req.prompt) == 0:
                # nothing to generate: complete without holding a slot
                req.done = True
                req.finish_time = now
                self.completed[req.rid] = req
                continue
            if self._capacity_needed(req) > self.cache_len:
                # submit() rejects these; a request injected straight
                # into the scheduler fails terminally instead of
                # killing the whole admission wave (and, via the
                # router, every other replica's traffic)
                req.done = True
                req.error = (f"needs {self._capacity_needed(req)} cache "
                             f"positions > cache_len={self.cache_len}")
                req.finish_time = now
                self.completed[req.rid] = req
                continue
            slot = free.pop(0)
            self.slot_req[slot] = req
            self.pos[slot] = len(req.prompt) - 1
            req.next_input = int(req.prompt[-1])
            if len(req.prompt) > 1:
                wave.append((slot, req))
        if not wave:
            return
        if self._fast_prefill:
            self._prefill_wave(wave)
        else:
            for slot, req in wave:
                self.pos[slot] = 0
                for t in req.prompt[:-1]:
                    self._step_slot_token(slot, int(t))
                self.counters["teacher_forced_tokens"] += \
                    len(req.prompt) - 1

    def _prefill_wave(self, wave: List[Tuple[int, Request]]):
        lmax = max(len(req.prompt) - 1 for _, req in wave)
        chunk = self.prefill_chunk
        L = min(max(-(-lmax // chunk) * chunk, 1), self.cache_len)
        tokens = np.zeros((self.b, L), np.int32)
        mask = np.zeros((self.b,), bool)
        for slot, req in wave:
            t = np.asarray(req.prompt[:-1], np.int32)
            tokens[slot, :t.size] = t
            mask[slot] = True
        self.caches = self._prefill_admit(
            self.params, jnp.array(tokens), jnp.array(mask),
            self.caches)
        self.counters["prefill_calls"] += 1
        self.counters["prefill_tokens"] += int(
            sum(len(req.prompt) - 1 for _, req in wave))

    def _step_slot_token(self, slot: int, token: int) -> int:
        """Teacher-forced fallback: feed one prompt token through decode
        (recurrent-state families, where padded prefill is unsound)."""
        tok = np.zeros((self.b, 1), np.int32)
        tok[slot, 0] = token
        # jnp.array (never asarray): jax may alias an aligned numpy
        # buffer zero-copy, and self.pos mutates while the async decode
        # is still in flight — observed as corrupted cache position tags
        logits, self.caches = self._decode(
            self.params, jnp.array(tok), jnp.array(self.pos), self.caches)
        self.pos[slot] += 1
        self.counters["host_syncs"] += 1
        return int(np.asarray(jnp.argmax(logits[slot])))

    # --------------------------------------------------------- decode loop

    def _block_decode(self, n: int) -> Callable:
        fn = self._block_fns.get(n)
        if fn is None:
            # pass the eagerly-resolved policy: a plan: file deleted
            # after construction must not fail the first dispatch
            fn = jax.jit(registry.make_block_decode(self.api, n,
                                                    policy=self.policy))
            self._block_fns[n] = fn
        return fn

    def _finish_slot(self, s: int, now: float):
        req = self.slot_req[s]
        req.done = True
        req.finish_time = now
        self.completed[req.rid] = req
        self.slot_req[s] = None
        self.pos[s] = 0

    def step(self):
        """One engine tick: admit + one decode block (``decode_block``
        tokens, one host sync) for every active slot."""
        self._admit()
        self.counters["ticks"] += 1
        active = [s for s in range(self.b) if self.slot_req[s] is not None]
        if not active:
            return False
        if self.decode_block > 1:
            return self._step_block(active)
        tok = np.zeros((self.b, 1), np.int32)
        for s in active:
            tok[s, 0] = self.slot_req[s].next_input
        # copying jnp.array: self.pos mutates below while the dispatch
        # may still be reading it (see _step_slot_token)
        logits, self.caches = self._decode(
            self.params, jnp.array(tok), jnp.array(self.pos),
            self.caches)
        self.counters["decode_steps"] += 1
        self.counters["host_syncs"] += 1
        nxt = np.asarray(jnp.argmax(logits, axis=-1))
        now = self.clock()
        for s in active:
            req = self.slot_req[s]
            self.pos[s] += 1
            if req.first_token_time is None:
                req.first_token_time = now
            req.tokens.append(int(nxt[s]))
            req.next_input = int(nxt[s])
            if req.new_tokens >= req.max_new_tokens:
                self._finish_slot(s, now)
        return True

    def _step_block(self, active: List[int]) -> bool:
        """Fast path: run min(decode_block, largest remaining budget)
        decode steps in ONE dispatch (jitted scan with on-device argmax
        + active masks) and sync the token trajectory once. Slot budgets
        are host-known, so each slot's active prefix of the block is
        replayed host-side without a second sync."""
        rem = np.zeros(self.b, np.int32)
        tok = np.zeros(self.b, np.int32)
        for s in active:
            req = self.slot_req[s]
            rem[s] = req.max_new_tokens - req.new_tokens
            tok[s] = req.next_input
        n = int(min(self.decode_block, int(rem.max())))
        tokens, _, _, _, self.caches = self._block_decode(n)(
            self.params, jnp.array(tok), jnp.array(self.pos),
            jnp.array(rem), self.caches)
        tokens = np.asarray(tokens)          # ONE host sync per block
        self.counters["decode_steps"] += n
        self.counters["host_syncs"] += 1
        now = self.clock()
        for s in active:
            req = self.slot_req[s]
            steps = int(min(rem[s], n))      # this slot's active prefix
            if req.first_token_time is None:
                req.first_token_time = now
            req.tokens.extend(int(t) for t in tokens[:steps, s])
            req.next_input = int(tokens[steps - 1, s])
            self.pos[s] += steps
            if req.new_tokens >= req.max_new_tokens:
                self._finish_slot(s, now)
        return True

    def run_until_drained(self, max_ticks: int = 10_000):
        ticks = 0
        while self.has_pending():
            self.step()
            ticks += 1
            if ticks > max_ticks:
                raise RuntimeError("engine did not drain")
        return ticks
