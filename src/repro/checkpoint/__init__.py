from repro.checkpoint.checkpoint import (  # noqa: F401
    CheckpointError,
    CheckpointManager,
    CheckpointNotFound,
    ChecksumError,
    latest_step,
    list_steps,
    restore_checkpoint,
    save_checkpoint,
)
