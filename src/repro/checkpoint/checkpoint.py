"""Fault-tolerant checkpointing: atomic writes, content manifest,
keep-last-k GC, restore-latest, and cross-topology resharding.

Layout:
    <dir>/step_000123/
        manifest.msgpack   (treedef, shapes, dtypes, metadata, checksums)
        arrays.npz         (leaf i -> 'a<i>')
    <dir>/step_000123.tmp...   (staging; atomic rename on completion)

Resharding: leaves are restored host-side (numpy) and device_put with
whatever shardings the *current* mesh prescribes — a checkpoint written
on N devices restores onto M devices (elastic scaling path).
"""
from __future__ import annotations

import hashlib
import os
import re
import shutil
from typing import Any, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import msgpack
import numpy as np


def _tree_paths(tree) -> List[str]:
    paths = []
    for kp, _ in jax.tree_util.tree_flatten_with_path(tree)[0]:
        paths.append(jax.tree_util.keystr(kp))
    return paths


def save_checkpoint(directory: str, step: int, tree: Any,
                    metadata: Optional[Dict] = None) -> str:
    """Atomic: stage into .tmp, fsync, rename."""
    os.makedirs(directory, exist_ok=True)
    final = os.path.join(directory, f"step_{step:09d}")
    tmp = final + ".tmp"
    if os.path.exists(tmp):
        shutil.rmtree(tmp)
    os.makedirs(tmp)

    leaves, treedef = jax.tree_util.tree_flatten(tree)
    host_leaves = [np.asarray(l) for l in leaves]
    # numpy's npz cannot hold bfloat16: store a uint16 view; the true
    # dtype lives in the manifest and restore_checkpoint casts back.
    storable = [l.view(np.uint16) if l.dtype == jnp.bfloat16 else l
                for l in host_leaves]
    arrays = {f"a{i}": l for i, l in enumerate(storable)}
    np.savez(os.path.join(tmp, "arrays.npz"), **arrays)

    checksum = hashlib.sha256()
    for l in host_leaves:
        checksum.update(np.ascontiguousarray(l).tobytes()[:4096])
    manifest = {
        "step": step,
        "n_leaves": len(host_leaves),
        "paths": _tree_paths(tree),
        "shapes": [list(l.shape) for l in host_leaves],
        "dtypes": [str(l.dtype) for l in host_leaves],
        "checksum": checksum.hexdigest(),
        "metadata": metadata or {},
    }
    with open(os.path.join(tmp, "manifest.msgpack"), "wb") as f:
        f.write(msgpack.packb(manifest))
    os.replace(tmp, final)  # atomic on POSIX
    return final


def list_steps(directory: str) -> List[int]:
    if not os.path.isdir(directory):
        return []
    out = []
    for name in os.listdir(directory):
        m = re.fullmatch(r"step_(\d+)", name)
        if m and os.path.exists(os.path.join(directory, name,
                                             "manifest.msgpack")):
            out.append(int(m.group(1)))
    return sorted(out)


def latest_step(directory: str) -> Optional[int]:
    steps = list_steps(directory)
    return steps[-1] if steps else None


def restore_checkpoint(directory: str, step: int, like: Any,
                       shardings: Any = None) -> Tuple[Any, Dict]:
    """Restore into the structure of ``like``; optionally device_put each
    leaf with the matching sharding from ``shardings`` (same treedef)."""
    path = os.path.join(directory, f"step_{step:09d}")
    with open(os.path.join(path, "manifest.msgpack"), "rb") as f:
        manifest = msgpack.unpackb(f.read())
    data = np.load(os.path.join(path, "arrays.npz"))
    leaves, treedef = jax.tree_util.tree_flatten(like)
    if manifest["n_leaves"] != len(leaves):
        raise ValueError(
            f"checkpoint has {manifest['n_leaves']} leaves, "
            f"expected {len(leaves)}")
    restored = []
    shard_leaves = (treedef.flatten_up_to(shardings)
                    if shardings is not None else [None] * len(leaves))
    for i, (ref, shd) in enumerate(zip(leaves, shard_leaves)):
        arr = data[f"a{i}"]
        if list(arr.shape) != list(ref.shape):
            raise ValueError(f"leaf {i}: shape {arr.shape} != {ref.shape}")
        if manifest["dtypes"][i] == "bfloat16":
            arr = arr.view(jnp.bfloat16)
        arr = arr.astype(ref.dtype)
        restored.append(jax.device_put(arr, shd) if shd is not None
                        else jnp.asarray(arr))
    return treedef.unflatten(restored), manifest["metadata"]


class CheckpointManager:
    """save/restore with keep-last-k garbage collection."""

    def __init__(self, directory: str, keep: int = 3):
        self.directory = directory
        self.keep = keep

    def save(self, step: int, tree: Any, metadata: Optional[Dict] = None):
        path = save_checkpoint(self.directory, step, tree, metadata)
        self._gc()
        return path

    def _gc(self):
        steps = list_steps(self.directory)
        for s in steps[:-self.keep]:
            shutil.rmtree(os.path.join(self.directory, f"step_{s:09d}"),
                          ignore_errors=True)

    def restore_latest(self, like: Any, shardings: Any = None):
        step = latest_step(self.directory)
        if step is None:
            return None, None, {}
        tree, meta = restore_checkpoint(self.directory, step, like,
                                        shardings)
        return step, tree, meta
