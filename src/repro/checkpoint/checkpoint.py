"""Fault-tolerant checkpointing: atomic writes, content manifest,
keep-last-k GC, restore-latest, and cross-topology resharding.

Layout:
    <dir>/step_000123/
        manifest.msgpack   (tree spec, shapes, dtypes, metadata, checksums)
        arrays.npz         (leaf i -> 'a<i>')
    <dir>/step_000123.tmp...   (staging; atomic rename on completion)

Two restore paths share one on-disk format:

  * **template-based** (``like=`` a pytree): leaves restore into the
    structure of ``like`` and are cast to each reference leaf's dtype —
    the training path, where the optimizer state the caller rebuilt is
    the source of truth for dtypes.
  * **self-describing** (``like=None``): the tree structure, container
    kinds and EXACT leaf dtypes come from the manifest's tree spec
    (``quant.prepare.tree_manifest``). This is the serving/fabric path:
    a :class:`~repro.quant.prepare.PreparedWeight` tree (nibble-packed
    int4 bytes, int8 rows, per-channel scales, act scales) round-trips
    bit-exactly with no template — an ``astype(ref.dtype)`` cast would
    destroy packed storage, and a restarted worker has no prepared
    template to offer without redoing the quantize/pack work the
    checkpoint exists to skip.

Integrity: the manifest carries a full sha256 per leaf and restore
verifies every one before rebuilding the tree — a corrupted checkpoint
raises :class:`ChecksumError` naming the damaged leaf path instead of
restoring silently.

Miss behavior (unified): a missing step/directory raises
:class:`CheckpointNotFound` everywhere — ``restore_checkpoint`` on an
absent step and ``CheckpointManager.restore_latest`` on an empty
directory alike. Callers that treat "no checkpoint yet" as a normal
state (e.g. ``runtime.fault_tolerance.FaultTolerantLoop`` on its first
run) pass ``missing_ok=True`` to get the ``(None, None, {})`` sentinel.

Resharding: leaves are restored host-side (numpy) and device_put with
whatever shardings the *current* mesh prescribes — a checkpoint written
on N devices restores onto M devices (elastic scaling path).
"""
from __future__ import annotations

import hashlib
import os
import re
import shutil
from typing import Any, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import msgpack
import numpy as np

MANIFEST_VERSION = 2


class CheckpointError(RuntimeError):
    """Base class for checkpoint failures."""


class ChecksumError(CheckpointError):
    """A restored leaf's bytes do not match its recorded sha256."""


class CheckpointNotFound(CheckpointError, FileNotFoundError):
    """The requested step (or any step at all) does not exist."""


def _tree_paths(tree) -> List[str]:
    paths = []
    for kp, _ in jax.tree_util.tree_flatten_with_path(tree)[0]:
        paths.append(jax.tree_util.keystr(kp))
    return paths


def _leaf_bytes(arr: np.ndarray) -> bytes:
    return np.ascontiguousarray(arr).tobytes()


def save_checkpoint(directory: str, step: int, tree: Any,
                    metadata: Optional[Dict] = None) -> str:
    """Atomic: stage into .tmp, write arrays + manifest, rename.

    The manifest records the tree's structure spec
    (``quant.prepare.tree_manifest`` — container kinds, PreparedWeight
    storage kinds, exact dtypes) and a full per-leaf sha256, so the
    checkpoint restores either against a template or self-describing.
    """
    from repro.quant.prepare import tree_manifest
    os.makedirs(directory, exist_ok=True)
    final = os.path.join(directory, f"step_{step:09d}")
    tmp = final + ".tmp"
    if os.path.exists(tmp):
        shutil.rmtree(tmp)
    os.makedirs(tmp)

    spec, leaves = tree_manifest(tree)
    host_leaves = [np.asarray(lf) for lf in leaves]
    # numpy's npz cannot hold bfloat16: store a uint16 view; the true
    # dtype lives in the manifest and restore casts the view back.
    storable = [lf.view(np.uint16) if lf.dtype == jnp.bfloat16 else lf
                for lf in host_leaves]
    arrays = {f"a{i}": lf for i, lf in enumerate(storable)}
    np.savez(os.path.join(tmp, "arrays.npz"), **arrays)

    manifest = {
        "version": MANIFEST_VERSION,
        "step": step,
        "n_leaves": len(host_leaves),
        "paths": _tree_paths(tree),
        "shapes": [list(lf.shape) for lf in host_leaves],
        "dtypes": [str(lf.dtype) for lf in host_leaves],
        "checksums": [hashlib.sha256(_leaf_bytes(lf)).hexdigest()
                      for lf in host_leaves],
        "tree_spec": spec,
        "metadata": metadata or {},
    }
    with open(os.path.join(tmp, "manifest.msgpack"), "wb") as f:
        f.write(msgpack.packb(manifest))
    if os.path.isdir(final):
        # re-saving an existing step: directory-rename cannot overwrite
        # a non-empty target, so drop the old step first (the staged
        # copy is complete, so a crash here loses only the stale copy)
        shutil.rmtree(final)
    os.replace(tmp, final)  # atomic on POSIX
    return final


def list_steps(directory: str) -> List[int]:
    if not os.path.isdir(directory):
        return []
    out = []
    for name in os.listdir(directory):
        m = re.fullmatch(r"step_(\d+)", name)
        if m and os.path.exists(os.path.join(directory, name,
                                             "manifest.msgpack")):
            out.append(int(m.group(1)))
    return sorted(out)


def latest_step(directory: str) -> Optional[int]:
    steps = list_steps(directory)
    return steps[-1] if steps else None


def _leaf_path(manifest: Dict, i: int) -> str:
    paths = manifest.get("paths") or []
    return paths[i] if i < len(paths) else f"leaf[{i}]"


def _load_step(directory: str, step: int):
    path = os.path.join(directory, f"step_{step:09d}")
    man = os.path.join(path, "manifest.msgpack")
    if not os.path.exists(man):
        raise CheckpointNotFound(
            f"no checkpoint for step {step} under {directory!r} "
            f"(have steps {list_steps(directory)})")
    with open(man, "rb") as f:
        manifest = msgpack.unpackb(f.read())
    data = np.load(os.path.join(path, "arrays.npz"))
    return manifest, data


def _verify_leaf(manifest: Dict, i: int, arr: np.ndarray):
    """Check leaf ``i``'s full sha256 against the manifest (computed on
    the true-dtype view, matching save). Pre-v2 manifests carried only a
    truncated combined digest — nothing per-leaf to verify."""
    sums = manifest.get("checksums")
    if not sums:
        return
    got = hashlib.sha256(_leaf_bytes(arr)).hexdigest()
    if got != sums[i]:
        raise ChecksumError(
            f"checkpoint leaf {_leaf_path(manifest, i)!r} (index {i}) is "
            f"corrupted: sha256 {got[:12]}... != recorded "
            f"{sums[i][:12]}...")


def _restored_leaf(manifest: Dict, data, i: int, verify: bool):
    arr = data[f"a{i}"]
    if manifest["dtypes"][i] == "bfloat16":
        arr = arr.view(jnp.bfloat16)
    if verify:
        _verify_leaf(manifest, i, arr)
    return arr


def restore_checkpoint(directory: str, step: int, like: Any = None,
                       shardings: Any = None,
                       verify: bool = True) -> Tuple[Any, Dict]:
    """Restore step ``step``; raises :class:`CheckpointNotFound` if it
    does not exist and :class:`ChecksumError` on corrupted leaves.

    With ``like`` (a pytree template): leaves restore into its structure
    and cast to each reference leaf's dtype. With ``like=None``: the
    tree rebuilds self-describing from the manifest's structure spec
    with EXACT stored dtypes (PreparedWeight containers included) —
    required for prepared-weight trees, whose packed storage no cast
    can reproduce.
    """
    from repro.quant.prepare import tree_from_manifest
    manifest, data = _load_step(directory, step)
    if like is None:
        spec = manifest.get("tree_spec")
        if spec is None:
            raise CheckpointError(
                f"checkpoint step {step} under {directory!r} predates "
                "the self-describing manifest (v2); pass a 'like' "
                "template to restore it")
        leaves = [jnp.asarray(_restored_leaf(manifest, data, i, verify))
                  for i in range(manifest["n_leaves"])]
        return tree_from_manifest(spec, leaves), manifest["metadata"]

    leaves, treedef = jax.tree_util.tree_flatten(like)
    if manifest["n_leaves"] != len(leaves):
        raise CheckpointError(
            f"checkpoint has {manifest['n_leaves']} leaves, "
            f"expected {len(leaves)}")
    restored = []
    shard_leaves = (treedef.flatten_up_to(shardings)
                    if shardings is not None else [None] * len(leaves))
    for i, (ref, shd) in enumerate(zip(leaves, shard_leaves)):
        arr = _restored_leaf(manifest, data, i, verify)
        if list(arr.shape) != list(ref.shape):
            raise CheckpointError(
                f"leaf {_leaf_path(manifest, i)!r}: shape {arr.shape} "
                f"!= {ref.shape}")
        arr = arr.astype(ref.dtype)
        restored.append(jax.device_put(arr, shd) if shd is not None
                        else jnp.asarray(arr))
    return treedef.unflatten(restored), manifest["metadata"]


class CheckpointManager:
    """save/restore with keep-last-k garbage collection."""

    def __init__(self, directory: str, keep: int = 3):
        self.directory = directory
        self.keep = keep

    def save(self, step: int, tree: Any, metadata: Optional[Dict] = None):
        path = save_checkpoint(self.directory, step, tree, metadata)
        self._gc()
        return path

    def _gc(self):
        steps = list_steps(self.directory)
        for s in steps[:-self.keep]:
            shutil.rmtree(os.path.join(self.directory, f"step_{s:09d}"),
                          ignore_errors=True)
        # stale staging dirs: a writer that crashed mid-save leaves
        # step_*.tmp behind; list_steps already ignores them, and GC
        # removes them so a crash can't leak disk forever
        for name in os.listdir(self.directory):
            if re.fullmatch(r"step_\d+\.tmp", name):
                shutil.rmtree(os.path.join(self.directory, name),
                              ignore_errors=True)

    def restore_latest(self, like: Any = None, shardings: Any = None,
                       missing_ok: bool = False):
        """Restore the newest step as ``(step, tree, metadata)``.

        Raises :class:`CheckpointNotFound` when the directory holds no
        checkpoint — the same miss behavior as ``restore_checkpoint``
        on an absent step. ``missing_ok=True`` opts into the
        ``(None, None, {})`` sentinel for callers (first-run resume
        loops) that treat an empty directory as a normal state.
        """
        step = latest_step(self.directory)
        if step is None:
            if missing_ok:
                return None, None, {}
            raise CheckpointNotFound(
                f"no checkpoint under {self.directory!r}")
        tree, meta = restore_checkpoint(self.directory, step, like,
                                        shardings)
        return step, tree, meta
