"""Declarative experiment engine for the paper-reproduction sweeps.

Every figure/table script declares its parameter space as a
:class:`SweepSpec`, and the engine takes care of the rest:

  * ``sweep``  — axis expansion (cartesian or zipped, with filters) into
    hashable :class:`ExperimentPoint`s;
  * ``cache``  — a content-addressed on-disk result store keyed by a
    stable hash of (eval function, params, code-version salt), so
    re-running any script only simulates missing points;
  * ``runner`` — executes points inline or via a process pool
    (``--jobs``), counts cache hits vs. fresh evaluations, and returns
    results in spec order so output is byte-identical at any job count.

Entry points share one CLI surface (``--jobs/--no-cache/--cache-dir``)
via :func:`add_cli_args` / :func:`EngineConfig.from_args`.
"""
from repro.exp.cache import ResultCache, code_salt, point_key
from repro.exp.runner import (EngineConfig, RunReport, add_cli_args,
                              rows_from, run_sweep)
from repro.exp.sweep import ExperimentPoint, SweepSpec

__all__ = [
    "EngineConfig", "ExperimentPoint", "ResultCache", "RunReport",
    "SweepSpec", "add_cli_args", "code_salt", "point_key", "rows_from",
    "run_sweep",
]
