"""Content-addressed on-disk result store for experiment points.

Key = SHA-256 of the canonical encoding of (schema version, salt,
eval-module source hash, eval-function path, sorted params). The salt
defaults to a hash of the ``repro.core`` + ``repro.exp`` source trees,
so editing the simulator or the engine invalidates every cached result;
the per-point module hash does the same for the benchmark module that
defines the eval function. The store stays append-only (stale entries
are simply never addressed again).

Entries are one JSON file per key, sharded by the first two hex chars,
written atomically (tmp file + rename) so concurrent writers — the
process-pool runner, or two scripts sharing a cache — can never leave a
torn entry. Values must be JSON-serializable; that is exactly the
"structured rows" contract the benchmark scripts emit.
"""
from __future__ import annotations

import dataclasses
import functools
import hashlib
import importlib.util
import json
import os
import tempfile
from typing import Any, Optional, Tuple

from repro.exp.sweep import ExperimentPoint

DEFAULT_CACHE_DIR = os.environ.get("REPRO_EXP_CACHE", "results/expcache")
_SCHEMA = "exp-v1"

# Packages whose source text feeds the default code-version salt.
# repro.autotune is registered here so editing the planner's objectives
# or search orphans every cached autotune score (same contract as the
# simulator itself); its __init__ is imports-lazy, so hashing it never
# pulls the jax model stack.
_SALT_PACKAGES = ("repro.core", "repro.exp", "repro.autotune")


@functools.lru_cache(maxsize=None)
def code_salt() -> str:
    """Hash of the simulator + engine sources (the code-version salt)."""
    h = hashlib.sha256()
    for pkg_name in _SALT_PACKAGES:
        pkg = __import__(pkg_name, fromlist=["__path__"])
        for path in sorted(pkg.__path__):
            for fname in sorted(os.listdir(path)):
                if not fname.endswith(".py"):
                    continue
                h.update(fname.encode())
                with open(os.path.join(path, fname), "rb") as f:
                    h.update(f.read())
    return h.hexdigest()[:16]


@functools.lru_cache(maxsize=None)
def _module_salt(mod_name: str) -> str:
    """Hash of the eval function's defining module source. Keyed per
    point, this invalidates a benchmark's cached results when its eval
    code changes even though the module lives outside _SALT_PACKAGES
    (benchmarks/ isn't an installed package). Uses find_spec so the
    module is never executed just to compute a key."""
    try:
        spec = importlib.util.find_spec(mod_name)
    except (ImportError, ValueError):
        return ""
    origin = getattr(spec, "origin", None) if spec else None
    if not origin or not os.path.exists(origin):
        return ""
    h = hashlib.sha256()
    with open(origin, "rb") as f:
        h.update(f.read())
    return h.hexdigest()[:16]


def point_key(point: ExperimentPoint, salt: Optional[str] = None) -> str:
    """Stable cache key for a point (hex SHA-256)."""
    payload = [_SCHEMA, salt if salt is not None else code_salt(),
               _module_salt(point.fn.partition(":")[0]),
               point.canonical()]
    blob = json.dumps(payload, sort_keys=True, separators=(",", ":"))
    return hashlib.sha256(blob.encode()).hexdigest()


_MISS = object()


@dataclasses.dataclass
class ResultCache:
    """Filesystem-backed point-result store.

    ``salt=None`` uses :func:`code_salt`; tests inject explicit salts to
    exercise invalidation.
    """

    root: str = DEFAULT_CACHE_DIR
    salt: Optional[str] = None

    def __post_init__(self):
        # fail at construction, not after the sweep has simulated
        if os.path.exists(self.root) and not os.path.isdir(self.root):
            raise ValueError(f"cache dir {self.root!r} is not a directory")

    def _path(self, key: str) -> str:
        return os.path.join(self.root, key[:2], key + ".json")

    def get(self, point: ExperimentPoint) -> Tuple[bool, Any]:
        """(hit, value). A corrupt/unreadable entry counts as a miss."""
        path = self._path(point_key(point, self.salt))
        try:
            with open(path) as f:
                entry = json.load(f)
        except (OSError, ValueError):
            return False, None
        if "result" not in entry:
            return False, None
        return True, entry["result"]

    def put(self, point: ExperimentPoint, result: Any) -> None:
        key = point_key(point, self.salt)
        path = self._path(key)
        os.makedirs(os.path.dirname(path), exist_ok=True)
        entry = {"key": key, "fn": point.fn, "params": point.label(),
                 "result": result}
        blob = json.dumps(entry, indent=1, sort_keys=True)
        fd, tmp = tempfile.mkstemp(dir=os.path.dirname(path),
                                   prefix=".tmp-", suffix=".json")
        try:
            with os.fdopen(fd, "w") as f:
                f.write(blob)
            os.replace(tmp, path)
        except BaseException:
            try:
                os.unlink(tmp)
            except OSError:
                pass
            raise

    def __len__(self) -> int:
        if not os.path.isdir(self.root):
            return 0
        return sum(1 for _, _, files in os.walk(self.root)
                   for f in files if f.endswith(".json")
                   and not f.startswith(".tmp-"))
