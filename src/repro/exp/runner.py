"""Sweep execution: cache lookup, fan-out, progress, counters.

``run_sweep`` resolves each point against the cache, evaluates only the
misses (inline for ``jobs<=1``, else in a ``ProcessPoolExecutor``), and
returns results in spec order — so the emitted JSON is byte-identical
at any job count. The returned :class:`RunReport` exposes
``n_executed``: the number of fresh simulator evaluations, the counter
the warm-cache acceptance check (and the CI smoke job) asserts on.

Simulator sweeps are embarrassingly parallel numpy/jax-CPU work; the
pool uses the ``spawn`` start method (the parent has JAX's internal
threads running, so forking risks deadlock) and spawn propagates
``sys.path``, so ``"benchmarks.fig8_perf:eval_point"`` style references
resolve in children exactly as in the parent.
"""
from __future__ import annotations

import argparse
import concurrent.futures
import dataclasses
import importlib
import multiprocessing
import sys
import time
from typing import Any, Dict, List, Optional, Sequence, Tuple

from repro.exp.cache import ResultCache
from repro.exp.sweep import ExperimentPoint, SweepSpec


def resolve_fn(ref: str):
    """Import ``"pkg.module:function"``."""
    mod_name, _, qual = ref.partition(":")
    if not qual:
        raise ValueError(f"bad fn reference {ref!r} (want 'pkg.mod:fn')")
    obj = importlib.import_module(mod_name)
    for part in qual.split("."):
        obj = getattr(obj, part)
    return obj


def _eval_point(point: ExperimentPoint) -> Any:
    return resolve_fn(point.fn)(**point.kwargs)


@dataclasses.dataclass
class RunReport:
    """Outcome of one ``run_sweep`` call."""

    name: str
    n_points: int = 0
    n_cached: int = 0
    n_executed: int = 0
    wall_s: float = 0.0

    def merged(self, other: "RunReport") -> "RunReport":
        return RunReport(self.name, self.n_points + other.n_points,
                         self.n_cached + other.n_cached,
                         self.n_executed + other.n_executed,
                         self.wall_s + other.wall_s)

    def summary(self) -> str:
        return (f"{self.name}: {self.n_points} points, "
                f"{self.n_cached} cached, {self.n_executed} executed "
                f"in {self.wall_s:.2f}s")


@dataclasses.dataclass
class EngineConfig:
    """Shared CLI surface of every benchmark entry point."""

    jobs: int = 1
    cache: Optional[ResultCache] = dataclasses.field(
        default_factory=ResultCache)
    progress: bool = False

    @classmethod
    def from_args(cls, args: argparse.Namespace) -> "EngineConfig":
        cache = None
        if not args.no_cache:
            cache = (ResultCache(args.cache_dir) if args.cache_dir
                     else ResultCache())
        return cls(jobs=args.jobs, cache=cache,
                   progress=not args.quiet_progress)

    # aggregate report across every sweep this config has run
    _total: RunReport = dataclasses.field(
        default_factory=lambda: RunReport("total"))

    @property
    def total(self) -> RunReport:
        return self._total


def add_cli_args(parser: argparse.ArgumentParser) -> None:
    g = parser.add_argument_group("experiment engine")
    g.add_argument("--jobs", type=int, default=1, metavar="N",
                   help="worker processes for sweep points (default 1)")
    g.add_argument("--no-cache", action="store_true",
                   help="ignore and don't write the on-disk result cache")
    g.add_argument("--cache-dir", default=None, metavar="DIR",
                   help="result cache location (default results/expcache)")
    g.add_argument("--quiet-progress", action="store_true",
                   help="suppress per-sweep progress lines on stderr")


def run_sweep(spec: SweepSpec,
              engine: Optional[EngineConfig] = None,
              ) -> Tuple[List[Tuple[ExperimentPoint, Any]], RunReport]:
    """Evaluate a sweep; returns ([(point, result)...] in spec order,
    report). Cached points are never re-evaluated."""
    engine = engine or EngineConfig()
    t0 = time.perf_counter()
    points = spec.points()
    report = RunReport(spec.name, n_points=len(points))
    results: List[Any] = [None] * len(points)
    todo: List[int] = []
    for i, p in enumerate(points):
        if engine.cache is not None:
            hit, value = engine.cache.get(p)
            if hit:
                results[i] = value
                report.n_cached += 1
                continue
        todo.append(i)

    if todo and engine.progress:
        print(f"[exp:{spec.name}] evaluating {len(todo)}/{len(points)} "
              f"points (jobs={engine.jobs})", file=sys.stderr, flush=True)

    def _record(i: int, value: Any) -> None:
        # cache incrementally (puts are atomic) so an interrupt or a
        # failing point keeps every result computed before it
        results[i] = value
        report.n_executed += 1
        if engine.cache is not None:
            engine.cache.put(points[i], value)

    if engine.jobs <= 1 or len(todo) <= 1:
        for n_done, i in enumerate(todo, 1):
            _record(i, _eval_point(points[i]))
            _progress(engine, spec.name, n_done, len(todo))
    else:
        workers = min(engine.jobs, len(todo))
        ctx = multiprocessing.get_context("spawn")
        with concurrent.futures.ProcessPoolExecutor(workers,
                                                    mp_context=ctx) as pool:
            futs = {pool.submit(_eval_point, points[i]): i for i in todo}
            n_done = 0
            first_exc: Optional[Exception] = None
            for fut in concurrent.futures.as_completed(futs):
                try:
                    value = fut.result()
                except Exception as e:
                    # keep draining so every finished point still gets
                    # cached; surface the first failure afterwards
                    if first_exc is None:
                        first_exc = e
                    continue
                _record(futs[fut], value)
                n_done += 1
                _progress(engine, spec.name, n_done, len(todo))
            if first_exc is not None:
                raise first_exc

    report.wall_s = time.perf_counter() - t0
    engine._total = engine._total.merged(report)
    if engine.progress:
        print(f"[exp:{spec.name}] {report.summary()}", file=sys.stderr,
              flush=True)
    return list(zip(points, results)), report


def _progress(engine: EngineConfig, name: str, done: int, total: int) -> None:
    if not engine.progress or total < 8:
        return
    step = max(total // 8, 1)
    if done % step == 0 or done == total:
        print(f"[exp:{name}] {done}/{total}", file=sys.stderr, flush=True)


def rows_from(results: Sequence[Tuple[ExperimentPoint, Any]],
              sweep: str) -> List[Dict[str, Any]]:
    """Flatten (point, result) pairs into structured JSON rows — the
    interchange format tools/roofline_table.py renders."""
    return [{"sweep": sweep, "params": p.kwargs, "value": v}
            for p, v in results]
