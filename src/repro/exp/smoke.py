"""Sweep-engine smoke check (the CI "sweep-smoke" job).

Runs a tiny simulator sweep three ways and asserts the engine's core
contracts end to end:

  1. cold cache  — every point is executed;
  2. warm cache  — a second run performs **zero** simulator evaluations
     (``report.n_executed == 0``) and returns identical rows;
  3. parallel    — ``--jobs 2`` against a fresh cache produces
     byte-identical JSON to the serial run.

    PYTHONPATH=src python -m repro.exp.smoke [--cache-dir DIR]
"""
from __future__ import annotations

import argparse
import dataclasses
import json
import os
import shutil
import sys
import tempfile

from repro.core import simulator as sim
from repro.core.workloads import ConvLayer
from repro.exp import EngineConfig, ResultCache, SweepSpec, run_sweep
from repro.exp.runner import rows_from

_TINY_LAYER = ("smoke", 32, 32, 8, 8, 3, 3, 1)


def eval_point(w: int, cluster: int, seed: int = 0,
               source: str = "forward") -> dict:
    """Simulate one tiny conv layer at one (adder width, cluster) point."""
    layer = ConvLayer(*_TINY_LAYER)
    tile = dataclasses.replace(sim.SMALL_TILE, adder_w=w,
                               cluster_size=cluster)
    src = sim.FORWARD_SOURCE if source == "forward" else sim.BACKWARD_SOURCE
    stats = sim.simulate_network([layer], tile, source=src, seed=seed,
                                 n_group_samples=64)
    return {"cycles": stats.cycles, "slowdown": stats.slowdown}


def square(x: int) -> int:
    """Trivial eval target for engine unit tests (no simulator)."""
    return x * x


def square_or_raise(x: int) -> int:
    """Eval target for the runner's partial-failure tests."""
    if x < 0:
        raise ValueError(f"negative input {x}")
    return x * x


def smoke_spec() -> SweepSpec:
    return SweepSpec(
        name="smoke",
        fn="repro.exp.smoke:eval_point",
        axes={"w": [12, 16], "cluster": [1, 4]},
        fixed={"seed": 0, "source": "forward"},
    )


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--cache-dir", default=None)
    ap.add_argument("--jobs", type=int, default=2,
                    help="job count for the parallel determinism leg")
    args = ap.parse_args(argv)
    # fresh run directory per invocation so the cold-cache leg really is
    # cold even when --cache-dir points at a reused location
    base = args.cache_dir or tempfile.gettempdir()
    os.makedirs(base, exist_ok=True)
    cache_dir = tempfile.mkdtemp(dir=base, prefix="exp-smoke-run-")
    spec = smoke_spec()

    cold = EngineConfig(jobs=1, cache=ResultCache(cache_dir), progress=True)
    res_cold, rep_cold = run_sweep(spec, cold)
    assert rep_cold.n_executed == len(spec.points()), \
        f"cold run executed {rep_cold.n_executed} != {len(spec.points())}"

    warm = EngineConfig(jobs=1, cache=ResultCache(cache_dir), progress=True)
    res_warm, rep_warm = run_sweep(spec, warm)
    assert rep_warm.n_executed == 0, \
        f"warm run re-executed {rep_warm.n_executed} points"
    assert rep_warm.n_cached == len(spec.points())

    serial = json.dumps(rows_from(res_cold, spec.name), sort_keys=True)
    cached = json.dumps(rows_from(res_warm, spec.name), sort_keys=True)
    assert serial == cached, "cached rows differ from computed rows"

    par = EngineConfig(jobs=args.jobs, cache=None, progress=True)
    res_par, rep_par = run_sweep(spec, par)
    assert rep_par.n_executed == len(spec.points())
    parallel = json.dumps(rows_from(res_par, spec.name), sort_keys=True)
    assert parallel == serial, \
        f"jobs={args.jobs} rows differ from serial rows"

    shutil.rmtree(cache_dir, ignore_errors=True)
    print(f"exp smoke OK: {rep_cold.summary()} | {rep_warm.summary()} | "
          f"{rep_par.summary()}")
    return 0


if __name__ == "__main__":
    sys.exit(main())
