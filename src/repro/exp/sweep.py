"""Sweep declaration and expansion.

A :class:`SweepSpec` names an evaluation function (as an importable
``"pkg.module:function"`` path so points survive pickling into worker
processes) and a set of named axes. Expansion produces
:class:`ExperimentPoint`s — frozen, hashable, canonically-encodable
parameter bindings — in a deterministic order: cartesian products
iterate the *last* axis fastest (like nested for-loops in declaration
order); zipped sweeps pair axes element-wise.

Axis values must be canonically encodable (see :func:`encode`):
primitives, sequences, mappings, and frozen dataclasses such as
``TileConfig``. Unencodable objects (open-ended class instances, numpy
arrays) are rejected at expansion time so cache keys can never silently
depend on ``repr`` quirks — pass a name and resolve it inside the eval
function instead.
"""
from __future__ import annotations

import dataclasses
import itertools
import json
from typing import Any, Callable, Dict, Iterator, Mapping, Optional
from typing import Sequence, Tuple


def encode(value: Any) -> Any:
    """Canonical JSON-able encoding of a parameter value.

    The encoding is injective on the supported domain (type tags keep
    ``(1, 2)`` distinct from ``[1, 2]`` and ``True`` from ``1``) and
    stable across processes and interpreter restarts — it is the basis
    of the cache key.
    """
    # numpy scalars subclass python numbers (np.float64 is a float) —
    # normalize them first or their repr leaks into the key
    if type(value).__module__.startswith("numpy") and hasattr(value, "item"):
        return encode(value.item())
    if value is None or isinstance(value, (str, int)) \
            and not isinstance(value, bool):
        return value
    if isinstance(value, bool):
        return ["bool", int(value)]
    if isinstance(value, float):
        return ["f", repr(value)]
    if isinstance(value, (list, tuple)):
        tag = "tuple" if isinstance(value, tuple) else "list"
        return [tag, [encode(v) for v in value]]
    if isinstance(value, Mapping):
        # keys are encoded too (so {1: v} != {"1": v}); sort on the
        # JSON form since encoded keys may be strings or tagged lists
        items = sorted(([encode(k), encode(v)] for k, v in value.items()),
                       key=lambda kv: json.dumps(kv[0], sort_keys=True))
        return ["map", items]
    if dataclasses.is_dataclass(value) and not isinstance(value, type):
        cls = type(value)
        fields = [(f.name, encode(getattr(value, f.name)))
                  for f in dataclasses.fields(value)]
        return ["dc", f"{cls.__module__}.{cls.__qualname__}", fields]
    raise TypeError(
        f"cannot canonically encode {type(value).__name__!r} ({value!r}); "
        "pass a name/primitive and resolve the object inside the eval fn")


@dataclasses.dataclass(frozen=True)
class ExperimentPoint:
    """One evaluation: ``fn(**params)``.

    ``fn`` is an importable ``"pkg.module:function"`` path; ``params``
    a tuple of (name, value) pairs in axis declaration order.
    """

    fn: str
    params: Tuple[Tuple[str, Any], ...]

    @property
    def kwargs(self) -> Dict[str, Any]:
        return dict(self.params)

    def canonical(self) -> Any:
        """Order-independent encodable form (sorted by param name)."""
        return [self.fn, sorted((k, encode(v)) for k, v in self.params)]

    def label(self) -> str:
        return "/".join(f"{k}={v}" for k, v in self.params)


def fn_path(fn: Callable) -> str:
    """Importable path of a module-level callable."""
    if "<locals>" in fn.__qualname__:
        raise ValueError(f"{fn.__qualname__} is not module-level; sweep "
                         "eval functions must be importable by workers")
    return f"{fn.__module__}:{fn.__qualname__}"


@dataclasses.dataclass(frozen=True)
class SweepSpec:
    """A named parameter sweep over one eval function.

    Attributes:
      name: sweep identifier (used in progress lines and result rows).
      fn: ``"pkg.module:function"`` path or a module-level callable.
      axes: ordered mapping axis name -> sequence of values.
      mode: 'product' (cartesian, last axis fastest) or 'zip'
        (element-wise; all axes must have equal length).
      fixed: extra params bound identically on every point.
      filters: predicates on the full param dict; points failing any
        are dropped at expansion time (never evaluated, never cached).
    """

    name: str
    fn: Any
    axes: Mapping[str, Sequence[Any]]
    mode: str = "product"
    fixed: Mapping[str, Any] = dataclasses.field(default_factory=dict)
    filters: Sequence[Callable[[Dict[str, Any]], bool]] = ()

    def __post_init__(self):
        if self.mode not in ("product", "zip"):
            raise ValueError(f"bad sweep mode {self.mode!r}")
        if not self.axes:
            raise ValueError("sweep needs at least one axis")
        overlap = set(self.axes) & set(self.fixed)
        if overlap:
            raise ValueError(f"params both swept and fixed: {sorted(overlap)}")
        if self.mode == "zip":
            lengths = {k: len(v) for k, v in self.axes.items()}
            if len(set(lengths.values())) > 1:
                raise ValueError(f"zip axes differ in length: {lengths}")

    @property
    def fn_ref(self) -> str:
        return self.fn if isinstance(self.fn, str) else fn_path(self.fn)

    def _combos(self) -> Iterator[Tuple[Any, ...]]:
        names = list(self.axes)
        if self.mode == "zip":
            yield from zip(*(self.axes[n] for n in names))
        else:
            yield from itertools.product(*(self.axes[n] for n in names))

    def points(self) -> Tuple[ExperimentPoint, ...]:
        """Expand to points in deterministic order (filters applied)."""
        names = list(self.axes)
        fixed = tuple(self.fixed.items())
        ref = self.fn_ref
        out = []
        for combo in self._combos():
            params = dict(zip(names, combo), **self.fixed)
            if any(not flt(params) for flt in self.filters):
                continue
            point = ExperimentPoint(ref, tuple(zip(names, combo)) + fixed)
            point.canonical()  # reject unencodable values eagerly
            out.append(point)
        return tuple(out)

    def __len__(self) -> int:
        return len(self.points())
