"""Pallas TPU kernel: quantized integer matmul (the deployment path).

The accelerator's INT mode maps onto the TPU MXU, which natively consumes
int8 operands with int32 accumulation. INT4 operands ride in int8 lanes
(values range-checked) or arrive as packed nibbles (two INT4 weights per
int8 byte) that the kernel unpacks in-register — halving weight HBM/VMEM
traffic exactly as the paper's nibble storage halves SRAM.

Blocking: grid (M/bm, N/bn, K/bk); A block (bm, bk) and B block (bk, bn)
live in VMEM; the int32 output block (bm, bn) is revisited across the k
steps (k is the innermost, sequential grid dimension). All dims are
MXU-aligned multiples of 128 by default (bm=bn=128, bk=256 for ~0.4 MB
VMEM per operand block).
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _qmm_kernel(a_ref, b_ref, o_ref):
    """o[m,n] += sum_k a[m,k] * b[k,n] in int32."""
    @pl.when(pl.program_id(2) == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    a = a_ref[...].astype(jnp.int32)
    b = b_ref[...].astype(jnp.int32)
    o_ref[...] += jax.lax.dot_general(
        a, b, (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.int32)


def _qmm_packed_kernel(a_ref, bp_ref, o_ref):
    """Packed-INT4 weights: bp holds two nibbles per byte along K.

    bp[k2, n] byte = (w[2*k2+1] << 4) | (w[2*k2] & 0xF); nibbles are
    sign-extended in-register, interleaved back to (bk, bn).
    """
    @pl.when(pl.program_id(2) == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    a = a_ref[...].astype(jnp.int32)          # (bm, bk)
    packed = bp_ref[...].astype(jnp.int32)    # (bk//2, bn)
    lo = ((packed & 0xF) ^ 8) - 8             # sign-extend low nibble
    hi = packed >> 4                          # arithmetic: sign-extended
    bk2, bn = packed.shape
    b = jnp.stack([lo, hi], axis=1).reshape(2 * bk2, bn)  # (bk, bn)
    o_ref[...] += jax.lax.dot_general(
        a, b, (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.int32)


def _pad_to(x: jax.Array, mults) -> jax.Array:
    pads = [(0, -dim % m) for dim, m in zip(x.shape, mults)]
    if any(p[1] for p in pads):
        x = jnp.pad(x, pads)
    return x


@functools.partial(jax.jit, static_argnames=("bm", "bn", "bk", "interpret"))
def qmm(a: jax.Array, b: jax.Array, *, bm: int = 128, bn: int = 128,
        bk: int = 256, interpret: bool = True) -> jax.Array:
    """int8 x int8 -> int32 blocked matmul. a: (M, K), b: (K, N)."""
    m, k = a.shape
    k2, n = b.shape
    assert k == k2, (a.shape, b.shape)
    a = _pad_to(a.astype(jnp.int8), (bm, bk))
    b = _pad_to(b.astype(jnp.int8), (bk, bn))
    mp, kp = a.shape
    _, np_ = b.shape
    grid = (mp // bm, np_ // bn, kp // bk)
    out = pl.pallas_call(
        _qmm_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bk), lambda mi, ni, ki: (mi, ki)),
            pl.BlockSpec((bk, bn), lambda mi, ni, ki: (ki, ni)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda mi, ni, ki: (mi, ni)),
        out_shape=jax.ShapeDtypeStruct((mp, np_), jnp.int32),
        interpret=interpret,
    )(a, b)
    return out[:m, :n]


@functools.partial(jax.jit, static_argnames=("bm", "bn", "bk", "interpret"))
def qmm_packed(a: jax.Array, b_packed: jax.Array, *, bm: int = 128,
               bn: int = 128, bk: int = 256,
               interpret: bool = True) -> jax.Array:
    """int8 activations x packed-int4 weights -> int32.

    a: (M, K) int8; b_packed: (K//2, N) int8 (see pack_int4 in ops.py).
    K must be even.
    """
    m, k = a.shape
    kh, n = b_packed.shape
    assert k == 2 * kh, (a.shape, b_packed.shape)
    assert bk % 2 == 0
    a = _pad_to(a.astype(jnp.int8), (bm, bk))
    b_packed = _pad_to(b_packed.astype(jnp.int8), (bk // 2, bn))
    mp, kp = a.shape
    _, np_ = b_packed.shape
    grid = (mp // bm, np_ // bn, kp // bk)
    out = pl.pallas_call(
        _qmm_packed_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bk), lambda mi, ni, ki: (mi, ki)),
            pl.BlockSpec((bk // 2, bn), lambda mi, ni, ki: (ki, ni)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda mi, ni, ki: (mi, ni)),
        out_shape=jax.ShapeDtypeStruct((mp, np_), jnp.int32),
        interpret=interpret,
    )(a, b_packed)
    return out[:m, :n]
