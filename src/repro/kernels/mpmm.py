"""Pallas TPU kernel: bounded-alignment approximate FP-IP matmul.

This is the paper's FP16 arithmetic (core.ipu semantics) at matmul scale —
the *fidelity path* that bit-exactly reproduces what the IPU(w) hardware
would compute for every output element. Because every partial product
takes a data-dependent alignment shift before summation, the inner loop is
elementwise VPU work over a (bm, g, bn) product cube rather than an MXU
dot; this kernel is intentionally compute-inflated (that is the price of
bit-exact hardware emulation, quantified in EXPERIMENTS.md §Perf).

Mapping of the paper's microarchitecture onto the TPU grid:
  * one K-group of size g == IPUConfig.n is one kernel invocation's block
    reduction (the EHU runs once per block, amortized over nibble planes,
    mirroring the shared-EHU hardware);
  * the 9 temporal nibble iterations run as a fori_loop over stacked
    5-bit planes held in VMEM;
  * the (33+t+l)-bit accumulator is a two-limb int32 pair + exponent,
    persisted across k grid steps in revisited output blocks
    (o[m,n] index map independent of k, k innermost and sequential);
  * output rounding (round-to-nearest-even into fp16/fp32) happens in a
    cheap jnp epilogue outside the kernel.

A ``fused`` variant computes the full 22-bit mantissa product in one pass
(one plane instead of nine) — different (slightly *more* accurate)
truncation semantics, ~9x less VPU work; this is the beyond-paper
optimized mode benchmarked against the faithful mode in §Perf.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.core import fixedpoint as fx, fp16 as fpmod, nibble
from repro.core.ipu import IPUConfig, NEG_INF_EXP, _shr_i32, accumulate


def _mpmm_kernel(a_ref, b_ref, hi_ref, lo_ref, exp_ref, *, cfg: IPUConfig,
                 fused: bool):
    @pl.when(pl.program_id(2) == 0)
    def _init():
        hi_ref[...] = jnp.zeros_like(hi_ref)
        lo_ref[...] = jnp.zeros_like(lo_ref)
        exp_ref[...] = jnp.full_like(exp_ref, NEG_INF_EXP)

    a = a_ref[...]  # (bm, g) f16
    b = b_ref[...]  # (g, bn) f16
    sa, ea, ma = fpmod.decompose(a, fpmod.FP16)
    sb, eb, mb = fpmod.decompose(b, fpmod.FP16)

    # EHU: product exponents for the whole block, once for all planes.
    c = ea[:, :, None] + eb[None, :, :]            # (bm, g, bn)
    mx = jnp.max(c, axis=1)                        # (bm, bn)
    shift = mx[:, None, :] - c
    active = shift <= cfg.mask_threshold

    acc = fx.FX(hi_ref[...], lo_ref[...])
    exp_acc = exp_ref[...]

    if fused:
        # Single-plane fused mode: full 22-bit mantissa products, one
        # alignment+truncation at a w_f = min(w, 26)-bit fused datapath
        # (keeps |aligned| < 2**26 so the g-way int32 sum cannot overflow);
        # the w - w_f difference folds into the accumulator pre-shift
        # pre = 1 + w_f - w (may be negative; accumulate() left-shifts).
        w_f = min(cfg.w, 26)
        pre = 1 + w_f - cfg.w
        d = (sa * ma)[:, :, None] * (sb * mb)[None, :, :]  # |d| < 2**22
        rs = shift + (22 - w_f)  # net right shift; < 0 -> exact left shift
        aligned = _shr_i32(d, jnp.maximum(rs, 0), cfg.rounding)
        aligned = aligned << jnp.clip(-rs, 0, max(w_f - 22, 0))
        aligned = jnp.where(active, aligned, 0)
        s_tree = jnp.sum(aligned, axis=1)
        acc, exp_acc = accumulate(acc, exp_acc, s_tree, mx,
                                  jnp.full_like(mx, pre),
                                  jnp.zeros_like(mx), cfg)
    else:
        pa = jnp.stack(nibble.fp16_planes(sa, ma))  # (3, bm, g)
        pb = jnp.stack(nibble.fp16_planes(sb, mb))  # (3, g, bn)

        def iter_body(it, carry):
            hi2, lo2, exp2 = carry
            acc2 = fx.FX(hi2, lo2)
            # (i, j) from the flat index — pallas forbids captured constant
            # tables. Within a group the 9 updates commute (the accumulator
            # exponent pins to the group max on the first update), so the
            # enumeration order does not change the result.
            i = it // 3
            j = it % 3
            na = jax.lax.dynamic_index_in_dim(pa, i, 0, keepdims=False)
            nb = jax.lax.dynamic_index_in_dim(pb, j, 0, keepdims=False)
            d = na[:, :, None] * nb[None, :, :]    # (bm, g, bn), |d|<=225
            dw = d << (cfg.w - 9)
            pre = 4 * (4 - i - j)
            aligned = _shr_i32(dw, shift, cfg.rounding)
            aligned = jnp.where(active, aligned, 0)
            s_tree = jnp.sum(aligned, axis=1)      # (bm, bn)
            acc2, exp2 = accumulate(acc2, exp2, s_tree, mx, pre,
                                    jnp.zeros_like(mx), cfg)
            return acc2.hi, acc2.lo, exp2

        hi2, lo2, exp_acc = jax.lax.fori_loop(
            0, 9, iter_body, (acc.hi, acc.lo, exp_acc))
        acc = fx.FX(hi2, lo2)

    hi_ref[...] = acc.hi
    lo_ref[...] = acc.lo
    exp_ref[...] = exp_acc


def _pad_axis(x, axis, mult):
    pad = -x.shape[axis] % mult
    if pad:
        pw = [(0, 0)] * x.ndim
        pw[axis] = (0, pad)
        x = jnp.pad(x, pw)
    return x


@functools.partial(jax.jit,
                   static_argnames=("cfg", "bm", "bn", "fused", "interpret"))
def mp_matmul(a: jax.Array, b: jax.Array, cfg: IPUConfig = IPUConfig(),
              *, bm: int = 16, bn: int = 128, fused: bool = False,
              interpret: bool = True) -> jax.Array:
    """Approximate FP-IP matmul: (M, K) f16 x (K, N) f16 -> accum format.

    Bit-exact to core.ipu.fp16_inner_product with the same cfg (K grouped
    in cfg.n chunks, zero-padded — value-neutral, see DESIGN.md). The k
    grid dimension is innermost/sequential; accumulator state lives in
    revisited int32 output blocks.
    """
    if cfg.multi_cycle:
        raise NotImplementedError(
            "kernel implements plain IPU(w); MC-IPU emulation is the "
            "vmapped core.ipu path (bit-different truncation points)")
    if cfg.operand != "fp16":
        raise NotImplementedError(
            "mpmm kernel is FP16-operand; BF16 runs via core.ipu")
    m, k = a.shape
    k2, n = b.shape
    assert k == k2
    g = cfg.n
    a = _pad_axis(_pad_axis(jnp.asarray(a, jnp.float16), 0, bm), 1, g)
    b = _pad_axis(_pad_axis(jnp.asarray(b, jnp.float16), 1, bn), 0, g)
    mp_, kp = a.shape
    _, np_ = b.shape
    grid = (mp_ // bm, np_ // bn, kp // g)
    kern = functools.partial(_mpmm_kernel, cfg=cfg, fused=fused)
    hi, lo, exp = pl.pallas_call(
        kern,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, g), lambda mi, ni, ki: (mi, ki)),
            pl.BlockSpec((g, bn), lambda mi, ni, ki: (ki, ni)),
        ],
        out_specs=[
            pl.BlockSpec((bm, bn), lambda mi, ni, ki: (mi, ni)),
            pl.BlockSpec((bm, bn), lambda mi, ni, ki: (mi, ni)),
            pl.BlockSpec((bm, bn), lambda mi, ni, ki: (mi, ni)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((mp_, np_), jnp.int32),
            jax.ShapeDtypeStruct((mp_, np_), jnp.int32),
            jax.ShapeDtypeStruct((mp_, np_), jnp.int32),
        ],
        interpret=interpret,
    )(a, b)
    out = fx.round_to_fp(fx.FX(hi, lo), exp, cfg.accum_format)
    return out[:m, :n]
