"""Pallas TPU kernels: fused dequant-matmul over packed storage.

The deployment gap these close: the blocked decode fast path used to
*stage* a full compute-dtype copy of every quantized projection per
decode block (``quant.prepare.stage_params``), so nibble-packed int4
weights paid bf16 bandwidth through the memory hierarchy at matmul
time. These kernels take the STORED operands — int8 rows, nibble-packed
int4 bytes, fp8 (e4m3) codes, nibble-packed fp4 (e2m1) codes — plus
per-channel or per-group scales as kernel inputs, unpack/decode and
dequantize in-register inside the VMEM block loop, and fuse the scale
epilogue. The calibrated static activation-quant step rides in the same
loop: activations arrive f32 and are quantized against the stored
scalar scale in-register, so no staged operand and no separately
materialized quantized activation ever exists.

Two kernels:

* :func:`fused_qmm` — the exact-INT datapath (per-channel scales,
  static act scale): in-register activation quantize, int32 MXU
  accumulation across k blocks, epilogue ``acc * sa * sw`` — BIT-EXACT
  to ``quantize_symmetric(scale=sa)`` + ``qmm.qmm[_packed]`` +
  ``ops._scale_epilogue`` (same elementwise ops in the same order).
* :func:`fused_dequant_mm` — the general f32 datapath (any storage
  kind, per-channel or per-group scales, optional in-register
  activation quantize or quantize-dequantize): weights decode to f32 in
  the block, scales broadcast over their K-groups, f32 accumulation.

Blocking mirrors qmm.py: grid (M/bm, N/bn, K/bk) with k innermost and
sequential; accumulators live in revisited output blocks. Per-group
scales constrain bk to a multiple of the group size (the wrappers pick
``bk = g * max(1, 256 // g)``) so every k block covers whole groups and
the scale block is ``(bk // g, bn)``.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.qmm import _pad_to
from repro.quant.quantize import FP4_E2M1, FP8_E4M3, fp_decode

# storage kinds the kernels decode in-register
KINDS = ("int8", "int4", "int4_packed", "fp8", "fp4", "fp4_packed")
# kinds whose stored K axis is halved by nibble packing
PACKED_KINDS = ("int4_packed", "fp4_packed")


def _decode_block(w, kind: str) -> jax.Array:
    """Stored block -> f32 values (packed kinds double their K axis)."""
    if kind in ("int8", "int4"):
        return w.astype(jnp.float32)
    if kind == "int4_packed":
        return _int_block(w, kind).astype(jnp.float32)
    if kind in ("fp8", "fp4"):
        return fp_decode(w, FP8_E4M3 if kind == "fp8" else FP4_E2M1)
    if kind == "fp4_packed":
        p = w.astype(jnp.int32)
        lo = p & 0xF
        hi = (p >> 4) & 0xF
        k2, n = p.shape
        codes = jnp.stack([lo, hi], axis=1).reshape(2 * k2, n)
        return fp_decode(codes, FP4_E2M1)
    raise ValueError(f"unknown storage kind {kind!r}")


def _int_block(w, kind: str) -> jax.Array:
    """Stored int block -> int32 values (exact datapath)."""
    if kind == "int4_packed":
        p = w.astype(jnp.int32)
        lo = ((p & 0xF) ^ 8) - 8
        hi = p >> 4
        k2, n = p.shape
        return jnp.stack([lo, hi], axis=1).reshape(2 * k2, n)
    return w.astype(jnp.int32)


def _quantize_act(x, sa):
    """In-register mirror of ``quantize_symmetric(x, 8, scale=sa)``."""
    return jnp.clip(jnp.round(x / sa), -128.0, 127.0)


def _fused_qmm_kernel(x_ref, w_ref, sw_ref, sa_ref, o_ref, acc_ref, *,
                      kind: str):
    """Exact INT: quantize acts in-register, int32 accumulate, fused
    ``acc * sa * sw`` epilogue at the last k step."""
    @pl.when(pl.program_id(2) == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        o_ref[...] = jnp.zeros_like(o_ref)

    sa = sa_ref[0, 0]
    aq = _quantize_act(x_ref[...].astype(jnp.float32), sa)
    b = _int_block(w_ref[...], kind)
    acc_ref[...] += jax.lax.dot_general(
        aq.astype(jnp.int32), b, (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.int32)

    @pl.when(pl.program_id(2) == pl.num_programs(2) - 1)
    def _epilogue():
        # identical op order to ops._scale_epilogue with a 0-d scale_a
        o_ref[...] = (acc_ref[...].astype(jnp.float32) * sa
                      * sw_ref[...].astype(jnp.float32))


def _fused_dequant_kernel(x_ref, w_ref, sw_ref, sa_ref, o_ref, *,
                          kind: str, act: str, groups_per_block: int):
    """General path: decode + dequantize weights in-register (scales
    broadcast over their K-groups), optional in-register activation
    quantize ('quant': int-valued f32 acts, sa folded in the epilogue)
    or quantize-dequantize ('qdq': the fake-quant grid), f32 dot."""
    @pl.when(pl.program_id(2) == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    x = x_ref[...].astype(jnp.float32)
    if act != "none":
        x = _quantize_act(x, sa_ref[0, 0])
        if act == "qdq":
            x = x * sa_ref[0, 0]
    w = _decode_block(w_ref[...], kind)            # (bk, bn) f32
    sw = sw_ref[...].astype(jnp.float32)           # (bk // g, bn)
    bk, bn = w.shape
    g = bk // groups_per_block
    wf = (w.reshape(groups_per_block, g, bn)
          * sw[:, None, :]).reshape(bk, bn)
    o_ref[...] += jax.lax.dot_general(
        x, wf, (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)

    if act == "quant":
        @pl.when(pl.program_id(2) == pl.num_programs(2) - 1)
        def _epilogue():
            o_ref[...] = o_ref[...] * sa_ref[0, 0]


def _stored_k(w, kind: str) -> int:
    return w.shape[0] * (2 if kind in PACKED_KINDS else 1)


def _group_bk(k: int, sw, bk: int) -> int:
    """k-block size honoring the scale layout: per-channel scales
    ((1, N)) leave ``bk`` alone; per-group scales ((G, N), G groups
    along K) need bk to be a multiple of g = K / G."""
    groups = sw.shape[0]
    if groups <= 1:
        return bk
    if k % groups:
        raise ValueError(f"per-group scales: K={k} not divisible by "
                         f"G={groups}")
    g = k // groups
    return g * max(1, bk // g)


@functools.partial(jax.jit, static_argnames=("kind", "bm", "bn", "bk",
                                             "interpret"))
def fused_qmm(x: jax.Array, w: jax.Array, sw: jax.Array, sa: jax.Array,
              *, kind: str = "int8", bm: int = 128, bn: int = 128,
              bk: int = 256, interpret: bool = True) -> jax.Array:
    """Exact fused int matmul: f32 acts x stored int weights -> f32.

    x: (M, K) f32; w: (K, N) int8 rows / (K//2, N) packed int4 bytes;
    sw: (1, N) or (N,) per-channel f32 scales; sa: scalar static act
    scale. Bit-exact to ``quantize_symmetric(x, 8, scale=sa)`` followed
    by ``ops.quantized_matmul[_packed]``.
    """
    assert kind in ("int8", "int4", "int4_packed"), kind
    m, k = x.shape
    n = w.shape[1]
    assert k == _stored_k(w, kind), (x.shape, w.shape, kind)
    assert bk % 2 == 0
    packed = kind == "int4_packed"
    x = _pad_to(x.astype(jnp.float32), (bm, bk))
    w = _pad_to(w, (bk // 2 if packed else bk, bn))
    sw = _pad_to(sw.astype(jnp.float32).reshape(1, -1), (1, bn))
    sa2 = jnp.asarray(sa, jnp.float32).reshape(1, 1)
    mp, kp = x.shape
    np_ = w.shape[1]
    wb = bk // 2 if packed else bk
    out, _ = pl.pallas_call(
        functools.partial(_fused_qmm_kernel, kind=kind),
        grid=(mp // bm, np_ // bn, kp // bk),
        in_specs=[
            pl.BlockSpec((bm, bk), lambda mi, ni, ki: (mi, ki)),
            pl.BlockSpec((wb, bn), lambda mi, ni, ki: (ki, ni)),
            pl.BlockSpec((1, bn), lambda mi, ni, ki: (0, ni)),
            pl.BlockSpec((1, 1), lambda mi, ni, ki: (0, 0)),
        ],
        out_specs=(
            pl.BlockSpec((bm, bn), lambda mi, ni, ki: (mi, ni)),
            pl.BlockSpec((bm, bn), lambda mi, ni, ki: (mi, ni)),
        ),
        out_shape=(
            jax.ShapeDtypeStruct((mp, np_), jnp.float32),
            jax.ShapeDtypeStruct((mp, np_), jnp.int32),  # accumulator
        ),
        interpret=interpret,
    )(x, w, sw, sa2)
    return out[:m, :n]


@functools.partial(jax.jit, static_argnames=("kind", "act", "bm", "bn",
                                             "bk", "interpret"))
def fused_dequant_mm(x: jax.Array, w: jax.Array, sw: jax.Array,
                     sa, *, kind: str = "int8", act: str = "none",
                     bm: int = 128, bn: int = 128, bk: int = 256,
                     interpret: bool = True) -> jax.Array:
    """General fused dequant matmul: f32 acts x ANY stored kind -> f32.

    x: (M, K) f32; w: stored operand ((K, N), packed kinds (K//2, N));
    sw: (G, N) scales — G == 1 is per-channel, G > 1 splits K into
    equal groups; sa: scalar static act scale, consumed per ``act``:

      'none'  — activations ride through unquantized (fp storage tier);
      'qdq'   — fake-quant grid (quantize-dequantize against sa);
      'quant' — exact int-valued activations, sa folded in the epilogue.
    """
    assert kind in KINDS, kind
    assert act in ("none", "qdq", "quant"), act
    m, k = x.shape
    n = w.shape[1]
    assert k == _stored_k(w, kind), (x.shape, w.shape, kind)
    sw = jnp.asarray(sw, jnp.float32)
    if sw.ndim == 1:
        sw = sw.reshape(1, -1)
    groups = sw.shape[0]
    if groups > 1:
        bk = _group_bk(k, sw, bk)
        groups_per_block = bk // (k // groups)
        sw_index = lambda mi, ni, ki: (ki, ni)       # noqa: E731
    else:
        groups_per_block = 1                         # per-channel
        sw_index = lambda mi, ni, ki: (0, ni)        # noqa: E731
    assert bk % 2 == 0
    packed = kind in PACKED_KINDS
    x = _pad_to(x.astype(jnp.float32), (bm, bk))
    w = _pad_to(w, (bk // 2 if packed else bk, bn))
    # padded K rows decode to zero-valued weights, so padded (zero)
    # scale groups are harmless
    sw = _pad_to(sw, (groups_per_block, bn))
    sa2 = (jnp.zeros((1, 1), jnp.float32) if sa is None
           else jnp.asarray(sa, jnp.float32).reshape(1, 1))
    mp, kp = x.shape
    np_ = w.shape[1]
    wb = bk // 2 if packed else bk
    out = pl.pallas_call(
        functools.partial(_fused_dequant_kernel, kind=kind, act=act,
                          groups_per_block=groups_per_block),
        grid=(mp // bm, np_ // bn, kp // bk),
        in_specs=[
            pl.BlockSpec((bm, bk), lambda mi, ni, ki: (mi, ki)),
            pl.BlockSpec((wb, bn), lambda mi, ni, ki: (ki, ni)),
            pl.BlockSpec((groups_per_block, bn), sw_index),
            pl.BlockSpec((1, 1), lambda mi, ni, ki: (0, 0)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda mi, ni, ki: (mi, ni)),
        out_shape=jax.ShapeDtypeStruct((mp, np_), jnp.float32),
        interpret=interpret,
    )(x, w, sw, sa2)
    return out[:m, :n]
