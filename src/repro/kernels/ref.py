"""Pure-jnp oracles for the Pallas kernels (no pallas_call anywhere).

Each kernel has a reference that computes the same math with plain jnp
ops; tests sweep shapes/dtypes and assert bit equality (integer/emulation
kernels are exact, so assert_array_equal, not allclose).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.core import fixedpoint as fx, fp16 as fpmod, nibble
from repro.core.ipu import (IPUConfig, NEG_INF_EXP, _shr_i32, accumulate,
                            fp16_inner_product)


def qmm_ref(a: jax.Array, b: jax.Array) -> jax.Array:
    """int8 x int8 -> int32 exact matmul."""
    return jax.lax.dot_general(
        a.astype(jnp.int32), b.astype(jnp.int32),
        (((1,), (0,)), ((), ())), preferred_element_type=jnp.int32)


def pack_int4_ref(w: jax.Array) -> jax.Array:
    """Pack int4 weights (..., K, N) int8 in [-8, 7] -> (..., K//2, N)
    bytes; leading (stacked-block / expert) axes pass through."""
    lo = w[..., 0::2, :].astype(jnp.int32) & 0xF
    hi = w[..., 1::2, :].astype(jnp.int32) & 0xF
    return ((hi << 4) | lo).astype(jnp.int8)


def unpack_int4_ref(packed: jax.Array) -> jax.Array:
    p = packed.astype(jnp.int32)
    lo = ((p & 0xF) ^ 8) - 8
    hi = p >> 4
    k2, n = packed.shape[-2:]
    out = jnp.stack([lo, hi], axis=-2)          # (..., K//2, 2, N)
    return out.reshape(*packed.shape[:-2], 2 * k2, n).astype(jnp.int8)


def pack_u4_ref(codes: jax.Array) -> jax.Array:
    """Pack UNSIGNED 4-bit codes (..., K, N) in [0, 15] -> (..., K//2, N)
    bytes, same (hi << 4) | lo layout as :func:`pack_int4_ref`. Used for
    fp4 (e2m1) bit-field codes, whose high bit is a sign field — the
    int4 unpack's sign extension would corrupt codes >= 8."""
    lo = codes[..., 0::2, :].astype(jnp.int32) & 0xF
    hi = codes[..., 1::2, :].astype(jnp.int32) & 0xF
    return ((hi << 4) | lo).astype(jnp.uint8)


def unpack_u4_ref(packed: jax.Array) -> jax.Array:
    p = packed.astype(jnp.int32)
    lo = p & 0xF
    hi = (p >> 4) & 0xF
    k2, n = packed.shape[-2:]
    out = jnp.stack([lo, hi], axis=-2)
    return out.reshape(*packed.shape[:-2], 2 * k2, n).astype(jnp.uint8)


def fused_qmm_ref(x: jax.Array, w: jax.Array, sw: jax.Array,
                  sa: jax.Array, *, kind: str = "int8") -> jax.Array:
    """Oracle for kernels.fused.fused_qmm: the staged exact-int path —
    static-scale activation quantize, int32 matmul, scale epilogue —
    composed from the already-verified pieces, in the same op order."""
    sa = jnp.asarray(sa, jnp.float32)
    aq = jnp.clip(jnp.round(x.astype(jnp.float32) / sa), -128, 127)
    wq = unpack_int4_ref(w) if kind == "int4_packed" else w
    acc = qmm_ref(aq.astype(jnp.int8), wq)
    return (acc.astype(jnp.float32) * sa
            * sw.reshape(-1)[None, :].astype(jnp.float32))


def fused_dequant_mm_ref(x: jax.Array, w: jax.Array, sw: jax.Array,
                         sa, *, kind: str = "int8",
                         act: str = "none") -> jax.Array:
    """Oracle for kernels.fused.fused_dequant_mm: decode storage to
    f32, broadcast (G, N) scales over their K-groups, f32 matmul."""
    from repro.quant.quantize import FP4_E2M1, FP8_E4M3, fp_decode
    if kind == "int4_packed":
        wf = unpack_int4_ref(w).astype(jnp.float32)
    elif kind == "fp4_packed":
        wf = fp_decode(unpack_u4_ref(w), FP4_E2M1)
    elif kind in ("fp8", "fp4"):
        wf = fp_decode(w, FP8_E4M3 if kind == "fp8" else FP4_E2M1)
    else:
        wf = w.astype(jnp.float32)
    sw = jnp.asarray(sw, jnp.float32)
    if sw.ndim == 1:
        sw = sw.reshape(1, -1)
    k, n = wf.shape
    groups = sw.shape[0]
    wf = (wf.reshape(groups, k // groups, n)
          * sw[:, None, :]).reshape(k, n)
    xf = x.astype(jnp.float32)
    if act != "none":
        sa = jnp.asarray(sa, jnp.float32)
        xf = jnp.clip(jnp.round(xf / sa), -128, 127)
        if act == "qdq":
            xf = xf * sa
    y = jax.lax.dot_general(xf, wf, (((1,), (0,)), ((), ())),
                            preferred_element_type=jnp.float32)
    return y * sa if act == "quant" else y


def mp_matmul_ref(a: jax.Array, b: jax.Array,
                  cfg: IPUConfig = IPUConfig()) -> jax.Array:
    """Oracle for the faithful mpmm kernel: the (already oracle-verified)
    core.ipu inner product, broadcast over (M, N). O(M*N*K) memory — test
    sizes only."""
    a = jnp.asarray(a, jnp.float16)
    b = jnp.asarray(b, jnp.float16)
    return fp16_inner_product(a[:, None, :], jnp.swapaxes(b, 0, 1)[None],
                              cfg)


def mp_matmul_xla(a: jax.Array, b: jax.Array,
                  cfg: IPUConfig = IPUConfig(), *, fused: bool = False
                  ) -> jax.Array:
    """Blocked pure-jnp FP-IP matmul — the same math as the mpmm kernel
    structured as a fori_loop over K-groups with (M, g, N) temporaries.

    ``fused=False``: the paper-faithful nine-plane datapath (bit-exact to
    mp_matmul_ref / core.ipu).
    ``fused=True``: the optimized single-plane mode: full 22-bit mantissa
    products, EHU alignment against the group max, truncation on a
    w_f = min(w, 26)-bit fused datapath
    (aligned_k = T(d_k * 2**(w_f - 22 - shift_k))), group sums entering
    the standard accumulator with pre_shift = 1 + w_f - w.
    """
    a = jnp.asarray(a, jnp.float16)
    b = jnp.asarray(b, jnp.float16)
    m, k = a.shape
    _, n = b.shape
    g = cfg.n
    pad = -k % g
    if pad:
        a = jnp.pad(a, ((0, 0), (0, pad)))
        b = jnp.pad(b, ((0, pad), (0, 0)))
    kp = a.shape[1]
    sa, ea, ma = fpmod.decompose(a, fpmod.FP16)
    sb, eb, mb = fpmod.decompose(b, fpmod.FP16)
    ea = ea.reshape(m, kp // g, g)
    eb = eb.reshape(kp // g, g, n)

    if fused:
        da = (sa * ma).reshape(m, kp // g, g)
        db = (sb * mb).reshape(kp // g, g, n)
    else:
        pa = jnp.stack(nibble.fp16_planes(sa, ma))  # (3, m, kp)
        pb = jnp.stack(nibble.fp16_planes(sb, mb))  # (3, kp, n)
        pa = pa.reshape(3, m, kp // g, g)
        pb = pb.reshape(3, kp // g, g, n)
        pairs = cfg.iteration_pairs()
        it_i = jnp.asarray([p[0] for p in pairs], jnp.int32)
        it_j = jnp.asarray([p[1] for p in pairs], jnp.int32)

    w_f = min(cfg.w, 26)
    pre_fused = 1 + w_f - cfg.w

    def group_body(gi, carry):
        hi, lo, exp_acc = carry
        acc = fx.FX(hi, lo)
        c = (jax.lax.dynamic_index_in_dim(ea, gi, 1, keepdims=False)
             [:, :, None]
             + jax.lax.dynamic_index_in_dim(eb, gi, 0, keepdims=False)
             [None])                                     # (m, g, n)
        mx = jnp.max(c, axis=1)
        shift = mx[:, None, :] - c
        active = shift <= cfg.mask_threshold

        if fused:
            dg = (jax.lax.dynamic_index_in_dim(da, gi, 1, keepdims=False)
                  [:, :, None]
                  * jax.lax.dynamic_index_in_dim(db, gi, 0, keepdims=False)
                  [None])
            rs = shift + (22 - w_f)
            aligned = _shr_i32(dg, jnp.maximum(rs, 0), cfg.rounding)
            aligned = aligned << jnp.clip(-rs, 0, max(w_f - 22, 0))
            aligned = jnp.where(active, aligned, 0)
            s_tree = jnp.sum(aligned, axis=1)
            acc, exp_acc = accumulate(acc, exp_acc, s_tree, mx,
                                      jnp.full_like(mx, pre_fused),
                                      jnp.zeros_like(mx), cfg)
            return acc.hi, acc.lo, exp_acc

        pa_g = jax.lax.dynamic_index_in_dim(pa, gi, 2, keepdims=False)
        pb_g = jax.lax.dynamic_index_in_dim(pb, gi, 1, keepdims=False)

        def iter_body(it, carry2):
            hi2, lo2, exp2 = carry2
            acc2 = fx.FX(hi2, lo2)
            i = it_i[it]
            j = it_j[it]
            na = jax.lax.dynamic_index_in_dim(pa_g, i, 0, keepdims=False)
            nb = jax.lax.dynamic_index_in_dim(pb_g, j, 0, keepdims=False)
            d = na[:, :, None] * nb[None]
            dw = d << (cfg.w - 9)
            aligned = _shr_i32(dw, shift, cfg.rounding)
            aligned = jnp.where(active, aligned, 0)
            s_tree = jnp.sum(aligned, axis=1)
            acc2, exp2 = accumulate(acc2, exp2, s_tree, mx, 4 * (4 - i - j),
                                    jnp.zeros_like(mx), cfg)
            return acc2.hi, acc2.lo, exp2

        return jax.lax.fori_loop(0, len(pairs), iter_body, (acc.hi, acc.lo,
                                                            exp_acc))

    z = jnp.zeros((m, n), jnp.int32)
    e0 = jnp.full((m, n), NEG_INF_EXP, jnp.int32)
    hi, lo, exp_acc = jax.lax.fori_loop(0, kp // g, group_body, (z, z, e0))
    return fx.round_to_fp(fx.FX(hi, lo), exp_acc, cfg.accum_format)


def mp_matmul_fused_ref(a: jax.Array, b: jax.Array,
                        cfg: IPUConfig = IPUConfig()) -> jax.Array:
    """Oracle alias for the fused mpmm mode."""
    return mp_matmul_xla(a, b, cfg, fused=True)
