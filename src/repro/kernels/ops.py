"""Public jit'd wrappers around the Pallas kernels.

``backend`` selection:
  * 'pallas'  — pl.pallas_call. On this CPU container it runs in
    interpret mode (the kernel body executes as traced jnp ops); on TPU
    the same call compiles to Mosaic.
  * 'xla'     — the pure-jnp reference path (ref.py). Identical math;
    used for wall-time measurement on CPU (interpret mode adds
    interpreter overhead that would pollute §Perf numbers) and as the
    oracle in kernel tests.

Quantized matmul wrappers fold per-channel scales in an epilogue, which
is how the deployment path (quant/ + layers/mplinear.py) consumes them.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from repro.core.ipu import IPUConfig
from repro.kernels import mpmm as _mpmm
from repro.kernels import qmm as _qmm
from repro.kernels import ref as _ref

_INTERPRET = True  # no TPU in this container; flipped by launch scripts


def pack_int4(w: jax.Array) -> jax.Array:
    """Pack (K, N) int4-valued int8 weights into (K//2, N) bytes."""
    if w.shape[0] % 2:
        raise ValueError("K must be even to pack nibbles")
    return _ref.pack_int4_ref(w)


def unpack_int4(packed: jax.Array) -> jax.Array:
    return _ref.unpack_int4_ref(packed)


@functools.partial(jax.jit, static_argnames=("backend",))
def int8_matmul(a: jax.Array, b: jax.Array, *, backend: str = "pallas"
                ) -> jax.Array:
    """(M,K) int8 x (K,N) int8 -> (M,N) int32."""
    if backend == "xla":
        return _ref.qmm_ref(a, b)
    return _qmm.qmm(a, b, interpret=_INTERPRET)


@functools.partial(jax.jit, static_argnames=("backend",))
def int4_matmul_packed(a: jax.Array, b_packed: jax.Array, *,
                       backend: str = "pallas") -> jax.Array:
    """(M,K) int8 activations x (K//2,N) packed int4 weights -> int32."""
    if backend == "xla":
        return _ref.qmm_ref(a, _ref.unpack_int4_ref(b_packed))
    return _qmm.qmm_packed(a, b_packed, interpret=_INTERPRET)


@functools.partial(jax.jit, static_argnames=("backend",))
def quantized_matmul(a_q: jax.Array, b_q: jax.Array, scale_a: jax.Array,
                     scale_b: jax.Array, *, backend: str = "pallas"
                     ) -> jax.Array:
    """Dequantizing matmul: int8/int4-valued operands with per-row (M,)
    activation scales and per-column (N,) weight scales -> f32."""
    acc = int8_matmul(a_q, b_q, backend=backend)
    return (acc.astype(jnp.float32)
            * scale_a[:, None].astype(jnp.float32)
            * scale_b[None, :].astype(jnp.float32))


@functools.partial(jax.jit, static_argnames=("cfg", "fused", "backend"))
def mp_matmul(a: jax.Array, b: jax.Array, cfg: IPUConfig = IPUConfig(),
              *, fused: bool = False, backend: str = "pallas"
              ) -> jax.Array:
    """Approximate FP-IP matmul (fidelity path): f16 x f16 -> accum fmt.

    ``fused=False`` is the paper-faithful nine-plane datapath;
    ``fused=True`` the optimized single-plane variant (§Perf)."""
    if backend == "xla":
        return _ref.mp_matmul_xla(a, b, cfg, fused=fused)
    return _mpmm.mp_matmul(a, b, cfg, fused=fused, interpret=_INTERPRET)
