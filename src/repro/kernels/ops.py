"""Public jit'd wrappers around the Pallas kernels.

``backend`` selection:
  * 'pallas'  — pl.pallas_call. On this CPU container it runs in
    interpret mode (the kernel body executes as traced jnp ops); on TPU
    the same call compiles to Mosaic.
  * 'xla'     — the pure-jnp reference path (ref.py). Identical math;
    used for wall-time measurement on CPU (interpret mode adds
    interpreter overhead that would pollute §Perf numbers) and as the
    oracle in kernel tests.

Interpret mode is resolved per call from the ``REPRO_KERNEL_INTERPRET``
environment variable (1/0, true/false; default: interpret everywhere
except on a real TPU backend) and passed down as a jit *static*
argument — no module global to mutate, so launch scripts configure it
through the environment and concurrent callers can't race on it. The
wrappers' own jit caches key on the resolved choice; a caller that
traces these wrappers inside an *outer* jit (e.g. the serving engine's
decode program) bakes the choice in at trace time, so set the
environment before building such programs.

Quantized matmul wrappers fold per-channel scales in an epilogue, which
is how the deployment path (quant/ + layers/mplinear.py) consumes them:
dynamically quantized weights through :func:`quantized_matmul`,
ahead-of-time nibble-packed weights (quant.prepare) through
:func:`quantized_matmul_packed`.
"""
from __future__ import annotations

import functools
import os
from typing import Optional

import jax
import jax.numpy as jnp

from repro.core.ipu import IPUConfig
from repro.kernels import fused as _fused
from repro.kernels import mpmm as _mpmm
from repro.kernels import qmm as _qmm
from repro.kernels import ref as _ref

_TRUE = ("1", "true", "yes", "on")
_FALSE = ("0", "false", "no", "off")


def kernel_interpret() -> bool:
    """Interpret-mode choice for the Pallas kernels, read per call.

    ``REPRO_KERNEL_INTERPRET`` overrides (1/0, true/false); the default
    interprets everywhere except on a real TPU backend. Read at wrapper
    level so it reaches the kernels as a static jit argument (resolved
    at trace time when called from inside an outer jit).
    """
    v = os.environ.get("REPRO_KERNEL_INTERPRET", "").strip().lower()
    if v in _TRUE:
        return True
    if v in _FALSE:
        return False
    return jax.default_backend() != "tpu"


def pack_int4(w: jax.Array) -> jax.Array:
    """Pack (..., K, N) int4-valued int8 weights into (..., K//2, N)
    bytes (two nibbles per byte along the contraction dim)."""
    if w.shape[-2] % 2:
        raise ValueError("K must be even to pack nibbles")
    return _ref.pack_int4_ref(w)


def unpack_int4(packed: jax.Array) -> jax.Array:
    return _ref.unpack_int4_ref(packed)


def pack_u4(codes: jax.Array) -> jax.Array:
    """Pack (..., K, N) UNSIGNED 4-bit codes (fp4 e2m1 bit fields) into
    (..., K//2, N) bytes — same nibble layout as :func:`pack_int4`, but
    unpacking never sign-extends."""
    if codes.shape[-2] % 2:
        raise ValueError("K must be even to pack nibbles")
    return _ref.pack_u4_ref(codes)


def unpack_u4(packed: jax.Array) -> jax.Array:
    return _ref.unpack_u4_ref(packed)


@functools.partial(jax.jit, static_argnames=("backend", "interpret"))
def _int8_matmul(a, b, *, backend: str, interpret: bool):
    if backend == "xla":
        return _ref.qmm_ref(a, b)
    return _qmm.qmm(a, b, interpret=interpret)


def int8_matmul(a: jax.Array, b: jax.Array, *, backend: str = "pallas"
                ) -> jax.Array:
    """(M,K) int8 x (K,N) int8 -> (M,N) int32."""
    return _int8_matmul(a, b, backend=backend, interpret=kernel_interpret())


@functools.partial(jax.jit, static_argnames=("backend", "interpret"))
def _int4_matmul_packed(a, b_packed, *, backend: str, interpret: bool):
    if backend == "xla":
        return _ref.qmm_ref(a, _ref.unpack_int4_ref(b_packed))
    return _qmm.qmm_packed(a, b_packed, interpret=interpret)


def int4_matmul_packed(a: jax.Array, b_packed: jax.Array, *,
                       backend: str = "pallas") -> jax.Array:
    """(M,K) int8 activations x (K//2,N) packed int4 weights -> int32."""
    return _int4_matmul_packed(a, b_packed, backend=backend,
                               interpret=kernel_interpret())


def _scale_epilogue(acc: jax.Array, scale_a: jax.Array,
                    scale_b: jax.Array) -> jax.Array:
    """Fold activation/weight scales into the int32 accumulator.

    ``scale_a`` is either per-row (M,) — dynamic per-token absmax — or a
    0-d scalar: a *calibrated static* activation scale (quant.calibrate)
    rides straight in with no broadcast and no per-row gather."""
    scale_a = jnp.asarray(scale_a, jnp.float32)
    if scale_a.ndim:
        scale_a = scale_a[:, None]
    return (acc.astype(jnp.float32) * scale_a
            * scale_b[None, :].astype(jnp.float32))


def quantized_matmul(a_q: jax.Array, b_q: jax.Array, scale_a: jax.Array,
                     scale_b: jax.Array, *, backend: str = "pallas"
                     ) -> jax.Array:
    """Dequantizing matmul: int8/int4-valued operands with per-row (M,)
    or scalar (static calibrated) activation scales and per-column (N,)
    weight scales -> f32."""
    return _scale_epilogue(int8_matmul(a_q, b_q, backend=backend),
                           scale_a, scale_b)


def quantized_matmul_packed(a_q: jax.Array, b_packed: jax.Array,
                            scale_a: jax.Array, scale_b: jax.Array, *,
                            backend: str = "pallas") -> jax.Array:
    """Dequantizing matmul over prepared nibble-packed weights: same
    epilogue as :func:`quantized_matmul`, so prepared int4 serving is
    bit-exact to the dynamic-quantization path on the same values."""
    return _scale_epilogue(
        int4_matmul_packed(a_q, b_packed, backend=backend),
        scale_a, scale_b)


@functools.partial(jax.jit,
                   static_argnames=("kind", "backend", "interpret"))
def _fused_quantized_matmul(x, w, sw, sa, *, kind: str, backend: str,
                            interpret: bool):
    if backend == "xla":
        return _ref.fused_qmm_ref(x, w, sw, sa, kind=kind)
    return _fused.fused_qmm(x, w, sw, sa, kind=kind, interpret=interpret)


def fused_quantized_matmul(x: jax.Array, w: jax.Array, sw: jax.Array,
                           sa, *, kind: str = "int8",
                           backend: str = "pallas") -> jax.Array:
    """Fused exact-int matmul over STORED operands: f32 activations are
    quantized in-register against the calibrated static scale ``sa``,
    the int32 accumulation runs on int8 rows (``kind='int8'``/``'int4'``)
    or nibble-packed int4 bytes (``'int4_packed'``) unpacked in the VMEM
    block loop, and the per-channel scale epilogue is fused. Bit-exact
    to ``quantize_symmetric(x, 8, scale=sa)`` + ``quantized_matmul`` /
    ``quantized_matmul_packed`` — with no staged operand and no
    materialized int activation tensor."""
    return _fused_quantized_matmul(x, w, sw, sa, kind=kind,
                                   backend=backend,
                                   interpret=kernel_interpret())


@functools.partial(jax.jit,
                   static_argnames=("kind", "act", "backend", "interpret"))
def _fused_dequant_matmul(x, w, sw, sa, *, kind: str, act: str,
                          backend: str, interpret: bool):
    if backend == "xla":
        return _ref.fused_dequant_mm_ref(x, w, sw, sa, kind=kind, act=act)
    return _fused.fused_dequant_mm(x, w, sw, sa, kind=kind, act=act,
                                   interpret=interpret)


def fused_dequant_matmul(x: jax.Array, w: jax.Array, sw: jax.Array,
                         sa=None, *, kind: str = "int8",
                         act: str = "none",
                         backend: str = "pallas") -> jax.Array:
    """General fused dequant matmul: any storage kind (int8/int4/
    int4_packed/fp8/fp4/fp4_packed) with per-channel ((1, N)) or
    per-group ((G, N)) scales decoded + dequantized in-register; the
    optional activation step (``act``: 'none' | 'qdq' fake-quant grid |
    'quant' exact int) fuses against the static scale ``sa``."""
    return _fused_dequant_matmul(x, w, sw, sa, kind=kind, act=act,
                                 backend=backend,
                                 interpret=kernel_interpret())


@functools.partial(jax.jit,
                   static_argnames=("cfg", "fused", "backend", "interpret"))
def _mp_matmul(a, b, cfg, *, fused: bool, backend: str, interpret: bool):
    if backend == "xla":
        return _ref.mp_matmul_xla(a, b, cfg, fused=fused)
    return _mpmm.mp_matmul(a, b, cfg, fused=fused, interpret=interpret)


def mp_matmul(a: jax.Array, b: jax.Array, cfg: IPUConfig = IPUConfig(),
              *, fused: bool = False, backend: str = "pallas"
              ) -> jax.Array:
    """Approximate FP-IP matmul (fidelity path): f16 x f16 -> accum fmt.

    ``fused=False`` is the paper-faithful nine-plane datapath;
    ``fused=True`` the optimized single-plane variant (§Perf)."""
    return _mp_matmul(a, b, cfg, fused=fused, backend=backend,
                      interpret=kernel_interpret())
