"""Deterministic, host-shardable synthetic data pipeline.

Every batch is a pure function of (seed, step, host_slice): restart-safe
(resume at any step reproduces the stream bit-for-bit — required by the
fault-tolerance tests) and shardable across hosts without coordination.

The token stream is a fixed random Markov chain over the vocabulary, so
models can actually *learn* it (examples/train_lm.py shows the loss
dropping toward the chain's conditional entropy), unlike uniform noise.
Modality stubs (frames/patches) are seeded Gaussians.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Dict, Iterator, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import InputShape, ModelConfig


@dataclasses.dataclass(frozen=True)
class DataConfig:
    vocab: int
    seq_len: int
    global_batch: int
    seed: int = 0
    branching: int = 16   # Markov out-degree; entropy ~ log(branching)


def _transition_table(cfg: DataConfig) -> np.ndarray:
    """(vocab, branching) successor table, deterministic from seed."""
    rng = np.random.default_rng(cfg.seed ^ 0x5EED)
    return rng.integers(0, cfg.vocab, (cfg.vocab, cfg.branching),
                        dtype=np.int32)


@functools.partial(jax.jit, static_argnames=("batch", "seq"))
def _gen_walk(table: jax.Array, key: jax.Array, batch: int, seq: int):
    b = table.shape[1]
    k0, k1, k2 = jax.random.split(key, 3)
    start = jax.random.randint(k0, (batch,), 0, table.shape[0])
    choices = jax.random.randint(k1, (batch, seq), 0, b)

    def step(tok, ch):
        nxt = table[tok, ch]
        return nxt, nxt

    _, walk = jax.lax.scan(step, start, choices.T)
    return jnp.concatenate([start[:, None], walk.T], axis=1)  # (B, S+1)


class SyntheticLMDataset:
    """Markov-chain LM batches. ``host_index/host_count`` slice the
    global batch for multi-host pipelines (each host materializes only
    its rows, deterministically)."""

    def __init__(self, cfg: DataConfig, host_index: int = 0,
                 host_count: int = 1):
        assert cfg.global_batch % host_count == 0
        self.cfg = cfg
        self.host_index = host_index
        self.host_count = host_count
        self.local_batch = cfg.global_batch // host_count
        self._table = jnp.asarray(_transition_table(cfg))

    def batch(self, step: int) -> Dict[str, jax.Array]:
        key = jax.random.fold_in(
            jax.random.fold_in(jax.random.PRNGKey(self.cfg.seed), step),
            self.host_index)
        tokens = _gen_walk(self._table, key, self.local_batch,
                           self.cfg.seq_len)
        return {"tokens": tokens}

    def __iter__(self) -> Iterator[Dict[str, jax.Array]]:
        step = 0
        while True:
            yield self.batch(step)
            step += 1

    def conditional_entropy(self) -> float:
        """Nats/token a perfect model converges to (uniform branching)."""
        return float(np.log(self.cfg.branching))


def batch_for(cfg: ModelConfig, shape: InputShape, step: int,
              seed: int = 0, host_index: int = 0, host_count: int = 1
              ) -> Dict[str, jax.Array]:
    """Full batch (tokens + modality stubs) for an (arch, shape) cell."""
    ds = SyntheticLMDataset(
        DataConfig(vocab=cfg.vocab, seq_len=shape.seq_len,
                   global_batch=shape.global_batch, seed=seed),
        host_index, host_count)
    out = dict(ds.batch(step))
    key = jax.random.fold_in(jax.random.PRNGKey(seed + 7), step)
    lb = ds.local_batch
    if cfg.family == "encdec":
        out["frames"] = jax.random.normal(
            key, (lb, shape.seq_len // 4, cfg.frontend_dim), jnp.float32)
    if cfg.family == "vlm":
        out["patches"] = jax.random.normal(
            key, (lb, cfg.n_patches, cfg.vit_dim), jnp.float32)
    return out
