"""Activation sharding constraints (Megatron-style pinning).

XLA SPMD propagation occasionally resolves conflicts catastrophically —
e.g. batch-unsharding the (B, S, V) logits when the head contraction dim
carries the ZeRO 'data' shard, or padding 14 attention heads onto a
16-way 'model' axis. These helpers pin the canonical activation layout:

    tokens/activations: batch over ('pod','data'), features unsharded
    q/k/v:              batch over dp, heads over 'model' iff divisible
    mlp hidden:         batch over dp, d_ff over 'model'
    logits:             batch over dp, vocab over 'model'

They are no-ops outside a mesh context (single-device smoke tests) and
silently drop axes that do not divide the dimension.
"""
from __future__ import annotations

from typing import Optional, Sequence, Tuple, Union

import jax
import numpy as np
from jax.sharding import PartitionSpec as P

DP = "__dp__"        # sentinel: the data-parallel axes ('pod','data')
MDL = "__model__"    # sentinel: the tensor-parallel axis


def _ambient_mesh():
    # Inside shard_map bodies the abstract mesh carries axis types (pod is
    # Manual there — constraints must not name it); otherwise fall back to
    # the `with mesh:` context mesh.
    try:
        am = jax.sharding.get_abstract_mesh()
        if am is not None and am.axis_names:
            return am
    except Exception:
        pass
    try:
        import jax._src.mesh as mesh_lib
        m = mesh_lib.thread_resources.env.physical_mesh
        return None if m.empty else m
    except Exception:  # pragma: no cover - jax internals moved
        return None


def _usable(mesh, name) -> bool:
    if name not in mesh.axis_names:
        return False
    try:
        from jax.sharding import AxisType
        t = dict(zip(mesh.axis_names, mesh.axis_types))[name]
        return t != AxisType.Manual
    except Exception:
        return True


def _resolve(axis, mesh):
    if axis == DP:
        axes = tuple(a for a in ("pod", "data") if _usable(mesh, a))
        return axes if len(axes) > 1 else (axes[0] if axes else None)
    if axis == MDL:
        return "model" if _usable(mesh, "model") else None
    return axis


def _size(mesh, axis) -> int:
    if axis is None:
        return 1
    if isinstance(axis, tuple):
        return int(np.prod([mesh.shape[a] for a in axis]))
    return mesh.shape[axis]


def constrain(x: jax.Array, *axes) -> jax.Array:
    """with_sharding_constraint(x, P(axes...)) with sentinel resolution,
    divisibility checks, and no-op without an ambient mesh."""
    mesh = _ambient_mesh()
    if mesh is None:
        return x
    spec = []
    for dim, ax in zip(x.shape, axes):
        r = _resolve(ax, mesh)
        spec.append(r if r is not None and dim % _size(mesh, r) == 0
                    else None)
    spec += [None] * (x.ndim - len(spec))
    return jax.lax.with_sharding_constraint(x, P(*spec))


def batch_seq(x: jax.Array) -> jax.Array:
    """(B, S, ...) block-boundary activations: batch over dp and, by
    default, sequence over 'model' (Megatron-style sequence parallelism —
    cuts the scan-carry residual memory by the TP degree; attention
    all-gathers the sequence internally). REPRO_SP=0 disables the
    sequence axis for A/B measurements (§Perf)."""
    import os
    if x.ndim >= 2 and os.environ.get("REPRO_SP", "1") == "1":
        return constrain(x, DP, MDL)
    return constrain(x, DP)


def heads(x: jax.Array) -> jax.Array:
    """(B, S, H, D): batch over dp, heads over model iff divisible."""
    return constrain(x, DP, None, MDL, None)


def ffn_hidden(x: jax.Array) -> jax.Array:
    """(B, S, F): batch over dp, d_ff over model."""
    return constrain(x, DP, None, MDL)


def logits(x: jax.Array) -> jax.Array:
    """(B, S, V) or (B, V): batch over dp, vocab over model."""
    if x.ndim == 3:
        return constrain(x, DP, None, MDL)
    return constrain(x, DP, MDL)


def expert_parallel(x: jax.Array) -> jax.Array:
    """(E, C, d) MoE expert-major activations: experts over model."""
    return constrain(x, MDL, DP, None)
