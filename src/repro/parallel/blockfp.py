"""Bounded-alignment block-FP compressed collectives (beyond-paper).

The paper's core empirical insight — exponent differences within a group
of FP values are almost always small (Fig. 9) — applied to the roofline's
*collective* term: gradients are quantized per block of 256 values to a
shared max exponent + w-bit aligned mantissas (the exact arithmetic of
the IPU's EHU + local shift path, reused from core/), all-reduced as
int8, and dequantized. Cross-pod (DCI) gradient traffic drops ~4x for
w=8 vs f32.

Semantics: the compressed all-reduce sums *quantized* values, so the
result equals psum(Q(g)) — an unbiased-ish approximation whose error is
bounded exactly like Theorem 1 (each value's truncation < 1 ULP of the
block scale 2^(max_e - w + 1)). ``make_compressed_grad_step`` wires this
into the train step as a shard_map over the 'pod' axis: within a pod the
usual SPMD program computes *pod-local* gradients; the explicit pod
all-reduce is the compressed exchange.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.core import fp16 as fpmod

BLOCK = 256


def blockfp_quantize(x: jax.Array, w: int = 8, block: int = BLOCK):
    """-> (mant int8, exp int8, orig_len). Per-block shared max exponent
    (EHU stage 1-2), mantissas aligned to it and truncated to w bits
    (local shift + truncate), exactly the IPU alignment datapath."""
    assert 2 <= w <= 8
    flat = x.astype(jnp.float32).ravel()
    n = flat.shape[0]
    pad = -n % block
    if pad:
        flat = jnp.pad(flat, (0, pad))
    blocks = flat.reshape(-1, block)
    _, e, m = fpmod.decompose(blocks, fpmod.FP32)  # mag 24 bits
    sign = jnp.where(blocks < 0, -1, 1).astype(jnp.int32)
    max_e = jnp.max(jnp.where(m > 0, e, -(1 << 20)), axis=-1,
                    keepdims=True)
    max_e = jnp.maximum(max_e, fpmod.FP32.min_exp)
    shift = max_e - e
    # keep top (w-1) magnitude bits of the aligned value
    mant = m >> jnp.minimum(shift + (24 - (w - 1)), 31)
    mant = (sign * mant).astype(jnp.int8)
    return mant, max_e[:, 0].astype(jnp.int8), n


def blockfp_dequantize(mant: jax.Array, exp: jax.Array, n: int, w: int,
                       shape, block: int = BLOCK) -> jax.Array:
    scale = jnp.exp2(exp.astype(jnp.float32) - (w - 2))[:, None]
    vals = mant.astype(jnp.float32) * scale
    return vals.ravel()[:n].reshape(shape)


def compressed_psum(x: jax.Array, axis_name: str, w: int = 8) -> jax.Array:
    """Sum of blockfp-quantized values over a shard_map axis.

    Wire-honest: the cross-participant exchange is an all-gather of INT8
    mantissas (plus an int32 per-block exponent max) — a psum would put
    int32 on the wire (int8 sums overflow). For an n-way ring,
    all-gather(int8) moves (n-1)/n * 1B vs all-reduce(f32) 2(n-1)/n * 4B:
    ~8x less DCI traffic; the reduce happens locally after the gather."""
    mant, exp, n = blockfp_quantize(x, w)
    # align block scales across participants (small int32 collective)
    gmax = jax.lax.pmax(exp.astype(jnp.int32), axis_name)
    adj = jnp.minimum(gmax[:, None] - exp.astype(jnp.int32)[:, None], 31)
    mant_al = (mant.astype(jnp.int32) >> adj).astype(jnp.int8)
    gathered = jax.lax.all_gather(mant_al, axis_name)   # int8 on the wire
    total = gathered.astype(jnp.int32).sum(0)
    return blockfp_dequantize(total, gmax.astype(jnp.int8), n, w, x.shape)


def int8_psum(x: jax.Array, axis_name: str) -> jax.Array:
    """Simpler per-tensor int8 compressed sum (absmax scale), same
    wire-honest gather+local-reduce structure."""
    scale = jnp.maximum(jnp.max(jnp.abs(x)), 1e-20) / 127.0
    scale = jax.lax.pmax(scale, axis_name)
    q = jnp.clip(jnp.round(x / scale), -127, 127).astype(jnp.int8)
    gathered = jax.lax.all_gather(q, axis_name)
    return gathered.astype(jnp.int32).sum(0).astype(x.dtype) * scale


def compress_grads(grads, axis_name: str, method: str):
    if method == "blockfp8":
        fn = lambda g: compressed_psum(g.astype(jnp.float32), axis_name, 8)
    elif method == "int8":
        fn = lambda g: int8_psum(g.astype(jnp.float32), axis_name)
    else:
        raise ValueError(method)
    return jax.tree.map(fn, grads)


def make_pod_exchange(mesh: Mesh, grad_shapes, method: str = "blockfp8",
                      fsdp_spec_fn=None):
    """Cross-pod gradient-exchange program (hierarchical DP).

    Deployment shape: each pod runs its own SPMD train program producing
    pod-local gradients (sharded over its data/model axes); this program
    is the explicit DCI exchange between pods — the only cross-pod
    collective. Gradients arrive stacked along a leading pod axis
    (shape (n_pods, ...) sharded P('pod', <fsdp spec>)) and leave
    pod-averaged and pod-replicated.

    ``method``: 'f32' (baseline all-gather exchange), 'int8', 'blockfp8'.
    The compressed variants put INT8 on the DCI wire — the paper's
    bounded-alignment insight applied to the collective roofline term
    (§Perf). A fully-manual shard_map: every mesh axis is manual, so the
    XLA partitioner sees only concrete per-device programs.
    """
    from repro.parallel import sharding as shd

    n_pods = mesh.shape["pod"]
    axis_names = set(mesh.axis_names)

    def leaf_exchange(g):
        g = g / n_pods
        if method == "f32":
            gathered = jax.lax.all_gather(g.astype(jnp.float32), "pod")
            return gathered.sum(0).astype(g.dtype)
        if method == "int8":
            return int8_psum(g.astype(jnp.float32), "pod").astype(g.dtype)
        if method == "blockfp8":
            return compressed_psum(g.astype(jnp.float32), "pod",
                                   8).astype(g.dtype)
        raise ValueError(method)

    def body(grads):
        return jax.tree.map(leaf_exchange, grads)

    def in_spec_of(path, leaf):
        # leading pod axis + the per-pod FSDP/TP sharding of the leaf
        base = (fsdp_spec_fn(path, leaf.shape[1:], mesh) if fsdp_spec_fn
                else shd.param_pspec(path, leaf.shape[1:], mesh))
        return P("pod", *base)

    def out_spec_of(path, leaf):
        base = (fsdp_spec_fn(path, leaf.shape[1:], mesh) if fsdp_spec_fn
                else shd.param_pspec(path, leaf.shape[1:], mesh))
        return P(None, *base)

    flat, treedef = jax.tree_util.tree_flatten_with_path(grad_shapes)
    in_specs = treedef.unflatten(
        [in_spec_of(jax.tree_util.keystr(kp), l) for kp, l in flat])
    out_specs = treedef.unflatten(
        [out_spec_of(jax.tree_util.keystr(kp), l) for kp, l in flat])

    mapped = jax.shard_map(body, mesh=mesh, in_specs=(in_specs,),
                           out_specs=out_specs,
                           axis_names=axis_names, check_vma=False)
    in_sh = jax.tree.map(lambda s: NamedSharding(mesh, s), in_specs)
    out_sh = jax.tree.map(lambda s: NamedSharding(mesh, s), out_specs)
    return jax.jit(mapped, in_shardings=(in_sh,), out_shardings=out_sh), \
        in_sh, out_sh
