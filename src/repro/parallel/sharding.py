"""Sharding rules: parameter/batch/cache PartitionSpecs for every family.

Scheme (DESIGN.md §5): batch shards over the data axes ('pod','data');
tensor parallelism over 'model'; parameters and optimizer state are fully
sharded over BOTH data and model axes (ZeRO-3-style — XLA SPMD inserts
the per-layer all-gathers under the scan); MoE experts shard over 'model'
(expert parallelism) when divisible, else d_ff (TP); KV caches shard
their capacity axis over 'model' (decode_32k memory) and batch over data.

Rules are path + rank driven, validated for divisibility (an axis that
does not divide the dim is dropped rather than relying on uneven
GSPMD padding).
"""
from __future__ import annotations

import re
from typing import Any, Optional, Tuple

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P


def data_axes(mesh: Mesh) -> Tuple[str, ...]:
    return tuple(a for a in ("pod", "data") if a in mesh.axis_names)


def fsdp_axes(mesh: Mesh) -> Tuple[str, ...]:
    """Parameter/optimizer sharding axes: FSDP within a pod only —
    parameters REPLICATE across pods (classic cross-pod DP; the backward
    gradient all-reduce over 'pod' is the DCI collective that
    parallel/blockfp.py compresses)."""
    return tuple(a for a in ("data",) if a in mesh.axis_names)


def _axis_size(mesh: Mesh, axis) -> int:
    if axis is None:
        return 1
    if isinstance(axis, tuple):
        return int(np.prod([mesh.shape[a] for a in axis]))
    return mesh.shape[axis]


def _fit(mesh: Mesh, shape, spec: Tuple) -> P:
    """Drop axes that don't divide their dim; pad spec to rank."""
    spec = (None,) * (len(shape) - len(spec)) + tuple(spec)
    out = []
    for dim, ax in zip(shape, spec):
        out.append(ax if ax is not None and dim % _axis_size(mesh, ax) == 0
                   else None)
    return P(*out)


def param_pspec(path: str, shape, mesh: Mesh) -> P:
    """PartitionSpec for one parameter leaf (path from keystr)."""
    D = fsdp_axes(mesh)
    D = D if len(D) > 1 else (D[0] if D else None)
    M = "model" if "model" in mesh.axis_names else None
    nd = len(shape)

    def fit(*spec):
        return _fit(mesh, shape, spec)

    if re.search(r"embed", path):
        return fit(M, D)
    if re.search(r"lm_head", path):
        return fit(D, M) if nd >= 2 else fit(M)
    # MoE expert stacks: (L, E, d, f) / (L, E, f, d)
    if re.search(r"moe.*(w_gate|w_up|w_down)", path) and nd == 4:
        e = shape[1]
        ep = e % _axis_size(mesh, M) == 0 if M else False
        if re.search(r"w_down", path):
            return fit(None, M, None, D) if ep else fit(None, None, M, D)
        return fit(None, M, D, None) if ep else fit(None, None, D, M)
    if re.search(r"router", path):
        return fit(D, None)
    # attention / rwkv / mlp projections: in -> out
    if re.search(r"(wq|wk|wv|w_r|w_k|w_v|w_g|w_gate|w_up|c_key|c_rec|"
                 r"w_in_rnn|w_in_gate|w_a|w_x|frontend_proj|fc1)", path):
        if path.endswith("['b']") or nd == 1 or (nd == 2 and "blocks" in
                                                 path and shape[0] < 256):
            return fit(M)  # bias on the sharded output dim
        return fit(D, M)
    if re.search(r"(wo|w_down|c_val|w_out|fc2)", path):
        if path.endswith("['b']"):
            return fit(D) if nd == 1 else fit(None, D)
        return fit(M, D)
    if re.search(r"w_lora_a", path):
        return fit(D, None)
    if re.search(r"w_lora_b", path):
        return fit(None, M)
    if re.search(r"\['u'\]", path):
        return fit(M, None)
    if re.search(r"conv_w", path):
        return fit(None, M)
    if re.search(r"(w_bias|conv_b|b_a|b_x|lambda)", path):
        return fit(M)
    # norms, mixing coefficients, scalars: replicated
    return P()


def _tree_with_paths(tree, fn):
    flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
    out = [fn(jax.tree_util.keystr(kp), leaf) for kp, leaf in flat]
    return jax.tree_util.tree_unflatten(treedef, out)


def param_shardings(params_shape, mesh: Mesh):
    """NamedSharding tree matching an (abstract) params pytree."""
    return _tree_with_paths(
        params_shape,
        lambda path, leaf: NamedSharding(
            mesh, param_pspec(path, leaf.shape, mesh)))


def opt_shardings(opt_state_shape, mesh: Mesh):
    """AdamW m/v shard like the params; step is replicated."""
    return _tree_with_paths(
        opt_state_shape,
        lambda path, leaf: NamedSharding(
            mesh,
            P() if leaf.ndim == 0 else param_pspec(
                re.sub(r"^\[[01]\]", "", path), leaf.shape, mesh)))


def batch_pspec(path: str, shape, mesh: Mesh) -> P:
    D = data_axes(mesh)
    D = D if len(D) > 1 else (D[0] if D else None)
    return _fit(mesh, shape, (D,) + (None,) * (len(shape) - 1))


def batch_shardings(batch_shape, mesh: Mesh):
    return _tree_with_paths(
        batch_shape,
        lambda path, leaf: NamedSharding(
            mesh, batch_pspec(path, leaf.shape, mesh)))


def cache_pspec(path: str, shape, mesh: Mesh) -> P:
    """KV caches: (G, B, C, H, Dh) -> batch over data, capacity over
    model. Recurrent states: (L, B, ...) -> batch over data, feature over
    model. Encoder outputs (B, T, d): batch over data, d over model."""
    D = data_axes(mesh)
    D = D if len(D) > 1 else (D[0] if D else None)
    M = "model" if "model" in mesh.axis_names else None
    nd = len(shape)
    if re.search(r"\.k'?\]|\.v'?\]|\['k'\]|\['v'\]", path) or nd == 5:
        return _fit(mesh, shape, (None, D, M, None, None))
    if nd == 4:   # rglru conv tails (L, B, W-1, dr)
        return _fit(mesh, shape, (None, D, None, M))
    if nd == 3:
        # encdec decode state: ([0]=kv caches, [1]=enc_out (B, T, d))
        if re.fullmatch(r"\[1\]", path):
            return _fit(mesh, shape, (D, None, M))
        # cache pos (G, B, C) / recurrent states (L, B, d)
        return _fit(mesh, shape, (None, D, M))
    if nd == 2:
        return _fit(mesh, shape, (D, M))
    return _fit(mesh, shape, (D,))


def cache_shardings(cache_shape, mesh: Mesh):
    return _tree_with_paths(
        cache_shape,
        lambda path, leaf: NamedSharding(
            mesh, cache_pspec(path, leaf.shape, mesh)))


def replicated(mesh: Mesh):
    return NamedSharding(mesh, P())
