import os
os.environ["XLA_FLAGS"] = (os.environ.get("DRYRUN_EXTRA_XLA", "") +
                           " --xla_force_host_platform_device_count="
                           + os.environ.get("DRYRUN_DEVICES", "512")).strip()

"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

THE FIRST LINES ABOVE MUST STAY FIRST: jax locks the device count on
first init, so the 512 placeholder host devices must be configured
before any jax import (including `from repro...`).

For each cell this driver:
  1. builds the production mesh (16x16 single-pod / 2x16x16 multi-pod),
  2. builds abstract state via jax.eval_shape (no allocation anywhere),
  3. jits the step (train_step / prefill / decode_step) with the
     sharding rules, .lower(...).compile(),
  4. records memory_analysis (fits-per-device proof), cost_analysis
     (FLOPs/bytes), and the parsed collective schedule into a JSON
     roofline record (EXPERIMENTS.md §Dry-run / §Roofline read these).

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch qwen2-0.5b \
      --shape train_4k --mesh single --out results/dryrun
  PYTHONPATH=src python -m repro.launch.dryrun --all --out results/dryrun
"""
import argparse      # noqa: E402
import dataclasses   # noqa: E402
import json          # noqa: E402
import sys           # noqa: E402
import time          # noqa: E402
import traceback     # noqa: E402

import jax           # noqa: E402
import jax.numpy as jnp  # noqa: E402

from repro.configs import ARCH_IDS, get_config          # noqa: E402
from repro.configs.base import SHAPES, shape_applicable  # noqa: E402
from repro.launch import roofline as rl                 # noqa: E402
from repro.launch.mesh import make_production_mesh      # noqa: E402
from repro.launch.train import (TrainConfig, init_state,  # noqa: E402
                                make_train_step)
from repro.models import registry                       # noqa: E402
from repro.parallel import sharding as shd              # noqa: E402


def _mem_analysis_dict(compiled):
    try:
        ma = compiled.memory_analysis()
    except Exception:
        return {}
    out = {}
    for k in ("argument_size_in_bytes", "output_size_in_bytes",
              "temp_size_in_bytes", "generated_code_size_in_bytes",
              "alias_size_in_bytes"):
        v = getattr(ma, k, None)
        if v is not None:
            out[k] = int(v)
    return out


def _cost_analysis_dict(compiled):
    try:
        ca = compiled.cost_analysis()
    except Exception:
        return {}
    if isinstance(ca, (list, tuple)):
        ca = ca[0] if ca else {}
    return {k: float(v) for k, v in dict(ca).items()
            if isinstance(v, (int, float))}


def run_cell(arch: str, shape_name: str, multi_pod: bool,
             compression: str = "none",
             remat: str = "full", microbatches: int = 1,
             moe_dispatch: str = None) -> dict:
    cfg = get_config(arch)
    cfg = dataclasses.replace(cfg, remat=remat)
    if moe_dispatch and cfg.moe:
        cfg = dataclasses.replace(
            cfg, moe=dataclasses.replace(cfg.moe, dispatch=moe_dispatch))
    shape = SHAPES[shape_name]
    mesh_name = "pod2x16x16" if multi_pod else "pod16x16"
    rec = {"arch": arch, "shape": shape_name, "mesh": mesh_name,
           "kind": shape.kind, "status": "skipped"}
    if not shape_applicable(cfg, shape):
        rec["reason"] = "long_500k needs sub-quadratic attention"
        return rec

    mesh = make_production_mesh(multi_pod=multi_pod)
    chips = mesh.devices.size
    api = registry.build(cfg)
    batch_shape = registry.input_specs(cfg, shape)
    t0 = time.time()

    with mesh:
        if shape.kind == "train":
            tc = TrainConfig(compression=compression,
                             microbatches=microbatches)
            step, st_shard, b_shard = make_train_step(
                api, mesh, tc, batch_shape=batch_shape, donate=True)
            state_shape = jax.eval_shape(
                lambda k: init_state(api, k), jax.random.PRNGKey(0))
            lowered = step.lower(state_shape, batch_shape)
        elif shape.kind == "prefill":
            param_shape = jax.eval_shape(api.init, jax.random.PRNGKey(0))
            p_shard = shd.param_shardings(param_shape, mesh)
            cache_shape = jax.eval_shape(
                lambda: api.init_cache(shape.global_batch, shape.seq_len))
            c_shard = shd.cache_shardings(cache_shape, mesh)
            b_shard = shd.batch_shardings(batch_shape, mesh)
            # encdec prefill returns (logits, (caches, enc_out)): pin
            # only the cache part of the state for that family.
            out_state = c_shard if cfg.family != "encdec" \
                else (c_shard, None)
            fn = jax.jit(lambda p, b, c: api.prefill(p, b, c),
                         in_shardings=(p_shard, b_shard, c_shard),
                         out_shardings=(None, out_state),
                         donate_argnums=(2,))
            lowered = fn.lower(param_shape, batch_shape, cache_shape)
        else:  # decode
            param_shape = jax.eval_shape(api.init, jax.random.PRNGKey(0))
            p_shard = shd.param_shardings(param_shape, mesh)
            cache_shape = jax.eval_shape(
                lambda: api.init_cache(shape.global_batch, shape.seq_len))
            if cfg.family == "encdec":
                enc_shape = jax.ShapeDtypeStruct(
                    (shape.global_batch, shape.seq_len // 4, cfg.d_model),
                    jnp.bfloat16)
                cache_shape = (cache_shape, enc_shape)
            c_shard = shd.cache_shardings(cache_shape, mesh)
            b_shard = shd.batch_shardings(batch_shape, mesh)
            fn = jax.jit(lambda p, b, c: api.decode_step(p, b, c),
                         in_shardings=(p_shard, b_shard, c_shard),
                         out_shardings=(None, c_shard),
                         donate_argnums=(2,))
            lowered = fn.lower(param_shape, batch_shape, cache_shape)

        t_lower = time.time() - t0
        compiled = lowered.compile()
        t_compile = time.time() - t0 - t_lower

    cost = _cost_analysis_dict(compiled)
    mem = _mem_analysis_dict(compiled)
    hlo = compiled.as_text()
    terms = rl.terms_from_compiled(arch, shape, mesh_name, chips, cost,
                                   hlo, cfg)
    coll = rl.parse_collectives(hlo, default_group=chips)
    rec.update({
        "status": "ok",
        "chips": chips,
        "lower_s": round(t_lower, 2),
        "compile_s": round(t_compile, 2),
        "memory": mem,
        "cost": {k: cost[k] for k in sorted(cost) if k in
                 ("flops", "bytes accessed", "transcendentals",
                  "utilization")},
        "collectives": {"per_chip_link_bytes": coll.total_bytes,
                        "count": coll.count, "by_op": coll.by_op},
        "roofline": terms.to_dict(),
        "params": cfg.params_count(),
        "active_params": cfg.active_params_count(),
    })
    # fits-per-device proof: argument+temp bytes under 16 GB HBM
    if mem.get("temp_size_in_bytes") is not None:
        per_dev = (mem.get("argument_size_in_bytes", 0)
                   + mem.get("temp_size_in_bytes", 0)
                   + mem.get("output_size_in_bytes", 0)
                   - mem.get("alias_size_in_bytes", 0))
        rec["per_device_bytes"] = int(per_dev)
        rec["fits_16gb"] = bool(per_dev < 16e9)
    return rec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--mesh", default="single",
                    choices=["single", "multi", "both"])
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--out", default="results/dryrun")
    ap.add_argument("--compression", default="none")
    ap.add_argument("--remat", default="full")
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--moe-dispatch", default=None)
    ap.add_argument("--recommended", action="store_true",
                    help="apply the per-cell production config "
                         "(launch/cell_configs.py) instead of the "
                         "paper-faithful baseline settings")
    args = ap.parse_args()

    archs = list(ARCH_IDS) if (args.all or args.arch is None) \
        else [args.arch]
    shapes = list(SHAPES) if (args.all or args.shape is None) \
        else [args.shape]
    meshes = {"single": [False], "multi": [True],
              "both": [False, True]}[args.mesh]

    os.makedirs(args.out, exist_ok=True)
    failures = 0
    for arch in archs:
        for shape in shapes:
            for multi in meshes:
                mb, md = args.microbatches, args.moe_dispatch
                if args.recommended:
                    from repro.launch.cell_configs import recommended
                    cc = recommended(arch, shape)
                    mb = max(mb, cc.microbatches)
                    md = md or cc.moe_dispatch
                tag = f"{arch}__{shape}__{'multi' if multi else 'single'}"
                if args.compression != "none":
                    tag += f"__{args.compression}"
                if args.remat != "full":
                    tag += f"__remat-{args.remat}"
                if mb > 1:
                    tag += f"__mb{mb}"
                if md:
                    tag += f"__{md}"
                path = os.path.join(args.out, tag + ".json")
                try:
                    rec = run_cell(arch, shape, multi,
                                   compression=args.compression,
                                   remat=args.remat,
                                   microbatches=mb,
                                   moe_dispatch=md)
                except Exception as e:  # noqa: BLE001
                    rec = {"arch": arch, "shape": shape,
                           "mesh": "multi" if multi else "single",
                           "status": "error", "error": repr(e),
                           "traceback": traceback.format_exc()[-4000:]}
                    failures += 1
                with open(path, "w") as f:
                    json.dump(rec, f, indent=1)
                status = rec["status"]
                extra = ""
                if status == "ok":
                    r = rec["roofline"]
                    extra = (f" bottleneck={r['bottleneck']}"
                             f" frac={r['roofline_fraction']:.3f}"
                             f" compile={rec['compile_s']:.0f}s")
                elif status == "error":
                    extra = " " + rec["error"][:120]
                print(f"[dryrun] {tag}: {status}{extra}", flush=True)
    sys.exit(1 if failures else 0)


if __name__ == "__main__":
    main()
