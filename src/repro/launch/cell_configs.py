"""Recommended production launch configuration per (arch × shape) cell.

Operationalizes the EXPERIMENTS.md §Perf findings: the dry-run baseline
runs every cell with the plain config (the paper-faithful reference);
these overrides are the measured-best settings that make every cell fit
16 GB/device and hit its best roofline terms. Consumed by
``dryrun.py --recommended`` and by deployment launch scripts.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Optional, Tuple


@dataclasses.dataclass(frozen=True)
class CellConfig:
    microbatches: int = 1
    moe_dispatch: Optional[str] = None   # None = arch default ('einsum')
    remat: str = "full"
    note: str = ""


# (arch, shape) -> config.  Cells not listed run the defaults.
RECOMMENDED: Dict[Tuple[str, str], CellConfig] = {
    # §Perf M7: activation peaks scale 1/k with gradient accumulation
    ("gemma2-9b", "train_4k"): CellConfig(
        microbatches=2, note="M7: 16.8 -> 9.2 GB/dev"),
    ("mixtral-8x7b", "train_4k"): CellConfig(
        microbatches=4, note="M7: 26.5 -> 9.8 GB/dev"),
    ("recurrentgemma-9b", "train_4k"): CellConfig(
        microbatches=4, note="M7: 22.7 -> 9.0 GB/dev"),
    ("stablelm-12b", "train_4k"): CellConfig(
        microbatches=2, note="headroom under 16 GB"),
    ("glm4-9b", "train_4k"): CellConfig(
        microbatches=2, note="headroom under 16 GB"),
    # §Perf C1-C3: gather dispatch removes the one-hot dispatch FLOPs
    ("qwen3-moe-30b-a3b", "prefill_32k"): CellConfig(
        moe_dispatch="gather",
        note="C1: compute 65 -> 8.4 ms, 17.8 -> 10.4 GB/dev"),
    ("qwen3-moe-30b-a3b", "decode_32k"): CellConfig(
        moe_dispatch="gather", note="C1 applies to decode as well"),
    ("qwen3-moe-30b-a3b", "train_4k"): CellConfig(
        moe_dispatch="gather", microbatches=4,
        note="C3: 2.1x compute at 12.1 GB/dev"),
}


def recommended(arch: str, shape: str) -> CellConfig:
    return RECOMMENDED.get((arch, shape), CellConfig())
