"""Roofline terms from compiled dry-run artifacts (no hardware needed).

Per the assignment:
    compute term    = HLO_FLOPs / (chips * peak_FLOP/s)
    memory term     = HLO_bytes / (chips * HBM_bw)
    collective term = collective_bytes / (chips * link_bw)

Sources: compiled.cost_analysis() gives per-partition flops/bytes (the
SPMD module is the per-device program — multiply by chips for the global
figure). Collective bytes are parsed from the post-SPMD HLO text: every
all-gather / all-reduce / reduce-scatter / all-to-all / collective-permute
operand, weighted by the ring traffic factor of its replica-group size.

Hardware constants (TPU v5e-class, per assignment): 197 TFLOP/s bf16,
819 GB/s HBM, ~50 GB/s/link ICI.
"""
from __future__ import annotations

import dataclasses
import math
import re
from typing import Dict, List, Optional, Tuple

PEAK_FLOPS = 197e12      # bf16 per chip
HBM_BW = 819e9           # bytes/s per chip
LINK_BW = 50e9           # bytes/s per ICI link

_DTYPE_BYTES = {
    "pred": 1, "s2": 1, "s4": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2,
    "s32": 4, "u32": 4, "s64": 8, "u64": 8,
    "f8e4m3fn": 1, "f8e5m2": 1, "bf16": 2, "f16": 2, "f32": 4, "f64": 8,
    "c64": 8, "c128": 16,
}

_COLL_RE = re.compile(
    r"=\s+(?:\(([^)]*)\)|(\S+))\s+"
    r"(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start|-done)?\(", )
_SHAPE_RE = re.compile(r"(pred|[suf]\d+|bf16|f8e4m3fn|f8e5m2|c64|c128)"
                       r"\[([\d,]*)\]")
_GROUPS_RE = re.compile(r"replica_groups=\{?\{([\d,\s]*)\}")
_GROUPS_IOTA_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")


def _shape_bytes(shape_str: str) -> int:
    total = 0
    for dtype, dims in _SHAPE_RE.findall(shape_str):
        n = 1
        if dims:
            for d in dims.split(","):
                if d:
                    n *= int(d)
        total += n * _DTYPE_BYTES.get(dtype, 4)
    return total


def _group_size(line: str, default: int) -> int:
    m = _GROUPS_IOTA_RE.search(line)
    if m:
        # [num_groups, group_size] iota format
        return int(m.group(2))
    m = _GROUPS_RE.search(line)
    if m:
        ids = [x for x in m.group(1).split(",") if x.strip()]
        return max(len(ids), 1)
    return default


def _ring_factor(op: str, group: int) -> float:
    """Per-chip link traffic as a multiple of the (per-chip) payload,
    assuming ring algorithms: all-reduce moves 2(n-1)/n, gather/scatter
    (n-1)/n, all-to-all (n-1)/n, permute 1."""
    if group <= 1:
        return 0.0
    if op == "all-reduce":
        return 2.0 * (group - 1) / group
    if op == "collective-permute":
        return 1.0
    return (group - 1) / group


@dataclasses.dataclass
class CollectiveStats:
    total_bytes: float = 0.0            # per-chip link bytes
    by_op: Dict[str, float] = dataclasses.field(default_factory=dict)
    count: int = 0

    def add(self, op: str, nbytes: float):
        self.total_bytes += nbytes
        self.by_op[op] = self.by_op.get(op, 0.0) + nbytes
        self.count += 1


def parse_collectives(hlo_text: str, default_group: int) -> CollectiveStats:
    """Sum per-chip link traffic over all collective ops in (post-SPMD,
    per-device) HLO text."""
    stats = CollectiveStats()
    for line in hlo_text.splitlines():
        m = _COLL_RE.search(line)
        if not m:
            continue
        op = m.group(3)
        # operand bytes: shapes inside the call parens (per-device payload)
        call = line[m.end():]
        payload = _shape_bytes(call)
        if op == "all-gather":
            # output = gathered; operand is the per-device shard
            out_shape = m.group(1) or m.group(2) or ""
            payload = _shape_bytes(out_shape) / max(
                _group_size(line, default_group), 1)
        group = _group_size(line, default_group)
        stats.add(op, payload * _ring_factor(op, group))
    return stats


@dataclasses.dataclass
class RooflineTerms:
    arch: str
    shape: str
    mesh: str
    chips: int
    hlo_flops: float            # global (all chips)
    hlo_bytes: float            # global HBM traffic
    collective_bytes: float     # global link traffic
    model_flops: float
    compute_s: float
    memory_s: float
    collective_s: float

    @property
    def bottleneck(self) -> str:
        terms = {"compute": self.compute_s, "memory": self.memory_s,
                 "collective": self.collective_s}
        return max(terms, key=terms.get)

    @property
    def roofline_fraction(self) -> float:
        """Useful-compute time / bound time: how close the dominant term
        lets us get to the MODEL_FLOPS roofline."""
        ideal = self.model_flops / (self.chips * PEAK_FLOPS)
        bound = max(self.compute_s, self.memory_s, self.collective_s)
        return ideal / bound if bound > 0 else 0.0

    @property
    def flops_ratio(self) -> float:
        """MODEL_FLOPS / HLO_FLOPs — useful fraction of compiled compute
        (catches remat/redundancy waste)."""
        return self.model_flops / self.hlo_flops if self.hlo_flops else 0.0

    def to_dict(self) -> Dict:
        return {**dataclasses.asdict(self),
                "bottleneck": self.bottleneck,
                "roofline_fraction": self.roofline_fraction,
                "flops_ratio": self.flops_ratio}


def model_flops(cfg, shape) -> float:
    """MODEL_FLOPS: 6*N*D train (N active params, D tokens); 2*N*D
    prefill; 2*N per decoded token x batch."""
    n = cfg.active_params_count()
    if shape.kind == "train":
        return 6.0 * n * shape.seq_len * shape.global_batch
    if shape.kind == "prefill":
        return 2.0 * n * shape.seq_len * shape.global_batch
    return 2.0 * n * shape.global_batch  # one decode step


def terms_from_compiled(arch: str, shape, mesh_name: str, chips: int,
                        cost: Dict, hlo_text: str, cfg) -> RooflineTerms:
    per_dev_flops = float(cost.get("flops", 0.0))
    per_dev_bytes = float(cost.get("bytes accessed", 0.0))
    coll = parse_collectives(hlo_text, default_group=chips)
    return RooflineTerms(
        arch=arch, shape=shape.name, mesh=mesh_name, chips=chips,
        hlo_flops=per_dev_flops * chips,
        hlo_bytes=per_dev_bytes * chips,
        collective_bytes=coll.total_bytes * chips,
        model_flops=model_flops(cfg, shape),
        compute_s=per_dev_flops / PEAK_FLOPS,
        memory_s=per_dev_bytes / HBM_BW,
        collective_s=coll.total_bytes / LINK_BW,
    )
