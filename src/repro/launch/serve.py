"""Serving: jitted prefill/decode steps + a continuous-batching driver.

serve_prefill / serve_decode are the artifacts the dry-run lowers for the
prefill_32k / decode_32k / long_500k cells. The ServingEngine is a
slot-based continuous-batching driver (used by examples/serve_lm.py):
fixed B decode slots, per-slot positions, join-on-free admission — the
single-host skeleton of the multi-replica serving deployment.
"""
from __future__ import annotations

import dataclasses
import queue
import time
from typing import Any, Callable, Dict, List, NamedTuple, Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh

from repro.configs.base import InputShape, ModelConfig
from repro.core import policy as policy_mod
from repro.models import registry
from repro.parallel import sharding as shd


def make_serve_fns(api: registry.ModelAPI, mesh: Mesh,
                   batch_shape: Dict, cache_len: int, batch_size: int):
    """Returns (jitted prefill, jitted decode, cache shardings)."""
    cache_shape = jax.eval_shape(lambda: api.init_cache(batch_size,
                                                        cache_len))
    cache_shard = shd.cache_shardings(cache_shape, mesh)
    param_shape = jax.eval_shape(api.init, jax.random.PRNGKey(0))
    param_shard = shd.param_shardings(param_shape, mesh)

    prefill_in = {k: v for k, v in batch_shape.items()
                  if k not in ("token", "pos")}
    pf_shard = shd.batch_shardings(prefill_in, mesh) if prefill_in else None

    prefill = jax.jit(
        lambda p, b, c: api.prefill(p, b, c),
        in_shardings=(param_shard, pf_shard, cache_shard),
        donate_argnums=(2,))

    # decode state sharding may differ from cache (encdec carries enc_out)
    def _decode(p, b, c):
        return api.decode_step(p, b, c)

    decode = jax.jit(_decode, in_shardings=(param_shard, None, None),
                     donate_argnums=(2,))
    return prefill, decode, cache_shard, param_shard


@dataclasses.dataclass
class Request:
    rid: int
    prompt: np.ndarray           # (S,) int32
    max_new_tokens: int = 16
    tokens: Optional[List[int]] = None
    done: bool = False


class ServingEngine:
    """Slot-based continuous batching on top of decode_step.

    All slots share one decode program (fixed batch); free slots idle on
    pad tokens. Prefill currently runs per-admission with batch 1 slots
    folded into the shared cache via per-slot positions.
    """

    def __init__(self, cfg: ModelConfig, api: registry.ModelAPI, params,
                 batch_slots: int = 4, cache_len: int = 512,
                 greedy: bool = True):
        self.cfg = cfg
        self.api = api
        self.params = params
        self.b = batch_slots
        self.cache_len = cache_len
        # resolve the serving policy up front: a bad policy name or a
        # missing/invalid plan file fails at engine construction, not on
        # the first decode (plan: refs load repro.autotune artifacts)
        self.policy = policy_mod.get_policy(cfg.precision_policy)
        self.caches = api.init_cache(batch_slots, cache_len)
        self.pos = np.zeros(batch_slots, np.int32)
        self.slot_req: List[Optional[Request]] = [None] * batch_slots
        self.queue: "queue.Queue[Request]" = queue.Queue()
        self.completed: Dict[int, Request] = {}
        self._decode = jax.jit(
            lambda p, tok, pos, c: api.decode_step(
                p, {"token": tok, "pos": pos}, c))

    def routing_report(self) -> Dict[str, str]:
        """Observed (parameter path -> datapath mode) of one decode step
        under the active policy. Traced abstractly (``jax.eval_shape``)
        so it never runs compute or touches the KV caches — the
        verification surface the plan-routing assertion tests use."""
        tok = jnp.zeros((self.b, 1), jnp.int32)
        pos = jnp.zeros((self.b,), jnp.int32)
        with policy_mod.trace_routing() as records:
            jax.eval_shape(
                lambda p, c: self.api.decode_step(
                    p, {"token": tok, "pos": pos}, c),
                self.params, self.caches)
        return dict(records)

    def submit(self, req: Request):
        req.tokens = list(req.prompt.tolist())
        self.queue.put(req)

    def _admit(self):
        for slot in range(self.b):
            if self.slot_req[slot] is None and not self.queue.empty():
                req = self.queue.get()
                self.slot_req[slot] = req
                # feed the prompt token-by-token through decode (teacher
                # forcing); tiny models only — prefill path covers bulk.
                self.pos[slot] = 0
                for t in req.prompt[:-1]:
                    self._step_slot_token(slot, int(t))
                req._next_input = int(req.prompt[-1])

    def _step_slot_token(self, slot: int, token: int) -> int:
        tok = np.zeros((self.b, 1), np.int32)
        tok[slot, 0] = token
        pos = jnp.asarray(self.pos)
        logits, self.caches = self._decode(
            self.params, jnp.asarray(tok), pos, self.caches)
        self.pos[slot] += 1
        return int(np.asarray(jnp.argmax(logits[slot])))

    def step(self):
        """One engine tick: admit + one decode for every active slot."""
        self._admit()
        active = [s for s in range(self.b) if self.slot_req[s] is not None]
        if not active:
            return False
        tok = np.zeros((self.b, 1), np.int32)
        for s in active:
            req = self.slot_req[s]
            tok[s, 0] = req._next_input
        logits, self.caches = self._decode(
            self.params, jnp.asarray(tok), jnp.asarray(self.pos),
            self.caches)
        nxt = np.asarray(jnp.argmax(logits, axis=-1))
        for s in active:
            req = self.slot_req[s]
            self.pos[s] += 1
            req.tokens.append(int(nxt[s]))
            req._next_input = int(nxt[s])
            if len(req.tokens) - len(req.prompt) >= req.max_new_tokens:
                req.done = True
                self.completed[req.rid] = req
                self.slot_req[s] = None
                self.pos[s] = 0
        return True

    def run_until_drained(self, max_ticks: int = 10_000):
        ticks = 0
        while (not self.queue.empty()
               or any(r is not None for r in self.slot_req)):
            self.step()
            ticks += 1
            if ticks > max_ticks:
                raise RuntimeError("engine did not drain")
        return ticks
