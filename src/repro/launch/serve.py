"""Compat shim — the serving runtime lives in :mod:`repro.serving`.

serve_prefill / serve_decode artifacts (``make_serve_fns``) and the
continuous-batching ``ServingEngine`` moved to ``repro.serving.engine``
when serving grew into a subsystem (scheduler, multi-replica router,
metrics). This module re-exports the public names so existing imports
(``from repro.launch.serve import Request, ServingEngine``) keep
working; the configuration surfaces (``EngineConfig`` /
``SamplingParams``) re-export from ``repro.serving.config``.
"""
from repro.serving.config import (EngineConfig,             # noqa: F401
                                  SamplingParams)
from repro.serving.engine import (Request, ServingEngine,   # noqa: F401
                                  make_serve_fns)
