"""Production meshes. Functions, not module constants — importing this
module never touches jax device state (dry-run sets device flags first).

Single pod: 16 x 16 = 256 chips, axes (data, model).
Multi-pod:  2 x 16 x 16 = 512 chips, axes (pod, data, model) — batch
shards over (pod, data); parameters replicate across pods (gradient
all-reduce over the pod axis is the cross-pod DCI collective).
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_test_mesh(*, multi_pod: bool = False):
    """Small mesh for CPU subprocess tests (8 forced host devices)."""
    shape = (2, 2, 2) if multi_pod else (2, 4)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_single_device_mesh():
    return jax.make_mesh((1, 1), ("data", "model"))
