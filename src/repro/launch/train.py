"""Distributed training step + CLI trainer.

make_train_step builds the jitted SPMD step for a mesh: forward + grad +
AdamW + optional dynamic loss scaling, with donated state buffers and
fully sharded params/optimizer. ``compression='blockfp8'`` switches the
cross-pod gradient sync to the bounded-alignment block-FP compressed
all-reduce (parallel/blockfp.py) via a shard_map over the pod axis — the
paper's alignment insight applied to the DCI-bound roofline term.

CLI (single host, small configs):
  PYTHONPATH=src python -m repro.launch.train --arch qwen2-0.5b \
      --reduced --steps 100 --batch 8 --seq 128 --ckpt-dir /tmp/ck
"""
from __future__ import annotations

import argparse
import dataclasses
import functools
import time
from typing import Any, Dict, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs.base import InputShape, ModelConfig
from repro.models import registry
from repro.optim import (AdamWConfig, adamw_init, adamw_update,
                         warmup_cosine)
from repro.optim.loss_scale import (LossScaleState, grads_finite,
                                    loss_scale_init, loss_scale_update)
from repro.parallel import sharding as shd


class TrainState(NamedTuple):
    params: Any
    opt: Any
    loss_scale: LossScaleState
    step: jax.Array


@dataclasses.dataclass(frozen=True)
class TrainConfig:
    adamw: AdamWConfig = AdamWConfig()
    warmup: int = 100
    total_steps: int = 10_000
    use_loss_scaling: bool = False   # fp16-arithmetic policies
    compression: str = "none"        # none | blockfp8 | int8 (pod grads)
    # Gradient accumulation: split the global batch into this many
    # microbatches run through a checkpointed scan — divides activation
    # memory by the count at identical math (grads are exact means).
    microbatches: int = 1


def init_state(api: registry.ModelAPI, key) -> TrainState:
    params = api.init(key)
    return TrainState(params, adamw_init(params), loss_scale_init(),
                      jnp.zeros((), jnp.int32))


def state_shardings(state_shape: TrainState, mesh: Mesh) -> TrainState:
    return TrainState(
        params=shd.param_shardings(state_shape.params, mesh),
        opt=shd.opt_shardings(state_shape.opt, mesh),
        loss_scale=jax.tree.map(lambda _: shd.replicated(mesh),
                                state_shape.loss_scale),
        step=shd.replicated(mesh),
    )


def _grad_once(api, tc: TrainConfig, state: TrainState, batch):
    def scaled_loss(p):
        loss, metrics = api.loss_fn(p, batch)
        return loss * state.loss_scale.scale, (loss, metrics)

    if tc.use_loss_scaling:
        grads, (loss, metrics) = jax.grad(scaled_loss, has_aux=True)(
            state.params)
        inv = 1.0 / state.loss_scale.scale
        grads = jax.tree.map(lambda g: g.astype(jnp.float32) * inv, grads)
    else:
        (loss, metrics), grads = jax.value_and_grad(
            lambda p: api.loss_fn(p, batch), has_aux=True)(state.params)
    return grads, loss, metrics


def _grad_step(api: registry.ModelAPI, tc: TrainConfig, state: TrainState,
               batch):
    if tc.microbatches <= 1:
        return _grad_once(api, tc, state, batch)
    mb = tc.microbatches

    def split(x):
        b = x.shape[0]
        assert b % mb == 0, (b, mb)
        return jnp.moveaxis(x.reshape(mb, b // mb, *x.shape[1:]), 0, 0)

    micro = jax.tree.map(split, batch)

    def mb_step(carry, mbatch):
        g_acc, l_acc = carry
        grads, loss, _ = _grad_once(api, tc, state, mbatch)
        g_acc = jax.tree.map(
            lambda a, g: a + g.astype(jnp.float32) / mb, g_acc, grads)
        return (g_acc, l_acc + loss / mb), None

    g0 = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32),
                      state.params)
    # no checkpoint needed: each scan step runs its own fwd+bwd internally
    (grads, loss), _ = jax.lax.scan(
        mb_step, (g0, jnp.zeros((), jnp.float32)), micro)
    return grads, loss, {"nll": loss, "aux": jnp.zeros(())}


def _apply_updates(api, tc: TrainConfig, state: TrainState, grads, loss,
                   metrics):
    finite = grads_finite(grads)
    lr_scale = warmup_cosine(state.step, warmup=tc.warmup,
                             total=tc.total_steps)
    new_params, new_opt, opt_metrics = adamw_update(
        tc.adamw, state.params, grads, state.opt, lr_scale)
    if tc.use_loss_scaling:
        # skip the update on overflow; adjust the scale
        new_params = jax.tree.map(
            lambda n, o: jnp.where(finite, n, o), new_params, state.params)
        new_opt = jax.tree.map(
            lambda n, o: jnp.where(finite, n, o), new_opt, state.opt)
        new_ls = loss_scale_update(state.loss_scale, finite)
    else:
        new_ls = state.loss_scale
    new_state = TrainState(new_params, new_opt, new_ls, state.step + 1)
    out_metrics = {"loss": loss, "finite": finite.astype(jnp.float32),
                   **{k: v for k, v in metrics.items()},
                   **opt_metrics,
                   "loss_scale": state.loss_scale.scale}
    return new_state, out_metrics


def make_train_step(api: registry.ModelAPI, mesh: Mesh,
                    tc: TrainConfig = TrainConfig(),
                    batch_shape: Optional[Dict] = None,
                    donate: bool = True):
    """Returns (jitted step fn, state_shardings, batch_shardings)."""

    if tc.compression != "none" and "pod" in mesh.axis_names:
        raise NotImplementedError(
            "compressed cross-pod gradient sync is the hierarchical-DP "
            "exchange program: see parallel.blockfp.make_pod_exchange "
            "(benchmarked in tools/exchange_bench.py / §Perf)")

    def step(state: TrainState, batch):
        grads, loss, metrics = _grad_step(api, tc, state, batch)
        return _apply_updates(api, tc, state, grads, loss, metrics)

    state_shape = jax.eval_shape(
        lambda k: init_state(api, k), jax.random.PRNGKey(0))
    st_shard = state_shardings(state_shape, mesh)
    if batch_shape is None:
        batch_shard = None
        in_shardings = (st_shard, None)
    else:
        batch_shard = shd.batch_shardings(batch_shape, mesh)
        in_shardings = (st_shard, batch_shard)
    jitted = jax.jit(step,
                     in_shardings=in_shardings,
                     out_shardings=(st_shard, None),
                     donate_argnums=(0,) if donate else ())
    return jitted, st_shard, batch_shard


# ----------------------------------------------------------------- CLI

def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2-0.5b")
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_ckpt")
    ap.add_argument("--ckpt-every", type=int, default=25)
    ap.add_argument("--policy", default=None)
    ap.add_argument("--lr", type=float, default=3e-4)
    args = ap.parse_args()

    from repro.configs import get_config, reduced
    from repro.data.pipeline import DataConfig, SyntheticLMDataset
    from repro.runtime.fault_tolerance import (FTConfig, FaultTolerantLoop)

    cfg = reduced(args.arch) if args.reduced else get_config(args.arch)
    if args.policy:
        cfg = dataclasses.replace(cfg, precision_policy=args.policy)
    api = registry.build(cfg)
    mesh = jax.make_mesh((1, jax.device_count()), ("data", "model")) \
        if jax.device_count() > 1 else \
        jax.make_mesh((1, 1), ("data", "model"))
    tc = TrainConfig(adamw=AdamWConfig(lr=args.lr),
                     total_steps=args.steps)
    step_fn, st_shard, _ = make_train_step(api, mesh, tc)
    state = init_state(api, jax.random.PRNGKey(0))

    ds = SyntheticLMDataset(DataConfig(
        vocab=cfg.vocab, seq_len=args.seq, global_batch=args.batch))

    loop = FaultTolerantLoop(
        step_fn=lambda s, b: step_fn(s, b),
        batch_fn=ds.batch,
        ckpt_dir=args.ckpt_dir,
        cfg=FTConfig(checkpoint_every=args.ckpt_every),
    )
    t0 = time.time()
    state, step = loop.run(state, 0, args.steps)
    dt = time.time() - t0
    losses = [h["loss"] for h in loop.history]
    print(f"arch={cfg.arch_id} steps={step} time={dt:.1f}s "
          f"loss[0]={losses[0]:.4f} loss[-1]={losses[-1]:.4f} "
          f"markov_entropy={np.log(16):.4f}")


if __name__ == "__main__":
    main()
