"""Ahead-of-time weight preparation: the paper's deployment story.

The accelerator consumes *stored* integer operands — INT4 weights ride
as packed nibbles (halving SRAM/HBM traffic, §2/§3.2) and FP16 is
realized on the same integer datapath — so re-quantizing static weights
on every forward call is pure overhead. ``prepare_params`` walks a param
tree once and, per ``PrecisionSpec``, replaces each projection's fp32
``w`` with a :class:`PreparedWeight` container in its target storage
format:

  * int8       — int8 rows + per-out-channel f32 scales;
  * int4       — nibble-packed bytes (``kernels.ops.pack_int4``) +
                 scales (falls back to int8-storage int4 when the
                 contraction dim is odd);
  * fp16_ipu   — fp16-cast weights;
  * bf16/fp32  — untouched (raw array stays in place).

``PreparedWeight`` is a registered pytree, so prepared trees thread
through ``jax.lax.scan`` over stacked blocks, ``jax.jit`` arguments and
``jax.eval_shape`` exactly like raw params (every data leaf keeps the
stacked leading axes; quantization always reduces over axis -2, the
contraction dim). Dequant-on-demand (:meth:`PreparedWeight.dequant`)
reproduces the dynamic fake-quant forward value bit-exactly — it is the
same ``q * scale`` product on the same ``q``/``scale`` — which is what
makes prepared and dynamic serving equivalent (tests/test_prepare.py).
"""
from __future__ import annotations

import contextlib
import dataclasses
from typing import (Any, Callable, Dict, List, Mapping, Optional,
                    Sequence, Tuple, Union)

import jax
import jax.numpy as jnp

from repro.core.policy import PrecisionPolicy, PrecisionSpec
from repro.quant.quantize import (FP4_E2M1, FP8_E4M3, fp_decode,
                                  fp_quantize, quantize_symmetric)

# storage bytes per weight element by policy mode (scales excluded);
# the table tools/plan_report.py and the serving memory columns use
MODE_BYTES_PER_PARAM = {
    "fp32": 4.0, "bf16": 2.0, "fp16_ipu": 2.0, "int8": 1.0, "int4": 0.5,
    "fp8": 1.0, "fp4": 0.5,
}

# storage kind -> the trace-time staged kind stage_params falls back to
# when the fused executors are not routing the projection
_STAGED_KIND = {
    "int8": "staged8", "int4": "staged4", "int4_packed": "staged4",
    "fp8": "staged_fp8", "fp4": "staged_fp4", "fp4_packed": "staged_fp4",
}
_FP_KINDS = ("fp8", "fp4", "fp4_packed")
_FP_FMT = {"fp8": FP8_E4M3, "fp4": FP4_E2M1, "fp4_packed": FP4_E2M1}


@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class PreparedWeight:
    """One projection weight in its deployment storage format.

    ``kind`` (static): 'int8' | 'int4' (int8-storage nibble values) |
    'int4_packed' (two nibbles per byte along the contraction dim) |
    'fp8' (e4m3 bit-field codes, uint8) | 'fp4' (e2m1 codes in the low
    nibble) | 'fp4_packed' (two e2m1 codes per byte along the
    contraction dim) | 'fp16'. ``data`` carries the stored operand,
    ``scale`` the f32 weight scales (``None`` for fp16): shape
    (..., G, N) with G scale groups along the contraction dim — G == 1
    (the keepdims layout quantize over axis -2 emits) is the
    per-out-channel case, G > 1 splits the contraction dim into equal
    groups (``PrecisionSpec.group_size``). Leading stacked-block axes
    are preserved so scan slices prepared weights exactly like raw
    ones.

    'staged8' / 'staged4' / 'staged_fp8' / 'staged_fp4' are
    *trace-time* kinds (``stage_params``): ``data`` holds the
    compute-dtype dequantized weights a blocked decode program
    materializes ONCE per block and reuses every scan step — the
    fallback datapath when the fused executors are off. They never
    live in engine storage — weight-resident bytes always describe the
    packed/int/fp forms above.

    ``act_scale`` optionally carries the *calibrated static activation
    scale* of the projection (f32 scalar, from ``quant.calibrate``):
    int executors that find one quantize incoming activations with it
    instead of running a per-token absmax reduce.
    """

    data: jax.Array
    scale: Optional[jax.Array] = dataclasses.field(default=None)
    kind: str = dataclasses.field(default="int8",
                                  metadata=dict(static=True))
    act_scale: Optional[jax.Array] = dataclasses.field(default=None)

    @property
    def weight_bits(self) -> Optional[int]:
        return {"int8": 8, "int4": 4, "int4_packed": 4,
                "staged8": 8, "staged4": 4}.get(self.kind)

    @property
    def staged(self) -> bool:
        return self.kind in ("staged8", "staged4",
                             "staged_fp8", "staged_fp4")

    @property
    def scale_groups(self) -> int:
        """Scale groups along the contraction dim (1 = per-channel)."""
        return 1 if self.scale is None else int(self.scale.shape[-2])

    def unpacked(self) -> jax.Array:
        """Stored codes with nibbles unpacked (packed kinds only)."""
        from repro.kernels import ops as kops
        if self.kind == "int4_packed":
            return kops.unpack_int4(self.data)
        if self.kind == "fp4_packed":
            return kops.unpack_u4(self.data)
        return self.data

    def dequant(self) -> jax.Array:
        """f32 weights — bit-exact to the dynamic fake-quant forward
        value for int kinds (same q * scale on the same q, scale)."""
        if self.kind == "fp16" or self.staged:
            return self.data.astype(jnp.float32)
        q = self.unpacked()
        if self.kind in _FP_KINDS:
            vals = fp_decode(q, _FP_FMT[self.kind])
        else:
            vals = q.astype(jnp.float32)
        groups = self.scale_groups
        if groups == 1:
            return vals * self.scale
        k, n = vals.shape[-2:]
        out = (vals.reshape(*vals.shape[:-2], groups, k // groups, n)
               * self.scale[..., :, None, :])
        return out.reshape(vals.shape)

    def nbytes(self) -> int:
        return int(self.data.nbytes
                   + (self.scale.nbytes if self.scale is not None else 0)
                   + (self.act_scale.nbytes
                      if self.act_scale is not None else 0))


def _resolved_groups(k: int, spec: PrecisionSpec) -> int:
    """Scale groups along the contraction dim for ``spec``: per-group
    when ``group_size`` divides K with more than one group, else the
    per-channel fallback (G = 1)."""
    g = getattr(spec, "group_size", None)
    if g and k % g == 0 and k // g > 1:
        return k // g
    return 1


def _quantize_spec(w: jax.Array, spec: PrecisionSpec
                   ) -> Tuple[jax.Array, jax.Array]:
    """Quantize ``w`` (..., K, N) per ``spec`` -> (stored values or
    codes (..., K, N), scales (..., G, N))."""
    wf = w.astype(jnp.float32)
    k, n = w.shape[-2:]
    groups = _resolved_groups(k, spec)
    if groups > 1:
        wf = wf.reshape(*w.shape[:-2], groups, k // groups, n)
    if spec.mode in ("fp8", "fp4"):
        fmt = FP8_E4M3 if spec.mode == "fp8" else FP4_E2M1
        q, s = fp_quantize(wf, fmt, axis=-2)
    else:
        q, s = quantize_symmetric(wf, spec.weight_bits, axis=-2)
    if groups > 1:
        q = q.reshape(*w.shape[:-2], k, n)
        s = jnp.squeeze(s, -2)
    return q, s


def prepare_weight(w: jax.Array, spec: PrecisionSpec,
                   act_scale: Optional[float] = None
                   ) -> Union[jax.Array, "PreparedWeight"]:
    """Prepare ONE weight array (..., d_in, d_out) for ``spec``.

    bf16/fp32 (and already-prepared containers) pass through untouched;
    int modes quantize over axis -2 (scales per out-channel, or per
    K-group when ``spec.group_size`` divides the contraction dim), int4
    and fp4 additionally nibble-pack when the contraction dim is even.
    ``act_scale`` (calibrated static activation scale, int modes only)
    is stored on the container so executors skip the per-token
    activation absmax reduce.
    """
    if isinstance(w, PreparedWeight):
        return w                     # idempotent: preparing twice is a no-op
    if spec.mode in ("bf16", "fp32"):
        return w
    if spec.mode == "fp16_ipu":
        return PreparedWeight(w.astype(jnp.float16), None, "fp16")
    # the act-scale leaf carries the weight's leading stacked-block axes
    # (broadcast) so scan slices prepared trees exactly like raw ones,
    # leaving a 0-d scalar per block
    a = None if act_scale is None else jnp.full(w.shape[:-2], act_scale,
                                                jnp.float32)
    q, s = _quantize_spec(w, spec)
    even_k = w.shape[-2] % 2 == 0
    if spec.mode == "fp8":
        return PreparedWeight(q, s, "fp8", a)
    if spec.mode == "fp4":
        from repro.kernels import ops as kops
        if even_k:
            return PreparedWeight(kops.pack_u4(q), s, "fp4_packed", a)
        return PreparedWeight(q, s, "fp4", a)
    if spec.weight_bits == 4 and even_k:
        from repro.kernels import ops as kops
        return PreparedWeight(kops.pack_int4(q), s, "int4_packed", a)
    return PreparedWeight(q, s,
                          "int8" if spec.weight_bits == 8 else "int4", a)


PathResolver = Union[Callable[[str], Optional[str]], Mapping[str, str]]


def _resolver(paths: PathResolver) -> Callable[[str], Optional[str]]:
    if callable(paths):
        return paths
    return paths.get


def _map_projections(params, resolve: Callable[[str], Optional[str]],
                     fn: Callable[[str, Any], Any]):
    """Rebuild ``params`` with ``fn(container_path, weight)`` applied to
    every projection 'w' leaf ``resolve`` targets — the one tree walk
    preparation and staging share. Untargeted leaves (and containers)
    pass through by reference."""
    def walk(node, prefix: str):
        if isinstance(node, dict):
            out = {}
            for k, v in node.items():
                child = f"{prefix}/{k}" if prefix else k
                if (k == "w" and isinstance(v, (jax.Array, PreparedWeight))
                        and resolve(prefix) is not None):
                    out[k] = fn(prefix, v)
                else:
                    out[k] = walk(v, child)
            return out
        if isinstance(node, (list, tuple)):
            return type(node)(
                walk(v, f"{prefix}/{i}" if prefix else str(i))
                for i, v in enumerate(node))
        return node

    return walk(params, "")


def prepare_params(params, policy: PrecisionPolicy, paths: PathResolver,
                   act_scales: Optional[Mapping[str, float]] = None):
    """Walk ``params`` once and prepare every projection weight.

    ``paths`` maps a param-tree container path (``'blocks/b0/attn/wq'``,
    the dict holding the ``'w'`` leaf) to the policy path the runtime
    passes to ``policy.spec_for`` (``'block/full/attn/wq'``) — or None
    for parameters that never route through the precision policy
    (embeddings, norms, the MoE router, recurrence gates). Families
    provide their map via ``models.registry`` (the ``prepare=`` hook).
    ``act_scales`` (policy path -> calibrated static activation scale,
    from ``quant.calibrate``) rides onto each int container it covers.

    Pure: returns a new tree; raw leaves (and containers whose spec is
    bf16/fp32) are passed through by reference, so preparing twice is a
    structural no-op and mixed policies leave full-precision groups
    untouched.
    """
    resolve = _resolver(paths)

    def prep(prefix: str, w):
        pol_path = resolve(prefix)
        a = act_scales.get(pol_path) if act_scales is not None else None
        return prepare_weight(w, policy.spec_for(pol_path), act_scale=a)

    return _map_projections(params, resolve, prep)


# --------------------------------------------- staged-operand counter

_STAGED_COUNT: Optional[List[int]] = None


@contextlib.contextmanager
def count_staged():
    """Count staged compute-dtype operand materializations traced while
    open: every quantized container ``stage_params`` replaces with a
    'staged*' container bumps it once. The fused-executor datapath
    never stages, so a fused decode program traces zero — the
    serving-smoke contract for the fused fast path."""
    global _STAGED_COUNT
    prev = _STAGED_COUNT
    box = [0]
    _STAGED_COUNT = box
    try:
        yield box
    finally:
        _STAGED_COUNT = prev


def note_staged(n: int = 1):
    """stage_params calls this per staged container; a no-op outside
    count_staged()."""
    if _STAGED_COUNT is not None:
        _STAGED_COUNT[0] += n


def stage_params(params, policy: PrecisionPolicy, paths: PathResolver,
                 compute_dtype=jnp.bfloat16):
    """Stage every fake-quant projection for a multi-step decode block.

    Called INSIDE a jitted block program (``registry.make_block_decode``)
    — the FALLBACK datapath when the fused executors are off: quantized
    containers whose spec runs the fake-quant path (``exact=False``)
    are replaced by 'staged' containers holding
    ``dequant().astype(compute_dtype)`` — the exact array the executor
    would otherwise rebuild from storage on every scan step — and
    bf16-routed raw f32 weights are cast once the same way. Bit-exact by
    construction (the identical value, computed once instead of N
    times); engine storage is untouched because staging only exists in
    the traced program. Exact-kernel and fp16 specs consume storage
    operands directly, so they pass through. The fused executors make
    this materialization unnecessary entirely — ``make_block_decode``
    skips the staging walk when fused (``count_staged`` observes the
    difference).
    """
    resolve = _resolver(paths)

    def stage(prefix: str, w):
        spec = policy.spec_for(resolve(prefix))
        if spec.exact:
            return w
        if isinstance(w, PreparedWeight):
            staged_kind = _STAGED_KIND.get(w.kind)
            if staged_kind is not None and not w.staged:
                note_staged()
                return PreparedWeight(
                    w.dequant().astype(compute_dtype), None,
                    staged_kind, w.act_scale)
            return w
        if spec.mode == "bf16":          # raw weights: one cast per block
            return w.astype(compute_dtype)
        return w

    return _map_projections(params, resolve, stage)


# ---------------------------------------------------------------------------
# pytree <-> manifest: the self-describing checkpoint codec
#
# ``repro.checkpoint`` persists param trees as (structure spec, flat leaf
# list). A PreparedWeight-bearing tree cannot round-trip through the
# template-based restore path (``astype(ref.dtype)`` would destroy packed
# int4 nibbles, and a restarted worker has no template to offer without
# re-running quantize/pack — the work checkpointing exists to skip), so
# the spec below records containers, PreparedWeight kinds and exact leaf
# dtypes explicitly. Leaf ORDER is jax-canonical (sorted dict keys,
# sequence order, dataclass field order with None fields skipped), so the
# same ``arrays.npz`` serves both the spec-based and the ``like``-based
# restore.

def tree_manifest(tree) -> Tuple[Any, list]:
    """Encode ``tree`` into a msgpack-able structure spec + flat leaves.

    Handles dicts, lists, tuples, ``None`` and :class:`PreparedWeight`
    containers; everything else is a leaf. The inverse is
    :func:`tree_from_manifest`.
    """
    leaves: list = []

    def ref(x) -> int:
        leaves.append(x)
        return len(leaves) - 1

    def enc(node):
        if node is None:
            return {"t": "none"}
        if isinstance(node, PreparedWeight):
            return {"t": "prepared", "kind": node.kind,
                    "data": ref(node.data),
                    "scale": None if node.scale is None
                    else ref(node.scale),
                    "act_scale": None if node.act_scale is None
                    else ref(node.act_scale)}
        if isinstance(node, dict):
            # sorted keys: jax.tree_util's dict flattening order, so the
            # leaf list lines up with a tree_flatten of the same tree
            return {"t": "dict",
                    "keys": sorted(node),
                    "items": [enc(node[k]) for k in sorted(node)]}
        if isinstance(node, (list, tuple)):
            return {"t": "list" if isinstance(node, list) else "tuple",
                    "items": [enc(v) for v in node]}
        return {"t": "leaf", "i": ref(node)}

    return enc(tree), leaves


def tree_from_manifest(spec, leaves: Sequence[Any]):
    """Rebuild the tree :func:`tree_manifest` encoded, consuming restored
    leaves (exact dtypes — no template, no cast)."""

    def dec(s):
        t = s["t"]
        if t == "none":
            return None
        if t == "prepared":
            return PreparedWeight(
                leaves[s["data"]],
                None if s["scale"] is None else leaves[s["scale"]],
                s["kind"],
                None if s["act_scale"] is None
                else leaves[s["act_scale"]])
        if t == "dict":
            return {k: dec(v) for k, v in zip(s["keys"], s["items"])}
        if t == "list":
            return [dec(v) for v in s["items"]]
        if t == "tuple":
            return tuple(dec(v) for v in s["items"])
        if t == "leaf":
            return leaves[s["i"]]
        raise ValueError(f"unknown tree-spec node type {t!r}")

    return dec(spec)


def iter_projection_weights(params, paths: PathResolver):
    """Yield (container_path, weight_leaf) for every projection the
    ``paths`` map targets — raw arrays and PreparedWeight alike."""
    resolve = _resolver(paths)

    def walk(node, prefix: str):
        if isinstance(node, dict):
            for k, v in node.items():
                child = f"{prefix}/{k}" if prefix else k
                if (k == "w" and isinstance(v, (jax.Array, PreparedWeight))
                        and resolve(prefix) is not None):
                    yield prefix, v
                else:
                    yield from walk(v, child)
        elif isinstance(node, (list, tuple)):
            for i, v in enumerate(node):
                yield from walk(v, f"{prefix}/{i}" if prefix else str(i))

    yield from walk(params, "")


def _leaf_bytes(leaf: Any) -> int:
    if isinstance(leaf, PreparedWeight):
        return leaf.nbytes()
    nb = getattr(leaf, "nbytes", None)
    return int(nb) if nb is not None else 0


def weight_resident_bytes(params, paths: Optional[PathResolver] = None,
                          by_kind: bool = True) -> Dict[str, Any]:
    """Weight memory actually resident in a param tree.

    Returns ``{'total': bytes over every leaf, 'projections': bytes of
    the policy-routed projection weights (when ``paths`` is given),
    'by_kind': projection bytes per storage kind ('raw' = unprepared
    fp32/bf16 arrays; every PreparedWeight kind — int8, int4_packed,
    fp8, fp4_packed, ... — reports under its own key; scales and act
    scales count toward their container)}`` — the per-replica numbers
    serving metrics and serve_bench report. ``by_kind=False`` omits the
    per-kind breakdown.
    """
    total = sum(_leaf_bytes(lf) for lf in jax.tree.leaves(
        params, is_leaf=lambda x: isinstance(x, PreparedWeight)))
    out: Dict[str, Any] = {"total": int(total)}
    if paths is not None:
        kinds: Dict[str, int] = {}
        proj = 0
        for _, w in iter_projection_weights(params, paths):
            b = _leaf_bytes(w)
            kind = w.kind if isinstance(w, PreparedWeight) else "raw"
            kinds[kind] = kinds.get(kind, 0) + b
            proj += b
        out["projections"] = int(proj)
        if by_kind:
            out["by_kind"] = kinds
    return out
