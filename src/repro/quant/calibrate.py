"""Static activation-scale calibration — the deployment-side fix for
the last per-token overhead of the int datapaths.

Dynamic fake-quant calibrates an absmax per *call*: every int8/int4
projection runs a full activation reduce on every decode token, and the
scale it finds spans a whole prompt in prefill but a single token in
decode, so the two admission paths only agree up to that granularity.
The paper's accelerator instead consumes operands quantized against
*stored* scales — operand preparation, not the MACs, is the overhead
worth engineering away (cf. FlexiBit in PAPERS.md).

``calibrate_act_scales`` runs a short calibration pass — a few prefill
forwards over calibration prompts, or random token batches exactly like
the autotune divergence probe (``registry.materialize_batch``) — with
the :func:`repro.layers.mplinear.collect_act_stats` hook open, and turns
the observed per-projection absmax into symmetric 8-bit scales keyed by
the runtime policy path (``'block/full/attn/wq'``). The resulting dict:

  * attaches to prepared weights (``quant.prepare.prepare_params(...,
    act_scales=...)`` -> ``PreparedWeight.act_scale``), where the int
    executors consume it instead of reducing;
  * serializes into ``repro.autotune`` plan artifacts (``act_scales``
    field), so an offline-searched plan carries its calibration;
  * makes prefill and decode fake-quant numerics identical — a fixed
    rounding grid is elementwise, so quantizing a prompt matrix equals
    quantizing its rows token by token.
"""
from __future__ import annotations

from typing import Dict, Optional, Sequence

# activations always quantize symmetrically to 8 bits in this codebase
# (see layers.mplinear._int_executor); scale = absmax / (2^7 - 1)
ACT_BITS = 8
ACT_QMAX = (1 << (ACT_BITS - 1)) - 1


def scales_from_absmax(absmax: Dict[str, float],
                       pct: float = 1.0) -> Dict[str, float]:
    """Observed per-path absolute maxima -> symmetric 8-bit scales.

    ``pct`` < 1 shrinks the clip range (simple outlier clipping); the
    floor mirrors ``quantize.calibrate_absmax`` so an all-zero
    calibration stream cannot emit a zero scale.
    """
    return {path: max(m * pct, 1e-8) / ACT_QMAX
            for path, m in absmax.items()}


def calibrate_act_scales(cfg, api=None, params=None, *,
                         prompts: Optional[Sequence] = None,
                         n_batches: int = 2, batch: int = 2,
                         seq_len: int = 16, seed: int = 0,
                         pct: float = 1.0) -> Dict[str, float]:
    """Per-projection static activation scales for serving ``cfg``.

    Runs ``n_batches`` prefill forwards — over ``prompts`` (token
    arrays, each run as a single-sequence batch) when given, else over
    random token batches shaped like the autotune probe — through the
    model under its own precision policy (so downstream activations see
    the same quantization noise they will at serve time), recording
    every projection's input absmax via ``collect_act_stats``. Returns
    {policy path -> f32 scale}; feed it to ``prepare_params`` /
    ``ServingEngine(act_calibration=...)``.
    """
    import jax
    import numpy as np

    from repro.configs.base import InputShape
    from repro.layers import mplinear
    from repro.models import registry

    if api is None:
        api = registry.build(cfg)
    if params is None:
        params = api.init(jax.random.PRNGKey(seed))

    with mplinear.collect_act_stats() as absmax:
        if prompts is not None:
            for p in prompts:
                tokens = np.asarray(p, np.int32)[None, :]
                caches = api.init_cache(1, tokens.shape[1])
                api.prefill(params, {"tokens": tokens}, caches)
        else:
            shape = InputShape("calib", seq_len, batch, "prefill")
            for i in range(n_batches):
                cal = registry.materialize_batch(cfg, shape, seed=seed + i)
                caches = api.init_cache(batch, seq_len)
                api.prefill(params, cal, caches)
        # the stats arrive through jax.debug callbacks: make sure every
        # dispatched forward has flushed before reading them
        jax.effects_barrier()
    return scales_from_absmax(absmax, pct=pct)
