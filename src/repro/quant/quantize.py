"""Symmetric per-channel quantization (the software side of INT mode).

The IPU's INT4/INT8 modes consume symmetric two's-complement operands
with per-output-channel weight scales and per-tensor (or per-token)
activation scales — the standard scheme the paper's quantization
references (Jacob et al., Jung et al.) use.

The fp8 (e4m3) / fp4 (e2m1) codecs below extend the same storage story
down the floating-point ladder (FlexiBit's INT8/INT4/FP8/FP4 modes):
weights are scaled so the format's max magnitude covers the channel (or
group) absmax, then encoded to bit-field codes — uint8 per element, fp4
codes nibble-packable like int4. Round-to-nearest-even on the mantissa,
saturating at the format max (e4m3's NaN encodings are never emitted).
``tools/fp_convert.py`` carries an independent numpy reference of the
same codec; tests cross-check the two.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class FPFormat:
    """A small saturating IEEE-style format (no inf/NaN emission)."""

    name: str
    exp_bits: int
    man_bits: int
    bias: int
    max: float          # largest representable magnitude (saturation)

    @property
    def bits(self) -> int:
        return 1 + self.exp_bits + self.man_bits


# OCP 8-bit e4m3: bias 7, max 448 (mantissa 0b111 at top exponent is the
# NaN pattern — saturation keeps codes below it); subnormals at 2^-9.
FP8_E4M3 = FPFormat("fp8", exp_bits=4, man_bits=3, bias=7, max=448.0)
# OCP 4-bit e2m1: bias 1, values {0, .5, 1, 1.5, 2, 3, 4, 6} (+sign);
# all 16 codes are finite.
FP4_E2M1 = FPFormat("fp4", exp_bits=2, man_bits=1, bias=1, max=6.0)

FP_FORMATS = {f.name: f for f in (FP8_E4M3, FP4_E2M1)}


def fp_encode(x: jax.Array, fmt: FPFormat) -> jax.Array:
    """fp32 -> uint8 bit-field codes (sign | exp | mantissa).

    Round-to-nearest-even on the mantissa grid, saturating clip at
    ``fmt.max``; subnormals are exact. fp4 codes occupy the low nibble.
    """
    xf = jnp.asarray(x, jnp.float32)
    sign = jnp.signbit(xf).astype(jnp.int32)
    ax = jnp.clip(jnp.abs(xf), 0.0, fmt.max)
    # frexp: ax = m * 2^e with m in [0.5, 1) -> normalized exponent e-1
    _, e = jnp.frexp(ax)
    en = jnp.maximum(e - 1, 1 - fmt.bias)      # subnormal exponent floor
    step = jnp.exp2((en - fmt.man_bits).astype(jnp.float32))
    q = jnp.round(ax / step).astype(jnp.int32)  # round-half-even
    # mantissa overflow from rounding bumps the exponent (2^(m+1) ->
    # significand 2^m one exponent up); saturation above bounds q
    of = q >= (1 << (fmt.man_bits + 1))
    en = jnp.where(of, en + 1, en)
    q = jnp.where(of, q >> 1, q)
    normal = q >= (1 << fmt.man_bits)
    exp_field = jnp.where(normal, en + fmt.bias, 0)
    man = jnp.where(normal, q - (1 << fmt.man_bits), q)
    code = ((sign << (fmt.exp_bits + fmt.man_bits))
            | (exp_field << fmt.man_bits) | man)
    return code.astype(jnp.uint8)


def fp_decode(codes: jax.Array, fmt: FPFormat) -> jax.Array:
    """uint8 bit-field codes -> fp32 (exact)."""
    c = codes.astype(jnp.int32)
    sign = (c >> (fmt.exp_bits + fmt.man_bits)) & 1
    exp_field = (c >> fmt.man_bits) & ((1 << fmt.exp_bits) - 1)
    man = c & ((1 << fmt.man_bits) - 1)
    normal = exp_field > 0
    sig = jnp.where(normal, man + (1 << fmt.man_bits), man)
    e = jnp.where(normal, exp_field - fmt.bias, 1 - fmt.bias)
    val = sig.astype(jnp.float32) * jnp.exp2(
        (e - fmt.man_bits).astype(jnp.float32))
    return jnp.where(sign == 1, -val, val)


def fp_quantize(x: jax.Array, fmt: FPFormat, axis=None,
                scale: Optional[jax.Array] = None
                ) -> Tuple[jax.Array, jax.Array]:
    """-> (uint8 codes, f32 scale): scale maps the (per-axis) absmax
    onto ``fmt.max``, mirroring :func:`quantize_symmetric`."""
    if scale is None:
        scale = calibrate_absmax(x, axis=axis) / fmt.max
    else:
        scale = jnp.asarray(scale, jnp.float32)
    return fp_encode(x.astype(jnp.float32) / scale, fmt), scale


def fp_dequantize(codes: jax.Array, scale: jax.Array,
                  fmt: FPFormat) -> jax.Array:
    return fp_decode(codes, fmt) * scale


def calibrate_absmax(x: jax.Array, axis=None, pct: float = 1.0) -> jax.Array:
    """Symmetric scale from the (clipped) absolute maximum."""
    a = jnp.abs(x.astype(jnp.float32))
    if pct >= 1.0:
        m = jnp.max(a, axis=axis, keepdims=axis is not None)
    else:
        m = jnp.quantile(a, pct, axis=axis, keepdims=axis is not None)
    return jnp.maximum(m, 1e-8)


def quantize_symmetric(x: jax.Array, bits: int, axis=None,
                       scale: Optional[jax.Array] = None
                       ) -> Tuple[jax.Array, jax.Array]:
    """-> (q int8-storage in [-2^(b-1), 2^(b-1)-1], scale f32)."""
    qmax = (1 << (bits - 1)) - 1
    if scale is None:
        scale = calibrate_absmax(x, axis=axis) / qmax
    else:
        scale = jnp.asarray(scale, jnp.float32)
    q = jnp.clip(jnp.round(x.astype(jnp.float32) / scale), -qmax - 1, qmax)
    return q.astype(jnp.int8), scale.astype(jnp.float32)


def dequantize(q: jax.Array, scale: jax.Array) -> jax.Array:
    return q.astype(jnp.float32) * scale


def fake_quant(x: jax.Array, bits: int, axis=None,
               scale: Optional[jax.Array] = None) -> jax.Array:
    """Quantize-dequantize with a straight-through estimator.

    Forward: the value the INT datapath would compute (up to the exact
    integer matmul, which is error-free); backward: identity. Keeps the
    matmul on the MXU and shards like a dense op — the at-scale mode.

    With an explicit ``scale`` (calibrated static activation scale) the
    absmax reduce is skipped entirely: the rounding grid is fixed, so
    the result is elementwise and therefore bit-identical whether ``x``
    is a whole prompt matrix or its rows one token at a time — what
    makes calibrated prefill and decode admission numerics agree.
    """
    def qdq(v):
        q, s = quantize_symmetric(v, bits, axis=axis, scale=scale)
        return dequantize(q, s).astype(v.dtype)

    return x + jax.lax.stop_gradient(qdq(x) - x)
