"""Symmetric per-channel quantization (the software side of INT mode).

The IPU's INT4/INT8 modes consume symmetric two's-complement operands
with per-output-channel weight scales and per-tensor (or per-token)
activation scales — the standard scheme the paper's quantization
references (Jacob et al., Jung et al.) use.
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp


def calibrate_absmax(x: jax.Array, axis=None, pct: float = 1.0) -> jax.Array:
    """Symmetric scale from the (clipped) absolute maximum."""
    a = jnp.abs(x.astype(jnp.float32))
    if pct >= 1.0:
        m = jnp.max(a, axis=axis, keepdims=axis is not None)
    else:
        m = jnp.quantile(a, pct, axis=axis, keepdims=axis is not None)
    return jnp.maximum(m, 1e-8)


def quantize_symmetric(x: jax.Array, bits: int, axis=None,
                       scale: Optional[jax.Array] = None
                       ) -> Tuple[jax.Array, jax.Array]:
    """-> (q int8-storage in [-2^(b-1), 2^(b-1)-1], scale f32)."""
    qmax = (1 << (bits - 1)) - 1
    if scale is None:
        scale = calibrate_absmax(x, axis=axis) / qmax
    else:
        scale = jnp.asarray(scale, jnp.float32)
    q = jnp.clip(jnp.round(x.astype(jnp.float32) / scale), -qmax - 1, qmax)
    return q.astype(jnp.int8), scale.astype(jnp.float32)


def dequantize(q: jax.Array, scale: jax.Array) -> jax.Array:
    return q.astype(jnp.float32) * scale


def fake_quant(x: jax.Array, bits: int, axis=None,
               scale: Optional[jax.Array] = None) -> jax.Array:
    """Quantize-dequantize with a straight-through estimator.

    Forward: the value the INT datapath would compute (up to the exact
    integer matmul, which is error-free); backward: identity. Keeps the
    matmul on the MXU and shards like a dense op — the at-scale mode.

    With an explicit ``scale`` (calibrated static activation scale) the
    absmax reduce is skipped entirely: the rounding grid is fixed, so
    the result is elementwise and therefore bit-identical whether ``x``
    is a whole prompt matrix or its rows one token at a time — what
    makes calibrated prefill and decode admission numerics agree.
    """
    def qdq(v):
        q, s = quantize_symmetric(v, bits, axis=axis, scale=scale)
        return dequantize(q, s).astype(v.dtype)

    return x + jax.lax.stop_gradient(qdq(x) - x)
