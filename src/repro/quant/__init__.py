from repro.quant.quantize import (  # noqa: F401
    fake_quant,
    quantize_symmetric,
    dequantize,
    calibrate_absmax,
)
