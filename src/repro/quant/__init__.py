from repro.quant.quantize import (  # noqa: F401
    fake_quant,
    quantize_symmetric,
    dequantize,
    calibrate_absmax,
)
from repro.quant.calibrate import (  # noqa: F401
    ACT_BITS,
    calibrate_act_scales,
    scales_from_absmax,
)
from repro.quant.prepare import (  # noqa: F401
    MODE_BYTES_PER_PARAM,
    PreparedWeight,
    prepare_params,
    prepare_weight,
    weight_resident_bytes,
)
