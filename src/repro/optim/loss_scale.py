"""Dynamic loss scaling for FP16-arithmetic training (paper context:
FP16 weights/activations with FP32 accumulation, Micikevicius et al.).

Scale doubles every ``growth_interval`` clean steps and halves on a
non-finite gradient, whose update is skipped."""
from __future__ import annotations

from typing import Any, NamedTuple, Tuple

import jax
import jax.numpy as jnp


class LossScaleState(NamedTuple):
    scale: jax.Array          # f32
    good_steps: jax.Array     # i32


def loss_scale_init(initial: float = 2.0 ** 15) -> LossScaleState:
    return LossScaleState(jnp.float32(initial), jnp.int32(0))


def grads_finite(grads) -> jax.Array:
    leaves = jax.tree_util.tree_leaves(grads)
    fin = jnp.asarray(True)
    for g in leaves:
        fin = fin & jnp.isfinite(g.astype(jnp.float32)).all()
    return fin


def loss_scale_update(state: LossScaleState, finite: jax.Array,
                      growth_interval: int = 2000,
                      factor: float = 2.0,
                      min_scale: float = 1.0,
                      max_scale: float = 2.0 ** 24
                      ) -> LossScaleState:
    grow = (state.good_steps + 1) >= growth_interval
    new_scale = jnp.where(
        finite,
        jnp.where(grow, jnp.minimum(state.scale * factor, max_scale),
                  state.scale),
        jnp.maximum(state.scale / factor, min_scale))
    new_good = jnp.where(finite & ~grow, state.good_steps + 1, 0)
    return LossScaleState(new_scale, new_good)
