"""AdamW in pure JAX pytrees (no optax dependency).

Optimizer state shards exactly like the parameters (the sharding rules
tree-map over m/v), giving ZeRO-style fully sharded optimizer memory.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: Optional[float] = 1.0


class AdamWState(NamedTuple):
    step: jax.Array
    m: Any
    v: Any


def adamw_init(params) -> AdamWState:
    zeros = jax.tree.map(lambda p: jnp.zeros_like(p, jnp.float32), params)
    return AdamWState(jnp.zeros((), jnp.int32), zeros,
                      jax.tree.map(jnp.copy, zeros))


def global_norm(tree) -> jax.Array:
    leaves = [jnp.sum(jnp.square(g.astype(jnp.float32)))
              for g in jax.tree_util.tree_leaves(tree)]
    return jnp.sqrt(sum(leaves))


def clip_by_global_norm(grads, max_norm: float):
    norm = global_norm(grads)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(norm, 1e-9))
    return jax.tree.map(lambda g: (g.astype(jnp.float32) * scale
                                   ).astype(g.dtype), grads), norm


def adamw_update(cfg: AdamWConfig, params, grads, state: AdamWState,
                 lr_scale: jax.Array = 1.0):
    """Returns (new_params, new_state, metrics)."""
    if cfg.grad_clip is not None:
        grads, gnorm = clip_by_global_norm(grads, cfg.grad_clip)
    else:
        gnorm = global_norm(grads)
    step = state.step + 1
    t = step.astype(jnp.float32)
    bc1 = 1.0 - cfg.b1 ** t
    bc2 = 1.0 - cfg.b2 ** t
    lr = cfg.lr * lr_scale

    def upd(p, g, m, v):
        g = g.astype(jnp.float32)
        m = cfg.b1 * m + (1 - cfg.b1) * g
        v = cfg.b2 * v + (1 - cfg.b2) * jnp.square(g)
        mh = m / bc1
        vh = v / bc2
        p32 = p.astype(jnp.float32)
        p32 = p32 - lr * (mh / (jnp.sqrt(vh) + cfg.eps)
                          + cfg.weight_decay * p32)
        return p32.astype(p.dtype), m, v

    flat_p, treedef = jax.tree_util.tree_flatten(params)
    flat_g = treedef.flatten_up_to(grads)
    flat_m = treedef.flatten_up_to(state.m)
    flat_v = treedef.flatten_up_to(state.v)
    out = [upd(p, g, m, v)
           for p, g, m, v in zip(flat_p, flat_g, flat_m, flat_v)]
    new_p = treedef.unflatten([o[0] for o in out])
    new_m = treedef.unflatten([o[1] for o in out])
    new_v = treedef.unflatten([o[2] for o in out])
    return new_p, AdamWState(step, new_m, new_v), {"grad_norm": gnorm}
