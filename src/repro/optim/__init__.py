from repro.optim.adamw import (  # noqa: F401
    AdamWConfig,
    adamw_init,
    adamw_update,
    clip_by_global_norm,
)
from repro.optim.schedule import warmup_cosine  # noqa: F401
from repro.optim.loss_scale import (  # noqa: F401
    LossScaleState,
    loss_scale_init,
    loss_scale_update,
)
