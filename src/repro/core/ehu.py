"""Exponent Handling Unit (EHU) — paper §2.2 and Fig. 5.

The EHU computes, per FP-IP operation (shared across all nine nibble
iterations, which is how the hardware amortizes it):

  1. element-wise product exponents  c_k = exp(a_k) + exp(b_k)
  2. the maximum product exponent    max_c
  3. alignment shift amounts         s_k = max_c - c_k
  4. software-precision masking      s_k > P  ->  product contributes 0
  5. (MC-IPU only) the multi-cycle service schedule: partition k serves
     products whose shift lies in [k*sp, (k+1)*sp), one partition per
     cycle (Fig. 5's ``serv_i`` bits / threshold walk).

All functions operate on int32 arrays with a trailing reduction axis (the
IPU's n inputs) and are jit/vmap-safe.
"""
from __future__ import annotations

from typing import NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

# Sentinel for "no product" lanes (padding): treated as -inf exponent.
NEG_INF_EXP = -(1 << 20)


class EHUOut(NamedTuple):
    max_exp: jax.Array     # (...,)  max product exponent per group
    shift: jax.Array       # (..., n) alignment shift per product
    active: jax.Array      # (..., n) bool: survives software masking


def product_exponents(exp_a: jax.Array, exp_b: jax.Array,
                      valid: Optional[jax.Array] = None) -> jax.Array:
    """Stage 1: element-wise exponent sums; padded lanes get -inf."""
    c = exp_a.astype(jnp.int32) + exp_b.astype(jnp.int32)
    if valid is not None:
        c = jnp.where(valid, c, NEG_INF_EXP)
    return c


def run(exp_a: jax.Array, exp_b: jax.Array, sw_precision: int,
        valid: Optional[jax.Array] = None, axis: int = -1) -> EHUOut:
    """Stages 1-4 of the EHU for one (group of) FP-IP operation(s)."""
    c = product_exponents(exp_a, exp_b, valid)
    max_c = jnp.max(c, axis=axis)
    shift = jnp.expand_dims(max_c, axis) - c
    active = shift <= sw_precision
    if valid is not None:
        active = active & valid
    # All-padding groups: max is NEG_INF_EXP; nothing active.
    return EHUOut(max_c, shift, active)


def partition_index(shift: jax.Array, sp: int) -> jax.Array:
    """MC-IPU partition k for each product: k = shift // sp (paper §3.2)."""
    return shift // sp


def num_cycles(shift: jax.Array, active: jax.Array, sp: int,
               skip_empty: bool = False, axis: int = -1) -> jax.Array:
    """Cycles an MC-IPU needs for one nibble iteration's alignment.

    Fig. 5's threshold walk serves partition k in cycle k, so the faithful
    count is ``max occupied partition + 1`` (empty intermediate partitions
    still burn a cycle). ``skip_empty=True`` models a smarter scheduler
    that skips unoccupied partitions (counts distinct occupied partitions)
    — an optimization knob we ablate in the simulator benches.

    Inactive (masked) products take no service. A group with no active
    products still costs 1 cycle (the adder tree produces a zero).
    """
    k = partition_index(shift, sp)
    k_masked = jnp.where(active, k, -1)
    if not skip_empty:
        cycles = jnp.max(k_masked, axis=axis) + 1
        return jnp.maximum(cycles, 1).astype(jnp.int32)
    # distinct occupied partitions: one-hot over partitions, OR-reduce.
    # Max meaningful partition index is 58 // sp.
    kmax = 58 // sp + 1
    ks = jnp.arange(kmax, dtype=jnp.int32)
    occupied = jnp.any(
        jnp.expand_dims(k_masked, -1) == ks, axis=axis
    )  # (..., kmax)
    cycles = jnp.sum(occupied, axis=-1).astype(jnp.int32)
    return jnp.maximum(cycles, 1)


def service_schedule(shift: jax.Array, active: jax.Array, sp: int
                     ) -> Tuple[jax.Array, jax.Array]:
    """Per-product (cycle_index, local_shift) under the MC-IPU schedule.

    cycle_index = partition k (served in cycle k); local_shift = shift
    remainder within the partition, guaranteed < sp <= w - 9, hence exact
    by Proposition 1. Masked products get cycle_index = -1.
    """
    k = partition_index(shift, sp)
    local = shift - k * sp
    cycle = jnp.where(active, k, -1)
    return cycle.astype(jnp.int32), local.astype(jnp.int32)
