"""Cycle-accurate performance model of MC-IPU convolution tiles (§4.1).

Models the paper's simulator: given a convolution workload, a tile
configuration (unrolls, cluster size, IPU precision) and the *statistics
of operand exponents*, compute execution cycles.

Mechanics modelled:
  * nibble iterations per inner-product group (INT: product of operand
    nibble counts; FP16: 9),
  * MC-IPU multi-cycle alignment: per group the EHU schedule is shared by
    all nine nibble iterations, so a group costing k cycles of alignment
    costs 9*k total (paper §3.2),
  * intra-tile clustering (§3.3): IPUs in a cluster stall together; the
    tile's clusters run independently (local buffers), so tile time is
    the max over clusters of their summed cycles. ``cluster_size=None``
    means the whole tile is one cluster (no clustering, the worst case).
  * empty-partition skipping (Fig. 5 threshold walk vs. an optimized
    scheduler) as an ablation flag.

The exponent statistics are sampled: activation/weight values are drawn
from a distribution (synthetic Laplace/Normal/uniform, as the paper uses)
or from empirical tensors; product exponent differences within each group
drive the per-group cycle counts. Everything is vectorized numpy.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Dict, Iterable, List, Optional, Sequence, Tuple

import numpy as np

from repro.core.workloads import ConvLayer


# --------------------------------------------------------------- operands

@dataclasses.dataclass(frozen=True)
class OperandTypes:
    """Workload datatype: integer bits or an FP format, per operand.

    FP operand ``bits`` are *significand magnitude* bits (hidden bit
    included) — the width the nibble-serial datapath actually iterates
    over: 12 for FP16 (sign-magnitude mantissa + round bits, paper
    §2.1), 4 for fp8 e4m3 (1 hidden + 3 mantissa), 2 for fp4 e2m1.
    Any fp operand engages the exponent-alignment machinery (EHU +
    shifters), narrower significands just take fewer iterations."""

    a_kind: str = "int"   # 'int' | 'fp16' | 'fp8' | 'fp4'
    a_bits: int = 4
    b_kind: str = "int"
    b_bits: int = 4

    @property
    def is_fp(self) -> bool:
        return (self.a_kind.startswith("fp")
                or self.b_kind.startswith("fp"))


INT4 = OperandTypes("int", 4, "int", 4)
INT8x4 = OperandTypes("int", 8, "int", 4)
INT8 = OperandTypes("int", 8, "int", 8)
FP16 = OperandTypes("fp16", 12, "fp16", 12)  # 12b signed magnitudes
# fp storage tier (quant/prepare): int8 activations against fp-coded
# weights — the weight side dequantizes through the alignment datapath
FP8 = OperandTypes("int", 8, "fp8", 4)    # e4m3: 4b significand
FP4 = OperandTypes("int", 8, "fp4", 2)    # e2m1: 2b significand


# ------------------------------------------------------------- exp source

class ExponentSource:
    """Samples product exponents for (group, lane) draws.

    ``kind``: 'laplace' | 'normal' | 'uniform' | 'lognormal_wide'
    (backward-path-like) | 'empirical' (values array provided).
    sigma: scale of the value distribution before FP16 cast.
    """

    def __init__(self, kind: str = "laplace", sigma: float = 1.0,
                 values: Optional[np.ndarray] = None,
                 weight_kind: Optional[str] = None,
                 weight_sigma: Optional[float] = None,
                 weight_values: Optional[np.ndarray] = None):
        self.kind = kind
        self.sigma = sigma
        self.values = values
        self.weight_kind = weight_kind or kind
        self.weight_sigma = weight_sigma if weight_sigma is not None else sigma
        self.weight_values = weight_values

    def _draw(self, rng: np.random.Generator, shape, kind, sigma, values):
        if kind == "empirical":
            v = rng.choice(values.ravel(), size=shape)
        elif kind == "laplace":
            v = rng.laplace(0.0, sigma, shape)
        elif kind == "normal":
            v = rng.normal(0.0, sigma, shape)
        elif kind == "uniform":
            v = rng.uniform(-sigma, sigma, shape)
        elif kind == "exp_normal":
            # exponent-controlled: value = sign * 2**N(0, sigma). The
            # forward calibration sigma=1.1 reproduces the paper's Fig.-9
            # tail (<1% of alignments exceed 8) and the ~1.2x multi-cycle
            # factor implied by Table 1 / the +25% TFLOPS headline.
            v = np.exp2(rng.normal(0.0, sigma, shape)) * rng.choice(
                [-1.0, 1.0], shape)
        elif kind == "lognormal_wide":
            # wide dynamic range, resembling backprop error tensors
            v = rng.normal(0.0, 1.0, shape) * np.exp2(
                rng.normal(0.0, 4.0, shape))
        else:
            raise ValueError(kind)
        return v

    def product_exponents(self, rng: np.random.Generator,
                          shape: Tuple[int, ...]) -> np.ndarray:
        """Unbiased exponents of FP16 products a*b for the given shape."""
        a = self._draw(rng, shape, self.kind, self.sigma, self.values)
        b = self._draw(rng, shape, self.weight_kind, self.weight_sigma,
                       self.weight_values)
        return (_fp16_exp(a) + _fp16_exp(b)).astype(np.int32)


def _fp16_exp(v: np.ndarray) -> np.ndarray:
    """Unbiased FP16 exponent of values (0 -> min exp -14). Values beyond
    the FP16 range saturate to the max normal exponent (overflow clamps)."""
    with np.errstate(over="ignore"):
        v16 = np.asarray(np.clip(v, -65504.0, 65504.0), np.float16)
    bits = v16.view(np.uint16)
    e = ((bits >> 10) & 0x1F).astype(np.int32)
    return np.where(e == 0, -14, np.minimum(e, 30) - 15)


FORWARD_SOURCE = ExponentSource("exp_normal", sigma=1.1,
                                weight_kind="exp_normal", weight_sigma=1.1)
BACKWARD_SOURCE = ExponentSource("lognormal_wide", weight_kind="normal",
                                 weight_sigma=0.05)


# ------------------------------------------------------------------ tiles

@dataclasses.dataclass(frozen=True)
class TileConfig:
    """Convolution tile (paper §4.1). Defaults = the 'big' tile.

    (c_unroll, k_unroll, h_unroll, w_unroll) = (C, K, H, Wo) unrolls; the
    small tile is (8, 8, 2, 2). ``adder_w`` is the MC-IPU precision; 38
    reproduces the baselines (single-cycle for any FP16 alignment).
    """

    c_unroll: int = 16
    k_unroll: int = 16
    h_unroll: int = 2
    w_unroll: int = 2
    n_tiles: int = 4
    adder_w: int = 38
    cluster_size: Optional[int] = None   # None -> whole tile in lockstep
    sw_precision: int = 28               # FP32 accumulation default
    skip_empty_partitions: bool = False
    ehu_share: int = 4                   # IPUs per EHU (area model input)
    weight_buf_depth: int = 9            # bytes (paper: depth of 9B)

    @property
    def ipus_per_tile(self) -> int:
        return self.k_unroll * self.h_unroll * self.w_unroll

    @property
    def macs_per_cycle(self) -> int:
        return self.c_unroll * self.ipus_per_tile * self.n_tiles

    @property
    def sp(self) -> int:
        return self.adder_w - 9

    def effective_cluster(self) -> int:
        return self.cluster_size or self.ipus_per_tile


BIG_TILE = TileConfig()
SMALL_TILE = TileConfig(c_unroll=8, k_unroll=8)
BASELINE1 = dataclasses.replace(SMALL_TILE, adder_w=38)
BASELINE2 = dataclasses.replace(BIG_TILE, adder_w=38)


def tile_for(n_inputs: int) -> TileConfig:
    """The paper's tile for an IPU input width (16 -> big, 8 -> small)."""
    if n_inputs not in (8, 16):
        raise ValueError(f"no paper tile with {n_inputs}-input IPUs")
    return BIG_TILE if n_inputs == 16 else SMALL_TILE


# ------------------------------------------------------------- simulation

@dataclasses.dataclass
class LayerStats:
    name: str
    cycles: float
    ideal_cycles: float        # same datapath, alignment always 1 cycle
    groups: int                # inner-product groups per output pass
    passes: int
    iterations_per_group: int
    utilization: float         # MAC array utilization from shape padding
    mc_factor: float           # mean alignment cycles per nibble iteration


def _nibbles(bits: int) -> int:
    return -(-bits // 4)


def iterations_per_group(types: OperandTypes) -> int:
    return _nibbles(types.a_bits) * _nibbles(types.b_bits)


def _group_cycles(exp: np.ndarray, sp: int, sw_precision: int,
                  skip_empty: bool) -> np.ndarray:
    """Alignment cycles per group given product exponents (..., n)."""
    mx = exp.max(axis=-1, keepdims=True)
    shift = mx - exp
    active = shift <= sw_precision
    k = np.where(active, shift // sp, -1)
    if skip_empty:
        kmax = sw_precision // sp + 1
        occ = np.zeros(k.shape[:-1] + (kmax + 1,), bool)
        np.put_along_axis(occ, np.maximum(k, 0), k >= 0, axis=-1)
        cycles = occ.sum(-1)
    else:
        cycles = k.max(axis=-1) + 1
    return np.maximum(cycles, 1)


def simulate_layer(layer: ConvLayer, tile: TileConfig,
                   types: OperandTypes = FP16,
                   source: ExponentSource = FORWARD_SOURCE,
                   rng: Optional[np.random.Generator] = None,
                   n_group_samples: int = 512) -> LayerStats:
    """Cycles to run one conv layer on the tile array."""
    rng = rng or np.random.default_rng(0)
    groups = -(-layer.c // tile.c_unroll) * layer.r * layer.s
    k_passes = -(-layer.k // tile.k_unroll)
    pix_passes = -(-layer.ho // tile.h_unroll) * -(-layer.wo // tile.w_unroll)
    passes = k_passes * pix_passes * layer.count
    # tiles split passes evenly (independent work)
    passes_per_tile = -(-passes // tile.n_tiles)
    iters = iterations_per_group(types)

    util_c = layer.c / (-(-layer.c // tile.c_unroll) * tile.c_unroll)
    util_k = layer.k / (-(-layer.k // tile.k_unroll) * tile.k_unroll)
    util_p = (layer.ho * layer.wo) / (
        pix_passes * tile.h_unroll * tile.w_unroll)
    util = util_c * util_k * util_p

    if not types.is_fp or tile.adder_w >= tile.sw_precision:
        # INT mode (no alignment), or the adder covers the software
        # precision: a plain IPU(w) serves any alignment <= w in one
        # truncating cycle (§3.1/§4.3) — multi-cycling only exists to
        # deliver P > w accurately (§3.2).
        cycles = passes_per_tile * groups * iters
        return LayerStats(layer.name, float(cycles), float(cycles), groups,
                          passes, iters, util, 1.0)

    # FP mode with MC-IPU: sample per-(group, IPU) alignment cycles.
    n_ipus = tile.ipus_per_tile
    csize = tile.effective_cluster()
    n_clusters = max(n_ipus // csize, 1)
    samples = min(n_group_samples, max(passes_per_tile * groups, 1))
    exp = source.product_exponents(
        rng, (samples, n_ipus, tile.c_unroll))
    g_cycles = _group_cycles(exp, tile.sp, tile.sw_precision,
                             tile.skip_empty_partitions)  # (samples, n_ipus)
    # lockstep within a cluster: per-group max over members
    g_cycles = g_cycles.reshape(samples, n_clusters, csize).max(-1)
    # independent clusters: each runs sum over its groups; tile waits for
    # the slowest cluster (infinite local buffers; see DESIGN.md).
    per_cluster_mean = g_cycles.mean(axis=0)  # (n_clusters,)
    mc_factor = float(per_cluster_mean.max())
    total_groups = passes_per_tile * groups
    cycles = total_groups * iters * mc_factor
    ideal = total_groups * iters
    return LayerStats(layer.name, float(cycles), float(ideal), groups,
                      passes, iters, util, mc_factor)


@dataclasses.dataclass
class NetworkStats:
    layers: List[LayerStats]

    @property
    def cycles(self) -> float:
        return sum(l.cycles for l in self.layers)

    @property
    def ideal_cycles(self) -> float:
        return sum(l.ideal_cycles for l in self.layers)

    @property
    def slowdown(self) -> float:
        return self.cycles / self.ideal_cycles

    @property
    def mean_mc_factor(self) -> float:
        return self.slowdown


def simulate_network(layers: Iterable[ConvLayer], tile: TileConfig,
                     types: OperandTypes = FP16,
                     source: ExponentSource = FORWARD_SOURCE,
                     seed: int = 0,
                     n_group_samples: int = 512) -> NetworkStats:
    rng = np.random.default_rng(seed)
    return NetworkStats([
        simulate_layer(l, tile, types, source, rng, n_group_samples)
        for l in layers
    ])


def normalized_exec_time(layers: Sequence[ConvLayer], tile: TileConfig,
                         baseline: TileConfig,
                         types: OperandTypes = FP16,
                         source: ExponentSource = FORWARD_SOURCE,
                         seed: int = 0) -> float:
    """Execution time of ``tile`` normalized to ``baseline`` (Fig. 8)."""
    t = simulate_network(layers, tile, types, source, seed).cycles
    b = simulate_network(layers, baseline, types, source, seed).cycles
    return t / b


def exponent_diff_histogram(source: ExponentSource, n: int = 16,
                            samples: int = 100_000, seed: int = 0,
                            max_diff: int = 59) -> np.ndarray:
    """Distribution of (max_exp - exp) alignment sizes (Fig. 9)."""
    rng = np.random.default_rng(seed)
    exp = source.product_exponents(rng, (samples, n))
    diff = exp.max(-1, keepdims=True) - exp
    hist = np.bincount(diff.ravel().clip(0, max_diff), minlength=max_diff + 1)
    return hist / hist.sum()
