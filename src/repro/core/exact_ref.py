"""Exact Python-integer oracle for the approximate FP-IP operation.

This is a second, independent implementation of the paper's Fig.-2
semantics using arbitrary-precision Python ints — no JAX, no limb tricks,
no f32 detours. The JAX emulation in ``core.ipu`` must agree with this
oracle bit-for-bit (tested in tests/test_ipu_exact.py); agreement of two
independently-written implementations is the correctness argument for the
whole numerics stack.

Also provides the infinitely-precise dot product (``exact_dot``) as a
Fraction, used to *measure* approximation error against Theorem 1.
"""
from __future__ import annotations

from fractions import Fraction
from typing import Iterable, List, Sequence, Tuple

import numpy as np

from repro.core.ipu import IPUConfig

_FMT = {
    "fp16": dict(exp_bits=5, mant=10, bias=15),
    "bf16": dict(exp_bits=8, mant=7, bias=127),
    "fp32": dict(exp_bits=8, mant=23, bias=127),
}


def decompose_fp16(x) -> Tuple[int, int, int]:
    """(sign, unbiased exp, integer magnitude) of a python/np scalar as
    FP16. value = sign * mag * 2**(exp - 10)."""
    bits = int(np.float16(x).view(np.uint16))
    s = 1 - 2 * (bits >> 15)
    e = (bits >> 10) & 0x1F
    m = bits & 0x3FF
    if e == 0x1F:
        raise ValueError("Inf/NaN not supported by the IPU datapath")
    if e == 0:
        return s, -14, m
    return s, e - 15, m | 0x400


def decompose_bf16(x) -> Tuple[int, int, int]:
    """BF16 fields: value = sign * mag * 2**(exp - 7), mag 8 bits."""
    import jax.numpy as jnp
    bits = int(np.asarray(jnp.asarray(float(x), jnp.bfloat16)
                          ).view(np.uint16))
    s = 1 - 2 * (bits >> 15)
    e = (bits >> 7) & 0xFF
    m = bits & 0x7F
    if e == 0xFF:
        raise ValueError("Inf/NaN not supported by the IPU datapath")
    if e == 0:
        return s, -126, m
    return s, e - 127, m | 0x80


def decompose_tf32(x) -> Tuple[int, int, int]:
    """f32 -> TF32 fields (RNE 24->11 bit magnitude).
    value = sign * mag * 2**(exp - 10)."""
    bits = int(np.float32(x).view(np.uint32))
    s = 1 - 2 * (bits >> 31)
    e = (bits >> 23) & 0xFF
    m = bits & 0x7FFFFF
    if e == 0xFF:
        raise ValueError("Inf/NaN not supported by the IPU datapath")
    if e == 0:
        e_u, mag = -126, m
    else:
        e_u, mag = e - 127, m | 0x800000
    q = mag >> 13
    rb = (mag >> 12) & 1
    sticky = (mag & 0xFFF) != 0
    if rb and (sticky or (q & 1)):
        q += 1
    if q >= (1 << 11):
        q >>= 1
        e_u += 1
    return s, e_u, q


def tf32_value(x) -> Fraction:
    s, e, m = decompose_tf32(x)
    return Fraction(s * m) * Fraction(2) ** (e - 10)


def fp16_value(x) -> Fraction:
    s, e, m = decompose_fp16(x)
    return Fraction(s * m) * Fraction(2) ** (e - 10)


def bf16_value(x) -> Fraction:
    s, e, m = decompose_bf16(x)
    return Fraction(s * m) * Fraction(2) ** (e - 7)


def exact_dot(a: Sequence, b: Sequence, operand: str = "fp16") -> Fraction:
    """Infinitely precise sum of FP16/BF16/TF32 products."""
    val = {"fp16": fp16_value, "bf16": bf16_value,
           "tf32": tf32_value}[operand]
    return sum((val(x) * val(y) for x, y in zip(a, b)), Fraction(0))


def _planes(sign: int, mag: int) -> List[int]:
    n2 = sign * ((mag >> 7) & 0xF)
    n1 = sign * ((mag >> 3) & 0xF)
    n0 = sign * ((mag & 0x7) << 1)
    return [n0, n1, n2]


def _planes_bf16(sign: int, mag: int) -> List[int]:
    return [sign * (mag & 0xF), sign * ((mag >> 4) & 0xF)]


def _shr(v: int, s: int, rounding: str) -> int:
    if s <= 0:
        return v << (-s)
    if rounding == "floor":
        return v >> s
    sgn = -1 if v < 0 else 1
    return sgn * (abs(v) >> s)


def round_value_to_fp(sign: int, mag: int, scale_exp: int, fmt: str):
    """RNE-round ``sign * mag * 2**scale_exp`` to fp16/fp32. Exact ints."""
    spec = _FMT[fmt]
    mant, bias = spec["mant"], spec["bias"]
    mt = mant + 1
    min_exp, max_exp = 1 - bias, (1 << spec["exp_bits"]) - 2 - bias
    def out(v):
        if fmt == "fp16":
            return np.float16(v)
        if fmt == "bf16":
            import jax.numpy as jnp
            return np.asarray(jnp.asarray(v, jnp.bfloat16))
        return np.float32(v)

    if mag == 0:
        return out(0.0)
    nb = mag.bit_length() - 1
    e_val = scale_exp + nb
    keep = nb + 1 - mt + max(min_exp - e_val, 0)
    if keep > 0:
        q = mag >> keep
        rb = (mag >> (keep - 1)) & 1
        sticky = (mag & ((1 << (keep - 1)) - 1)) != 0
        if rb and (sticky or (q & 1)):
            q += 1
    else:
        q = mag << (-keep)
    if q >= (1 << mt):
        q >>= 1
        e_val += 1
    e_q = max(e_val, min_exp)
    if e_q > max_exp:
        return out(float("inf") * sign)
    if q < (1 << mant):
        e_field = 0
    else:
        e_field = e_q + bias
    sign_bit = 1 if sign < 0 else 0
    if fmt == "fp16":
        bits = (sign_bit << 15) | (e_field << 10) | (q & ((1 << 10) - 1))
        return np.uint16(bits).view(np.float16)
    if fmt == "bf16":
        import jax.numpy as jnp
        bits = (sign_bit << 15) | (e_field << 7) | (q & ((1 << 7) - 1))
        return np.asarray(np.uint16(bits)).view(jnp.bfloat16)
    bits = (sign_bit << 31) | (e_field << 23) | (q & ((1 << 23) - 1))
    return np.uint32(bits).view(np.float32)


def approx_fp_ip(a: Sequence, b: Sequence, cfg: IPUConfig):
    """Oracle for ipu.fp16_inner_product on 1-D inputs. Returns np scalar."""
    if cfg.operand == "fp16":
        decomp, planes = decompose_fp16, _planes
        a = [np.float16(x) for x in a]
        b = [np.float16(x) for x in b]
    elif cfg.operand == "tf32":
        decomp, planes = decompose_tf32, _planes
        a = [np.float32(x) for x in a]
        b = [np.float32(x) for x in b]
    else:
        decomp, planes = decompose_bf16, _planes_bf16
        a = [float(x) for x in a]
        b = [float(x) for x in b]
    assert len(a) == len(b) and len(a) > 0
    n = cfg.n
    pairs = cfg.iteration_pairs()
    thresh = cfg.mask_threshold
    acc = 0
    exp_acc = None

    for g0 in range(0, len(a), n):
        ga = a[g0:g0 + n]
        gb = b[g0:g0 + n]
        dec_a = [decomp(x) for x in ga]
        dec_b = [decomp(x) for x in gb]
        c = [da[1] + db[1] for da, db in zip(dec_a, dec_b)]
        max_c = max(c)
        shift = [max_c - ck for ck in c]
        active = [s <= thresh for s in shift]
        pl_a = [planes(s, m) for s, _, m in dec_a]
        pl_b = [planes(s, m) for s, _, m in dec_b]

        for (i, j) in pairs:
            pre = cfg.pre_shift(i, j)
            if not cfg.multi_cycle:
                s_tree = 0
                for k in range(len(ga)):
                    if not active[k]:
                        continue
                    d = pl_a[k][i] * pl_b[k][j]
                    s_tree += _shr(d << (cfg.w - 9), shift[k], cfg.rounding)
                acc, exp_acc = _acc_update(acc, exp_acc, s_tree, max_c, pre,
                                           0, cfg)
            else:
                for cyc in range(cfg.num_cycles_static):
                    s_tree = 0
                    for k in range(len(ga)):
                        if not active[k] or shift[k] // cfg.sp != cyc:
                            continue
                        d = pl_a[k][i] * pl_b[k][j]
                        local = shift[k] - cyc * cfg.sp
                        s_tree += _shr(d << (cfg.w - 9), local, cfg.rounding)
                    acc, exp_acc = _acc_update(acc, exp_acc, s_tree, max_c,
                                               pre, cyc * cfg.sp, cfg)

    if exp_acc is None or acc == 0:
        exp_acc = 0
    sign = -1 if acc < 0 else 1
    return round_value_to_fp(sign, abs(acc), exp_acc - 30, cfg.accum)


def _acc_update(acc: int, exp_acc, s_tree: int, max_c: int, pre: int,
                extra: int, cfg: IPUConfig):
    if exp_acc is None:
        exp_acc = max_c
    if max_c > exp_acc:
        acc = _shr(acc, max_c - exp_acc, cfg.rounding)
        exp_acc = max_c
    inc_shift = pre + extra + (exp_acc - max_c)
    wide = s_tree << (33 - cfg.w)
    acc += _shr(wide, inc_shift, cfg.rounding) if inc_shift >= 0 else 0
    return acc, exp_acc


def int_dot(a: Iterable[int], b: Iterable[int]) -> int:
    return int(sum(int(x) * int(y) for x, y in zip(a, b)))
