"""Layer-shape workloads for the cycle-accurate simulator.

The paper's study cases (§4.1): ResNet-18 fwd, ResNet-50 fwd, InceptionV3
fwd, ResNet-18 bwd — convolution layers only (the tiles are convolution
tiles). Shapes are the standard ImageNet-224 configurations from public
model definitions. We also expose LM matmul shapes (from our assigned
architectures) mapped to 1x1 convolutions, so the simulator can score the
paper's technique on transformer workloads (beyond-paper extension).
"""
from __future__ import annotations

import dataclasses
from typing import Iterable, List, Sequence, Tuple


@dataclasses.dataclass(frozen=True)
class ConvLayer:
    """One convolution workload: OFM = conv(IFM, W).

    Attributes mirror the paper's Fig. 11 loop nest. ``count`` collapses
    repeated identical layers. A fully-connected / matmul layer is the
    special case R = S = Ho = Wo = 1 with batch folded into count or Ho.
    """

    name: str
    c: int       # input channels
    k: int       # output channels
    ho: int      # output height
    wo: int      # output width
    r: int = 3   # filter height
    s: int = 3   # filter width
    count: int = 1

    @property
    def macs(self) -> int:
        return self.c * self.k * self.ho * self.wo * self.r * self.s * self.count

    @property
    def ip_length(self) -> int:
        """Inner-product length per output pixel."""
        return self.c * self.r * self.s


def _bb(name: str, c: int, k: int, hw: int, count: int = 1,
        stride_first: bool = False) -> List[ConvLayer]:
    """ResNet basic block: two 3x3 convs (+ 1x1 shortcut when widening)."""
    layers = [
        ConvLayer(f"{name}.conv1", c, k, hw, hw, 3, 3, count),
        ConvLayer(f"{name}.conv2", k, k, hw, hw, 3, 3, count),
    ]
    if stride_first:
        layers.append(ConvLayer(f"{name}.down", c, k, hw, hw, 1, 1, 1))
    return layers


def resnet18() -> List[ConvLayer]:
    ls: List[ConvLayer] = [ConvLayer("conv1", 3, 64, 112, 112, 7, 7)]
    ls += _bb("layer1.0", 64, 64, 56) + _bb("layer1.1", 64, 64, 56)
    ls += _bb("layer2.0", 64, 128, 28, stride_first=True) + _bb("layer2.1", 128, 128, 28)
    ls += _bb("layer3.0", 128, 256, 14, stride_first=True) + _bb("layer3.1", 256, 256, 14)
    ls += _bb("layer4.0", 256, 512, 7, stride_first=True) + _bb("layer4.1", 512, 512, 7)
    ls.append(ConvLayer("fc", 512, 1000, 1, 1, 1, 1))
    return ls


def _bottleneck(name: str, c_in: int, c_mid: int, hw: int, count: int,
                downsample: bool) -> List[ConvLayer]:
    c_out = 4 * c_mid
    ls = [
        ConvLayer(f"{name}.conv1", c_in, c_mid, hw, hw, 1, 1, count),
        ConvLayer(f"{name}.conv2", c_mid, c_mid, hw, hw, 3, 3, count),
        ConvLayer(f"{name}.conv3", c_mid, c_out, hw, hw, 1, 1, count),
    ]
    if downsample:
        ls.append(ConvLayer(f"{name}.down", c_in, c_out, hw, hw, 1, 1, 1))
    return ls


def resnet50() -> List[ConvLayer]:
    ls: List[ConvLayer] = [ConvLayer("conv1", 3, 64, 112, 112, 7, 7)]
    # (stage, blocks, c_mid, hw)
    ls += _bottleneck("layer1.0", 64, 64, 56, 1, True)
    ls += _bottleneck("layer1.x", 256, 64, 56, 2, False)
    ls += _bottleneck("layer2.0", 256, 128, 28, 1, True)
    ls += _bottleneck("layer2.x", 512, 128, 28, 3, False)
    ls += _bottleneck("layer3.0", 512, 256, 14, 1, True)
    ls += _bottleneck("layer3.x", 1024, 256, 14, 5, False)
    ls += _bottleneck("layer4.0", 1024, 512, 7, 1, True)
    ls += _bottleneck("layer4.x", 2048, 512, 7, 2, False)
    ls.append(ConvLayer("fc", 2048, 1000, 1, 1, 1, 1))
    return ls


def inception_v3() -> List[ConvLayer]:
    """torchvision InceptionV3 conv shapes (aux head omitted)."""
    L = ConvLayer
    ls = [
        L("stem.1", 3, 32, 149, 149, 3, 3), L("stem.2", 32, 32, 147, 147, 3, 3),
        L("stem.3", 32, 64, 147, 147, 3, 3), L("stem.4", 64, 80, 73, 73, 1, 1),
        L("stem.5", 80, 192, 71, 71, 3, 3),
    ]

    def inception_a(name, cin, pool):
        return [
            L(f"{name}.b1", cin, 64, 35, 35, 1, 1),
            L(f"{name}.b5a", cin, 48, 35, 35, 1, 1),
            L(f"{name}.b5b", 48, 64, 35, 35, 5, 5),
            L(f"{name}.b3a", cin, 64, 35, 35, 1, 1),
            L(f"{name}.b3b", 64, 96, 35, 35, 3, 3),
            L(f"{name}.b3c", 96, 96, 35, 35, 3, 3),
            L(f"{name}.pool", cin, pool, 35, 35, 1, 1),
        ]

    ls += inception_a("5b", 192, 32) + inception_a("5c", 256, 64) \
        + inception_a("5d", 288, 64)
    ls += [  # reduction A
        L("6a.b3", 288, 384, 17, 17, 3, 3),
        L("6a.b3d1", 288, 64, 35, 35, 1, 1), L("6a.b3d2", 64, 96, 35, 35, 3, 3),
        L("6a.b3d3", 96, 96, 17, 17, 3, 3),
    ]

    def inception_b(name, c7):
        return [
            L(f"{name}.b1", 768, 192, 17, 17, 1, 1),
            L(f"{name}.b7a", 768, c7, 17, 17, 1, 1),
            L(f"{name}.b7b", c7, c7, 17, 17, 1, 7),
            L(f"{name}.b7c", c7, 192, 17, 17, 7, 1),
            L(f"{name}.d7a", 768, c7, 17, 17, 1, 1),
            L(f"{name}.d7b", c7, c7, 17, 17, 7, 1),
            L(f"{name}.d7c", c7, c7, 17, 17, 1, 7),
            L(f"{name}.d7d", c7, c7, 17, 17, 7, 1),
            L(f"{name}.d7e", c7, 192, 17, 17, 1, 7),
            L(f"{name}.pool", 768, 192, 17, 17, 1, 1),
        ]

    ls += inception_b("6b", 128) + inception_b("6c", 160) \
        + inception_b("6d", 160) + inception_b("6e", 192)
    ls += [  # reduction B
        L("7a.b3a", 768, 192, 17, 17, 1, 1), L("7a.b3b", 192, 320, 8, 8, 3, 3),
        L("7a.b7a", 768, 192, 17, 17, 1, 1), L("7a.b7b", 192, 192, 17, 17, 1, 7),
        L("7a.b7c", 192, 192, 17, 17, 7, 1), L("7a.b7d", 192, 192, 8, 8, 3, 3),
    ]

    def inception_e(name, cin):
        return [
            L(f"{name}.b1", cin, 320, 8, 8, 1, 1),
            L(f"{name}.b3a", cin, 384, 8, 8, 1, 1),
            L(f"{name}.b3b1", 384, 384, 8, 8, 1, 3),
            L(f"{name}.b3b2", 384, 384, 8, 8, 3, 1),
            L(f"{name}.d3a", cin, 448, 8, 8, 1, 1),
            L(f"{name}.d3b", 448, 384, 8, 8, 3, 3),
            L(f"{name}.d3c1", 384, 384, 8, 8, 1, 3),
            L(f"{name}.d3c2", 384, 384, 8, 8, 3, 1),
            L(f"{name}.pool", cin, 192, 8, 8, 1, 1),
        ]

    ls += inception_e("7b", 1280) + inception_e("7c", 2048)
    ls.append(L("fc", 2048, 1000, 1, 1, 1, 1))
    return ls


def resnet18_backward() -> List[ConvLayer]:
    """Backward pass of ResNet-18 as conv workloads: for each fwd conv,
    dX (K->C, transposed filters) and dW (gradient) have the same MAC
    volume as the forward layer; we model them as two conv workloads with
    the fwd shape (standard practice for cycle modelling)."""
    out = []
    for l in resnet18():
        if l.name == "conv1":
            out.append(dataclasses.replace(l, name=l.name + ".dW"))
            continue
        out.append(dataclasses.replace(l, name=l.name + ".dX",
                                       c=l.k, k=l.c))
        out.append(dataclasses.replace(l, name=l.name + ".dW"))
    return out


def lm_projection_layers(d_model: int, d_ff: int, n_layers: int,
                         vocab: int, seq: int = 1, name: str = "lm"
                         ) -> List[ConvLayer]:
    """Transformer projections as 1x1 convs: per-token matmuls with
    C=d_model, K=out features, Ho=seq tokens (beyond-paper workload)."""
    L = ConvLayer
    return [
        L(f"{name}.qkvo", d_model, 4 * d_model, seq, 1, 1, 1, n_layers),
        L(f"{name}.ffn_in", d_model, 2 * d_ff, seq, 1, 1, 1, n_layers),
        L(f"{name}.ffn_out", d_ff, d_model, seq, 1, 1, 1, n_layers),
        L(f"{name}.head", d_model, vocab, seq, 1, 1, 1, 1),
    ]


WORKLOADS = {
    "resnet18": resnet18,
    "resnet50": resnet50,
    "inception_v3": inception_v3,
    "resnet18_bwd": resnet18_backward,
}


def total_macs(layers: Iterable[ConvLayer]) -> int:
    return sum(l.macs for l in layers)
