"""Core numerics of the mixed-precision IPU (the paper's contribution).

Layers:
  fp16        - IEEE field codecs as int32 JAX ops
  fixedpoint  - two-limb int32 accumulator arithmetic
  nibble      - 5-bit signed nibble temporal decomposition
  ehu         - exponent handling unit + MC-IPU schedule
  ipu         - bit-exact approximate FP-IP / MC-IPU / INT-mode emulation
  exact_ref   - independent Python-int oracle
  error_bounds- Theorem 1 bounds
  simulator   - cycle-accurate tile/cluster performance model
  area_power  - calibrated 7nm area/power model (Fig. 7 / Table 1)
  workloads   - ResNet/Inception/LM layer shape sets for the simulator
"""
from repro.core.ipu import (  # noqa: F401
    IPUConfig,
    fp16_inner_product,
    fp16_inner_product_raw,
    int_inner_product,
)
from repro.core.fp16 import FP16, FP32, BF16, TF32, FPFormat  # noqa: F401
