"""Theorem 1 error bounds for the approximate nibble iteration.

Paper (Theorem 1): for an FP-IP with n FP16 input pairs, the absolute
error of approximate_nibble_iteration(i, j, precision) is no larger than

    225 * 2**(4*(i+j) - 22) * 2**(max - precision) * (n - 1)

where ``max`` is the maximum product exponent.

Our analysis (DESIGN.md "Shift semantics") shows the stated constant
covers the fully-shifted-out case the proof outline considers, but a
*partially* truncated product can drop up to one ULP of the iteration sum
scale, i.e. up to 2**9 * 2**(max-precision) * 2**(4(i+j)-22) per product
(2**9 = 512 > 225). We therefore also provide the provably safe bound
with constant 512; the property tests assert measured error <= tight
bound always, and track how often the paper's 225 constant holds
empirically (it holds for all practically distributed inputs; adversarial
inputs can exceed it — a reproduction note recorded in EXPERIMENTS.md).
"""
from __future__ import annotations

from fractions import Fraction
from typing import Iterable

PAPER_CONSTANT = 225
TIGHT_CONSTANT = 512  # 2**9: one ULP of the iteration-sum scale per product


def iteration_bound(i: int, j: int, precision: int, max_exp: int, n: int,
                    constant: int = PAPER_CONSTANT) -> Fraction:
    """Absolute-error bound for one approximate nibble iteration."""
    if n <= 1:
        return Fraction(0)
    return (Fraction(constant) * Fraction(2) ** (4 * (i + j) - 22)
            * Fraction(2) ** (max_exp - precision) * (n - 1))


def tight_iteration_bound(i: int, j: int, precision: int, max_exp: int,
                          n: int) -> Fraction:
    return iteration_bound(i, j, precision, max_exp, n, TIGHT_CONSTANT)


def fp_ip_bound(precision: int, max_exp: int, n: int,
                constant: int = PAPER_CONSTANT,
                acc_granularity_updates: int = 0) -> Fraction:
    """Total FP-IP bound: sum of the nine iteration bounds, plus (for the
    full pipeline) one accumulator-granularity ULP (2**(max-30)) per
    accumulator update that can truncate."""
    total = sum(
        (iteration_bound(i, j, precision, max_exp, n, constant)
         for i in range(3) for j in range(3)), Fraction(0))
    if acc_granularity_updates:
        total += acc_granularity_updates * Fraction(2) ** (max_exp - 30)
    return total


def remark1_weights() -> dict:
    """Remark 1: relative error weights of the nine iterations; the most
    significant nibble pair (i+j largest) dominates."""
    return {(i, j): Fraction(2) ** (4 * (i + j))
            for i in range(3) for j in range(3)}
