"""Per-layer mixed-precision policy — the paper's technique as a
first-class framework feature.

A PrecisionPolicy maps parameter paths (regex over 'block/attn/wq'-style
names) to a PrecisionSpec. Every projection in the model zoo routes its
matmul through layers.mplinear according to the spec:

  mode:
    'bf16' / 'fp32'  — plain dense matmul in that dtype.
    'int8' / 'int4'  — quantized path. ``exact=False`` (default) runs
        fake-quant (quantize-dequantize with a straight-through
        estimator): MXU-friendly, shardable, usable at scale — this is
        what the accelerator would compute up to the final dequant
        rounding. ``exact=True`` routes through the integer Pallas
        kernels (kernels.ops) — bit-exact INT mode, CPU/fidelity runs.
    'fp16_ipu'       — the approximate FP-IP datapath: ``exact=True``
        uses kernels.ops.mp_matmul (bit-exact IPU(w) emulation);
        ``exact=False`` approximates it as fp16-cast inputs + f32 dot,
        which §3.1 shows is indistinguishable at w >= 28 (and is what
        a w>=28 IPU computes up to accumulator granularity).

The paper's hybrid scheme (Appendix B) — a few FP16 layers, the rest
INT-quantized — is the 'paper_hybrid' preset.
"""
from __future__ import annotations

import contextlib
import dataclasses
import re
from typing import List, Optional, Sequence, Tuple

from repro.core.ipu import IPUConfig

# When set (via trace_routing), every spec_for resolution appends a
# (path, mode) record — the hook the plan-routing assertion tests use to
# observe which datapath each projection actually took.
_ROUTING_TRACE: Optional[List[Tuple[str, str]]] = None


@contextlib.contextmanager
def trace_routing():
    """Record every (path, mode) the active policies route while open."""
    global _ROUTING_TRACE
    records: List[Tuple[str, str]] = []
    prev = _ROUTING_TRACE
    _ROUTING_TRACE = records
    try:
        yield records
    finally:
        _ROUTING_TRACE = prev


@dataclasses.dataclass(frozen=True)
class PrecisionSpec:
    mode: str = "bf16"         # bf16|fp32|int8|int4|fp8|fp4|fp16_ipu
    exact: bool = False        # route through bit-exact kernels
    ipu: Optional[IPUConfig] = None   # for fp16_ipu exact mode
    # per-group weight scales: splits the contraction dim into
    # K/group_size scale groups (int + fp storage modes); None keeps
    # the per-out-channel layout. Named group_size, not group —
    # autotune's PlanRule already uses 'group' for the projection-group
    # name.
    group_size: Optional[int] = None

    def __post_init__(self):
        if self.mode not in ("bf16", "fp32", "int8", "int4",
                             "fp8", "fp4", "fp16_ipu"):
            raise ValueError(self.mode)
        if self.group_size is not None and self.group_size < 1:
            raise ValueError(f"group_size must be positive, got "
                             f"{self.group_size}")

    @property
    def weight_bits(self) -> Optional[int]:
        return {"int8": 8, "int4": 4}.get(self.mode)


@dataclasses.dataclass(frozen=True)
class PrecisionPolicy:
    """Ordered (regex, spec) rules; first match wins; default last."""

    name: str
    rules: Tuple[Tuple[str, PrecisionSpec], ...] = ()
    default: PrecisionSpec = PrecisionSpec("bf16")

    def spec_for(self, path: str) -> PrecisionSpec:
        spec = self.default
        for pattern, rule_spec in self.rules:
            if re.search(pattern, path):
                spec = rule_spec
                break
        if _ROUTING_TRACE is not None:
            _ROUTING_TRACE.append((path, spec.mode))
        return spec


BF16 = PrecisionPolicy("bf16")
FP32 = PrecisionPolicy("fp32", default=PrecisionSpec("fp32"))

# INT8 serving: everything quantized except the router/logits.
INT8_SERVING = PrecisionPolicy(
    "int8_serving",
    rules=(
        (r"router|lm_head", PrecisionSpec("bf16")),
    ),
    default=PrecisionSpec("int8"),
)

# INT4 serving: the common case the IPU is built for.
INT4_SERVING = PrecisionPolicy(
    "int4_serving",
    rules=(
        (r"router|lm_head", PrecisionSpec("bf16")),
    ),
    default=PrecisionSpec("int4"),
)

# Paper hybrid (Appendix B): sensitive projections in FP16 on the IPU
# datapath, the bulk in INT4. First/last blocks and attention outputs are
# the classic FP16 keeps.
PAPER_HYBRID = PrecisionPolicy(
    "paper_hybrid",
    rules=(
        (r"router|lm_head|embed", PrecisionSpec("fp16_ipu",
                                                ipu=IPUConfig(n=16, w=28))),
        (r"attn/wo", PrecisionSpec("fp16_ipu", ipu=IPUConfig(n=16, w=16))),
    ),
    default=PrecisionSpec("int4"),
)

# Fidelity: bit-exact IPU emulation everywhere (tiny models / tests).
FIDELITY_FP16_IPU = PrecisionPolicy(
    "fidelity_fp16_ipu",
    default=PrecisionSpec("fp16_ipu", exact=True,
                          ipu=IPUConfig(n=16, w=16, accum="fp32")),
)

FIDELITY_INT8 = PrecisionPolicy(
    "fidelity_int8",
    default=PrecisionSpec("int8", exact=True),
)

POLICIES = {p.name: p for p in (
    BF16, FP32, INT8_SERVING, INT4_SERVING, PAPER_HYBRID,
    FIDELITY_FP16_IPU, FIDELITY_INT8)}


def register_policy(policy: PrecisionPolicy) -> PrecisionPolicy:
    """Register a (possibly synthesized) policy under its name so model
    configs can reference it via ``precision_policy``. Re-registering a
    name replaces the previous policy (latest wins)."""
    POLICIES[policy.name] = policy
    return policy


def get_policy(name: str) -> PrecisionPolicy:
    """Resolve a policy name. ``"plan:<path.json>"`` loads a serialized
    ``repro.autotune`` PrecisionPlan artifact and returns its policy —
    the hook that makes an offline-searched plan the serving policy."""
    if name.startswith("plan:"):
        from repro.autotune.plan import load_policy
        return load_policy(name[len("plan:"):])
    return POLICIES[name]
