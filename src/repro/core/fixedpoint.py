"""Two-limb int32 signed fixed-point arithmetic (base 2**24).

The IPU accumulator register is ``33 + t + l`` bits wide (paper §2.2,
Fig. 1) — wider than int32. JAX disables int64 by default and Pallas TPU
kernels prefer 32-bit lanes, so we carry the accumulator as two int32
limbs::

    V = hi * 2**24 + lo,   lo in [0, 2**24),   hi signed

which represents |V| < 2**54 exactly — enough for the 33+t+l <= 48-bit
register of any practical IPU configuration. All ops are branchless,
elementwise, jit/vmap-safe, and usable inside Pallas kernel bodies.

Shift semantics: the paper's datapath is sign-magnitude ("5b x 5b sign
multipliers"), so right shifts truncate toward zero (shift the magnitude,
reapply the sign). ``shr_floor`` implements the two's-complement
alternative for comparison (see DESIGN.md "Shift semantics").
"""
from __future__ import annotations

from typing import NamedTuple, Tuple

import jax
import jax.numpy as jnp

LIMB_BITS = 24
LIMB_MASK = (1 << LIMB_BITS) - 1


class FX(NamedTuple):
    """Two-limb fixed-point value. hi*2**24 + lo with lo in [0, 2**24)."""

    hi: jax.Array
    lo: jax.Array


def canon(hi: jax.Array, lo: jax.Array) -> FX:
    """Normalize so lo is in [0, 2**24). Arithmetic >> gives a floor carry,
    which is correct for negative lo as well."""
    carry = lo >> LIMB_BITS
    return FX((hi + carry).astype(jnp.int32), (lo & LIMB_MASK).astype(jnp.int32))


def zero_like(x: jax.Array) -> FX:
    z = jnp.zeros_like(x, dtype=jnp.int32)
    return FX(z, z)


def from_int32(x: jax.Array) -> FX:
    return canon(jnp.zeros_like(x, dtype=jnp.int32), x.astype(jnp.int32))


def add(a: FX, b: FX) -> FX:
    return canon(a.hi + b.hi, a.lo + b.lo)


def neg(a: FX) -> FX:
    return canon(-a.hi, -a.lo)


def is_neg(a: FX) -> jax.Array:
    return a.hi < 0


def is_zero(a: FX) -> jax.Array:
    return (a.hi == 0) & (a.lo == 0)


def abs_(a: FX) -> Tuple[jax.Array, FX]:
    """Return (sign in {-1,+1}, |a|). sign(0) = +1."""
    n = is_neg(a)
    sign = jnp.where(n, -1, 1).astype(jnp.int32)
    na = neg(a)
    return sign, FX(jnp.where(n, na.hi, a.hi), jnp.where(n, na.lo, a.lo))


def mul_sign(sign: jax.Array, a: FX) -> FX:
    na = neg(a)
    neg_sel = sign < 0
    return FX(jnp.where(neg_sel, na.hi, a.hi), jnp.where(neg_sel, na.lo, a.lo))


def _shr_unsigned(a: FX, s: jax.Array) -> FX:
    """Logical right shift of a NON-NEGATIVE two-limb value by a per-element
    dynamic amount s >= 0 (values >= 48 yield 0). All lane shifts <= 31."""
    s = s.astype(jnp.int32)
    # --- branch A: s in [0, 24) ---
    sa = jnp.clip(s, 0, LIMB_BITS - 1)
    hi_a = a.hi >> sa
    cross = (a.hi & ((1 << sa) - 1)) << (LIMB_BITS - sa)  # < 2**24, no overflow
    lo_a = cross | (a.lo >> sa)
    # --- branch B: s in [24, 48) ---
    sb = jnp.clip(s - LIMB_BITS, 0, LIMB_BITS - 1)
    lo_b = a.hi >> sb
    # --- select ---
    ge48 = s >= 2 * LIMB_BITS
    in_b = (s >= LIMB_BITS) & ~ge48
    hi = jnp.where(ge48 | in_b, 0, hi_a)
    lo = jnp.where(ge48, 0, jnp.where(in_b, lo_b, lo_a))
    return FX(hi.astype(jnp.int32), lo.astype(jnp.int32))


def _dropped_nonzero(mag: FX, s: jax.Array) -> jax.Array:
    """True where shifting non-negative mag right by s drops a nonzero bit,
    i.e. any of bits [0, s) is set."""
    s = s.astype(jnp.int32)
    sa = jnp.clip(s, 0, LIMB_BITS - 1)
    low_a = (mag.lo & ((1 << sa) - 1)) != 0
    sb = jnp.clip(s - LIMB_BITS, 0, LIMB_BITS - 1)
    low_b = ((mag.hi & ((1 << sb) - 1)) != 0) | (mag.lo != 0)
    ge48 = s >= 2 * LIMB_BITS
    any_bits = (mag.hi != 0) | (mag.lo != 0)
    return jnp.where(ge48, any_bits, jnp.where(s >= LIMB_BITS, low_b, low_a))


def shr_trunc(a: FX, s: jax.Array) -> FX:
    """Right shift truncating toward zero (sign-magnitude datapath)."""
    sign, mag = abs_(a)
    return mul_sign(sign, _shr_unsigned(mag, s))


def shr_floor(a: FX, s: jax.Array) -> FX:
    """Arithmetic right shift (floor) — two's-complement datapath variant."""
    sign, mag = abs_(a)
    shifted = _shr_unsigned(mag, s)
    dropped = _dropped_nonzero(mag, s)
    res = mul_sign(sign, shifted)
    # floor(-m / 2**s) = -(m >> s) - 1 when bits were dropped
    adj = jnp.where((sign < 0) & dropped, 1, 0).astype(jnp.int32)
    return canon(res.hi, res.lo - adj)


def shl(a: FX, s: int) -> FX:
    """Static left shift by s in [0, 24). Caller guarantees no overflow of
    the 2**54 range. (The IPU needs at most 33 - w <= 21.)"""
    if s == 0:
        return a
    if not 0 < s < LIMB_BITS:
        raise ValueError("static shl must be in [0, 24); IPU needs <= 21")
    hi = (a.hi << s) | (a.lo >> (LIMB_BITS - s))
    lo = (a.lo << s) & LIMB_MASK
    return FX(hi.astype(jnp.int32), lo.astype(jnp.int32))


def shl_dyn(a: FX, s: jax.Array, max_s: int = LIMB_BITS - 1) -> FX:
    """Dynamic left shift by per-element s in [0, max_s], max_s < 24."""
    s = jnp.clip(s.astype(jnp.int32), 0, max_s)
    hi = (a.hi << s) | jnp.where(s == 0, 0, a.lo >> (LIMB_BITS - s))
    lo = (a.lo << s) & LIMB_MASK
    return FX(hi.astype(jnp.int32), lo.astype(jnp.int32))


def to_float32(a: FX) -> jax.Array:
    """Value as f32 — EXACT only when |V| <~ 2**24; for diagnostics."""
    return a.hi.astype(jnp.float32) * float(1 << LIMB_BITS) + a.lo.astype(
        jnp.float32
    )


def select(pred: jax.Array, t: FX, f: FX) -> FX:
    return FX(jnp.where(pred, t.hi, f.hi), jnp.where(pred, t.lo, f.lo))


def msb_index(mag: FX) -> jax.Array:
    """floor(log2(V)) of a non-negative two-limb value in canonical form.

    Exact: each limb < 2**24 is exactly representable in f32. Returns 0 for
    V == 0 (caller must mask)."""
    _, e_hi = jnp.frexp(mag.hi.astype(jnp.float32))
    _, e_lo = jnp.frexp(mag.lo.astype(jnp.float32))
    return jnp.where(
        mag.hi > 0, LIMB_BITS + e_hi.astype(jnp.int32) - 1,
        jnp.maximum(e_lo.astype(jnp.int32) - 1, 0),
    ).astype(jnp.int32)


def _bit_at(mag: FX, pos: jax.Array) -> jax.Array:
    """Bit ``pos`` (>=0, <48) of a non-negative two-limb value, as bool."""
    pos = pos.astype(jnp.int32)
    in_hi = pos >= LIMB_BITS
    p_lo = jnp.clip(pos, 0, LIMB_BITS - 1)
    p_hi = jnp.clip(pos - LIMB_BITS, 0, LIMB_BITS - 1)
    b_lo = (mag.lo >> p_lo) & 1
    b_hi = (mag.hi >> p_hi) & 1
    return jnp.where(in_hi, b_hi, b_lo).astype(jnp.bool_)


def round_to_fp(acc: FX, exp: jax.Array, fmt) -> jax.Array:
    """Round the non-normalized accumulator to an IEEE format, RNE.

    Accumulator semantics (paper §2.2): value = acc * 2**(exp - 30) —
    sign + (3+t+l) integer bits + 30 fraction bits w.r.t. ``exp``.

    Implements normalize -> round-to-nearest-even -> pack, handling
    subnormal outputs and overflow-to-inf, entirely in int32 ops.
    """
    from repro.core import fp16 as fp16mod  # local import to avoid cycle

    sign, mag = abs_(acc)
    zero = is_zero(mag)
    nb = msb_index(mag)  # MSB position; value in [2**nb, 2**(nb+1))
    # Unbiased exponent of the value: value = M * 2**(exp-30)
    e_val = exp - 30 + nb
    mt = fmt.mag_bits  # target magnitude bits incl hidden
    # Drop ``keep`` bits so the kept magnitude has mt bits.
    keep = nb + 1 - mt
    # Subnormal squeeze: if e_val < min_exp we must drop extra bits.
    extra = jnp.maximum(fmt.min_exp - e_val, 0)
    keep = keep + extra
    keep_pos = jnp.maximum(keep, 0)

    q = _shr_unsigned(mag, keep_pos)
    rb_pos = jnp.maximum(keep_pos - 1, 0)
    rb = _bit_at(mag, rb_pos) & (keep_pos > 0)
    sticky = _dropped_nonzero(mag, rb_pos)
    q_lsb = (q.lo & 1).astype(jnp.bool_)
    round_up = rb & (sticky | q_lsb)
    q = select(round_up, add(q, from_int32(jnp.ones_like(q.lo))), q)
    # q now fits 25 bits worst case; flatten to a plain int32.
    qi = q.hi * (1 << LIMB_BITS) + q.lo
    # keep < 0: value has fewer bits than the target mantissa — left-pad so
    # the hidden bit lands at position mt-1 (exact, no rounding happened).
    pad = jnp.clip(-keep, 0, mt - 1)
    qi = jnp.where(keep < 0, qi << pad, qi)
    # Rounding carry: q == 2**mt -> halve and bump exponent.
    carried = qi >= (1 << mt)
    qi = jnp.where(carried, qi >> 1, qi)
    e_q = jnp.where(carried, e_val + 1, e_val)
    e_q = jnp.maximum(e_q, fmt.min_exp)  # subnormal exponent pin
    overflow = e_q > fmt.max_exp
    out = fp16mod.compose(sign, e_q, qi.astype(jnp.int32), fmt)
    inf = fp16mod.make_inf(sign, fmt)
    out = jnp.where(overflow, inf, out)
    zero_val = fp16mod.compose(jnp.ones_like(sign), jnp.full_like(e_q, fmt.min_exp),
                               jnp.zeros_like(qi), fmt)
    return jnp.where(zero, zero_val, out)
