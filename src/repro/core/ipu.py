"""Bit-exact emulation of the mixed-precision inner-product unit (IPU).

Implements the paper's approximate FP-IP operation (Fig. 2) and the
multi-cycle MC-IPU variant (§3.2) as vectorized, jit/vmap-safe JAX integer
arithmetic:

  * FP16 operands are decomposed into 3 signed 5-bit nibble planes
    (``nibble.fp16_planes``); the 9 nibble iterations run as tensorized
    integer ops (the TPU-native realization of the paper's temporal
    decomposition — see DESIGN.md).
  * Per-iteration alignment: each 9-bit nibble product is left-shifted by
    ``w - 9``, right-shifted by its EHU alignment amount with truncation,
    and summed in a ``w``-bit adder tree (w = "IPU precision").
  * The accumulator is the paper's non-normalized (33+t+l)-bit register,
    carried as a two-limb int32 fixed-point value with 30 fraction bits
    w.r.t. the running exponent; swap-and-shift on exponent increase.
  * MC-IPU(w): alignments beyond the safe precision ``sp = w - 9`` are
    served in multiple cycles; partition k's products are locally shifted
    by ``shift - k*sp`` (exact, Proposition 1) and the adder output takes
    the extra ``k*sp`` shift into the accumulator.

INT mode (§2.1) runs the same datapath with zero alignment and exact
results for INT4/8/12 operands.

Numerical ranges are chosen so everything is exact in int32 lanes:
|nibble product| <= 225 < 2**8, adder sums < 2**31 for n <= 16, w <= 28,
and the accumulator < 2**48 in two int32 limbs.
"""
from __future__ import annotations

import dataclasses
import functools
import math
from typing import List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp

from repro.core import ehu, fixedpoint as fx, fp16 as fpmod, nibble

NEG_INF_EXP = ehu.NEG_INF_EXP


@dataclasses.dataclass(frozen=True)
class IPUConfig:
    """Static configuration of one IPU / MC-IPU.

    Attributes:
      n: number of IPU inputs (products per group); paper uses 8 or 16.
      w: IPU precision — adder-tree width and max local alignment shift.
      accum: accumulator target format, 'fp16' or 'fp32'.
      sw_precision: software precision P (EHU stage-4 mask threshold).
        Defaults to the paper's accuracy-preserving minima: 16 for FP16
        accumulation, 28 for FP32 accumulation (§3.1).
      multi_cycle: MC-IPU(w) mode — serve alignments up to P over
        ceil((P+1)/sp) cycles instead of truncating at w.
      rounding: 'trunc' (sign-magnitude, paper datapath) or 'floor'
        (two's-complement arithmetic shift) for alignment truncation.
      iter_order: 'asc' iterates nibble pairs (i,j) in Fig.-2 order
        (ascending significance); 'desc' most-significant-first.
      acc_l: l = ceil(log2(max accumulation depth d)); register is
        33 + ceil(log2 n) + l bits and must stay < 54 for two limbs.
    """

    n: int = 16
    w: int = 16
    accum: str = "fp32"
    sw_precision: Optional[int] = None
    multi_cycle: bool = False
    rounding: str = "trunc"
    iter_order: str = "asc"
    acc_l: int = 10
    # operand format (paper Appendix B): 'fp16' (3 nibble planes, 9
    # iterations); 'bf16' (8-bit exponents, 2 planes, 4 iterations);
    # 'tf32' (8-bit exponents with the FP16 11-bit magnitude -> the FP16
    # plane path on an 8-bit EHU; inputs are f32 RNE-rounded to TF32).
    operand: str = "fp16"

    def __post_init__(self):
        if self.w < 10:
            raise ValueError("IPU precision w must be >= 10 (sp = w-9 >= 1)")
        if self.accum not in ("fp16", "fp32", "bf16"):
            raise ValueError(f"bad accum {self.accum}")
        if self.operand not in ("fp16", "bf16", "tf32"):
            raise ValueError(f"bad operand {self.operand}")
        if self.accum == "bf16" and self.sw_precision is None:
            raise ValueError("accum='bf16' needs an explicit sw_precision")
        if self.rounding not in ("trunc", "floor"):
            raise ValueError(f"bad rounding {self.rounding}")
        # int32 adder-tree overflow guard: n * 225 * 2**(w-9) < 2**31
        if self.n * 225 * (1 << (self.w - 9)) >= (1 << 31):
            raise ValueError(f"n={self.n}, w={self.w} overflows int32 adder")
        t = math.ceil(math.log2(self.n))
        if 33 + t + self.acc_l >= 54:
            raise ValueError("accumulator exceeds two-limb range")

    @property
    def precision(self) -> int:
        """Effective software precision P."""
        if self.sw_precision is not None:
            return self.sw_precision
        return 16 if self.accum == "fp16" else 28

    @property
    def sp(self) -> int:
        """Safe precision: max exact local alignment (Proposition 1)."""
        return self.w - 9

    @property
    def mask_threshold(self) -> int:
        """Alignment beyond this contributes zero. Plain IPU cannot shift
        past its adder width; MC-IPU serves the full software precision."""
        return self.precision if self.multi_cycle else min(self.w, self.precision)

    @property
    def num_cycles_static(self) -> int:
        """Static upper bound on MC cycles per nibble iteration."""
        if not self.multi_cycle:
            return 1
        return self.mask_threshold // self.sp + 1

    @property
    def accum_format(self) -> fpmod.FPFormat:
        return {"fp16": fpmod.FP16, "fp32": fpmod.FP32,
                "bf16": fpmod.BF16}[self.accum]

    @property
    def operand_format(self) -> fpmod.FPFormat:
        return {"fp16": fpmod.FP16, "bf16": fpmod.BF16,
                "tf32": fpmod.TF32}[self.operand]

    @property
    def num_planes(self) -> int:
        return 2 if self.operand == "bf16" else 3

    def plane_fn(self):
        return (nibble.bf16_planes if self.operand == "bf16"
                else nibble.fp16_planes)

    def pre_shift(self, i, j):
        """Accumulator pre-shift 4*(2(K-1) - i - j) for plane pair (i,j);
        works on traced ints inside fori loops."""
        return 4 * (2 * (self.num_planes - 1) - i - j)

    def iteration_pairs(self) -> List[Tuple[int, int]]:
        k = self.num_planes
        pairs = [(i, j) for i in range(k) for j in range(k)]
        if self.iter_order == "desc":
            pairs = sorted(pairs, key=lambda p: -(p[0] + p[1]))
        return pairs


def _shr(v: fx.FX, s: jax.Array, rounding: str) -> fx.FX:
    return fx.shr_trunc(v, s) if rounding == "trunc" else fx.shr_floor(v, s)


def _shr_i32(d: jax.Array, s: jax.Array, rounding: str) -> jax.Array:
    """Right shift int32 products with the configured truncation.

    |d| < 2**31; shifts >= 31 are clamped (result 0 / -1 handled below)."""
    s = jnp.minimum(s.astype(jnp.int32), 31)
    if rounding == "trunc":
        mag = jnp.abs(d)
        return jnp.sign(d) * (mag >> s)
    return d >> s  # arithmetic shift == floor


def accumulate(acc: fx.FX, exp_acc: jax.Array, s_tree: jax.Array,
               max_c: jax.Array, pre_shift, extra_shift: jax.Array,
               cfg: IPUConfig) -> Tuple[fx.FX, jax.Array]:
    """One accumulator update (paper §2.2 right-hand side of Fig. 1).

    ``s_tree`` is the adder-tree output (int32, w + log2 n bits);
    ``pre_shift`` the static nibble-significance shift 4*(4-i-j);
    ``extra_shift`` the MC-IPU per-cycle k*sp (0 for plain IPU).

    The hardware concatenates (33 - w) zero bits then right-shifts by
    pre_shift + extra_shift + (exp_acc' - max_c); we apply the equivalent
    net shift to avoid widening past two limbs.
    """
    swap = max_c > exp_acc
    exp_new = jnp.maximum(exp_acc, max_c)
    acc = fx.select(swap, _shr(acc, jnp.minimum(exp_new - exp_acc, 63),
                               cfg.rounding), acc)
    inc_shift = pre_shift + extra_shift + (exp_new - max_c)
    net = inc_shift - (33 - cfg.w)  # >0: right shift; <0: exact left shift
    # Left shifts are exact; 23 is the static FX-safe bound (|s_tree| <
    # 2**30 -> < 2**53). Faithful mode needs at most 33-w <= 23; the fused
    # kernel mode can need (33-w)+1 via its negative pre_shift.
    v = fx.from_int32(s_tree)
    v = fx.shl_dyn(v, jnp.clip(-net, 0, 23), max_s=23)
    v = _shr(v, jnp.clip(net, 0, 1 << 20), cfg.rounding)
    return fx.add(acc, v), exp_new


def _prepare_groups(a: jax.Array, b: jax.Array, cfg: "IPUConfig"):
    """Decompose, pad to a multiple of n, reshape to (..., G, n) and move
    the G axis to the front for fori_loop indexing."""
    n = cfg.n
    dt = {"fp16": jnp.float16, "bf16": jnp.bfloat16,
          "tf32": jnp.float32}[cfg.operand]
    a = jnp.asarray(a, dt)
    b = jnp.asarray(b, dt)
    a, b = jnp.broadcast_arrays(a, b)
    if a.ndim == 0 or a.shape[-1] == 0:
        raise ValueError("inputs must have a non-empty last axis")
    length = a.shape[-1]
    g = -(-length // n)
    pad = g * n - length
    if pad:
        pw = [(0, 0)] * (a.ndim - 1) + [(0, pad)]
        a = jnp.pad(a, pw)
        b = jnp.pad(b, pw)
    valid = (jnp.arange(g * n) < length).reshape((1,) * (a.ndim - 1) + (g, n))
    valid = jnp.broadcast_to(valid, a.shape[:-1] + (g, n))

    if cfg.operand == "tf32":
        sa, ea, ma = _decompose_tf32(a)
        sb, eb, mb = _decompose_tf32(b)
    else:
        fmt = cfg.operand_format
        sa, ea, ma = fpmod.decompose(a, fmt)
        sb, eb, mb = fpmod.decompose(b, fmt)
    pa = cfg.plane_fn()(sa, ma)  # num_planes x (..., G*n)
    pb = cfg.plane_fn()(sb, mb)

    def to_front(x):
        x = x.reshape(x.shape[:-1] + (g, n))
        return jnp.moveaxis(x, -2, 0)  # (G, ..., n)

    pa = [to_front(p) for p in pa]
    pb = [to_front(p) for p in pb]
    ea = to_front(ea)
    eb = to_front(eb)
    valid = jnp.moveaxis(valid, -2, 0)
    return pa, pb, ea, eb, valid, g


def _decompose_tf32(x: jax.Array):
    """f32 -> TF32 fields: RNE-round the 24-bit magnitude to 11 bits.
    Returns (sign, unbiased exp, 11-bit magnitude): value = s*m*2**(e-10)
    after rounding — the TF32 input quantization TensorCores apply."""
    s, e, m = fpmod.decompose(x, fpmod.FP32)
    keep = 13  # 24 -> 11 bits
    q = m >> keep
    rb = (m >> (keep - 1)) & 1
    sticky = (m & ((1 << (keep - 1)) - 1)) != 0
    q = q + jnp.where((rb == 1) & (sticky | ((q & 1) == 1)), 1, 0)
    carry = q >= (1 << 11)
    q = jnp.where(carry, q >> 1, q)
    e = jnp.where(carry, e + 1, e)
    # subnormal f32 inputs keep mag < 2**10 (already representable)
    return s, e, q.astype(jnp.int32)


def fp16_inner_product_raw(a: jax.Array, b: jax.Array, cfg: IPUConfig
                           ) -> Tuple[fx.FX, jax.Array]:
    """Approximate FP-IP over the last axis; returns the non-normalized
    accumulator (two-limb FX, exponent) before output rounding.

    a, b: float16 arrays broadcastable to a common shape (..., N). The
    reduction runs in N/n groups of the IPU width n, 9 nibble iterations
    per group, exactly as the hardware schedules it.
    """
    pa, pb, ea, eb, valid, g = _prepare_groups(a, b, cfg)
    batch_shape = ea.shape[1:-1]

    # EHU (stages 1-4), shared across the 9 nibble iterations per group.
    out = ehu.run(ea, eb, cfg.mask_threshold, valid=valid, axis=-1)
    max_c, shift, active = out.max_exp, out.shift, out.active  # (G,...), (G,...,n)

    pairs = cfg.iteration_pairs()
    # Iteration order as lookup tables so the nibble loop can be a small
    # lax.fori_loop body (XLA-CPU compiles unrolled 9x/90x bodies in
    # minutes; dynamic indexing keeps the module tiny).
    it_i = jnp.asarray([p[0] for p in pairs], jnp.int32)
    it_j = jnp.asarray([p[1] for p in pairs], jnp.int32)
    pa_st = jnp.stack(pa)  # (3, G, ..., n)
    pb_st = jnp.stack(pb)

    if cfg.multi_cycle:
        cyc, local = ehu.service_schedule(shift, active, cfg.sp)

    def group_body(gi, carry):
        acc_hi, acc_lo, exp_acc = carry
        mc = jax.lax.dynamic_index_in_dim(max_c, gi, 0, keepdims=False)
        act = jax.lax.dynamic_index_in_dim(active, gi, 0, keepdims=False)
        pa_g = jax.lax.dynamic_index_in_dim(pa_st, gi, 1, keepdims=False)
        pb_g = jax.lax.dynamic_index_in_dim(pb_st, gi, 1, keepdims=False)
        if cfg.multi_cycle:
            cy_g = jax.lax.dynamic_index_in_dim(cyc, gi, 0, keepdims=False)
            lo_g = jax.lax.dynamic_index_in_dim(local, gi, 0, keepdims=False)
        else:
            sh_g = jax.lax.dynamic_index_in_dim(shift, gi, 0, keepdims=False)

        def iter_body(it, carry2):
            acc_hi2, acc_lo2, exp2 = carry2
            acc2 = fx.FX(acc_hi2, acc_lo2)
            i = it_i[it]
            j = it_j[it]
            na = jax.lax.dynamic_index_in_dim(pa_g, i, 0, keepdims=False)
            nb = jax.lax.dynamic_index_in_dim(pb_g, j, 0, keepdims=False)
            d = na * nb  # |d| <= 225
            dw = d << (cfg.w - 9)
            pre = cfg.pre_shift(i, j)  # 4*(2(K-1)-i-j), dynamic

            if not cfg.multi_cycle:
                aligned = _shr_i32(dw, sh_g, cfg.rounding)
                aligned = jnp.where(act, aligned, 0)
                s_tree = jnp.sum(aligned, axis=-1)
                acc2, exp2 = accumulate(acc2, exp2, s_tree, mc, pre,
                                         jnp.zeros_like(mc), cfg)
                return acc2.hi, acc2.lo, exp2

            def cycle_body(k, carry3):
                acc_hi3, acc_lo3, exp3 = carry3
                acc3 = fx.FX(acc_hi3, acc_lo3)
                sel = cy_g == k
                aligned = _shr_i32(dw, lo_g, cfg.rounding)
                aligned = jnp.where(sel, aligned, 0)
                s_tree = jnp.sum(aligned, axis=-1)
                acc3, exp3 = accumulate(acc3, exp3, s_tree, mc, pre,
                                         jnp.full_like(mc, k * cfg.sp), cfg)
                return acc3.hi, acc3.lo, exp3

            return jax.lax.fori_loop(0, cfg.num_cycles_static, cycle_body,
                                     (acc2.hi, acc2.lo, exp2))

        return jax.lax.fori_loop(0, len(pairs), iter_body,
                                 (acc_hi, acc_lo, exp_acc))

    z = jnp.zeros(batch_shape, jnp.int32)
    exp0 = jnp.full(batch_shape, NEG_INF_EXP, jnp.int32)
    hi, lo, exp_acc = jax.lax.fori_loop(0, g, group_body, (z, z, exp0))
    return fx.FX(hi, lo), exp_acc


@functools.lru_cache(maxsize=None)
def _jitted_fp_ip(cfg: IPUConfig):
    def f(a, b):
        acc, exp_acc = fp16_inner_product_raw(a, b, cfg)
        return fx.round_to_fp(acc, exp_acc, cfg.accum_format)
    return jax.jit(f)


def fp16_inner_product(a: jax.Array, b: jax.Array,
                       cfg: IPUConfig = IPUConfig()) -> jax.Array:
    """Approximate FP-IP (paper Fig. 2) rounded to the accumulator format.

    Returns float16 for cfg.accum='fp16', float32 for 'fp32'. Jitted and
    cached per config so repeated same-shape calls are cheap.
    """
    return _jitted_fp_ip(cfg)(a, b)


def int_inner_product(a: jax.Array, b: jax.Array, a_bits: int, b_bits: int,
                      cfg: IPUConfig = IPUConfig()) -> jax.Array:
    """INT-mode inner product over the last axis (paper §2.1). Exact.

    a, b: int32 arrays of two's-complement values fitting a_bits/b_bits.
    Nibble-decomposed and accumulated exactly as the hardware (result is
    bit-identical to the wide integer dot product). Returns int32.
    """
    a = jnp.asarray(a, jnp.int32)
    b = jnp.asarray(b, jnp.int32)
    a, b = jnp.broadcast_arrays(a, b)
    pa = nibble.int_planes(a, a_bits)
    pb = nibble.int_planes(b, b_bits)
    acc = fx.zero_like(a[..., 0])
    for i, p in enumerate(pa):
        for j, q in enumerate(pb):
            d = p * q
            s = jnp.sum(d, axis=-1)
            acc = fx.add(acc, fx.shl(fx.from_int32(s), 4 * (i + j)))
    out = acc.hi * (1 << fx.LIMB_BITS) + acc.lo  # caller range: < 2**31
    return out.astype(jnp.int32)


def fp16_inner_product_exact_fp32(a: jax.Array, b: jax.Array) -> jax.Array:
    """Reference: FP-IP in f32 (products exact, f32-rounded sum) — the
    'GPU-like' baseline used in accuracy comparisons, NOT the oracle."""
    return jnp.sum(a.astype(jnp.float32) * b.astype(jnp.float32), axis=-1)
