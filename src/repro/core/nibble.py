"""Temporal nibble decomposition for the 5b x 5b signed multiplier array.

The IPU's multipliers are 5-bit signed (paper §2): wide enough for a
*signed* 4-bit nibble (high nibble of a two's-complement operand, range
[-8, 7]) or an *unsigned* 4-bit nibble (low nibbles, range [0, 15]) — that
is exactly why 5-bit multipliers are used.

FP16 path (paper §2.2 "Converting numbers"): the 12-bit signed magnitude
M[11:0] is converted to three 5-bit operands::

    N2 = {M11 .. M7}        (sign + top 4 magnitude bits)
    N1 = {0, M6 .. M3}
    N0 = {0, M2 .. M0, 0}   (implicit left shift preserves accuracy)

We emulate each plane as a plain signed int32 carrying the operand's sign,
with plane weights 2**gamma_i, gamma = (-1, 3, 7) (the -1 accounts for
N0's implicit left-shift-by-one):

    signed_magnitude = n2*2**7 + n1*2**3 + n0*2**-1

INT path: a b-bit two's-complement integer is decomposed into
ceil(b/4) nibbles — unsigned low nibbles plus a signed top nibble — with
plane weights 16**i.
"""
from __future__ import annotations

from typing import List, Tuple

import jax
import jax.numpy as jnp

# Plane weights exponents for the FP16 mantissa decomposition:
# signed_mag = sum_i n_i * 2**GAMMA[i]
FP16_GAMMA: Tuple[int, int, int] = (-1, 3, 7)
FP16_NUM_PLANES = 3


def fp16_planes(sign: jax.Array, mag: jax.Array) -> List[jax.Array]:
    """Decompose an 11-bit magnitude + sign into 3 signed nibble planes.

    Returns [n0, n1, n2] (ascending significance), each int32 in
    [-30, 30] (n0 carries the implicit <<1), such that

        sign * mag = n2 * 2**7 + n1 * 2**3 + n0 * 2**-1.
    """
    n2 = sign * ((mag >> 7) & 0xF)
    n1 = sign * ((mag >> 3) & 0xF)
    n0 = sign * ((mag & 0x7) << 1)
    return [n0.astype(jnp.int32), n1.astype(jnp.int32), n2.astype(jnp.int32)]


def int_planes(x: jax.Array, bits: int) -> List[jax.Array]:
    """Decompose a two's-complement ``bits``-wide integer into nibbles.

    Low nibbles are unsigned in [0, 15]; the top nibble is signed. Planes
    are returned ascending, with value = sum_i plane_i * 16**i. ``bits``
    must be a multiple of 4 (pad operands before calling otherwise).
    """
    if bits % 4 != 0:
        raise ValueError(f"bits must be a multiple of 4, got {bits}")
    x = x.astype(jnp.int32)
    k = bits // 4
    planes = []
    for i in range(k):
        if i < k - 1:
            planes.append(((x >> (4 * i)) & 0xF).astype(jnp.int32))
        else:
            # top nibble: arithmetic shift keeps the sign
            planes.append((x >> (4 * i)).astype(jnp.int32))
    return planes


def num_nibble_iterations(a_bits: int, b_bits: int) -> int:
    """Total nibble iterations = product of operand nibble counts (paper §2).

    E.g. INT8 x INT12 -> 2 * 3 = 6; FP16 x FP16 -> 3 * 3 = 9.
    """
    return (a_bits // 4) * (b_bits // 4)


def int_iteration_shift(i: int, j: int, ka: int, kb: int) -> int:
    """Accumulator right-shift for INT-mode nibble iteration (i, j).

    Paper §2.1: 4 * ((Ka - i - 1) + (Kb - j - 1)).
    """
    return 4 * ((ka - i - 1) + (kb - j - 1))


def fp16_iteration_shift(i: int, j: int) -> int:
    """Accumulator right-shift for FP-mode nibble iteration (i, j) before
    exponent alignment. Paper §2.2: 4 * ((3-i-1) + (3-j-1)) = 4 * (4-i-j)."""
    return 4 * ((3 - i - 1) + (3 - j - 1))


# --- BF16 (paper Appendix B: 8-bit exponents, four nibble iterations) ---
# BF16 magnitude is 8 bits (1.mmmmmmm): two 4-bit nibbles with the sign
# carried on each plane, weights 16**i:  signed_mag = n1*16 + n0.
BF16_GAMMA: Tuple[int, int] = (0, 4)
BF16_NUM_PLANES = 2


def bf16_planes(sign: jax.Array, mag: jax.Array) -> List[jax.Array]:
    """Decompose an 8-bit magnitude + sign into 2 signed nibble planes."""
    n1 = sign * ((mag >> 4) & 0xF)
    n0 = sign * (mag & 0xF)
    return [n0.astype(jnp.int32), n1.astype(jnp.int32)]


def bf16_iteration_shift(i: int, j: int) -> int:
    """Accumulator right-shift for a BF16 nibble iteration: the K=2
    analogue of the §2.2 formula, 4 * ((2-i-1) + (2-j-1))."""
    return 4 * ((2 - i - 1) + (2 - j - 1))
