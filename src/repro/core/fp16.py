"""IEEE-754 binary16/binary32 field codecs as pure integer JAX ops.

The IPU datapath (paper §2.2, Appendix A.2) operates on the *signed
magnitude* and *unbiased exponent* of FP operands:

  value(a) = sign * mag * 2**(exp - MANT_BITS)

where ``mag`` is the integer magnitude including the hidden bit
(``1.mantissa`` for normals, ``0.mantissa`` for subnormals) and ``exp`` is
the unbiased exponent with the subnormal adjustment ``exp = 1 - bias``
(paper Fig. 12 note: "exp(x) = x's exponent - bias + 1 for subnormal").

All functions are jit/vmap-safe and use only int32 arithmetic, so they can
also be inlined into Pallas kernel bodies.
"""
from __future__ import annotations

from typing import NamedTuple, Tuple

import jax
import jax.numpy as jnp


class FPFormat(NamedTuple):
    """Static description of an IEEE-like binary FP format."""

    name: str
    exp_bits: int
    mant_bits: int  # explicit mantissa bits (no hidden bit)

    @property
    def bias(self) -> int:
        return (1 << (self.exp_bits - 1)) - 1

    @property
    def mag_bits(self) -> int:
        # magnitude incl. hidden bit
        return self.mant_bits + 1

    @property
    def min_exp(self) -> int:
        # unbiased exponent of subnormals and of the smallest normal
        return 1 - self.bias

    @property
    def max_exp(self) -> int:
        return (1 << self.exp_bits) - 2 - self.bias


FP16 = FPFormat("fp16", 5, 10)
BF16 = FPFormat("bf16", 8, 7)
FP32 = FPFormat("fp32", 8, 23)
# Nvidia TF32: 8-bit exponent, 10-bit mantissa (paper Appendix B).
TF32 = FPFormat("tf32", 8, 10)

FORMATS = {f.name: f for f in (FP16, BF16, FP32, TF32)}

_BITCAST_DTYPE = {16: jnp.uint16, 32: jnp.uint32}


def _storage_bits(fmt: FPFormat) -> int:
    return 16 if fmt.exp_bits + fmt.mant_bits + 1 <= 16 else 32


def _native_dtype(fmt: FPFormat):
    return {"fp16": jnp.float16, "bf16": jnp.bfloat16, "fp32": jnp.float32}[
        fmt.name
    ]


def decompose(x: jax.Array, fmt: FPFormat = FP16) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """Split an FP array into (sign, unbiased exp, integer magnitude).

    Returns int32 arrays with ``value = sign * mag * 2**(exp - fmt.mant_bits)``.
    sign is +-1 (sign of +-0 is +1 for magnitude 0; downstream arithmetic is
    insensitive to the sign of a zero magnitude). Inf/NaN are NOT handled by
    the IPU datapath (paper Fig. 12 assumes "neither INF nor NaN"): use
    :func:`is_finite` to validate inputs first.
    """
    if fmt is TF32:
        raise ValueError("TF32 has no native storage here; decompose from fp32")
    nbits = _storage_bits(fmt)
    bits = jax.lax.bitcast_convert_type(x, _BITCAST_DTYPE[nbits]).astype(jnp.int32)
    sign_bit = (bits >> (nbits - 1)) & 1
    sign = (1 - 2 * sign_bit).astype(jnp.int32)
    e = (bits >> fmt.mant_bits) & ((1 << fmt.exp_bits) - 1)
    m = bits & ((1 << fmt.mant_bits) - 1)
    is_sub = e == 0
    mag = jnp.where(is_sub, m, m | (1 << fmt.mant_bits)).astype(jnp.int32)
    exp = jnp.where(is_sub, fmt.min_exp, e - fmt.bias).astype(jnp.int32)
    return sign, exp, mag


def compose(sign: jax.Array, exp: jax.Array, mag: jax.Array, fmt: FPFormat = FP16) -> jax.Array:
    """Inverse of :func:`decompose` for in-range (sign, exp, mag) triples.

    Assumes canonical fields: for normals ``mag`` has the hidden bit set and
    ``exp`` in [min_exp, max_exp]; for subnormals ``exp == min_exp`` and
    ``mag < 2**mant_bits``. Exact (no rounding).
    """
    nbits = _storage_bits(fmt)
    is_sub = (mag < (1 << fmt.mant_bits)) | (exp < fmt.min_exp)
    e_field = jnp.where(is_sub, 0, exp + fmt.bias).astype(jnp.int32)
    m_field = (mag & ((1 << fmt.mant_bits) - 1)).astype(jnp.int32)
    sign_bit = jnp.where(sign < 0, 1, 0).astype(jnp.int32)
    bits = (sign_bit << (nbits - 1)) | (e_field << fmt.mant_bits) | m_field
    return jax.lax.bitcast_convert_type(
        bits.astype(_BITCAST_DTYPE[nbits]), _native_dtype(fmt)
    )


def make_inf(sign: jax.Array, fmt: FPFormat = FP16) -> jax.Array:
    """+-Inf with the given sign (+1/-1), as the format's native dtype."""
    nbits = _storage_bits(fmt)
    sign_bit = jnp.where(sign < 0, 1, 0).astype(jnp.int32)
    bits = (sign_bit << (nbits - 1)) | (((1 << fmt.exp_bits) - 1) << fmt.mant_bits)
    return jax.lax.bitcast_convert_type(
        bits.astype(_BITCAST_DTYPE[nbits]), _native_dtype(fmt)
    )


def is_finite(x: jax.Array, fmt: FPFormat = FP16) -> jax.Array:
    nbits = _storage_bits(fmt)
    bits = jax.lax.bitcast_convert_type(x, _BITCAST_DTYPE[nbits]).astype(jnp.int32)
    e = (bits >> fmt.mant_bits) & ((1 << fmt.exp_bits) - 1)
    return e != ((1 << fmt.exp_bits) - 1)


def product_exponent_range(fmt: FPFormat = FP16) -> Tuple[int, int]:
    """Range of the exponent of a product of two numbers of ``fmt``.

    For FP16: [-28, 30] (paper §2.2), hence worst-case alignment 58.
    """
    return 2 * fmt.min_exp, 2 * fmt.max_exp


def max_alignment(fmt: FPFormat = FP16) -> int:
    lo, hi = product_exponent_range(fmt)
    return hi - lo


def floor_log2(x: jax.Array) -> jax.Array:
    """floor(log2(x)) for int32 x in [1, 2**24). Exact via f32 frexp.

    Every int below 2**24 is exactly representable in f32, so frexp of the
    cast is exact and the returned exponent is floor(log2(x)) + 1.
    """
    _, e = jnp.frexp(x.astype(jnp.float32))
    return (e - 1).astype(jnp.int32)
