"""Analytical 7nm area/power model of MC-IPU convolution tiles.

The paper evaluates synthesized SystemVerilog (Synopsys DC, 7nm, 0.71V,
25% margin). Gate-level synthesis cannot run here, so we model each
datapath component with first-order gate-count scaling laws and calibrate
the unit constants against the paper's published numbers (Fig. 7
breakdown, Table 1 efficiency matrix, §4.2 deltas: 38b->28b adder saves
15-17% tile area; 12b adder saves up to 39%; FP16 support on MC-IPU(12)
costs +43% over INT-only).

Component laws (standard-cell first-order):
  multiplier (a x b bits)     ~ alpha_m * (a+1) * (b+1)   (array of FAs)
  adder tree (n inputs, w)    ~ alpha_a * (n - 1) * (w + log2(n)/2)
  barrel shifter (w wide, r range) ~ alpha_s * w * log2(r)
  registers / SRAM            ~ alpha_r / alpha_sram * bits
  EHU                         ~ adders + max-tree + compare on exponents
  fixed control per IPU       ~ ctrl_area                 (pipeline regs)
  misc control                ~ fixed fraction of datapath

Power uses per-component activity-weighted constants fitted the same way.
The calibration is produced by tools/calibrate_area.py (least squares over
Table 1 cells + Fig. 7 deltas) and frozen in DEFAULT_CAL; tests assert the
model reproduces the paper's tables within tolerance.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Dict, List, Optional, Tuple

from repro.core.simulator import (FP4, FP8, FP16, INT4, INT8, INT8x4,
                                  OperandTypes, TileConfig,
                                  iterations_per_group)

F_CLK = 0.488e9  # Hz — matches the paper's 4-TOPS big-tile baseline


@dataclasses.dataclass(frozen=True)
class Calibration:
    """Unit-cost constants (um^2 / mW-per-um^2 classes), fitted by
    tools/calibrate_area.py against the paper's published numbers."""

    a_scale: float = 0.1723
    b_scale: float = 9.64
    alpha_mult: float = 0.95
    alpha_add: float = 1.10
    alpha_shift: float = 0.42
    alpha_reg: float = 0.65
    alpha_sram: float = 0.30
    alpha_and: float = 0.08
    ctrl_area: float = 0.0       # fixed um^2-units per IPU
    misc_fraction: float = 0.18
    serial_area_factor: float = 0.5
    serial_power_factor: float = 1.8
    beta_mult: float = 1.05e-3
    beta_adder: float = 0.95e-3
    beta_shift: float = 0.80e-3
    beta_reg: float = 0.55e-3
    beta_sram: float = 0.25e-3
    beta_ehu: float = 0.70e-3
    beta_ctrl: float = 0.55e-3

    def alpha(self, name: str) -> float:
        return getattr(self, f"alpha_{name}") * self.a_scale

    def beta(self, name: str) -> float:
        return getattr(self, f"beta_{name}") * self.b_scale


# Frozen output of tools/calibrate_area.py (least squares over Table 1
# cells, Fig. 7 deltas, and abstract headline gains):
#   table1 median |err| 3.0%, max 14.3%
#   fig7 deltas: -17.7% / -43.0% / +44.4% (targets -17 / -39 / +43)
DEFAULT_CAL = Calibration(
    a_scale=0.304053,
    b_scale=9.91956,
    alpha_add=0.2,
    alpha_shift=0.445554,
    alpha_reg=1.38445,
    alpha_sram=0.161124,
    ctrl_area=400,
    serial_area_factor=0.1,
    serial_power_factor=1.0,
    beta_mult=0.00112049,
    beta_reg=0.000184674,
    beta_sram=0.0001012,
    misc_fraction=0.5,
)


@dataclasses.dataclass(frozen=True)
class IPUDesign:
    """One design point of the sensitivity study (§4.5, Table 1)."""

    name: str
    mult_a: int = 4            # multiplier operand bits (activation side)
    mult_b: int = 4            # weight side
    adder_w: int = 16          # adder tree precision (w)
    fp_support: bool = True
    tile: TileConfig = TileConfig()
    cluster_size: Optional[int] = None  # None -> no clustering
    # average MC alignment cycles per nibble iteration for FP16 workloads;
    # produced by the simulator (simulate_network().slowdown); 1.0 = never
    # multi-cycle (wide adder).
    fp_mc_factor: float = 1.0
    # FP16 iterations override. The paper's 8x8-based designs compute an
    # FP16 mantissa product in 2 cycles (NVDLA-style spatial decomposition
    # into two INT8 units — visible in Table 1's INT8:FP16 ratio of ~2),
    # not the naive ceil(12/8)**2 = 4; serial designs pay extra passes.
    fp16_iters: Optional[float] = None

    def n_inputs(self) -> int:
        return self.tile.c_unroll

    def supports(self, t: OperandTypes) -> bool:
        if t.is_fp and not self.fp_support:
            return False
        return True

    def iterations(self, t: OperandTypes) -> float:
        """Nibble/serial iterations per inner product for a workload.

        FP iterations scale with the operand *significand* widths
        (OperandTypes carries them: 12 for FP16, 4 for fp8 e4m3, 2 for
        fp4 e2m1); the ``fp16_iters`` override models 12-bit-specific
        decompositions (NVDLA dual-INT8, serial double pass) and so
        applies only to full-width (>= 12b) significands."""
        if t.is_fp:
            if self.fp16_iters is not None and min(t.a_bits,
                                                   t.b_bits) >= 12:
                it = self.fp16_iters
            else:
                it = ((-(-t.a_bits // self.mult_a))
                      * (-(-t.b_bits // self.mult_b)))
            return it * self.fp_mc_factor
        ia = -(-t.a_bits // self.mult_a)
        ib = -(-t.b_bits // self.mult_b)
        return ia * ib


# ------------------------------------------------------------ area model

def _log2(x: float) -> float:
    return math.log2(max(x, 2.0))


def ipu_component_areas(d: IPUDesign, cal: Calibration = None
                        ) -> Dict[str, float]:
    """um^2 per IPU, by component (paper Fig. 7 categories + CTRL)."""
    cal = cal or DEFAULT_CAL
    n = d.n_inputs()
    w = d.adder_w
    areas: Dict[str, float] = {}
    areas["MULT"] = n * cal.alpha("mult") * (d.mult_a + 1) * (d.mult_b + 1)
    if d.mult_b == 1:
        # Serial (Stripes-like) datapath: the "multiplier" is an AND row —
        # smaller than the array-multiplier law predicts (fitted factor).
        areas["MULT"] *= cal.serial_area_factor
    # adder tree over n products of width w
    areas["AT"] = cal.alpha("add") * (n - 1) * (w + _log2(n) / 2)
    if d.fp_support:
        # local right-shifters: one per multiplier, w wide, range w
        areas["Shft"] = n * cal.alpha("shift") * w * _log2(w)
        # EHU share: exponent adders (6b), max tree, subtract + compare;
        # amortized over tile.ehu_share IPUs
        ehu = (n * cal.alpha("add") * 6 * 2 + (n - 1) * cal.alpha("add") * 6
               + n * cal.alpha("add") * 6 + n * cal.alpha("reg") * 8)
        areas["ShCNT"] = ehu / d.tile.ehu_share
        # masking ANDs for MC service (9b products)
        areas["Shft"] += n * cal.alpha("and") * 9
    else:
        areas["Shft"] = 0.0
        areas["ShCNT"] = 0.0
    # accumulator: register + shifter + adder. INT-only designs carry a
    # narrower fixed-point accumulator.
    t_bits = math.ceil(_log2(n))
    acc_bits = (33 + t_bits + 10) if d.fp_support else (
        d.mult_a + d.mult_b + 4 + t_bits + 10)
    areas["FAcc"] = (cal.alpha("reg") * acc_bits
                     + cal.alpha("shift") * acc_bits * _log2(acc_bits)
                     + cal.alpha("add") * acc_bits)
    # weight buffer: depth bytes x n multipliers x 8 bits
    areas["WBuf"] = cal.alpha("sram") * d.tile.weight_buf_depth * 8 * n
    # fixed per-IPU control/pipeline registers
    areas["CTRL"] = cal.ctrl_area * cal.a_scale
    return areas


_POWER_CLASS = {"MULT": "mult", "AT": "adder", "Shft": "shift",
                "ShCNT": "ehu", "FAcc": "reg", "WBuf": "sram",
                "CTRL": "ctrl"}


def tile_area_mm2(d: IPUDesign, cal: Calibration = None) -> float:
    cal = cal or DEFAULT_CAL
    per_ipu = sum(ipu_component_areas(d, cal).values())
    n_ipus = d.tile.ipus_per_tile
    total = per_ipu * n_ipus * (1 + cal.misc_fraction)
    # cluster buffers (input/output per cluster, §3.3)
    if d.cluster_size:
        n_clusters = max(n_ipus // d.cluster_size, 1)
        total += n_clusters * cal.alpha("sram") * 2 * 64 * 8  # 2x 64B bufs
    return total * d.tile.n_tiles / 1e6


def tile_power_w(d: IPUDesign, cal: Calibration = None) -> float:
    cal = cal or DEFAULT_CAL
    areas = ipu_component_areas(d, cal)
    mw = sum(areas[k] * cal.beta(_POWER_CLASS[k]) for k in areas)
    if d.mult_b == 1:
        # Serial datapath toggles its full pipeline every cycle (weight-bit
        # serializers + per-cycle accumulator writes): fitted activity.
        mw *= cal.serial_power_factor
    n_ipus = d.tile.ipus_per_tile
    mw = mw * n_ipus * (1 + cal.misc_fraction * 0.5)
    return mw * d.tile.n_tiles / 1e3


def area_breakdown(d: IPUDesign, cal: Calibration = None) -> Dict[str, float]:
    """Fig. 7(a): per-component fraction of tile area."""
    areas = ipu_component_areas(d, cal)
    tot = sum(areas.values())
    return {k: v / tot for k, v in areas.items()}


def power_breakdown(d: IPUDesign, cal: Calibration = None) -> Dict[str, float]:
    cal = cal or DEFAULT_CAL
    areas = ipu_component_areas(d, cal)
    pw = {k: areas[k] * cal.beta(_POWER_CLASS[k]) for k in areas}
    tot = sum(pw.values())
    return {k: v / tot for k, v in pw.items()}


# ------------------------------------------------------- efficiency model

def throughput_tops(d: IPUDesign, t: OperandTypes) -> Optional[float]:
    """Tera-ops/s for a workload type (Table 1 'TOPS'). The paper counts a
    MAC as 2 ops (§4.1: the 1024-MAC small tile is '1 TOPS')."""
    if not d.supports(t):
        return None
    macs_per_cycle = d.tile.macs_per_cycle  # at 1 iteration
    return 2 * macs_per_cycle * F_CLK / d.iterations(t) / 1e12


def efficiency(d: IPUDesign, t: OperandTypes, cal: Calibration = None
               ) -> Tuple[Optional[float], Optional[float]]:
    """(TOPS/mm^2, TOPS/W) for a design x workload (Table 1 cells)."""
    tops = throughput_tops(d, t)
    if tops is None:
        return None, None
    return tops / tile_area_mm2(d, cal), tops / tile_power_w(d, cal)


# ------------------------------------------------------ paper design set

def _big(**kw) -> TileConfig:
    return dataclasses.replace(TileConfig(), **kw)


def paper_designs(fp_mc_factors: Optional[Dict[str, float]] = None
                  ) -> Dict[str, IPUDesign]:
    """The §4.5 / Table 1 design points. ``fp_mc_factors`` supplies the
    simulator-derived mean alignment cycles per iteration (defaults to the
    values measured by benchmarks/fig8_perf.py on the forward study
    cases; 1.0 for wide-adder designs)."""
    f = {"MC-SER": 1.15, "MC-IPU4": 1.30, "MC-IPU84": 1.22,
         "MC-IPU8": 1.06}
    if fp_mc_factors:
        f.update(fp_mc_factors)
    D = IPUDesign
    designs = {
        "MC-SER": D("MC-SER", 12, 1, 16, True, _big(), 1, f["MC-SER"],
                    fp16_iters=24),  # serial sign-magnitude double pass
        "MC-IPU4": D("MC-IPU4", 4, 4, 16, True, _big(), 1, f["MC-IPU4"]),
        "MC-IPU84": D("MC-IPU84", 8, 4, 20, True, _big(), 1, f["MC-IPU84"]),
        "MC-IPU8": D("MC-IPU8", 8, 8, 23, True, _big(), 1, f["MC-IPU8"],
                     fp16_iters=2),  # spatial dual-INT8 decomposition
        "NVDLA": D("NVDLA", 8, 8, 36, True, _big(), None, 1.0,
                   fp16_iters=2),
        "FP16": D("FP16", 12, 12, 36, True, _big(), None, 1.0, fp16_iters=1),
        "INT8": D("INT8", 8, 8, 16, False, _big(), None, 1.0),
        "INT4": D("INT4", 4, 4, 9, False, _big(), None, 1.0),
    }
    return designs


def baseline_design(n_inputs: int = 16) -> IPUDesign:
    """'Typical mixed-precision implementation': 4x4 multipliers with a
    38-bit adder tree and no clustering (Baseline1/2 of §4.1)."""
    tile = TileConfig() if n_inputs == 16 else dataclasses.replace(
        TileConfig(), c_unroll=8, k_unroll=8)
    return IPUDesign("baseline", 4, 4, 38, True, tile, None, 1.0)


def optimized_design(n_inputs: int = 16, w: int = 16, cluster: int = 1,
                     fp_mc_factor: float = 1.3) -> IPUDesign:
    tile = TileConfig() if n_inputs == 16 else dataclasses.replace(
        TileConfig(), c_unroll=8, k_unroll=8)
    tile = dataclasses.replace(tile, adder_w=w, cluster_size=cluster)
    return IPUDesign(f"mcipu({w},{cluster})", 4, 4, w, True, tile, cluster,
                     fp_mc_factor)


# Table 1 of the paper, for side-by-side reporting and tolerance tests.
PAPER_TABLE1 = {
    # design: {workload: (TOPS/mm2, TOPS/W)}
    "MC-SER":   {"4x4": (5.5, 1.4), "8x4": (5.5, 1.4), "8x8": (2.8, 0.7),
                 "fp16": (0.9, 0.2)},
    "MC-IPU4":  {"4x4": (18.8, 3.3), "8x4": (9.4, 1.7), "8x8": (4.7, 0.8),
                 "fp16": (1.6, 0.3)},
    "MC-IPU84": {"4x4": (14.3, 2.4), "8x4": (14.3, 2.4), "8x8": (7.2, 1.2),
                 "fp16": (1.8, 0.3)},
    "MC-IPU8":  {"4x4": (11.4, 1.8), "8x4": (11.4, 1.8), "8x8": (11.4, 1.8),
                 "fp16": (5.4, 0.8)},
    "NVDLA":    {"4x4": (9.7, 1.5), "8x4": (9.7, 1.5), "8x8": (9.7, 1.5),
                 "fp16": (4.9, 0.7)},
    "FP16":     {"4x4": (6.9, 0.9), "8x4": (6.9, 0.9), "8x8": (6.9, 0.9),
                 "fp16": (6.9, 0.9)},
    "INT8":     {"4x4": (18.5, 2.8), "8x4": (18.5, 2.8), "8x8": (18.5, 2.8),
                 "fp16": (None, None)},
    "INT4":     {"4x4": (30.6, 5.6), "8x4": (15.3, 2.8), "8x8": (7.7, 1.4),
                 "fp16": (None, None)},
}

WORKLOAD_TYPES = {"4x4": INT4, "8x4": INT8x4, "8x8": INT8, "fp16": FP16}

# fp storage-tier workloads (not Table 1 columns — the paper evaluates
# fp16 only; these score the fp8/fp4 prepared-weight modes the serving
# stack deploys, on the same alignment datapath with narrower
# significand iteration counts)
FP_STORAGE_TYPES = {"fp8": FP8, "fp4": FP4}

# §4.2 relative deltas (16-input tiles)
PAPER_FIG7_DELTAS = {
    "adder_38_to_28": -0.17,
    "adder_38_to_12": -0.39,
    "int_to_mcipu12": +0.43,
}


def fig7_deltas(cal: Calibration = None) -> Dict[str, float]:
    def tile_fp(w):
        return IPUDesign("x", 4, 4, w, True, TileConfig())
    a38 = tile_area_mm2(tile_fp(38), cal)
    a28 = tile_area_mm2(tile_fp(28), cal)
    a12 = tile_area_mm2(tile_fp(12), cal)
    aint = tile_area_mm2(IPUDesign("int", 4, 4, 9, False, TileConfig()), cal)
    return {
        "adder_38_to_28": a28 / a38 - 1,
        "adder_38_to_12": a12 / a38 - 1,
        "int_to_mcipu12": a12 / aint - 1,
    }


def table1_model(cal: Calibration = None
                 ) -> Dict[str, Dict[str, Tuple[Optional[float],
                                                Optional[float]]]]:
    """Model-predicted Table 1 (same keys as PAPER_TABLE1)."""
    out = {}
    for name, d in paper_designs().items():
        row = {}
        for wl, t in WORKLOAD_TYPES.items():
            row[wl] = efficiency(d, t, cal)
        out[name] = row
    return out


def headline_gains(fp_mc_factor_16: float = 1.3,
                   cal: Calibration = None) -> Dict[str, float]:
    """Abstract-style headline: the Pareto design (16-input, w=16,
    cluster=1) vs the typical mixed-precision baseline (same 4x4
    multipliers, 38-bit adder tree, no clustering) — TOPS for INT4 and
    TFLOPS for FP16, area and power efficiency gains."""
    base = baseline_design(16)
    opt = optimized_design(16, w=16, cluster=1, fp_mc_factor=fp_mc_factor_16)
    out = {}
    for wl in ("4x4", "fp16"):
        t = WORKLOAD_TYPES[wl]
        ba, bp = efficiency(base, t, cal)
        oa, op_ = efficiency(opt, t, cal)
        key = "tops" if wl == "4x4" else "tflops"
        out[f"{key}_per_mm2_gain"] = oa / ba - 1
        out[f"{key}_per_w_gain"] = op_ / bp - 1
    return out
