"""InternVL2-1B [arXiv:2404.16821; hf:OpenGVLab/InternVL2-1B].

Qwen2-0.5B language backbone (24L, d_model 896, 14 heads GQA kv=2,
d_ff 4864, vocab 151655) + InternViT stub frontend: input_specs()
provides precomputed patch embeddings (256 tokens after pixel-shuffle,
dim 1024) mapped through a 2-layer MLP projector.
"""
from repro.configs.base import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        arch_id="internvl2-1b",
        family="vlm",
        n_layers=24,
        d_model=896,
        n_heads=14,
        n_kv_heads=2,
        head_dim=64,
        d_ff=4864,
        vocab=151655,
        qkv_bias=True,
        norm="rms",
        act="silu",
        rope_theta=1e6,
        attn_pattern="full",
        tied_embeddings=True,
        n_patches=256,
        vit_dim=1024,
    )
