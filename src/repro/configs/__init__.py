"""Architecture registry: get_config(arch_id) / reduced(arch_id).

Each <arch>.py holds the exact published configuration; ``reduced()``
produces a family-preserving tiny variant for CPU smoke tests (same
block pattern, same attention/MoE/recurrence structure, small widths).
"""
from __future__ import annotations

import dataclasses

from repro.configs.base import (InputShape, ModelConfig, MoESpec, SHAPES,
                                shape_applicable)
from repro.configs import (gemma2_9b, glm4_9b, internvl2_1b, mixtral_8x7b,
                           qwen2_0_5b, qwen3_moe_30b_a3b, recurrentgemma_9b,
                           rwkv6_1_6b, seamless_m4t_medium, stablelm_12b)

_MODULES = {
    "qwen2-0.5b": qwen2_0_5b,
    "gemma2-9b": gemma2_9b,
    "stablelm-12b": stablelm_12b,
    "glm4-9b": glm4_9b,
    "rwkv6-1.6b": rwkv6_1_6b,
    "seamless-m4t-medium": seamless_m4t_medium,
    "mixtral-8x7b": mixtral_8x7b,
    "qwen3-moe-30b-a3b": qwen3_moe_30b_a3b,
    "recurrentgemma-9b": recurrentgemma_9b,
    "internvl2-1b": internvl2_1b,
}

ARCH_IDS = tuple(_MODULES)


def get_config(arch_id: str) -> ModelConfig:
    return _MODULES[arch_id].config()


def reduced(arch_id: str) -> ModelConfig:
    """Family-preserving tiny config for CPU smoke tests."""
    cfg = get_config(arch_id)
    kv = max(1, min(cfg.n_kv_heads, 2))
    moe = None
    if cfg.moe:
        moe = MoESpec(n_experts=min(cfg.moe.n_experts, 4),
                      top_k=min(cfg.moe.top_k, 2), d_expert=32,
                      capacity_factor=2.0)
    n_layers = {"lm": 2, "rwkv": 2, "vlm": 2, "encdec": 2,
                "griffin": 5}[cfg.family]
    if cfg.attn_pattern == "alt_local_global":
        n_layers = 2
    return dataclasses.replace(
        cfg,
        n_layers=n_layers,
        d_model=64,
        n_heads=4,
        n_kv_heads=kv,
        head_dim=16,
        d_ff=128,
        vocab=512,
        moe=moe,
        d_rnn=64 if cfg.d_rnn else None,
        window=min(cfg.window, 16) if cfg.window else None,
        n_enc_layers=2 if cfg.n_enc_layers else None,
        frontend_dim=16 if cfg.frontend_dim else None,
        n_patches=8 if cfg.n_patches else None,
        vit_dim=32 if cfg.vit_dim else None,
        remat="none",
    )
