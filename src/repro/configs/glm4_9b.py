"""GLM-4 9B [hf:THUDM/glm-4-9b].

40L, d_model 4096, 32 heads (GQA kv=2, head_dim 128), d_ff 13696,
vocab 151552. QKV bias, partial rotary (50%, GLM 2D RoPE approximated as
half-rotary), RMSNorm, SwiGLU, untied.
"""
from repro.configs.base import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        arch_id="glm4-9b",
        family="lm",
        n_layers=40,
        d_model=4096,
        n_heads=32,
        n_kv_heads=2,
        head_dim=128,
        d_ff=13696,
        vocab=151552,
        qkv_bias=True,
        norm="rms",
        act="silu",
        rotary_pct=0.5,
        attn_pattern="full",
        tied_embeddings=False,
    )
