"""Qwen2-0.5B [arXiv:2407.10671; hf:Qwen/Qwen2-0.5B].

24L, d_model 896, 14 heads (GQA kv=2, head_dim 64), d_ff 4864,
vocab 151936. QKV bias, RMSNorm, SwiGLU, tied embeddings, rope 1e6.
"""
from repro.configs.base import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        arch_id="qwen2-0.5b",
        family="lm",
        n_layers=24,
        d_model=896,
        n_heads=14,
        n_kv_heads=2,
        head_dim=64,
        d_ff=4864,
        vocab=151936,
        qkv_bias=True,
        norm="rms",
        act="silu",
        rope_theta=1e6,
        attn_pattern="full",
        tied_embeddings=True,
    )
