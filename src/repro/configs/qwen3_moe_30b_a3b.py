"""Qwen3-MoE 30B-A3B [hf:Qwen/Qwen3-30B-A3B].

48L, d_model 2048, 32 heads (GQA kv=4, head_dim 128), vocab 151936,
MoE: 128 experts, top-8, d_expert 768. QK-norm, no QKV bias, full
attention, rope 1e6.
"""
from repro.configs.base import ModelConfig, MoESpec


def config() -> ModelConfig:
    return ModelConfig(
        arch_id="qwen3-moe-30b-a3b",
        family="lm",
        n_layers=48,
        d_model=2048,
        n_heads=32,
        n_kv_heads=4,
        head_dim=128,
        d_ff=768,
        vocab=151936,
        norm="rms",
        act="silu",
        qk_norm=True,
        rope_theta=1e6,
        attn_pattern="full",
        moe=MoESpec(n_experts=128, top_k=8, d_expert=768),
        tied_embeddings=False,
    )
