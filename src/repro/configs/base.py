"""Architecture config schema + the assigned input-shape grid."""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple


@dataclasses.dataclass(frozen=True)
class MoESpec:
    n_experts: int
    top_k: int
    d_expert: int
    capacity_factor: float = 1.25
    dispatch: str = "einsum"   # einsum | gather (see layers/moe.py)


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    """One architecture. ``family`` selects the model implementation:
    'lm' (decoder-only), 'encdec', 'rwkv', 'griffin', 'vlm'."""

    arch_id: str
    family: str
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    head_dim: Optional[int] = None          # default d_model // n_heads
    act: str = "silu"
    norm: str = "rms"                       # rms | rms_zc | ln
    qkv_bias: bool = False
    qk_norm: bool = False
    rope_theta: float = 10000.0
    rotary_pct: float = 1.0
    # attention pattern: 'full' | 'swa' | 'alt_local_global' (gemma-2)
    attn_pattern: str = "full"
    window: Optional[int] = None
    logit_softcap: Optional[float] = None
    attn_softcap: Optional[float] = None
    post_norms: bool = False                # gemma-2 post-block norms
    tied_embeddings: bool = True
    attn_scale: Optional[float] = None
    moe: Optional[MoESpec] = None
    # rwkv / griffin
    d_rnn: Optional[int] = None
    conv_width: int = 4
    rec_pattern: Tuple[str, ...] = ()       # e.g. ('rec','rec','attn')
    # encdec
    n_enc_layers: Optional[int] = None
    frontend_dim: Optional[int] = None      # stub modality embedding dim
    # vlm
    n_patches: Optional[int] = None
    vit_dim: Optional[int] = None
    # numerics / policy
    precision_policy: str = "bf16"
    param_dtype: str = "float32"
    compute_dtype: str = "bfloat16"
    remat: str = "full"                     # none | dots | full

    @property
    def head_dim_(self) -> int:
        return self.head_dim or self.d_model // self.n_heads

    @property
    def padded_vocab(self) -> int:
        """Embedding rows padded to a shardable multiple of 64 (the extra
        logit columns are masked in the head; see models/*._head)."""
        return -(-self.vocab // 64) * 64

    @property
    def sub_quadratic(self) -> bool:
        """Bounded state/window per token -> long_500k-capable."""
        if self.family in ("rwkv", "griffin"):
            return True
        return self.attn_pattern == "swa" and self.window is not None

    def params_count(self) -> int:
        """Approximate parameter count (embeddings included once)."""
        d, hd = self.d_model, self.head_dim_
        attn = d * hd * (self.n_heads + 2 * self.n_kv_heads) \
            + self.n_heads * hd * d
        if self.moe:
            ffn = 3 * d * self.moe.d_expert * self.moe.n_experts \
                + d * self.moe.n_experts
        else:
            ffn = 3 * d * self.d_ff
        layers = self.n_layers * (attn + ffn)
        emb = self.vocab * d * (1 if self.tied_embeddings else 2)
        return layers + emb

    def active_params_count(self) -> int:
        if not self.moe:
            return self.params_count()
        d = self.d_model
        hd = self.head_dim_
        attn = d * hd * (self.n_heads + 2 * self.n_kv_heads) \
            + self.n_heads * hd * d
        ffn = 3 * d * self.moe.d_expert * self.moe.top_k
        return self.n_layers * (attn + ffn) + self.vocab * d


@dataclasses.dataclass(frozen=True)
class InputShape:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # 'train' | 'prefill' | 'decode'


SHAPES = {
    "train_4k": InputShape("train_4k", 4096, 256, "train"),
    "prefill_32k": InputShape("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": InputShape("decode_32k", 32768, 128, "decode"),
    "long_500k": InputShape("long_500k", 524288, 1, "decode"),
}


def shape_applicable(cfg: ModelConfig, shape: InputShape) -> bool:
    """long_500k needs sub-quadratic attention (assignment rule)."""
    if shape.name == "long_500k":
        return cfg.sub_quadratic
    return True
