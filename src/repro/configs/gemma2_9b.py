"""Gemma-2 9B [arXiv:2408.00118; hf:google/gemma-2-9b].

42L, d_model 3584, 16 heads (GQA kv=8, head_dim 256), d_ff 14336,
vocab 256000. Alternating local(4096)/global attention, logit softcap 30,
attention softcap 50, GeGLU, zero-centered RMSNorm with pre+post block
norms, query scale 1/sqrt(256), tied embeddings.
"""
from repro.configs.base import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        arch_id="gemma2-9b",
        family="lm",
        n_layers=42,
        d_model=3584,
        n_heads=16,
        n_kv_heads=8,
        head_dim=256,
        d_ff=14336,
        vocab=256000,
        norm="rms_zc",
        act="gelu_tanh",
        attn_pattern="alt_local_global",
        window=4096,
        logit_softcap=30.0,
        attn_softcap=50.0,
        post_norms=True,
        attn_scale=0.0625,  # 1/sqrt(query_pre_attn_scalar=256)
        tied_embeddings=True,
    )
