"""StableLM-2 12B [hf:stabilityai/stablelm-2-12b; arXiv:2402.17834].

40L, d_model 5120, 32 heads (GQA kv=8, head_dim 160), d_ff 13824,
vocab 100352. LayerNorm, partial rotary (25%), SwiGLU, untied.
"""
from repro.configs.base import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        arch_id="stablelm-12b",
        family="lm",
        n_layers=40,
        d_model=5120,
        n_heads=32,
        n_kv_heads=8,
        head_dim=160,
        d_ff=13824,
        vocab=100352,
        norm="ln",
        act="silu",
        rotary_pct=0.25,
        attn_pattern="full",
        tied_embeddings=False,
    )
