"""RecurrentGemma-9B (Griffin) [arXiv:2402.19427].

38L, d_model 4096, RG-LRU recurrence + local attention 1:2
(rec, rec, attn triples; 2 trailing rec), 16 heads MQA (kv=1,
head_dim 256), d_ff 12288, d_rnn 4096, window 2048, vocab 256000.
Gemma-style zero-centered RMSNorm + GeGLU. Sub-quadratic.
"""
from repro.configs.base import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        arch_id="recurrentgemma-9b",
        family="griffin",
        n_layers=38,
        d_model=4096,
        n_heads=16,
        n_kv_heads=1,
        head_dim=256,
        d_ff=12288,
        vocab=256000,
        norm="rms_zc",
        act="gelu_tanh",
        attn_pattern="swa",
        window=2048,
        d_rnn=4096,
        conv_width=4,
        rec_pattern=("rec", "rec", "attn"),
        tied_embeddings=True,
    )
