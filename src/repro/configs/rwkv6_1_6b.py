"""RWKV-6 "Finch" 1.6B [arXiv:2404.05892].

24L, d_model 2048, attention-free (32 heads of size 64 in the wkv state),
d_ff 7168, vocab 65536. Data-dependent decay via LoRA; LayerNorm;
sub-quadratic (long_500k-capable).
"""
from repro.configs.base import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        arch_id="rwkv6-1.6b",
        family="rwkv",
        n_layers=24,
        d_model=2048,
        n_heads=32,          # wkv heads (head size 64)
        n_kv_heads=32,
        d_ff=7168,
        vocab=65536,
        norm="ln",
        tied_embeddings=False,
    )
