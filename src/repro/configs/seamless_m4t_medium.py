"""SeamlessM4T-medium backbone [arXiv:2308.11596].

Enc-dec transformer: 12 encoder + 12 decoder layers, d_model 1024,
16 heads (MHA, kv=16), d_ff 4096, vocab 256206. The speech frontend is a
STUB per the assignment: input_specs() provides precomputed frame
embeddings (seq/4 frames at dim 160); positions use RoPE as the backbone
approximation (documented in DESIGN.md).
"""
from repro.configs.base import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        arch_id="seamless-m4t-medium",
        family="encdec",
        n_layers=12,            # decoder layers
        n_enc_layers=12,
        d_model=1024,
        n_heads=16,
        n_kv_heads=16,
        head_dim=64,
        d_ff=4096,
        vocab=256206,
        norm="ln",
        act="gelu",
        frontend_dim=160,
        attn_pattern="full",
        tied_embeddings=False,
    )
