"""Mixtral 8x7B [arXiv:2401.04088; hf:mistralai/Mixtral-8x7B-v0.1].

32L, d_model 4096, 32 heads (GQA kv=8, head_dim 128), vocab 32000,
MoE: 8 experts, top-2, d_expert 14336. Sliding-window attention (4096)
bounds the KV cache -> long_500k-capable.
"""
from repro.configs.base import ModelConfig, MoESpec


def config() -> ModelConfig:
    return ModelConfig(
        arch_id="mixtral-8x7b",
        family="lm",
        n_layers=32,
        d_model=4096,
        n_heads=32,
        n_kv_heads=8,
        head_dim=128,
        d_ff=14336,
        vocab=32000,
        norm="rms",
        act="silu",
        rope_theta=1e6,
        attn_pattern="swa",
        window=4096,
        moe=MoESpec(n_experts=8, top_k=2, d_expert=14336),
        tied_embeddings=False,
    )
