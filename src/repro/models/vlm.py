"""InternVL2-style VLM: stub vision frontend + decoder-LM backbone.

Per the assignment the modality frontend is a STUB — ``input_specs()``
provides precomputed patch embeddings (B, n_patches, vit_dim). An MLP
projector maps them into the LM embedding space; the sequence is
[patch embeddings ; token embeddings], loss/logits over token positions.
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.core.policy import get_policy
from repro.layers.common import apply_norm
from repro.layers.mplinear import linear_init, mp_linear
from repro.models import lm as lm_model


def init(key, cfg: ModelConfig):
    k1, k2, k3 = jax.random.split(key, 3)
    dtype = jnp.dtype(cfg.param_dtype)
    params = lm_model.init(k1, cfg)
    params["projector"] = {
        "fc1": linear_init(k2, cfg.vit_dim, cfg.d_model, True, dtype),
        "fc2": linear_init(k3, cfg.d_model, cfg.d_model, True, dtype),
    }
    return params


def _project(params, cfg: ModelConfig, patches):
    policy = get_policy(cfg.precision_policy)
    x = patches.astype(jnp.dtype(cfg.compute_dtype))
    x = mp_linear(params["projector"]["fc1"], x,
                  policy.spec_for("projector/fc1"), path="projector/fc1")
    x = jax.nn.gelu(x.astype(jnp.float32)).astype(x.dtype)
    return mp_linear(params["projector"]["fc2"], x,
                     policy.spec_for("projector/fc2"), path="projector/fc2")


def _prefix_seq(params, cfg: ModelConfig, tokens, patches):
    pe = _project(params, cfg, patches)            # (B, P, d)
    te = lm_model._embed(params, cfg, tokens)      # (B, S, d)
    return jnp.concatenate([pe, te], axis=1)


def train_logits(params, cfg: ModelConfig, tokens, patches):
    x = _prefix_seq(params, cfg, tokens, patches)
    b, s, _ = x.shape
    positions = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32), (b, s))
    x, aux, _ = lm_model._run_blocks(params, cfg, x, positions, "train")
    x = apply_norm(cfg.norm, x, params["final_norm"])
    n_p = patches.shape[1]
    return lm_model._head(params, cfg, x[:, n_p:]), aux


def loss_fn(params, cfg: ModelConfig, batch):
    from repro.models.losses import fused_chunked_xent
    tokens = batch["tokens"]
    inp, tgt = tokens[:, :-1], tokens[:, 1:]
    x = _prefix_seq(params, cfg, inp, batch["patches"])
    b, s, _ = x.shape
    positions = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32), (b, s))
    x, aux, _ = lm_model._run_blocks(params, cfg, x, positions, "train")
    x = apply_norm(cfg.norm, x, params["final_norm"])
    n_p = batch["patches"].shape[1]
    loss, m = fused_chunked_xent(
        x[:, n_p:], lambda xc: lm_model._head(params, cfg, xc), tgt)
    return loss + 0.01 * aux, {**m, "aux": aux}


def init_cache(cfg: ModelConfig, batch: int, max_len: int,
               dtype=jnp.bfloat16):
    return lm_model.init_cache(cfg, batch, max_len, dtype)


def prefill(params, cfg: ModelConfig, tokens, caches, patches):
    x = _prefix_seq(params, cfg, tokens, patches)
    b, s, _ = x.shape
    positions = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32), (b, s))
    x, _, new_caches = lm_model._run_blocks(params, cfg, x, positions,
                                            "prefill", caches=caches)
    x = apply_norm(cfg.norm, x[:, -1:], params["final_norm"])
    return lm_model._head(params, cfg, x)[:, 0], new_caches


def decode_step(params, cfg: ModelConfig, token, pos, caches):
    return lm_model.decode_step(params, cfg, token, pos, caches)
