"""RecurrentGemma / Griffin hybrid: RG-LRU recurrent blocks + local MQA
attention in a repeating (rec, rec, attn) pattern; long_500k-capable
(bounded window + O(1) recurrent state).

38 layers = 12 scanned (rec, rec, attn) triples + 2 trailing rec blocks
(kept unscanned to preserve the published depth).
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.core.policy import get_policy
from repro.layers import attention, mlp, rglru
from repro.layers.attention import AttnConfig, KVCache
from repro.layers.common import apply_norm, embed_init, norm_init, softcap
from repro.models import lm as lm_model
from repro.parallel import act_sharding as act


def _rg_cfg(cfg: ModelConfig) -> rglru.RGLRUConfig:
    return rglru.RGLRUConfig(cfg.d_model, cfg.d_rnn or cfg.d_model,
                             cfg.conv_width)


def _attn_cfg(cfg: ModelConfig) -> AttnConfig:
    return AttnConfig(
        d_model=cfg.d_model, n_heads=cfg.n_heads,
        n_kv_heads=cfg.n_kv_heads, head_dim=cfg.head_dim_,
        rope_theta=cfg.rope_theta, rotary_pct=cfg.rotary_pct,
        window=cfg.window, causal=True, attn_softcap=cfg.attn_softcap,
    )


def _pattern(cfg: ModelConfig):
    pat = cfg.rec_pattern or ("rec", "rec", "attn")
    n_triples = cfg.n_layers // len(pat)
    n_tail = cfg.n_layers - n_triples * len(pat)
    return pat, n_triples, n_tail


def _block_init(key, cfg: ModelConfig, kind: str, dtype):
    k1, k2 = jax.random.split(key)
    p = {"ln1": norm_init(cfg.norm, cfg.d_model, dtype),
         "ln2": norm_init(cfg.norm, cfg.d_model, dtype)}
    if kind == "rec":
        p["rec"] = rglru.init(k1, _rg_cfg(cfg), dtype)
    else:
        p["attn"] = attention.init(k1, _attn_cfg(cfg), dtype)
    p["mlp"] = mlp.init(k2, cfg.d_model, cfg.d_ff, dtype)
    return p


def init(key, cfg: ModelConfig):
    dtype = jnp.dtype(cfg.param_dtype)
    pat, n_triples, n_tail = _pattern(cfg)
    ke, kb, kt, kh = jax.random.split(key, 4)

    def group_init(gk):
        sub = jax.random.split(gk, len(pat))
        return {f"b{i}": _block_init(sub[i], cfg, kind, dtype)
                for i, kind in enumerate(pat)}

    params = {
        "embed": {"w": embed_init(ke, cfg.padded_vocab, cfg.d_model,
                                  dtype)},
        "blocks": jax.vmap(group_init)(jax.random.split(kb, n_triples)),
        "final_norm": norm_init(cfg.norm, cfg.d_model, dtype),
    }
    tails = jax.random.split(kt, max(n_tail, 1))
    params["tail"] = [_block_init(tails[i], cfg, "rec", dtype)
                      for i in range(n_tail)]
    return params


def init_cache(cfg: ModelConfig, batch: int, max_len: int,
               dtype=jnp.bfloat16):
    pat, n_triples, n_tail = _pattern(cfg)
    rg = _rg_cfg(cfg)
    cap = min(cfg.window or max_len, max_len)
    group = {}
    for i, kind in enumerate(pat):
        if kind == "rec":
            s = rglru.init_state(batch, rg, dtype)
            group[f"b{i}"] = rglru.RGLRUState(
                *(jnp.broadcast_to(a, (n_triples,) + a.shape) for a in s))
        else:
            c = attention.init_cache(batch, cap, _attn_cfg(cfg), dtype)
            group[f"b{i}"] = KVCache(
                *(jnp.broadcast_to(a, (n_triples,) + a.shape) for a in c))
    tail = [rglru.init_state(batch, rg, dtype) for _ in range(n_tail)]
    return {"groups": group, "tail": tail}


def _apply_block(bp, cfg: ModelConfig, kind: str, x, positions, policy,
                 mode: str, cache, pos):
    h = apply_norm(cfg.norm, x, bp["ln1"])
    if kind == "rec":
        if mode == "decode":
            a, cache = rglru.decode_step(bp["rec"], _rg_cfg(cfg), h, cache,
                                         policy, "block/rec")
        else:
            a, cache = rglru.forward(bp["rec"], _rg_cfg(cfg), h, cache,
                                     policy, "block/rec")
    else:
        acfg = _attn_cfg(cfg)
        if mode == "train":
            a = attention.forward(bp["attn"], acfg, h, positions, policy,
                                  "block/attn")
        elif mode == "prefill":
            a, cache = attention.prefill(bp["attn"], acfg, h, positions,
                                         cache, policy, "block/attn")
        else:
            a, cache = attention.decode_step(bp["attn"], acfg, h, pos,
                                             cache, policy, "block/attn")
    x = x + a
    h = apply_norm(cfg.norm, x, bp["ln2"])
    f = mlp.forward(bp["mlp"], h, policy, "block/mlp", cfg.act)
    return x + f, cache


def _run(params, cfg: ModelConfig, x, positions, mode, caches, pos):
    policy = get_policy(cfg.precision_policy)
    pat, n_triples, n_tail = _pattern(cfg)

    def group_step(h, xs):
        h = act.batch_seq(h)
        gp, gc = xs
        new_gc = {}
        for i, kind in enumerate(pat):
            h, nc = _apply_block(gp[f"b{i}"], cfg, kind, h, positions,
                                 policy, mode, gc[f"b{i}"], pos)
            new_gc[f"b{i}"] = nc
        return h, new_gc

    step = group_step
    if cfg.remat != "none" and mode == "train":
        step = jax.checkpoint(group_step)
    x, new_groups = jax.lax.scan(step, x,
                                 (params["blocks"], caches["groups"]))
    new_tail = []
    for i in range(n_tail):
        x, nc = _apply_block(params["tail"][i], cfg, "rec", x, positions,
                             policy, mode, caches["tail"][i], pos)
        new_tail.append(nc)
    return x, {"groups": new_groups, "tail": new_tail}


def _logits(params, cfg, x):
    w = params["embed"]["w"]
    logits = jnp.dot(x, w.T.astype(x.dtype),
                     preferred_element_type=jnp.float32)
    logits = softcap(logits.astype(jnp.float32), cfg.logit_softcap)
    if cfg.padded_vocab != cfg.vocab:
        col = jnp.arange(cfg.padded_vocab)
        logits = jnp.where(col < cfg.vocab, logits, -1e30)
    return act.logits(logits)


def train_logits(params, cfg: ModelConfig, tokens):
    b, s = tokens.shape
    positions = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32), (b, s))
    x = jnp.take(params["embed"]["w"], tokens, axis=0).astype(
        jnp.dtype(cfg.compute_dtype))
    caches = init_cache(cfg, b, max_len=s)
    x, _ = _run(params, cfg, x, positions, "train", caches, None)
    x = apply_norm(cfg.norm, x, params["final_norm"])
    return _logits(params, cfg, x), jnp.zeros((), jnp.float32)


def loss_fn(params, cfg: ModelConfig, batch):
    from repro.models.losses import fused_chunked_xent
    tokens = batch["tokens"]
    inp, tgt = tokens[:, :-1], tokens[:, 1:]
    b, s = inp.shape
    positions = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32), (b, s))
    x = jnp.take(params["embed"]["w"], inp, axis=0).astype(
        jnp.dtype(cfg.compute_dtype))
    caches = init_cache(cfg, b, max_len=s)
    x, _ = _run(params, cfg, x, positions, "train", caches, None)
    x = apply_norm(cfg.norm, x, params["final_norm"])
    mask = batch.get("mask")
    loss, m = fused_chunked_xent(
        x, lambda xc: _logits(params, cfg, xc), tgt,
        mask[:, 1:] if mask is not None else None)
    return loss, {**m, "aux": jnp.zeros(())}


def prefill(params, cfg: ModelConfig, tokens, caches):
    b, s = tokens.shape
    positions = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32), (b, s))
    x = jnp.take(params["embed"]["w"], tokens, axis=0).astype(
        jnp.dtype(cfg.compute_dtype))
    x, new_caches = _run(params, cfg, x, positions, "prefill", caches, None)
    x = apply_norm(cfg.norm, x[:, -1:], params["final_norm"])
    return _logits(params, cfg, x)[:, 0], new_caches


def decode_step(params, cfg: ModelConfig, token, pos, caches):
    x = jnp.take(params["embed"]["w"], token, axis=0).astype(
        jnp.dtype(cfg.compute_dtype))
    x, new_caches = _run(params, cfg, x, pos[:, None], "decode", caches,
                         pos)
    x = apply_norm(cfg.norm, x, params["final_norm"])
    return _logits(params, cfg, x)[:, 0], new_caches
