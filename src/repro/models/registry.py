"""Uniform model API across families + dry-run input specs.

build(cfg) -> ModelAPI with:
  init(key) -> params
  loss_fn(params, batch) -> (loss, metrics)      [train]
  prefill(params, batch, caches) -> (logits, caches)
  decode_step(params, batch, caches) -> (logits, caches)
  init_cache(batch_size, max_len) -> caches

input_specs(cfg, shape) -> batch of jax.ShapeDtypeStruct — the dry-run
stand-ins (weak-type-correct, shardable, no allocation).

Frontend stubs (assignment): seamless frames_len = seq_len // 4 at
frontend_dim; internvl2 patch embeddings (n_patches, vit_dim) prepended
to the token sequence.
"""
from __future__ import annotations

import contextlib
import dataclasses
import re
from typing import Any, Callable, Dict, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import InputShape, ModelConfig
from repro.models import encdec, griffin, lm, rwkv, vlm


@dataclasses.dataclass(frozen=True)
class ProjGroup:
    """One tunable projection group of an architecture.

    ``pattern`` is the policy-rule regex matching every parameter path the
    group's matmuls route through (the same paths the layers pass to
    ``PrecisionPolicy.spec_for``); (d_in, d_out, count) give the matmul
    shape the accelerator models score (count = matmuls of that shape per
    forward pass).
    """

    name: str
    pattern: str
    d_in: int
    d_out: int
    count: int

    @property
    def macs_per_token(self) -> int:
        return self.d_in * self.d_out * self.count


def projection_groups(cfg: ModelConfig) -> Tuple["ProjGroup", ...]:
    """The per-layer precision-tuning units of an architecture — what
    ``repro.autotune`` enumerates candidates over. Grouping is by role
    (qkv / attn-out / ffn-in / ffn-out / head), the granularity at which
    mixed-precision schemes are actually deployed (paper Appendix B).

    Patterns must match the literal paths the layers pass to
    ``PrecisionPolicy.spec_for`` ('block/full/attn/wq', 'block/mix/w_r',
    'block/rec/w_in_rnn', 'dec/xattn/wo', ...): a pattern that matches
    nothing makes the rule dead at serve time and the divergence probe
    silently measure zero.
    """
    hd = cfg.head_dim_
    groups = []
    # layers that carry attention / per-family projection counts
    n_attn = cfg.n_layers
    n_ffn = cfg.n_layers
    if cfg.family == "griffin":
        # (rec, rec, attn) repeating pattern + trailing blocks: only the
        # 'attn' slots have attention, every block has an MLP
        pat = cfg.rec_pattern or ("rec", "rec", "attn")
        n_triples = cfg.n_layers // len(pat)
        tail = pat[:cfg.n_layers - n_triples * len(pat)]
        n_attn = n_triples * pat.count("attn") + tail.count("attn")
    elif cfg.family == "encdec":
        # encoder self + decoder self + decoder cross-attention (the
        # xattn paths match the same attn/w* patterns)
        n_enc = cfg.n_enc_layers or cfg.n_layers
        n_attn = n_enc + 2 * cfg.n_layers
        n_ffn = n_enc + cfg.n_layers
    if cfg.family in ("lm", "vlm", "griffin", "encdec"):
        groups += [
            ProjGroup("attn_qkv", r"attn/w[qkv]$", cfg.d_model,
                      (cfg.n_heads + 2 * cfg.n_kv_heads) * hd, n_attn),
            ProjGroup("attn_wo", r"attn/wo$", cfg.n_heads * hd,
                      cfg.d_model, n_attn),
        ]
    if cfg.family == "rwkv":
        groups += [
            ProjGroup("tmix_rkvg", r"mix/w_[rkvg]$", cfg.d_model,
                      cfg.d_model, 4 * cfg.n_layers),
            ProjGroup("tmix_out", r"mix/w_o$", cfg.d_model, cfg.d_model,
                      cfg.n_layers),
            ProjGroup("cmix", r"mix/c_(key|val|rec)$", cfg.d_model,
                      cfg.d_ff, 2 * cfg.n_layers),
        ]
    if cfg.family == "griffin" and cfg.d_rnn:
        n_rec = cfg.n_layers - n_attn
        groups += [
            ProjGroup("rglru_in", r"rec/w_in_(rnn|gate)$", cfg.d_model,
                      cfg.d_rnn, 2 * n_rec),
            ProjGroup("rglru_out", r"rec/w_out$", cfg.d_rnn, cfg.d_model,
                      n_rec),
        ]
    if cfg.moe:
        groups.append(ProjGroup(
            "moe_experts", r"moe/experts$", cfg.d_model, cfg.moe.d_expert,
            3 * cfg.moe.top_k * cfg.n_layers))
    elif cfg.family != "rwkv":
        groups += [
            ProjGroup("ffn_in", r"mlp/w_(gate|up)$", cfg.d_model,
                      cfg.d_ff, 2 * n_ffn),
            ProjGroup("ffn_out", r"mlp/w_down$", cfg.d_ff, cfg.d_model,
                      n_ffn),
        ]
    if cfg.family == "vlm":
        groups.append(ProjGroup(
            "projector", r"projector/fc[12]$", cfg.vit_dim or cfg.d_model,
            cfg.d_model, 2))
    groups.append(ProjGroup(
        "head", r"lm_head|embed|frontend_proj", cfg.d_model,
        cfg.padded_vocab, 1))
    return tuple(groups)


def _lm_projection_paths(cfg: ModelConfig
                         ) -> Callable[[str], Optional[str]]:
    kinds = lm.group_kinds(cfg)

    def path_for(p: str) -> Optional[str]:
        m = re.fullmatch(r"blocks/b(\d+)/attn/(w[qkvo])", p)
        if m:
            return f"block/{kinds[int(m.group(1))]}/attn/{m.group(2)}"
        m = re.fullmatch(r"blocks/b\d+/mlp/(w_(?:gate|up|down))", p)
        if m:
            return f"block/mlp/{m.group(1)}"
        if re.fullmatch(r"blocks/b\d+/moe/(?:w_gate|w_up|w_down)", p):
            return "block/moe/experts"
        return None

    return path_for


def _vlm_projection_paths(cfg: ModelConfig
                          ) -> Callable[[str], Optional[str]]:
    base = _lm_projection_paths(cfg)

    def path_for(p: str) -> Optional[str]:
        m = re.fullmatch(r"projector/(fc[12])", p)
        if m:
            return f"projector/{m.group(1)}"
        return base(p)

    return path_for


def _rwkv_projection_paths(cfg: ModelConfig
                           ) -> Callable[[str], Optional[str]]:
    def path_for(p: str) -> Optional[str]:
        m = re.fullmatch(r"blocks/mix/(w_[rkvgo]|c_(?:key|val|rec))", p)
        if m:
            return f"block/mix/{m.group(1)}"
        return None

    return path_for


def _griffin_projection_paths(cfg: ModelConfig
                              ) -> Callable[[str], Optional[str]]:
    def path_for(p: str) -> Optional[str]:
        m = re.fullmatch(
            r"(?:blocks/b\d+|tail/\d+)/rec/(w_in_rnn|w_in_gate|w_out)", p)
        if m:
            return f"block/rec/{m.group(1)}"
        m = re.fullmatch(r"(?:blocks/b\d+|tail/\d+)/attn/(w[qkvo])", p)
        if m:
            return f"block/attn/{m.group(1)}"
        m = re.fullmatch(
            r"(?:blocks/b\d+|tail/\d+)/mlp/(w_(?:gate|up|down))", p)
        if m:
            return f"block/mlp/{m.group(1)}"
        return None

    return path_for


def _encdec_projection_paths(cfg: ModelConfig
                             ) -> Callable[[str], Optional[str]]:
    def path_for(p: str) -> Optional[str]:
        if p == "frontend_proj":
            return "frontend_proj"
        m = re.fullmatch(r"enc_blocks/attn/(w[qkvo])", p)
        if m:
            return f"enc/attn/{m.group(1)}"
        m = re.fullmatch(r"enc_blocks/mlp/(w_(?:gate|up|down))", p)
        if m:
            return f"enc/mlp/{m.group(1)}"
        m = re.fullmatch(r"dec_blocks/(attn|xattn)/(w[qkvo])", p)
        if m:
            return f"dec/{m.group(1)}/{m.group(2)}"
        m = re.fullmatch(r"dec_blocks/mlp/(w_(?:gate|up|down))", p)
        if m:
            return f"dec/mlp/{m.group(1)}"
        return None

    return path_for


_PROJECTION_PATHS = {
    "lm": _lm_projection_paths,
    "vlm": _vlm_projection_paths,
    "rwkv": _rwkv_projection_paths,
    "griffin": _griffin_projection_paths,
    "encdec": _encdec_projection_paths,
}


def projection_paths(cfg: ModelConfig) -> Callable[[str], Optional[str]]:
    """Param-tree container path -> runtime policy path for every
    projection that routes through the precision policy (the map
    ``quant.prepare.prepare_params`` consumes). Paths the family never
    routes (embeddings, norms, MoE router, recurrence gates) resolve to
    None and stay untouched by preparation."""
    return _PROJECTION_PATHS[cfg.family](cfg)


def _prepare_fn(cfg: ModelConfig) -> Callable:
    def prepare(params, policy, act_scales=None):
        from repro.quant.prepare import prepare_params
        return prepare_params(params, policy, projection_paths(cfg),
                              act_scales=act_scales)

    return prepare


# families eligible for the blocked decode fast path: decode_step must
# consume a {'token', 'pos'} batch, emit last-position logits, and keep
# batch rows independent — AND the masked pad steps a budget-exhausted
# slot keeps receiving inside a block must be causally invisible. That
# holds for position-tagged KV caches (the pad write at position 0 is
# overwritten/masked exactly as under per-token dispatch) but NOT for
# recurrent state (rwkv/griffin fold every consumed token into O(1)
# state, so the block-vs-tick pad cadence difference diverges the
# token streams — measured, not hypothetical); encdec's decode state
# only exists after prefill, so it cannot serve through the engine's
# decode program at all. Mirror of
# ``repro.serving.engine._FAST_PREFILL_FAMILIES`` for new families.
_BLOCK_DECODE_FAMILIES = ("lm", "vlm")


def block_decode_eligible(cfg: ModelConfig) -> bool:
    return cfg.family in _BLOCK_DECODE_FAMILIES


class DecodeCarry(NamedTuple):
    """Per-slot scan state of the blocked decode program.

    All arrays are batch-leading (B = engine slots). ``rem`` is the
    remaining token budget (0 = inactive/freed slot); ``taken`` counts
    the steps a slot actually took inside the current block (the host
    resets it to 0 per dispatch and replays ``tokens[:taken]`` — with
    EOS stopping, ``rem`` alone no longer determines the active
    prefix). ``stops`` holds each slot's stop ids (-1 = unused slot,
    never matches a real token); ``temp``/``top_k``/``top_p`` are the
    per-slot sampling parameters and ``keys`` the (B, 2) uint32 PRNG
    keys the sampler threads through the scan."""

    tok: Any     # (B,)  int32 current input token
    pos: Any     # (B,)  int32 absolute position
    rem: Any     # (B,)  int32 remaining budget, 0 = inactive
    taken: Any   # (B,)  int32 steps taken this block
    stops: Any   # (B, K) int32 stop ids, -1 = unused
    temp: Any    # (B,)  f32 temperature, <= 0 = greedy
    top_k: Any   # (B,)  int32, 0 = unrestricted
    top_p: Any   # (B,)  f32
    keys: Any    # (B, 2) uint32 PRNG keys


def make_block_decode(api: "ModelAPI", n: int, policy=None,
                      sample: bool = False, tracer=None,
                      fused: bool = False) -> Callable:
    """Generic multi-token decode block: a ``lax.scan`` of ``n``
    ``api.decode_step`` calls with on-device token selection.

    Returns ``fn(params, carry, state) -> (tokens, carry, state)`` with
    ``carry`` a :class:`DecodeCarry` and ``tokens`` the (n, B) int32
    trajectory (rows past a slot's ``taken`` are garbage the host
    ignores). Slots with an exhausted budget are masked: they feed the
    pad token at their current position — exactly what the per-token
    engine feeds idle slots — and stop advancing, so a host driving
    blocks of n is
    token-for-token identical to one dispatching single steps, while
    syncing once per block instead of once per token. A selected token
    matching one of the slot's ``stops`` zeroes ``rem`` on device (EOS
    stopping): the slot keeps its stop token, goes inactive for the
    rest of the block, and the host frees it at the next sync. Callers
    jit the result (one compile per distinct ``(n, sample)``).

    ``sample=False`` selects greedy argmax for every slot;
    ``sample=True`` compiles ``models.sampling.sample_tokens`` into the
    scan — greedy rows (``temp <= 0``) still take the bit-identical
    argmax, so one program serves mixed batches, and every active row
    consumes exactly one key split per step (sampled streams are
    invariant to ``decode_block``).

    Weight operands are STAGED once per block
    (``quant.prepare.stage_params``): fake-quant int projections
    materialize their compute-dtype dequantized form — the identical
    array the executors rebuild from packed storage every call — before
    the scan, so the n steps reuse it instead of re-deriving it n
    times. Bit-exact, and engine storage stays packed.

    ``policy`` is the already-resolved PrecisionPolicy the staging walk
    routes specs from; engines pass their eagerly-resolved policy so a
    ``plan:`` file that disappears after construction (or a transient
    registered policy) cannot fail the first blocked dispatch. Resolved
    here — never at trace time — when omitted.

    ``fused=True`` routes the block through the fused Pallas executors
    instead of per-block staging: the staging walk is skipped entirely
    (prepared storage — packed nibbles, fp codes, int8 rows — enters
    the kernels as operands and dequantizes in-register), and the whole
    scan is traced under ``layers.mplinear.executor_variant('fused')``
    so every eligible projection takes the fused datapath. No staged
    compute-dtype operand is ever materialized
    (``quant.prepare.count_staged`` observes zero).

    ``tracer`` (an :class:`repro.obs.Tracer`) marks each jax trace of
    the program with an instant event: the body below runs exactly once
    per compile (jit caches the traced program afterwards), so the
    marker pairs with the wall-clock ``compile:*`` span the engine's
    ``traced_jit`` wrapper records around the same dispatch."""
    if not block_decode_eligible(api.cfg):
        raise ValueError(
            f"family {api.cfg.family!r} is not eligible for blocked "
            f"decode (want one of {_BLOCK_DECODE_FAMILIES})")
    if policy is None:
        from repro.core.policy import get_policy
        policy = get_policy(api.cfg.precision_policy)

    def run(params, carry, state):
        from repro.layers.mplinear import executor_variant
        from repro.models.sampling import sample_tokens
        from repro.quant.prepare import stage_params
        if tracer is not None:
            # this function body executes only while jax traces the
            # program (once per compile): an instant here timestamps
            # the trace phase of each block-decode compilation
            tracer.instant(f"jax_trace:block_decode[n={n}]",
                           cat="compile")
        variant = contextlib.nullcontext()
        if fused:
            variant = executor_variant("fused")
        else:
            params = stage_params(params, policy,
                                  projection_paths(api.cfg))
        c = carry

        def body(inner, _):
            tok, pos, rem, taken, keys, st = inner
            active = rem > 0
            # inactive rows keep their REAL position: the pad write must
            # land on the slot's current frontier (where the next real
            # write — decode or prefill chunk — overwrites it before any
            # query attends), never on position 0, which may hold live
            # prompt context for a slot still mid-prefill
            batch = {"token": jnp.where(active, tok, 0)[:, None],
                     "pos": pos}
            logits, st = api.decode_step(params, batch, st)
            if sample:
                keys2, nxt = sample_tokens(keys, logits, c.temp,
                                           c.top_k, c.top_p)
                keys = jnp.where(active[:, None], keys2, keys)
            else:
                nxt = jnp.argmax(logits, axis=-1).astype(jnp.int32)
            hit = (nxt[:, None] == c.stops).any(axis=-1) & active
            tok = jnp.where(active, nxt, tok)
            pos = jnp.where(active, pos + 1, pos)
            rem = jnp.where(active, jnp.where(hit, 0, rem - 1), rem)
            taken = taken + active.astype(jnp.int32)
            return (tok, pos, rem, taken, keys, st), nxt

        with variant:
            (tok, pos, rem, taken, keys, state), tokens = jax.lax.scan(
                body, (c.tok, c.pos, c.rem, c.taken, c.keys, state),
                None, length=n)
        out = c._replace(tok=tok, pos=pos, rem=rem, taken=taken,
                         keys=keys)
        return tokens, out, state

    return run


class ModelAPI(NamedTuple):
    cfg: ModelConfig
    init: Callable
    loss_fn: Callable
    prefill: Callable
    decode_step: Callable
    init_cache: Callable
    # prepare(params, policy) -> params with each projection weight in
    # its deployment storage format (see quant/prepare.py)
    prepare: Callable = None
    # prefill_chunk(params, batch, caches) -> caches: position-offset
    # prefill continuation for the continuous engine (batch carries
    # 'tokens' (B, S), 'offsets' (B,), 'lengths' (B,)); None for
    # families whose prefill is not a pure token-cache fill
    prefill_chunk: Callable = None


def build(cfg: ModelConfig) -> ModelAPI:
    if cfg.family == "lm":
        return ModelAPI(
            cfg,
            lambda key: lm.init(key, cfg),
            lambda p, batch: lm.loss_fn(p, cfg, batch),
            lambda p, batch, caches: lm.prefill(p, cfg, batch["tokens"],
                                                caches),
            lambda p, batch, caches: lm.decode_step(
                p, cfg, batch["token"], batch["pos"], caches),
            lambda bsz, max_len: lm.init_cache(cfg, bsz, max_len),
            _prepare_fn(cfg),
            lambda p, batch, caches: lm.prefill_chunk(
                p, cfg, batch["tokens"], batch["offsets"],
                batch["lengths"], caches),
        )
    if cfg.family == "rwkv":
        return ModelAPI(
            cfg,
            lambda key: rwkv.init(key, cfg),
            lambda p, batch: rwkv.loss_fn(p, cfg, batch),
            lambda p, batch, caches: rwkv.prefill(p, cfg, batch["tokens"],
                                                  caches),
            lambda p, batch, caches: rwkv.decode_step(
                p, cfg, batch["token"], batch["pos"], caches),
            lambda bsz, max_len: rwkv.init_cache(cfg, bsz, max_len),
            _prepare_fn(cfg),
        )
    if cfg.family == "griffin":
        return ModelAPI(
            cfg,
            lambda key: griffin.init(key, cfg),
            lambda p, batch: griffin.loss_fn(p, cfg, batch),
            lambda p, batch, caches: griffin.prefill(
                p, cfg, batch["tokens"], caches),
            lambda p, batch, caches: griffin.decode_step(
                p, cfg, batch["token"], batch["pos"], caches),
            lambda bsz, max_len: griffin.init_cache(cfg, bsz, max_len),
            _prepare_fn(cfg),
        )
    if cfg.family == "encdec":
        return ModelAPI(
            cfg,
            lambda key: encdec.init(key, cfg),
            lambda p, batch: encdec.loss_fn(p, cfg, batch),
            lambda p, batch, caches: encdec.prefill(
                p, cfg, batch["tokens"], caches, batch["frames"]),
            lambda p, batch, state: encdec.decode_step(
                p, cfg, batch["token"], batch["pos"], state),
            lambda bsz, max_len: encdec.init_cache(cfg, bsz, max_len),
            _prepare_fn(cfg),
        )
    if cfg.family == "vlm":
        return ModelAPI(
            cfg,
            lambda key: vlm.init(key, cfg),
            lambda p, batch: vlm.loss_fn(p, cfg, batch),
            lambda p, batch, caches: vlm.prefill(
                p, cfg, batch["tokens"], caches, batch["patches"]),
            lambda p, batch, caches: vlm.decode_step(
                p, cfg, batch["token"], batch["pos"], caches),
            lambda bsz, max_len: vlm.init_cache(
                cfg, bsz, max_len + (cfg.n_patches or 0)),
            _prepare_fn(cfg),
        )
    raise ValueError(cfg.family)


def input_specs(cfg: ModelConfig, shape: InputShape) -> Dict[str, Any]:
    """ShapeDtypeStruct batch for (arch x shape), per step kind."""
    b, s = shape.global_batch, shape.seq_len
    i32 = jnp.int32
    f32 = jnp.float32

    def sds(shp, dt=i32):
        return jax.ShapeDtypeStruct(shp, dt)

    if shape.kind == "train":
        batch = {"tokens": sds((b, s + 1))}
        if cfg.family == "encdec":
            batch["frames"] = sds((b, s // 4, cfg.frontend_dim), f32)
        if cfg.family == "vlm":
            batch["patches"] = sds((b, cfg.n_patches, cfg.vit_dim), f32)
        return batch
    if shape.kind == "prefill":
        batch = {"tokens": sds((b, s))}
        if cfg.family == "encdec":
            batch["frames"] = sds((b, s // 4, cfg.frontend_dim), f32)
        if cfg.family == "vlm":
            batch["patches"] = sds((b, cfg.n_patches, cfg.vit_dim), f32)
        return batch
    if shape.kind == "decode":
        return {"token": sds((b, 1)), "pos": sds((b,))}
    raise ValueError(shape.kind)


def materialize_batch(cfg: ModelConfig, shape: InputShape, seed: int = 0
                      ) -> Dict[str, jax.Array]:
    """Concrete random batch matching input_specs (smoke tests)."""
    specs = input_specs(cfg, shape)
    key = jax.random.PRNGKey(seed)
    out = {}
    for name, spec in specs.items():
        key, k = jax.random.split(key)
        if spec.dtype == jnp.int32:
            if name == "pos":
                out[name] = jnp.full(spec.shape, shape.seq_len - 1,
                                     jnp.int32)
            else:
                out[name] = jax.random.randint(k, spec.shape, 0,
                                               min(cfg.vocab, 1000))
        else:
            out[name] = jax.random.normal(k, spec.shape, spec.dtype)
    return out
