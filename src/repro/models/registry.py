"""Uniform model API across families + dry-run input specs.

build(cfg) -> ModelAPI with:
  init(key) -> params
  loss_fn(params, batch) -> (loss, metrics)      [train]
  prefill(params, batch, caches) -> (logits, caches)
  decode_step(params, batch, caches) -> (logits, caches)
  init_cache(batch_size, max_len) -> caches

input_specs(cfg, shape) -> batch of jax.ShapeDtypeStruct — the dry-run
stand-ins (weak-type-correct, shardable, no allocation).

Frontend stubs (assignment): seamless frames_len = seq_len // 4 at
frontend_dim; internvl2 patch embeddings (n_patches, vit_dim) prepended
to the token sequence.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, NamedTuple

import jax
import jax.numpy as jnp

from repro.configs.base import InputShape, ModelConfig
from repro.models import encdec, griffin, lm, rwkv, vlm


class ModelAPI(NamedTuple):
    cfg: ModelConfig
    init: Callable
    loss_fn: Callable
    prefill: Callable
    decode_step: Callable
    init_cache: Callable


def build(cfg: ModelConfig) -> ModelAPI:
    if cfg.family == "lm":
        return ModelAPI(
            cfg,
            lambda key: lm.init(key, cfg),
            lambda p, batch: lm.loss_fn(p, cfg, batch),
            lambda p, batch, caches: lm.prefill(p, cfg, batch["tokens"],
                                                caches),
            lambda p, batch, caches: lm.decode_step(
                p, cfg, batch["token"], batch["pos"], caches),
            lambda bsz, max_len: lm.init_cache(cfg, bsz, max_len),
        )
    if cfg.family == "rwkv":
        return ModelAPI(
            cfg,
            lambda key: rwkv.init(key, cfg),
            lambda p, batch: rwkv.loss_fn(p, cfg, batch),
            lambda p, batch, caches: rwkv.prefill(p, cfg, batch["tokens"],
                                                  caches),
            lambda p, batch, caches: rwkv.decode_step(
                p, cfg, batch["token"], batch["pos"], caches),
            lambda bsz, max_len: rwkv.init_cache(cfg, bsz, max_len),
        )
    if cfg.family == "griffin":
        return ModelAPI(
            cfg,
            lambda key: griffin.init(key, cfg),
            lambda p, batch: griffin.loss_fn(p, cfg, batch),
            lambda p, batch, caches: griffin.prefill(
                p, cfg, batch["tokens"], caches),
            lambda p, batch, caches: griffin.decode_step(
                p, cfg, batch["token"], batch["pos"], caches),
            lambda bsz, max_len: griffin.init_cache(cfg, bsz, max_len),
        )
    if cfg.family == "encdec":
        return ModelAPI(
            cfg,
            lambda key: encdec.init(key, cfg),
            lambda p, batch: encdec.loss_fn(p, cfg, batch),
            lambda p, batch, caches: encdec.prefill(
                p, cfg, batch["tokens"], caches, batch["frames"]),
            lambda p, batch, state: encdec.decode_step(
                p, cfg, batch["token"], batch["pos"], state),
            lambda bsz, max_len: encdec.init_cache(cfg, bsz, max_len),
        )
    if cfg.family == "vlm":
        return ModelAPI(
            cfg,
            lambda key: vlm.init(key, cfg),
            lambda p, batch: vlm.loss_fn(p, cfg, batch),
            lambda p, batch, caches: vlm.prefill(
                p, cfg, batch["tokens"], caches, batch["patches"]),
            lambda p, batch, caches: vlm.decode_step(
                p, cfg, batch["token"], batch["pos"], caches),
            lambda bsz, max_len: vlm.init_cache(
                cfg, bsz, max_len + (cfg.n_patches or 0)),
        )
    raise ValueError(cfg.family)


def input_specs(cfg: ModelConfig, shape: InputShape) -> Dict[str, Any]:
    """ShapeDtypeStruct batch for (arch x shape), per step kind."""
    b, s = shape.global_batch, shape.seq_len
    i32 = jnp.int32
    f32 = jnp.float32

    def sds(shp, dt=i32):
        return jax.ShapeDtypeStruct(shp, dt)

    if shape.kind == "train":
        batch = {"tokens": sds((b, s + 1))}
        if cfg.family == "encdec":
            batch["frames"] = sds((b, s // 4, cfg.frontend_dim), f32)
        if cfg.family == "vlm":
            batch["patches"] = sds((b, cfg.n_patches, cfg.vit_dim), f32)
        return batch
    if shape.kind == "prefill":
        batch = {"tokens": sds((b, s))}
        if cfg.family == "encdec":
            batch["frames"] = sds((b, s // 4, cfg.frontend_dim), f32)
        if cfg.family == "vlm":
            batch["patches"] = sds((b, cfg.n_patches, cfg.vit_dim), f32)
        return batch
    if shape.kind == "decode":
        return {"token": sds((b, 1)), "pos": sds((b,))}
    raise ValueError(shape.kind)


def materialize_batch(cfg: ModelConfig, shape: InputShape, seed: int = 0
                      ) -> Dict[str, jax.Array]:
    """Concrete random batch matching input_specs (smoke tests)."""
    specs = input_specs(cfg, shape)
    key = jax.random.PRNGKey(seed)
    out = {}
    for name, spec in specs.items():
        key, k = jax.random.split(key)
        if spec.dtype == jnp.int32:
            if name == "pos":
                out[name] = jnp.full(spec.shape, shape.seq_len - 1,
                                     jnp.int32)
            else:
                out[name] = jax.random.randint(k, spec.shape, 0,
                                               min(cfg.vocab, 1000))
        else:
            out[name] = jax.random.normal(k, spec.shape, spec.dtype)
    return out
